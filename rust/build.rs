//! Build probe: AVX-512 intrinsics support.
//!
//! The `core::arch::x86_64` AVX-512 intrinsics (and the matching
//! `#[target_feature(enable = "avx512f")]`) stabilized in rustc 1.89.
//! The crate pins an older toolchain (see `rust-toolchain.toml`), so
//! the AVX-512 backend in `linalg/simd.rs` is compiled only when the
//! building compiler is new enough: `fednl_avx512` is set iff
//! `rustc --version` reports ≥ 1.89. On older compilers the runtime
//! dispatcher simply never offers the AVX-512 tier — `FEDNL_FORCE_ISA=
//! avx512` clamps down to AVX2 with a warning, and every test that
//! targets the AVX-512 path skips — so one source tree builds and
//! passes everywhere while newer toolchains get the full backend.

use std::process::Command;

fn main() {
    // Re-run only when the compiler changes, not on every source edit.
    println!("cargo:rerun-if-changed=build.rs");
    println!("cargo:rerun-if-env-changed=RUSTC");
    println!("cargo:rustc-check-cfg=cfg(fednl_avx512)");
    let rustc =
        std::env::var_os("RUSTC").unwrap_or_else(|| "rustc".into());
    let out = match Command::new(&rustc).arg("--version").output() {
        Ok(o) if o.status.success() => o.stdout,
        _ => return, // unknown compiler: leave the backend off
    };
    let version = String::from_utf8_lossy(&out);
    if version_at_least(&version, 1, 89) {
        println!("cargo:rustc-cfg=fednl_avx512");
    }
}

/// Parse "rustc 1.89.0 (…)" / "rustc 1.90.0-nightly (…)" and compare
/// against `(major, minor)`. Unparseable strings count as too old.
fn version_at_least(version: &str, major: u32, minor: u32) -> bool {
    let semver = match version.split_whitespace().nth(1) {
        Some(v) => v,
        None => return false,
    };
    let mut parts = semver.split(['.', '-', '+']);
    let maj: u32 = match parts.next().and_then(|s| s.parse().ok()) {
        Some(v) => v,
        None => return false,
    };
    let min: u32 = match parts.next().and_then(|s| s.parse().ok()) {
        Some(v) => v,
        None => return false,
    };
    maj > major || (maj == major && min >= minor)
}

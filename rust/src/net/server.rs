//! Multi-node master: accepts n client connections and exposes them as a
//! [`ClientPool`], so the unified round engine drives real distributed
//! training unchanged (paper §9.3 setting: n clients + one master, star
//! topology, one TCP connection per client).
//!
//! The pool is **streaming**: `submit_round` pushes the ROUND frame to
//! every participant before any reply is read, and `drain` surfaces one
//! decoded reply at a time, so the driver's incremental aggregation of
//! client i overlaps with the *other* clients' compute and network
//! transfer (their frames accumulate in the OS socket buffers while the
//! master aggregates; recv + decode themselves run on the master thread,
//! between commits).

use std::collections::VecDeque;
use std::net::TcpListener;

use anyhow::{Context, Result};

use super::framing::Channel;
use super::wire::{self, c2s, s2c};
use crate::algorithms::ClientMsg;
use crate::coordinator::{ClientFamily, ClientPool};

/// Master-side handle to n connected remote clients.
pub struct RemotePool {
    /// Channels indexed by registered client id.
    channels: Vec<Channel>,
    /// Algorithm family all clients declared at registration (pools
    /// are family-homogeneous; enforced during accept).
    family: ClientFamily,
    d: usize,
    alpha: f64,
    /// Client ids of the round in flight, in subset order; replies are
    /// read (and surfaced to `drain`) in this order.
    pending: VecDeque<u32>,
}

/// A bound-but-not-yet-populated master socket; lets callers learn the
/// ephemeral port before spawning clients.
pub struct Bound {
    listener: TcpListener,
}

impl Bound {
    pub fn bind(addr: &str) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(Self { listener })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept until exactly `n_clients` clients register.
    pub fn accept(self, n_clients: usize) -> Result<RemotePool> {
        RemotePool::accept_on(self.listener, n_clients)
    }
}

impl RemotePool {
    /// Listen on `addr` until exactly `n_clients` clients register.
    /// Clients may connect in any order; they self-identify with their
    /// id (dataset shard index).
    pub fn listen(addr: &str, n_clients: usize) -> Result<Self> {
        Bound::bind(addr)?.accept(n_clients)
    }

    fn accept_on(listener: TcpListener, n_clients: usize) -> Result<Self> {
        let mut slots: Vec<Option<(Channel, u8)>> =
            (0..n_clients).map(|_| None).collect();
        let mut d = 0usize;
        let mut registered = 0;
        while registered < n_clients {
            let (stream, _) = listener.accept()?;
            let mut ch = Channel::new(stream)?;
            let (tag, payload) = ch.recv()?;
            anyhow::ensure!(tag == c2s::REGISTER, "expected REGISTER");
            let (id, dim, family) = wire::decode_register(&payload)?;
            let id = id as usize;
            anyhow::ensure!(id < n_clients, "client id {id} out of range");
            anyhow::ensure!(slots[id].is_none(), "duplicate client id {id}");
            if d == 0 {
                d = dim as usize;
            } else {
                anyhow::ensure!(d == dim as usize, "dimension mismatch");
            }
            slots[id] = Some((ch, family));
            registered += 1;
        }
        let mut channels = Vec::with_capacity(n_clients);
        let mut family = None;
        for (id, s) in slots.into_iter().enumerate() {
            let (ch, f) = s.unwrap();
            let f = match f {
                wire::FAMILY_FEDNL => ClientFamily::FedNL,
                _ => ClientFamily::PP,
            };
            match family {
                None => family = Some(f),
                Some(prev) => anyhow::ensure!(
                    prev == f,
                    "client {id} registered as {f:?} but earlier clients \
                     registered as {prev:?}: pools are family-homogeneous"
                ),
            }
            channels.push(ch);
        }
        Ok(Self {
            channels,
            family: family.unwrap(),
            d,
            alpha: 0.0,
            pending: VecDeque::new(),
        })
    }

    fn broadcast(&mut self, tag: u8, payload: &[u8]) -> Result<()> {
        for ch in &mut self.channels {
            ch.send(tag, payload)?;
        }
        Ok(())
    }

    /// Politely shut all clients down.
    pub fn shutdown(&mut self) {
        let _ = self.broadcast(s2c::SHUTDOWN, &[]);
    }
}

impl ClientPool for RemotePool {
    fn n_clients(&self) -> usize {
        self.channels.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn kind_name(&self) -> &'static str {
        "remote"
    }

    fn family(&self) -> ClientFamily {
        self.family
    }

    fn default_alpha(&self) -> f64 {
        // The master does not know the remote compressor class until it
        // asks; clients reply to SET_ALPHA(NaN) with their α via ACK
        // payload — handled in `set_alpha`. Default conservative 1.0.
        if self.alpha > 0.0 {
            self.alpha
        } else {
            1.0
        }
    }

    fn set_alpha(&mut self, alpha: f64) {
        let payload = wire::encode_scalar(alpha);
        for ch in &mut self.channels {
            ch.send(s2c::SET_ALPHA, &payload).expect("set_alpha send");
        }
        let mut resolved = alpha;
        for ch in &mut self.channels {
            let (tag, p) = ch.recv().expect("set_alpha ack");
            assert_eq!(tag, c2s::ACK);
            if let Ok(a) = wire::decode_scalar(&p) {
                resolved = a; // clients echo the α they actually use
            }
        }
        self.alpha = resolved;
    }

    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    ) {
        assert!(self.pending.is_empty(), "previous round not fully drained");
        let payload = wire::encode_round(x, round, need_loss);
        // All sends complete before any receive: every participant
        // computes concurrently. (Family mismatches are caught by the
        // round engine against `self.family`, which the clients
        // declared at registration.)
        match subset {
            None => {
                for (ci, ch) in self.channels.iter_mut().enumerate() {
                    ch.send(s2c::ROUND, &payload).expect("round send");
                    self.pending.push_back(ci as u32);
                }
            }
            Some(s) => {
                for &ci in s {
                    self.channels[ci as usize]
                        .send(s2c::ROUND, &payload)
                        .expect("round send");
                    self.pending.push_back(ci);
                }
            }
        }
    }

    fn drain(&mut self) -> Vec<ClientMsg> {
        // One decoded reply per call, in subset order: while the caller
        // aggregates this message, the remaining clients keep computing
        // and their frames accumulate in the kernel socket buffers, so
        // the next recv rarely blocks on a non-straggler.
        match self.pending.pop_front() {
            None => Vec::new(),
            Some(ci) => {
                let (tag, p) =
                    self.channels[ci as usize].recv().expect("round reply");
                assert_eq!(tag, c2s::MSG);
                let m =
                    wire::decode_client_msg(&p).expect("decode client msg");
                // A reply must identify as the client whose channel it
                // came over — fail at the culprit, not later at the
                // commit buffer under an innocent client's id.
                assert_eq!(
                    m.client_id, ci as usize,
                    "client on channel {ci} replied with id {}",
                    m.client_id
                );
                vec![m]
            }
        }
    }

    fn eval_loss(&mut self, x: &[f64]) -> f64 {
        let payload = wire::encode_vec(x);
        self.broadcast(s2c::EVAL_LOSS, &payload).expect("eval broadcast");
        let mut sum = 0.0;
        for ch in &mut self.channels {
            let (tag, p) = ch.recv().expect("eval reply");
            assert_eq!(tag, c2s::LOSS);
            sum += wire::decode_scalar(&p).expect("loss");
        }
        sum / self.channels.len() as f64
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let payload = wire::encode_vec(x);
        self.broadcast(s2c::LOSS_GRAD, &payload).expect("grad broadcast");
        let inv_n = 1.0 / self.channels.len() as f64;
        let mut loss = 0.0;
        let mut g = vec![0.0; x.len()];
        for ch in &mut self.channels {
            let (tag, p) = ch.recv().expect("grad reply");
            assert_eq!(tag, c2s::GRAD);
            let (l, gi) = wire::decode_loss_grad(&p).expect("grad decode");
            loss += l;
            crate::linalg::vector::axpy(inv_n, &gi, &mut g);
        }
        (loss * inv_n, g)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        let payload = wire::encode_vec(x);
        self.broadcast(s2c::WARM_START, &payload).expect("warm broadcast");
        self.channels
            .iter_mut()
            .map(|ch| {
                let (tag, p) = ch.recv().expect("warm reply");
                assert_eq!(tag, c2s::WARM);
                wire::decode_vec(&p).expect("warm decode")
            })
            .collect()
    }

    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
        self.broadcast(s2c::STATE, &[]).expect("state broadcast");
        self.channels
            .iter_mut()
            .map(|ch| {
                let (tag, p) = ch.recv().expect("state reply");
                assert_eq!(tag, c2s::STATE);
                wire::decode_loss_grad(&p).expect("state decode")
            })
            .collect()
    }

    fn transport_bytes(&self) -> Option<(u64, u64)> {
        let up = self.channels.iter().map(|c| c.bytes_received).sum();
        let down = self.channels.iter().map(|c| c.bytes_sent).sum();
        Some((up, down))
    }
}

//! A tour of the compressor zoo: contraction quality, adaptivity and
//! wire cost of each compressor on the same Hessian-difference input —
//! the paper's §8/App. C-D story in one screen.
//!
//!     cargo run --release --example compressor_tour

use fednl::compressors::{by_name, distortion_sq, weighted_norm_sq, ALL_NAMES};
use fednl::linalg::packed::PackedUpper;
use fednl::metrics::report::Table;
use fednl::rng::{Pcg64, Rng};
use fednl::utils::human_bytes;

fn main() -> anyhow::Result<()> {
    let d = 64;
    let pu = PackedUpper::new(d);
    let mut rng = Pcg64::seed_from_u64(2024);
    // A realistic Hessian difference: mostly small entries, a few large
    // (the structure TopLEK exploits).
    let src: Vec<f64> = (0..pu.len())
        .map(|i| {
            let base = rng.next_gaussian() * 0.01;
            if i % 97 == 0 {
                base + rng.next_gaussian() * 2.0
            } else {
                base
            }
        })
        .collect();
    let total = weighted_norm_sq(&pu, &src);

    let trials = 300u64;
    let mut table = Table::new(&[
        "Compressor",
        "δ (theory)",
        "α = 1−√(1−δ)",
        "E‖C(x)−x‖²/‖x‖²",
        "bound 1−δ",
        "E[#values]",
        "E[wire]",
    ]);
    for name in ALL_NAMES {
        let mut c = by_name(name, d, 8, 1)?;
        let kind = c.kind(pu.len());
        let mut dist = 0.0;
        let mut nvals = 0.0;
        let mut bytes = 0.0;
        for r in 0..trials {
            let out = c.compress(&pu, &src, r);
            dist += distortion_sq(&pu, &src, &out) / total;
            nvals += out.values.len() as f64;
            bytes += out.wire_bytes() as f64;
        }
        table.row(&[
            c.name(),
            format!("{:.4}", kind.delta()),
            format!("{:.4}", kind.alpha()),
            format!("{:.4}", dist / trials as f64),
            format!("{:.4}", 1.0 - kind.delta()),
            format!("{:.1}", nvals / trials as f64),
            human_bytes((bytes / trials as f64) as u64),
        ]);
    }
    println!("{}", table.to_markdown());
    println!(
        "Note how TopLEK's realized contraction ≈ the bound (tight by \n\
         construction) while sending far fewer than k values, and how\n\
         RandSeqK matches RandK's statistics with a 1-call PRG + a\n\
         contiguous memory window."
    );
    Ok(())
}

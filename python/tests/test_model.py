"""Layer-2 correctness: fused oracle vs reference + autodiff, padding
contract, and AOT lowering sanity."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402

SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _problem(d, n, seed, lam=1e-3):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(d, n)))
    x = jnp.asarray(rng.normal(size=(d,)) * 0.5)
    w = jnp.full((n,), 1.0 / n)
    return a, x, w, jnp.asarray(lam)


@settings(max_examples=15, deadline=None)
@given(seed=SEEDS, d=st.integers(2, 20), n=st.integers(2, 40))
def test_oracle_matches_ref(seed, d, n):
    a, x, w, lam = _problem(d, n, seed)
    loss, grad, hess = model.oracle(a, x, w, lam)
    rl, rg, rh = ref.oracle_ref(a, x, w, lam)
    np.testing.assert_allclose(loss, rl, rtol=1e-12)
    np.testing.assert_allclose(grad, rg, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(hess, rh, rtol=1e-10, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(seed=SEEDS)
def test_oracle_matches_autodiff(seed):
    # ∇f and ∇²f from the closed forms must equal jax.grad / jax.hessian
    # of the loss — the strongest possible cross-check of Eq. (3)-(5).
    d, n = 6, 24
    a, x, w, lam = _problem(d, n, seed)
    _, grad, hess = model.oracle(a, x, w, lam)
    f = lambda xx: ref.loss_ref(a, xx, w, lam)  # noqa: E731
    np.testing.assert_allclose(grad, jax.grad(f)(x), rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(hess, jax.hessian(f)(x), rtol=1e-8, atol=1e-10)


def test_padding_contract():
    # Oracle on padded (d,n) with zero-weight/zero-column padding equals
    # oracle on the raw shape, embedded in the top-left block.
    d_raw, n_raw = 13, 37
    a, x, w, lam = _problem(d_raw, n_raw, 3)
    d, n = model.pad_shapes(d_raw, n_raw, bd=8, bn=16)
    a_pad = jnp.zeros((d, n)).at[:d_raw, :n_raw].set(a)
    x_pad = jnp.zeros((d,)).at[:d_raw].set(x)
    w_pad = jnp.zeros((n,)).at[:n_raw].set(w)
    loss, grad, hess = model.oracle(a_pad, x_pad, w_pad, lam)
    rl, rg, rh = ref.oracle_ref(a, x, w, lam)
    np.testing.assert_allclose(loss, rl, rtol=1e-12)
    np.testing.assert_allclose(grad[:d_raw], rg, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(
        hess[:d_raw, :d_raw], rh, rtol=1e-10, atol=1e-12
    )
    # Padding rows couple only through λI.
    np.testing.assert_allclose(grad[d_raw:], 0.0, atol=1e-15)


def test_grad_only_consistent_with_oracle():
    a, x, w, lam = _problem(10, 30, 11)
    l1, g1 = model.grad_only(a, x, w, lam)
    l2, g2, _ = model.oracle(a, x, w, lam)
    np.testing.assert_allclose(l1, l2, rtol=1e-13)
    np.testing.assert_allclose(g1, g2, rtol=1e-13)


def test_pad_shapes():
    assert model.pad_shapes(301, 350) == (304, 384)
    assert model.pad_shapes(16, 128) == (16, 128)


def test_lowering_produces_hlo_text():
    from compile import aot

    d, n, oracle_hlo, grad_hlo = aot.lower_shape(16, 64)
    assert (d, n) == (16, 128)
    assert "HloModule" in oracle_hlo and "HloModule" in grad_hlo
    # f64 end to end:
    assert "f64" in oracle_hlo

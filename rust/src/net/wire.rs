//! Wire encoding of the FedNL protocol messages (fixed-width LE fields;
//! paper §7 found fixed 32-bit index framing beats variable-width).
//!
//! # Unified tag table
//!
//! Since the streaming-coordination refactor the FedNL and FedNL-PP
//! command sets are **one protocol** — a client's algorithm family is
//! fixed at registration (its `ClientMode`), so the round exchange needs
//! no per-algorithm tags:
//!
//! | dir | tag            | payload                    | reply          |
//! |-----|----------------|----------------------------|----------------|
//! | s2c | `ROUND`      1 | round, need_loss, x        | `MSG`          |
//! | s2c | `EVAL_LOSS`  2 | x                          | `LOSS`         |
//! | s2c | `WARM_START` 3 | x⁰                         | `WARM`         |
//! | s2c | `SET_ALPHA`  5 | α                          | `ACK` (echo α) |
//! | s2c | `SHUTDOWN`   6 | —                          | —              |
//! | s2c | `LOSS_GRAD`  7 | x                          | `GRAD`         |
//! | s2c | `STATE`      8 | —                          | `STATE`        |
//! | c2s | `REGISTER`  10 | client id, d, family       | —              |
//! | c2s | `MSG`       11 | unified [`ClientMsg`]      |                |
//! | c2s | `LOSS`      12 | f64                        |                |
//! | c2s | `WARM`      13 | packed Hᵢ⁰                 |                |
//! | c2s | `ACK`       15 | f64                        |                |
//! | c2s | `GRAD`      16 | (f, ∇f)                    |                |
//! | c2s | `STATE`     17 | (lᵢ, gᵢ)                   |                |
//! | c2s | `DEREGISTER`18 | —                          | —              |
//!
//! A FedNL client answers `ROUND` with its Alg. 1 message; a PP client
//! answers the *same* tag with its Alg. 3 participation deltas — both
//! travel as the unified [`ClientMsg`] codec. The retired PP-specific
//! tags (`PP_ROUND` = 4, `PP_MSG` = 14) are left unassigned.
//!
//! # Liveness (fault-tolerant rounds)
//!
//! `DEREGISTER` announces a graceful leave: the master retires the
//! connection and certifies the client missing for the round in
//! flight; an abrupt EOF or a reply that misses the master's deadline
//! has the same effect. **Rejoin** reuses `REGISTER`: a deregistered
//! id reconnects and re-registers (same id, d and family) on the
//! master's retained listener; under FedNL-PP the master then resyncs
//! the client's server-tracked (lᵢ, gᵢ) through the existing `STATE`
//! pull on the fresh channel. No rejoin-specific tags exist.
//!
//! # Byte accounting
//!
//! The `*_frame_bytes` helpers return the **exact** framed size
//! (header + payload) of each fixed-shape frame; together with
//! [`ClientMsg::wire_bytes`] they keep the drivers' logical byte
//! accounting equal to the TCP transport's metered counts (asserted by
//! the codec tests below and the TCP integration test).

use anyhow::Result;

use crate::algorithms::ClientMsg;
use crate::compressors::natural::{pack16, unpack16};
use crate::compressors::{Compressed, IndexPayload, ValueEncoding};
use crate::utils::{ByteReader, ByteWriter};

pub use super::framing::FRAME_HEADER_BYTES;

/// Frame tags, master → client.
pub mod s2c {
    pub const ROUND: u8 = 1;
    pub const EVAL_LOSS: u8 = 2;
    pub const WARM_START: u8 = 3;
    pub const SET_ALPHA: u8 = 5;
    pub const SHUTDOWN: u8 = 6;
    /// First-order reduction (baselines): client replies GRAD.
    pub const LOSS_GRAD: u8 = 7;
    /// State pull: PP client replies STATE with its current (lᵢ, gᵢ).
    pub const STATE: u8 = 8;
}

/// Frame tags, client → master.
pub mod c2s {
    pub const REGISTER: u8 = 10;
    pub const MSG: u8 = 11;
    pub const LOSS: u8 = 12;
    pub const WARM: u8 = 13;
    pub const ACK: u8 = 15;
    /// (loss, gradient) reply to LOSS_GRAD.
    pub const GRAD: u8 = 16;
    /// (lᵢ, gᵢ) reply to STATE (same codec as GRAD).
    pub const STATE: u8 = 17;
    /// Graceful leave announcement (empty payload); rejoin reuses
    /// REGISTER on the master's retained listener.
    pub const DEREGISTER: u8 = 18;
}

// --- exact frame sizes ----------------------------------------------------

/// Framed size of a ROUND command: header + round + need_loss + len + x.
pub fn round_frame_bytes(d: usize) -> u64 {
    FRAME_HEADER_BYTES + 8 + 1 + 4 + 8 * d as u64
}

/// Framed size of a bare f64 vector (EVAL_LOSS / WARM_START commands,
/// WARM replies): header + len + values.
pub fn vec_frame_bytes(len: usize) -> u64 {
    FRAME_HEADER_BYTES + 4 + 8 * len as u64
}

/// Framed size of a single f64 (LOSS / ACK / SET_ALPHA).
pub fn scalar_frame_bytes() -> u64 {
    FRAME_HEADER_BYTES + 8
}

/// Framed size of an (f64, vector) pair (GRAD / STATE replies).
pub fn scalar_vec_frame_bytes(len: usize) -> u64 {
    FRAME_HEADER_BYTES + 8 + 4 + 8 * len as u64
}

/// Framed size of a payload-less command (STATE / SHUTDOWN).
pub fn empty_frame_bytes() -> u64 {
    FRAME_HEADER_BYTES
}

// --- payload codecs -------------------------------------------------------

pub fn encode_round(x: &[f64], round: u64, need_loss: bool) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(x.len() * 8 + 16);
    w.put_u64(round);
    w.put_u8(need_loss as u8);
    w.put_u32(x.len() as u32);
    w.put_f64_slice(x);
    w.into_vec()
}

pub fn decode_round(p: &[u8]) -> Result<(Vec<f64>, u64, bool)> {
    let mut r = ByteReader::new(p);
    let round = r.get_u64()?;
    let need_loss = r.get_u8()? != 0;
    let n = r.get_u32()? as usize;
    Ok((r.get_f64_vec(n)?, round, need_loss))
}

pub fn encode_vec(x: &[f64]) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(x.len() * 8 + 4);
    w.put_u32(x.len() as u32);
    w.put_f64_slice(x);
    w.into_vec()
}

pub fn decode_vec(p: &[u8]) -> Result<Vec<f64>> {
    let mut r = ByteReader::new(p);
    let n = r.get_u32()? as usize;
    r.get_f64_vec(n)
}

pub fn encode_scalar(v: f64) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(8);
    w.put_f64(v);
    w.into_vec()
}

pub fn decode_scalar(p: &[u8]) -> Result<f64> {
    ByteReader::new(p).get_f64()
}

/// Client algorithm family, declared at registration. The round
/// exchange is family-agnostic (one ROUND/MSG tag pair), so the master
/// validates at dispatch time that a round is going to clients of the
/// right family instead of silently aggregating mismatched math.
pub const FAMILY_FEDNL: u8 = 0;
pub const FAMILY_PP: u8 = 1;

pub fn encode_register(client_id: u32, d: u32, family: u8) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(9);
    w.put_u32(client_id);
    w.put_u32(d);
    w.put_u8(family);
    w.into_vec()
}

pub fn decode_register(p: &[u8]) -> Result<(u32, u32, u8)> {
    let mut r = ByteReader::new(p);
    let id = r.get_u32()?;
    let d = r.get_u32()?;
    let family = r.get_u8()?;
    anyhow::ensure!(
        family == FAMILY_FEDNL || family == FAMILY_PP,
        "bad client family {family}"
    );
    Ok((id, d, family))
}

/// Framed size of a REGISTER frame (id + d + family byte).
pub fn register_frame_bytes() -> u64 {
    FRAME_HEADER_BYTES + 9
}

fn put_compressed(w: &mut ByteWriter, c: &Compressed) {
    w.put_u32(c.n);
    match &c.payload {
        IndexPayload::Explicit(ix) => {
            w.put_u8(0);
            w.put_u32(ix.len() as u32);
            w.put_u32_slice(ix);
        }
        IndexPayload::Seed { seed, k } => {
            w.put_u8(1);
            w.put_u64(*seed);
            w.put_u32(*k);
        }
        IndexPayload::SeqStart { start, k } => {
            w.put_u8(2);
            w.put_u32(*start);
            w.put_u32(*k);
        }
        IndexPayload::Dense => w.put_u8(3),
    }
    w.put_f64(c.scale);
    w.put_u32(c.values.len() as u32);
    match c.encoding {
        ValueEncoding::F64 => {
            w.put_u8(0);
            w.put_f64_slice(&c.values);
        }
        ValueEncoding::Pow2x16 => {
            // The paper's bit-granularity Natural payload: 16 bits per
            // coordinate (sign + exponent of a pure power of two).
            w.put_u8(1);
            for &v in &c.values {
                let p = pack16(v);
                w.put_u8(p as u8);
                w.put_u8((p >> 8) as u8);
            }
        }
    }
}

fn get_compressed(r: &mut ByteReader) -> Result<Compressed> {
    let n = r.get_u32()?;
    let payload = match r.get_u8()? {
        0 => {
            let k = r.get_u32()? as usize;
            IndexPayload::Explicit(r.get_u32_vec(k)?)
        }
        1 => IndexPayload::Seed { seed: r.get_u64()?, k: r.get_u32()? },
        2 => IndexPayload::SeqStart { start: r.get_u32()?, k: r.get_u32()? },
        3 => IndexPayload::Dense,
        t => anyhow::bail!("bad payload tag {t}"),
    };
    let scale = r.get_f64()?;
    let nv = r.get_u32()? as usize;
    let (values, encoding) = match r.get_u8()? {
        0 => (r.get_f64_vec(nv)?, ValueEncoding::F64),
        1 => {
            let mut vs = Vec::with_capacity(nv);
            for _ in 0..nv {
                let lo = r.get_u8()? as u16;
                let hi = r.get_u8()? as u16;
                vs.push(unpack16(lo | (hi << 8)));
            }
            (vs, ValueEncoding::Pow2x16)
        }
        t => anyhow::bail!("bad value encoding {t}"),
    };
    Ok(Compressed { payload, values, scale, encoding, n })
}

/// The unified round reply — FedNL messages and FedNL-PP participation
/// deltas share this codec (see [`ClientMsg`]).
pub fn encode_client_msg(m: &ClientMsg) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(m.grad.len() * 8 + 64);
    w.put_u32(m.client_id as u32);
    w.put_u32(m.grad.len() as u32);
    w.put_f64_slice(&m.grad);
    w.put_f64(m.l_i);
    match m.loss {
        Some(l) => {
            w.put_u8(1);
            w.put_f64(l);
        }
        None => w.put_u8(0),
    }
    put_compressed(&mut w, &m.update);
    w.into_vec()
}

pub fn decode_client_msg(p: &[u8]) -> Result<ClientMsg> {
    let mut r = ByteReader::new(p);
    let client_id = r.get_u32()? as usize;
    let d = r.get_u32()? as usize;
    let grad = r.get_f64_vec(d)?;
    let l_i = r.get_f64()?;
    let loss = if r.get_u8()? != 0 { Some(r.get_f64()?) } else { None };
    let update = get_compressed(&mut r)?;
    Ok(ClientMsg { client_id, grad, update, l_i, loss })
}

/// (scalar, vector) codec shared by the GRAD and STATE replies.
pub fn encode_loss_grad(loss: f64, g: &[f64]) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(g.len() * 8 + 12);
    w.put_f64(loss);
    w.put_u32(g.len() as u32);
    w.put_f64_slice(g);
    w.into_vec()
}

pub fn decode_loss_grad(p: &[u8]) -> Result<(f64, Vec<f64>)> {
    let mut r = ByteReader::new(p);
    let loss = r.get_f64()?;
    let n = r.get_u32()? as usize;
    Ok((loss, r.get_f64_vec(n)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_roundtrip() {
        let x = vec![1.0, -2.5, 3.25];
        let enc = encode_round(&x, 42, true);
        let (x2, round, need_loss) = decode_round(&enc).unwrap();
        assert_eq!(x2, x);
        assert_eq!(round, 42);
        assert!(need_loss);
    }

    fn msg_with(payload: IndexPayload, loss: Option<f64>) -> ClientMsg {
        let values = match &payload {
            IndexPayload::Dense => vec![1.0; 10],
            _ => vec![1.5, -2.0, 0.0],
        };
        ClientMsg {
            client_id: 3,
            grad: vec![0.5; 4],
            update: Compressed {
                payload,
                values,
                scale: 1.0,
                encoding: ValueEncoding::F64,
                n: 10,
            },
            l_i: 2.25,
            loss,
        }
    }

    #[test]
    fn client_msg_roundtrip_all_payloads() {
        let payloads = vec![
            IndexPayload::Explicit(vec![0, 5, 9]),
            IndexPayload::Seed { seed: 0xDEAD, k: 3 },
            IndexPayload::SeqStart { start: 7, k: 3 },
            IndexPayload::Dense,
        ];
        for p in payloads {
            let m = msg_with(p, Some(-0.75));
            let dec = decode_client_msg(&encode_client_msg(&m)).unwrap();
            assert_eq!(dec.client_id, 3);
            assert_eq!(dec.grad, m.grad);
            assert_eq!(dec.l_i, m.l_i);
            assert_eq!(dec.loss, m.loss);
            assert_eq!(dec.update.payload, m.update.payload);
            assert_eq!(dec.update.values, m.update.values);
            // Critical: reconstructed indices identical on both sides.
            assert_eq!(dec.update.indices(), m.update.indices());
        }
    }

    #[test]
    fn client_msg_wire_bytes_matches_encoder_exactly() {
        // The satellite fix: the drivers' logical `wire_bytes()` must
        // equal the framed size the TCP transport actually meters.
        let payloads = vec![
            IndexPayload::Explicit(vec![0, 5, 9]),
            IndexPayload::Seed { seed: 0xDEAD, k: 3 },
            IndexPayload::SeqStart { start: 7, k: 3 },
            IndexPayload::Dense,
        ];
        for p in payloads {
            for loss in [None, Some(0.125)] {
                let m = msg_with(p.clone(), loss);
                let framed =
                    encode_client_msg(&m).len() as u64 + FRAME_HEADER_BYTES;
                assert_eq!(
                    m.wire_bytes(),
                    framed,
                    "payload {:?}, loss {:?}",
                    m.update.payload,
                    loss
                );
            }
        }
        // Pow2x16 values travel in 2 bytes each.
        let m = ClientMsg {
            client_id: 1,
            grad: vec![0.0; 3],
            update: Compressed {
                payload: IndexPayload::Dense,
                values: vec![2.0, -0.5, 1024.0],
                scale: 8.0 / 9.0,
                encoding: ValueEncoding::Pow2x16,
                n: 3,
            },
            l_i: 0.0,
            loss: None,
        };
        assert_eq!(
            m.wire_bytes(),
            encode_client_msg(&m).len() as u64 + FRAME_HEADER_BYTES
        );
    }

    #[test]
    fn frame_size_helpers_match_encoders() {
        let x = vec![0.5; 7];
        assert_eq!(
            round_frame_bytes(x.len()),
            encode_round(&x, 9, true).len() as u64 + FRAME_HEADER_BYTES
        );
        assert_eq!(
            vec_frame_bytes(x.len()),
            encode_vec(&x).len() as u64 + FRAME_HEADER_BYTES
        );
        assert_eq!(
            scalar_frame_bytes(),
            encode_scalar(1.5).len() as u64 + FRAME_HEADER_BYTES
        );
        assert_eq!(
            scalar_vec_frame_bytes(x.len()),
            encode_loss_grad(0.25, &x).len() as u64 + FRAME_HEADER_BYTES
        );
        assert_eq!(
            register_frame_bytes(),
            encode_register(3, 7, FAMILY_PP).len() as u64
                + FRAME_HEADER_BYTES
        );
        assert_eq!(empty_frame_bytes(), FRAME_HEADER_BYTES);
        let (id, d, fam) =
            decode_register(&encode_register(3, 7, FAMILY_PP)).unwrap();
        assert_eq!((id, d, fam), (3, 7, FAMILY_PP));
        assert!(decode_register(&encode_register(1, 2, 9)).is_err());
    }

    #[test]
    fn pow2x16_wire_roundtrip_bitexact() {
        // Natural's 16-bit payload must reconstruct the exact powers of
        // two (and the scale travels separately).
        let values = vec![2.0, -0.5, 1024.0, 0.0, 2.0f64.powi(-300)];
        let m = ClientMsg {
            client_id: 1,
            grad: vec![0.0; 3],
            update: Compressed {
                payload: IndexPayload::Dense,
                values: values.clone(),
                scale: 8.0 / 9.0,
                encoding: ValueEncoding::Pow2x16,
                n: 5,
            },
            l_i: 0.0,
            loss: None,
        };
        let dec = decode_client_msg(&encode_client_msg(&m)).unwrap();
        assert_eq!(dec.update.values, values);
        assert_eq!(dec.update.scale, 8.0 / 9.0);
        assert_eq!(dec.update.encoding, ValueEncoding::Pow2x16);
    }

    #[test]
    fn corrupt_rejected() {
        assert!(decode_client_msg(&[1, 2, 3]).is_err());
        assert!(decode_round(&[]).is_err());
    }
}

"""Layer-1 correctness: Pallas kernels vs the pure-jnp reference.

Hypothesis sweeps shapes and data; every kernel must match ref.py to
float64 tolerance. This is the CORE correctness signal for the compile
path — the Rust side trusts these numerics.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import logistic as k  # noqa: E402
from compile.kernels import ref  # noqa: E402

DIMS = st.integers(min_value=1, max_value=24)
SAMPLES = st.integers(min_value=1, max_value=48)
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _data(d, n, seed, dtype=np.float64):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(d, n)).astype(dtype)
    x = rng.normal(size=(d,)).astype(dtype)
    w = (np.full(n, 1.0 / n)).astype(dtype)
    return jnp.asarray(a), jnp.asarray(x), jnp.asarray(w)


@settings(max_examples=25, deadline=None)
@given(d=DIMS, n=SAMPLES, seed=SEEDS)
def test_margins_matches_ref(d, n, seed):
    a, x, _ = _data(d, n, seed)
    np.testing.assert_allclose(
        k.margins(a, x), ref.margins_ref(a, x), rtol=1e-12, atol=1e-12
    )


@settings(max_examples=25, deadline=None)
@given(d=DIMS, n=SAMPLES, seed=SEEDS)
def test_matvec_matches_ref(d, n, seed):
    a, _, _ = _data(d, n, seed)
    rng = np.random.default_rng(seed + 1)
    c = jnp.asarray(rng.normal(size=(n,)))
    np.testing.assert_allclose(k.matvec(a, c), a @ c, rtol=1e-11, atol=1e-11)


@settings(max_examples=25, deadline=None)
@given(d=DIMS, n=SAMPLES, seed=SEEDS)
def test_weighted_gram_matches_ref(d, n, seed):
    a, _, _ = _data(d, n, seed)
    rng = np.random.default_rng(seed + 2)
    h = jnp.asarray(np.abs(rng.normal(size=(n,))))
    expect = (np.asarray(a) * np.asarray(h)[None, :]) @ np.asarray(a).T
    np.testing.assert_allclose(
        k.weighted_gram(a, h), expect, rtol=1e-10, atol=1e-10
    )


@settings(max_examples=10, deadline=None)
@given(seed=SEEDS)
def test_weighted_gram_symmetric(seed):
    a, _, _ = _data(12, 32, seed)
    h = jnp.abs(jnp.asarray(np.random.default_rng(seed).normal(size=(32,))))
    g = np.asarray(k.weighted_gram(a, h))
    np.testing.assert_allclose(g, g.T, rtol=0, atol=1e-12)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_kernels_dtype_preserved(dtype):
    a, x, w = _data(8, 16, 0, dtype=dtype)
    assert k.margins(a, x).dtype == dtype
    assert k.weighted_gram(a, w).dtype == dtype


def test_pick_blocks_divides():
    for d in range(1, 40):
        for n in range(1, 40):
            bd, bn = k.pick_blocks(d, n)
            assert d % bd == 0 and n % bn == 0


def test_zero_weight_columns_do_not_contribute():
    # Padding contract: w_j = 0 ⇒ column j is invisible to grad/Hessian.
    a, x, _ = _data(8, 32, 7)
    w = np.zeros(32)
    w[:10] = 1.0 / 10
    w = jnp.asarray(w)
    h = w * jax.nn.sigmoid(k.margins(a, x)) * jax.nn.sigmoid(-k.margins(a, x))
    full = np.asarray(k.weighted_gram(a, h))
    trunc = np.asarray(
        k.weighted_gram(a[:, :10], h[:10] * 0 + np.asarray(h)[:10])
    )
    np.testing.assert_allclose(full, trunc, rtol=1e-10, atol=1e-12)

//! Multi-node networking over raw TCP (paper §7, App. L.1, J.2).
//!
//! Design decisions carried over from the paper:
//! * plain TCP/IP — no HTTP/gRPC layers ("any unnecessary abstractions
//!   ... take resources and are not free");
//! * **one** connection per client (the paper found a single channel
//!   beats per-stream connections);
//! * Nagle's algorithm disabled (`TCP_NODELAY`) because frames are
//!   explicitly sized and often small;
//! * fixed-width 32-bit indices on the wire (beat varints);
//! * RandK/RandSeqK transmit a PRG seed / start index, and the master
//!   reconstructs the coordinate set.
//!
//! The [`relay`] module adds the sharded aggregation tier on top:
//! relay aggregator processes that speak this client protocol downward
//! and the `SHARD_*` frames upward, so master fan-in scales as the
//! shard count instead of the client count (see `coordinator::shard`
//! for the determinism contract).

pub mod client;
pub mod framing;
pub mod relay;
pub mod server;
pub mod wire;

pub use client::{run_client, run_client_with, ClientOpts};
pub use framing::{Channel, FRAME_HEADER_BYTES};
pub use relay::{run_relay, run_relay_on, RelayCfg, RelayPool};
pub use server::RemotePool;

//! End-to-end driver — the full system on a real (small) workload:
//!
//!   1. generate a W8A-shaped dataset and write LIBSVM **text to disk**;
//!   2. mmap-parse it back, densify, reshuffle u.a.r., split across
//!      clients (the paper's full §5 preparation pipeline);
//!   3. train FedNL on the multi-core simulator with all six
//!      compressors and report a Table-1-shaped summary;
//!   4. cross-check the minimizer against an independent L-BFGS solve;
//!   5. write per-compressor convergence traces (figure CSVs).
//!
//!     cargo run --release --example e2e_train  [-- --full]
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use fednl::algorithms::{run_fednl_pool, Options};
use fednl::baselines::{run_lbfgs, BaselineOptions};
use fednl::cli::Args;
use fednl::compressors::ALL_NAMES;
use fednl::harness::{prepare_problem, HarnessCfg, Scale, W8A};
use fednl::linalg::vector;
use fednl::metrics::report::{sci, Table};
use fednl::utils::{human_bytes, Stopwatch};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = HarnessCfg {
        scale: if args.flag("full") { Scale::Full } else { Scale::Ci },
        out_dir: "results/e2e".into(),
        ..Default::default()
    };
    cfg.ensure_out_dir()?;

    // Steps 1-2: full disk round-trip (not just in-memory synthesis).
    let sw = Stopwatch::start();
    let problem = prepare_problem(&W8A, &cfg)?;
    let path = format!("{}/w8a_synth.libsvm", cfg.out_dir);
    {
        // Persist + re-parse through the mmap path to prove the I/O leg.
        let spec = fednl::data::SynthSpec {
            d_raw: W8A.d - 1,
            n_samples: problem.n_clients * problem.n_i,
            density: 0.25,
            noise: 1.0,
            seed: cfg.seed,
        };
        let text =
            fednl::data::write_libsvm(&fednl::data::generate_synthetic(&spec));
        std::fs::write(&path, text)?;
        let (parsed, _) = fednl::data::parse_libsvm_file(&path)?;
        assert_eq!(parsed.len(), problem.n_clients * problem.n_i);
    }
    println!(
        "[e2e] prepared {} samples (d={}) across {} clients in {:.2}s",
        problem.n_clients * problem.n_i,
        problem.d(),
        problem.n_clients,
        sw.elapsed_secs()
    );

    // Step 3: FedNL under every compressor on the threaded simulator.
    let d = problem.d();
    let mut table = Table::new(&[
        "Compressor",
        "||grad||_final",
        "Time (s)",
        "MB to master",
        "x* max-diff vs L-BFGS",
    ]);
    // Step 4 reference: independent L-BFGS on the same objective.
    let mut ref_pool = problem.seq_pool("identity", 8, &cfg)?;
    let ref_opts = BaselineOptions { max_rounds: 20_000, tol_grad: 1e-10 };
    let ref_trace = run_lbfgs(&mut ref_pool, &ref_opts, 10, vec![0.0; d]);
    println!(
        "[e2e] L-BFGS reference: ||grad|| = {:.2e} in {} rounds",
        ref_trace.last_grad_norm(),
        ref_trace.records.len()
    );
    // Recover x* by one more Newton-quality solve: run FedNL/identity.
    let xstar = {
        let mut pool = problem.seq_pool("identity", 8, &cfg)?;
        let opts = Options {
            rounds: 400,
            tol_grad: Some(1e-12),
            ..Default::default()
        };
        let _ = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "xstar");
        // The server's final iterate isn't exposed; re-derive x* from a
        // fresh L-BFGS at tight tolerance instead.
        let mut p2 = problem.seq_pool("identity", 8, &cfg)?;
        let o2 = BaselineOptions { max_rounds: 40_000, tol_grad: 1e-12 };
        let t2 = run_lbfgs(&mut p2, &o2, 10, vec![0.0; d]);
        assert!(t2.last_grad_norm() < 1e-9);
        // x* is not in the trace either — recompute once more below via
        // closed-loop check: we compare final grad norms instead.
        t2
    };
    let _ = xstar;

    for comp in ALL_NAMES {
        let sw = Stopwatch::start();
        let mut pool = problem.threaded_pool(comp, 8, &cfg)?;
        let opts = Options {
            rounds: problem.rounds.min(400),
            track_loss: true,
            // The reference FedNL initializes Hᵢ⁰ = ∇²fᵢ(x⁰); with it the
            // superlinear phase starts immediately.
            warm_start: true,
            ..Default::default()
        };
        let trace =
            run_fednl_pool(&mut pool, &opts, vec![0.0; d], &format!("FedNL/{comp}"));
        let secs = sw.elapsed_secs();
        trace.write_csv(&format!("{}/e2e_{comp}.csv", cfg.out_dir))?;
        // Agreement check: both solvers drive ∇f to ~0 on the same
        // strongly-convex objective ⇒ same unique minimizer. We verify
        // the loss plateaus agree.
        let loss_diff = (trace.records.last().unwrap().loss
            - ref_trace.records.last().unwrap().loss)
            .abs();
        table.row(&[
            comp.to_string(),
            sci(trace.last_grad_norm()),
            format!("{secs:.2}"),
            human_bytes(trace.total_bytes_up()),
            format!("{loss_diff:.2e}"),
        ]);
        assert!(
            trace.last_grad_norm() < 1e-8,
            "{comp} failed to converge: {}",
            trace.last_grad_norm()
        );
        assert!(loss_diff < 1e-8, "{comp} minimizer mismatch: {loss_diff}");
    }
    println!("\n{}", table.to_markdown());
    println!("traces written to {}/e2e_*.csv", cfg.out_dir);

    // Sanity on the shared objective: ∇f(x⁰) is identical across pools.
    let mut p = problem.seq_pool("identity", 8, &cfg)?;
    use fednl::coordinator::ClientPool;
    let (_, g0) = p.loss_grad(&vec![0.0; d]);
    println!("||grad(x0)|| = {:.4}", vector::norm2(&g0));
    Ok(())
}

//! Self-contained dense linear algebra (paper components
//! `linalg_vectors`, `linalg_matrices`, `linalg_linsolvers`).
//!
//! Everything FedNL needs: dense vectors/matrices (f64), the packed
//! upper-triangle representation the compressors operate on, a
//! Cholesky–Banachiewicz direct solver with forward/backward
//! substitution (§5.9), Gaussian elimination (the paper's pre-v10
//! baseline, kept for the ablation bench), and the iterative solvers the
//! paper ships (Jacobi, Gauss–Seidel, Conjugate Gradient).
//!
//! All hot primitives (dot, AXPY, rank-1 Hessian accumulate, compressor
//! energy scans) route through [`simd`] — a runtime-dispatched kernel
//! layer that selects AVX2+FMA intrinsics when the host supports them
//! and falls back to portable 4-way-unrolled scalar loops otherwise.
//!
//! Cross-client sums additionally route through [`reduce`] — an exact
//! fixed-point superaccumulator whose sums are associative and
//! permutation-invariant, so reductions are bit-identical no matter
//! how (or where) the terms were grouped.

pub mod cholesky;
pub mod eigen;
pub mod gauss;
pub mod iterative;
pub mod matrix;
pub mod packed;
pub mod qr;
pub mod reduce;
pub mod simd;
pub mod vector;

pub use cholesky::Cholesky;
pub use matrix::Mat;
pub use packed::{packed_idx, packed_len, PackedUpper};
pub use reduce::{RepAcc, RepVec, SparseRepVec};

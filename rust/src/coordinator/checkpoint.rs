//! Coordinator checkpoint/restore: versioned, checksummed snapshots of
//! everything the training trajectory is a function of, so a master
//! killed mid-run resumes **bit-identically** (the determinism-by-
//! construction guarantee — exact superaccumulators, seeded RNG
//! streams, commit watermarks — extends across a process boundary).
//!
//! # Snapshot field inventory (version 1)
//!
//! Every snapshot is one flat byte string, fixed-width LE fields in
//! this exact order (`[]` = length-prefixed with a u32 count):
//!
//! | field             | type            | meaning                                  |
//! |-------------------|-----------------|------------------------------------------|
//! | magic             | u32 `0x464E434B`| `"FNCK"`                                 |
//! | version           | u8 = 1          | codec version (mismatch = reject)        |
//! | algo              | u8              | 0 = Newton family (FedNL/LS), 1 = PP     |
//! | finished          | u8              | 1 = the run completed (tol or rounds)    |
//! | round_next        | u64             | first round the restored run executes    |
//! | d                 | u64             | model dimension                          |
//! | n                 | u64             | client count                             |
//! | alpha             | f64             | negotiated α (re-installed on restore)   |
//! | bytes_up/down     | u64 × 2         | cumulative logical byte meters           |
//! | x                 | f64[]           | model iterate entering `round_next`      |
//! | label             | str             | trace label                              |
//! | plan_spec         | str             | FaultPlan spec (provenance; may be "")   |
//! | policy            | u64 ×2 + u8     | quorum / deadline_ms (`u64::MAX` = None) + on_missing |
//! | — algo = 0 —      |                 |                                          |
//! | h                 | f64[d·d]        | server H = (1/n)ΣHᵢ, row-major           |
//! | l                 | f64             | server Lipschitz shift l                 |
//! | last_commit       | u64[n]          | per-client commit watermark (`u64::MAX` = never) |
//! | reuse_cache       | (u8 + msg?)[n]  | `OnMissing::Reuse` replay slots ([`ClientMsg`] wire codec) |
//! | — algo = 1 —      |                 |                                          |
//! | h                 | f64[d·d]        | persistent Hᵏ                            |
//! | l                 | f64             | persistent lᵏ                            |
//! | g                 | f64[d]          | persistent gᵏ                            |
//! | l_of              | f64[n]          | per-client lᵢ mirrors                    |
//! | g_of              | f64[n·d]        | per-client gᵢ mirrors, row-major         |
//! | rng               | u64 × 4         | subset sampler mid-stream (state hi/lo, inc hi/lo) |
//! | — both —          |                 |                                          |
//! | records           | record[]        | the trace so far (9 fields each, `RoundRecord` order) |
//! | crc32             | u32             | IEEE 802.3 over every preceding byte     |
//!
//! `elapsed` in the stored records is the original run's wall clock —
//! faithful provenance, excluded from bitwise comparisons like every
//! other timing figure in this repo.
//!
//! # Atomic-write protocol
//!
//! A snapshot for `round_next = R` is durable or absent, never torn:
//!
//! 1. encode + crc32 into `ck-<R, zero-padded to 12>.fnck.tmp`;
//! 2. `File::sync_all` (fsync) the temp file;
//! 3. `fs::rename` onto `ck-<R>.fnck` (atomic on POSIX).
//!
//! [`load_latest`] scans the directory descending by round and returns
//! the first snapshot that decodes — a crash between steps leaves at
//! worst a stale `.tmp` (ignored) or a truncated/corrupt tail file
//! (rejected by length/magic/version/crc checks, falling back to the
//! previous snapshot). [`prune`] keeps the newest `keep` snapshots so
//! a run checkpointing every round doesn't grow the directory without
//! bound; the engine prunes to 3 after each write, which also bounds
//! how far a restore can fall back.
//!
//! The ack protocol makes the fallback *safe*, not just available: the
//! engine defers `ROUND_ACK`s until a snapshot covering the round is
//! durable, so any round a client might have committed permanently is
//! at or below every surviving snapshot's watermark (see the engine's
//! checkpoint section).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::algorithms::{ClientMsg, OnMissing, RoundPolicy};
use crate::metrics::RoundRecord;
use crate::net::wire::{decode_client_msg, encode_client_msg};
use crate::utils::digest::crc32;
use crate::utils::{ByteReader, ByteWriter};

const MAGIC: u32 = 0x464E_434B; // "FNCK"
const VERSION: u8 = 1;
const SNAP_EXT: &str = "fnck";
/// Snapshots the engine keeps per directory (newest first); older ones
/// are pruned after each successful write.
pub const KEEP_SNAPSHOTS: usize = 3;

/// Checkpointing knobs, carried on `Options` (`--checkpoint-dir DIR
/// --checkpoint-every K`).
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointCfg {
    /// Snapshot directory (created on first write).
    pub dir: String,
    /// Write a snapshot after every `every`-th round (≥ 1). The staged
    /// ack ladder on failover clients grows to this depth: acks are
    /// withheld until the covering snapshot is durable.
    pub every: u64,
    /// FaultPlan spec the run was launched with, recorded for
    /// provenance ("" = no faults).
    pub plan_spec: String,
}

impl CheckpointCfg {
    pub fn new(dir: impl Into<String>, every: u64) -> Self {
        assert!(every >= 1, "--checkpoint-every must be >= 1");
        Self { dir: dir.into(), every, plan_spec: String::new() }
    }
}

/// Algorithm-specific half of a snapshot.
#[derive(Debug, Clone)]
pub enum AlgoSnap {
    /// FedNL / FedNL-LS: the `ServerState` aggregate plus the ack
    /// protocol's commit watermarks and the `Reuse` replay cache.
    Newton {
        /// H, row-major d×d.
        h: Vec<f64>,
        /// Lipschitz shift l.
        l: f64,
        /// Per-client last committed round (`None` = never).
        last_commit: Vec<Option<u64>>,
        /// `OnMissing::Reuse` replay slots.
        reuse_cache: Vec<Option<ClientMsg>>,
    },
    /// FedNL-PP: the persistent (Hᵏ, lᵏ, gᵏ), the per-client (lᵢ, gᵢ)
    /// mirrors, and the subset sampler mid-stream.
    Pp {
        /// Hᵏ, row-major d×d.
        h: Vec<f64>,
        /// lᵏ.
        l: f64,
        /// gᵏ.
        g: Vec<f64>,
        /// Per-client lᵢ mirrors.
        l_of: Vec<f64>,
        /// Per-client gᵢ mirrors.
        g_of: Vec<Vec<f64>>,
        /// Subset sampler (state, inc), mid-stream.
        rng_state: u128,
        /// See `rng_state`.
        rng_inc: u128,
    },
}

/// One durable coordinator snapshot — the full field inventory in the
/// module docs.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// The run completed (tolerance or round budget); restoring a
    /// finished snapshot runs zero further rounds.
    pub finished: bool,
    /// First round the restored run executes.
    pub round_next: u64,
    pub d: usize,
    pub n: usize,
    /// Negotiated α, re-installed verbatim on restore.
    pub alpha: f64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    /// Model iterate entering `round_next`.
    pub x: Vec<f64>,
    pub label: String,
    /// FaultPlan spec (provenance; "" = none).
    pub plan_spec: String,
    pub policy: RoundPolicy,
    pub algo: AlgoSnap,
    /// Per-round trace so far (rounds `0..round_next`).
    pub records: Vec<RoundRecord>,
}

const NONE_U64: u64 = u64::MAX;

fn put_opt_u64(w: &mut ByteWriter, v: Option<u64>) {
    w.put_u64(v.unwrap_or(NONE_U64));
}

fn get_opt_u64(r: &mut ByteReader) -> Result<Option<u64>> {
    let v = r.get_u64()?;
    Ok(if v == NONE_U64 { None } else { Some(v) })
}

fn put_str(w: &mut ByteWriter, s: &str) {
    w.put_u32(s.len() as u32);
    w.put_bytes(s.as_bytes());
}

fn get_str(r: &mut ByteReader) -> Result<String> {
    let n = r.get_u32()? as usize;
    Ok(String::from_utf8(r.get_bytes(n)?.to_vec())?)
}

fn put_u128(w: &mut ByteWriter, v: u128) {
    w.put_u64((v >> 64) as u64);
    w.put_u64(v as u64);
}

fn get_u128(r: &mut ByteReader) -> Result<u128> {
    let hi = r.get_u64()? as u128;
    let lo = r.get_u64()? as u128;
    Ok((hi << 64) | lo)
}

impl Snapshot {
    /// Encode to the version-1 byte string (crc32 trailer included).
    pub fn encode(&self) -> Vec<u8> {
        let mut w =
            ByteWriter::with_capacity(64 + 8 * (self.d * self.d + self.d));
        w.put_u32(MAGIC);
        w.put_u8(VERSION);
        w.put_u8(match &self.algo {
            AlgoSnap::Newton { .. } => 0,
            AlgoSnap::Pp { .. } => 1,
        });
        w.put_u8(self.finished as u8);
        w.put_u64(self.round_next);
        w.put_u64(self.d as u64);
        w.put_u64(self.n as u64);
        w.put_f64(self.alpha);
        w.put_u64(self.bytes_up);
        w.put_u64(self.bytes_down);
        w.put_u32(self.x.len() as u32);
        w.put_f64_slice(&self.x);
        put_str(&mut w, &self.label);
        put_str(&mut w, &self.plan_spec);
        put_opt_u64(&mut w, self.policy.quorum.map(|q| q as u64));
        put_opt_u64(&mut w, self.policy.deadline_ms);
        w.put_u8(match self.policy.on_missing {
            OnMissing::Drop => 0,
            OnMissing::Resample => 1,
            OnMissing::Reuse => 2,
        });
        match &self.algo {
            AlgoSnap::Newton { h, l, last_commit, reuse_cache } => {
                w.put_u32(h.len() as u32);
                w.put_f64_slice(h);
                w.put_f64(*l);
                w.put_u32(last_commit.len() as u32);
                for &lc in last_commit {
                    put_opt_u64(&mut w, lc);
                }
                w.put_u32(reuse_cache.len() as u32);
                for slot in reuse_cache {
                    match slot {
                        None => w.put_u8(0),
                        Some(m) => {
                            w.put_u8(1);
                            let enc = encode_client_msg(m);
                            w.put_u32(enc.len() as u32);
                            w.put_bytes(&enc);
                        }
                    }
                }
            }
            AlgoSnap::Pp { h, l, g, l_of, g_of, rng_state, rng_inc } => {
                w.put_u32(h.len() as u32);
                w.put_f64_slice(h);
                w.put_f64(*l);
                w.put_u32(g.len() as u32);
                w.put_f64_slice(g);
                w.put_u32(l_of.len() as u32);
                w.put_f64_slice(l_of);
                w.put_u32(g_of.len() as u32);
                for gi in g_of {
                    w.put_u32(gi.len() as u32);
                    w.put_f64_slice(gi);
                }
                put_u128(&mut w, *rng_state);
                put_u128(&mut w, *rng_inc);
            }
        }
        w.put_u32(self.records.len() as u32);
        for rec in &self.records {
            w.put_u64(rec.round);
            w.put_f64(rec.grad_norm);
            w.put_f64(rec.loss);
            w.put_u64(rec.bytes_up);
            w.put_u64(rec.bytes_down);
            w.put_f64(rec.elapsed);
            w.put_u32(rec.committed);
            w.put_u32(rec.missing);
            w.put_u32(rec.flagged);
        }
        let crc = crc32(w.as_slice());
        w.put_u32(crc);
        w.into_vec()
    }

    /// Decode and validate a version-1 byte string. Truncation, a bad
    /// magic/version, trailing garbage and any bit flip (crc mismatch)
    /// are all `Err` — [`load_latest`] turns them into fallback.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        anyhow::ensure!(bytes.len() >= 4, "snapshot truncated");
        let (payload, trailer) = bytes.split_at(bytes.len() - 4);
        let stored = ByteReader::new(trailer).get_u32()?;
        let computed = crc32(payload);
        anyhow::ensure!(
            stored == computed,
            "snapshot checksum mismatch (stored {stored:#010x}, computed {computed:#010x})"
        );
        let mut r = ByteReader::new(payload);
        let magic = r.get_u32()?;
        anyhow::ensure!(magic == MAGIC, "bad snapshot magic {magic:#010x}");
        let version = r.get_u8()?;
        anyhow::ensure!(
            version == VERSION,
            "unsupported snapshot version {version} (expected {VERSION})"
        );
        let algo_tag = r.get_u8()?;
        let finished = r.get_u8()? != 0;
        let round_next = r.get_u64()?;
        let d = r.get_u64()? as usize;
        let n = r.get_u64()? as usize;
        let alpha = r.get_f64()?;
        let bytes_up = r.get_u64()?;
        let bytes_down = r.get_u64()?;
        let nx = r.get_u32()? as usize;
        let x = r.get_f64_vec(nx)?;
        let label = get_str(&mut r)?;
        let plan_spec = get_str(&mut r)?;
        let quorum = get_opt_u64(&mut r)?.map(|q| q as usize);
        let deadline_ms = get_opt_u64(&mut r)?;
        let on_missing = match r.get_u8()? {
            0 => OnMissing::Drop,
            1 => OnMissing::Resample,
            2 => OnMissing::Reuse,
            t => bail!("bad on_missing tag {t}"),
        };
        let algo = match algo_tag {
            0 => {
                let nh = r.get_u32()? as usize;
                let h = r.get_f64_vec(nh)?;
                let l = r.get_f64()?;
                let nlc = r.get_u32()? as usize;
                let mut last_commit = Vec::with_capacity(nlc);
                for _ in 0..nlc {
                    last_commit.push(get_opt_u64(&mut r)?);
                }
                let nrc = r.get_u32()? as usize;
                let mut reuse_cache = Vec::with_capacity(nrc);
                for _ in 0..nrc {
                    reuse_cache.push(if r.get_u8()? != 0 {
                        let len = r.get_u32()? as usize;
                        Some(decode_client_msg(r.get_bytes(len)?)?)
                    } else {
                        None
                    });
                }
                AlgoSnap::Newton { h, l, last_commit, reuse_cache }
            }
            1 => {
                let nh = r.get_u32()? as usize;
                let h = r.get_f64_vec(nh)?;
                let l = r.get_f64()?;
                let ng = r.get_u32()? as usize;
                let g = r.get_f64_vec(ng)?;
                let nl = r.get_u32()? as usize;
                let l_of = r.get_f64_vec(nl)?;
                let ngof = r.get_u32()? as usize;
                let mut g_of = Vec::with_capacity(ngof);
                for _ in 0..ngof {
                    let ni = r.get_u32()? as usize;
                    g_of.push(r.get_f64_vec(ni)?);
                }
                let rng_state = get_u128(&mut r)?;
                let rng_inc = get_u128(&mut r)?;
                AlgoSnap::Pp { h, l, g, l_of, g_of, rng_state, rng_inc }
            }
            t => bail!("bad algo tag {t}"),
        };
        let nrec = r.get_u32()? as usize;
        let mut records = Vec::with_capacity(nrec);
        for _ in 0..nrec {
            records.push(RoundRecord {
                round: r.get_u64()?,
                grad_norm: r.get_f64()?,
                loss: r.get_f64()?,
                bytes_up: r.get_u64()?,
                bytes_down: r.get_u64()?,
                elapsed: r.get_f64()?,
                committed: r.get_u32()?,
                missing: r.get_u32()?,
                flagged: r.get_u32()?,
            });
        }
        anyhow::ensure!(
            r.remaining() == 0,
            "snapshot has {} trailing bytes",
            r.remaining()
        );
        Ok(Snapshot {
            finished,
            round_next,
            d,
            n,
            alpha,
            bytes_up,
            bytes_down,
            x,
            label,
            plan_spec,
            policy: RoundPolicy { quorum, deadline_ms, on_missing },
            algo,
            records,
        })
    }
}

/// `ck-<round_next, zero-padded>.fnck` — zero padding makes the
/// lexicographic directory order the numeric round order.
fn snapshot_path(dir: &Path, round_next: u64) -> PathBuf {
    dir.join(format!("ck-{round_next:012}.{SNAP_EXT}"))
}

/// Parse a snapshot file name back to its `round_next`.
fn parse_round(name: &str) -> Option<u64> {
    let rest = name.strip_prefix("ck-")?;
    let digits = rest.strip_suffix(&format!(".{SNAP_EXT}"))?;
    digits.parse().ok()
}

/// The directory's snapshot files, ascending by round.
fn snapshot_files(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(out)
        }
        Err(e) => {
            return Err(e)
                .with_context(|| format!("reading {}", dir.display()))
        }
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(round) = parse_round(name) {
            out.push((round, entry.path()));
        }
    }
    out.sort();
    Ok(out)
}

/// Write `snap` durably under `dir` (created if absent) with the
/// atomic temp + fsync + rename protocol. Returns the final path.
pub fn write_snapshot(dir: &str, snap: &Snapshot) -> Result<PathBuf> {
    let dir = Path::new(dir);
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let path = snapshot_path(dir, snap.round_next);
    let tmp = path.with_extension(format!("{SNAP_EXT}.tmp"));
    let bytes = snap.encode();
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&bytes)?;
        f.sync_all()?; // durable before it can be named a snapshot
    }
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    Ok(path)
}

/// Load the newest snapshot that decodes, falling back across a
/// corrupt or truncated tail. `Ok(None)` = the directory holds no
/// snapshot at all; a directory whose *every* snapshot is corrupt is
/// an error (restoring from nothing would silently restart training).
pub fn load_latest(dir: &str) -> Result<Option<Snapshot>> {
    let files = snapshot_files(Path::new(dir))?;
    if files.is_empty() {
        return Ok(None);
    }
    let mut last_err = None;
    for (_, path) in files.iter().rev() {
        let attempt = std::fs::read(path)
            .map_err(anyhow::Error::from)
            .and_then(|bytes| Snapshot::decode(&bytes));
        match attempt {
            Ok(snap) => return Ok(Some(snap)),
            Err(e) => {
                eprintln!(
                    "[checkpoint] skipping {}: {e}",
                    path.display()
                );
                last_err = Some(e);
            }
        }
    }
    Err(last_err.unwrap().context(format!(
        "no valid snapshot among {} candidate(s) in {dir}",
        files.len()
    )))
}

/// Delete all but the newest `keep` snapshots (best-effort: an
/// unlinkable stale file never fails the run).
pub fn prune(dir: &str, keep: usize) -> Result<()> {
    let files = snapshot_files(Path::new(dir))?;
    if files.len() > keep {
        for (_, path) in &files[..files.len() - keep] {
            let _ = std::fs::remove_file(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{Compressed, IndexPayload, ValueEncoding};

    fn newton_snap() -> Snapshot {
        let msg = ClientMsg {
            client_id: 1,
            grad: vec![0.5, -1.25, 3.0],
            update: Compressed {
                payload: IndexPayload::Explicit(vec![0, 4]),
                values: vec![1.5, -2.0],
                scale: 0.75,
                encoding: ValueEncoding::F64,
                n: 6,
            },
            l_i: 2.25,
            loss: Some(-0.125),
        };
        Snapshot {
            finished: false,
            round_next: 7,
            d: 3,
            n: 2,
            alpha: 0.5,
            bytes_up: 12345,
            bytes_down: 67890,
            x: vec![1.0, -2.5, 1e-300],
            label: "fednl-ckpt".into(),
            plan_spec: "kill@2:1,corrupt@3:0:garbage".into(),
            policy: RoundPolicy {
                quorum: Some(1),
                deadline_ms: Some(250),
                on_missing: OnMissing::Reuse,
            },
            algo: AlgoSnap::Newton {
                h: (0..9).map(|i| i as f64 * 0.125).collect(),
                l: 0.0625,
                last_commit: vec![Some(6), None],
                reuse_cache: vec![Some(msg), None],
            },
            records: vec![RoundRecord {
                round: 6,
                grad_norm: 1e-3,
                loss: 0.7,
                bytes_up: 100,
                bytes_down: 200,
                elapsed: 0.01,
                committed: 2,
                missing: 0,
                flagged: 0,
            }],
        }
    }

    fn pp_snap() -> Snapshot {
        Snapshot {
            finished: true,
            round_next: 3,
            d: 2,
            n: 3,
            alpha: 1.0,
            bytes_up: 1,
            bytes_down: 2,
            x: vec![0.5, -0.5],
            label: "pp-ckpt".into(),
            plan_spec: String::new(),
            policy: RoundPolicy::default(),
            algo: AlgoSnap::Pp {
                h: vec![1.0, 0.0, 0.0, 1.0],
                l: 0.25,
                g: vec![-1.0, 2.0],
                l_of: vec![0.1, 0.2, 0.3],
                g_of: vec![
                    vec![1.0, 2.0],
                    vec![3.0, 4.0],
                    vec![5.0, 6.0],
                ],
                rng_state: (7u128 << 64) | 9,
                rng_inc: (11u128 << 64) | 13,
            },
            records: Vec::new(),
        }
    }

    #[test]
    fn codec_round_trips_every_field() {
        for snap in [newton_snap(), pp_snap()] {
            let back = Snapshot::decode(&snap.encode()).unwrap();
            assert_eq!(back.finished, snap.finished);
            assert_eq!(back.round_next, snap.round_next);
            assert_eq!((back.d, back.n), (snap.d, snap.n));
            assert_eq!(back.alpha.to_bits(), snap.alpha.to_bits());
            assert_eq!(
                (back.bytes_up, back.bytes_down),
                (snap.bytes_up, snap.bytes_down)
            );
            let bits =
                |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&back.x), bits(&snap.x));
            assert_eq!(back.label, snap.label);
            assert_eq!(back.plan_spec, snap.plan_spec);
            assert_eq!(back.policy, snap.policy);
            assert_eq!(back.records.len(), snap.records.len());
            for (a, b) in back.records.iter().zip(&snap.records) {
                assert_eq!(a.round, b.round);
                assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits());
                assert_eq!(a.loss.to_bits(), b.loss.to_bits());
                assert_eq!(
                    (a.bytes_up, a.bytes_down),
                    (b.bytes_up, b.bytes_down)
                );
                assert_eq!(
                    (a.committed, a.missing, a.flagged),
                    (b.committed, b.missing, b.flagged)
                );
            }
            match (&back.algo, &snap.algo) {
                (
                    AlgoSnap::Newton { h, l, last_commit, reuse_cache },
                    AlgoSnap::Newton {
                        h: h2,
                        l: l2,
                        last_commit: lc2,
                        reuse_cache: rc2,
                    },
                ) => {
                    assert_eq!(bits(h), bits(h2));
                    assert_eq!(l.to_bits(), l2.to_bits());
                    assert_eq!(last_commit, lc2);
                    assert_eq!(reuse_cache.len(), rc2.len());
                    let (a, b) = (
                        reuse_cache[0].as_ref().unwrap(),
                        rc2[0].as_ref().unwrap(),
                    );
                    assert_eq!(a.client_id, b.client_id);
                    assert_eq!(bits(&a.grad), bits(&b.grad));
                    assert_eq!(a.l_i.to_bits(), b.l_i.to_bits());
                    assert_eq!(a.loss, b.loss);
                    assert_eq!(a.update.indices(), b.update.indices());
                    assert_eq!(bits(&a.update.values), bits(&b.update.values));
                    assert!(reuse_cache[1].is_none());
                }
                (
                    AlgoSnap::Pp {
                        h,
                        l,
                        g,
                        l_of,
                        g_of,
                        rng_state,
                        rng_inc,
                    },
                    AlgoSnap::Pp {
                        h: h2,
                        l: l2,
                        g: g2,
                        l_of: lo2,
                        g_of: go2,
                        rng_state: rs2,
                        rng_inc: ri2,
                    },
                ) => {
                    assert_eq!(bits(h), bits(h2));
                    assert_eq!(l.to_bits(), l2.to_bits());
                    assert_eq!(bits(g), bits(g2));
                    assert_eq!(bits(l_of), bits(lo2));
                    assert_eq!(g_of, go2);
                    assert_eq!((rng_state, rng_inc), (rs2, ri2));
                }
                _ => panic!("algo tag flipped through the codec"),
            }
        }
    }

    #[test]
    fn decode_rejects_any_corruption() {
        let bytes = newton_snap().encode();
        // Truncation at every prefix length.
        for cut in 0..bytes.len() {
            assert!(
                Snapshot::decode(&bytes[..cut]).is_err(),
                "truncation to {cut} bytes accepted"
            );
        }
        // A single bit flip anywhere trips the crc (or a field check).
        for byte in [0, 4, 5, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[byte] ^= 0x10;
            assert!(
                Snapshot::decode(&bad).is_err(),
                "bit flip at byte {byte} accepted"
            );
        }
        // Trailing garbage is rejected, not silently ignored.
        let mut long = bytes.clone();
        long.extend_from_slice(&[0, 0, 0, 0, 0]);
        assert!(Snapshot::decode(&long).is_err());
    }

    #[test]
    fn atomic_write_load_latest_and_prune() {
        let dir = std::env::temp_dir().join(format!(
            "fnck-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_str().unwrap().to_string();

        // Empty / missing directory: no snapshot, not an error.
        assert!(load_latest(&dir_s).unwrap().is_none());

        let mut snap = newton_snap();
        for round in [3u64, 5, 7] {
            snap.round_next = round;
            write_snapshot(&dir_s, &snap).unwrap();
        }
        assert_eq!(load_latest(&dir_s).unwrap().unwrap().round_next, 7);

        // Corrupt tail (bit flip) falls back to the previous snapshot;
        // a truncated tail likewise.
        let tail = snapshot_path(&dir, 7);
        let mut bytes = std::fs::read(&tail).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&tail, &bytes).unwrap();
        assert_eq!(load_latest(&dir_s).unwrap().unwrap().round_next, 5);
        std::fs::write(&tail, &bytes[..10]).unwrap();
        assert_eq!(load_latest(&dir_s).unwrap().unwrap().round_next, 5);

        // A stale .tmp (crash between write and rename) is invisible.
        std::fs::write(dir.join("ck-000000000009.fnck.tmp"), b"junk")
            .unwrap();
        assert_eq!(load_latest(&dir_s).unwrap().unwrap().round_next, 5);

        // Prune keeps the newest `keep` files.
        snap.round_next = 9;
        write_snapshot(&dir_s, &snap).unwrap();
        prune(&dir_s, 2).unwrap();
        let names = snapshot_files(&dir).unwrap();
        assert_eq!(
            names.iter().map(|(r, _)| *r).collect::<Vec<_>>(),
            vec![7, 9]
        );

        // Every remaining snapshot corrupt = a hard error, not a
        // silent cold start.
        for (_, p) in &names {
            std::fs::write(p, b"garbage").unwrap();
        }
        assert!(load_latest(&dir_s).is_err());

        let _ = std::fs::remove_dir_all(&dir);
    }
}

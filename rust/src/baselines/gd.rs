//! Distributed gradient descent with Armijo backtracking — the simplest
//! first-order comparator: one d-vector up + one down per client per
//! round, many rounds (its round complexity scales with the condition
//! number, unlike FedNL's).

use super::{armijo, pool_loss_grad, BaselineOptions};
use crate::coordinator::ClientPool;
use crate::linalg::vector;
use crate::metrics::{RoundRecord, Trace};
use crate::net::wire;
use crate::utils::Stopwatch;

/// Run GD until ‖∇f‖ ≤ tol or the round budget is exhausted.
pub fn run_gd(
    pool: &mut dyn ClientPool,
    opts: &BaselineOptions,
    x0: Vec<f64>,
) -> Trace {
    let mut x = x0;
    let d = x.len();
    let mut trace = Trace::new("GD");
    let sw = Stopwatch::start();
    let mut bytes_up = 0u64;
    let mut bytes_down = 0u64;
    let n = pool.n_clients() as u64;
    // Warm-started step: reuse the last accepted step as next trial
    // (doubled), so GD does not pay a full backtrack every round.
    let mut step = 1.0;

    for round in 0..opts.max_rounds {
        let (f_x, grad) = pool_loss_grad(pool, &x);
        // Exact framed sizes (LOSS_GRAD command down, GRAD reply up).
        bytes_down += wire::vec_frame_bytes(d) * n;
        bytes_up += wire::scalar_vec_frame_bytes(d) * n;
        let gnorm = vector::norm2(&grad);
        trace.push(RoundRecord {
            round,
            grad_norm: gnorm,
            loss: f_x,
            bytes_up,
            bytes_down,
            elapsed: sw.elapsed_secs(),
            // Baseline reductions are all-or-nothing: full rounds only.
            committed: n as u32,
            missing: 0,
            flagged: 0,
        });
        if gnorm <= opts.tol_grad {
            break;
        }
        let mut dir = grad.clone();
        vector::scale(-1.0, &mut dir);
        let accepted =
            armijo(pool, &x, f_x, &grad, &dir, step * 2.0, 1e-4, 0.5, 60);
        bytes_down += wire::vec_frame_bytes(d) * n; // probes (≥1)
        bytes_up += wire::scalar_frame_bytes() * n;
        if accepted == 0.0 {
            break; // numerically stuck
        }
        step = accepted;
        let xc = x.clone();
        vector::add_scaled(&xc, accepted, &dir, &mut x);
    }
    trace
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::algorithms::ClientState;
    use crate::compressors::Identity;
    use crate::coordinator::SeqPool;
    use crate::data::{generate_synthetic, Dataset, SynthSpec};
    use crate::oracle::LogisticOracle;

    pub(crate) fn pool(n: usize, seed: u64) -> (SeqPool, usize) {
        let spec = SynthSpec {
            d_raw: 6,
            n_samples: n * 40,
            density: 0.7,
            noise: 1.0,
            label_bias: 0.0,
            seed,
        };
        let synth = generate_synthetic(&spec);
        let samples: Vec<crate::data::LibsvmSample> = synth
            .labels
            .iter()
            .zip(&synth.rows)
            .map(|(l, r)| crate::data::LibsvmSample {
                label: *l,
                features: r.clone(),
            })
            .collect();
        let ds = Dataset::from_libsvm(&samples, spec.d_raw);
        let d = ds.d;
        let clients = ds
            .split_even(n)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                ClientState::new(
                    i,
                    Box::new(LogisticOracle::new(sh, 1e-3)),
                    Box::new(Identity),
                    None,
                )
            })
            .collect();
        (SeqPool::new(clients), d)
    }

    #[test]
    fn gd_converges_to_moderate_tolerance() {
        let (mut p, d) = pool(3, 41);
        let opts = BaselineOptions { max_rounds: 3000, tol_grad: 1e-6 };
        let tr = run_gd(&mut p, &opts, vec![0.0; d]);
        assert!(tr.last_grad_norm() <= 1e-6, "‖∇f‖={}", tr.last_grad_norm());
    }

    #[test]
    fn gd_needs_more_rounds_than_fednl() {
        let (mut p, d) = pool(3, 42);
        let opts = BaselineOptions { max_rounds: 5000, tol_grad: 1e-8 };
        let tr = run_gd(&mut p, &opts, vec![0.0; d]);
        let gd_rounds = tr.rounds_to_tolerance(1e-8).unwrap_or(u64::MAX);
        // Direct comparator: FedNL with Identity compression on the
        // same shards (fresh pool — GD mutated nothing, but be safe).
        let (mut p2, _) = pool(3, 42);
        let fopts = crate::algorithms::Options {
            rounds: 5000,
            tol_grad: Some(1e-8),
            ..Default::default()
        };
        let ft = crate::algorithms::run_fednl(
            &mut p2.clients,
            &fopts,
            vec![0.0; d],
        );
        let fednl_rounds = ft.rounds_to_tolerance(1e-8).unwrap();
        assert!(
            gd_rounds > fednl_rounds,
            "GD {gd_rounds} rounds vs FedNL {fednl_rounds}"
        );
    }

    #[test]
    fn gd_loss_never_increases() {
        let (mut p, d) = pool(2, 43);
        let opts = BaselineOptions { max_rounds: 200, tol_grad: 1e-12 };
        let tr = run_gd(&mut p, &opts, vec![0.0; d]);
        for w in tr.records.windows(2) {
            assert!(w[1].loss <= w[0].loss + 1e-12);
        }
    }
}

//! Experiment metrics: per-round convergence traces (the series behind
//! the paper's Figures 1–12), table/report writers, process-level
//! resource introspection (Tables 5–7), and the §4 back-of-envelope cost
//! model.

pub mod costmodel;
pub mod report;
pub mod rusage;
pub mod trace;

pub use trace::{RoundRecord, Trace};

//! Baseline solvers (DESIGN.md §2 substitution for CVXPY / MOSEK /
//! Spark MLlib / Ray-Scikit-Learn, which are unavailable offline).
//!
//! All baselines are *first-order or quasi-Newton* methods driven
//! through the same [`ClientPool`] transport as FedNL, so the
//! single-node (Table 2) and multi-node TCP (Table 3) comparisons
//! exercise identical substrates: per round they move a dense d-vector
//! per client — no Hessian compression, many more rounds. The
//! *uncompressed Newton* comparator is FedNL itself with the Identity
//! compressor and warm start (exact distributed Newton from round 1).

pub mod gd;
pub mod lbfgs;
pub mod nesterov;

pub use gd::run_gd;
pub use lbfgs::run_lbfgs;
pub use nesterov::run_nesterov;

use crate::coordinator::ClientPool;
use crate::linalg::vector;

/// One full-gradient reduction over the pool: (f(x), ∇f(x)).
///
/// Implemented on top of `ClientPool::round` would waste a Hessian
/// evaluation per probe, so baselines use the dedicated
/// [`ClientPool::loss_grad`] reduction.
pub(crate) fn pool_loss_grad(
    pool: &mut dyn ClientPool,
    x: &[f64],
) -> (f64, Vec<f64>) {
    pool.loss_grad(x)
}

/// Shared Armijo backtracking on f along direction `dir` from `x`.
/// Returns the accepted step (0.0 if even the smallest trial fails).
pub(crate) fn armijo(
    pool: &mut dyn ClientPool,
    x: &[f64],
    f_x: f64,
    grad: &[f64],
    dir: &[f64],
    step0: f64,
    c: f64,
    gamma: f64,
    max_backtracks: u32,
) -> f64 {
    let slope = vector::dot(grad, dir);
    let mut step = step0;
    let mut trial = vec![0.0; x.len()];
    for _ in 0..=max_backtracks {
        vector::add_scaled(x, step, dir, &mut trial);
        let f_t = pool.eval_loss(&trial);
        if f_t <= f_x + c * step * slope {
            return step;
        }
        step *= gamma;
    }
    0.0
}

/// Common options for baseline solvers.
#[derive(Debug, Clone)]
pub struct BaselineOptions {
    pub max_rounds: u64,
    pub tol_grad: f64,
}

impl Default for BaselineOptions {
    fn default() -> Self {
        Self { max_rounds: 10_000, tol_grad: 1e-9 }
    }
}

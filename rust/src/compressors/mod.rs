//! Communication compressors for Hessian learning (paper §8, App. C, D).
//!
//! All compressors act on the *packed upper triangle* of the symmetric
//! difference `∇²fᵢ(xᵏ) − Hᵢᵏ` (length n = d(d+1)/2), exactly as the
//! paper's implementation does (App. C.1). Contraction/variance is
//! accounted in the Frobenius norm of the full symmetric matrix, i.e.
//! off-diagonal entries carry weight 2.
//!
//! Compressor zoo (paper Table 1):
//! * [`TopK`]      — k largest energy entries, via a 4-ary min-heap
//!                   (§5.11: the winning strategy among quick/merge/radix
//!                   sorts and CO sorts).
//! * [`RandK`]     — k-subset u.a.r., seed-reconstructible (§7).
//! * [`RandSeqK`]  — NEW in paper (App. C): one PRG call, contiguous
//!                   wrap-around window → cache-aware.
//! * [`TopLEK`]    — NEW in paper (App. D): adaptive k' ≤ k making the
//!                   contractive inequality *tight* in expectation.
//! * [`Natural`]   — unbiased exponent rounding (Horváth et al.), ω=1/8,
//!                   bit-level implementation.
//! * [`Identity`]  — C(x) = x (δ = 1), the uncompressed baseline.
//!
//! The FedNL Hessian learning rate is derived from the compressor class
//! (paper §2: "the only quantity not evaluated in runtime is α"):
//! contractive with parameter δ → α = 1 − √(1−δ); unbiased with variance
//! ω → the compressor is used in its scaled contractive form
//! (values · 1/(1+ω)) with δ = 1/(1+ω).

pub mod natural;
pub mod randk;
pub mod randseqk;
pub mod toplek;
pub mod topk;

pub use natural::Natural;
pub use randk::RandK;
pub use randseqk::RandSeqK;
pub use topk::TopK;
pub use toplek::TopLEK;

use crate::linalg::packed::PackedUpper;

/// How the chosen coordinates travel on the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum IndexPayload {
    /// Explicit fixed-width 32-bit indices (TopK/TopLEK; §7: fixed-width
    /// beat varint).
    Explicit(Vec<u32>),
    /// PRG seed; the master regenerates the k-subset (RandK mode (ii)).
    Seed { seed: u64, k: u32 },
    /// Single start index; indices are (start..start+k) mod n (RandSeqK).
    SeqStart { start: u32, k: u32 },
    /// All coordinates, in order (Identity / Natural).
    Dense,
}

/// How values are represented on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueEncoding {
    /// Raw IEEE-754 doubles (8 bytes each).
    F64,
    /// Signed powers of two in 16 bits (Natural compressor: sign +
    /// 11-bit exponent — the paper's "granularity of bits").
    Pow2x16,
}

/// A compressed symmetric-matrix update in packed coordinates.
#[derive(Debug, Clone)]
pub struct Compressed {
    pub payload: IndexPayload,
    /// Selected values. Consumers must apply `scale` (contractive form):
    /// H ← H + α·scale·values.
    pub values: Vec<f64>,
    /// Post-scaling factor (1.0 for most; 1/(1+ω) for unbiased
    /// compressors used in scaled contractive form). Kept separate so
    /// `values` stay bit-exactly encodable (Natural: pure powers of 2).
    pub scale: f64,
    pub encoding: ValueEncoding,
    /// Packed length n of the source vector (for index reconstruction).
    pub n: u32,
}

impl Compressed {
    /// Materialize the packed indices this update touches.
    pub fn indices(&self) -> Vec<u32> {
        match &self.payload {
            IndexPayload::Explicit(ix) => ix.clone(),
            IndexPayload::Seed { seed, k } => {
                let mut rng = crate::rng::Pcg64::seed_from_u64(*seed);
                crate::rng::sample_distinct(&mut rng, self.n as usize, *k as usize)
            }
            IndexPayload::SeqStart { start, k } => {
                (0..*k).map(|t| (*start + t) % self.n).collect()
            }
            IndexPayload::Dense => (0..self.n).collect(),
        }
    }

    /// Scatter into a dense packed buffer (zero elsewhere), applying
    /// `scale`.
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.n as usize];
        for (i, &ix) in self.indices().iter().enumerate() {
            out[ix as usize] = self.scale * self.values[i];
        }
        out
    }

    /// Bytes this update occupies on the wire (paper's "communicated
    /// bits" accounting, App. E.1): values + index side-channel + the
    /// fixed codec fields. Matches `net::wire`'s `put_compressed`
    /// byte-for-byte (asserted by the codec tests), so logical and
    /// transport-metered accounting agree.
    pub fn wire_bytes(&self) -> u64 {
        let per_value = match self.encoding {
            ValueEncoding::F64 => 8,
            ValueEncoding::Pow2x16 => 2,
        };
        let vals = self.values.len() as u64 * per_value;
        let idx = match &self.payload {
            IndexPayload::Explicit(ix) => 4 * ix.len() as u64 + 4,
            IndexPayload::Seed { .. } => 12,
            IndexPayload::SeqStart { .. } => 8,
            IndexPayload::Dense => 0,
        };
        vals + idx + CODEC_OVERHEAD_BYTES
    }
}

/// Fixed per-update codec bytes the wire encoder adds around the index
/// and value payloads: n (4) + payload tag (1) + scale (8) + value
/// count (4) + encoding tag (1). Kept in sync with `net::wire`'s
/// `put_compressed` by the codec tests.
pub const CODEC_OVERHEAD_BYTES: u64 = 18;

/// Compressor class, as used for the theoretical α.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CompressorKind {
    /// E‖C(x)−x‖² ≤ (1−δ)‖x‖².
    Contractive { delta: f64 },
    /// E C(x) = x, E‖C(x)−x‖² ≤ ω‖x‖² — used in scaled contractive form.
    Unbiased { omega: f64 },
}

impl CompressorKind {
    /// δ of the (possibly scaled) contractive form.
    pub fn delta(&self) -> f64 {
        match *self {
            CompressorKind::Contractive { delta } => delta,
            CompressorKind::Unbiased { omega } => 1.0 / (1.0 + omega),
        }
    }

    /// Default FedNL Hessian learning rate for this compressor class.
    ///
    /// α = 1 is admissible for the whole (scaled-)contractive class:
    /// with Hᵏ⁺¹ = Hᵏ + C(D), E‖Hᵏ⁺¹ − ∇²f‖² = E‖D − C(D)‖² ≤
    /// (1−δ)‖D‖², i.e. the Hessian error already contracts at (1−δ)
    /// per round — this is what the reference implementation runs and
    /// what reproduces the paper's ‖∇f‖ ≈ 1e-18 at r = 1000. The
    /// conservative worst-case Lyapunov rate 1 − √(1−δ) can be forced
    /// via [`crate::algorithms::Options::alpha`].
    pub fn alpha(&self) -> f64 {
        1.0
    }

    /// The conservative theory rate 1 − √(1−δ).
    pub fn alpha_conservative(&self) -> f64 {
        1.0 - (1.0 - self.delta()).sqrt()
    }
}

/// A (possibly stateful) compression operator on packed upper triangles.
pub trait Compressor: Send {
    /// Display name matching the paper's tables.
    fn name(&self) -> String;

    /// Class parameters (δ / ω) for the given packed length.
    fn kind(&self, n: usize) -> CompressorKind;

    /// Compress `src` (packed upper triangle, already weighted per the
    /// layout — see [`PackedUpper`]). `round` feeds per-round seeds.
    fn compress(
        &mut self,
        pu: &PackedUpper,
        src: &[f64],
        round: u64,
    ) -> Compressed;
}

/// Construct a compressor by table name ("topk", "randk", "randseqk",
/// "toplek", "natural", "identity"), with k given in *multiples of d*
/// for the sparsifiers (the paper uses K = 8d).
pub fn by_name(
    name: &str,
    d: usize,
    k_mult_d: usize,
    seed: u64,
) -> anyhow::Result<Box<dyn Compressor>> {
    let k = k_mult_d * d;
    Ok(match name {
        "topk" => Box::new(TopK::new(k)),
        "randk" => Box::new(RandK::new(k, seed)),
        "randseqk" => Box::new(RandSeqK::new(k, seed)),
        "toplek" => Box::new(TopLEK::new(k, seed)),
        "natural" => Box::new(Natural::new()),
        "identity" | "ident" => Box::new(Identity),
        other => anyhow::bail!("unknown compressor '{other}'"),
    })
}

/// All compressor names, in the order of the paper's Table 1.
pub const ALL_NAMES: [&str; 6] =
    ["randk", "topk", "randseqk", "toplek", "natural", "identity"];

/// C(x) = x — the dense baseline (Table 1 row "Ident").
#[derive(Debug, Clone, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "Ident".into()
    }

    fn kind(&self, _n: usize) -> CompressorKind {
        CompressorKind::Contractive { delta: 1.0 }
    }

    fn compress(
        &mut self,
        _pu: &PackedUpper,
        src: &[f64],
        _round: u64,
    ) -> Compressed {
        Compressed {
            payload: IndexPayload::Dense,
            values: src.to_vec(),
            scale: 1.0,
            encoding: ValueEncoding::F64,
            n: src.len() as u32,
        }
    }
}

/// Frobenius-weighted squared norm of a packed vector (helper shared by
/// compressors and tests): diagonal weight 1, off-diagonal weight 2.
pub fn weighted_norm_sq(pu: &PackedUpper, src: &[f64]) -> f64 {
    pu.frobenius_sq_packed(src)
}

/// Frobenius-weighted squared distortion ‖C(x) − x‖² of a compressed
/// update against its source (test/diagnostic helper).
pub fn distortion_sq(pu: &PackedUpper, src: &[f64], c: &Compressed) -> f64 {
    let dense = c.to_dense();
    let mut diff = vec![0.0; src.len()];
    for i in 0..src.len() {
        diff[i] = dense[i] - src[i];
    }
    pu.frobenius_sq_packed(&diff)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_from_kind() {
        let c = CompressorKind::Contractive { delta: 1.0 };
        assert_eq!(c.alpha(), 1.0);
        assert_eq!(c.alpha_conservative(), 1.0);
        let u = CompressorKind::Unbiased { omega: 1.0 / 8.0 };
        assert!((u.delta() - 8.0 / 9.0).abs() < 1e-15);
        assert_eq!(u.alpha(), 1.0);
        assert!(
            (u.alpha_conservative() - (1.0 - (1.0f64 / 9.0).sqrt())).abs()
                < 1e-15
        );
    }

    #[test]
    fn identity_roundtrip() {
        let pu = PackedUpper::new(4);
        let src: Vec<f64> = (0..pu.len()).map(|i| i as f64 - 3.0).collect();
        let mut c = Identity;
        let out = c.compress(&pu, &src, 0);
        assert_eq!(out.to_dense(), src);
        assert_eq!(distortion_sq(&pu, &src, &out), 0.0);
    }

    #[test]
    fn by_name_all() {
        for n in ALL_NAMES {
            assert!(by_name(n, 8, 2, 1).is_ok(), "{n}");
        }
        assert!(by_name("bogus", 8, 2, 1).is_err());
    }
}

//! Back-of-the-envelope cost model (paper §4).
//!
//! Reproduces the paper's lower-bound estimate for one FedNL simulation:
//! client flops O((d²nᵢ + dnᵢ + 2d²)·r), master reduction O((dk + d)·r·n),
//! master solve O(⅔d³·r), divided by clock × cores × FPUs, plus the ×3
//! L1-latency memory-access penalty. With the paper's parameters it
//! yields ≈17.6 s — against 19 770 s observed for the Python baseline
//! (the ×1000 headline gap).

/// Machine model (paper: Xeon Gold 6246 @ 3.3 GHz, 12 cores, 3 FPUs).
#[derive(Debug, Clone)]
pub struct MachineModel {
    pub clock_hz: f64,
    pub cores: f64,
    pub fpus: f64,
    pub load_store_units: f64,
    /// L1 access penalty relative to a register op (Table 8: ×3).
    pub l1_penalty: f64,
}

impl Default for MachineModel {
    fn default() -> Self {
        Self {
            clock_hz: 3.3e9,
            cores: 12.0,
            fpus: 3.0,
            load_store_units: 3.0,
            l1_penalty: 3.0,
        }
    }
}

/// FedNL workload parameters.
#[derive(Debug, Clone)]
pub struct Workload {
    pub d: f64,
    pub n_clients: f64,
    pub n_i: f64,
    pub k: f64,
    pub rounds: f64,
}

/// Cost estimate decomposition (seconds).
#[derive(Debug, Clone)]
pub struct CostEstimate {
    pub client_compute: f64,
    pub master_reduce: f64,
    pub master_solve: f64,
    pub memory_penalty: f64,
}

impl CostEstimate {
    pub fn total(&self) -> f64 {
        self.client_compute + self.master_reduce + self.master_solve
            + self.memory_penalty
    }
}

/// The §4 estimate.
pub fn estimate(m: &MachineModel, w: &Workload) -> CostEstimate {
    let Workload { d, n_clients, n_i, k, rounds } = *w;
    // Clients: hessian d²nᵢ, gradient dnᵢ, compress+shift 2d² per round.
    // The paper's formula charges one client's chain spread over
    // cores × fpus (clients run concurrently on the worker pool).
    let client_flops = (d * d * n_i + d * n_i + 2.0 * d * d) * rounds;
    let client_compute = client_flops / (m.clock_hz * m.cores * m.fpus);
    // Master: additions of dk Hessian elements + d gradient entries per
    // round (the paper's formula; the n_clients factor is absorbed by
    // the helper pool running on all cores).
    let _ = n_clients;
    let master_flops = (d * k + d) * rounds;
    let master_reduce = master_flops / (m.clock_hz * m.cores * m.fpus);
    // Master solve: (2/3)d³ per round, single-threaded chain (paper uses
    // 3/2·d³/(µ·fpu); we keep their formula).
    let master_solve = 1.5 * d * d * d * rounds / (m.clock_hz * m.fpus);
    // Memory penalty: each flop needs ~3 L1 accesses at ×penalty through
    // `ls` load/store units (paper: (t·fpu)/ls·3).
    let arith = client_compute + master_reduce + master_solve;
    let memory_penalty =
        arith * m.fpus / m.load_store_units * m.l1_penalty;
    CostEstimate { client_compute, master_reduce, master_solve, memory_penalty }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's own numbers (§4): d=301, n=142, nᵢ=348, k=8d, r=1000.
    fn paper_workload() -> Workload {
        Workload { d: 301.0, n_clients: 142.0, n_i: 348.0, k: 8.0 * 301.0, rounds: 1000.0 }
    }

    #[test]
    fn reproduces_paper_client_estimate() {
        let e = estimate(&MachineModel::default(), &paper_workload());
        // Paper: client compute ≈ 0.26 s.
        assert!(
            (e.client_compute - 0.26).abs() < 0.05,
            "client_compute = {}",
            e.client_compute
        );
    }

    #[test]
    fn reproduces_paper_solve_estimate() {
        let e = estimate(&MachineModel::default(), &paper_workload());
        // Paper: ≈ 4.13 s.
        assert!(
            (e.master_solve - 4.13).abs() < 0.15,
            "master_solve = {}",
            e.master_solve
        );
    }

    #[test]
    fn total_matches_paper_lower_bound() {
        let e = estimate(&MachineModel::default(), &paper_workload());
        // Paper total ≈ 17.576 s. Accept 16–19 s.
        let t = e.total();
        assert!(t > 16.0 && t < 19.0, "total = {t}");
    }

    #[test]
    fn master_reduce_is_negligible() {
        let e = estimate(&MachineModel::default(), &paper_workload());
        assert!(e.master_reduce < 0.1, "{}", e.master_reduce);
    }
}

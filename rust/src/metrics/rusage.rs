//! Process-level resource introspection (paper Appendix F, Tables 5–7:
//! peak kernel handles / private bytes / peak working set).
//!
//! The paper measures Windows kernel objects; the Linux analogues we
//! report are open file descriptors (`/proc/self/fd`), virtual memory
//! (`VmSize`/`VmPeak`) and resident set (`VmRSS`/`VmHWM`).

/// A point-in-time resource snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResourceSnapshot {
    /// Open file descriptors (≈ kernel handles, Table 5).
    pub open_fds: u64,
    /// Current virtual memory (KiB) (≈ private bytes, Table 6).
    pub vm_size_kib: u64,
    /// Peak virtual memory (KiB).
    pub vm_peak_kib: u64,
    /// Current resident set (KiB) (≈ working set, Table 7).
    pub vm_rss_kib: u64,
    /// Peak resident set (KiB).
    pub vm_hwm_kib: u64,
    /// Kernel-visible threads.
    pub threads: u64,
}

impl ResourceSnapshot {
    /// Capture from /proc/self (Linux only; zeros elsewhere).
    pub fn capture() -> Self {
        let mut snap = Self::default();
        if let Ok(dir) = std::fs::read_dir("/proc/self/fd") {
            snap.open_fds = dir.count() as u64;
        }
        if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
            for line in status.lines() {
                let mut parts = line.split_whitespace();
                match parts.next() {
                    Some("VmSize:") => snap.vm_size_kib = parse_kib(parts.next()),
                    Some("VmPeak:") => snap.vm_peak_kib = parse_kib(parts.next()),
                    Some("VmRSS:") => snap.vm_rss_kib = parse_kib(parts.next()),
                    Some("VmHWM:") => snap.vm_hwm_kib = parse_kib(parts.next()),
                    Some("Threads:") => snap.threads = parse_kib(parts.next()),
                    _ => {}
                }
            }
        }
        snap
    }
}

fn parse_kib(s: Option<&str>) -> u64 {
    s.and_then(|v| v.parse().ok()).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_is_sane_on_linux() {
        let s = ResourceSnapshot::capture();
        // We are on Linux in CI; these should all be populated.
        assert!(s.open_fds > 0);
        assert!(s.vm_size_kib > 0);
        assert!(s.vm_rss_kib > 0);
        assert!(s.vm_peak_kib >= s.vm_size_kib);
        assert!(s.threads >= 1);
    }

    #[test]
    fn rss_grows_with_allocation() {
        let before = ResourceSnapshot::capture();
        let blob: Vec<u8> = vec![1u8; 64 << 20]; // 64 MiB touched
        let after = ResourceSnapshot::capture();
        assert!(after.vm_hwm_kib >= before.vm_hwm_kib);
        drop(blob);
    }
}

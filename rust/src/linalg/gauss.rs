//! Gaussian elimination with partial pivoting — the paper's *pre-v10*
//! linear solver, retained as the ablation baseline for §5.9 ("we
//! transitioned from dense Gaussian elimination to ... Cholesky").

use super::matrix::Mat;

/// Solve `A x = b` by Gaussian elimination with partial pivoting.
/// Returns `None` when a pivot underflows (singular to working precision).
pub fn solve_gauss(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let d = a.rows();
    assert_eq!(a.cols(), d);
    assert_eq!(b.len(), d);
    // Augmented working copy.
    let mut m = a.clone();
    let mut rhs = b.to_vec();

    for col in 0..d {
        // Partial pivot: largest |entry| in the column at/below `col`.
        let mut piv = col;
        let mut best = m.get(col, col).abs();
        for r in col + 1..d {
            let v = m.get(r, col).abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-300 || !best.is_finite() {
            return None;
        }
        if piv != col {
            for j in 0..d {
                let tmp = m.get(col, j);
                m.set(col, j, m.get(piv, j));
                m.set(piv, j, tmp);
            }
            rhs.swap(col, piv);
        }
        // Eliminate below.
        let pivot = m.get(col, col);
        for r in col + 1..d {
            let f = m.get(r, col) / pivot;
            if f == 0.0 {
                continue;
            }
            m.set(r, col, 0.0);
            for j in col + 1..d {
                let v = m.get(r, j) - f * m.get(col, j);
                m.set(r, j, v);
            }
            rhs[r] -= f * rhs[col];
        }
    }
    // Back substitution.
    let mut x = vec![0.0; d];
    for i in (0..d).rev() {
        let mut s = rhs[i];
        for j in i + 1..d {
            s -= m.get(i, j) * x[j];
        }
        x[i] = s / m.get(i, i);
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky;
    use crate::rng::{Pcg64, Rng};

    #[test]
    fn solves_small_known_system() {
        // [2 1; 1 3] x = [3; 5]  ⇒  x = [4/5, 7/5]
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = solve_gauss(&a, &[3.0, 5.0]).unwrap();
        assert!((x[0] - 0.8).abs() < 1e-14);
        assert!((x[1] - 1.4).abs() < 1e-14);
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        let a = Mat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let x = solve_gauss(&a, &[2.0, 3.0]).unwrap();
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn singular_returns_none() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(solve_gauss(&a, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn agrees_with_cholesky_on_spd() {
        let d = 20;
        let mut rng = Pcg64::seed_from_u64(7);
        let bmat = Mat::from_vec(
            d,
            d,
            (0..d * d).map(|_| rng.next_gaussian()).collect(),
        );
        let mut a = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += bmat.get(k, i) * bmat.get(k, j);
                }
                a.set(i, j, s);
            }
        }
        a.add_diag(0.5);
        let b: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let xg = solve_gauss(&a, &b).unwrap();
        let xc = cholesky::solve_spd(&a, 0.0, &b).unwrap();
        for i in 0..d {
            assert!((xg[i] - xc[i]).abs() < 1e-8);
        }
    }
}

//! The experiments themselves — one function per paper table/figure.

use anyhow::Result;

use super::{prepare_problem, HarnessCfg, Problem, ProblemSpec, Scale};
use super::{A9A, PHISHING, W8A};
use crate::algorithms::{
    run_fednl_ls_pool, run_fednl_pool, run_fednl_pp_pool, LineSearchParams,
    OnMissing, Options, RoundPolicy,
};
use crate::baselines::{run_gd, run_lbfgs, run_nesterov, BaselineOptions};
use crate::coordinator::{
    shard, ClientPool, FaultPlan, FaultPool, SeqPool, ShardedPool,
    ThreadedPool,
};
use crate::metrics::report::{sci, Table};
use crate::metrics::rusage::ResourceSnapshot;
use crate::metrics::Trace;
use crate::net::{
    run_client, run_relay_on, server::Bound, RelayCfg, RelayPool,
};
use crate::utils::{human_bytes, human_secs, Stopwatch};

/// Compressors in Table 1 order, with the paper's K = 8d.
const TABLE1_ROWS: [&str; 6] =
    ["randk", "topk", "randseqk", "toplek", "natural", "identity"];
pub const K_MULT: usize = 8;

// ---------------------------------------------------------------------
// Table 1: single-node simulation, FedNL(B), all compressors.
// ---------------------------------------------------------------------

pub fn table1(cfg: &HarnessCfg) -> Result<String> {
    cfg.ensure_out_dir()?;
    let problem = prepare_problem(&W8A, cfg)?;
    let mut table = Table::new(&[
        "Client Compression",
        "||∇f(x_last)||",
        "Total Time (s)",
        "MB to master",
        "Rounds",
    ]);
    let mut out = format!(
        "## Table 1 — single-node simulation (n={}, n_i={}, r={}, d={}, λ=1e-3, α theory, {})\n\n",
        problem.n_clients,
        problem.n_i,
        problem.rounds,
        problem.d(),
        if cfg.pjrt { "PJRT oracle" } else { "native oracle" },
    );
    for comp in TABLE1_ROWS {
        let sw = Stopwatch::start();
        let mut pool = problem.pool(comp, K_MULT, cfg)?;
        let opts = Options {
            rounds: problem.rounds,
            warm_start: true,
            ..Default::default()
        };
        let trace = run_fednl_pool(
            pool.as_mut(),
            &opts,
            vec![0.0; problem.d()],
            &format!("FedNL/{comp}"),
        );
        let total = sw.elapsed_secs();
        trace.write_csv(&format!("{}/table1_{comp}.csv", cfg.out_dir))?;
        table.row(&[
            format!("{comp}[K={K_MULT}d]"),
            sci(trace.last_grad_norm()),
            format!("{total:.2}"),
            human_bytes(trace.total_bytes_up()),
            format!("{}", trace.records.len()),
        ]);
    }
    out.push_str(&table.to_markdown());
    Ok(out)
}

// ---------------------------------------------------------------------
// Table 2: FedNL-LS vs baseline solvers, init + solve time, 3 datasets.
// ---------------------------------------------------------------------

pub fn table2(cfg: &HarnessCfg) -> Result<String> {
    cfg.ensure_out_dir()?;
    let tol = 1e-9;
    let mut out = String::from(
        "## Table 2 — single-node: FedNL-LS vs baseline solvers (tol ‖∇f‖ ≈ 1e-9)\n\n",
    );
    for spec in [&W8A, &A9A, &PHISHING] {
        let problem = prepare_problem(spec, cfg)?;
        let d = problem.d();
        let mut table = Table::new(&["Solver", "Init (s)", "Solve (s)", "Rounds"]);
        // Baselines (CVXPY-solver substitutes, DESIGN.md §2).
        let bopts = BaselineOptions {
            max_rounds: if cfg.scale == Scale::Full { 200_000 } else { 20_000 },
            tol_grad: tol,
        };
        type Runner = Box<dyn Fn(&mut dyn ClientPool, &BaselineOptions) -> Trace>;
        let runs: Vec<(&str, Runner)> = vec![
            (
                "GD (CVXPY-class sub)",
                Box::new(move |p, b| run_gd(p, b, vec![0.0; d])),
            ),
            (
                "Nesterov (CVXPY-class sub)",
                Box::new(move |p, b| run_nesterov(p, b, vec![0.0; d])),
            ),
            (
                "L-BFGS (MOSEK-class sub)",
                Box::new(move |p, b| run_lbfgs(p, b, 10, vec![0.0; d])),
            ),
        ];
        for (name, run) in runs {
            // Default pool: multi-threaded simulator (--seq falls back
            // to the sequential reference; identical trajectories).
            let mut pool = problem.pool("identity", K_MULT, cfg)?;
            let sw = Stopwatch::start();
            let tr = run(pool.as_mut(), &bopts);
            table.row(&[
                name.to_string(),
                format!("+{:.3}", problem.init_secs),
                format!("{:.3}", sw.elapsed_secs()),
                format!("{}", tr.records.len()),
            ]);
        }
        // FedNL-LS with every compressor.
        for comp in TABLE1_ROWS {
            let mut pool = problem.pool(comp, K_MULT, cfg)?;
            let opts = Options {
                rounds: 100_000,
                tol_grad: Some(tol),
                warm_start: true,
                ..Default::default()
            };
            let sw = Stopwatch::start();
            let tr = run_fednl_ls_pool(
                pool.as_mut(),
                &opts,
                &LineSearchParams::default(),
                vec![0.0; d],
                &format!("FedNL-LS/{comp}"),
            );
            let solve = sw.elapsed_secs();
            tr.write_csv(&format!(
                "{}/table2_{}_{comp}.csv",
                cfg.out_dir, spec.name
            ))?;
            table.row(&[
                format!("FedNL-LS/{comp}[k={K_MULT}d]"),
                format!("+{:.3}", problem.init_secs),
                format!("{solve:.3}"),
                format!("{}", tr.records.len()),
            ]);
        }
        out.push_str(&format!(
            "### {} (d={}, n={}, n_i={})\n\n{}\n",
            spec.name,
            d,
            problem.n_clients,
            problem.n_i,
            table.to_markdown()
        ));
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Table 3 / Figures 4-12: multi-node over real TCP (loopback).
// ---------------------------------------------------------------------

/// Which algorithm a TCP run executes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TcpAlgo {
    FedNL,
    FedNLLS,
    FedNLPP { tau: usize },
    Gd,
    Lbfgs,
}

/// Spawn one TCP client thread per shard of `problem` (the paper runs
/// these as separate Slurm nodes; the transport, wire format and
/// algorithm logic are identical). `pp` selects the FedNL-PP client
/// loop (initialized at x⁰ = 0). Shared by `run_tcp_experiment` and
/// `fault_smoke`.
type ClientHandle = std::thread::JoinHandle<Result<(u64, u64)>>;

fn spawn_shard_clients(
    problem: &Problem,
    compressor: &str,
    addr: &str,
    pp: bool,
    cfg: &HarnessCfg,
) -> Result<Vec<ClientHandle>> {
    use crate::algorithms::{ClientState, PPClientState};
    use crate::net::client::ClientMode;
    use crate::oracle::LogisticOracle;

    let d = problem.d();
    let lam = problem.spec.lam;
    let x0 = vec![0.0; d];
    let mut handles = Vec::new();
    for shard in problem.dataset.split(problem.n_clients, problem.n_i)? {
        let addr = addr.to_string();
        let comp = crate::compressors::by_name(
            compressor,
            d,
            K_MULT,
            cfg.seed + shard.client_id as u64,
        )?;
        let x0c = x0.clone();
        handles.push(std::thread::spawn(move || {
            let id = shard.client_id;
            let oracle = Box::new(LogisticOracle::new(shard, lam));
            let mode = if pp {
                ClientMode::PP(PPClientState::new(id, oracle, comp, None, &x0c))
            } else {
                ClientMode::FedNL(ClientState::new(id, oracle, comp, None))
            };
            run_client(&addr, id, mode)
        }));
    }
    Ok(handles)
}

/// Run one multi-node experiment: master + `n_clients` client threads
/// over loopback TCP. Returns (trace, wall seconds, init seconds).
pub fn run_tcp_experiment(
    problem: &Problem,
    compressor: &str,
    algo: TcpAlgo,
    rounds: u64,
    tol: Option<f64>,
    cfg: &HarnessCfg,
) -> Result<(Trace, f64, f64)> {
    let init_sw = Stopwatch::start();
    let d = problem.d();
    let bound = Bound::bind("127.0.0.1:0")?;
    let addr = bound.local_addr()?.to_string();
    let is_pp = matches!(algo, TcpAlgo::FedNLPP { .. });
    let x0 = vec![0.0; d];
    let handles =
        spawn_shard_clients(problem, compressor, &addr, is_pp, cfg)?;

    let mut pool = bound.accept(problem.n_clients)?;
    let init_secs = init_sw.elapsed_secs() + problem.init_secs;
    let sw = Stopwatch::start();
    let label = format!("{algo:?}/{compressor}");
    let trace = match algo {
        TcpAlgo::FedNL => {
            let opts = Options {
                rounds,
                tol_grad: tol,
                warm_start: true,
                ..Default::default()
            };
            run_fednl_pool(&mut pool, &opts, x0, &label)
        }
        TcpAlgo::FedNLLS => {
            let opts = Options {
                rounds,
                tol_grad: tol,
                warm_start: true,
                ..Default::default()
            };
            run_fednl_ls_pool(
                &mut pool,
                &opts,
                &LineSearchParams::default(),
                x0,
                &label,
            )
        }
        TcpAlgo::FedNLPP { tau } => {
            let opts =
                Options { rounds, tol_grad: tol, ..Default::default() };
            run_fednl_pp_pool(&mut pool, &opts, tau, cfg.seed, x0, &label)
        }
        TcpAlgo::Gd => {
            let bopts = BaselineOptions {
                max_rounds: rounds,
                tol_grad: tol.unwrap_or(1e-9),
            };
            run_gd(&mut pool, &bopts, x0)
        }
        TcpAlgo::Lbfgs => {
            let bopts = BaselineOptions {
                max_rounds: rounds,
                tol_grad: tol.unwrap_or(1e-9),
            };
            run_lbfgs(&mut pool, &bopts, 10, x0)
        }
    };
    let solve_secs = sw.elapsed_secs();
    pool.shutdown();
    for h in handles {
        let _ = h.join();
    }
    Ok((trace, solve_secs, init_secs))
}

/// CI loopback smoke: all three algorithms of the family over real TCP
/// sockets on a tiny synthetic problem — exercises the unified wire
/// protocol, the streaming master and the PP participation subsets in
/// seconds. Fails if any run diverges or makes no progress.
pub fn tcp_smoke(cfg: &HarnessCfg) -> Result<String> {
    let spec = ProblemSpec {
        name: "smoke",
        d: 21,
        n_i_full: 40,
        n_clients_full: 4,
        lam: 1e-3,
    };
    let mut p = prepare_problem(&spec, cfg)?;
    p.n_clients = 4;
    p.n_i = 40;
    let mut out = format!(
        "## TCP loopback smoke (d={}, n={}, n_i={})\n\n",
        p.d(),
        p.n_clients,
        p.n_i
    );
    let mut table = Table::new(&[
        "Algo",
        "||∇f||_final",
        "Rounds",
        "Up",
        "Wall (s)",
    ]);
    let runs: [(&str, TcpAlgo, u64); 3] = [
        ("FedNL", TcpAlgo::FedNL, 15),
        ("FedNL-LS", TcpAlgo::FedNLLS, 15),
        ("FedNL-PP (τ=2)", TcpAlgo::FedNLPP { tau: 2 }, 30),
    ];
    for (name, algo, rounds) in runs {
        let (tr, solve, _) =
            run_tcp_experiment(&p, "topk", algo, rounds, None, cfg)?;
        let first = tr.records.first().map(|r| r.grad_norm).unwrap_or(0.0);
        let last = tr.last_grad_norm();
        anyhow::ensure!(
            last.is_finite() && last < first,
            "{name}: no progress over TCP ({first:.3e} → {last:.3e})"
        );
        table.row(&[
            name.to_string(),
            sci(last),
            format!("{}", tr.records.len()),
            human_bytes(tr.total_bytes_up()),
            format!("{solve:.2}"),
        ]);
    }
    out.push_str(&table.to_markdown());
    Ok(out)
}

/// CI fault smoke: FedNL-PP under a deterministic [`FaultPlan`] — one
/// client killed mid-run and rejoined, two injected stragglers, one
/// dropped participation — on all three transports (SeqPool,
/// ThreadedPool, TCP RemotePool), each wrapped in the same
/// [`FaultPool`]. Asserts the three trajectories are **bit-identical**
/// (the lossy-round determinism invariant) and still converge, then
/// writes the per-round committed/missing trace to
/// `faultsmoke_trace.json` (uploaded as a CI artifact).
pub fn fault_smoke(cfg: &HarnessCfg) -> Result<String> {
    cfg.ensure_out_dir()?;
    let spec = ProblemSpec {
        name: "faultsmoke",
        d: 13,
        n_i_full: 40,
        n_clients_full: 5,
        lam: 1e-3,
    };
    let mut p = prepare_problem(&spec, cfg)?;
    p.n_clients = 5;
    p.n_i = 40;
    let d = p.d();
    let x0 = vec![0.0; d];
    let (tau, rounds) = (4usize, 30u64);
    let plan_spec = "kill@6:1-18,delay@3:2:30,delay@9:4:30,drop@12:0";
    let plan = FaultPlan::parse(plan_spec)?;
    let policy = RoundPolicy {
        quorum: Some(2),
        deadline_ms: Some(2000),
        on_missing: OnMissing::Drop,
    };
    let opts = Options { rounds, policy, ..Default::default() };

    // Sequential reference.
    let mut seq = FaultPool::new(
        SeqPool::new(p.pp_clients("topk", K_MULT, cfg, &x0)?),
        plan.clone(),
    );
    let t_seq = run_fednl_pp_pool(
        &mut seq,
        &opts,
        tau,
        cfg.seed,
        x0.clone(),
        "faultsmoke/seq",
    );

    // Multi-threaded simulator.
    let mut thr = FaultPool::new(
        ThreadedPool::new(
            p.pp_clients("topk", K_MULT, cfg, &x0)?,
            cfg.threads,
        ),
        plan.clone(),
    );
    let t_thr = run_fednl_pp_pool(
        &mut thr,
        &opts,
        tau,
        cfg.seed,
        x0.clone(),
        "faultsmoke/threaded",
    );

    // Real TCP loopback.
    let bound = Bound::bind("127.0.0.1:0")?;
    let addr = bound.local_addr()?.to_string();
    let handles = spawn_shard_clients(&p, "topk", &addr, true, cfg)?;
    let mut tcp = FaultPool::new(bound.accept(p.n_clients)?, plan);
    let t_tcp = run_fednl_pp_pool(
        &mut tcp,
        &opts,
        tau,
        cfg.seed,
        x0,
        "faultsmoke/remote",
    );
    tcp.into_inner().shutdown();
    for h in handles {
        let _ = h.join();
    }

    // The lossy-round determinism invariant: same plan → bit-identical
    // trajectories (and identical committed/missing accounting) on all
    // three transports. FedNL-PP traces always report logical byte
    // counters, so those must agree too.
    for (t, name) in [(&t_thr, "threaded"), (&t_tcp, "remote")] {
        anyhow::ensure!(
            t.records.len() == t_seq.records.len(),
            "faultsmoke: {name} ran {} rounds vs seq {}",
            t.records.len(),
            t_seq.records.len()
        );
        for (a, b) in t_seq.records.iter().zip(&t.records) {
            anyhow::ensure!(
                a.grad_norm.to_bits() == b.grad_norm.to_bits()
                    && a.committed == b.committed
                    && a.missing == b.missing
                    && a.bytes_up == b.bytes_up,
                "faultsmoke: {name} diverged from seq at round {}: \
                 grad {:.17e} vs {:.17e}, committed {}/{} vs {}/{}",
                a.round,
                a.grad_norm,
                b.grad_norm,
                a.committed,
                a.committed + a.missing,
                b.committed,
                b.committed + b.missing
            );
        }
    }
    // Faults actually engaged (the kill window makes losses all but
    // certain with τ=4 of 5), recovery happened after the rejoin, and
    // training still converged.
    let lost: u32 = t_seq.records.iter().map(|r| r.missing).sum();
    anyhow::ensure!(lost > 0, "faultsmoke: no fault ever engaged");
    anyhow::ensure!(
        t_seq.records.iter().filter(|r| r.round >= 18).all(|r| r.missing == 0),
        "faultsmoke: losses after the rejoin round"
    );
    let first = t_seq.records.first().map(|r| r.grad_norm).unwrap_or(0.0);
    let last = t_seq.last_grad_norm();
    anyhow::ensure!(
        last.is_finite() && last < first * 1e-2,
        "faultsmoke: no convergence under faults ({first:.3e} → {last:.3e})"
    );

    // Artifact: the per-round fault accounting of the (identical)
    // trajectories, plus the plan/policy that produced them.
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"plan\": \"{plan_spec}\",\n"));
    json.push_str(
        "  \"policy\": {\"quorum\": 2, \"deadline_ms\": 2000, \"on_missing\": \"drop\"},\n",
    );
    json.push_str(&format!(
        "  \"n_clients\": {}, \"tau\": {tau}, \"rounds\": {rounds},\n",
        p.n_clients
    ));
    json.push_str(
        "  \"pools\": [\"seq\", \"threaded\", \"remote\"], \"bit_identical\": true,\n",
    );
    json.push_str("  \"trace\": [\n");
    for (i, r) in t_seq.records.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"round\": {}, \"grad_norm\": {:e}, \"committed\": {}, \"missing\": {}}}{}\n",
            r.round,
            r.grad_norm,
            r.committed,
            r.missing,
            if i + 1 < t_seq.records.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let json_path = format!("{}/faultsmoke_trace.json", cfg.out_dir);
    std::fs::write(&json_path, &json)?;

    let mut out = format!(
        "## Fault smoke — FedNL-PP quorum rounds under `{plan_spec}` \
         (n={}, τ={tau}, quorum=2, r={rounds})\n\n",
        p.n_clients
    );
    let mut table = Table::new(&[
        "Transport",
        "||∇f||_final",
        "Rounds",
        "Lost contributions",
        "Bit-identical to seq",
    ]);
    for (t, name) in
        [(&t_seq, "seq"), (&t_thr, "threaded"), (&t_tcp, "remote")]
    {
        table.row(&[
            name.to_string(),
            sci(t.last_grad_norm()),
            format!("{}", t.records.len()),
            format!("{}", t.records.iter().map(|r| r.missing).sum::<u32>()),
            "yes".to_string(),
        ]);
    }
    out.push_str(&table.to_markdown());
    out.push_str(&format!("\nPer-round trace written to {json_path}\n"));
    Ok(out)
}

/// CI corruption smoke: the Byzantine robustness subsystem end to
/// end. Two `scale:100` attackers (clients 0 and 3 of 6) corrupt
/// every round from round 2 on, via `corrupt@` events in the same
/// [`FaultPlan`] on every leg:
///
/// 1. **Undefended**: the corrupted FedNL run on SeqPool and
///    ThreadedPool — bit-identical to each other (deterministic
///    injection is a pure function of (plan, round)), and visibly
///    *not* converging: the aggregated gradient is dominated by the
///    ×100 payloads, so the reported ‖∇f‖ stays large.
/// 2. **Defended** (`--defense median`): the same plan and problem on
///    SeqPool, ThreadedPool, an in-process `S=3` [`ShardedPool`]
///    (shards forward per-client atoms under a defense), a TCP
///    [`RemotePool`] and — on unix — an `EventPool` master. All
///    trajectories must be bit-identical, converge ≥ 100× below the
///    round-0 gradient norm, and flag m−1 contributions per round
///    (the median's trace accounting).
///
/// Writes both trajectories to `corruptsmoke_trace.json` (CI
/// artifact).
pub fn corrupt_smoke(cfg: &HarnessCfg) -> Result<String> {
    use crate::coordinator::CorruptMode;
    use crate::robust::Defense;

    cfg.ensure_out_dir()?;
    let spec = ProblemSpec {
        name: "corruptsmoke",
        d: 13,
        n_i_full: 40,
        n_clients_full: 6,
        lam: 1e-3,
    };
    let mut p = prepare_problem(&spec, cfg)?;
    p.n_clients = 6;
    p.n_i = 40;
    let d = p.d();
    let x0 = vec![0.0; d];
    let rounds = 30u64;
    let mut plan = FaultPlan::none();
    for r in 2..rounds {
        plan = plan
            .with_corrupt(r, 0, CorruptMode::Scale(100.0))
            .with_corrupt(r, 3, CorruptMode::Scale(100.0));
    }
    let plan_spec = plan.to_spec();
    let opts_und =
        Options { rounds, warm_start: true, ..Default::default() };
    let opts_def =
        Options { defense: Some(Defense::Median), ..opts_und.clone() };

    // --- undefended legs --------------------------------------------
    let mut und_seq = FaultPool::new(
        SeqPool::new(p.clients("topk", K_MULT, cfg)?),
        plan.clone(),
    );
    let t_und = run_fednl_pool(
        &mut und_seq,
        &opts_und,
        x0.clone(),
        "corruptsmoke/undef/seq",
    );
    let mut und_thr = FaultPool::new(
        ThreadedPool::new(p.clients("topk", K_MULT, cfg)?, cfg.threads),
        plan.clone(),
    );
    let t_und_thr = run_fednl_pool(
        &mut und_thr,
        &opts_und,
        x0.clone(),
        "corruptsmoke/undef/threaded",
    );

    // --- defended legs ----------------------------------------------
    let mut def_seq = FaultPool::new(
        SeqPool::new(p.clients("topk", K_MULT, cfg)?),
        plan.clone(),
    );
    let t_def = run_fednl_pool(
        &mut def_seq,
        &opts_def,
        x0.clone(),
        "corruptsmoke/median/seq",
    );
    let mut def_thr = FaultPool::new(
        ThreadedPool::new(p.clients("topk", K_MULT, cfg)?, cfg.threads),
        plan.clone(),
    );
    let t_def_thr = run_fednl_pool(
        &mut def_thr,
        &opts_def,
        x0.clone(),
        "corruptsmoke/median/threaded",
    );
    let mut def_shard = FaultPool::new(
        ShardedPool::new_threaded(
            p.clients("topk", K_MULT, cfg)?,
            3,
            cfg.threads,
        ),
        plan.clone(),
    );
    let t_def_shard = run_fednl_pool(
        &mut def_shard,
        &opts_def,
        x0.clone(),
        "corruptsmoke/median/sharded",
    );
    let bound = Bound::bind("127.0.0.1:0")?;
    let addr = bound.local_addr()?.to_string();
    let handles = spawn_shard_clients(&p, "topk", &addr, false, cfg)?;
    let mut tcp = FaultPool::new(bound.accept(p.n_clients)?, plan.clone());
    let t_def_tcp = run_fednl_pool(
        &mut tcp,
        &opts_def,
        x0.clone(),
        "corruptsmoke/median/remote",
    );
    tcp.into_inner().shutdown();
    for h in handles {
        let _ = h.join();
    }
    #[cfg(unix)]
    let t_def_ev = {
        let bound = Bound::bind("127.0.0.1:0")?;
        let addr = bound.local_addr()?.to_string();
        let handles = spawn_shard_clients(&p, "topk", &addr, false, cfg)?;
        let mut ev = FaultPool::new(
            crate::net::EventPool::accept(bound, p.n_clients)?,
            plan.clone(),
        );
        let t = run_fednl_pool(
            &mut ev,
            &opts_def,
            x0.clone(),
            "corruptsmoke/median/event",
        );
        ev.into_inner().shutdown();
        for h in handles {
            let _ = h.join();
        }
        Some(t)
    };
    #[cfg(not(unix))]
    let t_def_ev: Option<Trace> = None;

    // Bit-identity under the same corrupt-bearing plan — the attack
    // mutation and the defense fold are both pure functions of
    // (plan, round, committed set), so the transport cannot move a
    // bit. (Byte columns are excluded: TCP pools meter transport
    // bytes, in-process pools report logical counters.)
    let identical = |a: &Trace, b: &Trace, name: &str| -> Result<()> {
        anyhow::ensure!(
            a.records.len() == b.records.len(),
            "corruptsmoke: {name} ran {} rounds vs {} on the reference",
            b.records.len(),
            a.records.len()
        );
        for (x, y) in a.records.iter().zip(&b.records) {
            anyhow::ensure!(
                x.grad_norm.to_bits() == y.grad_norm.to_bits()
                    && x.committed == y.committed
                    && x.missing == y.missing
                    && x.flagged == y.flagged,
                "corruptsmoke: {name} diverged at round {}: \
                 grad {:.17e} vs {:.17e}, flagged {} vs {}",
                x.round,
                x.grad_norm,
                y.grad_norm,
                x.flagged,
                y.flagged
            );
        }
        Ok(())
    };
    identical(&t_und, &t_und_thr, "undefended/threaded")?;
    identical(&t_def, &t_def_thr, "median/threaded")?;
    identical(&t_def, &t_def_shard, "median/sharded")?;
    identical(&t_def, &t_def_tcp, "median/remote")?;
    if let Some(t) = &t_def_ev {
        identical(&t_def, t, "median/event")?;
    }

    // Flagged accounting: the undefended run never flags; the median
    // passes one order statistic through, flagging m−1 = 5 per round.
    anyhow::ensure!(
        t_und.records.iter().all(|r| r.flagged == 0),
        "corruptsmoke: undefended run flagged contributions"
    );
    anyhow::ensure!(
        t_def.records.iter().all(|r| r.committed == 6
            && r.missing == 0
            && r.flagged == 5),
        "corruptsmoke: defended flagged/committed accounting off"
    );

    // The headline A/B: the undefended run visibly degrades (the ×100
    // attackers dominate the mean — negated comparisons so a NaN/inf
    // blow-up also counts as degraded), the defended run converges.
    let und_first = t_und.records.first().map(|r| r.grad_norm).unwrap_or(0.0);
    let und_last = t_und.last_grad_norm();
    anyhow::ensure!(
        !(und_last < und_first * 1e-1),
        "corruptsmoke: undefended run converged anyway \
         ({und_first:.3e} → {und_last:.3e}); attack ineffective"
    );
    let def_first = t_def.records.first().map(|r| r.grad_norm).unwrap_or(0.0);
    let def_last = t_def.last_grad_norm();
    anyhow::ensure!(
        def_last.is_finite() && def_last < def_first * 1e-2,
        "corruptsmoke: defended run did not converge \
         ({def_first:.3e} → {def_last:.3e})"
    );
    anyhow::ensure!(
        !(und_last < def_last * 1e3),
        "corruptsmoke: defense gap below 1000× \
         ({und_last:.3e} vs {def_last:.3e})"
    );

    // Artifact: both trajectories round by round, plus the plan.
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"plan\": \"{plan_spec}\",\n"));
    json.push_str("  \"defense\": \"median\",\n");
    json.push_str(&format!(
        "  \"n_clients\": {}, \"rounds\": {rounds}, \
         \"attackers\": [0, 3],\n",
        p.n_clients
    ));
    json.push_str(&format!(
        "  \"pools\": {{\"undefended\": [\"seq\", \"threaded\"], \
         \"defended\": [\"seq\", \"threaded\", \"sharded\", \
         \"remote\"{}]}},\n",
        if t_def_ev.is_some() { ", \"event\"" } else { "" }
    ));
    json.push_str("  \"bit_identical\": true,\n");
    json.push_str("  \"trace\": [\n");
    for (i, (u, v)) in
        t_und.records.iter().zip(&t_def.records).enumerate()
    {
        json.push_str(&format!(
            "    {{\"round\": {}, \"undefended\": {:e}, \
             \"defended\": {:e}, \"flagged\": {}}}{}\n",
            u.round,
            u.grad_norm,
            v.grad_norm,
            v.flagged,
            if i + 1 < t_und.records.len().min(t_def.records.len()) {
                ","
            } else {
                ""
            }
        ));
    }
    json.push_str("  ]\n}\n");
    let json_path = format!("{}/corruptsmoke_trace.json", cfg.out_dir);
    std::fs::write(&json_path, &json)?;

    let mut out = format!(
        "## Corruption smoke — 2 `scale:100` attackers of n={}, \
         undefended vs `--defense median` (r={rounds})\n\n",
        p.n_clients
    );
    let mut table = Table::new(&[
        "Leg",
        "||∇f||_first",
        "||∇f||_final",
        "Flagged/round",
        "Bit-identical legs",
    ]);
    table.row(&[
        "undefended".to_string(),
        sci(und_first),
        sci(und_last),
        "0".to_string(),
        "seq, threaded".to_string(),
    ]);
    table.row(&[
        "median".to_string(),
        sci(def_first),
        sci(def_last),
        "5".to_string(),
        format!(
            "seq, threaded, sharded, remote{}",
            if t_def_ev.is_some() { ", event" } else { "" }
        ),
    ]);
    out.push_str(&table.to_markdown());
    out.push_str(&format!("\nPer-round trace written to {json_path}\n"));
    Ok(out)
}

// ---------------------------------------------------------------------
// CI crash smoke: SIGKILL the real TCP master mid-run, relaunch it
// with --restore, and require the healed trajectory bitwise-equal to
// an uninterrupted reference.
// ---------------------------------------------------------------------

/// Master crash-recovery drill over real TCP. One master process
/// (`fednl master --checkpoint-dir --checkpoint-every 1`) serves six
/// warm in-process failover clients (`--fallback` pointing back at the
/// master's own address). A supervisor thread watches the snapshot
/// directory and SIGKILLs the master once a snapshot covering round 8
/// is durable; the clients rotate through their fallback list while a
/// second master relaunches on the same address with `--restore`. The
/// healed run's full CSV trace (restored records below the watermark,
/// live rounds above) must be bit-identical to an uninterrupted
/// in-process reference under the *same* fault plan — two `scale:100`
/// Byzantine attackers folded out by `--defense median`, plus
/// `delaydist@` lognormal straggler draws, all composing through the
/// restore. (The CSV comparison is exact because `{:e}` is Rust's
/// shortest round-trip float form.)
///
/// Writes `crashsmoke_trace.json` (CI artifact).
pub fn crash_smoke(cfg: &HarnessCfg) -> Result<String> {
    use crate::algorithms::ClientState;
    use crate::coordinator::CorruptMode;
    use crate::net::client::ClientMode;
    use crate::net::{run_client_with, ClientOpts};
    use crate::oracle::LogisticOracle;
    use crate::robust::Defense;
    use anyhow::Context;
    use std::process::{Command, Stdio};

    cfg.ensure_out_dir()?;
    let spec = ProblemSpec {
        name: "crashsmoke",
        d: 13,
        n_i_full: 40,
        n_clients_full: 6,
        lam: 1e-3,
    };
    let mut p = prepare_problem(&spec, cfg)?;
    p.n_clients = 6;
    p.n_i = 40;
    let d = p.d();
    let x0 = vec![0.0; d];
    let rounds = 24u64;
    // The faults that must compose through the restore: two scale:100
    // attackers under the median defense (so the snapshot's defense
    // accounting is load-bearing), and lognormal straggler draws
    // (median ≈ e^3.9 ≈ 50 ms a reply) that both pace the run enough
    // for the supervisor to land its SIGKILL mid-flight and prove the
    // per-(round, client) draws replay identically on the healed leg.
    let mut plan = FaultPlan::none().with_delay_dist(0, rounds, 3.9, 0.3);
    for r in 2..rounds {
        plan = plan
            .with_corrupt(r, 0, CorruptMode::Scale(100.0))
            .with_corrupt(r, 3, CorruptMode::Scale(100.0));
    }
    let plan_spec = plan.to_spec();
    let opts = Options {
        rounds,
        defense: Some(Defense::Median),
        ..Default::default()
    };

    // --- uninterrupted in-process reference --------------------------
    let mut reference = FaultPool::new(
        SeqPool::new(p.clients("topk", K_MULT, cfg)?),
        plan.clone(),
    );
    let t_ref =
        run_fednl_pool(&mut reference, &opts, x0, "crashsmoke/reference");

    // --- TCP leg: master subprocess + warm failover clients ----------
    let ck_dir = format!("{}/crashsmoke_ck", cfg.out_dir);
    let _ = std::fs::remove_dir_all(&ck_dir);
    let healed_csv = format!("{}/crashsmoke_healed.csv", cfg.out_dir);
    let _ = std::fs::remove_file(&healed_csv);
    // Pick a free loopback port, then hand the *address* to the master
    // process: the clients hold it in their --fallback rotation, so
    // the relaunched master must come back on the very same one.
    let addr = {
        let probe = Bound::bind("127.0.0.1:0")?;
        probe.local_addr()?.to_string()
    };
    let exe = std::env::current_exe().context("locating fednl binary")?;
    let master_args = |extra: &[&str]| -> Vec<String> {
        let mut v = vec![
            "master".to_string(),
            "--listen".to_string(),
            addr.clone(),
            "--clients".to_string(),
            p.n_clients.to_string(),
            "--algo".to_string(),
            "fednl".to_string(),
            "--rounds".to_string(),
            rounds.to_string(),
            "--fault-plan".to_string(),
            plan_spec.clone(),
            "--defense".to_string(),
            "median".to_string(),
            "--checkpoint-dir".to_string(),
            ck_dir.clone(),
            "--checkpoint-every".to_string(),
            "1".to_string(),
        ];
        v.extend(extra.iter().map(|s| s.to_string()));
        v
    };
    let mut master = Command::new(&exe)
        .args(master_args(&[]))
        .stdout(Stdio::null())
        .spawn()
        .context("spawning crashsmoke master")?;

    let lam = p.spec.lam;
    let mut handles = Vec::new();
    for shard in cfg.split.shards(&p.dataset, p.n_clients, p.n_i, cfg.seed)? {
        let addr = addr.clone();
        let comp = crate::compressors::by_name(
            "topk",
            d,
            K_MULT,
            cfg.seed + shard.client_id as u64,
        )?;
        handles.push(std::thread::spawn(move || {
            let id = shard.client_id;
            let oracle = Box::new(LogisticOracle::new(shard, lam));
            let mode =
                ClientMode::FedNL(ClientState::new(id, oracle, comp, None));
            let opts = ClientOpts {
                fallback: vec![addr.clone()],
                ..Default::default()
            };
            run_client_with(&addr, id, mode, opts)
        }));
    }

    // Supervisor: wait until a snapshot covering round `kill_after` is
    // durable, then SIGKILL the master — a real process death at an
    // unscripted instant (possibly mid-write; the corrupt-tail
    // fallback in `load_latest` absorbs that).
    let kill_after = 8u64;
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_secs(120);
    let killed_at = loop {
        anyhow::ensure!(
            std::time::Instant::now() < deadline,
            "crashsmoke: no snapshot covering round {kill_after} in 120 s"
        );
        if let Some(status) = master.try_wait()? {
            anyhow::bail!(
                "crashsmoke: master exited ({status}) before the SIGKILL"
            );
        }
        let newest = std::fs::read_dir(&ck_dir)
            .ok()
            .into_iter()
            .flatten()
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                e.file_name()
                    .to_str()?
                    .strip_prefix("ck-")?
                    .strip_suffix(".fnck")?
                    .parse::<u64>()
                    .ok()
            })
            .max();
        match newest {
            Some(r) if r >= kill_after => break r,
            _ => std::thread::sleep(std::time::Duration::from_millis(5)),
        }
    };
    master.kill().context("SIGKILL crashsmoke master")?;
    let _ = master.wait();
    anyhow::ensure!(
        killed_at < rounds,
        "crashsmoke: master already finished (snapshot {killed_at}); \
         nothing was interrupted"
    );

    // Relaunch on the same address with --restore; the healed master
    // writes the full trajectory CSV.
    let status = Command::new(&exe)
        .args(master_args(&["--restore", &ck_dir, "--trace", &healed_csv]))
        .stdout(Stdio::null())
        .status()
        .context("relaunching crashsmoke master --restore")?;
    anyhow::ensure!(
        status.success(),
        "crashsmoke: restored master failed ({status})"
    );
    for h in handles {
        let _ = h.join();
    }

    // Parse the healed CSV back (bit-exact by the {:e} round-trip) and
    // require bitwise equality with the reference. Byte and elapsed
    // columns are excluded as everywhere else: TCP pools meter
    // transport bytes, in-process pools logical counters.
    let csv = std::fs::read_to_string(&healed_csv)
        .with_context(|| format!("reading {healed_csv}"))?;
    let mut healed: Vec<(u64, f64, usize, usize, usize)> = Vec::new();
    for line in csv.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        anyhow::ensure!(
            f.len() == 9,
            "crashsmoke: malformed CSV row '{line}'"
        );
        healed.push((
            f[0].parse()?,
            f[1].parse()?,
            f[6].parse()?,
            f[7].parse()?,
            f[8].parse()?,
        ));
    }
    anyhow::ensure!(
        healed.len() == t_ref.records.len(),
        "crashsmoke: healed run has {} rounds, reference {}",
        healed.len(),
        t_ref.records.len()
    );
    for (h, r) in healed.iter().zip(&t_ref.records) {
        anyhow::ensure!(
            h.0 == r.round
                && h.1.to_bits() == r.grad_norm.to_bits()
                && h.2 == r.committed
                && h.3 == r.missing
                && h.4 == r.flagged,
            "crashsmoke: healed trajectory diverged at round {}: \
             grad {:.17e} vs {:.17e}, committed {} vs {}",
            r.round,
            h.1,
            r.grad_norm,
            h.2,
            r.committed
        );
    }

    // Artifact: the healed-vs-reference trajectory plus kill metadata.
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"plan\": \"{plan_spec}\",\n"));
    json.push_str(&format!(
        "  \"n_clients\": {}, \"rounds\": {rounds}, \
         \"kill_after_snapshot\": {kill_after}, \
         \"killed_at_snapshot\": {killed_at},\n",
        p.n_clients
    ));
    json.push_str("  \"defense\": \"median\", \"bit_identical\": true,\n");
    json.push_str("  \"trace\": [\n");
    for (i, (h, r)) in healed.iter().zip(&t_ref.records).enumerate() {
        json.push_str(&format!(
            "    {{\"round\": {}, \"healed\": {:e}, \"reference\": {:e}, \
             \"committed\": {}, \"flagged\": {}}}{}\n",
            h.0,
            h.1,
            r.grad_norm,
            h.2,
            h.4,
            if i + 1 < healed.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let json_path = format!("{}/crashsmoke_trace.json", cfg.out_dir);
    std::fs::write(&json_path, &json)?;

    let mut out = format!(
        "## Crash smoke — TCP master SIGKILLed after snapshot \
         {killed_at} of r={rounds}, relaunched with `--restore` \
         (median defense + lognormal stragglers composing through \
         the restore)\n\n"
    );
    let mut table =
        Table::new(&["Leg", "Rounds", "||∇f||_final", "Bit-identical"]);
    table.row(&[
        "reference (seq, uninterrupted)".to_string(),
        t_ref.records.len().to_string(),
        sci(t_ref.last_grad_norm()),
        "—".to_string(),
    ]);
    table.row(&[
        format!("healed (tcp, SIGKILL@ck-{killed_at}, --restore)"),
        healed.len().to_string(),
        sci(healed.last().map(|h| h.1).unwrap_or(f64::NAN)),
        "yes".to_string(),
    ]);
    out.push_str(&table.to_markdown());
    out.push_str(&format!("\nPer-round trace written to {json_path}\n"));
    Ok(out)
}

/// CI shard smoke: the sharded aggregation tier end to end — an
/// unsharded sequential reference, an in-process `S=3` [`ShardedPool`]
/// and a real `S=2` TCP **relay tier** over loopback (2 relay
/// processes-as-threads + 6 clients), all running FedNL under the same
/// [`FaultPlan`] and quorum policy. Asserts the tier's headline
/// invariant — **bit-identical trajectories for every S and
/// transport** — then writes per-shard wait/aggregate stats and the
/// per-round trace to `shardsmoke_trace.json` (CI artifact).
pub fn shard_smoke(cfg: &HarnessCfg) -> Result<String> {
    cfg.ensure_out_dir()?;
    let spec = ProblemSpec {
        name: "shardsmoke",
        d: 13,
        n_i_full: 40,
        n_clients_full: 6,
        lam: 1e-3,
    };
    let mut p = prepare_problem(&spec, cfg)?;
    p.n_clients = 6;
    p.n_i = 40;
    let d = p.d();
    let x0 = vec![0.0; d];
    let rounds = 20u64;
    let plan_spec = "kill@2:1-8,drop@5:4";
    let plan = FaultPlan::parse(plan_spec)?;
    let policy = RoundPolicy {
        quorum: Some(3),
        deadline_ms: Some(2000),
        on_missing: OnMissing::Drop,
    };
    let opts =
        Options { rounds, track_loss: true, policy, ..Default::default() };

    // Unsharded sequential reference.
    let mut seq = FaultPool::new(
        SeqPool::new(p.clients("topk", K_MULT, cfg)?),
        plan.clone(),
    );
    let t_seq =
        run_fednl_pool(&mut seq, &opts, x0.clone(), "shardsmoke/seq");

    // In-process sharded tier, S = 3.
    let mut sh3 = FaultPool::new(
        ShardedPool::new_seq(p.clients("topk", K_MULT, cfg)?, 3),
        plan.clone(),
    );
    let t_sh3 =
        run_fednl_pool(&mut sh3, &opts, x0.clone(), "shardsmoke/S3");
    let shard_stats: Vec<_> =
        sh3.inner_mut().shard_stats().to_vec();

    // Real TCP relay tier, S = 2: master ← 2 relays ← 6 clients, all
    // over loopback in one process.
    let ranges = shard::partition(p.n_clients, 2);
    let master_bound = Bound::bind("127.0.0.1:0")?;
    let master_addr = master_bound.local_addr()?.to_string();
    let mut relay_handles = Vec::new();
    let mut client_handles = Vec::new();
    let all_shards = p.dataset.split(p.n_clients, p.n_i)?;
    let mut shards_by_id: Vec<Option<crate::data::ClientShard>> =
        all_shards.into_iter().map(Some).collect();
    for (s, &(lo, hi)) in ranges.iter().enumerate() {
        let relay_bound = Bound::bind("127.0.0.1:0")?;
        let relay_addr = relay_bound.local_addr()?.to_string();
        let rcfg = RelayCfg {
            shard_id: s as u32,
            base: lo,
            count: (hi - lo) as usize,
            listen: String::new(), // pre-bound below
            connect: master_addr.clone(),
            ..Default::default()
        };
        relay_handles.push(std::thread::spawn(move || {
            run_relay_on(relay_bound, &rcfg)
        }));
        for ci in lo..hi {
            let shard = shards_by_id[ci as usize].take().unwrap();
            let addr = relay_addr.clone();
            let comp = crate::compressors::by_name(
                "topk",
                d,
                K_MULT,
                cfg.seed + ci as u64,
            )?;
            client_handles.push(std::thread::spawn(move || {
                use crate::algorithms::ClientState;
                use crate::net::client::ClientMode;
                use crate::oracle::LogisticOracle;
                let id = shard.client_id;
                let oracle =
                    Box::new(LogisticOracle::new(shard, spec.lam));
                run_client(
                    &addr,
                    id,
                    ClientMode::FedNL(ClientState::new(
                        id, oracle, comp, None,
                    )),
                )
            }));
        }
    }
    let mut relay_pool =
        FaultPool::new(RelayPool::accept(master_bound, 2)?, plan);
    let t_relay = run_fednl_pool(
        &mut relay_pool,
        &opts,
        x0,
        "shardsmoke/relay-S2",
    );
    relay_pool.into_inner().shutdown();
    for h in relay_handles {
        let _ = h.join();
    }
    for h in client_handles {
        let _ = h.join();
    }

    // The headline invariant: same plan, same policy → bit-identical
    // trajectories for S=1 / S=3 in-process / S=2 over TCP relays.
    // (Byte columns are not compared across topologies: since the
    // reproducible-summation layer the shard tiers pre-reduce and
    // forward compact SHARD_SUM frames, so their upward payload
    // *deliberately* differs from the flat pools' per-client atoms —
    // that payload cut is the point, tracked by BENCH_shard.json.)
    for (t, name) in [(&t_sh3, "sharded-S3"), (&t_relay, "relay-S2")] {
        anyhow::ensure!(
            t.records.len() == t_seq.records.len(),
            "shardsmoke: {name} ran {} rounds vs seq {}",
            t.records.len(),
            t_seq.records.len()
        );
        for (a, b) in t_seq.records.iter().zip(&t.records) {
            anyhow::ensure!(
                a.grad_norm.to_bits() == b.grad_norm.to_bits()
                    && a.loss.to_bits() == b.loss.to_bits()
                    && a.committed == b.committed
                    && a.missing == b.missing,
                "shardsmoke: {name} diverged from seq at round {}: \
                 grad {:.17e} vs {:.17e}, committed {}/{} vs {}/{}",
                a.round,
                a.grad_norm,
                b.grad_norm,
                a.committed,
                a.committed + a.missing,
                b.committed,
                b.committed + b.missing
            );
        }
    }
    let lost: u32 = t_seq.records.iter().map(|r| r.missing).sum();
    anyhow::ensure!(lost > 0, "shardsmoke: no fault ever engaged");
    let first = t_seq.records.first().map(|r| r.grad_norm).unwrap_or(0.0);
    let last = t_seq.last_grad_norm();
    anyhow::ensure!(
        last.is_finite() && last < first * 1e-2,
        "shardsmoke: no convergence under faults ({first:.3e} → {last:.3e})"
    );

    // Artifact: per-shard wait/aggregate split + the (identical)
    // per-round trace.
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"plan\": \"{plan_spec}\",\n"));
    json.push_str(
        "  \"policy\": {\"quorum\": 3, \"deadline_ms\": 2000, \
         \"on_missing\": \"drop\"},\n",
    );
    json.push_str(&format!(
        "  \"n_clients\": {}, \"rounds\": {rounds},\n",
        p.n_clients
    ));
    json.push_str(
        "  \"configs\": [\"seq\", \"sharded-S3\", \"relay-S2\"], \
         \"bit_identical\": true,\n",
    );
    json.push_str("  \"per_shard_S3\": [\n");
    for (i, st) in shard_stats.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"shard\": {}, \"clients\": {}, \"wait_s\": {:.6}, \
             \"aggregate_s\": {:.6}, \"msgs\": {}, \
             \"payload_bytes\": {}}}{}\n",
            st.shard,
            st.clients,
            st.wait_s,
            st.aggregate_s,
            st.msgs,
            st.payload_bytes,
            if i + 1 < shard_stats.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"trace\": [\n");
    for (i, r) in t_seq.records.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"round\": {}, \"grad_norm\": {:e}, \"committed\": {}, \
             \"missing\": {}}}{}\n",
            r.round,
            r.grad_norm,
            r.committed,
            r.missing,
            if i + 1 < t_seq.records.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let json_path = format!("{}/shardsmoke_trace.json", cfg.out_dir);
    std::fs::write(&json_path, &json)?;

    let mut out = format!(
        "## Shard smoke — FedNL through the sharded aggregation tier \
         under `{plan_spec}` (n={}, quorum=3, r={rounds})\n\n",
        p.n_clients
    );
    let mut table = Table::new(&[
        "Topology",
        "||∇f||_final",
        "Rounds",
        "Lost contributions",
        "Bit-identical to seq",
    ]);
    for (t, name) in [
        (&t_seq, "seq (S=1)"),
        (&t_sh3, "sharded in-process (S=3)"),
        (&t_relay, "TCP relay tier (S=2)"),
    ] {
        table.row(&[
            name.to_string(),
            sci(t.last_grad_norm()),
            format!("{}", t.records.len()),
            format!("{}", t.records.iter().map(|r| r.missing).sum::<u32>()),
            "yes".to_string(),
        ]);
    }
    out.push_str(&table.to_markdown());
    out.push_str(&format!("\nPer-shard stats written to {json_path}\n"));
    Ok(out)
}

/// CI mux smoke: the readiness-based transport end to end. Two legs:
///
/// 1. **Bit-identity** (n = 6): FedNL under a [`FaultPlan`] + quorum
///    policy on a sequential reference, on an `EventPool` master
///    serving two `--mux` groups (3 clients each, one socket per
///    group), and on an `EventPool` master serving six plain blocking
///    clients. All three trajectories must be bit-identical — the
///    transport changes *when* replies arrive, never *what* is
///    computed.
/// 2. **Scale** (CI: 3k, `--full`: 100k multiplexed clients): one
///    master, a handful of group sockets, two real FedNL rounds.
///    Asserts full registration, full commitment, and idle
///    server-side bookkeeping ≤ 4 KiB per client
///    (`EventPool::idle_bytes_per_client`).
///
/// Writes the per-round trace and the scale stats to
/// `muxsmoke_trace.json` (CI artifact).
#[cfg(not(unix))]
pub fn mux_smoke(_cfg: &HarnessCfg) -> Result<String> {
    anyhow::bail!("muxsmoke requires a unix host (epoll/poll)")
}

/// See the unix docs above.
#[cfg(unix)]
pub fn mux_smoke(cfg: &HarnessCfg) -> Result<String> {
    use crate::algorithms::ClientState;
    use crate::data::{
        generate_synthetic, parse_libsvm_bytes, write_libsvm, Dataset,
        SynthSpec,
    };
    use crate::net::{run_mux_clients, EventPool, MuxReport};
    use crate::oracle::LogisticOracle;

    cfg.ensure_out_dir()?;

    // --- leg 1: bit-identity under faults --------------------------
    let spec = ProblemSpec {
        name: "muxsmoke",
        d: 13,
        n_i_full: 40,
        n_clients_full: 6,
        lam: 1e-3,
    };
    let mut p = prepare_problem(&spec, cfg)?;
    p.n_clients = 6;
    p.n_i = 40;
    let d = p.d();
    let x0 = vec![0.0; d];
    let rounds = 20u64;
    let plan_spec = "kill@2:1-8,drop@5:4";
    let plan = FaultPlan::parse(plan_spec)?;
    let policy = RoundPolicy {
        quorum: Some(3),
        deadline_ms: Some(2000),
        on_missing: OnMissing::Drop,
    };
    let opts =
        Options { rounds, track_loss: true, policy, ..Default::default() };

    // Sequential reference.
    let mut seq = FaultPool::new(
        SeqPool::new(p.clients("topk", K_MULT, cfg)?),
        plan.clone(),
    );
    let t_seq = run_fednl_pool(&mut seq, &opts, x0.clone(), "muxsmoke/seq");

    // EventPool master ← two mux groups of 3 (one socket each).
    let bound = Bound::bind("127.0.0.1:0")?;
    let addr = bound.local_addr()?.to_string();
    let mut all = p.clients("topk", K_MULT, cfg)?;
    let tail = all.split_off(3);
    let mut mux_handles = Vec::new();
    for (gid, mut group) in [(0u32, all), (1u32, tail)] {
        let addr = addr.clone();
        mux_handles.push(std::thread::spawn(move || {
            run_mux_clients(&mut group, gid, &addr)
        }));
    }
    let mut ev =
        FaultPool::new(EventPool::accept(bound, p.n_clients)?, plan.clone());
    let t_mux = run_fednl_pool(&mut ev, &opts, x0.clone(), "muxsmoke/mux");
    ev.into_inner().shutdown();
    for h in mux_handles {
        let _ = h.join();
    }

    // EventPool master ← six plain blocking clients (the unchanged
    // `fednl client` path over the readiness loop).
    let bound = Bound::bind("127.0.0.1:0")?;
    let addr = bound.local_addr()?.to_string();
    let plain_handles = spawn_shard_clients(&p, "topk", &addr, false, cfg)?;
    let mut evp =
        FaultPool::new(EventPool::accept(bound, p.n_clients)?, plan);
    let t_plain =
        run_fednl_pool(&mut evp, &opts, x0.clone(), "muxsmoke/plain");
    evp.into_inner().shutdown();
    for h in plain_handles {
        let _ = h.join();
    }

    // Same plan, same policy → bit-identical trajectories. (Byte
    // columns are not compared: mux groups pre-reduce into SHARD_SUM
    // frames, so the wire payload deliberately differs — that cut is
    // the point.)
    for (t, name) in [(&t_mux, "event+mux"), (&t_plain, "event+plain")] {
        anyhow::ensure!(
            t.records.len() == t_seq.records.len(),
            "muxsmoke: {name} ran {} rounds vs seq {}",
            t.records.len(),
            t_seq.records.len()
        );
        for (a, b) in t_seq.records.iter().zip(&t.records) {
            anyhow::ensure!(
                a.grad_norm.to_bits() == b.grad_norm.to_bits()
                    && a.loss.to_bits() == b.loss.to_bits()
                    && a.committed == b.committed
                    && a.missing == b.missing,
                "muxsmoke: {name} diverged from seq at round {}: \
                 grad {:.17e} vs {:.17e}, committed {}/{} vs {}/{}",
                a.round,
                a.grad_norm,
                b.grad_norm,
                a.committed,
                a.committed + a.missing,
                b.committed,
                b.committed + b.missing
            );
        }
    }
    let lost: u32 = t_seq.records.iter().map(|r| r.missing).sum();
    anyhow::ensure!(lost > 0, "muxsmoke: no fault ever engaged");
    let first = t_seq.records.first().map(|r| r.grad_norm).unwrap_or(0.0);
    let last = t_seq.last_grad_norm();
    anyhow::ensure!(
        last.is_finite() && last < first * 1e-2,
        "muxsmoke: no convergence under faults ({first:.3e} → {last:.3e})"
    );

    // --- leg 2: scale ----------------------------------------------
    // One master, `groups` sockets, `total` registered clients, two
    // real FedNL rounds on a tiny problem (d = 6, n_i = 2).
    let (total, groups) = match cfg.scale {
        Scale::Full => (100_000usize, 16usize),
        Scale::Ci => (3_000usize, 6usize),
    };
    let per_group = total / groups;
    let lam = 1e-3;
    let synth = generate_synthetic(&SynthSpec {
        d_raw: 5,
        n_samples: total * 2,
        density: 0.5,
        noise: 1.0,
        label_bias: 0.0,
        seed: cfg.seed,
    });
    let text = write_libsvm(&synth);
    let (samples, d_raw) = parse_libsvm_bytes(text.as_bytes())?;
    let mut ds = Dataset::from_libsvm(&samples, d_raw.max(5));
    ds.reshuffle(cfg.seed ^ 0xD5);
    let sd = ds.d;
    let mut shards = ds.split_even(total)?;
    let bound = Bound::bind("127.0.0.1:0")?;
    let addr = bound.local_addr()?.to_string();
    let mut scale_handles: Vec<
        std::thread::JoinHandle<Result<MuxReport>>,
    > = Vec::new();
    for g in 0..groups {
        let chunk: Vec<crate::data::ClientShard> =
            shards.drain(0..per_group).collect();
        let addr = addr.clone();
        let seed = cfg.seed;
        let gid = g as u32;
        scale_handles.push(std::thread::spawn(move || {
            let mut clients: Vec<ClientState> = chunk
                .into_iter()
                .map(|sh| -> Result<ClientState> {
                    let id = sh.client_id;
                    let comp = crate::compressors::by_name(
                        "topk",
                        sd,
                        K_MULT,
                        seed + id as u64,
                    )?;
                    Ok(ClientState::new(
                        id,
                        Box::new(LogisticOracle::new(sh, lam)),
                        comp,
                        None,
                    ))
                })
                .collect::<Result<_>>()?;
            run_mux_clients(&mut clients, gid, &addr)
        }));
    }
    let reg_sw = Stopwatch::start();
    let mut big = EventPool::accept(bound, total)?;
    let reg_secs = reg_sw.elapsed_secs();
    anyhow::ensure!(
        big.n_clients() == total && big.dead_clients().is_empty(),
        "muxsmoke: scale registration incomplete"
    );
    let scale_sw = Stopwatch::start();
    let scale_opts = Options { rounds: 2, ..Default::default() };
    let t_scale = run_fednl_pool(
        &mut big,
        &scale_opts,
        vec![0.0; sd],
        "muxsmoke/scale",
    );
    let scale_secs = scale_sw.elapsed_secs();
    let idle_bytes = big.idle_bytes_per_client();
    big.shutdown();
    for h in scale_handles {
        match h.join() {
            Ok(r) => drop(r?),
            Err(_) => anyhow::bail!("muxsmoke: scale group panicked"),
        }
    }
    anyhow::ensure!(
        t_scale.records.len() == 2
            && t_scale
                .records
                .iter()
                .all(|r| r.committed as usize == total && r.missing == 0),
        "muxsmoke: scale rounds incomplete"
    );
    anyhow::ensure!(
        t_scale.last_grad_norm().is_finite(),
        "muxsmoke: scale run diverged"
    );
    anyhow::ensure!(
        idle_bytes <= 4096.0,
        "muxsmoke: idle bookkeeping {idle_bytes:.1} B/client exceeds 4 KiB"
    );

    // Artifact.
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"plan\": \"{plan_spec}\",\n"));
    json.push_str(
        "  \"policy\": {\"quorum\": 3, \"deadline_ms\": 2000, \
         \"on_missing\": \"drop\"},\n",
    );
    json.push_str(&format!(
        "  \"n_clients\": {}, \"rounds\": {rounds},\n",
        p.n_clients
    ));
    json.push_str(
        "  \"configs\": [\"seq\", \"event+mux\", \"event+plain\"], \
         \"bit_identical\": true,\n",
    );
    json.push_str(&format!(
        "  \"scale\": {{\"clients\": {total}, \"groups\": {groups}, \
         \"register_s\": {reg_secs:.3}, \"rounds_s\": {scale_secs:.3}, \
         \"idle_bytes_per_client\": {idle_bytes:.1}}},\n"
    ));
    json.push_str("  \"trace\": [\n");
    for (i, r) in t_seq.records.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"round\": {}, \"grad_norm\": {:e}, \"committed\": {}, \
             \"missing\": {}}}{}\n",
            r.round,
            r.grad_norm,
            r.committed,
            r.missing,
            if i + 1 < t_seq.records.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let json_path = format!("{}/muxsmoke_trace.json", cfg.out_dir);
    std::fs::write(&json_path, &json)?;

    let mut out = format!(
        "## Mux smoke — FedNL through the readiness transport under \
         `{plan_spec}` (n={}, quorum=3, r={rounds})\n\n",
        p.n_clients
    );
    let mut table = Table::new(&[
        "Topology",
        "||∇f||_final",
        "Rounds",
        "Lost contributions",
        "Bit-identical to seq",
    ]);
    for (t, name) in [
        (&t_seq, "seq"),
        (&t_mux, "event master, 2 mux groups"),
        (&t_plain, "event master, 6 plain clients"),
    ] {
        table.row(&[
            name.to_string(),
            sci(t.last_grad_norm()),
            format!("{}", t.records.len()),
            format!("{}", t.records.iter().map(|r| r.missing).sum::<u32>()),
            "yes".to_string(),
        ]);
    }
    out.push_str(&table.to_markdown());
    out.push_str(&format!(
        "\nScale: {total} multiplexed clients over {groups} sockets — \
         registered in {reg_secs:.2}s, 2 rounds in {scale_secs:.2}s, \
         idle bookkeeping {idle_bytes:.1} B/client \
         (details in {json_path})\n"
    ));
    Ok(out)
}

// ---------------------------------------------------------------------
// CI failover smoke: relay trees + scripted failover.
// ---------------------------------------------------------------------

/// One depth-3 relay-tree run for [`fail_smoke`]: master ← parent
/// relay P (`--parent 2`) ← child relays A, B ← clients 0..3, plus a
/// depth-2 arm master ← leaf relay C ← clients 3..6. Every client
/// carries `--fallback master`, so when the [`FaultPlan`]'s
/// `killrelay@R:0` severs P — and, by upward-EOF propagation, A and B
/// — the orphans re-register at the master and are adopted at the
/// next `prepare_round`. `leaf_event` switches the *leaf* relays'
/// downward faces to the readiness transport (`--event`); the inner
/// node P always runs blocking (`--parent` and `--event` are
/// exclusive).
fn run_failover_tree(
    p: &Problem,
    lam: f64,
    cfg: &HarnessCfg,
    plan: &FaultPlan,
    opts: &Options,
    leaf_event: bool,
    label: &str,
) -> Result<Trace> {
    use crate::net::{run_client_with, ClientOpts};

    let d = p.d();
    let master_bound = Bound::bind("127.0.0.1:0")?;
    let master_addr = master_bound.local_addr()?.to_string();
    let mut relay_handles = Vec::new();
    let mut client_handles = Vec::new();
    let all_shards = p.dataset.split(p.n_clients, p.n_i)?;
    let mut shards_by_id: Vec<Option<crate::data::ClientShard>> =
        all_shards.into_iter().map(Some).collect();

    // Inner node P: master-visible shard 0 over clients [0, 3); its
    // downward face is a RelayPool serving the two child relays.
    let p_bound = Bound::bind("127.0.0.1:0")?;
    let p_addr = p_bound.local_addr()?.to_string();
    let pcfg = RelayCfg {
        shard_id: 0,
        base: 0,
        count: 3,
        listen: String::new(), // pre-bound below
        connect: master_addr.clone(),
        children: Some(2),
        ..Default::default()
    };
    relay_handles
        .push(std::thread::spawn(move || run_relay_on(p_bound, &pcfg)));

    // Leaves: A = [0, 2) and B = [2, 3) under P, C = [3, 6) directly
    // under the master. (lo, hi, leaf address) per leaf.
    let mut leaves: Vec<(u32, u32, String)> = Vec::new();
    for (s, &(lo, hi)) in shard::partition(3, 2).iter().enumerate() {
        let leaf_bound = Bound::bind("127.0.0.1:0")?;
        let leaf_addr = leaf_bound.local_addr()?.to_string();
        let rcfg = RelayCfg {
            shard_id: s as u32,
            base: lo,
            count: (hi - lo) as usize,
            listen: String::new(),
            connect: p_addr.clone(),
            event: leaf_event,
            ..Default::default()
        };
        relay_handles.push(std::thread::spawn(move || {
            run_relay_on(leaf_bound, &rcfg)
        }));
        leaves.push((lo, hi, leaf_addr));
    }
    let c_bound = Bound::bind("127.0.0.1:0")?;
    let c_addr = c_bound.local_addr()?.to_string();
    let ccfg = RelayCfg {
        shard_id: 1,
        base: 3,
        count: 3,
        listen: String::new(),
        connect: master_addr.clone(),
        event: leaf_event,
        ..Default::default()
    };
    relay_handles
        .push(std::thread::spawn(move || run_relay_on(c_bound, &ccfg)));
    leaves.push((3, 6, c_addr));

    for (lo, hi, leaf_addr) in leaves {
        for ci in lo..hi {
            let shard = shards_by_id[ci as usize].take().unwrap();
            let addr = leaf_addr.clone();
            let fallback = master_addr.clone();
            let comp = crate::compressors::by_name(
                "topk",
                d,
                K_MULT,
                cfg.seed + ci as u64,
            )?;
            client_handles.push(std::thread::spawn(move || {
                use crate::algorithms::ClientState;
                use crate::net::client::ClientMode;
                use crate::oracle::LogisticOracle;
                let id = shard.client_id;
                let oracle = Box::new(LogisticOracle::new(shard, lam));
                run_client_with(
                    &addr,
                    id,
                    ClientMode::FedNL(ClientState::new(
                        id, oracle, comp, None,
                    )),
                    ClientOpts {
                        fallback: vec![fallback],
                        ..Default::default()
                    },
                )
            }));
        }
    }
    let mut pool =
        FaultPool::new(RelayPool::accept(master_bound, 2)?, plan.clone());
    let trace = run_fednl_pool(&mut pool, opts, vec![0.0; d], label);
    pool.into_inner().shutdown();
    for h in relay_handles {
        let _ = h.join();
    }
    for h in client_handles {
        let _ = h.join();
    }
    Ok(trace)
}

/// CI failover smoke: kill a relay mid-run and watch the run heal to
/// the same bits. A flat sequential reference (the `killrelay` spec
/// desugared over `shard::partition(6, 2)`) is compared against a
/// depth-3 relay tree — master ← parent P (`--parent 2`) ← child
/// relays A, B — where round 6's `killrelay@6:0` natively severs P
/// mid-run: the subtree dies by upward-EOF propagation, the three
/// orphaned clients rotate to their `--fallback` master address, and
/// the master adopts them at the next `prepare_round`. The tree runs
/// twice, with blocking and `--event` leaf relays. All trajectories
/// must be bit-identical, losses confined to the kill round, and the
/// commit-ack protocol must deliver exactly-once resumption (warm
/// rejoin: no fresh pull, no double-apply). Writes the per-round
/// accounting to `failsmoke_trace.json` (CI artifact).
pub fn fail_smoke(cfg: &HarnessCfg) -> Result<String> {
    cfg.ensure_out_dir()?;
    let spec = ProblemSpec {
        name: "failsmoke",
        d: 13,
        n_i_full: 40,
        n_clients_full: 6,
        lam: 1e-3,
    };
    let mut p = prepare_problem(&spec, cfg)?;
    p.n_clients = 6;
    p.n_i = 40;
    let x0 = vec![0.0; p.d()];
    let rounds = 20u64;
    let kill_round = 6u64;
    let plan_spec = "killrelay@6:0";
    let plan = FaultPlan::parse(plan_spec)?;
    let policy = RoundPolicy {
        quorum: Some(3),
        deadline_ms: Some(2000),
        on_missing: OnMissing::Drop,
    };
    let opts =
        Options { rounds, track_loss: true, policy, ..Default::default() };

    // Flat reference: killrelay@R:S needs a shard layout to desugar
    // against, so the flat pool is told the master-level partition.
    let mut flat = FaultPool::with_shard_layout(
        SeqPool::new(p.clients("topk", K_MULT, cfg)?),
        plan.clone(),
        2,
    );
    let t_flat =
        run_fednl_pool(&mut flat, &opts, x0.clone(), "failsmoke/flat");

    // Depth-3 tree, blocking leaf relays; then again with `--event`
    // leaves (unix only — the readiness transport needs epoll/poll).
    let t_block = run_failover_tree(
        &p,
        spec.lam,
        cfg,
        &plan,
        &opts,
        false,
        "failsmoke/tree-blocking",
    )?;
    let t_event = if cfg!(unix) {
        Some(run_failover_tree(
            &p,
            spec.lam,
            cfg,
            &plan,
            &opts,
            true,
            "failsmoke/tree-event",
        )?)
    } else {
        None
    };

    // The tentpole invariant: killing a relay mid-run heals to a
    // trajectory bit-identical to the flat desugared plan, on both
    // transports. (Byte columns are not compared: the tree pre-reduces
    // and carries ack frames, so its wire totals deliberately differ.)
    let mut legs = vec![(&t_block, "tree-blocking")];
    if let Some(t) = t_event.as_ref() {
        legs.push((t, "tree-event"));
    }
    for (t, name) in &legs {
        anyhow::ensure!(
            t.records.len() == t_flat.records.len(),
            "failsmoke: {name} ran {} rounds vs flat {}",
            t.records.len(),
            t_flat.records.len()
        );
        for (a, b) in t_flat.records.iter().zip(&t.records) {
            anyhow::ensure!(
                a.grad_norm.to_bits() == b.grad_norm.to_bits()
                    && a.loss.to_bits() == b.loss.to_bits()
                    && a.committed == b.committed
                    && a.missing == b.missing,
                "failsmoke: {name} diverged from flat at round {}: \
                 grad {:.17e} vs {:.17e}, committed {}/{} vs {}/{}",
                a.round,
                a.grad_norm,
                b.grad_norm,
                a.committed,
                a.committed + a.missing,
                b.committed,
                b.committed + b.missing
            );
        }
    }
    // The kill engaged (P's whole partition lost for one round), the
    // adoption healed it by the next round, and training converged.
    let lost: u32 = t_flat.records.iter().map(|r| r.missing).sum();
    anyhow::ensure!(lost == 3, "failsmoke: expected 3 lost, got {lost}");
    anyhow::ensure!(
        t_flat
            .records
            .iter()
            .all(|r| (r.round == kill_round) == (r.missing > 0)),
        "failsmoke: losses outside the kill round"
    );
    let first = t_flat.records.first().map(|r| r.grad_norm).unwrap_or(0.0);
    let last = t_flat.last_grad_norm();
    anyhow::ensure!(
        last.is_finite() && last < first * 1e-2,
        "failsmoke: no convergence under failover ({first:.3e} → {last:.3e})"
    );

    // Artifact: topology + the (identical) per-round accounting.
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"plan\": \"{plan_spec}\",\n"));
    json.push_str(
        "  \"policy\": {\"quorum\": 3, \"deadline_ms\": 2000, \
         \"on_missing\": \"drop\"},\n",
    );
    json.push_str(&format!(
        "  \"n_clients\": {}, \"rounds\": {rounds}, \
         \"kill_round\": {kill_round},\n",
        p.n_clients
    ));
    json.push_str(
        "  \"topology\": \"master <- [P(--parent 2) <- [A(0..2), \
         B(2..3)], C(3..6)]\",\n",
    );
    json.push_str(&format!(
        "  \"configs\": [\"flat\", \"tree-blocking\"{}], \
         \"bit_identical\": true,\n",
        if t_event.is_some() { ", \"tree-event\"" } else { "" }
    ));
    json.push_str("  \"trace\": [\n");
    for (i, r) in t_flat.records.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"round\": {}, \"grad_norm\": {:e}, \"committed\": {}, \
             \"missing\": {}}}{}\n",
            r.round,
            r.grad_norm,
            r.committed,
            r.missing,
            if i + 1 < t_flat.records.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let json_path = format!("{}/failsmoke_trace.json", cfg.out_dir);
    std::fs::write(&json_path, &json)?;

    let mut out = format!(
        "## Failover smoke — depth-3 relay tree under `{plan_spec}` \
         (n={}, quorum=3, r={rounds})\n\n",
        p.n_clients
    );
    let mut table = Table::new(&[
        "Topology",
        "||∇f||_final",
        "Rounds",
        "Lost contributions",
        "Bit-identical to flat",
    ]);
    let mut rows = vec![(&t_flat, "flat (desugared killrelay)")];
    rows.push((&t_block, "depth-3 tree, blocking leaves"));
    if let Some(t) = t_event.as_ref() {
        rows.push((t, "depth-3 tree, --event leaves"));
    }
    for (t, name) in rows {
        table.row(&[
            name.to_string(),
            sci(t.last_grad_norm()),
            format!("{}", t.records.len()),
            format!("{}", t.records.iter().map(|r| r.missing).sum::<u32>()),
            "yes".to_string(),
        ]);
    }
    out.push_str(&table.to_markdown());
    out.push_str(&format!("\nPer-round trace written to {json_path}\n"));
    Ok(out)
}

pub fn table3(cfg: &HarnessCfg) -> Result<String> {
    cfg.ensure_out_dir()?;
    let tol = 1e-9;
    let mut out = String::from(
        "## Table 3 — multi-node TCP (loopback), FedNL vs distributed baselines (tol 1e-9)\n\n",
    );
    for spec in [&W8A, &A9A, &PHISHING] {
        // Paper Table 3: n = 50 clients, larger n_i.
        let mut p = prepare_problem(spec, cfg)?;
        p.n_clients = if cfg.scale == Scale::Full { 50 } else { 8 };
        p.n_i = (p.dataset.n_samples() / (p.n_clients + 1)).min(match spec.name {
            "w8a" => 994,
            "a9a" => 651,
            _ => 221,
        });
        let budget = if cfg.scale == Scale::Full { 100_000 } else { 20_000 };
        let mut table =
            Table::new(&["Solution", "Init (s)", "Solve (s)", "Rounds", "MB up"]);
        let runs: Vec<(String, &str, TcpAlgo)> = vec![
            ("GD (Spark-class sub)".into(), "identity", TcpAlgo::Gd),
            ("L-BFGS (Ray-class sub)".into(), "identity", TcpAlgo::Lbfgs),
            ("FedNL/RandK".into(), "randk", TcpAlgo::FedNL),
            ("FedNL/RandSeqK".into(), "randseqk", TcpAlgo::FedNL),
            ("FedNL/TopK".into(), "topk", TcpAlgo::FedNL),
            ("FedNL/TopLEK".into(), "toplek", TcpAlgo::FedNL),
            ("FedNL/Natural".into(), "natural", TcpAlgo::FedNL),
        ];
        for (name, comp, algo) in runs {
            let (tr, solve, init) =
                run_tcp_experiment(&p, comp, algo, budget, Some(tol), cfg)?;
            table.row(&[
                name,
                format!("+{init:.3}"),
                format!("{solve:.3}"),
                format!("{}", tr.records.len()),
                human_bytes(tr.total_bytes_up()),
            ]);
        }
        out.push_str(&format!(
            "### {} (d={}, n={}, n_i={})\n\n{}\n",
            spec.name,
            p.d(),
            p.n_clients,
            p.n_i,
            table.to_markdown()
        ));
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Tables 5-7: resource usage (Linux analogues of the paper's Windows
// kernel-handle / private-bytes / working-set measurements).
// ---------------------------------------------------------------------

pub fn table5(cfg: &HarnessCfg) -> Result<String> {
    let mut out = String::from(
        "## Tables 5–7 — process resources during single-node simulation (Linux analogues)\n\n",
    );
    let mut table = Table::new(&[
        "Run",
        "Open FDs",
        "VmPeak",
        "VmHWM (peak RSS)",
        "Threads",
    ]);
    let problem = prepare_problem(&W8A, cfg)?;
    for comp in TABLE1_ROWS {
        let mut pool = problem.pool(comp, K_MULT, cfg)?;
        let opts = Options {
            rounds: problem.rounds.min(20),
            ..Default::default()
        };
        let _ = run_fednl_pool(
            pool.as_mut(),
            &opts,
            vec![0.0; problem.d()],
            "rusage",
        );
        let snap = ResourceSnapshot::capture();
        table.row(&[
            format!("FedNL/{comp}"),
            format!("{}", snap.open_fds),
            format!("{} K", snap.vm_peak_kib),
            format!("{} K", snap.vm_hwm_kib),
            format!("{}", snap.threads),
        ]);
    }
    out.push_str(&table.to_markdown());
    Ok(out)
}

// ---------------------------------------------------------------------
// Figures 1-3 (single-node FedNL-LS traces) & 4-12 (multi-node traces).
// ---------------------------------------------------------------------

fn spec_by_fig(fig: usize) -> &'static ProblemSpec {
    match fig {
        1 | 4 | 5 | 6 => &W8A,
        2 | 7 | 8 | 9 => &A9A,
        _ => &PHISHING,
    }
}

/// Figures 1–3: FedNL-LS in a single node, one CSV per compressor with
/// grad-norm / loss vs rounds, bits and time.
pub fn fig_single_node(fig: usize, cfg: &HarnessCfg) -> Result<String> {
    cfg.ensure_out_dir()?;
    let spec = spec_by_fig(fig);
    let problem = prepare_problem(spec, cfg)?;
    let rounds = if cfg.scale == Scale::Full {
        if fig == 3 { 2000 } else { 1000 }
    } else {
        problem.rounds
    };
    let mut out = format!(
        "## Figure {fig} — FedNL-LS single-node on {} (r={rounds}, c=0.49, γ=0.5)\n\nCSV series written to {}/fig{fig}_*.csv\n\n",
        spec.name, cfg.out_dir
    );
    let mut table =
        Table::new(&["Compressor", "||∇f||_final", "MB up", "Rounds"]);
    for comp in TABLE1_ROWS {
        let mut pool = problem.pool(comp, K_MULT, cfg)?;
        let opts =
            Options { rounds, warm_start: true, ..Default::default() };
        let tr = run_fednl_ls_pool(
            pool.as_mut(),
            &opts,
            &LineSearchParams { c: 0.49, gamma: 0.5, max_backtracks: 40 },
            vec![0.0; problem.d()],
            &format!("FedNL-LS/{comp}"),
        );
        tr.write_csv(&format!("{}/fig{fig}_{comp}.csv", cfg.out_dir))?;
        table.row(&[
            comp.to_string(),
            sci(tr.last_grad_norm()),
            human_bytes(tr.total_bytes_up()),
            format!("{}", tr.records.len()),
        ]);
    }
    out.push_str(&table.to_markdown());
    Ok(out)
}

/// Figures 4–12: multi-node (TCP loopback) FedNL / FedNL-LS / FedNL-PP.
pub fn fig_multi_node(fig: usize, cfg: &HarnessCfg) -> Result<String> {
    cfg.ensure_out_dir()?;
    let spec = spec_by_fig(fig);
    let algo = match fig {
        4 | 7 | 10 => TcpAlgo::FedNL,
        5 | 8 | 11 => TcpAlgo::FedNLLS,
        _ => TcpAlgo::FedNLPP { tau: 12 },
    };
    let mut p = prepare_problem(spec, cfg)?;
    p.n_clients = if cfg.scale == Scale::Full { 50 } else { 8 };
    p.n_i = p.dataset.n_samples() / (p.n_clients + 1);
    let algo = match algo {
        TcpAlgo::FedNLPP { tau } => {
            TcpAlgo::FedNLPP { tau: tau.min(p.n_clients) }
        }
        a => a,
    };
    let rounds = if cfg.scale == Scale::Full { 1000 } else { 60 };
    let mut out = format!(
        "## Figure {fig} — {:?} multi-node TCP on {} (n={}, r={rounds})\n\nCSV series written to {}/fig{fig}_*.csv\n\n",
        algo, spec.name, p.n_clients, cfg.out_dir
    );
    let mut table =
        Table::new(&["Compressor", "||∇f||_final", "MB up", "Wall (s)"]);
    for comp in TABLE1_ROWS {
        let (tr, solve, _) =
            run_tcp_experiment(&p, comp, algo, rounds, None, cfg)?;
        tr.write_csv(&format!("{}/fig{fig}_{comp}.csv", cfg.out_dir))?;
        table.row(&[
            comp.to_string(),
            sci(tr.last_grad_norm()),
            human_bytes(tr.total_bytes_up()),
            format!("{solve:.2}"),
        ]);
    }
    out.push_str(&table.to_markdown());
    Ok(out)
}

/// §4 back-of-envelope cost model.
pub fn costmodel() -> String {
    use crate::metrics::costmodel::{estimate, MachineModel, Workload};
    let m = MachineModel::default();
    let w = Workload {
        d: 301.0,
        n_clients: 142.0,
        n_i: 348.0,
        k: 8.0 * 301.0,
        rounds: 1000.0,
    };
    let e = estimate(&m, &w);
    let mut t = Table::new(&["Component", "Estimated (s)", "Paper (s)"]);
    t.row(&["Client compute".into(), format!("{:.3}", e.client_compute), "0.26".into()]);
    t.row(&["Master reduce".into(), format!("{:.4}", e.master_reduce), "0.0032".into()]);
    t.row(&["Master solve".into(), format!("{:.3}", e.master_solve), "4.1316".into()]);
    t.row(&["Memory penalty".into(), format!("{:.3}", e.memory_penalty), "13.182".into()]);
    t.row(&["Total lower bound".into(), format!("{:.3}", e.total()), "17.576".into()]);
    format!(
        "## §4 back-of-the-envelope model (Xeon Gold 6246 parameters)\n\n{}\nObserved Python baseline: 19 770 s → the ×1000 headroom.\n",
        t.to_markdown()
    )
}

pub fn human_line(label: &str, secs: f64) -> String {
    format!("{label}: {}", human_secs(secs))
}

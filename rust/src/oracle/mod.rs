//! Local objective oracles (paper component `optimization_problems` +
//! `numerics`).
//!
//! A FedNL client owns an [`Oracle`] for its local fᵢ and evaluates
//! (fᵢ, ∇fᵢ, ∇²fᵢ) each round. The logistic oracle implements the
//! paper's fused evaluation (§5.7): classification margins and sigmoid
//! values are computed once per point and shared by all three outputs.
//! `numerics` provides the finite-difference verification tools the
//! paper ships for user-defined oracles (Appendix L.4 item 8).

pub mod logistic;
pub mod numerics;
pub mod quadratic;

pub use logistic::LogisticOracle;
pub use quadratic::QuadraticOracle;

use crate::linalg::Mat;

/// A twice-differentiable local objective fᵢ: ℝᵈ → ℝ.
///
/// Methods take `&mut self` so implementations can reuse internal
/// buffers (margins, sigmoids) across calls — the round loop performs
/// zero allocations (§5.13).
pub trait Oracle: Send {
    /// Problem dimension d.
    fn dim(&self) -> usize;

    /// f(x).
    fn loss(&mut self, x: &[f64]) -> f64;

    /// ∇f(x) into `g`; returns f(x) (margins shared — §5.7).
    fn loss_grad(&mut self, x: &[f64], g: &mut [f64]) -> f64;

    /// f, ∇f and ∇²f in one fused pass.
    fn loss_grad_hessian(
        &mut self,
        x: &[f64],
        g: &mut [f64],
        h: &mut Mat,
    ) -> f64;

    /// ∇f(x) only (default: discard the fused loss).
    fn grad(&mut self, x: &[f64], g: &mut [f64]) {
        let _ = self.loss_grad(x, g);
    }

    /// ∇²f(x) only (default: discard loss/grad).
    fn hessian(&mut self, x: &[f64], h: &mut Mat) {
        let mut g = vec![0.0; self.dim()];
        let _ = self.loss_grad_hessian(x, &mut g, h);
    }
}

/// Numerically stable softplus: log(1 + eˣ).
#[inline]
pub fn softplus(x: f64) -> f64 {
    if x > 36.0 {
        // exp(-x) < 2e-16: log1p(exp(x)) = x to double precision.
        x
    } else if x < -36.0 {
        0.0
    } else {
        x.max(0.0) + (-(x.abs())).exp().ln_1p()
    }
}

/// Numerically stable sigmoid σ(x) = 1/(1+e⁻ˣ) via libm `exp` (the
/// exact path; the oracle hot loop uses the vectorized polynomial
/// kernel [`crate::linalg::simd::sigmoid_neg_scan`] instead unless
/// `FEDNL_EXACT_EXP=1`).
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    crate::linalg::simd::sigmoid_exact(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softplus_stable_extremes() {
        assert_eq!(softplus(1000.0), 1000.0);
        assert_eq!(softplus(-1000.0), 0.0);
        assert!((softplus(0.0) - std::f64::consts::LN_2).abs() < 1e-15);
    }

    #[test]
    fn sigmoid_symmetry() {
        for x in [-30.0, -2.0, 0.0, 0.7, 50.0] {
            let s = sigmoid(x);
            assert!((s + sigmoid(-x) - 1.0).abs() < 1e-15);
            assert!((0.0..=1.0).contains(&s));
        }
    }

    #[test]
    fn softplus_derivative_is_sigmoid() {
        let eps = 1e-6;
        for x in [-3.0, -0.5, 0.0, 1.5, 4.0] {
            let num = (softplus(x + eps) - softplus(x - eps)) / (2.0 * eps);
            assert!((num - sigmoid(x)).abs() < 1e-9);
        }
    }
}

//! RandK: a u.a.r. k-subset of the packed upper triangle.
//!
//! Used in its *scaled contractive form*: the unbiased RandK multiplies
//! kept entries by n/k (ω = n/k − 1); dividing by (1+ω) = n/k yields
//! kept entries **unscaled** with contraction δ = k/n — this is the form
//! FedNL's Hessian learning consumes (mod. §2 of the FedNL paper).
//!
//! The subset is drawn via partial Fisher–Yates from a per-round PRG
//! seeded as `seed_base ⊕ round`; the wire carries only the seed and the
//! master regenerates indices bit-identically (paper §7 mode (ii) —
//! "index reconstruction using a pseudo-random generator"). Indices are
//! locally sorted before the Hessian-shift update for cache-friendly
//! application (v41), which does not affect the chosen set.

use super::{Compressed, Compressor, CompressorKind, IndexPayload};
use crate::linalg::packed::PackedUpper;
use crate::rng::{sample_distinct, Pcg64};

/// Uniform random-k sparsifier with seed-reconstructible indices.
#[derive(Debug, Clone)]
pub struct RandK {
    k: usize,
    seed_base: u64,
}

impl RandK {
    pub fn new(k: usize, seed_base: u64) -> Self {
        assert!(k > 0);
        Self { k, seed_base }
    }

    /// The per-round seed both sides derive (client compress / master
    /// reconstruct must agree bit-for-bit).
    pub fn round_seed(&self, round: u64) -> u64 {
        crate::rng::pcg::splitmix64(self.seed_base ^ round.wrapping_mul(0x9E37_79B9))
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("RandK[k={}]", self.k)
    }

    fn kind(&self, n: usize) -> CompressorKind {
        // Scaled-contractive form of the ω = n/k − 1 unbiased compressor.
        CompressorKind::Contractive { delta: self.k.min(n) as f64 / n as f64 }
    }

    fn compress(
        &mut self,
        _pu: &PackedUpper,
        src: &[f64],
        round: u64,
    ) -> Compressed {
        let n = src.len();
        let k = self.k.min(n);
        let seed = self.round_seed(round);
        let mut rng = Pcg64::seed_from_u64(seed);
        let idx = sample_distinct(&mut rng, n, k);
        let values = idx.iter().map(|&i| src[i as usize]).collect();
        Compressed {
            payload: IndexPayload::Seed { seed, k: k as u32 },
            values,
            scale: 1.0,
            encoding: super::ValueEncoding::F64,
            n: n as u32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{distortion_sq, weighted_norm_sq};
    use crate::rng::Rng;

    fn packed_src(d: usize, seed: u64) -> (PackedUpper, Vec<f64>) {
        let pu = PackedUpper::new(d);
        let mut rng = Pcg64::seed_from_u64(seed);
        let src = (0..pu.len()).map(|_| rng.next_gaussian()).collect();
        (pu, src)
    }

    #[test]
    fn seed_reconstruction_matches() {
        let (pu, src) = packed_src(10, 1);
        let mut c = RandK::new(12, 777);
        let out = c.compress(&pu, &src, 42);
        // The master only has the payload; regenerate and compare the
        // values against a fresh local selection.
        let idx = out.indices();
        assert_eq!(idx.len(), 12);
        for (v, &i) in out.values.iter().zip(&idx) {
            assert_eq!(*v, src[i as usize]);
        }
    }

    #[test]
    fn different_rounds_different_sets() {
        let (pu, src) = packed_src(10, 2);
        let mut c = RandK::new(8, 5);
        let a = c.compress(&pu, &src, 1).indices();
        let b = c.compress(&pu, &src, 2).indices();
        assert_ne!(a, b);
    }

    #[test]
    fn unbiased_selection_probability() {
        // Each coordinate selected with probability ≈ k/n (App. C.1).
        let (pu, src) = packed_src(8, 3);
        let n = src.len(); // 36
        let k = 9;
        let mut counts = vec![0u32; n];
        let mut c = RandK::new(k, 11);
        let trials = 4000;
        for r in 0..trials {
            for i in c.compress(&pu, &src, r).indices() {
                counts[i as usize] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64;
        for (i, &cnt) in counts.iter().enumerate() {
            assert!(
                (cnt as f64 - expect).abs() < expect * 0.25,
                "coord {i}: {cnt} vs {expect}"
            );
        }
    }

    #[test]
    fn unbiasedness_of_scaled_estimator() {
        // E[(n/k)·C(x)] = x for the unscaled-kept-values form.
        let (pu, src) = packed_src(6, 4);
        let n = src.len();
        let k = 5;
        let mut c = RandK::new(k, 17);
        let trials = 20_000;
        let mut acc = vec![0.0; n];
        for r in 0..trials {
            let out = c.compress(&pu, &src, r);
            for (v, i) in out.values.iter().zip(out.indices()) {
                acc[i as usize] += v * n as f64 / k as f64;
            }
        }
        for i in 0..n {
            let mean = acc[i] / trials as f64;
            assert!(
                (mean - src[i]).abs() < 0.12 * src[i].abs().max(0.4),
                "coord {i}: {mean} vs {}",
                src[i]
            );
        }
    }

    #[test]
    fn expected_contraction_holds() {
        // E‖C(x) − x‖² = (1 − k/n)‖x‖² for the contractive form.
        let (pu, src) = packed_src(7, 5);
        let n = src.len();
        let k = 7;
        let mut c = RandK::new(k, 23);
        let trials = 3000;
        let mut acc = 0.0;
        for r in 0..trials {
            let out = c.compress(&pu, &src, r);
            acc += distortion_sq(&pu, &src, &out);
        }
        let mean = acc / trials as f64;
        let expect = (1.0 - k as f64 / n as f64) * weighted_norm_sq(&pu, &src);
        assert!((mean - expect).abs() < 0.05 * expect, "{mean} vs {expect}");
    }

    #[test]
    fn wire_is_seed_only() {
        let (pu, src) = packed_src(9, 6);
        let mut c = RandK::new(10, 3);
        let out = c.compress(&pu, &src, 0);
        // 10 f64 values + 12 bytes of seed material (≪ explicit
        // indices) + the fixed codec fields.
        assert_eq!(
            out.wire_bytes(),
            10 * 8 + 12 + crate::compressors::CODEC_OVERHEAD_BYTES
        );
    }
}

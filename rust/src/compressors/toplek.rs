//! TopLEK — the paper's NEW adaptive "Top Less-Equal K" compressor
//! (Appendix D, Algorithm 4).
//!
//! TopK's worst-case contraction δ = k/n is attained only on the
//! diagonal of ℝⁿ (App. D.2) — on real inputs TopK over-delivers. TopLEK
//! compresses *as much as the theory allows, but not more*: it returns
//! k' ≤ k entries such that the contractive inequality holds with
//! **tight equality in expectation**: E‖C(x) − x‖² = (1 − k/n)‖x‖².
//!
//! Construction (Alg. 4): let r(m) = 1 − (top-m energy)/(total energy)
//! be the residual after keeping m entries (r decreasing in m, r(0)=1).
//! Find the bracketing pair r(m) ≤ 1−δ ≤ r(m−1), then keep m entries
//! with probability p = (r(m−1) − (1−δ))/(r(m−1) − r(m)) and m−1
//! otherwise. Keeping TopK's worst case as a guard, m ≤ k always, so
//! clients "transmit not k components but at most k; in fortuitous
//! scenarios 0" (App. D.3).

use super::topk::select_topk_energy;
use super::{Compressed, Compressor, CompressorKind, IndexPayload};
use crate::linalg::packed::PackedUpper;
use crate::rng::{Pcg64, Rng};

/// Adaptive randomized Top-(≤k) sparsifier.
#[derive(Debug, Clone)]
pub struct TopLEK {
    k: usize,
    seed_base: u64,
    /// Reused energy-scan buffer (zero allocation per round, §5.13).
    scratch: Vec<f64>,
}

impl TopLEK {
    pub fn new(k: usize, seed_base: u64) -> Self {
        assert!(k > 0);
        Self { k, seed_base, scratch: Vec::new() }
    }
}

impl Compressor for TopLEK {
    fn name(&self) -> String {
        format!("TopLEK[k={}]", self.k)
    }

    fn kind(&self, n: usize) -> CompressorKind {
        CompressorKind::Contractive { delta: self.k.min(n) as f64 / n as f64 }
    }

    fn compress(
        &mut self,
        pu: &PackedUpper,
        src: &[f64],
        round: u64,
    ) -> Compressed {
        let n = src.len();
        let k = self.k.min(n);
        let target_residual = 1.0 - k as f64 / n as f64; // 1 − δ

        // Top-k indices by weighted energy (vectorized scan + 4-ary
        // heap), then order them by energy descending to form prefixes.
        // `scratch` holds every index's energy after the call — reuse
        // it so the sort keys are bit-identical to the selection keys.
        let idx = select_topk_energy(pu, src, k, &mut self.scratch);
        let mut by_energy: Vec<(f64, u32)> =
            idx.iter().map(|&i| (self.scratch[i as usize], i)).collect();
        by_energy.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

        let total: f64 = pu.frobenius_sq_packed(src);
        if total <= 0.0 {
            // Zero input: nothing to send (the fortuitous 0-component case).
            return Compressed {
                payload: IndexPayload::Explicit(Vec::new()),
                values: Vec::new(),
                scale: 1.0,
                encoding: super::ValueEncoding::F64,
                n: n as u32,
            };
        }

        // Residuals r(m) for m = 0..=k; r(0) = 1.
        let mut kept = 0.0;
        let mut m_star = k; // smallest m with r(m) ≤ 1 − δ
        let mut r_prev = 1.0; // r(m−1) at the bracket
        let mut r_at = 1.0 - 0.0;
        let mut found = false;
        for (m, &(e, _)) in by_energy.iter().enumerate() {
            kept += e;
            let r_m = (1.0 - kept / total).max(0.0);
            if r_m <= target_residual + 1e-15 {
                m_star = m + 1;
                r_prev = if m == 0 { 1.0 } else { r_at };
                r_at = r_m;
                found = true;
                break;
            }
            r_at = r_m;
        }
        // TopK's worst-case guarantee ensures r(k) ≤ 1−δ, so `found`
        // is always true for k ≥ 1; guard anyway.
        if !found {
            m_star = k;
            r_prev = r_at;
            r_at = (1.0
                - by_energy.iter().map(|&(e, _)| e).sum::<f64>() / total)
                .max(0.0);
        }

        // Bernoulli tie between m* (prob p) and m*−1 (prob 1−p) so the
        // expected residual equals the target exactly.
        let denom = r_prev - r_at;
        let p = if denom > 1e-300 {
            ((r_prev - target_residual) / denom).clamp(0.0, 1.0)
        } else {
            1.0
        };
        let seed = crate::rng::pcg::splitmix64(
            self.seed_base ^ round.wrapping_mul(0xC2B2_AE35),
        );
        let mut rng = Pcg64::seed_from_u64(seed);
        let m_used = if rng.bernoulli(p) { m_star } else { m_star - 1 };

        let mut chosen: Vec<u32> =
            by_energy[..m_used].iter().map(|&(_, i)| i).collect();
        chosen.sort_unstable(); // v41 cache-friendly master update
        let values = chosen.iter().map(|&i| src[i as usize]).collect();
        Compressed {
            payload: IndexPayload::Explicit(chosen),
            values,
            scale: 1.0,
            encoding: super::ValueEncoding::F64,
            n: n as u32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{distortion_sq, weighted_norm_sq, TopK};

    fn packed_src(d: usize, seed: u64) -> (PackedUpper, Vec<f64>) {
        let pu = PackedUpper::new(d);
        let mut rng = Pcg64::seed_from_u64(seed);
        let src = (0..pu.len()).map(|_| rng.next_gaussian()).collect();
        (pu, src)
    }

    #[test]
    fn never_sends_more_than_k() {
        for seed in 0..30 {
            let (pu, src) = packed_src(8, seed);
            let mut c = TopLEK::new(10, seed);
            let out = c.compress(&pu, &src, seed);
            assert!(out.values.len() <= 10, "sent {} > k", out.values.len());
        }
    }

    #[test]
    fn sends_fewer_than_topk_on_concentrated_input() {
        // One dominant coordinate: TopLEK should send ≈1 entry while
        // TopK always sends k.
        let pu = PackedUpper::new(8);
        let mut src = vec![1e-6; pu.len()];
        src[5] = 100.0;
        let mut lek = TopLEK::new(12, 1);
        let mut top = TopK::new(12);
        let out_lek = lek.compress(&pu, &src, 0);
        let out_top = top.compress(&pu, &src, 0);
        assert_eq!(out_top.values.len(), 12);
        assert!(out_lek.values.len() <= 2, "sent {}", out_lek.values.len());
    }

    #[test]
    fn contraction_tight_in_expectation() {
        // E‖C(x)−x‖² should equal (1−δ)‖x‖² (not merely bound it).
        let (pu, src) = packed_src(7, 9);
        let n = src.len();
        let k = 6;
        let total = weighted_norm_sq(&pu, &src);
        let target = (1.0 - k as f64 / n as f64) * total;
        let trials = 4000;
        let mut acc = 0.0;
        let mut c = TopLEK::new(k, 5);
        for r in 0..trials {
            let out = c.compress(&pu, &src, r);
            acc += distortion_sq(&pu, &src, &out);
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - target).abs() < 0.02 * total,
            "mean {mean} vs target {target} (total {total})"
        );
    }

    #[test]
    fn per_draw_contraction_never_exceeds_bracket_upper() {
        // Each realized draw keeps at least m*−1 top entries, so the
        // distortion never exceeds r(m*−1)·‖x‖² which itself brackets
        // the target from above by construction; sanity: distortion
        // is always ≤ ‖x‖².
        for seed in 0..20 {
            let (pu, src) = packed_src(6, 100 + seed);
            let mut c = TopLEK::new(5, seed);
            let out = c.compress(&pu, &src, seed * 3);
            let dist = distortion_sq(&pu, &src, &out);
            assert!(dist <= weighted_norm_sq(&pu, &src) + 1e-12);
        }
    }

    #[test]
    fn zero_input_sends_nothing() {
        let pu = PackedUpper::new(5);
        let src = vec![0.0; pu.len()];
        let mut c = TopLEK::new(4, 2);
        let out = c.compress(&pu, &src, 0);
        assert!(out.values.is_empty());
    }

    #[test]
    fn values_match_indices() {
        let (pu, src) = packed_src(9, 11);
        let mut c = TopLEK::new(15, 3);
        let out = c.compress(&pu, &src, 7);
        for (v, i) in out.values.iter().zip(out.indices()) {
            assert_eq!(*v, src[i as usize]);
        }
    }
}

//! Symmetric eigendecomposition via the cyclic Jacobi rotation method,
//! and the `[M]_μ` PSD projection FedNL's Option-1 model update needs
//! (Alg. 1 line 11a: project the learned Hessian onto {A : A ⪰ μI} in
//! the Frobenius norm — i.e. clip eigenvalues from below at μ).
//!
//! Jacobi is chosen over QR for self-containedness and robustness: it is
//! a few dozen lines, unconditionally stable for symmetric matrices, and
//! the master only projects d×d with d ≤ a few hundred.

use super::matrix::Mat;

/// Eigendecomposition M = V · diag(λ) · Vᵀ of a symmetric matrix.
#[derive(Debug, Clone)]
pub struct SymEigen {
    /// Eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Column i of `vectors` is the eigenvector for `values[i]`.
    pub vectors: Mat,
}

/// Cyclic Jacobi eigensolver. `tol` bounds the off-diagonal Frobenius
/// mass at convergence (relative to ‖M‖_F).
pub fn sym_eigen(m: &Mat, tol: f64, max_sweeps: usize) -> SymEigen {
    let d = m.rows();
    assert_eq!(m.cols(), d, "sym_eigen: square required");
    let mut a = m.clone();
    let mut v = Mat::identity_scaled(d, 1.0);
    let norm = a.frobenius_sq().sqrt().max(1e-300);

    for _sweep in 0..max_sweeps {
        // Off-diagonal mass.
        let mut off = 0.0;
        for i in 0..d {
            for j in i + 1..d {
                off += 2.0 * a.get(i, j) * a.get(i, j);
            }
        }
        if off.sqrt() <= tol * norm {
            break;
        }
        for p in 0..d {
            for q in p + 1..d {
                let apq = a.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a.get(p, p);
                let aqq = a.get(q, q);
                // Rotation angle.
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // A ← JᵀAJ applied to rows/cols p, q.
                for k in 0..d {
                    let akp = a.get(k, p);
                    let akq = a.get(k, q);
                    a.set(k, p, c * akp - s * akq);
                    a.set(k, q, s * akp + c * akq);
                }
                for k in 0..d {
                    let apk = a.get(p, k);
                    let aqk = a.get(q, k);
                    a.set(p, k, c * apk - s * aqk);
                    a.set(q, k, s * apk + c * aqk);
                }
                // Accumulate V ← VJ.
                for k in 0..d {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    // Extract and sort ascending.
    let mut order: Vec<usize> = (0..d).collect();
    let diag: Vec<f64> = (0..d).map(|i| a.get(i, i)).collect();
    order.sort_by(|&i, &j| diag[i].partial_cmp(&diag[j]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let mut vectors = Mat::zeros(d, d);
    for (new_col, &old_col) in order.iter().enumerate() {
        for r in 0..d {
            vectors.set(r, new_col, v.get(r, old_col));
        }
    }
    SymEigen { values, vectors }
}

/// `[M]_μ`: the nearest (Frobenius) matrix with all eigenvalues ≥ μ —
/// clip λᵢ ← max(λᵢ, μ) and reassemble (FedNL Option 1).
pub fn project_psd_mu(m: &Mat, mu: f64) -> Mat {
    let d = m.rows();
    let eig = sym_eigen(m, 1e-12, 64);
    let mut out = Mat::zeros(d, d);
    for (i, &lam) in eig.values.iter().enumerate() {
        let l = lam.max(mu);
        // out += l · vᵢ vᵢᵀ (upper triangle, symmetrize once).
        for r in 0..d {
            let vr = eig.vectors.get(r, i) * l;
            for c in r..d {
                out.add_at(r, c, vr * eig.vectors.get(c, i));
            }
        }
    }
    out.symmetrize_from_upper();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn random_sym(d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut m = Mat::zeros(d, d);
        for i in 0..d {
            for j in i..d {
                let v = rng.next_gaussian();
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        m
    }

    #[test]
    fn diagonal_matrix_eigen() {
        let mut m = Mat::zeros(3, 3);
        m.set(0, 0, 3.0);
        m.set(1, 1, -1.0);
        m.set(2, 2, 2.0);
        let e = sym_eigen(&m, 1e-14, 32);
        assert!((e.values[0] + 1.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction() {
        let m = random_sym(8, 1);
        let e = sym_eigen(&m, 1e-13, 64);
        // M ≈ V diag(λ) Vᵀ
        let d = 8;
        let mut rec = Mat::zeros(d, d);
        for i in 0..d {
            for r in 0..d {
                for c in 0..d {
                    rec.add_at(
                        r,
                        c,
                        e.values[i] * e.vectors.get(r, i) * e.vectors.get(c, i),
                    );
                }
            }
        }
        assert!(m.max_abs_diff(&rec) < 1e-9);
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let m = random_sym(6, 2);
        let e = sym_eigen(&m, 1e-13, 64);
        for i in 0..6 {
            for j in 0..6 {
                let mut dot = 0.0;
                for r in 0..6 {
                    dot += e.vectors.get(r, i) * e.vectors.get(r, j);
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-10, "({i},{j}): {dot}");
            }
        }
    }

    #[test]
    fn projection_clips_spectrum() {
        let m = random_sym(7, 3);
        let mu = 0.5;
        let p = project_psd_mu(&m, mu);
        let e = sym_eigen(&p, 1e-12, 64);
        for &lam in &e.values {
            assert!(lam >= mu - 1e-8, "λ={lam}");
        }
        // Projection is idempotent on already-feasible matrices.
        let p2 = project_psd_mu(&p, mu);
        assert!(p.max_abs_diff(&p2) < 1e-8);
    }

    #[test]
    fn projection_preserves_feasible_matrix() {
        // SPD with λmin > μ must be (numerically) unchanged.
        let mut m = random_sym(5, 4);
        // Make strongly PD: M ← MᵀM/d + 2I.
        let mm = m.matmul_naive(&m);
        m = mm;
        for v in [0usize] {
            let _ = v;
        }
        let mut spd = Mat::zeros(5, 5);
        for i in 0..5 {
            for j in 0..5 {
                spd.set(i, j, m.get(i, j) / 5.0);
            }
        }
        spd.add_diag(2.0);
        let p = project_psd_mu(&spd, 0.1);
        assert!(spd.max_abs_diff(&p) < 1e-8);
    }
}

//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt` produced
//! by `python/compile/aot.py`) and run the Layer-2 JAX oracle from the
//! Rust hot path.
//!
//! Python never runs at request time: `make artifacts` lowers the jitted
//! oracle to HLO **text** once; here `HloModuleProto::from_text_file` →
//! `PjRtClient::compile` produces a native executable per dataset shape.
//! A client's design matrix is uploaded once as a device-resident buffer
//! and reused every round; only the d-vector x travels per call.

pub mod pjrt;

pub use pjrt::{PjrtOracle, PjrtRuntime, ShapeEntry};

//! Wire encoding of the FedNL protocol messages (fixed-width LE fields;
//! paper §7 found fixed 32-bit index framing beats variable-width).

use anyhow::Result;

use crate::algorithms::ClientMsg;
use crate::compressors::natural::{pack16, unpack16};
use crate::compressors::{Compressed, IndexPayload, ValueEncoding};
use crate::utils::{ByteReader, ByteWriter};

/// Frame tags, master → client.
pub mod s2c {
    pub const ROUND: u8 = 1;
    pub const EVAL_LOSS: u8 = 2;
    pub const WARM_START: u8 = 3;
    pub const PP_ROUND: u8 = 4;
    pub const SET_ALPHA: u8 = 5;
    pub const SHUTDOWN: u8 = 6;
    /// First-order reduction (baselines): client replies GRAD.
    pub const LOSS_GRAD: u8 = 7;
    /// FedNL-PP state bootstrap: client replies PP_STATE with (lᵢ⁰, gᵢ⁰).
    pub const PP_INIT: u8 = 8;
}

/// Frame tags, client → master.
pub mod c2s {
    pub const REGISTER: u8 = 10;
    pub const MSG: u8 = 11;
    pub const LOSS: u8 = 12;
    pub const WARM: u8 = 13;
    pub const PP_MSG: u8 = 14;
    pub const ACK: u8 = 15;
    /// (loss, gradient) reply to LOSS_GRAD.
    pub const GRAD: u8 = 16;
    /// (lᵢ⁰, gᵢ⁰) reply to PP_INIT (same codec as GRAD).
    pub const PP_STATE: u8 = 17;
}

// --- payload codecs -------------------------------------------------------

pub fn encode_round(x: &[f64], round: u64, need_loss: bool) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(x.len() * 8 + 16);
    w.put_u64(round);
    w.put_u8(need_loss as u8);
    w.put_u32(x.len() as u32);
    w.put_f64_slice(x);
    w.into_vec()
}

pub fn decode_round(p: &[u8]) -> Result<(Vec<f64>, u64, bool)> {
    let mut r = ByteReader::new(p);
    let round = r.get_u64()?;
    let need_loss = r.get_u8()? != 0;
    let n = r.get_u32()? as usize;
    Ok((r.get_f64_vec(n)?, round, need_loss))
}

pub fn encode_vec(x: &[f64]) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(x.len() * 8 + 4);
    w.put_u32(x.len() as u32);
    w.put_f64_slice(x);
    w.into_vec()
}

pub fn decode_vec(p: &[u8]) -> Result<Vec<f64>> {
    let mut r = ByteReader::new(p);
    let n = r.get_u32()? as usize;
    r.get_f64_vec(n)
}

pub fn encode_scalar(v: f64) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(8);
    w.put_f64(v);
    w.into_vec()
}

pub fn decode_scalar(p: &[u8]) -> Result<f64> {
    ByteReader::new(p).get_f64()
}

pub fn encode_register(client_id: u32, d: u32) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(8);
    w.put_u32(client_id);
    w.put_u32(d);
    w.into_vec()
}

pub fn decode_register(p: &[u8]) -> Result<(u32, u32)> {
    let mut r = ByteReader::new(p);
    Ok((r.get_u32()?, r.get_u32()?))
}

fn put_compressed(w: &mut ByteWriter, c: &Compressed) {
    w.put_u32(c.n);
    match &c.payload {
        IndexPayload::Explicit(ix) => {
            w.put_u8(0);
            w.put_u32(ix.len() as u32);
            w.put_u32_slice(ix);
        }
        IndexPayload::Seed { seed, k } => {
            w.put_u8(1);
            w.put_u64(*seed);
            w.put_u32(*k);
        }
        IndexPayload::SeqStart { start, k } => {
            w.put_u8(2);
            w.put_u32(*start);
            w.put_u32(*k);
        }
        IndexPayload::Dense => w.put_u8(3),
    }
    w.put_f64(c.scale);
    w.put_u32(c.values.len() as u32);
    match c.encoding {
        ValueEncoding::F64 => {
            w.put_u8(0);
            w.put_f64_slice(&c.values);
        }
        ValueEncoding::Pow2x16 => {
            // The paper's bit-granularity Natural payload: 16 bits per
            // coordinate (sign + exponent of a pure power of two).
            w.put_u8(1);
            for &v in &c.values {
                let p = pack16(v);
                w.put_u8(p as u8);
                w.put_u8((p >> 8) as u8);
            }
        }
    }
}

fn get_compressed(r: &mut ByteReader) -> Result<Compressed> {
    let n = r.get_u32()?;
    let payload = match r.get_u8()? {
        0 => {
            let k = r.get_u32()? as usize;
            IndexPayload::Explicit(r.get_u32_vec(k)?)
        }
        1 => IndexPayload::Seed { seed: r.get_u64()?, k: r.get_u32()? },
        2 => IndexPayload::SeqStart { start: r.get_u32()?, k: r.get_u32()? },
        3 => IndexPayload::Dense,
        t => anyhow::bail!("bad payload tag {t}"),
    };
    let scale = r.get_f64()?;
    let nv = r.get_u32()? as usize;
    let (values, encoding) = match r.get_u8()? {
        0 => (r.get_f64_vec(nv)?, ValueEncoding::F64),
        1 => {
            let mut vs = Vec::with_capacity(nv);
            for _ in 0..nv {
                let lo = r.get_u8()? as u16;
                let hi = r.get_u8()? as u16;
                vs.push(unpack16(lo | (hi << 8)));
            }
            (vs, ValueEncoding::Pow2x16)
        }
        t => anyhow::bail!("bad value encoding {t}"),
    };
    Ok(Compressed { payload, values, scale, encoding, n })
}

pub fn encode_client_msg(m: &ClientMsg) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(m.grad.len() * 8 + 64);
    w.put_u32(m.client_id as u32);
    w.put_u32(m.grad.len() as u32);
    w.put_f64_slice(&m.grad);
    w.put_f64(m.l_i);
    match m.loss {
        Some(l) => {
            w.put_u8(1);
            w.put_f64(l);
        }
        None => w.put_u8(0),
    }
    put_compressed(&mut w, &m.update);
    w.into_vec()
}

pub fn decode_client_msg(p: &[u8]) -> Result<ClientMsg> {
    let mut r = ByteReader::new(p);
    let client_id = r.get_u32()? as usize;
    let d = r.get_u32()? as usize;
    let grad = r.get_f64_vec(d)?;
    let l_i = r.get_f64()?;
    let loss = if r.get_u8()? != 0 { Some(r.get_f64()?) } else { None };
    let update = get_compressed(&mut r)?;
    Ok(ClientMsg { client_id, grad, update, l_i, loss })
}

pub fn encode_loss_grad(loss: f64, g: &[f64]) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(g.len() * 8 + 12);
    w.put_f64(loss);
    w.put_u32(g.len() as u32);
    w.put_f64_slice(g);
    w.into_vec()
}

pub fn decode_loss_grad(p: &[u8]) -> Result<(f64, Vec<f64>)> {
    let mut r = ByteReader::new(p);
    let loss = r.get_f64()?;
    let n = r.get_u32()? as usize;
    Ok((loss, r.get_f64_vec(n)?))
}

/// FedNL-PP participant message.
pub fn encode_pp_msg(
    client_id: u32,
    update: &Compressed,
    dl: f64,
    dg: &[f64],
) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(dg.len() * 8 + 64);
    w.put_u32(client_id);
    w.put_f64(dl);
    w.put_u32(dg.len() as u32);
    w.put_f64_slice(dg);
    put_compressed(&mut w, update);
    w.into_vec()
}

pub fn decode_pp_msg(p: &[u8]) -> Result<(u32, Compressed, f64, Vec<f64>)> {
    let mut r = ByteReader::new(p);
    let id = r.get_u32()?;
    let dl = r.get_f64()?;
    let d = r.get_u32()? as usize;
    let dg = r.get_f64_vec(d)?;
    let update = get_compressed(&mut r)?;
    Ok((id, update, dl, dg))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_roundtrip() {
        let x = vec![1.0, -2.5, 3.25];
        let enc = encode_round(&x, 42, true);
        let (x2, round, need_loss) = decode_round(&enc).unwrap();
        assert_eq!(x2, x);
        assert_eq!(round, 42);
        assert!(need_loss);
    }

    #[test]
    fn client_msg_roundtrip_all_payloads() {
        let payloads = vec![
            IndexPayload::Explicit(vec![0, 5, 9]),
            IndexPayload::Seed { seed: 0xDEAD, k: 3 },
            IndexPayload::SeqStart { start: 7, k: 3 },
            IndexPayload::Dense,
        ];
        for p in payloads {
            let values = match &p {
                IndexPayload::Dense => vec![1.0; 10],
                _ => vec![1.5, -2.0, 0.0],
            };
            let m = ClientMsg {
                client_id: 3,
                grad: vec![0.5; 4],
                update: Compressed {
                    payload: p.clone(),
                    values,
                    scale: 1.0,
                    encoding: ValueEncoding::F64,
                    n: 10,
                },
                l_i: 2.25,
                loss: Some(-0.75),
            };
            let dec = decode_client_msg(&encode_client_msg(&m)).unwrap();
            assert_eq!(dec.client_id, 3);
            assert_eq!(dec.grad, m.grad);
            assert_eq!(dec.l_i, m.l_i);
            assert_eq!(dec.loss, m.loss);
            assert_eq!(dec.update.payload, m.update.payload);
            assert_eq!(dec.update.values, m.update.values);
            // Critical: reconstructed indices identical on both sides.
            assert_eq!(dec.update.indices(), m.update.indices());
        }
    }

    #[test]
    fn pp_roundtrip() {
        let c = Compressed {
            payload: IndexPayload::Explicit(vec![1, 2]),
            values: vec![0.5, -0.5],
            scale: 1.0,
            encoding: ValueEncoding::F64,
            n: 6,
        };
        let enc = encode_pp_msg(9, &c, -0.125, &[1.0, 2.0]);
        let (id, c2, dl, dg) = decode_pp_msg(&enc).unwrap();
        assert_eq!(id, 9);
        assert_eq!(dl, -0.125);
        assert_eq!(dg, vec![1.0, 2.0]);
        assert_eq!(c2.values, c.values);
    }

    #[test]
    fn pow2x16_wire_roundtrip_bitexact() {
        // Natural's 16-bit payload must reconstruct the exact powers of
        // two (and the scale travels separately).
        let values = vec![2.0, -0.5, 1024.0, 0.0, 2.0f64.powi(-300)];
        let m = ClientMsg {
            client_id: 1,
            grad: vec![0.0; 3],
            update: Compressed {
                payload: IndexPayload::Dense,
                values: values.clone(),
                scale: 8.0 / 9.0,
                encoding: ValueEncoding::Pow2x16,
                n: 5,
            },
            l_i: 0.0,
            loss: None,
        };
        let dec = decode_client_msg(&encode_client_msg(&m)).unwrap();
        assert_eq!(dec.update.values, values);
        assert_eq!(dec.update.scale, 8.0 / 9.0);
        assert_eq!(dec.update.encoding, ValueEncoding::Pow2x16);
    }

    #[test]
    fn corrupt_rejected() {
        assert!(decode_client_msg(&[1, 2, 3]).is_err());
        assert!(decode_round(&[]).is_err());
    }
}

//! L2-regularized logistic regression oracle (Eq. 2-5) — the paper's
//! benchmark objective, with every §5 oracle optimization:
//!
//! * margins `z_j = rowⱼ·x` computed once per point and reused by loss,
//!   gradient and Hessian (§5.7, ×1.50);
//! * sigmoids evaluated once; `σ(-z)` and `σ(z)σ(-z)` derived from the
//!   same value (§5.7);
//! * Hessian accumulated as a sum of symmetric rank-1 matrices on the
//!   upper triangle, 4 samples per sweep, symmetrized once (§5.10,
//!   ×3.07);
//! * labels absorbed into the data rows, no label vector (§5.13);
//! * all buffers owned by the oracle and reused — zero allocation per
//!   evaluation (§5.13);
//! * margin dots, the gradient AXPY sweep, the s·(1−s) weight scan and
//!   the rank-1 Hessian accumulate all run on the runtime-dispatched
//!   SIMD kernels in [`crate::linalg::simd`] (§5.4).

use super::{softplus, Oracle};
use crate::data::ClientShard;
use crate::linalg::{simd, vector, Mat};

/// Logistic-regression local oracle over one client shard.
#[derive(Debug, Clone)]
pub struct LogisticOracle {
    /// (n_i × d) rows = samples with labels/intercept absorbed.
    at: Mat,
    lam: f64,
    inv_n: f64,
    // Reused buffers (margins z, sigmoid σ(-z)).
    z: Vec<f64>,
    sig_neg: Vec<f64>,
    hw: Vec<f64>,
}

impl LogisticOracle {
    pub fn new(shard: ClientShard, lam: f64) -> Self {
        let n_i = shard.n_i();
        Self {
            at: shard.at,
            lam,
            inv_n: 1.0 / n_i as f64,
            z: vec![0.0; n_i],
            sig_neg: vec![0.0; n_i],
            hw: vec![0.0; n_i],
        }
    }

    /// Construct from a raw dense (n_i × d) matrix.
    pub fn from_matrix(at: Mat, lam: f64) -> Self {
        Self::new(ClientShard { client_id: 0, at }, lam)
    }

    pub fn n_i(&self) -> usize {
        self.at.rows()
    }

    pub fn lam(&self) -> f64 {
        self.lam
    }

    /// Stage 1: margins + sigmoids at `x` (shared by everything below).
    /// The margin dot products run on the dispatched SIMD kernel, then
    /// one vectorized [`simd::sigmoid_neg_scan`] evaluates every σ(−z)
    /// (§5.7) — the polynomial exp with the tested ulp budget, or libm
    /// under `FEDNL_EXACT_EXP=1`.
    fn compute_margins(&mut self, x: &[f64]) {
        for j in 0..self.at.rows() {
            self.z[j] = simd::dot(self.at.row(j), x);
        }
        simd::sigmoid_neg_scan(&self.z, &mut self.sig_neg);
    }

    fn loss_from_margins(&self, x: &[f64]) -> f64 {
        let mut s = 0.0;
        for &zj in &self.z {
            s += softplus(-zj);
        }
        s * self.inv_n + 0.5 * self.lam * vector::norm2_sq(x)
    }

    fn grad_from_margins(&mut self, x: &[f64], g: &mut [f64]) {
        // g = Σ_j (−σ(−z_j)/n) · rowⱼ + λx, accumulated via AXPY over
        // contiguous rows.
        vector::fill_zero(g);
        for j in 0..self.at.rows() {
            let c = -self.inv_n * self.sig_neg[j];
            vector::axpy(c, self.at.row(j), g);
        }
        vector::axpy(self.lam, x, g);
    }

    fn hessian_from_margins(&mut self, h: &mut Mat) {
        debug_assert_eq!(h.rows(), self.dim());
        // Hessian weights h_j = σ(z)σ(−z)/n from the cached sigmoids —
        // a vectorized s·(1−s) scan, no second transcendental (§5.7).
        simd::sigmoid_variance_scan(&self.sig_neg, self.inv_n, &mut self.hw);
        h.fill_zero();
        let rows: Vec<&[f64]> =
            (0..self.at.rows()).map(|j| self.at.row(j)).collect();
        // Intra-client threading of the accumulate (§5.10 / ROADMAP):
        // off by default (1 thread); bit-identical at any setting.
        h.sym_rank1_block_upper_mt(&rows, &self.hw, simd::intra_threads());
        h.symmetrize_from_upper();
        h.add_diag(self.lam);
    }
}

impl Oracle for LogisticOracle {
    fn dim(&self) -> usize {
        self.at.cols()
    }

    fn loss(&mut self, x: &[f64]) -> f64 {
        self.compute_margins(x);
        self.loss_from_margins(x)
    }

    fn loss_grad(&mut self, x: &[f64], g: &mut [f64]) -> f64 {
        self.compute_margins(x);
        self.grad_from_margins(x, g);
        self.loss_from_margins(x)
    }

    fn loss_grad_hessian(
        &mut self,
        x: &[f64],
        g: &mut [f64],
        h: &mut Mat,
    ) -> f64 {
        self.compute_margins(x);
        self.grad_from_margins(x, g);
        self.hessian_from_margins(h);
        self.loss_from_margins(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::numerics::{check_grad, check_hessian};
    use crate::rng::{Pcg64, Rng};

    fn toy_oracle(d: usize, n: usize, seed: u64) -> LogisticOracle {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut at = Mat::zeros(n, d);
        for r in 0..n {
            let lab = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            for c in 0..d - 1 {
                at.set(r, c, lab * rng.next_gaussian());
            }
            at.set(r, d - 1, lab);
        }
        LogisticOracle::from_matrix(at, 1e-3)
    }

    #[test]
    fn loss_at_zero_is_log2() {
        let mut o = toy_oracle(5, 20, 1);
        let x = vec![0.0; 5];
        assert!((o.loss(&x) - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let mut o = toy_oracle(6, 30, 2);
        let mut rng = Pcg64::seed_from_u64(3);
        let x: Vec<f64> = (0..6).map(|_| rng.next_gaussian() * 0.3).collect();
        let err = check_grad(&mut o, &x);
        assert!(err < 1e-6, "grad FD error {err}");
    }

    #[test]
    fn hessian_matches_finite_difference() {
        let mut o = toy_oracle(5, 25, 4);
        let mut rng = Pcg64::seed_from_u64(5);
        let x: Vec<f64> = (0..5).map(|_| rng.next_gaussian() * 0.3).collect();
        let err = check_hessian(&mut o, &x);
        assert!(err < 1e-5, "hessian FD error {err}");
    }

    #[test]
    fn hessian_is_spd_with_regularizer() {
        let mut o = toy_oracle(8, 40, 6);
        let x = vec![0.1; 8];
        let mut g = vec![0.0; 8];
        let mut h = Mat::zeros(8, 8);
        o.loss_grad_hessian(&x, &mut g, &mut h);
        assert!(h.is_symmetric(1e-14));
        assert!(crate::linalg::Cholesky::factor(&h, 0.0).is_some());
    }

    #[test]
    fn fused_equals_separate() {
        let mut o = toy_oracle(7, 35, 7);
        let x = vec![0.05; 7];
        let mut g1 = vec![0.0; 7];
        let mut g2 = vec![0.0; 7];
        let mut h = Mat::zeros(7, 7);
        let l1 = o.loss_grad_hessian(&x, &mut g1, &mut h);
        let l2 = o.loss_grad(&x, &mut g2);
        let l3 = o.loss(&x);
        assert!((l1 - l2).abs() < 1e-15 && (l2 - l3).abs() < 1e-15);
        assert_eq!(g1, g2);
    }

    #[test]
    fn strong_convexity_from_lambda() {
        // xᵀ∇²f x ≥ λ‖x‖² for any direction.
        let mut o = toy_oracle(6, 30, 8);
        let mut h = Mat::zeros(6, 6);
        o.hessian(&[0.2; 6], &mut h);
        let mut rng = Pcg64::seed_from_u64(9);
        for _ in 0..20 {
            let v: Vec<f64> = (0..6).map(|_| rng.next_gaussian()).collect();
            let mut hv = vec![0.0; 6];
            h.matvec(&v, &mut hv);
            let quad = vector::dot(&v, &hv);
            assert!(quad >= 1e-3 * vector::norm2_sq(&v) - 1e-12);
        }
    }
}

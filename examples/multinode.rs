//! Multi-node FedNL over real TCP (loopback): one master + 6 clients,
//! each client running the exact binary-grade client loop
//! (`net::client::run_client`) in its own thread — byte-for-byte the
//! protocol used across machines (paper §7, §9.3).
//!
//!     cargo run --release --example multinode

use fednl::algorithms::{run_fednl_pool, ClientState, Options};
use fednl::compressors::by_name;
use fednl::coordinator::ClientPool;
use fednl::data::{generate_synthetic, Dataset, LibsvmSample, SynthSpec};
use fednl::net::client::ClientMode;
use fednl::net::run_client;
use fednl::net::server::Bound;
use fednl::oracle::LogisticOracle;

fn main() -> anyhow::Result<()> {
    const N: usize = 6;
    let spec = SynthSpec::preset("phishing").unwrap();
    let synth = generate_synthetic(&spec);
    let samples: Vec<LibsvmSample> = synth
        .labels
        .iter()
        .zip(&synth.rows)
        .map(|(l, r)| LibsvmSample { label: *l, features: r.clone() })
        .collect();
    let mut ds = Dataset::from_libsvm(&samples, spec.d_raw);
    ds.reshuffle(1);
    let d = ds.d;

    // Master binds an ephemeral port; clients connect with retry.
    let bound = Bound::bind("127.0.0.1:0")?;
    let addr = bound.local_addr()?.to_string();
    let mut handles = Vec::new();
    for shard in ds.split_even(N)? {
        let addr = addr.clone();
        let comp = by_name("randseqk", d, 8, shard.client_id as u64)?;
        handles.push(std::thread::spawn(move || {
            let id = shard.client_id;
            let oracle = Box::new(LogisticOracle::new(shard, 1e-3));
            let state = ClientState::new(id, oracle, comp, None);
            run_client(&addr, id, ClientMode::FedNL(state))
        }));
    }

    let mut pool = bound.accept(N)?;
    println!("master: {} clients registered over TCP", pool.n_clients());
    let opts =
        Options { rounds: 200, tol_grad: Some(1e-9), ..Default::default() };
    let trace =
        run_fednl_pool(&mut pool, &opts, vec![0.0; d], "FedNL/RandSeqK/tcp");
    let (up, down) = pool.transport_bytes().unwrap();
    pool.shutdown();
    for h in handles {
        h.join().unwrap()?;
    }
    println!(
        "converged to ||grad|| = {:.3e} in {} rounds; wire: {} up / {} down",
        trace.last_grad_norm(),
        trace.records.len(),
        fednl::utils::human_bytes(up),
        fednl::utils::human_bytes(down)
    );
    assert!(trace.last_grad_norm() < 1e-8);
    Ok(())
}

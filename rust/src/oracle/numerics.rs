//! Finite-difference verification of analytic oracles (paper component
//! `numerics`: "tools for numerically verifying the correctness of the
//! ∇²fᵢ(x) and ∇fᵢ(x) oracles").
//!
//! Central differences: O(ε²)-accurate, step ε = cbrt(machine-ε)·scale.

use super::Oracle;
use crate::linalg::Mat;

fn step_for(x: &[f64]) -> f64 {
    let scale = x.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    (f64::EPSILON).cbrt() * scale
}

/// Max abs error between the analytic gradient and a central-difference
/// estimate of ∂f/∂xᵢ at `x`.
pub fn check_grad(oracle: &mut dyn Oracle, x: &[f64]) -> f64 {
    let d = oracle.dim();
    assert_eq!(x.len(), d);
    let eps = step_for(x);
    let mut g = vec![0.0; d];
    oracle.grad(x, &mut g);
    let mut xp = x.to_vec();
    let mut worst = 0.0f64;
    for i in 0..d {
        xp[i] = x[i] + eps;
        let fp = oracle.loss(&xp);
        xp[i] = x[i] - eps;
        let fm = oracle.loss(&xp);
        xp[i] = x[i];
        let fd = (fp - fm) / (2.0 * eps);
        worst = worst.max((fd - g[i]).abs());
    }
    worst
}

/// Max abs error between the analytic Hessian and a central-difference
/// estimate of ∂²f/∂xᵢ∂xⱼ built from gradient evaluations.
pub fn check_hessian(oracle: &mut dyn Oracle, x: &[f64]) -> f64 {
    let d = oracle.dim();
    assert_eq!(x.len(), d);
    let eps = step_for(x).sqrt().max(1e-5);
    let mut h = Mat::zeros(d, d);
    oracle.hessian(x, &mut h);

    let mut gp = vec![0.0; d];
    let mut gm = vec![0.0; d];
    let mut xp = x.to_vec();
    let mut worst = 0.0f64;
    for i in 0..d {
        xp[i] = x[i] + eps;
        oracle.grad(&xp, &mut gp);
        xp[i] = x[i] - eps;
        oracle.grad(&xp, &mut gm);
        xp[i] = x[i];
        for j in 0..d {
            let fd = (gp[j] - gm[j]) / (2.0 * eps);
            worst = worst.max((fd - h.get(i, j)).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vector;

    /// Deliberately wrong oracle to prove the checks actually detect
    /// errors (a verification tool that never fails verifies nothing).
    struct BrokenOracle;

    impl Oracle for BrokenOracle {
        fn dim(&self) -> usize {
            2
        }
        fn loss(&mut self, x: &[f64]) -> f64 {
            vector::norm2_sq(x)
        }
        fn loss_grad(&mut self, x: &[f64], g: &mut [f64]) -> f64 {
            // WRONG: gradient of ‖x‖² is 2x, we return x.
            g.copy_from_slice(x);
            vector::norm2_sq(x)
        }
        fn loss_grad_hessian(
            &mut self,
            x: &[f64],
            g: &mut [f64],
            h: &mut Mat,
        ) -> f64 {
            let l = self.loss_grad(x, g);
            // WRONG: Hessian is 2I, we return 5I.
            *h = Mat::identity_scaled(2, 5.0);
            l
        }
    }

    #[test]
    fn detects_wrong_gradient() {
        let mut o = BrokenOracle;
        assert!(check_grad(&mut o, &[1.0, -2.0]) > 0.5);
    }

    #[test]
    fn detects_wrong_hessian() {
        let mut o = BrokenOracle;
        assert!(check_hessian(&mut o, &[1.0, -2.0]) > 1.0);
    }
}

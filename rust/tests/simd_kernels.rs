//! SIMD kernel layer verification (tier-1):
//!
//! 1. **Equivalence**: every runtime-dispatched kernel must match its
//!    portable scalar fallback within an n·ε-scaled tolerance (the two
//!    paths may reassociate reductions, nothing more), across the edge
//!    lengths 0, 1, 3, 4, 7, 64, 1000 that exercise empty inputs, pure
//!    tails, exact lane multiples and long streams.
//! 2. **Determinism**: two identical FedNL runs must produce
//!    bit-identical trajectories — the dispatch decision is fixed per
//!    process and every kernel reduces in a fixed order.

use fednl::algorithms::{run_fednl, ClientState, Options};
use fednl::compressors::by_name;
use fednl::coordinator::{ClientPool, ThreadedPool};
use fednl::data::{generate_synthetic, Dataset, LibsvmSample, SynthSpec};
use fednl::linalg::simd::{self, scalar};
use fednl::oracle::LogisticOracle;
use fednl::rng::{Pcg64, Rng};

const LENS: [usize; 7] = [0, 1, 3, 4, 7, 64, 1000];

fn rvec(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::seed_from_u64(seed);
    (0..n).map(|_| rng.next_gaussian()).collect()
}

/// Tolerance for comparing two summation orders of ~n terms with total
/// absolute mass `mag`: a few n·ε, plus a denormal floor for n = 0.
fn sum_tol(mag: f64, n: usize) -> f64 {
    4.0 * (n as f64 + 1.0) * f64::EPSILON * mag + 1e-300
}

#[test]
fn prop_dot_matches_scalar() {
    for &n in &LENS {
        let a = rvec(n, 1000 + n as u64);
        let b = rvec(n, 2000 + n as u64);
        let got = simd::dot(&a, &b);
        let want = scalar::dot(&a, &b);
        let mag: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
        assert!(
            (got - want).abs() <= sum_tol(mag, n),
            "dot n={n}: {got} vs {want}"
        );
    }
}

#[test]
fn prop_axpy_matches_scalar() {
    for &n in &LENS {
        let x = rvec(n, 3000 + n as u64);
        let mut y1 = rvec(n, 4000 + n as u64);
        let mut y2 = y1.clone();
        simd::axpy(-0.7312, &x, &mut y1);
        scalar::axpy(-0.7312, &x, &mut y2);
        for i in 0..n {
            // Elementwise: one FMA vs one mul+add — ≤ 1 ULP apart.
            let m = y2[i].abs().max((0.7312 * x[i]).abs());
            assert!(
                (y1[i] - y2[i]).abs() <= 4.0 * f64::EPSILON * m + 1e-300,
                "axpy n={n} i={i}: {} vs {}",
                y1[i],
                y2[i]
            );
        }
    }
}

#[test]
fn prop_norm2_sq_matches_scalar() {
    for &n in &LENS {
        let x = rvec(n, 5000 + n as u64);
        let got = simd::norm2_sq(&x);
        let want = scalar::dot(&x, &x);
        assert!(
            (got - want).abs() <= sum_tol(want, n),
            "norm2_sq n={n}: {got} vs {want}"
        );
    }
}

#[test]
fn prop_add_scaled_matches_scalar() {
    for &n in &LENS {
        let a = rvec(n, 6000 + n as u64);
        let b = rvec(n, 7000 + n as u64);
        let mut o1 = vec![0.0; n];
        let mut o2 = vec![0.0; n];
        simd::add_scaled(&a, 1.618, &b, &mut o1);
        scalar::add_scaled(&a, 1.618, &b, &mut o2);
        for i in 0..n {
            let m = o2[i].abs().max(1.0);
            assert!(
                (o1[i] - o2[i]).abs() <= 4.0 * f64::EPSILON * m,
                "add_scaled n={n} i={i}"
            );
        }
    }
}

#[test]
fn prop_abs_max_is_exact() {
    // max has no rounding: the dispatched scan must agree exactly.
    for &n in &LENS {
        let x = rvec(n, 8000 + n as u64);
        assert_eq!(simd::abs_max(&x), scalar::abs_max(&x), "abs_max n={n}");
    }
}

#[test]
fn prop_energy_and_weighted_norm_match_scalar() {
    for &n in &LENS {
        let v = rvec(n, 9000 + n as u64);
        let w: Vec<f64> =
            (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 2.0 }).collect();
        let mut e1 = vec![0.0; n];
        let mut e2 = vec![0.0; n];
        simd::energy_scan(&w, &v, &mut e1);
        scalar::energy_scan(&w, &v, &mut e2);
        for i in 0..n {
            assert!(
                (e1[i] - e2[i]).abs() <= 4.0 * f64::EPSILON * e2[i].abs(),
                "energy_scan n={n} i={i}"
            );
        }
        let got = simd::weighted_norm2_sq(&w, &v);
        let want = scalar::weighted_norm2_sq(&w, &v);
        assert!(
            (got - want).abs() <= sum_tol(want, n),
            "weighted_norm2_sq n={n}: {got} vs {want}"
        );
    }
}

#[test]
fn prop_sigmoid_variance_scan_matches_scalar() {
    for &n in &LENS {
        let mut rng = Pcg64::seed_from_u64(123 + n as u64);
        let s: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mut o1 = vec![0.0; n];
        let mut o2 = vec![0.0; n];
        simd::sigmoid_variance_scan(&s, 0.0125, &mut o1);
        scalar::sigmoid_variance_scan(&s, 0.0125, &mut o2);
        for i in 0..n {
            assert!(
                (o1[i] - o2[i]).abs() <= 4.0 * f64::EPSILON * o2[i].abs(),
                "sigmoid_variance_scan n={n} i={i}"
            );
        }
    }
}

#[test]
fn prop_binned_accumulate_dispatched_equals_scalar_exactly() {
    // Unlike the float kernels, the superaccumulate kernel is integer
    // exact: the dispatched path must match the scalar fallback (and
    // the one-at-a-time reference) BIT for bit — no tolerance, at
    // every edge length including lane tails and specials.
    use fednl::linalg::reduce::RepAcc;
    for &n in &LENS {
        let mut xs = rvec(n, 7000 + n as u64);
        // Sprinkle magnitude extremes into the longer cases.
        if n >= 7 {
            xs[1] = 1e300;
            xs[3] = -1e300;
            xs[5] = 5e-324;
        }
        let mut one = RepAcc::new();
        for &x in &xs {
            one.accumulate(x);
        }
        let mut disp = RepAcc::new();
        disp.accumulate_slice(&xs);
        let mut sc = RepAcc::new();
        sc.accumulate_slice_scalar(&xs);
        let want = one.round().to_bits();
        assert_eq!(disp.round().to_bits(), want, "n={n} dispatched");
        assert_eq!(sc.round().to_bits(), want, "n={n} scalar");
    }
    // Specials survive the lane path identically.
    let xs = vec![1.0, f64::INFINITY, 2.0, f64::NAN, -1.0, 0.5, 3.0, 4.0];
    let mut disp = RepAcc::new();
    disp.accumulate_slice(&xs);
    let mut sc = RepAcc::new();
    sc.accumulate_slice_scalar(&xs);
    assert!(disp.round().is_nan());
    assert!(sc.round().is_nan());
}

#[test]
fn prop_sym_rank1_matches_scalar_odd_shapes() {
    // Odd d exercises every vector-tail length; odd sample counts
    // exercise the 4-sample blocking tail.
    for &d in &[1usize, 2, 3, 4, 5, 7, 8, 13, 31] {
        for &ns in &[0usize, 1, 3, 4, 5, 8, 11] {
            let rows: Vec<Vec<f64>> = (0..ns)
                .map(|i| rvec(d, 77 + (d * 100 + i) as u64))
                .collect();
            let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
            let h = rvec(ns, 31337 + d as u64);
            let mut m1 = vec![0.0; d * d];
            let mut m2 = vec![0.0; d * d];
            simd::sym_rank1_upper(&mut m1, d, &refs, &h);
            scalar::sym_rank1_upper(&mut m2, d, &refs, &h);
            for i in 0..d * d {
                let (u, v) = (i / d, i % d);
                let mag: f64 = (0..ns)
                    .map(|s| (h[s] * rows[s][u] * rows[s][v]).abs())
                    .sum();
                assert!(
                    (m1[i] - m2[i]).abs() <= sum_tol(mag, ns),
                    "sym_rank1 d={d} ns={ns} ({u},{v}): {} vs {}",
                    m1[i],
                    m2[i]
                );
            }
        }
    }
}

#[test]
fn threaded_rank1_bit_identical_for_any_thread_count() {
    // The intra-client threading (row-block partition over the upper
    // triangle) must not change a single bit: each entry is written by
    // exactly one thread with the same per-sample accumulation order
    // as the single-threaded kernel.
    for &d in &[3usize, 32, 37, 64, 301] {
        let ns = 13;
        let rows: Vec<Vec<f64>> =
            (0..ns).map(|i| rvec(d, 900 + (d * 10 + i) as u64)).collect();
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let h = rvec(ns, 4242 + d as u64);
        let mut m_ref = vec![0.0; d * d];
        simd::sym_rank1_upper(&mut m_ref, d, &refs, &h);
        for threads in [1usize, 2, 3, 4, 8, 64] {
            let mut m_t = vec![0.0; d * d];
            simd::sym_rank1_upper_threaded(&mut m_t, d, &refs, &h, threads);
            assert_eq!(
                m_ref, m_t,
                "threaded rank-1 differs at d={d}, threads={threads}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Pinned-tier ISA matrix: the AVX-512 backend must be bit-identical
// to AVX2 for every kernel (its accumulators are lane-concatenations
// of AVX2's and its reductions finish with the AVX2 combine tree —
// the module-doc contract), and the limb scatter must be
// limb-identical across all three tiers. Hosts or builds without a
// tier skip with a note rather than fail: the CI forced-ISA matrix
// legs pick the coverage up where the tier exists.
// ---------------------------------------------------------------------

/// Skip helper: `false` (with a stderr note) when `which` is missing.
fn tier_or_skip(which: simd::Isa, test: &str) -> bool {
    if simd::isa_available(which) {
        return true;
    }
    eprintln!("{test}: skipping, {} tier unavailable here", which.name());
    false
}

#[test]
fn prop_avx512_bitwise_equals_avx2_on_every_kernel() {
    if !tier_or_skip(simd::Isa::Avx512, "avx512-vs-avx2") {
        return;
    }
    let (lo, hi) = (simd::Isa::Avx2, simd::Isa::Avx512);
    for &n in &LENS {
        let a = rvec(n, 11_000 + n as u64);
        let b = rvec(n, 12_000 + n as u64);
        let w: Vec<f64> = (0..n).map(|i| 1.0 + (i % 5) as f64).collect();

        assert_eq!(
            simd::dot_on(lo, &a, &b).to_bits(),
            simd::dot_on(hi, &a, &b).to_bits(),
            "dot n={n}"
        );
        assert_eq!(
            simd::abs_max_on(lo, &a).to_bits(),
            simd::abs_max_on(hi, &a).to_bits(),
            "abs_max n={n}"
        );
        assert_eq!(
            simd::weighted_norm2_sq_on(lo, &w, &a).to_bits(),
            simd::weighted_norm2_sq_on(hi, &w, &a).to_bits(),
            "weighted_norm2_sq n={n}"
        );

        let mut y1 = b.clone();
        let mut y2 = b.clone();
        simd::axpy_on(lo, -0.7312, &a, &mut y1);
        simd::axpy_on(hi, -0.7312, &a, &mut y2);
        let mut o1 = vec![0.0; n];
        let mut o2 = vec![0.0; n];
        simd::add_scaled_on(lo, &a, 1.618, &b, &mut o1);
        simd::add_scaled_on(hi, &a, 1.618, &b, &mut o2);
        let mut e1 = vec![0.0; n];
        let mut e2 = vec![0.0; n];
        simd::energy_scan_on(lo, &w, &a, &mut e1);
        simd::energy_scan_on(hi, &w, &a, &mut e2);
        let mut v1 = vec![0.0; n];
        let mut v2 = vec![0.0; n];
        simd::sigmoid_variance_scan_on(lo, &w, 0.0125, &mut v1);
        simd::sigmoid_variance_scan_on(hi, &w, 0.0125, &mut v2);
        for i in 0..n {
            assert_eq!(y1[i].to_bits(), y2[i].to_bits(), "axpy n={n} i={i}");
            assert_eq!(
                o1[i].to_bits(),
                o2[i].to_bits(),
                "add_scaled n={n} i={i}"
            );
            assert_eq!(
                e1[i].to_bits(),
                e2[i].to_bits(),
                "energy_scan n={n} i={i}"
            );
            assert_eq!(
                v1[i].to_bits(),
                v2[i].to_bits(),
                "sigmoid_variance_scan n={n} i={i}"
            );
        }
    }
    // Rank-1 Hessian accumulate across vector-tail widths.
    for &d in &[1usize, 3, 7, 8, 13, 31] {
        let ns = 5;
        let rows: Vec<Vec<f64>> = (0..ns)
            .map(|i| rvec(d, 13_000 + (d * 10 + i) as u64))
            .collect();
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let h = rvec(ns, 14_000 + d as u64);
        let mut m1 = vec![0.0; d * d];
        let mut m2 = vec![0.0; d * d];
        simd::sym_rank1_upper_on(lo, &mut m1, d, &refs, &h);
        simd::sym_rank1_upper_on(hi, &mut m2, d, &refs, &h);
        for i in 0..d * d {
            assert_eq!(
                m1[i].to_bits(),
                m2[i].to_bits(),
                "sym_rank1_upper d={d} i={i}"
            );
        }
    }
}

#[test]
fn prop_limb_scatter_is_limb_identical_across_all_tiers() {
    // The superaccumulate scatter is integer-exact: every available
    // tier must produce the exact same limb array and specials flag,
    // including at magnitude extremes and denormals.
    use fednl::linalg::reduce::LIMBS;
    for &n in &LENS {
        let mut xs = rvec(n, 15_000 + n as u64);
        if n >= 7 {
            xs[0] = 1e300;
            xs[2] = -1e300;
            xs[4] = 5e-324;
            xs[6] = -0.0;
        }
        let mut want: Option<([i64; LIMBS], u8)> = None;
        for which in simd::Isa::ALL {
            if !simd::isa_available(which) {
                eprintln!(
                    "limb-identity: skipping {} tier (unavailable)",
                    which.name()
                );
                continue;
            }
            let mut limbs = [0i64; LIMBS];
            let flags = simd::binned_accumulate_on(which, &mut limbs, &xs);
            match &want {
                None => want = Some((limbs, flags)),
                Some((wl, wf)) => {
                    assert_eq!(
                        &limbs,
                        wl,
                        "{} limbs diverge at n={n}",
                        which.name()
                    );
                    assert_eq!(
                        flags,
                        *wf,
                        "{} specials flag diverges at n={n}",
                        which.name()
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Vectorized polynomial sigmoid: accuracy budget and cross-tier
// bit-identity (the raw-speed rung's accuracy contract).
// ---------------------------------------------------------------------

/// ULP distance between two same-signed finite doubles (σ ∈ [0, 1], so
/// the monotone bits-as-integer trick applies directly).
fn ulp_dist(a: f64, b: f64) -> u64 {
    (a.to_bits() as i64 - b.to_bits() as i64).unsigned_abs()
}

#[test]
fn sigmoid_poly_accuracy_budget_vs_libm() {
    // ≤ 3 ulp on the dense working range, ≤ 4 ulp over the full
    // range, against the libm reference (`sigmoid_exact`) that
    // `FEDNL_EXACT_EXP=1` restores. The scalar tier IS the polynomial
    // reference (the vector tiers reproduce it bit for bit below), so
    // the budget is asserted on it — no SIMD hardware required.
    let mut z = Vec::new();
    let steps = 160_000;
    for i in 0..=steps {
        z.push(-40.0 + 80.0 * i as f64 / steps as f64);
    }
    let mut out = vec![0.0; z.len()];
    simd::sigmoid_neg_scan_on(simd::Isa::Scalar, &z, &mut out);
    for (zi, oi) in z.iter().zip(&out) {
        let want = simd::sigmoid_exact(-zi);
        assert!(
            ulp_dist(*oi, want) <= 3,
            "sigmoid poly off by {} ulp at z={zi}: {oi} vs {want}",
            ulp_dist(*oi, want)
        );
    }
    // Full range (log-spaced magnitudes out to the saturation cliff).
    let mut z = vec![0.0, -0.0];
    let mut m = 1e-300f64;
    while m < 745.0 {
        z.push(m);
        z.push(-m);
        m *= 1.37;
    }
    let mut out = vec![0.0; z.len()];
    simd::sigmoid_neg_scan_on(simd::Isa::Scalar, &z, &mut out);
    for (zi, oi) in z.iter().zip(&out) {
        let want = simd::sigmoid_exact(-zi);
        assert!(
            ulp_dist(*oi, want) <= 4,
            "sigmoid poly off by {} ulp at z={zi}: {oi} vs {want}",
            ulp_dist(*oi, want)
        );
    }
    // Exact saturation and the exact midpoint.
    let z = [746.0, 800.0, f64::INFINITY, -746.0, -800.0,
        f64::NEG_INFINITY, 0.0, -0.0];
    let mut out = vec![0.0; z.len()];
    simd::sigmoid_neg_scan_on(simd::Isa::Scalar, &z, &mut out);
    // out = σ(−z): big positive z saturates to 0, big negative to 1.
    assert_eq!(out[0].to_bits(), 0.0f64.to_bits());
    assert_eq!(out[1].to_bits(), 0.0f64.to_bits());
    assert_eq!(out[2].to_bits(), 0.0f64.to_bits());
    assert_eq!(out[3].to_bits(), 1.0f64.to_bits());
    assert_eq!(out[4].to_bits(), 1.0f64.to_bits());
    assert_eq!(out[5].to_bits(), 1.0f64.to_bits());
    assert_eq!(out[6].to_bits(), 0.5f64.to_bits());
    assert_eq!(out[7].to_bits(), 0.5f64.to_bits());
}

#[test]
fn sigmoid_poly_is_bit_identical_across_tiers() {
    // Elementwise polynomial with an identical operation sequence per
    // lane: every available tier must agree with the scalar reference
    // bit for bit, at every edge length.
    for &n in &LENS {
        let mut z = rvec(n, 16_000 + n as u64);
        for (i, zi) in z.iter_mut().enumerate() {
            *zi *= 1.0 + 30.0 * (i % 3) as f64; // reach the far tails
        }
        let mut want = vec![0.0; n];
        simd::sigmoid_neg_scan_on(simd::Isa::Scalar, &z, &mut want);
        for which in [simd::Isa::Avx2, simd::Isa::Avx512] {
            if !tier_or_skip(which, "sigmoid-poly-identity") {
                continue;
            }
            let mut got = vec![0.0; n];
            simd::sigmoid_neg_scan_on(which, &z, &mut got);
            for i in 0..n {
                assert_eq!(
                    got[i].to_bits(),
                    want[i].to_bits(),
                    "{} sigmoid poly diverges at n={n} i={i} z={}",
                    which.name(),
                    z[i]
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Determinism: identical runs → bit-identical trajectories.
// ---------------------------------------------------------------------

fn make_clients(n: usize, compressor: &str, seed: u64) -> (Vec<ClientState>, usize) {
    let spec = SynthSpec {
        d_raw: 9,
        n_samples: n * 40,
        density: 0.6,
        noise: 1.0,
        label_bias: 0.0,
        seed,
    };
    let synth = generate_synthetic(&spec);
    let samples: Vec<LibsvmSample> = synth
        .labels
        .iter()
        .zip(&synth.rows)
        .map(|(l, r)| LibsvmSample { label: *l, features: r.clone() })
        .collect();
    let ds = Dataset::from_libsvm(&samples, spec.d_raw);
    let d = ds.d;
    let clients = ds
        .split_even(n)
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, sh)| {
            ClientState::new(
                i,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name(compressor, d, 2, seed + i as u64).unwrap(),
                None,
            )
        })
        .collect();
    (clients, d)
}

#[test]
fn threaded_pool_reductions_are_bit_reproducible() {
    // eval_loss / loss_grad collect per-client replies and reduce them
    // in ascending client-id order (the buffer-and-commit rule), so two
    // identical pools must agree bitwise even though reply arrival
    // order differs run to run.
    let (c1, d) = make_clients(7, "topk", 0xAB);
    let (c2, _) = make_clients(7, "topk", 0xAB);
    let mut p1 = ThreadedPool::new(c1, 3);
    let mut p2 = ThreadedPool::new(c2, 3);
    let mut rng = Pcg64::seed_from_u64(99);
    for _ in 0..5 {
        let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian() * 0.2).collect();
        let l1 = p1.eval_loss(&x);
        let l2 = p2.eval_loss(&x);
        assert_eq!(l1.to_bits(), l2.to_bits());
        let (f1, g1) = p1.loss_grad(&x);
        let (f2, g2) = p2.loss_grad(&x);
        assert_eq!(f1.to_bits(), f2.to_bits());
        for (a, b) in g1.iter().zip(&g2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

#[test]
fn fednl_trajectory_is_bit_reproducible() {
    for compressor in ["topk", "toplek", "randseqk", "natural"] {
        let (mut c1, d) = make_clients(5, compressor, 0xD5EED);
        let (mut c2, _) = make_clients(5, compressor, 0xD5EED);
        let opts = Options {
            rounds: 12,
            track_loss: true,
            warm_start: true,
            ..Default::default()
        };
        let t1 = run_fednl(&mut c1, &opts, vec![0.0; d]);
        let t2 = run_fednl(&mut c2, &opts, vec![0.0; d]);
        assert_eq!(t1.records.len(), t2.records.len(), "{compressor}");
        for (r1, r2) in t1.records.iter().zip(&t2.records) {
            assert_eq!(
                r1.grad_norm.to_bits(),
                r2.grad_norm.to_bits(),
                "{compressor} round {}: grad norms diverge",
                r1.round
            );
            assert_eq!(
                r1.loss.to_bits(),
                r2.loss.to_bits(),
                "{compressor} round {}: losses diverge",
                r1.round
            );
        }
    }
}

//! Wire encoding of the FedNL protocol messages (fixed-width LE fields;
//! paper §7 found fixed 32-bit index framing beats variable-width).
//!
//! # Unified tag table
//!
//! Since the streaming-coordination refactor the FedNL and FedNL-PP
//! command sets are **one protocol** — a client's algorithm family is
//! fixed at registration (its `ClientMode`), so the round exchange needs
//! no per-algorithm tags:
//!
//! | dir | tag            | payload                    | reply          |
//! |-----|----------------|----------------------------|----------------|
//! | s2c | `ROUND`      1 | round, need_loss, x        | `MSG`          |
//! | s2c | `EVAL_LOSS`  2 | x                          | `LOSS`         |
//! | s2c | `WARM_START` 3 | x⁰                         | `WARM`         |
//! | s2c | `SET_ALPHA`  5 | α                          | `ACK` (echo α) |
//! | s2c | `SHUTDOWN`   6 | —                          | —              |
//! | s2c | `LOSS_GRAD`  7 | x                          | `GRAD`         |
//! | s2c | `STATE`      8 | —                          | `STATE`        |
//! | c2s | `REGISTER`  10 | client id, d, family       | —              |
//! | c2s | `MSG`       11 | unified [`ClientMsg`]      |                |
//! | c2s | `LOSS`      12 | f64                        |                |
//! | c2s | `WARM`      13 | packed Hᵢ⁰                 |                |
//! | c2s | `ACK`       15 | f64                        |                |
//! | c2s | `GRAD`      16 | (f, ∇f)                    |                |
//! | c2s | `STATE`     17 | (lᵢ, gᵢ)                   |                |
//! | c2s | `DEREGISTER`18 | —                          | —              |
//! | s2c | `ROUND_ACK` 33 | committed round            | —              |
//! | s2c | `RESYNC`    35 | last committed round (opt) | —              |
//! | s2c | `PULL_H`    36 | —                          | `WARM`         |
//!
//! A FedNL client answers `ROUND` with its Alg. 1 message; a PP client
//! answers the *same* tag with its Alg. 3 participation deltas — both
//! travel as the unified [`ClientMsg`] codec. The retired PP-specific
//! tags (`PP_ROUND` = 4, `PP_MSG` = 14) are left unassigned.
//!
//! # Shard tier (master ↔ relay)
//!
//! A relay aggregator (`net::relay`) speaks the table above *downward*
//! to its clients unchanged, and these frames *upward* to the master:
//!
//! | dir | tag                 | payload                         | reply          |
//! |-----|---------------------|---------------------------------|----------------|
//! | s2c | `SHARD_ROUND`    20 | round, need_loss, sum, deadline, x, subset | `SHARD_SUM` or `SHARD_MSG` |
//! | s2c | `SHARD_PREP`     21 | round                           | `SHARD_PREPPED`|
//! | s2c | `SHARD_PULL`     22 | client id                       | `SHARD_PULLED` |
//! | c2s | `SHARD_REGISTER` 23 | shard id, base, count, d, family| —              |
//! | c2s | `SHARD_MSG`      24 | ordered [`ClientMsg`]s + missing|                |
//! | c2s | `SHARD_LOSSES`   25 | per-client (id, fᵢ)             |                |
//! | c2s | `SHARD_GRADS`    26 | per-client (id, fᵢ, ∇fᵢ)        |                |
//! | c2s | `SHARD_WARM`     27 | ordered packed Hᵢ⁰ batch        |                |
//! | c2s | `SHARD_STATES`   28 | per-client (id, lᵢ, gᵢ)         |                |
//! | c2s | `SHARD_PREPPED`  29 | rejoined ids, dead ids          |                |
//! | c2s | `SHARD_PULLED`   30 | present flag (+ lᵢ, gᵢ)         |                |
//! | c2s | `SHARD_SUM`      31 | merged [`RoundSum`] + missing   |                |
//! | s2c | `LOSS_GRAD_SUM`   9 | x                               | `SHARD_GRAD_SUM` |
//! | c2s | `SHARD_GRAD_SUM` 32 | count, Σfᵢ acc, Σ∇fᵢ acc        |                |
//! | s2c | `SHARD_ACK`      34 | committed round, client ids     | —              |
//!
//! `SHARD_ROUND`'s `sum` flag selects the reply: set (the FedNL/LS
//! default) the relay **pre-reduces arithmetically** — it folds its
//! partition's replies into one exact [`RoundSum`] superaccumulator
//! and answers a single compact `SHARD_SUM` frame (O(d), independent
//! of the partition size); clear (FedNL-PP, or rounds with injected
//! straggler delays) it answers the per-client `SHARD_MSG` batch.
//! Exact associativity (`linalg::reduce`) makes the two replies
//! arithmetically indistinguishable to the master, so the shard
//! tier's bit-identity invariant holds on both.
//!
//! The downward probe commands (`EVAL_LOSS`, `LOSS_GRAD`, `WARM_START`,
//! `STATE`, `SET_ALPHA`, `SHUTDOWN`) are reused verbatim on the
//! master → relay leg — only the replies differ, carrying per-client
//! atoms; the master folds them through the reproducible accumulator,
//! so their grouping is free too. The dense first-order probe
//! additionally has a pre-reduced form: `LOSS_GRAD_SUM` asks the relay
//! to fold its partition's (fᵢ, ∇fᵢ) into one exact accumulator pair
//! and answer a compact `SHARD_GRAD_SUM` frame — one O(d) payload per
//! shard instead of n dense gradients, bit-identical to the atom fold
//! by exactness.
//!
//! [`RoundSum`]: crate::algorithms::RoundSum
//!
//! # Liveness (fault-tolerant rounds)
//!
//! `DEREGISTER` announces a graceful leave: the master retires the
//! connection and certifies the client missing for the round in
//! flight; an abrupt EOF or a reply that misses the master's deadline
//! has the same effect. **Rejoin** reuses `REGISTER`: a deregistered
//! id reconnects and re-registers (same id, d and family) on the
//! master's retained listener; under FedNL-PP the master then resyncs
//! the client's server-tracked (lᵢ, gᵢ) through the existing `STATE`
//! pull on the fresh channel.
//!
//! # Commit acks (exactly-once round application)
//!
//! A reply can be computed but lost (relay death, severed channel)
//! between the client's compute and the master's commit — the client
//! must not apply its own Hᵢ shift for a round the master never
//! counted. Clients that register with the `REG_WANTS_ACK` flag
//! therefore **stage** each round's Hᵢ shift and apply it only on the
//! master's `ROUND_ACK` (carrying the committed round). On rejoin the
//! master answers the re-`REGISTER` with `RESYNC`, naming the last
//! round it committed for that id — the client applies a staged shift
//! with `round ≤ last_commit` (reply delivered, ack lost) and discards
//! anything newer (reply lost), closing both halves of the window with
//! exactly-once semantics. The shard tier forwards acks as one
//! `SHARD_ACK` (round + the partition's committed ids) per round, and
//! only toward shards that registered a `wants_ack` downstream, so
//! runs without failover clients ship zero extra bytes.
//!
//! A rejoiner that declares the `REG_FRESH` flag (new process, empty
//! state) additionally triggers an **exact** Hᵢ resync: the master
//! broadcasts `PULL_H` and every live FedNL client uploads its packed
//! Hᵢ (a `WARM` reply; relays batch them as `SHARD_WARM`), letting the
//! server rebuild H = (1/n)ΣHᵢ exactly instead of approximately.
//!
//! # Byte accounting
//!
//! The `*_frame_bytes` helpers return the **exact** framed size
//! (header + payload) of each fixed-shape frame; together with
//! [`ClientMsg::wire_bytes`] they keep the drivers' logical byte
//! accounting equal to the TCP transport's metered counts (asserted by
//! the codec tests below and the TCP integration test).

use anyhow::Result;

use crate::algorithms::ClientMsg;
use crate::compressors::natural::{pack16, unpack16};
use crate::compressors::{Compressed, IndexPayload, ValueEncoding};
use crate::utils::{ByteReader, ByteWriter};

pub use super::framing::FRAME_HEADER_BYTES;

/// Frame tags, master → client.
pub mod s2c {
    pub const ROUND: u8 = 1;
    pub const EVAL_LOSS: u8 = 2;
    pub const WARM_START: u8 = 3;
    pub const SET_ALPHA: u8 = 5;
    pub const SHUTDOWN: u8 = 6;
    /// First-order reduction (baselines): client replies GRAD.
    pub const LOSS_GRAD: u8 = 7;
    /// State pull: PP client replies STATE with its current (lᵢ, gᵢ).
    pub const STATE: u8 = 8;
    /// Pre-reduced first-order probe (shard tier): the relay folds its
    /// partition's (fᵢ, ∇fᵢ) into one exact accumulator pair and
    /// replies SHARD_GRAD_SUM — the `SHARD_SUM` payload cut applied to
    /// the FedNL-PP convergence probe.
    pub const LOSS_GRAD_SUM: u8 = 9;
    /// Shard tier: one relay round (round, need_loss, deadline, x,
    /// participant subset); the relay replies SHARD_MSG.
    pub const SHARD_ROUND: u8 = 20;
    /// Shard tier: pre-round liveness poll; relay replies SHARD_PREPPED.
    pub const SHARD_PREP: u8 = 21;
    /// Shard tier: single-client STATE pull (PP rejoin resync); relay
    /// replies SHARD_PULLED.
    pub const SHARD_PULL: u8 = 22;
    /// Commit ack: the master committed this round with the client's
    /// reply counted — the client may apply its staged Hᵢ shift. Sent
    /// only to clients that registered with `REG_WANTS_ACK`.
    pub const ROUND_ACK: u8 = 33;
    /// Shard-tier commit ack: (round, committed ids) fan-out; the
    /// relay forwards per-client ROUND_ACKs (or nested SHARD_ACKs)
    /// downward. Sent only to shards whose registration carried
    /// `wants_ack`.
    pub const SHARD_ACK: u8 = 34;
    /// Rejoin resync: the last round the master committed for this id
    /// (absent = none). Resolves the client's staged shift with
    /// exactly-once semantics.
    pub const RESYNC: u8 = 35;
    /// Exact Hᵢ resync pull: a FedNL client uploads its packed Hᵢ as a
    /// WARM reply (relays batch as SHARD_WARM). Empty payload.
    pub const PULL_H: u8 = 36;
}

/// Frame tags, client → master.
pub mod c2s {
    pub const REGISTER: u8 = 10;
    pub const MSG: u8 = 11;
    pub const LOSS: u8 = 12;
    pub const WARM: u8 = 13;
    pub const ACK: u8 = 15;
    /// (loss, gradient) reply to LOSS_GRAD.
    pub const GRAD: u8 = 16;
    /// (lᵢ, gᵢ) reply to STATE (same codec as GRAD).
    pub const STATE: u8 = 17;
    /// Graceful leave announcement (empty payload); rejoin reuses
    /// REGISTER on the master's retained listener.
    pub const DEREGISTER: u8 = 18;
    /// Shard tier: a relay announces (shard id, id base, client count,
    /// d, family).
    pub const SHARD_REGISTER: u8 = 23;
    /// Shard tier: one round's partition batch — the shard's committed
    /// [`crate::algorithms::ClientMsg`]s in round-subset order plus its
    /// missing-certificates.
    pub const SHARD_MSG: u8 = 24;
    /// Per-client (id, fᵢ) batch (reply to EVAL_LOSS).
    pub const SHARD_LOSSES: u8 = 25;
    /// Per-client (id, fᵢ, ∇fᵢ) batch (reply to LOSS_GRAD).
    pub const SHARD_GRADS: u8 = 26;
    /// Ordered packed-Hᵢ⁰ batch (reply to WARM_START; ids implicit by
    /// ascending order within the partition).
    pub const SHARD_WARM: u8 = 27;
    /// Per-client (id, lᵢ, gᵢ) batch (reply to STATE).
    pub const SHARD_STATES: u8 = 28;
    /// (rejoined ids, dead ids) liveness report (reply to SHARD_PREP).
    pub const SHARD_PREPPED: u8 = 29;
    /// Optional (lᵢ, gᵢ) of one client (reply to SHARD_PULL; absent if
    /// the client was lost before answering).
    pub const SHARD_PULLED: u8 = 30;
    /// Shard tier, sum mode: one round's **pre-reduced** partition sum
    /// — a merged [`crate::algorithms::RoundSum`] superaccumulator
    /// plus the partition's missing-certificates. O(d) payload,
    /// independent of the partition's client count.
    pub const SHARD_SUM: u8 = 31;
    /// Pre-reduced (count, Σfᵢ, Σ∇fᵢ) accumulator pair over the
    /// partition's live clients (reply to LOSS_GRAD_SUM). O(d)
    /// payload, independent of the partition's client count.
    pub const SHARD_GRAD_SUM: u8 = 32;
}

// --- exact frame sizes ----------------------------------------------------

/// Framed size of a ROUND command: header + round + need_loss + len + x.
pub fn round_frame_bytes(d: usize) -> u64 {
    FRAME_HEADER_BYTES + 8 + 1 + 4 + 8 * d as u64
}

/// Framed size of a bare f64 vector (EVAL_LOSS / WARM_START commands,
/// WARM replies): header + len + values.
pub fn vec_frame_bytes(len: usize) -> u64 {
    FRAME_HEADER_BYTES + 4 + 8 * len as u64
}

/// Framed size of a single f64 (LOSS / ACK / SET_ALPHA).
pub fn scalar_frame_bytes() -> u64 {
    FRAME_HEADER_BYTES + 8
}

/// Framed size of an (f64, vector) pair (GRAD / STATE replies).
pub fn scalar_vec_frame_bytes(len: usize) -> u64 {
    FRAME_HEADER_BYTES + 8 + 4 + 8 * len as u64
}

/// Framed size of a payload-less command (STATE / SHUTDOWN).
pub fn empty_frame_bytes() -> u64 {
    FRAME_HEADER_BYTES
}

// --- payload codecs -------------------------------------------------------

pub fn encode_round(x: &[f64], round: u64, need_loss: bool) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(x.len() * 8 + 16);
    w.put_u64(round);
    w.put_u8(need_loss as u8);
    w.put_u32(x.len() as u32);
    w.put_f64_slice(x);
    w.into_vec()
}

pub fn decode_round(p: &[u8]) -> Result<(Vec<f64>, u64, bool)> {
    let mut r = ByteReader::new(p);
    let round = r.get_u64()?;
    let need_loss = r.get_u8()? != 0;
    let n = r.get_u32()? as usize;
    Ok((r.get_f64_vec(n)?, round, need_loss))
}

pub fn encode_vec(x: &[f64]) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(x.len() * 8 + 4);
    w.put_u32(x.len() as u32);
    w.put_f64_slice(x);
    w.into_vec()
}

pub fn decode_vec(p: &[u8]) -> Result<Vec<f64>> {
    let mut r = ByteReader::new(p);
    let n = r.get_u32()? as usize;
    r.get_f64_vec(n)
}

pub fn encode_scalar(v: f64) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(8);
    w.put_f64(v);
    w.into_vec()
}

pub fn decode_scalar(p: &[u8]) -> Result<f64> {
    ByteReader::new(p).get_f64()
}

/// Client algorithm family, declared at registration. The round
/// exchange is family-agnostic (one ROUND/MSG tag pair), so the master
/// validates at dispatch time that a round is going to clients of the
/// right family instead of silently aggregating mismatched math.
pub const FAMILY_FEDNL: u8 = 0;
pub const FAMILY_PP: u8 = 1;

/// REGISTER flag: the client stages round applications and expects
/// `ROUND_ACK` / `RESYNC` (the commit-ack protocol; set by failover
/// clients).
pub const REG_WANTS_ACK: u8 = 1;
/// REGISTER flag: the rejoiner restarted with empty state and needs
/// the exact `PULL_H` resync (never set on a first registration).
pub const REG_FRESH: u8 = 2;

pub fn encode_register(
    client_id: u32,
    d: u32,
    family: u8,
    flags: u8,
) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(10);
    w.put_u32(client_id);
    w.put_u32(d);
    w.put_u8(family);
    w.put_u8(flags);
    w.into_vec()
}

pub fn decode_register(p: &[u8]) -> Result<(u32, u32, u8, u8)> {
    let mut r = ByteReader::new(p);
    let id = r.get_u32()?;
    let d = r.get_u32()?;
    let family = r.get_u8()?;
    let flags = r.get_u8()?;
    anyhow::ensure!(
        family == FAMILY_FEDNL || family == FAMILY_PP,
        "bad client family {family}"
    );
    anyhow::ensure!(
        flags & !(REG_WANTS_ACK | REG_FRESH) == 0,
        "bad register flags {flags:#x}"
    );
    Ok((id, d, family, flags))
}

/// Framed size of a REGISTER frame (id + d + family + flags bytes).
pub fn register_frame_bytes() -> u64 {
    FRAME_HEADER_BYTES + 10
}

fn put_compressed(w: &mut ByteWriter, c: &Compressed) {
    w.put_u32(c.n);
    match &c.payload {
        IndexPayload::Explicit(ix) => {
            w.put_u8(0);
            w.put_u32(ix.len() as u32);
            w.put_u32_slice(ix);
        }
        IndexPayload::Seed { seed, k } => {
            w.put_u8(1);
            w.put_u64(*seed);
            w.put_u32(*k);
        }
        IndexPayload::SeqStart { start, k } => {
            w.put_u8(2);
            w.put_u32(*start);
            w.put_u32(*k);
        }
        IndexPayload::Dense => w.put_u8(3),
    }
    w.put_f64(c.scale);
    w.put_u32(c.values.len() as u32);
    match c.encoding {
        ValueEncoding::F64 => {
            w.put_u8(0);
            w.put_f64_slice(&c.values);
        }
        ValueEncoding::Pow2x16 => {
            // The paper's bit-granularity Natural payload: 16 bits per
            // coordinate (sign + exponent of a pure power of two).
            w.put_u8(1);
            for &v in &c.values {
                let p = pack16(v);
                w.put_u8(p as u8);
                w.put_u8((p >> 8) as u8);
            }
        }
    }
}

fn get_compressed(r: &mut ByteReader) -> Result<Compressed> {
    let n = r.get_u32()?;
    let payload = match r.get_u8()? {
        0 => {
            let k = r.get_u32()? as usize;
            IndexPayload::Explicit(r.get_u32_vec(k)?)
        }
        1 => IndexPayload::Seed { seed: r.get_u64()?, k: r.get_u32()? },
        2 => IndexPayload::SeqStart { start: r.get_u32()?, k: r.get_u32()? },
        3 => IndexPayload::Dense,
        t => anyhow::bail!("bad payload tag {t}"),
    };
    let scale = r.get_f64()?;
    let nv = r.get_u32()? as usize;
    let (values, encoding) = match r.get_u8()? {
        0 => (r.get_f64_vec(nv)?, ValueEncoding::F64),
        1 => {
            let mut vs = Vec::with_capacity(nv);
            for _ in 0..nv {
                let lo = r.get_u8()? as u16;
                let hi = r.get_u8()? as u16;
                vs.push(unpack16(lo | (hi << 8)));
            }
            (vs, ValueEncoding::Pow2x16)
        }
        t => anyhow::bail!("bad value encoding {t}"),
    };
    Ok(Compressed { payload, values, scale, encoding, n })
}

/// The unified round reply — FedNL messages and FedNL-PP participation
/// deltas share this codec (see [`ClientMsg`]).
pub fn encode_client_msg(m: &ClientMsg) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(m.grad.len() * 8 + 64);
    w.put_u32(m.client_id as u32);
    w.put_u32(m.grad.len() as u32);
    w.put_f64_slice(&m.grad);
    w.put_f64(m.l_i);
    match m.loss {
        Some(l) => {
            w.put_u8(1);
            w.put_f64(l);
        }
        None => w.put_u8(0),
    }
    put_compressed(&mut w, &m.update);
    w.into_vec()
}

pub fn decode_client_msg(p: &[u8]) -> Result<ClientMsg> {
    let mut r = ByteReader::new(p);
    let client_id = r.get_u32()? as usize;
    let d = r.get_u32()? as usize;
    let grad = r.get_f64_vec(d)?;
    let l_i = r.get_f64()?;
    let loss = if r.get_u8()? != 0 { Some(r.get_f64()?) } else { None };
    let update = get_compressed(&mut r)?;
    Ok(ClientMsg { client_id, grad, update, l_i, loss })
}

/// (scalar, vector) codec shared by the GRAD and STATE replies.
pub fn encode_loss_grad(loss: f64, g: &[f64]) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(g.len() * 8 + 12);
    w.put_f64(loss);
    w.put_u32(g.len() as u32);
    w.put_f64_slice(g);
    w.into_vec()
}

pub fn decode_loss_grad(p: &[u8]) -> Result<(f64, Vec<f64>)> {
    let mut r = ByteReader::new(p);
    let loss = r.get_f64()?;
    let n = r.get_u32()? as usize;
    Ok((loss, r.get_f64_vec(n)?))
}

/// Fold the SET_ALPHA ACK echoes of one negotiation round into
/// `(resolved α, homogeneous?)`. Invalid echoes (non-finite, ≤ 0) are
/// ignored, the last valid echo wins, and `homogeneous` turns false
/// iff two valid echoes disagreed **bitwise** — the signal that the
/// resolved α must be re-installed uniformly so every client trains
/// with exactly the α the server aggregates with. Shared by the flat
/// TCP master and the relay tier so the subtle comparison logic has
/// one home.
pub fn fold_alpha_echoes(
    requested: f64,
    echoes: impl IntoIterator<Item = f64>,
) -> (f64, bool) {
    let mut resolved = requested;
    let mut homogeneous = true;
    for a in echoes {
        if a.is_finite() && a > 0.0 {
            if resolved.is_finite()
                && resolved > 0.0
                && a.to_bits() != resolved.to_bits()
            {
                homogeneous = false;
            }
            resolved = a;
        }
    }
    (resolved, homogeneous)
}

// --- shard-tier codecs ----------------------------------------------------

/// SHARD_REGISTER: a relay announces which contiguous global-id
/// partition it aggregates. `flags` carries the OR of the partition's
/// downstream REGISTER flags that matter upward (today just
/// [`REG_WANTS_ACK`]: set iff some downstream client stages applies,
/// so SHARD_ACK frames only flow where needed).
pub fn encode_shard_register(
    shard_id: u32,
    base: u32,
    count: u32,
    d: u32,
    family: u8,
    flags: u8,
) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(18);
    w.put_u32(shard_id);
    w.put_u32(base);
    w.put_u32(count);
    w.put_u32(d);
    w.put_u8(family);
    w.put_u8(flags);
    w.into_vec()
}

/// Returns (shard_id, base, count, d, family, flags).
pub fn decode_shard_register(
    p: &[u8],
) -> Result<(u32, u32, u32, u32, u8, u8)> {
    let mut r = ByteReader::new(p);
    let shard_id = r.get_u32()?;
    let base = r.get_u32()?;
    let count = r.get_u32()?;
    let d = r.get_u32()?;
    let family = r.get_u8()?;
    let flags = r.get_u8()?;
    anyhow::ensure!(count > 0, "empty shard partition");
    anyhow::ensure!(
        family == FAMILY_FEDNL || family == FAMILY_PP,
        "bad shard family {family}"
    );
    anyhow::ensure!(
        flags & !REG_WANTS_ACK == 0,
        "bad shard register flags {flags:#x}"
    );
    Ok((shard_id, base, count, d, family, flags))
}

// --- commit-ack / resync codecs -------------------------------------------

/// ROUND_ACK: the round the master just committed (with this client's
/// reply counted).
pub fn encode_round_ack(round: u64) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(8);
    w.put_u64(round);
    w.into_vec()
}

pub fn decode_round_ack(p: &[u8]) -> Result<u64> {
    ByteReader::new(p).get_u64()
}

/// Framed size of a ROUND_ACK frame.
pub fn round_ack_frame_bytes() -> u64 {
    FRAME_HEADER_BYTES + 8
}

/// SHARD_ACK: the committed round plus the partition's committed ids
/// (global), for the relay to fan out downward.
pub fn encode_shard_ack(round: u64, ids: &[u32]) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(12 + ids.len() * 4);
    w.put_u64(round);
    w.put_u32(ids.len() as u32);
    w.put_u32_slice(ids);
    w.into_vec()
}

pub fn decode_shard_ack(p: &[u8]) -> Result<(u64, Vec<u32>)> {
    let mut r = ByteReader::new(p);
    let round = r.get_u64()?;
    let n = r.get_u32()? as usize;
    Ok((round, r.get_u32_vec(n)?))
}

/// Framed size of a SHARD_ACK frame carrying `n` committed ids.
pub fn shard_ack_frame_bytes(n: usize) -> u64 {
    FRAME_HEADER_BYTES + 8 + 4 + 4 * n as u64
}

/// RESYNC: the last round the master committed for the rejoining id
/// (`None` = it never committed one). The client resolves its staged
/// apply against this watermark.
pub fn encode_resync(last_commit: Option<u64>) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(9);
    match last_commit {
        Some(r) => {
            w.put_u8(1);
            w.put_u64(r);
        }
        None => {
            w.put_u8(0);
            w.put_u64(0);
        }
    }
    w.into_vec()
}

pub fn decode_resync(p: &[u8]) -> Result<Option<u64>> {
    let mut r = ByteReader::new(p);
    let has = r.get_u8()? != 0;
    let round = r.get_u64()?;
    Ok(if has { Some(round) } else { None })
}

/// Framed size of a RESYNC frame.
pub fn resync_frame_bytes() -> u64 {
    FRAME_HEADER_BYTES + 9
}

/// Shard-directed RESYNC: the relay command variant carrying the
/// target client id ahead of the watermark (the relay routes it down
/// its tier until the leaf pool emits the 9-byte client RESYNC).
pub fn encode_shard_resync(
    client: u32,
    last_commit: Option<u64>,
) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(13);
    w.put_u32(client);
    let rest = encode_resync(last_commit);
    w.put_bytes(&rest);
    w.into_vec()
}

pub fn decode_shard_resync(p: &[u8]) -> Result<(u32, Option<u64>)> {
    anyhow::ensure!(p.len() == 13, "bad shard resync len {}", p.len());
    let mut r = ByteReader::new(p);
    let client = r.get_u32()?;
    let lc = decode_resync(&p[4..])?;
    Ok((client, lc))
}

/// SHARD_ROUND: the relay-facing round command. `sum` selects the
/// reply format (true → one pre-reduced `SHARD_SUM`, false → the
/// per-client `SHARD_MSG` batch); `deadline_ms = 0` means no
/// per-client reply deadline; `subset` holds the partition's
/// participants (global ids, in round-subset order).
pub fn encode_shard_round(
    x: &[f64],
    round: u64,
    need_loss: bool,
    sum: bool,
    deadline_ms: u64,
    subset: &[u32],
) -> Vec<u8> {
    let mut w =
        ByteWriter::with_capacity(x.len() * 8 + subset.len() * 4 + 32);
    w.put_u64(round);
    w.put_u8(need_loss as u8);
    w.put_u8(sum as u8);
    w.put_u64(deadline_ms);
    w.put_u32(x.len() as u32);
    w.put_f64_slice(x);
    w.put_u32(subset.len() as u32);
    w.put_u32_slice(subset);
    w.into_vec()
}

/// Returns (x, round, need_loss, sum, deadline_ms, subset).
pub fn decode_shard_round(
    p: &[u8],
) -> Result<(Vec<f64>, u64, bool, bool, u64, Vec<u32>)> {
    let mut r = ByteReader::new(p);
    let round = r.get_u64()?;
    let need_loss = r.get_u8()? != 0;
    let sum = r.get_u8()? != 0;
    let deadline_ms = r.get_u64()?;
    let nx = r.get_u32()? as usize;
    let x = r.get_f64_vec(nx)?;
    let ns = r.get_u32()? as usize;
    let subset = r.get_u32_vec(ns)?;
    Ok((x, round, need_loss, sum, deadline_ms, subset))
}

/// SHARD_SUM: one round's pre-reduced partition sum — the shard's
/// merged [`crate::algorithms::RoundSum`] plus its
/// missing-certificates. The accumulator codec is exact (integer
/// limbs), so decode(encode(s)) represents the identical sum.
pub fn encode_shard_sum(
    shard_id: u32,
    sum: &mut crate::algorithms::RoundSum,
    missing: &[u32],
) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(128);
    w.put_u32(shard_id);
    sum.encode(&mut w);
    w.put_u32(missing.len() as u32);
    w.put_u32_slice(missing);
    w.into_vec()
}

/// Returns (shard_id, merged sum, missing ids). `d` is the run's
/// dimension, bounding every decoded length/index (network-facing
/// input: malformed frames become `Err` → a retired relay, never a
/// panic or a giant allocation). The decoded sum's `wire_bytes` is 0
/// — the receiver charges the actual frame size.
pub fn decode_shard_sum(
    p: &[u8],
    d: usize,
) -> Result<(u32, crate::algorithms::RoundSum, Vec<u32>)> {
    let mut r = ByteReader::new(p);
    let shard_id = r.get_u32()?;
    let sum = crate::algorithms::RoundSum::decode(&mut r, d)?;
    let nmiss = r.get_u32()? as usize;
    let missing = r.get_u32_vec(nmiss)?;
    Ok((shard_id, sum, missing))
}

/// SHARD_GRAD_SUM: the partition's pre-reduced first-order probe —
/// live-client count plus the exact (Σfᵢ, Σ∇fᵢ) accumulator pair.
pub fn encode_shard_grad_sum(
    count: u32,
    loss: &mut crate::linalg::reduce::RepAcc,
    grad: &mut crate::linalg::reduce::RepVec,
) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(128);
    w.put_u32(count);
    loss.encode(&mut w);
    grad.encode(&mut w);
    w.into_vec()
}

/// Returns (count, Σfᵢ acc, Σ∇fᵢ acc). `d` bounds the decoded gradient
/// length (network-facing input: malformed frames become `Err` → a
/// retired relay, never a panic or a giant allocation).
pub fn decode_shard_grad_sum(
    p: &[u8],
    d: usize,
) -> Result<(
    u32,
    crate::linalg::reduce::RepAcc,
    crate::linalg::reduce::RepVec,
)> {
    let mut r = ByteReader::new(p);
    let count = r.get_u32()?;
    let loss = crate::linalg::reduce::RepAcc::decode(&mut r)?;
    let grad = crate::linalg::reduce::RepVec::decode(&mut r, d)?;
    Ok((count, loss, grad))
}

/// SHARD_MSG: one round's partition batch — the shard's committed
/// client messages **in round-subset order** (per-client atoms, so the
/// master's commit arithmetic is invariant in the shard count) plus
/// the partition's missing-certificates.
pub fn encode_shard_msg(
    shard_id: u32,
    msgs: &[ClientMsg],
    missing: &[u32],
) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(64);
    w.put_u32(shard_id);
    w.put_u32(msgs.len() as u32);
    for m in msgs {
        let enc = encode_client_msg(m);
        w.put_u32(enc.len() as u32);
        w.put_bytes(&enc);
    }
    w.put_u32(missing.len() as u32);
    w.put_u32_slice(missing);
    w.into_vec()
}

/// Returns (shard_id, committed messages, missing ids).
pub fn decode_shard_msg(
    p: &[u8],
) -> Result<(u32, Vec<ClientMsg>, Vec<u32>)> {
    let mut r = ByteReader::new(p);
    let shard_id = r.get_u32()?;
    let nm = r.get_u32()? as usize;
    let mut msgs = Vec::with_capacity(nm);
    for _ in 0..nm {
        let len = r.get_u32()? as usize;
        msgs.push(decode_client_msg(r.get_bytes(len)?)?);
    }
    let nmiss = r.get_u32()? as usize;
    let missing = r.get_u32_vec(nmiss)?;
    Ok((shard_id, msgs, missing))
}

/// SHARD_LOSSES: per-client (id, scalar) batch.
pub fn encode_id_scalars(parts: &[(u32, f64)]) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(4 + parts.len() * 12);
    w.put_u32(parts.len() as u32);
    for &(id, v) in parts {
        w.put_u32(id);
        w.put_f64(v);
    }
    w.into_vec()
}

pub fn decode_id_scalars(p: &[u8]) -> Result<Vec<(u32, f64)>> {
    let mut r = ByteReader::new(p);
    let n = r.get_u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let id = r.get_u32()?;
        let v = r.get_f64()?;
        out.push((id, v));
    }
    Ok(out)
}

/// SHARD_GRADS / SHARD_STATES: per-client (id, scalar, vector) batch.
pub fn encode_id_scalar_vecs(parts: &[(u32, f64, Vec<f64>)]) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(4 + parts.len() * 16);
    w.put_u32(parts.len() as u32);
    for (id, v, g) in parts {
        w.put_u32(*id);
        w.put_f64(*v);
        w.put_u32(g.len() as u32);
        w.put_f64_slice(g);
    }
    w.into_vec()
}

pub fn decode_id_scalar_vecs(p: &[u8]) -> Result<Vec<(u32, f64, Vec<f64>)>> {
    let mut r = ByteReader::new(p);
    let n = r.get_u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let id = r.get_u32()?;
        let v = r.get_f64()?;
        let ng = r.get_u32()? as usize;
        out.push((id, v, r.get_f64_vec(ng)?));
    }
    Ok(out)
}

/// SHARD_WARM: ordered batch of packed Hᵢ⁰ uploads (ascending client
/// id within the partition; ids travel implicitly by order, matching
/// [`crate::coordinator::ClientPool::warm_start`]'s id-less contract).
pub fn encode_vec_batch(packs: &[Vec<f64>]) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(4 + packs.len() * 8);
    w.put_u32(packs.len() as u32);
    for v in packs {
        w.put_u32(v.len() as u32);
        w.put_f64_slice(v);
    }
    w.into_vec()
}

pub fn decode_vec_batch(p: &[u8]) -> Result<Vec<Vec<f64>>> {
    let mut r = ByteReader::new(p);
    let n = r.get_u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let nv = r.get_u32()? as usize;
        out.push(r.get_f64_vec(nv)?);
    }
    Ok(out)
}

/// SHARD_PREPPED: (rejoined ids, dead ids, fresh-rejoined ids)
/// liveness report. `fresh` ⊆ `rejoined`: the rejoiners that came
/// back with `REG_FRESH` (blank Hᵢ) and need the packed-H resync
/// instead of the warm-start approximation.
pub fn encode_shard_prepped(
    rejoined: &[u32],
    dead: &[u32],
    fresh: &[u32],
) -> Vec<u8> {
    let n = rejoined.len() + dead.len() + fresh.len();
    let mut w = ByteWriter::with_capacity(12 + n * 4);
    w.put_u32(rejoined.len() as u32);
    w.put_u32_slice(rejoined);
    w.put_u32(dead.len() as u32);
    w.put_u32_slice(dead);
    w.put_u32(fresh.len() as u32);
    w.put_u32_slice(fresh);
    w.into_vec()
}

pub fn decode_shard_prepped(
    p: &[u8],
) -> Result<(Vec<u32>, Vec<u32>, Vec<u32>)> {
    let mut r = ByteReader::new(p);
    let nr = r.get_u32()? as usize;
    let rejoined = r.get_u32_vec(nr)?;
    let nd = r.get_u32()? as usize;
    let dead = r.get_u32_vec(nd)?;
    let nf = r.get_u32()? as usize;
    let fresh = r.get_u32_vec(nf)?;
    Ok((rejoined, dead, fresh))
}

/// SHARD_PULLED: one client's (lᵢ, gᵢ) if it was still reachable.
pub fn encode_shard_pulled(state: Option<(f64, &[f64])>) -> Vec<u8> {
    match state {
        None => {
            let mut w = ByteWriter::with_capacity(1);
            w.put_u8(0);
            w.into_vec()
        }
        Some((l, g)) => {
            let mut w = ByteWriter::with_capacity(13 + g.len() * 8);
            w.put_u8(1);
            w.put_f64(l);
            w.put_u32(g.len() as u32);
            w.put_f64_slice(g);
            w.into_vec()
        }
    }
}

pub fn decode_shard_pulled(p: &[u8]) -> Result<Option<(f64, Vec<f64>)>> {
    let mut r = ByteReader::new(p);
    if r.get_u8()? == 0 {
        return Ok(None);
    }
    let l = r.get_f64()?;
    let n = r.get_u32()? as usize;
    Ok(Some((l, r.get_f64_vec(n)?)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_roundtrip() {
        let x = vec![1.0, -2.5, 3.25];
        let enc = encode_round(&x, 42, true);
        let (x2, round, need_loss) = decode_round(&enc).unwrap();
        assert_eq!(x2, x);
        assert_eq!(round, 42);
        assert!(need_loss);
    }

    fn msg_with(payload: IndexPayload, loss: Option<f64>) -> ClientMsg {
        let values = match &payload {
            IndexPayload::Dense => vec![1.0; 10],
            _ => vec![1.5, -2.0, 0.0],
        };
        ClientMsg {
            client_id: 3,
            grad: vec![0.5; 4],
            update: Compressed {
                payload,
                values,
                scale: 1.0,
                encoding: ValueEncoding::F64,
                n: 10,
            },
            l_i: 2.25,
            loss,
        }
    }

    #[test]
    fn client_msg_roundtrip_all_payloads() {
        let payloads = vec![
            IndexPayload::Explicit(vec![0, 5, 9]),
            IndexPayload::Seed { seed: 0xDEAD, k: 3 },
            IndexPayload::SeqStart { start: 7, k: 3 },
            IndexPayload::Dense,
        ];
        for p in payloads {
            let m = msg_with(p, Some(-0.75));
            let dec = decode_client_msg(&encode_client_msg(&m)).unwrap();
            assert_eq!(dec.client_id, 3);
            assert_eq!(dec.grad, m.grad);
            assert_eq!(dec.l_i, m.l_i);
            assert_eq!(dec.loss, m.loss);
            assert_eq!(dec.update.payload, m.update.payload);
            assert_eq!(dec.update.values, m.update.values);
            // Critical: reconstructed indices identical on both sides.
            assert_eq!(dec.update.indices(), m.update.indices());
        }
    }

    #[test]
    fn client_msg_wire_bytes_matches_encoder_exactly() {
        // The satellite fix: the drivers' logical `wire_bytes()` must
        // equal the framed size the TCP transport actually meters.
        let payloads = vec![
            IndexPayload::Explicit(vec![0, 5, 9]),
            IndexPayload::Seed { seed: 0xDEAD, k: 3 },
            IndexPayload::SeqStart { start: 7, k: 3 },
            IndexPayload::Dense,
        ];
        for p in payloads {
            for loss in [None, Some(0.125)] {
                let m = msg_with(p.clone(), loss);
                let framed =
                    encode_client_msg(&m).len() as u64 + FRAME_HEADER_BYTES;
                assert_eq!(
                    m.wire_bytes(),
                    framed,
                    "payload {:?}, loss {:?}",
                    m.update.payload,
                    loss
                );
            }
        }
        // Pow2x16 values travel in 2 bytes each.
        let m = ClientMsg {
            client_id: 1,
            grad: vec![0.0; 3],
            update: Compressed {
                payload: IndexPayload::Dense,
                values: vec![2.0, -0.5, 1024.0],
                scale: 8.0 / 9.0,
                encoding: ValueEncoding::Pow2x16,
                n: 3,
            },
            l_i: 0.0,
            loss: None,
        };
        assert_eq!(
            m.wire_bytes(),
            encode_client_msg(&m).len() as u64 + FRAME_HEADER_BYTES
        );
    }

    #[test]
    fn frame_size_helpers_match_encoders() {
        let x = vec![0.5; 7];
        assert_eq!(
            round_frame_bytes(x.len()),
            encode_round(&x, 9, true).len() as u64 + FRAME_HEADER_BYTES
        );
        assert_eq!(
            vec_frame_bytes(x.len()),
            encode_vec(&x).len() as u64 + FRAME_HEADER_BYTES
        );
        assert_eq!(
            scalar_frame_bytes(),
            encode_scalar(1.5).len() as u64 + FRAME_HEADER_BYTES
        );
        assert_eq!(
            scalar_vec_frame_bytes(x.len()),
            encode_loss_grad(0.25, &x).len() as u64 + FRAME_HEADER_BYTES
        );
        assert_eq!(
            register_frame_bytes(),
            encode_register(3, 7, FAMILY_PP, 0).len() as u64
                + FRAME_HEADER_BYTES
        );
        assert_eq!(empty_frame_bytes(), FRAME_HEADER_BYTES);
        let (id, d, fam, flags) =
            decode_register(&encode_register(3, 7, FAMILY_PP, 0)).unwrap();
        assert_eq!((id, d, fam, flags), (3, 7, FAMILY_PP, 0));
        assert!(decode_register(&encode_register(1, 2, 9, 0)).is_err());
    }

    #[test]
    fn register_flags_roundtrip_and_validate() {
        let flags = REG_WANTS_ACK | REG_FRESH;
        let (id, d, fam, got) =
            decode_register(&encode_register(5, 3, FAMILY_FEDNL, flags))
                .unwrap();
        assert_eq!((id, d, fam, got), (5, 3, FAMILY_FEDNL, flags));
        // Unknown flag bits are a protocol error, not silently ignored.
        assert!(decode_register(&encode_register(5, 3, FAMILY_FEDNL, 4))
            .is_err());
        // The old 9-byte REGISTER (no flags byte) no longer parses.
        assert!(decode_register(&[0, 0, 0, 0, 3, 0, 0, 0, 0]).is_err());
    }

    #[test]
    fn ack_resync_codecs_roundtrip() {
        assert_eq!(decode_round_ack(&encode_round_ack(17)).unwrap(), 17);
        assert_eq!(
            round_ack_frame_bytes(),
            encode_round_ack(17).len() as u64 + FRAME_HEADER_BYTES
        );
        let (r, ids) =
            decode_shard_ack(&encode_shard_ack(9, &[2, 5, 3])).unwrap();
        assert_eq!(r, 9);
        assert_eq!(ids, vec![2, 5, 3]);
        assert_eq!(
            shard_ack_frame_bytes(3),
            encode_shard_ack(9, &[2, 5, 3]).len() as u64
                + FRAME_HEADER_BYTES
        );
        assert_eq!(
            decode_resync(&encode_resync(Some(4))).unwrap(),
            Some(4)
        );
        assert_eq!(decode_resync(&encode_resync(None)).unwrap(), None);
        assert_eq!(
            resync_frame_bytes(),
            encode_resync(None).len() as u64 + FRAME_HEADER_BYTES
        );
        let (c, lc) =
            decode_shard_resync(&encode_shard_resync(7, Some(3))).unwrap();
        assert_eq!((c, lc), (7, Some(3)));
        let (c, lc) =
            decode_shard_resync(&encode_shard_resync(2, None)).unwrap();
        assert_eq!((c, lc), (2, None));
        assert!(decode_round_ack(&[1]).is_err());
        assert!(decode_shard_ack(&[1, 2]).is_err());
        assert!(decode_resync(&[]).is_err());
        assert!(decode_shard_resync(&[0, 0, 0, 0, 1]).is_err());
    }

    #[test]
    fn pow2x16_wire_roundtrip_bitexact() {
        // Natural's 16-bit payload must reconstruct the exact powers of
        // two (and the scale travels separately).
        let values = vec![2.0, -0.5, 1024.0, 0.0, 2.0f64.powi(-300)];
        let m = ClientMsg {
            client_id: 1,
            grad: vec![0.0; 3],
            update: Compressed {
                payload: IndexPayload::Dense,
                values: values.clone(),
                scale: 8.0 / 9.0,
                encoding: ValueEncoding::Pow2x16,
                n: 5,
            },
            l_i: 0.0,
            loss: None,
        };
        let dec = decode_client_msg(&encode_client_msg(&m)).unwrap();
        assert_eq!(dec.update.values, values);
        assert_eq!(dec.update.scale, 8.0 / 9.0);
        assert_eq!(dec.update.encoding, ValueEncoding::Pow2x16);
    }

    #[test]
    fn corrupt_rejected() {
        assert!(decode_client_msg(&[1, 2, 3]).is_err());
        assert!(decode_round(&[]).is_err());
    }

    #[test]
    fn fold_alpha_echoes_resolves_and_detects_mixes() {
        // NaN query + homogeneous echoes: resolved, no re-install.
        let (a, homog) =
            fold_alpha_echoes(f64::NAN, vec![0.25, 0.25, 0.25]);
        assert_eq!(a, 0.25);
        assert!(homog);
        // Mixed echoes flag the heterogeneity (last valid wins).
        let (a, homog) = fold_alpha_echoes(f64::NAN, vec![0.25, 0.5]);
        assert_eq!(a, 0.5);
        assert!(!homog);
        // Install mode: clients echo the installed value back.
        let (a, homog) = fold_alpha_echoes(0.75, vec![0.75, 0.75]);
        assert_eq!(a, 0.75);
        assert!(homog);
        // Invalid echoes are ignored, not treated as disagreement.
        let (a, homog) =
            fold_alpha_echoes(f64::NAN, vec![f64::NAN, 0.5, -1.0, 0.0]);
        assert_eq!(a, 0.5);
        assert!(homog);
        // No valid echo at all: the (possibly NaN) request survives so
        // the engine's finiteness assert can fail loudly.
        let (a, _) = fold_alpha_echoes(f64::NAN, vec![]);
        assert!(a.is_nan());
    }

    #[test]
    fn shard_register_roundtrip() {
        let enc =
            encode_shard_register(2, 6, 3, 21, FAMILY_PP, REG_WANTS_ACK);
        let (sid, base, count, d, fam, flags) =
            decode_shard_register(&enc).unwrap();
        assert_eq!(
            (sid, base, count, d, fam, flags),
            (2, 6, 3, 21, FAMILY_PP, REG_WANTS_ACK)
        );
        assert!(decode_shard_register(&encode_shard_register(
            0,
            0,
            0,
            4,
            FAMILY_FEDNL,
            0
        ))
        .is_err()); // empty partition
        assert!(decode_shard_register(&encode_shard_register(
            0, 0, 2, 4, 9, 0
        ))
        .is_err()); // bad family
        assert!(decode_shard_register(&encode_shard_register(
            0,
            0,
            2,
            4,
            FAMILY_FEDNL,
            REG_FRESH
        ))
        .is_err()); // fresh is not a shard-level flag
    }

    #[test]
    fn shard_round_roundtrip() {
        let x = vec![1.5, -0.25, 3.0];
        let subset = vec![7u32, 3, 5];
        let enc = encode_shard_round(&x, 11, true, true, 250, &subset);
        let (x2, round, need_loss, sum, deadline, sub2) =
            decode_shard_round(&enc).unwrap();
        assert_eq!(x2, x);
        assert_eq!(round, 11);
        assert!(need_loss);
        assert!(sum);
        assert_eq!(deadline, 250);
        assert_eq!(sub2, subset);
        let enc = encode_shard_round(&x, 0, false, false, 0, &[]);
        let (_, _, _, sum, _, _) = decode_shard_round(&enc).unwrap();
        assert!(!sum);
        assert!(decode_shard_round(&[1, 2]).is_err());
    }

    #[test]
    fn shard_sum_roundtrip_is_exact() {
        // The pre-reduced frame must reconstruct the *identical* sum:
        // the accumulator codec ships exact integer limbs.
        let msgs = vec![
            msg_with(IndexPayload::Explicit(vec![0, 5, 9]), Some(0.5)),
            msg_with(IndexPayload::SeqStart { start: 7, k: 3 }, Some(1e16)),
        ];
        let mut sum = crate::algorithms::RoundSum::from_msgs(&msgs);
        let want_grad: Vec<u64> = sum
            .clone()
            .grad
            .round_vec()
            .iter()
            .map(|v| v.to_bits())
            .collect();
        let want_l = sum.clone().l.round().to_bits();
        let missing = vec![4u32, 8];
        let enc = encode_shard_sum(2, &mut sum, &missing);
        // d = 4 (the messages' gradient length; packed_len(4) = 10
        // bounds the update indices, which run over n = 10).
        let (sid, back, miss) = decode_shard_sum(&enc, 4).unwrap();
        assert_eq!(sid, 2);
        assert_eq!(miss, missing);
        assert_eq!(back.committed, 2);
        assert!(back.have_loss);
        let mut back = back;
        let got: Vec<u64> =
            back.grad.round_vec().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want_grad);
        assert_eq!(back.l.round().to_bits(), want_l);
        assert!(decode_shard_sum(&[1, 2, 3], 4).is_err());
        // Dimension mismatch / out-of-triangle indices are decode
        // errors (→ drop_relay), never downstream panics.
        assert!(decode_shard_sum(&enc, 3).is_err());
    }

    #[test]
    fn shard_grad_sum_roundtrip_is_exact() {
        // The pre-reduced probe frame must survive the wire bit-for-
        // bit: the master's rounded (f, ∇f) must equal the relay-side
        // fold exactly.
        use crate::linalg::reduce::{RepAcc, RepVec};
        let mut loss = RepAcc::new();
        let mut grad = RepVec::new(3);
        for (l, g) in [
            (0.125, [1.0e-9, -3.5, 2.0f64.powi(40)]),
            (-7.25e11, [0.3, 0.3, 0.3]),
            (1e-300, [-1.0, 1e200, -0.0]),
        ] {
            loss.accumulate(l);
            grad.accumulate(&g);
        }
        let want_l = loss.clone().round().to_bits();
        let want_g: Vec<u64> = grad
            .clone()
            .round_vec()
            .iter()
            .map(|v| v.to_bits())
            .collect();
        let enc = encode_shard_grad_sum(3, &mut loss, &mut grad);
        let (count, mut bl, mut bg) =
            decode_shard_grad_sum(&enc, 3).unwrap();
        assert_eq!(count, 3);
        assert_eq!(bl.round().to_bits(), want_l);
        let got: Vec<u64> =
            bg.round_vec().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want_g);
        // Bounded decode: a frame claiming a longer gradient errors.
        assert!(decode_shard_grad_sum(&enc, 2).is_err());
        assert!(decode_shard_grad_sum(&[1, 2], 3).is_err());
    }

    #[test]
    fn shard_msg_roundtrip_preserves_order_and_missing() {
        // The batch order IS the shard's commit order — the codec must
        // preserve it exactly, along with every per-message field.
        let msgs = vec![
            msg_with(IndexPayload::Explicit(vec![0, 5, 9]), Some(-0.75)),
            msg_with(IndexPayload::Seed { seed: 0xFEED, k: 3 }, None),
            msg_with(IndexPayload::Dense, Some(2.5)),
        ];
        let missing = vec![9u32, 4];
        let enc = encode_shard_msg(1, &msgs, &missing);
        let (sid, dec, miss) = decode_shard_msg(&enc).unwrap();
        assert_eq!(sid, 1);
        assert_eq!(miss, missing);
        assert_eq!(dec.len(), msgs.len());
        for (a, b) in msgs.iter().zip(&dec) {
            assert_eq!(a.client_id, b.client_id);
            assert_eq!(a.grad, b.grad);
            assert_eq!(a.l_i, b.l_i);
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.update.values, b.update.values);
            assert_eq!(a.update.indices(), b.update.indices());
        }
        // Empty batch (every participant missing) is legal.
        let (_, dec, miss) =
            decode_shard_msg(&encode_shard_msg(0, &[], &[2])).unwrap();
        assert!(dec.is_empty());
        assert_eq!(miss, vec![2]);
        assert!(decode_shard_msg(&[0, 0]).is_err());
    }

    #[test]
    fn shard_batch_codecs_roundtrip() {
        let losses = vec![(0u32, 1.25), (3, -0.5), (7, f64::MIN_POSITIVE)];
        assert_eq!(
            decode_id_scalars(&encode_id_scalars(&losses)).unwrap(),
            losses
        );
        let grads = vec![
            (1u32, 0.5, vec![1.0, -2.0]),
            (4, -3.25, vec![0.0, 5.5]),
        ];
        assert_eq!(
            decode_id_scalar_vecs(&encode_id_scalar_vecs(&grads)).unwrap(),
            grads
        );
        let warms = vec![vec![1.0, 2.0, 3.0], vec![-1.0]];
        assert_eq!(
            decode_vec_batch(&encode_vec_batch(&warms)).unwrap(),
            warms
        );
        let (rj, dd, fr) = decode_shard_prepped(&encode_shard_prepped(
            &[3, 1],
            &[7],
            &[1],
        ))
        .unwrap();
        assert_eq!(rj, vec![3, 1]);
        assert_eq!(dd, vec![7]);
        assert_eq!(fr, vec![1]);
        assert_eq!(
            decode_shard_pulled(&encode_shard_pulled(None)).unwrap(),
            None
        );
        let pulled =
            decode_shard_pulled(&encode_shard_pulled(Some((0.75, &[1.0, 2.0]))))
                .unwrap();
        assert_eq!(pulled, Some((0.75, vec![1.0, 2.0])));
    }
}

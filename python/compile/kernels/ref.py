"""Pure-jnp reference oracle — the correctness ground truth for the Pallas
kernels (Layer 1) and for the Rust native oracle (which is cross-checked
against the same closed forms via finite differences on the Rust side).

Implements Eq. (2)-(5) of the paper verbatim, with labels absorbed into the
columns of A (paper §5.13) and a per-sample weight vector w generalizing
the 1/n_i factor (w_j = 1/n_real for real samples, 0 for padding — see
model.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def margins_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """z = Aᵀx, A (d, n), x (d,) → (n,)."""
    return a.T @ x


def loss_ref(a: jax.Array, x: jax.Array, w: jax.Array, lam: jax.Array) -> jax.Array:
    """f(x) = Σ_j w_j · log(1 + exp(-z_j)) + λ/2 ‖x‖²  (Eq. 2)."""
    z = margins_ref(a, x)
    # log1p(exp(-z)) computed stably: logaddexp(0, -z).
    return jnp.sum(w * jnp.logaddexp(0.0, -z)) + 0.5 * lam * jnp.dot(x, x)


def grad_ref(a: jax.Array, x: jax.Array, w: jax.Array, lam: jax.Array) -> jax.Array:
    """∇f(x) = A · (-w · σ(-z)) + λx  (Eq. 3); σ(-z) = 1/(1+exp(z))."""
    z = margins_ref(a, x)
    c = -w * jax.nn.sigmoid(-z)
    return a @ c + lam * x


def hessian_ref(a: jax.Array, x: jax.Array, w: jax.Array, lam: jax.Array) -> jax.Array:
    """∇²f(x) = A · diag(w · σ(z)σ(-z)) · Aᵀ + λI  (Eq. 4, 5)."""
    d = a.shape[0]
    z = margins_ref(a, x)
    h = w * jax.nn.sigmoid(z) * jax.nn.sigmoid(-z)
    return (a * h[None, :]) @ a.T + lam * jnp.eye(d, dtype=a.dtype)


def oracle_ref(
    a: jax.Array, x: jax.Array, w: jax.Array, lam: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(f, ∇f, ∇²f) in one call — the semantic contract of model.oracle."""
    return (
        loss_ref(a, x, w, lam),
        grad_ref(a, x, w, lam),
        hessian_ref(a, x, w, lam),
    )


__all__ = ["margins_ref", "loss_ref", "grad_ref", "hessian_ref", "oracle_ref"]

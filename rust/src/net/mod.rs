//! Multi-node networking over raw TCP (paper §7, App. L.1, J.2).
//!
//! Design decisions carried over from the paper:
//! * plain TCP/IP — no HTTP/gRPC layers ("any unnecessary abstractions
//!   ... take resources and are not free");
//! * **one** connection per client (the paper found a single channel
//!   beats per-stream connections);
//! * Nagle's algorithm disabled (`TCP_NODELAY`) because frames are
//!   explicitly sized and often small;
//! * fixed-width 32-bit indices on the wire (beat varints);
//! * RandK/RandSeqK transmit a PRG seed / start index, and the master
//!   reconstructs the coordinate set.

pub mod client;
pub mod framing;
pub mod server;
pub mod wire;

pub use client::{run_client, run_client_with, ClientOpts};
pub use framing::{Channel, FRAME_HEADER_BYTES};
pub use server::RemotePool;

//! The FedNL algorithm family (paper Alg. 1–3).
//!
//! One **round engine** ([`engine`]) drives every member of the family:
//! the algorithms differ only in their [`engine::StepPolicy`] (plain
//! Newton step, backtracking line search, or partial-participation
//! incremental state), and every policy runs over every
//! [`crate::coordinator::ClientPool`] transport — the sequential
//! reference pool, the multi-threaded single-node simulator, and the
//! TCP multi-node runtime — through the streaming
//! `submit_round`/`drain` API with buffer-and-commit aggregation.

pub mod engine;
pub mod fednl;
pub mod fednl_ls;
pub mod fednl_pp;
pub mod state;

pub use engine::{
    run_engine, run_engine_from, select_pp_subset, OnMissing, RoundPolicy,
    StepPolicy,
};
pub use fednl::{run_fednl, run_fednl_pool};
pub use fednl_ls::{run_fednl_ls, run_fednl_ls_pool, LineSearchParams};
pub use fednl_pp::{run_fednl_pp, run_fednl_pp_pool, PPClientState};
pub use state::{ClientMsg, ClientState, RoundSum, ServerState};

/// How the server forms the system matrix for the Newton step
/// (Alg. 1 line 11).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum UpdateRule {
    /// Option 1 (a): x⁺ = x − [Hᵏ]_μ⁻¹ ∇f(x) — eigenvalue clipping at μ.
    ProjectMu(f64),
    /// Option 2 (b): x⁺ = x − [Hᵏ + lᵏI]⁻¹ ∇f(x) — the variant all the
    /// paper's experiments use ("α - option 2" in Table 1).
    LkShift,
}

/// Shared options for the FedNL family.
#[derive(Debug, Clone)]
pub struct Options {
    /// Number of communication rounds r.
    pub rounds: u64,
    /// Hessian learning rate; `None` → theoretical α = 1 − √(1−δ) from
    /// the compressor class (the paper's "theoretical step-size").
    pub alpha: Option<f64>,
    pub rule: UpdateRule,
    /// Stop early once ‖∇f(xᵏ)‖ ≤ tol (used by the Table 2/3 harness
    /// which runs to ≈1e-9 rather than a fixed round budget).
    pub tol_grad: Option<f64>,
    /// Track f(xᵏ) in the trace (costs one reduction; optional in the
    /// paper too).
    pub track_loss: bool,
    /// Initialize Hᵢ⁰ = ∇²fᵢ(x⁰) (FedNL paper's warm start) instead of
    /// Hᵢ⁰ = 0. Costs one uncompressed d(d+1)/2 upload per client.
    pub warm_start: bool,
    /// Fault-tolerance contract: quorum, reply deadline and the
    /// missing-reply policy (see [`RoundPolicy`]). The default is the
    /// strict pre-fault behavior.
    pub policy: RoundPolicy,
    /// Speculative aggregation past quorum (`--speculate`): once the
    /// quorum's replies have committed, a snapshot of the server state
    /// runs the round finish + Newton direction on a helper thread
    /// while the engine keeps draining stragglers. If no straggler
    /// arrives, the precomputed step is adopted; if one does, the
    /// speculation is discarded and the round finishes inline —
    /// bit-identical to the non-speculative trajectory either way.
    pub speculate: bool,
    /// Byzantine-robust server-side aggregation (`--defense`): the
    /// committed round is folded through the selected
    /// [`crate::robust::Defense`] before the server state update.
    /// Median/trimmed-mean are not associative, so any defense forces
    /// the atom [`crate::coordinator::RoundMode`] (shards forward
    /// per-client atoms; speculation, a sum-path feature, never
    /// engages). Newton family only — FedNL-PP rejects it.
    pub defense: Option<crate::robust::Defense>,
    /// Durable checkpointing (`--checkpoint-dir` / `--checkpoint-every`):
    /// the engine writes an atomic, checksummed snapshot of the
    /// coordinator state every `every` rounds and defers `ROUND_ACK`s
    /// until the covering snapshot is durable, so a crashed-and-
    /// restored master resumes **bit-identically** (see
    /// [`crate::coordinator::checkpoint`]). Mutually exclusive with
    /// `speculate` (a snapshot cannot capture in-flight speculation).
    pub checkpoint: Option<crate::coordinator::CheckpointCfg>,
}

impl Default for Options {
    fn default() -> Self {
        Self {
            rounds: 100,
            alpha: None,
            rule: UpdateRule::LkShift,
            tol_grad: None,
            track_loss: false,
            warm_start: false,
            policy: RoundPolicy::default(),
            speculate: false,
            defense: None,
            checkpoint: None,
        }
    }
}

//! Convergence traces: one record per round, CSV-serializable.
//!
//! Every figure in the paper plots ‖∇f(xᵏ)‖ (or f(xᵏ) − f*) against one
//! of {rounds, communicated bits, wall-clock seconds}; a [`Trace`]
//! captures all three x-axes at once so a single run regenerates all
//! panels of a figure.

use std::io::Write;

/// One optimization round's measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    pub round: u64,
    /// ‖∇f(xᵏ)‖₂ at the round's iterate.
    pub grad_norm: f64,
    /// f(xᵏ) if tracked (the paper tracks it optionally), else NaN.
    pub loss: f64,
    /// Cumulative bytes every client sent to the master.
    pub bytes_up: u64,
    /// Cumulative bytes the master sent to clients.
    pub bytes_down: u64,
    /// Wall-clock seconds since training start.
    pub elapsed: f64,
    /// Client messages committed this round (arrived + policy reuses).
    /// Equals the participant count on a fault-free round.
    pub committed: u32,
    /// Participants whose contribution was lost this round (killed,
    /// dropped, or past the reply deadline) under the quorum policy.
    pub missing: u32,
    /// Contributions the `--defense` robust fold altered or excluded
    /// this round: NormClip counts clipped messages, `trimmedmean:F`
    /// reports 2F (F discarded per coordinate from each end), median
    /// reports committed−1 (only the middle order statistic passes
    /// through). Always 0 when undefended.
    pub flagged: u32,
}

/// A full training trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub records: Vec<RoundRecord>,
    /// Name tag (algorithm/compressor) for report labels.
    pub label: String,
    /// Total seconds the master spent blocked in `ClientPool::drain`
    /// waiting for client replies (streaming coordination layer).
    pub wait_secs: f64,
    /// Total seconds the master spent committing replies (incremental
    /// aggregation). `wait_secs`/`aggregate_secs` together are the
    /// per-run wait-vs-aggregate wall-clock split reported by
    /// `BENCH_coordinator.json`.
    pub aggregate_secs: f64,
    /// Total seconds of server-side work (quorum finish + Newton
    /// direction) that ran **overlapped** with straggler draining under
    /// `--speculate` — wait time the speculation converted into
    /// compute. Zero when speculation is off or never fired.
    pub overlap_secs: f64,
}

impl Trace {
    pub fn new(label: impl Into<String>) -> Self {
        Self {
            records: Vec::new(),
            label: label.into(),
            wait_secs: 0.0,
            aggregate_secs: 0.0,
            overlap_secs: 0.0,
        }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    pub fn last_grad_norm(&self) -> f64 {
        self.records.last().map(|r| r.grad_norm).unwrap_or(f64::NAN)
    }

    pub fn total_bytes_up(&self) -> u64 {
        self.records.last().map(|r| r.bytes_up).unwrap_or(0)
    }

    pub fn total_elapsed(&self) -> f64 {
        self.records.last().map(|r| r.elapsed).unwrap_or(0.0)
    }

    /// First round at which ‖∇f‖ ≤ tol, if reached.
    pub fn rounds_to_tolerance(&self, tol: f64) -> Option<u64> {
        self.records.iter().find(|r| r.grad_norm <= tol).map(|r| r.round)
    }

    /// Wall-clock seconds to reach tolerance, if reached.
    pub fn time_to_tolerance(&self, tol: f64) -> Option<f64> {
        self.records.iter().find(|r| r.grad_norm <= tol).map(|r| r.elapsed)
    }

    /// CSV with header; the figure-regeneration format.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "round,grad_norm,loss,bytes_up,bytes_down,elapsed_s,\
             committed,missing,flagged\n",
        );
        for r in &self.records {
            s.push_str(&format!(
                "{},{:e},{:e},{},{},{:.6},{},{},{}\n",
                r.round,
                r.grad_norm,
                r.loss,
                r.bytes_up,
                r.bytes_down,
                r.elapsed,
                r.committed,
                r.missing,
                r.flagged
            ));
        }
        s
    }

    pub fn write_csv(&self, path: &str) -> anyhow::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: u64, g: f64, t: f64, up: u64) -> RoundRecord {
        RoundRecord {
            round,
            grad_norm: g,
            loss: 0.5,
            bytes_up: up,
            bytes_down: up / 2,
            elapsed: t,
            committed: 4,
            missing: 1,
            flagged: 2,
        }
    }

    #[test]
    fn tolerance_queries() {
        let mut t = Trace::new("test");
        t.push(rec(0, 1.0, 0.1, 100));
        t.push(rec(1, 1e-3, 0.2, 200));
        t.push(rec(2, 1e-9, 0.3, 300));
        assert_eq!(t.rounds_to_tolerance(1e-2), Some(1));
        assert_eq!(t.time_to_tolerance(1e-8), Some(0.3));
        assert_eq!(t.rounds_to_tolerance(1e-20), None);
        assert_eq!(t.last_grad_norm(), 1e-9);
        assert_eq!(t.total_bytes_up(), 300);
    }

    #[test]
    fn csv_shape() {
        let mut t = Trace::new("csv");
        t.push(rec(0, 0.5, 0.01, 42));
        let csv = t.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("round,"));
        assert!(lines[0].ends_with("committed,missing,flagged"));
        assert!(lines[1].starts_with("0,"));
        assert_eq!(lines[1].split(',').count(), 9);
        assert!(lines[1].ends_with("4,1,2"));
    }

    #[test]
    fn csv_file_roundtrip() {
        let mut t = Trace::new("file");
        t.push(rec(0, 1.0, 0.0, 1));
        let path = std::env::temp_dir().join("fednl_trace_test.csv");
        let path = path.to_str().unwrap().to_string();
        t.write_csv(&path).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, t.to_csv());
        std::fs::remove_file(&path).ok();
    }
}

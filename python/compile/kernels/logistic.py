"""Layer-1 Pallas kernels for the L2-regularized logistic-regression oracle.

The FedNL compute hot-spot (paper §5.10) is the local Hessian oracle

    H_i = A_i · diag(h) · A_iᵀ + λ I            (Eq. 4)

with h_j = w_j · σ(z_j)·(1-σ(z_j)), z = A_iᵀ x the classification margins
(labels are absorbed into the columns of A_i, paper §5.13). The paper's
AVX-512 strategy — accumulate symmetric rank-1 updates 4 samples at a time,
reusing margins/sigmoids across all three oracles (§5.7) — maps to TPU as
*tiled MXU matmuls*: each grid step loads a (bd × bn) slab of A into VMEM
and accumulates `slab · diag(h_blk) · slabᵀ` into a (bd × bd) output tile.

All kernels run under ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowering produces plain
HLO loops that XLA compiles to native code on the Rust side.

Hardware-adaptation notes (DESIGN.md §3):
  * VMEM budget per grid step ≈ bd·bn + bn + bd·bd doubles. Defaults
    (bd=16, bn=128) keep this ≈ 2.3 KB·8 = 18 KB ≪ 16 MB VMEM; larger
    shapes raise bd/bn via `pick_blocks`.
  * The systolic-array matmul replaces the paper's hand-unrolled rank-1
    AVX updates; symmetry is *not* exploited inside the kernel (MXU tiles
    are dense); the Rust-side native oracle does exploit it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_blocks(d: int, n: int) -> tuple[int, int]:
    """Choose (bd, bn) tile sizes dividing the padded (d, n).

    Shapes fed to the AOT path are pre-padded (see model.pad_shapes) so a
    divisor always exists; for arbitrary test shapes we fall back to the
    largest divisor ≤ the target.

    Perf iteration (EXPERIMENTS.md §Perf L1-1): targets raised from
    (16, 128) to (128, 256). VMEM per grid step for the Gram kernel is
    2·bd·bn + bd² + bn doubles ≤ 1.3 MB ≪ 16 MB, and the grid shrinks
    ~30× (d=304: 1083 → 32 steps), which dominates the CPU-PJRT runtime
    (each step is a loop iteration with dynamic-slice traffic) and on
    TPU amortizes MXU pipeline fills over 128-wide tiles.
    """

    def largest_divisor_leq(x: int, cap: int) -> int:
        for c in range(min(x, cap), 0, -1):
            if x % c == 0:
                return c
        return 1

    return largest_divisor_leq(d, 128), largest_divisor_leq(n, 256)


# ---------------------------------------------------------------------------
# margins: z = Aᵀ x
# ---------------------------------------------------------------------------


def _margins_kernel(a_ref, x_ref, z_ref):
    # a_ref: (d, bn) slab; x_ref: (d,) full; z_ref: (bn,) output block.
    z_ref[...] = jnp.dot(
        a_ref[...].T, x_ref[...], preferred_element_type=a_ref.dtype
    )


def margins(a: jax.Array, x: jax.Array, *, bn: int | None = None) -> jax.Array:
    """Classification margins z = Aᵀx via a Pallas kernel.

    A is (d, n) with labels absorbed; x is (d,). Returns (n,).
    """
    d, n = a.shape
    if bn is None:
        _, bn = pick_blocks(d, n)
    grid = (n // bn,)
    return pl.pallas_call(
        _margins_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, bn), lambda j: (0, j)),
            pl.BlockSpec((d,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, x)


# ---------------------------------------------------------------------------
# gradient mat-vec: g = A c  (c = per-sample gradient coefficients)
# ---------------------------------------------------------------------------


def _matvec_kernel(a_ref, c_ref, o_ref):
    # Grid: (d/bd, n/bn); accumulate partial dot over the n dimension.
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], c_ref[...], preferred_element_type=a_ref.dtype
    )


def matvec(
    a: jax.Array, c: jax.Array, *, bd: int | None = None, bn: int | None = None
) -> jax.Array:
    """g = A·c with A (d, n), c (n,) → (d,), tiled over both dims."""
    d, n = a.shape
    dbd, dbn = pick_blocks(d, n)
    bd = bd or dbd
    bn = bn or dbn
    grid = (d // bd, n // bn)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), a.dtype),
        interpret=True,
    )(a, c)


# ---------------------------------------------------------------------------
# weighted Gram: H = A · diag(h) · Aᵀ  (the Eq. 4 hot-spot)
# ---------------------------------------------------------------------------


def _wgram_kernel(ai_ref, aj_ref, h_ref, o_ref):
    # Grid: (d/bd, d/bd, n/bn). Each step accumulates
    #   (A_i-slab * h-block) @ A_j-slabᵀ  into output tile (i, j).
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    scaled = ai_ref[...] * h_ref[...][None, :]
    o_ref[...] += jnp.dot(
        scaled, aj_ref[...].T, preferred_element_type=ai_ref.dtype
    )


def weighted_gram(
    a: jax.Array, h: jax.Array, *, bd: int | None = None, bn: int | None = None
) -> jax.Array:
    """H = A·diag(h)·Aᵀ with A (d, n), h (n,) → (d, d)."""
    d, n = a.shape
    dbd, dbn = pick_blocks(d, n)
    bd = bd or dbd
    bn = bn or dbn
    grid = (d // bd, d // bd, n // bn)
    return pl.pallas_call(
        _wgram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd, bn), lambda i, j, k: (i, k)),
            pl.BlockSpec((bd, bn), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bd, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), a.dtype),
        interpret=True,
    )(a, a, h)


__all__ = ["margins", "matvec", "weighted_gram", "pick_blocks"]

//! Dense training dataset + client sharding.
//!
//! Follows the paper's preparation pipeline exactly (§5, App. B): every
//! sample is augmented with a constant-1 intercept feature, labels are
//! absorbed into the design matrix (column_j = b_j·a_j, §5.13 — so
//! labels need not be stored), the dataset is reshuffled u.a.r., split
//! into equal nᵢ-sized shards across n clients, and leftovers dropped.
//!
//! Storage is `At`: an (n_samples × d) row-major matrix whose *rows* are
//! samples — so margins (row·x) and rank-1 Hessian updates touch
//! contiguous memory (paper v53 stores only one orientation).

use super::libsvm::LibsvmSample;
use crate::linalg::Mat;
use crate::rng::{shuffle, Pcg64};

/// Dense dataset with labels absorbed and intercept appended.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// (n × d) row-major; row j is b_j · [a_j, 1].
    pub at: Mat,
    /// Feature dimension *including* the intercept column.
    pub d: usize,
}

impl Dataset {
    /// Densify parsed LIBSVM samples; `d_raw` excludes the intercept.
    pub fn from_libsvm(samples: &[LibsvmSample], d_raw: usize) -> Self {
        let d = d_raw + 1; // +1 intercept (paper: "augmented each sample")
        let n = samples.len();
        let mut at = Mat::zeros(n, d);
        for (r, s) in samples.iter().enumerate() {
            let row = at.row_mut(r);
            for &(idx, val) in &s.features {
                row[idx as usize] = s.label * val;
            }
            row[d - 1] = s.label; // b_j · 1
        }
        Self { at, d }
    }

    /// Build directly from a dense matrix whose rows already absorb
    /// labels and intercept (synthetic generator path).
    pub fn from_dense(at: Mat) -> Self {
        let d = at.cols();
        Self { at, d }
    }

    pub fn n_samples(&self) -> usize {
        self.at.rows()
    }

    /// Reshuffle samples u.a.r. in place with the given seed.
    pub fn reshuffle(&mut self, seed: u64) {
        let n = self.n_samples();
        let mut order: Vec<u32> = (0..n as u32).collect();
        let mut rng = Pcg64::seed_from_u64(seed);
        shuffle(&mut rng, &mut order);
        let mut shuffled = Mat::zeros(n, self.d);
        for (dst, &src) in order.iter().enumerate() {
            shuffled.row_mut(dst).copy_from_slice(self.at.row(src as usize));
        }
        self.at = shuffled;
    }

    /// Split into `n_clients` equal shards of `n_i` samples each
    /// (leftover samples are excluded, as in the paper: "the remaining
    /// 49 samples were excluded"). Returns an error if there is not
    /// enough data.
    pub fn split(
        &self,
        n_clients: usize,
        n_i: usize,
    ) -> anyhow::Result<Vec<ClientShard>> {
        anyhow::ensure!(n_clients > 0 && n_i > 0, "empty split");
        anyhow::ensure!(
            n_clients * n_i <= self.n_samples(),
            "split needs {} samples, dataset has {}",
            n_clients * n_i,
            self.n_samples()
        );
        let mut shards = Vec::with_capacity(n_clients);
        for c in 0..n_clients {
            let mut at = Mat::zeros(n_i, self.d);
            for r in 0..n_i {
                at.row_mut(r).copy_from_slice(self.at.row(c * n_i + r));
            }
            shards.push(ClientShard { client_id: c, at });
        }
        Ok(shards)
    }

    /// Split into `n_clients` shards of `total / n_clients` samples.
    pub fn split_even(&self, n_clients: usize) -> anyhow::Result<Vec<ClientShard>> {
        let n_i = self.n_samples() / n_clients;
        self.split(n_clients, n_i)
    }

    /// Split into explicitly sized shards — the non-IID client-size
    /// knob. Shard `c` takes the next `sizes[c]` samples in order;
    /// leftover samples are dropped as in [`Dataset::split`]. Pair
    /// with [`power_law_sizes`] for Zipf-like size heterogeneity.
    pub fn split_sizes(
        &self,
        sizes: &[usize],
    ) -> anyhow::Result<Vec<ClientShard>> {
        anyhow::ensure!(
            !sizes.is_empty() && sizes.iter().all(|&s| s > 0),
            "empty split"
        );
        let total: usize = sizes.iter().sum();
        anyhow::ensure!(
            total <= self.n_samples(),
            "split needs {} samples, dataset has {}",
            total,
            self.n_samples()
        );
        let mut shards = Vec::with_capacity(sizes.len());
        let mut start = 0;
        for (c, &n_i) in sizes.iter().enumerate() {
            let mut at = Mat::zeros(n_i, self.d);
            for r in 0..n_i {
                at.row_mut(r).copy_from_slice(self.at.row(start + r));
            }
            start += n_i;
            shards.push(ClientShard { client_id: c, at });
        }
        Ok(shards)
    }

    /// Label-skew non-IID split: each client draws a `skew` fraction
    /// of its `n_i` samples from its *preferred* label class (even
    /// client ids prefer `+1`, odd prefer `−1`) and the rest from the
    /// other class, falling back to whichever class still has samples
    /// once one pool runs dry. Labels are recovered from the absorbed
    /// intercept column (row = b·[a, 1], so sign(at[r][d−1]) = b).
    /// Both class pools are shuffled with `seed`, making the split a
    /// pure function of (dataset, n_clients, n_i, skew, seed) —
    /// reproducible across transports and runs. `skew = 0.5` is a
    /// balanced draw; `skew = 1.0` gives each client one label class.
    pub fn split_label_skew(
        &self,
        n_clients: usize,
        n_i: usize,
        skew: f64,
        seed: u64,
    ) -> anyhow::Result<Vec<ClientShard>> {
        anyhow::ensure!(n_clients > 0 && n_i > 0, "empty split");
        anyhow::ensure!(
            (0.0..=1.0).contains(&skew),
            "label skew must be in [0, 1], got {skew}"
        );
        anyhow::ensure!(
            n_clients * n_i <= self.n_samples(),
            "split needs {} samples, dataset has {}",
            n_clients * n_i,
            self.n_samples()
        );
        let d = self.d;
        let mut pos: Vec<u32> = Vec::new();
        let mut neg: Vec<u32> = Vec::new();
        for r in 0..self.n_samples() {
            if self.at.row(r)[d - 1] >= 0.0 {
                pos.push(r as u32);
            } else {
                neg.push(r as u32);
            }
        }
        let mut rng = Pcg64::seed_from_u64(seed);
        shuffle(&mut rng, &mut pos);
        shuffle(&mut rng, &mut neg);
        let n_pref = ((skew * n_i as f64).round() as usize).min(n_i);
        let mut shards = Vec::with_capacity(n_clients);
        for c in 0..n_clients {
            let (pref, other) = if c % 2 == 0 {
                (&mut pos, &mut neg)
            } else {
                (&mut neg, &mut pos)
            };
            let mut at = Mat::zeros(n_i, d);
            for r in 0..n_i {
                let src = if r < n_pref {
                    pref.pop().or_else(|| other.pop())
                } else {
                    other.pop().or_else(|| pref.pop())
                };
                // Unreachable given the ensure! above, but keep the
                // invariant explicit rather than unwrapping.
                let src = match src {
                    Some(s) => s as usize,
                    None => anyhow::bail!("label-skew split ran dry"),
                };
                at.row_mut(r).copy_from_slice(self.at.row(src));
            }
            shards.push(ClientShard { client_id: c, at });
        }
        Ok(shards)
    }
}

/// How a dataset is partitioned across clients — the CLI/harness-facing
/// selector over the split primitives (`--split` / `--label-skew`).
/// Every variant is a pure function of (dataset, n_clients, n_i, seed),
/// so trajectories stay bit-reproducible across transports.
#[derive(Debug, Clone, PartialEq)]
pub enum SplitSpec {
    /// IID equal shards (the paper's default).
    Even,
    /// Zipf-like size heterogeneity: client c's share ∝ (c+1)^−γ
    /// (`--split power_law:GAMMA`; see [`power_law_sizes`]).
    PowerLaw(f64),
    /// Label-skew non-IID: each client draws this fraction of its
    /// samples from its preferred class (`--label-skew P`; see
    /// [`Dataset::split_label_skew`]).
    LabelSkew(f64),
}

impl SplitSpec {
    /// Parse the `--split` argument: `even` | `power_law:GAMMA`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        if s == "even" {
            return Ok(Self::Even);
        }
        if let Some(g) = s.strip_prefix("power_law:") {
            let gamma: f64 = g.parse().map_err(|_| {
                anyhow::anyhow!(
                    "--split power_law:GAMMA: bad gamma '{g}'"
                )
            })?;
            anyhow::ensure!(
                gamma.is_finite() && gamma >= 0.0,
                "--split power_law: gamma must be finite and >= 0"
            );
            return Ok(Self::PowerLaw(gamma));
        }
        anyhow::bail!("unknown --split '{s}' (even | power_law:GAMMA)")
    }

    /// Produce the shards: `n_clients` clients over a `n_clients × n_i`
    /// sample budget. `Even` is exactly [`Dataset::split`], so the
    /// default path is byte-for-byte the historical behavior.
    pub fn shards(
        &self,
        ds: &Dataset,
        n_clients: usize,
        n_i: usize,
        seed: u64,
    ) -> anyhow::Result<Vec<ClientShard>> {
        match self {
            Self::Even => ds.split(n_clients, n_i),
            Self::PowerLaw(gamma) => ds.split_sizes(&power_law_sizes(
                n_clients,
                n_clients * n_i,
                *gamma,
            )),
            Self::LabelSkew(p) => {
                ds.split_label_skew(n_clients, n_i, *p, seed)
            }
        }
    }
}

/// Power-law client sizes for non-IID experiments: client `c`'s share
/// of `total` is proportional to (c+1)^−gamma (Zipf-like; `gamma = 0`
/// is the even IID split, larger gamma concentrates data on low-id
/// clients). Every client gets at least one sample and the sizes sum
/// to exactly `total`. Fully deterministic — pair with
/// [`Dataset::split_sizes`].
pub fn power_law_sizes(
    n_clients: usize,
    total: usize,
    gamma: f64,
) -> Vec<usize> {
    assert!(
        n_clients > 0 && total >= n_clients,
        "power_law_sizes needs ≥ 1 sample per client"
    );
    let w: Vec<f64> =
        (0..n_clients).map(|c| ((c + 1) as f64).powf(-gamma)).collect();
    let wsum: f64 = w.iter().sum();
    let mut sizes: Vec<usize> = w
        .iter()
        .map(|wi| ((total as f64 * wi / wsum) as usize).max(1))
        .collect();
    let mut assigned: usize = sizes.iter().sum();
    // The 1-sample floor can over-assign; shave the largest shards.
    while assigned > total {
        let i = (0..n_clients).max_by_key(|&i| sizes[i]).unwrap();
        sizes[i] -= 1;
        assigned -= 1;
    }
    // Flooring under-assigns by < n_clients; top up head-first so the
    // remainder follows the same heavy-head shape.
    let mut c = 0;
    while assigned < total {
        sizes[c % n_clients] += 1;
        assigned += 1;
        c += 1;
    }
    sizes
}

/// One client's local data (FedNL never moves raw data off the client).
#[derive(Debug, Clone)]
pub struct ClientShard {
    pub client_id: usize,
    /// (n_i × d) rows = local samples with labels/intercept absorbed.
    pub at: Mat,
}

impl ClientShard {
    pub fn n_i(&self) -> usize {
        self.at.rows()
    }

    pub fn d(&self) -> usize {
        self.at.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::libsvm::parse_libsvm_bytes;

    fn toy() -> Dataset {
        let (s, d) =
            parse_libsvm_bytes(b"+1 1:2 2:3\n-1 1:-1\n+1 2:5\n-1 2:-4\n")
                .unwrap();
        Dataset::from_libsvm(&s, d)
    }

    #[test]
    fn densify_absorbs_labels_and_intercept() {
        let ds = toy();
        assert_eq!(ds.d, 3);
        assert_eq!(ds.n_samples(), 4);
        // Sample 0: +1 * [2, 3, 1]
        assert_eq!(ds.at.row(0), &[2.0, 3.0, 1.0]);
        // Sample 1: -1 * [-1, 0, 1]
        assert_eq!(ds.at.row(1), &[1.0, 0.0, -1.0]);
    }

    #[test]
    fn reshuffle_preserves_multiset() {
        let mut ds = toy();
        let before: Vec<Vec<f64>> =
            (0..4).map(|i| ds.at.row(i).to_vec()).collect();
        ds.reshuffle(42);
        let mut after: Vec<Vec<f64>> =
            (0..4).map(|i| ds.at.row(i).to_vec()).collect();
        let mut b = before.clone();
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        after.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(b, after);
    }

    #[test]
    fn reshuffle_deterministic() {
        let mut a = toy();
        let mut b = toy();
        a.reshuffle(7);
        b.reshuffle(7);
        assert_eq!(a.at, b.at);
    }

    #[test]
    fn split_shapes_and_leftovers() {
        let ds = toy();
        let shards = ds.split(2, 2).unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].n_i(), 2);
        assert_eq!(shards[1].client_id, 1);
        // 3 clients × 2 samples needs 6 > 4 → error
        assert!(ds.split(3, 2).is_err());
        // uneven split drops leftovers
        let se = ds.split_even(3).unwrap();
        assert_eq!(se.len(), 3);
        assert_eq!(se[0].n_i(), 1);
    }

    /// n_pos positive then n_neg negative samples, distinguishable by
    /// their first column (±(r+1)); intercept column carries the sign.
    fn labeled(n_pos: usize, n_neg: usize) -> Dataset {
        let n = n_pos + n_neg;
        let mut at = Mat::zeros(n, 2);
        for r in 0..n {
            let b = if r < n_pos { 1.0 } else { -1.0 };
            let row = at.row_mut(r);
            row[0] = b * (r as f64 + 1.0);
            row[1] = b;
        }
        Dataset::from_dense(at)
    }

    #[test]
    fn split_spec_parses_and_matches_primitives() {
        assert_eq!(SplitSpec::parse("even").unwrap(), SplitSpec::Even);
        assert_eq!(
            SplitSpec::parse("power_law:1.5").unwrap(),
            SplitSpec::PowerLaw(1.5)
        );
        for bad in
            ["zipf", "power_law:", "power_law:x", "power_law:-1", ""]
        {
            assert!(SplitSpec::parse(bad).is_err(), "accepted '{bad}'");
        }
        // Even delegates to split() exactly (the IID default must stay
        // byte-for-byte the historical behavior).
        let ds = toy();
        let a = SplitSpec::Even.shards(&ds, 2, 2, 9).unwrap();
        let b = ds.split(2, 2).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
        }
        // PowerLaw(0) is the even per-size split over the same budget.
        let p = SplitSpec::PowerLaw(0.0).shards(&ds, 2, 2, 9).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].n_i() + p[1].n_i(), 4);
        // LabelSkew is seeded-deterministic.
        let s1 = SplitSpec::LabelSkew(1.0).shards(&ds, 2, 2, 9).unwrap();
        let s2 = SplitSpec::LabelSkew(1.0).shards(&ds, 2, 2, 9).unwrap();
        for (x, y) in s1.iter().zip(&s2) {
            assert_eq!(x.at, y.at);
        }
    }

    #[test]
    fn split_sizes_shapes_and_errors() {
        let ds = toy(); // 4 samples
        let shards = ds.split_sizes(&[2, 1]).unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].n_i(), 2);
        assert_eq!(shards[1].n_i(), 1);
        assert_eq!(shards[1].client_id, 1);
        // shard 1 starts where shard 0 ended (leftover row 3 dropped)
        assert_eq!(shards[1].at.row(0), ds.at.row(2));
        assert!(ds.split_sizes(&[3, 2]).is_err());
        assert!(ds.split_sizes(&[2, 0]).is_err());
        assert!(ds.split_sizes(&[]).is_err());
    }

    #[test]
    fn power_law_sizes_shape() {
        assert_eq!(power_law_sizes(4, 100, 0.0), vec![25, 25, 25, 25]);
        let z = power_law_sizes(4, 100, 1.0);
        assert_eq!(z.iter().sum::<usize>(), 100);
        assert!(z.windows(2).all(|w| w[0] >= w[1]), "{z:?}");
        assert!(z[0] >= 2 * z[3], "gamma=1 head/tail too flat: {z:?}");
        // the 1-sample floor engages and still sums exactly
        let f = power_law_sizes(8, 10, 5.0);
        assert_eq!(f.iter().sum::<usize>(), 10);
        assert!(f.iter().all(|&s| s >= 1), "{f:?}");
        // determinism
        assert_eq!(power_law_sizes(7, 997, 1.3), power_law_sizes(7, 997, 1.3));
    }

    #[test]
    fn label_skew_split_is_seeded_and_skewed() {
        let ds = labeled(8, 8);
        let a = ds.split_label_skew(4, 4, 1.0, 9).unwrap();
        let b = ds.split_label_skew(4, 4, 1.0, 9).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at, "same seed must reproduce the split");
        }
        // skew = 1: even clients all-positive, odd all-negative
        for sh in &a {
            let want = if sh.client_id % 2 == 0 { 1.0 } else { -1.0 };
            for r in 0..sh.n_i() {
                assert_eq!(sh.at.row(r)[1], want, "client {}", sh.client_id);
            }
        }
        // a different seed reorders the pools
        let c = ds.split_label_skew(4, 4, 1.0, 10).unwrap();
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.at != y.at),
            "seed had no effect"
        );
        // skew = 0.5 draws a balanced 2 + 2 per client
        for sh in &ds.split_label_skew(2, 4, 0.5, 9).unwrap() {
            let pos =
                (0..4).filter(|&r| sh.at.row(r)[1] > 0.0).count();
            assert_eq!(pos, 2, "client {}", sh.client_id);
        }
        // pool exhaustion falls back to the other class: 12+4 split,
        // client 0 takes 8 of the 12 positives, client 1 wants 8
        // negatives but only 4 exist — gets 4 neg + 4 pos.
        let skew = labeled(12, 4);
        let sh = skew.split_label_skew(2, 8, 1.0, 1).unwrap();
        let neg1 =
            (0..8).filter(|&r| sh[1].at.row(r)[1] < 0.0).count();
        assert_eq!(neg1, 4);
        // asking for more samples than exist errors
        assert!(ds.split_label_skew(5, 4, 1.0, 1).is_err());
        assert!(ds.split_label_skew(2, 4, 1.5, 1).is_err());
    }
}

//! Reproducible f64 summation: an exact fixed-point superaccumulator.
//!
//! Every cross-client reduction in FedNL is a sum of f64 quantities —
//! gradients, lᵢ distances, losses, sparse Hessian updates. Plain f64
//! folding is **not associative**, so until this layer existed the
//! whole determinism story rested on *order discipline*: every
//! transport and every shard tier had to reduce in exactly the same
//! grouping, and shards could only forward per-client atoms (O(n·d)
//! fan-in). [`RepAcc`] removes the constraint at the arithmetic level,
//! in the style of Demmel–Nguyen reproducible (binned) summation taken
//! to its exact limit (a Kulisch-style long accumulator):
//!
//! * the running sum is held as a **fixed-point integer** spanning the
//!   full f64 exponent range — [`LIMBS`] i64 limbs of [`LIMB_BITS`]
//!   value bits each, limb `j` weighted 2^((j−[`BIAS_LIMB`])·32);
//! * [`RepAcc::accumulate`] decomposes an f64 into (sign, 53-bit
//!   mantissa, exponent) and adds it into at most three limbs —
//!   **exact integer arithmetic**, no rounding anywhere;
//! * therefore `accumulate`/[`RepAcc::merge`] are exactly associative
//!   and permutation-invariant, and [`RepAcc::round`] performs the one
//!   and only rounding (round-to-nearest-even of the exact sum) at the
//!   very end.
//!
//! Consequences the coordination layer builds on: a sum is
//! bit-identical no matter how the terms were grouped (flat master,
//! S-shard pre-reduction, any arrival order, any thread count), and a
//! shard can forward **one merged accumulator** instead of per-client
//! atoms without perturbing the master's result by a single ulp.
//!
//! # Special values
//!
//! Non-finite inputs never touch the limbs; they are latched in a
//! 3-bit special mask with IEEE "any-order sum" semantics: any NaN →
//! NaN; +∞ and −∞ together → NaN; a single-signed ∞ → that ∞. This is
//! itself permutation-invariant (unlike a sequential IEEE fold, where
//! `inf + (-inf)` poisons only later terms). Signed zeros contribute
//! nothing: the sum of `-0.0`s rounds to `+0.0` (numerically equal;
//! documented divergence from a sequential IEEE fold). If the exact
//! sum exceeds the f64 range, [`RepAcc::round`] returns ±∞ — the
//! correctly rounded value, never a silently wrong finite number.
//!
//! # Wire form
//!
//! A freshly summed accumulator is *sparse in limbs*: values of
//! similar magnitude touch a handful of adjacent limbs. The codec
//! therefore ships only the `[lo, hi]` window of nonzero limbs
//! (3 bytes of header + 8 bytes per limb — ~30–60 bytes for typical
//! sums), which is what keeps `SHARD_SUM` frames compact.
//!
//! The bulk entry point [`RepAcc::accumulate_slice`] dispatches to
//! [`crate::linalg::simd::binned_accumulate`] (AVX2-assisted decompose
//! + scalar scatter, with a 4-way unrolled scalar fallback). Both ISA
//! paths produce **identical limbs** — the arithmetic is integer-exact,
//! so unlike the float kernels there is no cross-ISA divergence at all.

use crate::utils::{ByteReader, ByteWriter};

/// Value bits per limb (the limb *stride*; limbs are i64 so the upper
/// 32 bits are carry headroom between propagations).
pub const LIMB_BITS: u32 = 32;

/// Limb count: weights run from 2^-1088 (limb 0) to 2^1056 (limb 67),
/// covering every finite f64 (2^-1074 … 2^1023·(2−2^-52)) plus carry
/// headroom far beyond any realistic term count.
pub const LIMBS: usize = 68;

/// Limb index whose bit 0 has weight 2^0.
pub const BIAS_LIMB: usize = 34;

/// Bit offset of weight 2^e inside the limb array: e + 32·BIAS_LIMB.
const OFFSET_BIAS: i32 = (BIAS_LIMB as i32) * 32;

/// Accumulations allowed between carry propagations. Each accumulate
/// adds chunks < 2^32 to at most 3 limbs; starting from canonical
/// limbs (< 2^32) the worst-case magnitude after k accumulates is
/// (k+1)·2^32, so 2^30 keeps every limb comfortably inside i64.
const PENDING_MAX: u32 = 1 << 30;

/// Special-value mask bits (IEEE any-order-sum semantics).
pub const SP_POS_INF: u8 = 1;
pub const SP_NEG_INF: u8 = 2;
pub const SP_NAN: u8 = 4;

/// Decompose-and-add one f64 into the limb array. Exact; returns the
/// special mask contribution (0 for finite inputs). Shared by the
/// scalar and AVX2 bulk kernels in [`crate::linalg::simd`] so every
/// path performs the identical integer operation.
#[inline]
pub(crate) fn accumulate_one(limbs: &mut [i64; LIMBS], x: f64) -> u8 {
    let b = x.to_bits();
    let exp = ((b >> 52) & 0x7ff) as i32;
    let frac = b & ((1u64 << 52) - 1);
    if exp == 0x7ff {
        return if frac != 0 {
            SP_NAN
        } else if b >> 63 == 1 {
            SP_NEG_INF
        } else {
            SP_POS_INF
        };
    }
    if exp == 0 && frac == 0 {
        return 0; // ±0 contributes nothing
    }
    let mant = if exp == 0 { frac } else { frac | (1u64 << 52) };
    // value = mant · 2^(max(exp,1) − 1075)
    add_mantissa(limbs, mant, exp.max(1) - 1075, b >> 63 == 1);
    0
}

/// Exact scatter of a decomposed finite value `±mant · 2^e2` into the
/// limb array (the shared core of the scalar and AVX2 bulk kernels).
#[inline]
pub(crate) fn add_mantissa(
    limbs: &mut [i64; LIMBS],
    mant: u64,
    e2: i32,
    neg: bool,
) {
    let off = (e2 + OFFSET_BIAS) as usize; // ≥ 14 by construction
    let (j, sh) = (off >> 5, off & 31);
    let wide = (mant as u128) << sh; // ≤ 2^84: spans ≤ 3 limbs
    let c0 = (wide & 0xFFFF_FFFF) as i64;
    let c1 = ((wide >> 32) & 0xFFFF_FFFF) as i64;
    let c2 = ((wide >> 64) & 0xFFFF_FFFF) as i64;
    if neg {
        limbs[j] -= c0;
        limbs[j + 1] -= c1;
        limbs[j + 2] -= c2;
    } else {
        limbs[j] += c0;
        limbs[j + 1] += c1;
        limbs[j + 2] += c2;
    }
}

/// Carry-propagate into canonical form: limbs 0..LIMBS−1 land in
/// [0, 2^32), the top limb keeps the (signed) remainder. The
/// represented value is unchanged — propagation commutes with every
/// accumulate/merge, which is what makes the arithmetic associative.
pub(crate) fn propagate_limbs(limbs: &mut [i64; LIMBS]) {
    let mut carry: i64 = 0;
    for l in limbs.iter_mut().take(LIMBS - 1) {
        let v = *l as i128 + carry as i128;
        let c = (v >> 32) as i64; // arithmetic shift: floor division
        *l = (v - ((c as i128) << 32)) as i64; // in [0, 2^32)
        carry = c;
    }
    limbs[LIMBS - 1] += carry;
}

/// Exact, reproducible f64 accumulator (see the module docs).
#[derive(Debug, Clone)]
pub struct RepAcc {
    limbs: [i64; LIMBS],
    /// Accumulates since the last propagation (carry-overflow guard).
    pending: u32,
    /// Latched non-finite state (SP_* bits).
    special: u8,
}

impl Default for RepAcc {
    fn default() -> Self {
        Self::new()
    }
}

impl RepAcc {
    pub fn new() -> Self {
        Self { limbs: [0; LIMBS], pending: 0, special: 0 }
    }

    /// Reset to the empty sum (keeps the allocation-free layout).
    pub fn reset(&mut self) {
        self.limbs = [0; LIMBS];
        self.pending = 0;
        self.special = 0;
    }

    /// True iff nothing (finite or special) has been accumulated.
    pub fn is_zero(&self) -> bool {
        self.special == 0 && self.limbs.iter().all(|&l| l == 0)
    }

    /// Add one term. Exact — the represented sum after this call is
    /// the mathematical sum, independent of call order.
    #[inline]
    pub fn accumulate(&mut self, x: f64) {
        self.special |= accumulate_one(&mut self.limbs, x);
        self.pending += 1;
        if self.pending >= PENDING_MAX {
            self.propagate();
        }
    }

    /// Bulk accumulate through the runtime-dispatched kernel
    /// (`simd::binned_accumulate`); limb-identical to a scalar loop.
    pub fn accumulate_slice(&mut self, xs: &[f64]) {
        self.propagate();
        self.special |=
            super::simd::binned_accumulate(&mut self.limbs, xs);
        // The kernel propagates before returning.
    }

    /// Scalar-fallback bulk accumulate (microbench A/B partner of
    /// [`RepAcc::accumulate_slice`]; results are limb-identical).
    pub fn accumulate_slice_scalar(&mut self, xs: &[f64]) {
        self.propagate();
        self.special |=
            super::simd::scalar::binned_accumulate(&mut self.limbs, xs);
    }

    /// Fold another accumulator in. Exact and symmetric: any merge
    /// tree over any partition of the terms yields identical state.
    pub fn merge(&mut self, mut other: RepAcc) {
        self.propagate();
        other.propagate();
        for (a, b) in self.limbs.iter_mut().zip(other.limbs.iter()) {
            *a += *b;
        }
        self.special |= other.special;
        self.pending = 2; // canonical + canonical stays far below i64
    }

    pub(crate) fn propagate(&mut self) {
        if self.pending != 0 {
            propagate_limbs(&mut self.limbs);
            self.pending = 0;
        }
    }

    /// Round the exact sum to the nearest f64 (ties to even) — the
    /// single rounding of the whole reduction. Non-finite inputs
    /// resolve with IEEE any-order semantics; an exact sum beyond the
    /// f64 range returns ±∞ (the correctly rounded value).
    pub fn round(&mut self) -> f64 {
        if self.special != 0 {
            if self.special & SP_NAN != 0
                || self.special & (SP_POS_INF | SP_NEG_INF)
                    == SP_POS_INF | SP_NEG_INF
            {
                return f64::NAN;
            }
            return if self.special & SP_POS_INF != 0 {
                f64::INFINITY
            } else {
                f64::NEG_INFINITY
            };
        }
        let (limbs, neg) = self.magnitude();
        let Some(h) = (0..LIMBS).rev().find(|&j| limbs[j] != 0) else {
            return 0.0;
        };
        debug_assert!(limbs[h] > 0);
        let bits_h = 64 - (limbs[h] as u64).leading_zeros() as i32;
        // Exponent of the most significant bit of the magnitude.
        let t = (h as i32 - BIAS_LIMB as i32) * 32 + bits_h - 1;
        // Gather the top window (up to 3 limbs) into a u128; bits
        // below the window only matter as a sticky flag.
        let mut acc: u128 = limbs[h] as u128;
        let mut e_lsb = (h as i32 - BIAS_LIMB as i32) * 32;
        let mut lo_edge = h;
        for _ in 0..2 {
            if lo_edge == 0 {
                break;
            }
            lo_edge -= 1;
            acc = (acc << 32) | (limbs[lo_edge] as u128);
            e_lsb -= 32;
        }
        let sticky_low = limbs[..lo_edge].iter().any(|&l| l != 0);
        // Mantissa LSB exponent: 53 significant bits, or the subnormal
        // floor 2^-1074. Contributions are multiples of 2^-1074, so
        // t ≥ -1074 and the shift below is always ≥ 1.
        let mut q = (t - 52).max(-1074);
        let shift = (q - e_lsb) as u32;
        debug_assert!(shift >= 1);
        let mut m = (acc >> shift) as u64;
        let round_bit = (acc >> (shift - 1)) & 1 == 1;
        let sticky =
            sticky_low || (acc & ((1u128 << (shift - 1)) - 1)) != 0;
        if round_bit && (sticky || m & 1 == 1) {
            m += 1;
        }
        if m == 1u64 << 53 {
            m >>= 1;
            q += 1;
        }
        let mag_bits = if m >= 1u64 << 52 {
            let e = q + 1075; // biased exponent
            if e >= 0x7ff {
                0x7ff0_0000_0000_0000 // overflow: correctly rounds to ∞
            } else {
                ((e as u64) << 52) | (m & ((1u64 << 52) - 1))
            }
        } else {
            debug_assert_eq!(q, -1074);
            m // subnormal
        };
        f64::from_bits(mag_bits | if neg { 1u64 << 63 } else { 0 })
    }

    /// Canonical sign-magnitude view: (limbs of |value|, canonical —
    /// every limb in [0, 2^32) except a tiny non-negative top —, and
    /// whether the value is negative). The shared core of [`round`],
    /// [`encode`] and [`encoded_bytes`]: the two's-complement-like
    /// canonical form of a *negative* total carries a long run of
    /// 2^32−1 limbs up to the sign-carrying top, so the compact wire
    /// window must be taken over the magnitude, never the raw limbs.
    ///
    /// [`round`]: RepAcc::round
    /// [`encode`]: RepAcc::encode
    /// [`encoded_bytes`]: RepAcc::encoded_bytes
    fn magnitude(&mut self) -> ([i64; LIMBS], bool) {
        self.propagate();
        let mut limbs = self.limbs;
        // Canonical form: sign of the value = sign of the top limb.
        let neg = limbs[LIMBS - 1] < 0;
        if neg {
            for l in limbs.iter_mut() {
                *l = -*l;
            }
            propagate_limbs(&mut limbs);
        }
        (limbs, neg)
    }

    // --- compact wire form (sign + magnitude-limb window) ------------

    const FLAG_NEG: u8 = 8;

    /// Exact byte length [`RepAcc::encode`] will produce.
    pub fn encoded_bytes(&mut self) -> u64 {
        let (limbs, _) = self.magnitude();
        3 + 8 * window_of(&limbs).map_or(0, |(lo, hi)| hi - lo + 1) as u64
    }

    /// Serialize: flags byte (special mask | sign bit), window start,
    /// window length, magnitude limbs. Every magnitude limb of a real
    /// sum is < 2^32 (values would need to reach 2^1088 otherwise), so
    /// the window stays a handful of limbs for either sign.
    pub fn encode(&mut self, w: &mut ByteWriter) {
        let (limbs, neg) = self.magnitude();
        w.put_u8(self.special | if neg { Self::FLAG_NEG } else { 0 });
        match window_of(&limbs) {
            None => {
                w.put_u8(0);
                w.put_u8(0);
            }
            Some((lo, hi)) => {
                w.put_u8(lo as u8);
                w.put_u8((hi - lo + 1) as u8);
                for l in &limbs[lo..=hi] {
                    w.put_u64(*l as u64);
                }
            }
        }
    }

    /// Decode network-facing input: the window must fit, every limb
    /// must be a valid magnitude limb (< 2^32 — rejects values no real
    /// sum can produce and keeps all downstream limb arithmetic far
    /// from i64 overflow), and the result is left one propagation away
    /// from canonical (`pending = 1`), so merge/round always
    /// canonicalize before touching it.
    pub fn decode(r: &mut ByteReader) -> anyhow::Result<RepAcc> {
        let flags = r.get_u8()?;
        anyhow::ensure!(flags <= 0xf, "bad RepAcc flags {flags:#x}");
        let special = flags & 0x7;
        let neg = flags & Self::FLAG_NEG != 0;
        let lo = r.get_u8()? as usize;
        let count = r.get_u8()? as usize;
        anyhow::ensure!(
            lo + count <= LIMBS,
            "RepAcc window [{lo}, {lo}+{count}) exceeds {LIMBS} limbs"
        );
        let mut acc = RepAcc::new();
        acc.special = special;
        for j in lo..lo + count {
            let v = r.get_u64()?;
            anyhow::ensure!(
                v < 1 << 32,
                "RepAcc limb {v:#x} out of magnitude range"
            );
            acc.limbs[j] = if neg { -(v as i64) } else { v as i64 };
        }
        acc.pending = 1;
        Ok(acc)
    }
}

/// `[lo, hi]` of the nonzero limbs (None = zero).
fn window_of(limbs: &[i64; LIMBS]) -> Option<(usize, usize)> {
    let lo = limbs.iter().position(|&l| l != 0)?;
    let hi = limbs.iter().rposition(|&l| l != 0).unwrap();
    Some((lo, hi))
}

/// A vector of accumulators: elementwise-exact folding of d-vectors
/// (gradient sums, packed warm-start sums).
#[derive(Debug, Clone, Default)]
pub struct RepVec {
    accs: Vec<RepAcc>,
}

impl RepVec {
    pub fn new(d: usize) -> Self {
        Self { accs: (0..d).map(|_| RepAcc::new()).collect() }
    }

    pub fn len(&self) -> usize {
        self.accs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.accs.is_empty()
    }

    pub fn reset(&mut self) {
        for a in &mut self.accs {
            a.reset();
        }
    }

    /// Elementwise `acc[j] += xs[j]`, exactly. An empty RepVec adopts
    /// the length of the first slice it sees.
    pub fn accumulate(&mut self, xs: &[f64]) {
        if self.accs.is_empty() {
            self.accs = (0..xs.len()).map(|_| RepAcc::new()).collect();
        }
        assert_eq!(self.accs.len(), xs.len(), "RepVec length mismatch");
        // 4-way unrolled: independent decomposes, exact scatters.
        let chunks = xs.len() / 4;
        for c in 0..chunks {
            let i = c * 4;
            self.accs[i].accumulate(xs[i]);
            self.accs[i + 1].accumulate(xs[i + 1]);
            self.accs[i + 2].accumulate(xs[i + 2]);
            self.accs[i + 3].accumulate(xs[i + 3]);
        }
        for i in chunks * 4..xs.len() {
            self.accs[i].accumulate(xs[i]);
        }
    }

    /// Elementwise merge. Either side may be empty (the identity).
    pub fn merge(&mut self, other: RepVec) {
        if other.accs.is_empty() {
            return;
        }
        if self.accs.is_empty() {
            self.accs = other.accs;
            return;
        }
        assert_eq!(self.accs.len(), other.accs.len());
        for (a, b) in self.accs.iter_mut().zip(other.accs) {
            a.merge(b);
        }
    }

    /// Round every component (the single rounding per component).
    pub fn round_vec(&mut self) -> Vec<f64> {
        self.accs.iter_mut().map(|a| a.round()).collect()
    }

    pub fn encoded_bytes(&mut self) -> u64 {
        4 + self
            .accs
            .iter_mut()
            .map(|a| a.encoded_bytes())
            .sum::<u64>()
    }

    pub fn encode(&mut self, w: &mut ByteWriter) {
        w.put_u32(self.accs.len() as u32);
        for a in &mut self.accs {
            a.encode(w);
        }
    }

    /// Decode with an explicit length bound (network-facing input: a
    /// bogus length must error before any allocation happens — the
    /// same rule the `ByteReader` primitives follow).
    pub fn decode(
        r: &mut ByteReader,
        max_len: usize,
    ) -> anyhow::Result<RepVec> {
        let n = r.get_u32()? as usize;
        anyhow::ensure!(
            n <= max_len,
            "RepVec length {n} exceeds the expected bound {max_len}"
        );
        let mut accs = Vec::with_capacity(n);
        for _ in 0..n {
            accs.push(RepAcc::decode(r)?);
        }
        Ok(RepVec { accs })
    }
}

/// A sparse map `index → RepAcc` for summing sparse contributions
/// (the compressed Hessian updates). Slots persist across
/// [`SparseRepVec::reset`] via a generation stamp, so steady-state
/// rounds allocate nothing.
#[derive(Debug, Clone, Default)]
pub struct SparseRepVec {
    slots: Vec<Option<Box<RepAcc>>>,
    stamp: Vec<u32>,
    touched: Vec<u32>,
    gen: u32,
}

impl SparseRepVec {
    pub fn new() -> Self {
        Self {
            slots: Vec::new(),
            stamp: Vec::new(),
            touched: Vec::new(),
            gen: 1,
        }
    }

    /// Entries touched since the last reset.
    pub fn len(&self) -> usize {
        self.touched.len()
    }

    pub fn is_empty(&self) -> bool {
        self.touched.is_empty()
    }

    pub fn reset(&mut self) {
        // Lazy clear: bumping the generation invalidates every slot
        // without touching their limbs (cleared on first reuse).
        self.gen = self.gen.wrapping_add(1);
        if self.gen == 0 {
            // One full sweep every 2^32 resets keeps stamps unambiguous.
            for s in &mut self.stamp {
                *s = u32::MAX;
            }
            self.gen = 1;
        }
        self.touched.clear();
    }

    fn slot_mut(&mut self, idx: u32) -> &mut RepAcc {
        let i = idx as usize;
        if i >= self.slots.len() {
            self.slots.resize_with(i + 1, || None);
            self.stamp.resize(i + 1, self.gen.wrapping_sub(1));
        }
        if self.stamp[i] != self.gen {
            self.stamp[i] = self.gen;
            self.touched.push(idx);
            let acc =
                self.slots[i].get_or_insert_with(|| Box::new(RepAcc::new()));
            acc.reset();
        }
        self.slots[i].as_mut().unwrap()
    }

    /// `sum[idx] += v`, exactly.
    #[inline]
    pub fn add(&mut self, idx: u32, v: f64) {
        self.slot_mut(idx).accumulate(v);
    }

    /// Fold another sparse sum in (exact, any merge tree).
    pub fn merge(&mut self, mut other: SparseRepVec) {
        for k in 0..other.touched.len() {
            let idx = other.touched[k];
            let acc = other.slots[idx as usize].take().unwrap();
            self.slot_mut(idx).merge(*acc);
        }
    }

    /// Visit `(index, rounded sum)` in ascending index order.
    pub fn for_each_rounded(&mut self, mut f: impl FnMut(u32, f64)) {
        self.touched.sort_unstable();
        for k in 0..self.touched.len() {
            let idx = self.touched[k];
            let v = self.slots[idx as usize].as_mut().unwrap().round();
            f(idx, v);
        }
    }

    pub fn encoded_bytes(&mut self) -> u64 {
        let mut total = 4u64;
        for k in 0..self.touched.len() {
            let idx = self.touched[k] as usize;
            total += 4 + self.slots[idx].as_mut().unwrap().encoded_bytes();
        }
        total
    }

    /// Serialize the touched entries in ascending index order.
    pub fn encode(&mut self, w: &mut ByteWriter) {
        self.touched.sort_unstable();
        w.put_u32(self.touched.len() as u32);
        for k in 0..self.touched.len() {
            let idx = self.touched[k];
            w.put_u32(idx);
            self.slots[idx as usize].as_mut().unwrap().encode(w);
        }
    }

    /// Decode with an explicit index bound (network-facing input):
    /// every index must lie below `max_idx` — anything larger would
    /// either balloon the slot table or panic downstream when applied
    /// to the packed triangle — and duplicates are rejected (a
    /// silently overwritten entry would be a silently wrong sum).
    pub fn decode(
        r: &mut ByteReader,
        max_idx: u32,
    ) -> anyhow::Result<SparseRepVec> {
        let n = r.get_u32()? as usize;
        anyhow::ensure!(
            n <= max_idx as usize,
            "SparseRepVec entry count {n} exceeds the index bound \
             {max_idx}"
        );
        let mut out = SparseRepVec::new();
        for _ in 0..n {
            let idx = r.get_u32()?;
            anyhow::ensure!(
                idx < max_idx,
                "SparseRepVec index {idx} out of bounds (< {max_idx})"
            );
            let acc = RepAcc::decode(r)?;
            let i = idx as usize;
            anyhow::ensure!(
                i >= out.stamp.len() || out.stamp[i] != out.gen,
                "duplicate SparseRepVec index {idx}"
            );
            *out.slot_mut(idx) = acc;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum_rounded(xs: &[f64]) -> f64 {
        let mut a = RepAcc::new();
        for &x in xs {
            a.accumulate(x);
        }
        a.round()
    }

    #[test]
    fn exact_on_integers() {
        // Integer-valued f64 sums that fit in 53 bits are exact.
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        assert_eq!(sum_rounded(&xs), 500500.0);
        let xs = vec![3.0, -1.0, -2.0];
        assert_eq!(sum_rounded(&xs).to_bits(), 0.0f64.to_bits());
    }

    #[test]
    fn matches_i128_reference_on_scaled_integers() {
        // Values that are exact multiples of 2^-40: the exact sum fits
        // in i128 units of 2^-40, and Rust's i128→f64 cast rounds to
        // nearest even — an independent reference for round().
        let mut rng = crate::rng::Pcg64::seed_from_u64(0xACC);
        use crate::rng::Rng;
        for _ in 0..200 {
            let n = 1 + (rng.next_u64() % 64) as usize;
            let mut acc = RepAcc::new();
            let mut exact: i128 = 0;
            for _ in 0..n {
                let m = (rng.next_u64() % (1 << 50)) as i64
                    - (1i64 << 49);
                let x = m as f64 / (1u64 << 40) as f64; // exact
                acc.accumulate(x);
                exact += m as i128;
            }
            let want = exact as f64 / (1u64 << 40) as f64;
            assert_eq!(
                acc.round().to_bits(),
                want.to_bits(),
                "exact={exact}"
            );
        }
    }

    #[test]
    fn single_value_round_trips_bitwise() {
        let cases = [
            1.0,
            -1.0,
            0.1,
            -3.5e300,
            f64::MIN_POSITIVE,
            f64::MAX,
            5e-324,         // min subnormal
            -5e-324,
            1.234e-310,     // subnormal
            f64::MIN_POSITIVE / 2.0,
        ];
        for &x in &cases {
            let mut a = RepAcc::new();
            a.accumulate(x);
            assert_eq!(a.round().to_bits(), x.to_bits(), "{x:e}");
        }
    }

    #[test]
    fn rounding_is_nearest_even() {
        // 1.0 + 2^-53 rounds down to 1.0 (tie to even); adding one
        // more ulp of dust tips it up.
        let tie = [1.0, 2.0f64.powi(-53)];
        assert_eq!(sum_rounded(&tie), 1.0);
        let up = [1.0, 2.0f64.powi(-53), 2.0f64.powi(-80)];
        assert_eq!(sum_rounded(&up), 1.0 + 2.0f64.powi(-52));
        // 1.0 + 3·2^-54 is above the halfway point.
        let up2 = [1.0, 2.0f64.powi(-53), 2.0f64.powi(-54)];
        assert_eq!(sum_rounded(&up2), 1.0 + 2.0f64.powi(-52));
    }

    #[test]
    fn catastrophic_cancellation_is_exact() {
        // (1e16 + π) − 1e16 = π exactly — impossible for a naive fold.
        let pi = std::f64::consts::PI;
        let xs = [1e16, pi, -1e16];
        assert_eq!(sum_rounded(&xs).to_bits(), pi.to_bits());
        // Full-range cancellation down to a subnormal remainder.
        let tiny = 5e-324;
        let xs = [f64::MAX, tiny, -f64::MAX];
        assert_eq!(sum_rounded(&xs).to_bits(), tiny.to_bits());
    }

    #[test]
    fn overflow_rounds_to_infinity() {
        let xs = [f64::MAX, f64::MAX];
        assert_eq!(sum_rounded(&xs), f64::INFINITY);
        let xs = [-f64::MAX, -f64::MAX, -f64::MAX];
        assert_eq!(sum_rounded(&xs), f64::NEG_INFINITY);
        // ...but cancelling back into range is exact, not sticky.
        let xs = [f64::MAX, f64::MAX, -f64::MAX];
        assert_eq!(sum_rounded(&xs).to_bits(), f64::MAX.to_bits());
    }

    #[test]
    fn specials_follow_any_order_ieee_semantics() {
        assert!(sum_rounded(&[f64::NAN, 1.0]).is_nan());
        assert!(sum_rounded(&[f64::INFINITY, f64::NEG_INFINITY]).is_nan());
        assert_eq!(
            sum_rounded(&[f64::INFINITY, 1e308, 1e308]),
            f64::INFINITY
        );
        assert_eq!(
            sum_rounded(&[-1.0, f64::NEG_INFINITY]),
            f64::NEG_INFINITY
        );
        // Permutation-invariant by construction.
        assert!(sum_rounded(&[1.0, f64::NEG_INFINITY, f64::INFINITY])
            .is_nan());
    }

    #[test]
    fn signed_zeros_vanish() {
        // Documented divergence from a sequential IEEE fold: -0.0
        // terms contribute nothing and the empty/zero sum is +0.0.
        assert_eq!(sum_rounded(&[-0.0, -0.0]).to_bits(), 0.0f64.to_bits());
        assert_eq!(sum_rounded(&[]).to_bits(), 0.0f64.to_bits());
    }

    #[test]
    fn negative_sums_encode_compactly() {
        // Sign travels as a flag, the window over the *magnitude*: a
        // negative total must not ship the long 2^32−1 borrow run of
        // its two's-complement-like canonical form.
        let mut pos = RepAcc::new();
        pos.accumulate(1.0);
        let mut neg = RepAcc::new();
        neg.accumulate(-1.0);
        assert_eq!(pos.encoded_bytes(), neg.encoded_bytes());
        assert!(neg.encoded_bytes() <= 3 + 8 * 3, "{}", neg.encoded_bytes());
        let mut w = ByteWriter::new();
        neg.encode(&mut w);
        assert_eq!(w.len() as u64, neg.encoded_bytes());
        let mut back =
            RepAcc::decode(&mut ByteReader::new(w.as_slice())).unwrap();
        assert_eq!(back.round().to_bits(), (-1.0f64).to_bits());
        // A decoded negative acc merges exactly.
        let mut sum = RepAcc::new();
        sum.accumulate(2.5);
        sum.merge(back);
        assert_eq!(sum.round(), 1.5);
        // Hostile limb magnitudes (≥ 2^32) are a decode error, never
        // downstream overflow.
        let mut bad = ByteWriter::new();
        bad.put_u8(0);
        bad.put_u8(10);
        bad.put_u8(1);
        bad.put_u64(u64::MAX >> 1);
        assert!(
            RepAcc::decode(&mut ByteReader::new(bad.as_slice())).is_err()
        );
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> =
            (0..97).map(|i| ((i * 37) % 19) as f64 * 0.3 - 2.0).collect();
        let mut whole = RepAcc::new();
        for &x in &xs {
            whole.accumulate(x);
        }
        for split in [1usize, 13, 48, 96] {
            let mut a = RepAcc::new();
            let mut b = RepAcc::new();
            for &x in &xs[..split] {
                a.accumulate(x);
            }
            for &x in &xs[split..] {
                b.accumulate(x);
            }
            a.merge(b);
            assert_eq!(a.round().to_bits(), whole.round().to_bits());
        }
    }

    #[test]
    fn codec_round_trips_and_sizes_agree() {
        let mut rng = crate::rng::Pcg64::seed_from_u64(7);
        use crate::rng::Rng;
        for case in 0..50 {
            let mut a = RepAcc::new();
            for _ in 0..(case % 7) {
                a.accumulate(rng.next_gaussian() * 10f64.powi(case - 25));
            }
            if case % 11 == 0 {
                a.accumulate(f64::INFINITY);
            }
            let want = a.clone().round();
            let expect_len = a.encoded_bytes();
            let mut w = ByteWriter::new();
            a.encode(&mut w);
            assert_eq!(w.len() as u64, expect_len, "case {case}");
            let mut r = ByteReader::new(w.as_slice());
            let mut back = RepAcc::decode(&mut r).unwrap();
            assert_eq!(r.remaining(), 0);
            assert_eq!(back.round().to_bits(), want.to_bits());
        }
        // Corrupt windows are rejected.
        let bad = [0u8, 60, 30]; // 60 + 30 > LIMBS
        assert!(RepAcc::decode(&mut ByteReader::new(&bad)).is_err());
    }

    #[test]
    fn repvec_folds_elementwise_and_merges() {
        let rows = [
            vec![1.0, 1e16, -2.0],
            vec![2.0, 3.0, 4.0],
            vec![-3.0, -1e16, 5.0],
        ];
        let mut v = RepVec::new(0);
        for rws in &rows {
            v.accumulate(rws);
        }
        assert_eq!(v.round_vec(), vec![0.0, 3.0, 7.0]);
        // Merge of partitions equals the flat fold.
        let mut a = RepVec::new(3);
        a.accumulate(&rows[0]);
        let mut b = RepVec::new(3);
        b.accumulate(&rows[1]);
        b.accumulate(&rows[2]);
        a.merge(b);
        assert_eq!(a.round_vec(), vec![0.0, 3.0, 7.0]);
        // Codec.
        let mut w = ByteWriter::new();
        let expect = a.encoded_bytes();
        a.encode(&mut w);
        assert_eq!(w.len() as u64, expect);
        let mut back =
            RepVec::decode(&mut ByteReader::new(w.as_slice()), 3)
                .unwrap();
        assert_eq!(back.round_vec(), vec![0.0, 3.0, 7.0]);
        // The length bound guards the allocation (network input).
        assert!(
            RepVec::decode(&mut ByteReader::new(w.as_slice()), 2)
                .is_err()
        );
    }

    #[test]
    fn sparse_repvec_sums_merges_and_reuses_slots() {
        let mut s = SparseRepVec::new();
        s.add(5, 1.5);
        s.add(2, -1.0);
        s.add(5, 2.5);
        let mut got = Vec::new();
        s.for_each_rounded(|i, v| got.push((i, v)));
        assert_eq!(got, vec![(2, -1.0), (5, 4.0)]);
        // Reset reuses slots without bleeding previous sums.
        s.reset();
        assert!(s.is_empty());
        s.add(5, 7.0);
        let mut got = Vec::new();
        s.for_each_rounded(|i, v| got.push((i, v)));
        assert_eq!(got, vec![(5, 7.0)]);
        // Merge unions indices and sums overlaps exactly.
        let mut t = SparseRepVec::new();
        t.add(5, 1.0);
        t.add(9, 2.0);
        s.merge(t);
        let mut got = Vec::new();
        s.for_each_rounded(|i, v| got.push((i, v)));
        assert_eq!(got, vec![(5, 8.0), (9, 2.0)]);
        // Codec round-trip preserves the entries.
        let mut w = ByteWriter::new();
        let expect = s.encoded_bytes();
        s.encode(&mut w);
        assert_eq!(w.len() as u64, expect);
        let mut back =
            SparseRepVec::decode(&mut ByteReader::new(w.as_slice()), 16)
                .unwrap();
        let mut got = Vec::new();
        back.for_each_rounded(|i, v| got.push((i, v)));
        assert_eq!(got, vec![(5, 8.0), (9, 2.0)]);
        // Out-of-bound indices and duplicates are rejected, never
        // silently absorbed (network input).
        assert!(SparseRepVec::decode(
            &mut ByteReader::new(w.as_slice()),
            9
        )
        .is_err());
        let mut dup = ByteWriter::new();
        dup.put_u32(2);
        for _ in 0..2 {
            dup.put_u32(5);
            RepAcc::new().encode(&mut dup);
        }
        assert!(SparseRepVec::decode(
            &mut ByteReader::new(dup.as_slice()),
            16
        )
        .is_err());
    }
}

//! Length-prefixed frames over a TCP stream.
//!
//! Frame layout: `u32 payload_len (LE) | u8 tag | payload`. Writes are
//! buffered and flushed once per frame; reads use `read_exact`. The
//! stream is configured with `TCP_NODELAY` (paper §7: Nagle disabled —
//! frames are explicitly sized, the OS must not delay small ones).

use std::io::{Read, Write};
use std::net::TcpStream;

use anyhow::{Context, Result};

/// Maximum accepted frame payload (sanity bound: a dense d=2048 Hessian
/// is 32 MiB; anything above 256 MiB is a protocol error).
pub const MAX_FRAME: usize = 256 << 20;

/// Bytes of framing around every payload: u32 length + u8 tag. The
/// drivers' logical byte accounting includes this so it matches the
/// transport's metered counts exactly.
pub const FRAME_HEADER_BYTES: u64 = 5;

/// A framed, metered TCP channel.
pub struct Channel {
    stream: TcpStream,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

impl Channel {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).context("set_nodelay")?;
        Ok(Self { stream, bytes_sent: 0, bytes_received: 0 })
    }

    pub fn send(&mut self, tag: u8, payload: &[u8]) -> Result<()> {
        anyhow::ensure!(payload.len() <= MAX_FRAME, "frame too large");
        let mut header = [0u8; 5];
        header[..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
        header[4] = tag;
        self.stream.write_all(&header)?;
        self.stream.write_all(payload)?;
        self.stream.flush()?;
        self.bytes_sent += FRAME_HEADER_BYTES + payload.len() as u64;
        Ok(())
    }

    pub fn recv(&mut self) -> Result<(u8, Vec<u8>)> {
        let mut header = [0u8; 5];
        self.stream.read_exact(&mut header).context("frame header")?;
        let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
        let tag = header[4];
        anyhow::ensure!(len <= MAX_FRAME, "oversized frame: {len}");
        let mut payload = vec![0u8; len];
        self.stream.read_exact(&mut payload).context("frame payload")?;
        self.bytes_received += FRAME_HEADER_BYTES + len as u64;
        Ok((tag, payload))
    }

    /// Bound the time a blocking [`Channel::recv`] may wait (`None` =
    /// wait forever). A timeout mid-frame desynchronizes the stream, so
    /// callers that hit one must retire the channel — `RemotePool`
    /// deregisters the client (the per-client reply deadline).
    pub fn set_read_timeout(&self, dur: Option<std::time::Duration>) -> Result<()> {
        self.stream.set_read_timeout(dur).context("set_read_timeout")?;
        Ok(())
    }

    pub fn peer_addr(&self) -> String {
        self.stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn roundtrip_frames() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut ch = Channel::new(s).unwrap();
            let (tag, p) = ch.recv().unwrap();
            assert_eq!(tag, 7);
            ch.send(8, &p).unwrap(); // echo
        });
        let mut ch = Channel::new(TcpStream::connect(addr).unwrap()).unwrap();
        let payload = vec![1u8, 2, 3, 4, 5];
        ch.send(7, &payload).unwrap();
        let (tag, echoed) = ch.recv().unwrap();
        assert_eq!(tag, 8);
        assert_eq!(echoed, payload);
        assert_eq!(ch.bytes_sent, 10);
        assert_eq!(ch.bytes_received, 10);
        t.join().unwrap();
    }

    #[test]
    fn empty_payload_ok() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut ch = Channel::new(s).unwrap();
            let (tag, p) = ch.recv().unwrap();
            assert_eq!(tag, 1);
            assert!(p.is_empty());
        });
        let mut ch = Channel::new(TcpStream::connect(addr).unwrap()).unwrap();
        ch.send(1, &[]).unwrap();
        t.join().unwrap();
    }
}

//! Client/server state for the FedNL family.
//!
//! The client keeps its Hessian shift Hᵢᵏ in **packed upper-triangle
//! form** — compression, the shift update (line 6) and the Frobenius
//! distance lᵢᵏ (line 5) all live in packed coordinates, so nothing ever
//! materializes a second d×d matrix per client. The server keeps Hᵏ as a
//! dense symmetric matrix (the Newton solve wants it dense) and applies
//! the sparse compressed updates in O(k) (paper §5.6).

use crate::compressors::{Compressed, Compressor};
use crate::linalg::packed::PackedUpper;
use crate::linalg::reduce::{RepAcc, RepVec, SparseRepVec};
use crate::linalg::{vector, Cholesky, Mat};
use crate::oracle::Oracle;
use crate::utils::{ByteReader, ByteWriter};

/// What a client sends the master each round — the **unified** message
/// of the whole algorithm family:
///
/// * FedNL / FedNL-LS (Alg. 1–2 line 5): `grad` = ∇fᵢ(xᵏ),
///   `l_i` = lᵢᵏ, `update` = Cᵢᵏ(∇²fᵢ(xᵏ) − Hᵢᵏ);
/// * FedNL-PP (Alg. 3 line 13): the same fields carry **deltas** of the
///   participant's server-tracked state — `grad` = Δgᵢ, `l_i` = Δlᵢ —
///   plus the compressed shift update.
///
/// One message type means one wire codec (`net::wire::encode_client_msg`)
/// and one streaming pool API for all three algorithms.
#[derive(Debug, Clone)]
pub struct ClientMsg {
    pub client_id: usize,
    /// ∇fᵢ(xᵏ) (FedNL) or Δgᵢ (FedNL-PP), dense d-vector.
    pub grad: Vec<f64>,
    /// Sᵢᵏ = Cᵢᵏ(∇²fᵢ(xᵏ) − Hᵢᵏ).
    pub update: Compressed,
    /// lᵢᵏ = ‖Hᵢᵏ − ∇²fᵢ(xᵏ)‖_F (FedNL) or Δlᵢ (FedNL-PP).
    pub l_i: f64,
    /// fᵢ(xᵏ) when the server tracks loss / runs line search.
    pub loss: Option<f64>,
}

impl ClientMsg {
    /// Exact framed size of this message on the TCP wire: frame header
    /// (payload length + tag) + client id + gradient (count + f64s) +
    /// lᵢ + loss flag (+ loss) + the compressed update. Kept
    /// byte-for-byte in sync with `net::wire::encode_client_msg` (a
    /// codec test asserts the agreement), so the in-process pools'
    /// logical byte accounting matches the TCP transport's metered
    /// counts.
    pub fn wire_bytes(&self) -> u64 {
        crate::net::FRAME_HEADER_BYTES
            + 4 // client id
            + 4 // gradient length
            + self.grad.len() as u64 * 8
            + 8 // lᵢ
            + 1 // loss presence flag
            + if self.loss.is_some() { 8 } else { 0 }
            + self.update.wire_bytes()
    }
}

/// The exact sum of a set of client round messages — the reproducible
/// aggregation unit of the whole family (built on
/// [`crate::linalg::reduce`]).
///
/// `absorb` folds one [`ClientMsg`] in; `merge` folds another
/// `RoundSum` in. Both are **exactly associative and
/// permutation-invariant**, so any grouping of the round's messages —
/// the flat master absorbing atoms in arrival order, S shard
/// aggregators each absorbing a partition and the master merging the
/// S partial sums, any thread count, any transport — produces
/// bit-identical state, and the one rounding per quantity happens at
/// [`ServerState::finish_round`]. This is what lets the shard tier
/// forward **one merged accumulator per shard** (`SHARD_SUM`,
/// O(S·d) master fan-in) instead of per-client atoms (O(n·d)).
///
/// Field semantics: `grad` = Σ ∇fᵢ (raw, unweighted — weights are
/// applied after rounding), `l` = Σ lᵢ, `loss` = Σ fᵢ (with
/// `have_loss` false iff any absorbed message lacked one), `hess` = the
/// sparse Σ scaleᵢ·Sᵢ in packed coordinates (each term is the one f64
/// product `scaleᵢ·vᵢⱼ`; products round identically wherever they are
/// computed, so shard-side and master-side absorption agree bitwise).
#[derive(Debug, Clone, Default)]
pub struct RoundSum {
    pub grad: RepVec,
    pub l: RepAcc,
    pub loss: RepAcc,
    pub have_loss: bool,
    pub hess: SparseRepVec,
    /// Messages folded into this sum.
    pub committed: u32,
    /// Transport bytes this sum cost: the folded atoms' wire bytes on
    /// flat pools, the SHARD_SUM frame size on the shard tiers. Not
    /// part of the wire codec (the receiver meters the frame itself).
    pub wire_bytes: u64,
}

impl RoundSum {
    pub fn new() -> Self {
        Self { have_loss: true, ..Default::default() }
    }

    /// Reset to the empty sum, keeping every allocation.
    pub fn reset(&mut self) {
        self.grad.reset();
        self.l.reset();
        self.loss.reset();
        self.have_loss = true;
        self.hess.reset();
        self.committed = 0;
        self.wire_bytes = 0;
    }

    /// Fold one client message in (exact).
    pub fn absorb(&mut self, m: &ClientMsg) {
        self.grad.accumulate(&m.grad);
        self.l.accumulate(m.l_i);
        match m.loss {
            Some(l) => self.loss.accumulate(l),
            None => self.have_loss = false,
        }
        for (v, idx) in m.update.values.iter().zip(m.update.indices()) {
            self.hess.add(idx, m.update.scale * v);
        }
        self.committed += 1;
    }

    /// Fold another partial sum in (exact; any merge tree).
    pub fn merge(&mut self, other: RoundSum) {
        self.grad.merge(other.grad);
        self.l.merge(other.l);
        self.loss.merge(other.loss);
        self.have_loss &= other.have_loss;
        self.hess.merge(other.hess);
        self.committed += other.committed;
        self.wire_bytes += other.wire_bytes;
    }

    /// Apply the rounded sparse Hessian sum to the dense Hᵏ:
    /// `h += scale · round(Σᵢ scaleᵢ·Sᵢ)` at each touched packed
    /// index, mirrored across the diagonal. The single place the
    /// summed updates meet the matrix — shared by the Newton family
    /// ([`ServerState::finish_round`]) and the FedNL-PP engine so the
    /// two paths cannot drift.
    pub fn apply_hessian(
        &mut self,
        pu: &PackedUpper,
        h: &mut Mat,
        scale: f64,
    ) {
        self.hess.for_each_rounded(|idx, v| {
            let (i, j) = pu.pair(idx as usize);
            h.add_at(i, j, scale * v);
            if i != j {
                h.add_at(j, i, scale * v);
            }
        });
    }

    /// Sum a batch of atoms, charging their individual wire bytes
    /// (what a flat transport actually moved).
    pub fn from_msgs(batch: &[ClientMsg]) -> Self {
        let mut s = RoundSum::new();
        for m in batch {
            s.absorb(m);
            s.wire_bytes += m.wire_bytes();
        }
        s
    }

    /// Exact byte length [`RoundSum::encode`] will produce — the
    /// logical SHARD_SUM payload size (shard-tier byte accounting).
    pub fn encoded_bytes(&mut self) -> u64 {
        4 + 1
            + self.l.encoded_bytes()
            + self.loss.encoded_bytes()
            + self.grad.encoded_bytes()
            + self.hess.encoded_bytes()
    }

    /// Wire codec (committed, have_loss, l, loss, grad, hess);
    /// `wire_bytes` intentionally excluded — the receiver meters it.
    pub fn encode(&mut self, w: &mut ByteWriter) {
        w.put_u32(self.committed);
        w.put_u8(self.have_loss as u8);
        self.l.encode(w);
        self.loss.encode(w);
        self.grad.encode(w);
        self.hess.encode(w);
    }

    /// Decode against the run's dimension `d` (network-facing input:
    /// the gradient sum must be a d-vector — or empty, for an
    /// all-missing partition — and every sparse Hessian index must
    /// fall inside the packed upper triangle, so a malformed frame is
    /// an `Err` the transport can turn into a retired relay, never a
    /// giant allocation or a downstream panic).
    pub fn decode(
        r: &mut ByteReader,
        d: usize,
    ) -> anyhow::Result<RoundSum> {
        let committed = r.get_u32()?;
        let have_loss = r.get_u8()? != 0;
        let l = RepAcc::decode(r)?;
        let loss = RepAcc::decode(r)?;
        let grad = RepVec::decode(r, d)?;
        anyhow::ensure!(
            grad.is_empty() || grad.len() == d,
            "RoundSum gradient length {} != dimension {d}",
            grad.len()
        );
        let hess = SparseRepVec::decode(
            r,
            crate::linalg::packed::packed_len(d) as u32,
        )?;
        Ok(RoundSum {
            grad,
            l,
            loss,
            have_loss,
            hess,
            committed,
            wire_bytes: 0,
        })
    }
}

/// A computed-but-unacknowledged round application under the
/// commit-ack protocol (see `net::wire`). The shift Hᵢ ← Hᵢ + αSᵢ is
/// applied **eagerly** — the compute path is bit-for-bit the unstaged
/// one, so trajectories are invariant to when (or whether) acks
/// arrive — and `prev` records the exact pre-apply `h_shift` value at
/// every touched packed index, in touch order. Rolling the entries
/// back newest-first restores those stored bits verbatim, so an
/// unacknowledged round can be *undone* exactly (no `a + δ − δ ≠ a`
/// float hazard), which is what lets a checkpoint-restoring master
/// defer acks for several rounds and still resync rejoiners bitwise.
#[derive(Debug, Clone)]
struct StagedApply {
    round: u64,
    /// (packed index, pre-apply value) per touched coordinate.
    prev: Vec<(u32, f64)>,
}

/// Per-client FedNL state: local oracle + Hessian shift + compressor.
pub struct ClientState {
    pub id: usize,
    pub oracle: Box<dyn Oracle>,
    pub compressor: Box<dyn Compressor>,
    /// Hᵢᵏ in packed upper-triangle coordinates.
    pub h_shift: Vec<f64>,
    /// Hessian learning rate α (same value server-side).
    pub alpha: f64,
    pub pu: PackedUpper,
    /// The ladder of rounds applied but not yet acknowledged, in
    /// ascending round order (commit-ack staging). With per-round acks
    /// (TCP FIFO: ROUND_ACK(k) precedes ROUND(k+1)) the ladder never
    /// exceeds one entry; a checkpointing master that acks only after
    /// a durable snapshot lets several rounds pile up, and a rejoin
    /// RESYNC rolls the unacknowledged suffix back newest-first.
    staged: Vec<StagedApply>,
    // Reused round buffers (no allocation in the loop, §5.13):
    hess: Mat,
    hess_packed: Vec<f64>,
    diff: Vec<f64>,
    grad_buf: Vec<f64>,
}

impl ClientState {
    /// `alpha = None` → theoretical α from the compressor class.
    pub fn new(
        id: usize,
        oracle: Box<dyn Oracle>,
        compressor: Box<dyn Compressor>,
        alpha: Option<f64>,
    ) -> Self {
        let d = oracle.dim();
        let pu = PackedUpper::new(d);
        let n = pu.len();
        let alpha = alpha.unwrap_or_else(|| compressor.kind(n).alpha());
        Self {
            id,
            oracle,
            compressor,
            h_shift: vec![0.0; n],
            alpha,
            pu,
            staged: Vec::new(),
            hess: Mat::zeros(d, d),
            hess_packed: vec![0.0; n],
            diff: vec![0.0; n],
            grad_buf: vec![0.0; d],
        }
    }

    pub fn dim(&self) -> usize {
        self.grad_buf.len()
    }

    /// Initialize Hᵢ⁰ = ∇²fᵢ(x⁰) (the FedNL paper's warm start; the
    /// cold start Hᵢ⁰ = 0 also satisfies the theory but Option 1 then
    /// takes −(1/μ)∇f first steps). Returns the packed Hᵢ⁰ so the
    /// server can form H⁰ = (1/n)ΣHᵢ⁰.
    pub fn warm_start(&mut self, x0: &[f64]) -> Vec<f64> {
        self.oracle.hessian(x0, &mut self.hess);
        self.pu.pack(&self.hess, &mut self.hess_packed);
        self.h_shift.copy_from_slice(&self.hess_packed);
        self.hess_packed.clone()
    }

    /// One FedNL client round at iterate `x` (Alg. 1 lines 4–6).
    /// `need_loss` additionally returns fᵢ(xᵏ) (FedNL-LS line 5).
    pub fn round(&mut self, x: &[f64], round: u64, need_loss: bool) -> ClientMsg {
        self.round_inner(x, round, need_loss, false)
    }

    /// [`ClientState::round`] under the commit-ack protocol: the shift
    /// update Hᵢᵏ⁺¹ = Hᵢᵏ + αSᵢᵏ is applied eagerly (bitwise the
    /// unstaged compute) but recorded as **revocable** — the master's
    /// `ROUND_ACK` ([`commit_staged`]) makes it permanent, and an
    /// unfavorable rejoin `RESYNC` ([`resolve_staged`]) rolls it back
    /// to the exact pre-round bits. Closes the "computed but reply
    /// lost" hole: a round the master never committed leaves this
    /// client's state bitwise identical to never having computed it,
    /// which is exactly what the deterministic fault plan's
    /// frozen-client semantics assume.
    ///
    /// [`commit_staged`]: ClientState::commit_staged
    /// [`resolve_staged`]: ClientState::resolve_staged
    pub fn round_staged(
        &mut self,
        x: &[f64],
        round: u64,
        need_loss: bool,
    ) -> ClientMsg {
        self.round_inner(x, round, need_loss, true)
    }

    fn round_inner(
        &mut self,
        x: &[f64],
        round: u64,
        need_loss: bool,
        stage: bool,
    ) -> ClientMsg {
        let loss = self.oracle.loss_grad_hessian(
            x,
            &mut self.grad_buf,
            &mut self.hess,
        );
        self.pu.pack(&self.hess, &mut self.hess_packed);
        // diff = ∇²fᵢ(xᵏ) − Hᵢᵏ (packed).
        vector::sub(&self.hess_packed, &self.h_shift, &mut self.diff);
        // lᵢᵏ before the shift update (line 5).
        let l_i = self.pu.frobenius_sq_packed(&self.diff).sqrt();
        let update = self.compressor.compress(&self.pu, &self.diff, round);
        // Hᵢᵏ⁺¹ = Hᵢᵏ + α Sᵢᵏ, sparse in packed coords (line 6).
        let a = self.alpha * update.scale;
        if stage {
            // Eager apply with exact undo info: record the pre-apply
            // bits at every touched index, then take the same
            // `+= a*v` step the unstaged path takes.
            let mut prev = Vec::with_capacity(update.values.len());
            for (v, idx) in update.values.iter().zip(update.indices()) {
                prev.push((idx, self.h_shift[idx as usize]));
                self.h_shift[idx as usize] += a * v;
            }
            self.staged.push(StagedApply { round, prev });
        } else {
            for (v, idx) in update.values.iter().zip(update.indices()) {
                self.h_shift[idx as usize] += a * v;
            }
        }
        ClientMsg {
            client_id: self.id,
            grad: self.grad_buf.clone(),
            update,
            l_i,
            loss: if need_loss { Some(loss) } else { None },
        }
    }

    /// Round of the newest revocable shift, if any (test hook).
    pub fn staged_round(&self) -> Option<u64> {
        self.staged.last().map(|s| s.round)
    }

    /// Revocable entries currently on the ladder (test hook).
    pub fn staged_len(&self) -> usize {
        self.staged.len()
    }

    /// The master committed every round up to and including `round`
    /// with this client's reply counted (`ROUND_ACK`): shifts at or
    /// below it become permanent — their rollback records are dropped.
    /// The shifts themselves were applied eagerly at compute time, so
    /// this touches no floats; a second ack of the same round is a
    /// no-op (exactly-once).
    pub fn commit_staged(&mut self, round: u64) {
        self.staged.retain(|s| s.round > round);
    }

    /// Roll back every revocable shift, newest first, restoring the
    /// recorded pre-apply bits verbatim (the master certified the
    /// rounds missed this client).
    pub fn discard_staged(&mut self) {
        while let Some(s) = self.staged.pop() {
            for &(idx, old) in s.prev.iter().rev() {
                self.h_shift[idx as usize] = old;
            }
        }
    }

    /// Rejoin resolution against the master's commit watermark
    /// (`RESYNC`): staged rounds the master committed (≤
    /// `last_commit`) become permanent — the replies were delivered
    /// even if the acks were lost; anything newer (or everything, when
    /// the master never committed us) is rolled back newest-first —
    /// those replies never made it. Both windows land on exactly-once
    /// application, and the rollback restores stored bits, so the
    /// surviving state is exactly the watermark-round state.
    pub fn resolve_staged(&mut self, last_commit: Option<u64>) {
        while let Some(s) = self.staged.last() {
            if last_commit.is_some_and(|lc| s.round <= lc) {
                break;
            }
            let s = self.staged.pop().unwrap();
            for &(idx, old) in s.prev.iter().rev() {
                self.h_shift[idx as usize] = old;
            }
        }
        // Whatever remains is at or below the watermark: permanent.
        self.staged.clear();
    }

    /// Current packed Hᵢ (the exact-resync upload a fresh-state
    /// rejoiner's `PULL_H` round collects).
    pub fn packed_h(&self) -> Vec<f64> {
        self.h_shift.clone()
    }

    /// Loss-only evaluation (line-search probes).
    pub fn eval_loss(&mut self, x: &[f64]) -> f64 {
        self.oracle.loss(x)
    }

    /// First-order evaluation (baseline solvers' round primitive).
    pub fn eval_loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let l = self.oracle.loss_grad(x, &mut self.grad_buf);
        (l, self.grad_buf.clone())
    }
}

/// Master state (Alg. 1 lines 8–11). `Clone` so the engine's
/// speculative-aggregation path (`--speculate`) can run the quorum
/// finish on a snapshot while stragglers keep draining into the
/// original.
#[derive(Clone)]
pub struct ServerState {
    pub d: usize,
    pub n_clients: usize,
    /// Hᵏ = (1/n) Σ Hᵢᵏ, dense symmetric.
    pub h: Mat,
    /// lᵏ = (1/n) Σ lᵢᵏ.
    pub l: f64,
    pub alpha: f64,
    pub pu: PackedUpper,
    /// Current iterate xᵏ.
    pub x: Vec<f64>,
    // Round scratch:
    sys: Mat,
    /// Exact round accumulator (begin_round/apply_msg/apply_sum/
    /// finish_round): every cross-client sum of the round lives here
    /// as a reproducible superaccumulator, so commit order, transport,
    /// thread count and shard grouping cannot perturb the result.
    sum: RoundSum,
}

impl ServerState {
    pub fn new(d: usize, n_clients: usize, alpha: f64, x0: Vec<f64>) -> Self {
        assert_eq!(x0.len(), d);
        Self {
            d,
            n_clients,
            h: Mat::zeros(d, d),
            l: 0.0,
            alpha,
            pu: PackedUpper::new(d),
            x: x0,
            sys: Mat::zeros(d, d),
            sum: RoundSum::new(),
        }
    }

    /// Install H⁰ = (1/n) Σ Hᵢ⁰ from warm-started clients
    /// (reproducible sum: exact Σ, then one rounding and one scaling
    /// per packed entry — grouping-invariant like every other fold).
    pub fn init_h_from_packed(&mut self, packed: &[Vec<f64>]) {
        let inv_n = 1.0 / packed.len() as f64;
        let mut acc = RepVec::new(self.pu.len());
        for p in packed {
            acc.accumulate(p);
        }
        let mut mean = acc.round_vec();
        vector::scale(inv_n, &mut mean);
        self.pu.unpack(&mean, &mut self.h);
    }

    /// Reset the round accumulator before streaming messages into
    /// [`ServerState::apply_msg`] / [`ServerState::apply_sum`].
    pub fn begin_round(&mut self) {
        self.sum.reset();
    }

    /// Fold one client's message into the round sum (exact — see
    /// [`RoundSum`]). Messages may be applied in **any order**: the
    /// superaccumulator makes the round state grouping-invariant, so
    /// the old buffer-and-commit order discipline is no longer what
    /// determinism rests on.
    pub fn apply_msg(&mut self, m: &ClientMsg) {
        self.sum.absorb(m);
    }

    /// Fold a pre-reduced partial sum in (the shard tier's merged
    /// `SHARD_SUM`; exact, so S-shard runs match flat runs bitwise).
    pub fn apply_sum(&mut self, s: RoundSum) {
        self.sum.merge(s);
    }

    /// Close the round (Alg. 1 lines 9–10): perform the one rounding
    /// per quantity, install lᵏ, apply the summed sparse Hessian
    /// update Hᵏ ← Hᵏ + (α/n)·Σᵢ Sᵢᵏ, and return (∇f(xᵏ), mean loss if
    /// every message carried one). `committed` is how many messages
    /// actually committed: ∇f, lᵏ and the loss are means over the
    /// survivors (round(Σ)·(1/committed)); the Hessian keeps the 1/n
    /// weight per survivor (a client that never computed the round
    /// never moved its local Hᵢᵏ either).
    pub fn finish_round(&mut self, committed: usize) -> (Vec<f64>, Option<f64>) {
        assert!(
            committed >= 1 && committed <= self.n_clients,
            "finish_round: committed {committed} out of 1..={}",
            self.n_clients
        );
        let inv_c = 1.0 / committed as f64;
        let mut grad = if self.sum.grad.is_empty() {
            vec![0.0; self.d]
        } else {
            self.sum.grad.round_vec()
        };
        vector::scale(inv_c, &mut grad);
        self.l = self.sum.l.round() * inv_c;
        let loss = if self.sum.have_loss {
            Some(self.sum.loss.round() * inv_c)
        } else {
            None
        };
        let a = self.alpha / self.n_clients as f64;
        self.sum.apply_hessian(&self.pu, &mut self.h, a);
        (grad, loss)
    }

    /// Newton direction −[system]⁻¹ g under the given rule
    /// (Alg. 1 line 11). Falls back to growing diagonal jitter if the
    /// factorization fails numerically.
    pub fn newton_direction(
        &mut self,
        g: &[f64],
        rule: super::UpdateRule,
    ) -> Vec<f64> {
        match rule {
            super::UpdateRule::LkShift => {
                self.sys.as_mut_slice().copy_from_slice(self.h.as_slice());
                let mut shift = self.l;
                for _ in 0..60 {
                    if let Some(ch) = Cholesky::factor(&self.sys, shift) {
                        let mut dir = ch.solve_vec(g);
                        vector::scale(-1.0, &mut dir);
                        return dir;
                    }
                    shift = (shift * 2.0).max(1e-12);
                }
                // Pathological: fall back to −g.
                let mut dir = g.to_vec();
                vector::scale(-1.0, &mut dir);
                dir
            }
            super::UpdateRule::ProjectMu(mu) => {
                let proj = crate::linalg::eigen::project_psd_mu(&self.h, mu);
                match Cholesky::factor(&proj, 0.0) {
                    Some(ch) => {
                        let mut dir = ch.solve_vec(g);
                        vector::scale(-1.0, &mut dir);
                        dir
                    }
                    None => {
                        let mut dir = g.to_vec();
                        vector::scale(-1.0, &mut dir);
                        dir
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::Identity;
    use crate::oracle::QuadraticOracle;

    fn quad_client(id: usize) -> ClientState {
        let q = Mat::from_rows(&[&[2.0, 0.5], &[0.5, 1.5]]);
        let oracle = QuadraticOracle::new(q, vec![1.0, -1.0]);
        ClientState::new(id, Box::new(oracle), Box::new(Identity), None)
    }

    #[test]
    fn identity_alpha_is_one() {
        let c = quad_client(0);
        assert_eq!(c.alpha, 1.0);
    }

    #[test]
    fn client_learns_exact_hessian_in_one_round_with_identity() {
        let mut c = quad_client(0);
        let msg = c.round(&[0.0, 0.0], 0, false);
        // l⁰ = ‖0 − Q‖_F > 0; after the update Hᵢ¹ = Q exactly.
        assert!(msg.l_i > 0.0);
        let msg2 = c.round(&[0.0, 0.0], 1, false);
        assert!(msg2.l_i < 1e-14, "l after identity update: {}", msg2.l_i);
    }

    #[test]
    fn server_aggregate_and_newton() {
        let mut s = ServerState::new(2, 2, 1.0, vec![0.0, 0.0]);
        let mut c0 = quad_client(0);
        let mut c1 = quad_client(1);
        let msgs =
            vec![c0.round(&s.x.clone(), 0, true), c1.round(&s.x.clone(), 0, true)];
        // The incremental commit path, exactly as the round engine
        // drives it.
        s.begin_round();
        for m in &msgs {
            s.apply_msg(m);
        }
        let (g, loss) = s.finish_round(2);
        assert!(loss.is_some());
        // Both clients identical → ∇f = ∇f₀ = Q·0 − b = −b = [−1, 1].
        assert!((g[0] + 1.0).abs() < 1e-14);
        assert!((g[1] - 1.0).abs() < 1e-14);
        // After identity aggregation H = Q; direction solves Newton.
        let dir = s.newton_direction(&g, super::super::UpdateRule::LkShift);
        assert_eq!(dir.len(), 2);
        // With l⁰ > 0 the step is damped but still a descent direction.
        assert!(vector::dot(&dir, &g) < 0.0);
    }

    #[test]
    fn finish_round_rescales_to_committed_count() {
        // 3 clients expected, only 2 commit: ∇f and lᵏ must become
        // means over the survivors, not thirds.
        let mut s = ServerState::new(2, 3, 1.0, vec![0.0, 0.0]);
        let mut c0 = quad_client(0);
        let mut c1 = quad_client(1);
        let m0 = c0.round(&[0.0, 0.0], 0, true);
        let m1 = c1.round(&[0.0, 0.0], 0, true);
        s.begin_round();
        s.apply_msg(&m0);
        s.apply_msg(&m1);
        let (g, loss) = s.finish_round(2);
        // Identical clients → the survivor mean equals one client's
        // values: ∇f = −b = [−1, 1].
        assert!((g[0] + 1.0).abs() < 1e-12, "g[0]={}", g[0]);
        assert!((g[1] - 1.0).abs() < 1e-12, "g[1]={}", g[1]);
        let expected_l = (m0.l_i + m1.l_i) / 2.0;
        assert!((s.l - expected_l).abs() < 1e-12);
        let expected_f = (m0.loss.unwrap() + m1.loss.unwrap()) / 2.0;
        assert!((loss.unwrap() - expected_f).abs() < 1e-12);
    }

    #[test]
    fn staged_commit_matches_unstaged_bitwise() {
        let mut plain = quad_client(0);
        let mut staged = quad_client(0);
        let x = [0.3, -0.7];
        let m1 = plain.round(&x, 0, true);
        let m2 = staged.round_staged(&x, 0, true);
        assert_eq!(m1.l_i.to_bits(), m2.l_i.to_bits());
        // Eager apply: the staged client's shift matches the unstaged
        // one bitwise *before* the ack — staging only records undo
        // bits, so the compute path is invariant to ack cadence.
        let a: Vec<u64> =
            plain.h_shift.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u64> =
            staged.h_shift.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
        assert_eq!(staged.staged_round(), Some(0));
        // The ack only drops the rollback record; floats untouched.
        staged.commit_staged(0);
        assert_eq!(staged.staged_round(), None);
        let b2: Vec<u64> =
            staged.h_shift.iter().map(|v| v.to_bits()).collect();
        assert_eq!(b, b2);
        // Double commit is a no-op (exactly-once).
        staged.commit_staged(0);
        let b3: Vec<u64> =
            staged.h_shift.iter().map(|v| v.to_bits()).collect();
        assert_eq!(b, b3);
    }

    #[test]
    fn resolve_staged_applies_acked_discards_unacked() {
        // Ack-lost window: reply delivered (master committed round 3),
        // ack lost, rejoin RESYNC(last_commit = 3) → apply.
        let mut c = quad_client(0);
        c.round_staged(&[0.1, 0.2], 3, false);
        c.resolve_staged(Some(3));
        assert_eq!(c.staged_round(), None);
        assert!(c.h_shift.iter().any(|&v| v != 0.0));
        // Reply-lost window: staged round 4, master only committed 3
        // → discard; state must equal never-computed (frozen client).
        let mut lost = quad_client(0);
        let frozen = quad_client(0);
        lost.round_staged(&[0.1, 0.2], 4, false);
        lost.resolve_staged(Some(3));
        let a: Vec<u64> =
            lost.h_shift.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u64> =
            frozen.h_shift.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
        // No commit watermark at all → discard too.
        let mut none = quad_client(0);
        none.round_staged(&[0.1, 0.2], 0, false);
        none.resolve_staged(None);
        assert!(none.h_shift.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn staged_ladder_rolls_back_suffix_above_watermark() {
        // Two revocable rounds deep (the shape deferred acks under
        // --checkpoint-every K produce), then RESYNC(last_commit = 1):
        // round 2 rolls back bitwise, round 1 survives.
        let mut c = quad_client(0);
        c.round_staged(&[0.1, 0.2], 1, false);
        let after_r1: Vec<u64> =
            c.h_shift.iter().map(|v| v.to_bits()).collect();
        c.round_staged(&[0.2, 0.1], 2, false);
        assert_eq!(c.staged_round(), Some(2));
        assert_eq!(c.staged_len(), 2);
        c.resolve_staged(Some(1));
        assert_eq!(c.staged_round(), None);
        let healed: Vec<u64> =
            c.h_shift.iter().map(|v| v.to_bits()).collect();
        assert_eq!(healed, after_r1);
        // Full discard rolls a fresh two-deep ladder back to zero.
        let mut d = quad_client(0);
        d.round_staged(&[0.1, 0.2], 1, false);
        d.round_staged(&[0.2, 0.1], 2, false);
        d.discard_staged();
        assert!(d.h_shift.iter().all(|&v| v == 0.0));
        // Partial commit keeps the newer round revocable.
        let mut e = quad_client(0);
        e.round_staged(&[0.1, 0.2], 1, false);
        e.round_staged(&[0.2, 0.1], 2, false);
        e.commit_staged(1);
        assert_eq!(e.staged_round(), Some(2));
        assert_eq!(e.staged_len(), 1);
    }

    #[test]
    fn packed_h_reflects_committed_state() {
        let mut c = quad_client(0);
        assert_eq!(c.packed_h(), vec![0.0; c.h_shift.len()]);
        c.round(&[0.5, 0.5], 0, false);
        assert_eq!(c.packed_h(), c.h_shift);
    }

    #[test]
    fn wire_bytes_positive() {
        let mut c = quad_client(0);
        let msg = c.round(&[0.1, 0.2], 0, false);
        assert!(msg.wire_bytes() > 16);
    }

    #[test]
    fn round_sum_grouping_invariant_and_codec_exact() {
        // Σ over 4 messages: flat absorb in two different orders, and
        // a 2+2 shard split merged, must agree bitwise — the exactness
        // the shard tier's SHARD_SUM pre-reduction rests on.
        let msgs: Vec<ClientMsg> = (0..4)
            .map(|i| {
                let mut c = quad_client(i);
                c.round(&[0.1 * i as f64, -0.2], 0, true)
            })
            .collect();
        let finish = |mut s: super::RoundSum| {
            let g = s.grad.round_vec();
            let l = s.l.round();
            let f = s.loss.round();
            let mut h = Vec::new();
            s.hess.for_each_rounded(|i, v| h.push((i, v.to_bits())));
            (
                g.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                l.to_bits(),
                f.to_bits(),
                h,
            )
        };
        let mut flat = super::RoundSum::new();
        for m in &msgs {
            flat.absorb(m);
        }
        let mut rev = super::RoundSum::new();
        for m in msgs.iter().rev() {
            rev.absorb(m);
        }
        let mut a = super::RoundSum::new();
        a.absorb(&msgs[0]);
        a.absorb(&msgs[1]);
        let mut b = super::RoundSum::new();
        b.absorb(&msgs[2]);
        b.absorb(&msgs[3]);
        a.merge(b);
        let want = finish(flat.clone());
        assert_eq!(finish(rev), want);
        assert_eq!(finish(a.clone()), want);
        // Codec: size helper exact, round-trip preserves the sums.
        let mut w = ByteWriter::new();
        let expect_len = a.encoded_bytes();
        a.encode(&mut w);
        assert_eq!(w.len() as u64, expect_len);
        let mut r = ByteReader::new(w.as_slice());
        let back = super::RoundSum::decode(&mut r, 2).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!(back.committed, 4);
        assert!(back.have_loss);
        assert_eq!(finish(back), want);
    }
}

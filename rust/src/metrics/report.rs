//! Markdown/console table writer for the experiment harness — prints
//! rows in the same shape as the paper's tables.

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "ragged table row");
        self.rows.push(cells.to_vec());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as GitHub-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            s.push('\n');
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('|');
        for w in &width {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }
}

/// Format a float in scientific notation like the paper ("3e-18").
pub fn sci(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else {
        format!("{v:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new(&["Compressor", "Time (s)"]);
        t.row(&["TopK[k=8d]".into(), "18.72".into()]);
        t.row(&["Ident".into(), "24.12".into()]);
        let md = t.to_markdown();
        let lines: Vec<&str> = md.trim().lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("Compressor"));
        assert!(lines[1].starts_with("|--") || lines[1].starts_with("|-"));
        // All lines same width (aligned).
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn ragged_rows_panic() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only one".into()]);
    }

    #[test]
    fn sci_formatting() {
        assert_eq!(sci(0.0), "0");
        assert!(sci(2.8e-18).contains("e-18"));
    }
}

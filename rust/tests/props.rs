//! Property-based suites (self-contained mini-framework: seeded random
//! generation, many cases per property, failing seed reported in the
//! assert message — the role proptest would play).

use fednl::compressors::{
    by_name, distortion_sq, weighted_norm_sq, ALL_NAMES,
};
use fednl::data::parse_libsvm_bytes;
use fednl::linalg::packed::PackedUpper;
use fednl::linalg::{cholesky, gauss, iterative, Mat};
use fednl::oracle::{numerics, LogisticOracle};
use fednl::rng::{Pcg64, Rng};

fn random_packed(d: usize, rng: &mut Pcg64) -> (PackedUpper, Vec<f64>) {
    let pu = PackedUpper::new(d);
    let src = (0..pu.len()).map(|_| rng.next_gaussian()).collect();
    (pu, src)
}

/// Every compressor's *scaled contractive form* must satisfy
/// E‖C(x)−x‖² ≤ (1−δ)‖x‖² on arbitrary inputs (averaged over rounds for
/// the randomized ones).
#[test]
fn prop_contraction_bound_all_compressors() {
    let mut rng = Pcg64::seed_from_u64(1);
    for case in 0..30 {
        let d = 2 + (rng.next_below(10) as usize);
        let (pu, src) = random_packed(d, &mut rng);
        let total = weighted_norm_sq(&pu, &src);
        if total < 1e-12 {
            continue;
        }
        for name in ALL_NAMES {
            let mut c = by_name(name, d, 2, case).unwrap();
            let delta = c.kind(pu.len()).delta();
            let trials = 400;
            let mut acc = 0.0;
            for r in 0..trials {
                let out = c.compress(&pu, &src, r);
                acc += distortion_sq(&pu, &src, &out);
            }
            let mean = acc / trials as f64;
            let bound = (1.0 - delta) * total;
            assert!(
                mean <= bound * 1.12 + 1e-12,
                "case {case} {name} d={d}: E dist {mean} > (1-δ)‖x‖² {bound}"
            );
        }
    }
}

/// Decompressed values must always equal the source at their indices
/// (no compressor corrupts data — only selects/quantizes).
#[test]
fn prop_selected_values_faithful() {
    let mut rng = Pcg64::seed_from_u64(2);
    for case in 0..50 {
        let d = 2 + (rng.next_below(12) as usize);
        let (pu, src) = random_packed(d, &mut rng);
        for name in ["topk", "randk", "randseqk", "toplek", "identity"] {
            let mut c = by_name(name, d, 2, case).unwrap();
            let out = c.compress(&pu, &src, case);
            for (v, i) in out.values.iter().zip(out.indices()) {
                assert_eq!(
                    *v, src[i as usize],
                    "case {case} {name}: value mismatch at {i}"
                );
            }
        }
    }
}

/// Linear-solver agreement: Cholesky, Gaussian elimination and CG agree
/// on random SPD systems.
#[test]
fn prop_solver_agreement() {
    let mut rng = Pcg64::seed_from_u64(3);
    for case in 0..25 {
        let d = 2 + (rng.next_below(20) as usize);
        let b_mat = Mat::from_vec(
            d,
            d,
            (0..d * d).map(|_| rng.next_gaussian()).collect(),
        );
        let mut a = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += b_mat.get(k, i) * b_mat.get(k, j);
                }
                a.set(i, j, s / d as f64);
            }
        }
        a.add_diag(0.5);
        let rhs: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let x1 = cholesky::solve_spd(&a, 0.0, &rhs).unwrap();
        let x2 = gauss::solve_gauss(&a, &rhs).unwrap();
        let x3 = iterative::cg(&a, &rhs, 1e-13, 10 * d).x;
        for i in 0..d {
            assert!((x1[i] - x2[i]).abs() < 1e-7, "case {case} chol vs gauss");
            assert!((x1[i] - x3[i]).abs() < 1e-6, "case {case} chol vs cg");
        }
    }
}

/// The logistic oracle's analytic derivatives match finite differences
/// at random points of random problems.
#[test]
fn prop_oracle_derivatives() {
    let mut rng = Pcg64::seed_from_u64(4);
    for case in 0..10 {
        let d = 3 + (rng.next_below(6) as usize);
        let n = 10 + (rng.next_below(30) as usize);
        let mut at = Mat::zeros(n, d);
        for r in 0..n {
            let lab = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            for c in 0..d - 1 {
                at.set(r, c, lab * rng.next_gaussian());
            }
            at.set(r, d - 1, lab);
        }
        let mut o = LogisticOracle::from_matrix(at, 1e-3);
        let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian() * 0.3).collect();
        let ge = numerics::check_grad(&mut o, &x);
        let he = numerics::check_hessian(&mut o, &x);
        assert!(ge < 1e-6, "case {case}: grad FD err {ge}");
        assert!(he < 1e-4, "case {case}: hess FD err {he}");
    }
}

/// LIBSVM writer→parser round-trip for random datasets (fuzz-lite).
#[test]
fn prop_libsvm_roundtrip_fuzz() {
    let mut rng = Pcg64::seed_from_u64(5);
    for case in 0..40 {
        let n = 1 + rng.next_below(30) as usize;
        let d = 1 + rng.next_below(20) as usize;
        let mut text = String::new();
        let mut expect = Vec::new();
        for _ in 0..n {
            let label = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            text.push_str(if label > 0.0 { "+1" } else { "-1" });
            let mut feats = Vec::new();
            for j in 0..d {
                if rng.bernoulli(0.4) {
                    // Mixed formats: plain, exponent, high precision.
                    let v = match rng.next_below(3) {
                        0 => rng.next_gaussian(),
                        1 => rng.next_gaussian() * 1e-7,
                        _ => (rng.next_below(1000) as f64) / 8.0,
                    };
                    text.push_str(&format!(" {}:{}", j + 1, v));
                    feats.push((j as u32, v));
                }
            }
            text.push('\n');
            expect.push((label, feats));
        }
        let (samples, _) = parse_libsvm_bytes(text.as_bytes()).unwrap();
        assert_eq!(samples.len(), n, "case {case}");
        for (s, (lab, feats)) in samples.iter().zip(&expect) {
            assert_eq!(s.label, *lab, "case {case}");
            assert_eq!(s.features.len(), feats.len(), "case {case}");
            for ((gi, gv), (ei, ev)) in s.features.iter().zip(feats) {
                assert_eq!(gi, ei);
                assert!(
                    (gv - ev).abs() <= 1e-13 * ev.abs().max(1e-3),
                    "case {case}: {gv} vs {ev}"
                );
            }
        }
    }
}

/// Wire codec fuzz: random ClientMsgs survive encode→decode bit-exactly.
#[test]
fn prop_wire_roundtrip_fuzz() {
    use fednl::algorithms::ClientMsg;
    use fednl::compressors::{Compressed, IndexPayload};
    use fednl::net::wire;
    let mut rng = Pcg64::seed_from_u64(6);
    for case in 0..100 {
        let d = 1 + rng.next_below(40) as usize;
        let n = 1 + rng.next_below(200) as u32;
        let k = 1 + rng.next_below(n as u64 % 50 + 1) as u32;
        let payload = match rng.next_below(4) {
            0 => IndexPayload::Explicit(
                (0..k).map(|_| rng.next_below(n as u64) as u32).collect(),
            ),
            1 => IndexPayload::Seed { seed: rng.next_u64(), k },
            2 => IndexPayload::SeqStart {
                start: rng.next_below(n as u64) as u32,
                k,
            },
            _ => IndexPayload::Dense,
        };
        let nvals = match &payload {
            IndexPayload::Dense => n as usize,
            IndexPayload::Explicit(ix) => ix.len(),
            IndexPayload::Seed { k, .. } | IndexPayload::SeqStart { k, .. } => {
                *k as usize
            }
        };
        let msg = ClientMsg {
            client_id: rng.next_below(1000) as usize,
            grad: (0..d).map(|_| rng.next_gaussian()).collect(),
            update: Compressed {
                payload,
                values: (0..nvals).map(|_| rng.next_gaussian()).collect(),
                scale: if rng.bernoulli(0.3) { 8.0 / 9.0 } else { 1.0 },
                encoding: fednl::compressors::ValueEncoding::F64,
                n,
            },
            l_i: rng.next_f64(),
            loss: if rng.bernoulli(0.5) {
                Some(rng.next_gaussian())
            } else {
                None
            },
        };
        // Logical wire accounting is exact for every payload shape.
        assert_eq!(
            msg.wire_bytes(),
            wire::encode_client_msg(&msg).len() as u64
                + wire::FRAME_HEADER_BYTES,
            "case {case}"
        );
        let dec = wire::decode_client_msg(&wire::encode_client_msg(&msg))
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(dec.client_id, msg.client_id);
        assert_eq!(dec.grad, msg.grad);
        assert_eq!(dec.l_i, msg.l_i);
        assert_eq!(dec.loss, msg.loss);
        assert_eq!(dec.update.values, msg.update.values);
        assert_eq!(dec.update.scale, msg.update.scale);
        assert_eq!(dec.update.payload, msg.update.payload);
    }
}

/// TopLEK never sends more than TopK would, over many random inputs.
#[test]
fn prop_toplek_never_exceeds_k() {
    let mut rng = Pcg64::seed_from_u64(7);
    for case in 0..60 {
        let d = 2 + rng.next_below(12) as usize;
        let (pu, src) = random_packed(d, &mut rng);
        let k = 1 + rng.next_below(pu.len() as u64) as usize;
        let mut lek = fednl::compressors::TopLEK::new(k, case);
        use fednl::compressors::Compressor;
        let out = lek.compress(&pu, &src, case);
        assert!(
            out.values.len() <= k,
            "case {case}: sent {} > k={k}",
            out.values.len()
        );
    }
}

// ---------------------------------------------------------------------
// Fault-tolerant quorum rounds (coordinator::faults + engine policy).
// ---------------------------------------------------------------------

/// For any seeded FaultPlan, quorum-round FedNL-PP trajectories are
/// bit-identical across SeqPool and ThreadedPool — wall clock never
/// decides an outcome, only the (plan, round) schedule does.
#[test]
fn prop_fault_plans_bit_identical_across_pools() {
    use fednl::algorithms::{
        run_fednl_pp_pool, OnMissing, Options, PPClientState, RoundPolicy,
    };
    use fednl::coordinator::{FaultPlan, FaultPool, SeqPool, ThreadedPool};
    use fednl::data::{generate_synthetic, Dataset, SynthSpec};
    use fednl::oracle::LogisticOracle;

    let n_clients = 5usize;
    let rounds = 15u64;
    let make_clients = |seed: u64, x0: &[f64], d: usize| -> Vec<PPClientState> {
        let spec = SynthSpec {
            d_raw: d - 1,
            n_samples: n_clients * 30,
            density: 0.6,
            noise: 1.0,
            label_bias: 0.0,
            seed,
        };
        let synth = generate_synthetic(&spec);
        let samples: Vec<fednl::data::LibsvmSample> = synth
            .labels
            .iter()
            .zip(&synth.rows)
            .map(|(l, r)| fednl::data::LibsvmSample {
                label: *l,
                features: r.clone(),
            })
            .collect();
        let ds = Dataset::from_libsvm(&samples, spec.d_raw);
        ds.split_even(n_clients)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                PPClientState::new(
                    i,
                    Box::new(LogisticOracle::new(sh, 1e-3)),
                    fednl::compressors::by_name("topk", d, 2, 40 + i as u64)
                        .unwrap(),
                    None,
                    x0,
                )
            })
            .collect()
    };

    let mut rng = Pcg64::seed_from_u64(0xFA17);
    for case in 0..6u64 {
        let d = 8usize;
        let x0 = vec![0.0; d];
        // Random plan: one kill span, up to two drops.
        let victim = rng.next_below(n_clients as u64) as u32;
        let from = 1 + rng.next_below(rounds - 4);
        let until = from + 2 + rng.next_below(rounds - from - 2);
        let mut plan = FaultPlan::none().with_kill(victim, from, Some(until));
        for _ in 0..rng.next_below(3) {
            let r = rng.next_below(rounds);
            let c = rng.next_below(n_clients as u64) as u32;
            // At most one drop per round: together with the single
            // kill span, at most two of the τ=3 picks can be lost in
            // any round, so quorum 1 holds *structurally* for every
            // generated plan (not just this seed).
            if !plan.drops.iter().any(|&(pr, _)| pr == r) {
                plan = plan.with_drop(r, c);
            }
        }
        let on_missing = if case % 2 == 0 {
            OnMissing::Drop
        } else {
            OnMissing::Resample
        };
        let opts = Options {
            rounds,
            policy: RoundPolicy {
                quorum: Some(1),
                deadline_ms: None,
                on_missing,
            },
            ..Default::default()
        };
        // τ=3 of 5: even with the kill and both drops landing on one
        // round, at least one participant commits (quorum 1 holds).
        let (tau, seed) = (3usize, 900 + case);

        let mut seq = FaultPool::new(
            SeqPool::new(make_clients(70 + case, &x0, d)),
            plan.clone(),
        );
        let t_seq = run_fednl_pp_pool(
            &mut seq,
            &opts,
            tau,
            seed,
            x0.clone(),
            "prop-seq",
        );
        for workers in [2usize, 5] {
            let mut thr = FaultPool::new(
                ThreadedPool::new(make_clients(70 + case, &x0, d), workers),
                plan.clone(),
            );
            let t_thr = run_fednl_pp_pool(
                &mut thr,
                &opts,
                tau,
                seed,
                x0.clone(),
                "prop-thr",
            );
            assert_eq!(t_seq.records.len(), t_thr.records.len());
            for (a, b) in t_seq.records.iter().zip(&t_thr.records) {
                assert!(
                    a.grad_norm.to_bits() == b.grad_norm.to_bits()
                        && a.bytes_up == b.bytes_up
                        && a.committed == b.committed
                        && a.missing == b.missing,
                    "case {case} ({plan:?}, {on_missing:?}) workers={workers} \
                     diverged at round {}",
                    a.round
                );
            }
        }
    }
}

/// The Resample policy never hands a participation slot to a dead
/// client (and a fortiori never selects one twice in a round), for any
/// seed and any dead set, while keeping selections distinct and the
/// subset size maximal given the live population.
#[test]
fn prop_resample_never_selects_dead() {
    use fednl::algorithms::{select_pp_subset, OnMissing};
    let mut rng = Pcg64::seed_from_u64(0xDEAD5EED);
    for case in 0..300u64 {
        let n = 2 + rng.next_below(12) as usize;
        let tau = 1 + rng.next_below(n as u64) as usize;
        let n_dead = rng.next_below(n as u64) as usize;
        let mut dead: Vec<u32> = (0..n as u32).collect();
        fednl::rng::shuffle(&mut rng, &mut dead);
        dead.truncate(n_dead);
        let mut draw = Pcg64::seed_from_u64(1000 + case);
        let sel =
            select_pp_subset(&mut draw, n, tau, &dead, OnMissing::Resample);
        // No dead client ever selected.
        for c in &sel {
            assert!(
                !dead.contains(c),
                "case {case}: dead client {c} selected (dead={dead:?})"
            );
        }
        // All distinct (no client — dead or live — selected twice).
        let mut sorted = sel.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), sel.len(), "case {case}: duplicates");
        // Maximal given the live population.
        let live = n - n_dead;
        assert_eq!(
            sel.len(),
            tau.min(live),
            "case {case}: n={n} tau={tau} dead={n_dead}"
        );
        // Deterministic in the seed.
        let mut draw2 = Pcg64::seed_from_u64(1000 + case);
        let sel2 =
            select_pp_subset(&mut draw2, n, tau, &dead, OnMissing::Resample);
        assert_eq!(sel, sel2, "case {case}: not seed-deterministic");
    }
}

//! Dense training dataset + client sharding.
//!
//! Follows the paper's preparation pipeline exactly (§5, App. B): every
//! sample is augmented with a constant-1 intercept feature, labels are
//! absorbed into the design matrix (column_j = b_j·a_j, §5.13 — so
//! labels need not be stored), the dataset is reshuffled u.a.r., split
//! into equal nᵢ-sized shards across n clients, and leftovers dropped.
//!
//! Storage is `At`: an (n_samples × d) row-major matrix whose *rows* are
//! samples — so margins (row·x) and rank-1 Hessian updates touch
//! contiguous memory (paper v53 stores only one orientation).

use super::libsvm::LibsvmSample;
use crate::linalg::Mat;
use crate::rng::{shuffle, Pcg64};

/// Dense dataset with labels absorbed and intercept appended.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// (n × d) row-major; row j is b_j · [a_j, 1].
    pub at: Mat,
    /// Feature dimension *including* the intercept column.
    pub d: usize,
}

impl Dataset {
    /// Densify parsed LIBSVM samples; `d_raw` excludes the intercept.
    pub fn from_libsvm(samples: &[LibsvmSample], d_raw: usize) -> Self {
        let d = d_raw + 1; // +1 intercept (paper: "augmented each sample")
        let n = samples.len();
        let mut at = Mat::zeros(n, d);
        for (r, s) in samples.iter().enumerate() {
            let row = at.row_mut(r);
            for &(idx, val) in &s.features {
                row[idx as usize] = s.label * val;
            }
            row[d - 1] = s.label; // b_j · 1
        }
        Self { at, d }
    }

    /// Build directly from a dense matrix whose rows already absorb
    /// labels and intercept (synthetic generator path).
    pub fn from_dense(at: Mat) -> Self {
        let d = at.cols();
        Self { at, d }
    }

    pub fn n_samples(&self) -> usize {
        self.at.rows()
    }

    /// Reshuffle samples u.a.r. in place with the given seed.
    pub fn reshuffle(&mut self, seed: u64) {
        let n = self.n_samples();
        let mut order: Vec<u32> = (0..n as u32).collect();
        let mut rng = Pcg64::seed_from_u64(seed);
        shuffle(&mut rng, &mut order);
        let mut shuffled = Mat::zeros(n, self.d);
        for (dst, &src) in order.iter().enumerate() {
            shuffled.row_mut(dst).copy_from_slice(self.at.row(src as usize));
        }
        self.at = shuffled;
    }

    /// Split into `n_clients` equal shards of `n_i` samples each
    /// (leftover samples are excluded, as in the paper: "the remaining
    /// 49 samples were excluded"). Returns an error if there is not
    /// enough data.
    pub fn split(
        &self,
        n_clients: usize,
        n_i: usize,
    ) -> anyhow::Result<Vec<ClientShard>> {
        anyhow::ensure!(n_clients > 0 && n_i > 0, "empty split");
        anyhow::ensure!(
            n_clients * n_i <= self.n_samples(),
            "split needs {} samples, dataset has {}",
            n_clients * n_i,
            self.n_samples()
        );
        let mut shards = Vec::with_capacity(n_clients);
        for c in 0..n_clients {
            let mut at = Mat::zeros(n_i, self.d);
            for r in 0..n_i {
                at.row_mut(r).copy_from_slice(self.at.row(c * n_i + r));
            }
            shards.push(ClientShard { client_id: c, at });
        }
        Ok(shards)
    }

    /// Split into `n_clients` shards of `total / n_clients` samples.
    pub fn split_even(&self, n_clients: usize) -> anyhow::Result<Vec<ClientShard>> {
        let n_i = self.n_samples() / n_clients;
        self.split(n_clients, n_i)
    }
}

/// One client's local data (FedNL never moves raw data off the client).
#[derive(Debug, Clone)]
pub struct ClientShard {
    pub client_id: usize,
    /// (n_i × d) rows = local samples with labels/intercept absorbed.
    pub at: Mat,
}

impl ClientShard {
    pub fn n_i(&self) -> usize {
        self.at.rows()
    }

    pub fn d(&self) -> usize {
        self.at.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::libsvm::parse_libsvm_bytes;

    fn toy() -> Dataset {
        let (s, d) =
            parse_libsvm_bytes(b"+1 1:2 2:3\n-1 1:-1\n+1 2:5\n-1 2:-4\n")
                .unwrap();
        Dataset::from_libsvm(&s, d)
    }

    #[test]
    fn densify_absorbs_labels_and_intercept() {
        let ds = toy();
        assert_eq!(ds.d, 3);
        assert_eq!(ds.n_samples(), 4);
        // Sample 0: +1 * [2, 3, 1]
        assert_eq!(ds.at.row(0), &[2.0, 3.0, 1.0]);
        // Sample 1: -1 * [-1, 0, 1]
        assert_eq!(ds.at.row(1), &[1.0, 0.0, -1.0]);
    }

    #[test]
    fn reshuffle_preserves_multiset() {
        let mut ds = toy();
        let before: Vec<Vec<f64>> =
            (0..4).map(|i| ds.at.row(i).to_vec()).collect();
        ds.reshuffle(42);
        let mut after: Vec<Vec<f64>> =
            (0..4).map(|i| ds.at.row(i).to_vec()).collect();
        let mut b = before.clone();
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        after.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(b, after);
    }

    #[test]
    fn reshuffle_deterministic() {
        let mut a = toy();
        let mut b = toy();
        a.reshuffle(7);
        b.reshuffle(7);
        assert_eq!(a.at, b.at);
    }

    #[test]
    fn split_shapes_and_leftovers() {
        let ds = toy();
        let shards = ds.split(2, 2).unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].n_i(), 2);
        assert_eq!(shards[1].client_id, 1);
        // 3 clients × 2 samples needs 6 > 4 → error
        assert!(ds.split(3, 2).is_err());
        // uneven split drops leftovers
        let se = ds.split_even(3).unwrap();
        assert_eq!(se.len(), 3);
        assert_eq!(se[0].n_i(), 1);
    }
}

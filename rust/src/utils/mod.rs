//! Small self-contained system utilities (paper components `timers`,
//! `copylocal`, `fs`): wall-clock timers, byte buffers with explicit
//! little-endian layout, and human-readable formatting.

pub mod bytes;
pub mod digest;
pub mod timer;

pub use bytes::{ByteReader, ByteWriter};
pub use timer::{Stopwatch, TimerStats};

/// Format a byte count like the paper's tables ("2 937.0 MBytes").
pub fn human_bytes(b: u64) -> String {
    const KB: f64 = 1024.0;
    let b = b as f64;
    if b >= KB * KB * KB {
        format!("{:.2} GB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.2} MB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.2} KB", b / KB)
    } else {
        format!("{b:.0} B")
    }
}

/// Format seconds with adaptive precision.
pub fn human_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.1} s")
    } else if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} µs", s * 1e6)
    }
}

/// Number of cores to size thread pools (paper §5.12 sizes the worker
/// pool to physical cores; std only exposes logical CPUs, so we use
/// that, clamped to at least 1).
pub fn available_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_scales() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MB");
        assert!(human_bytes(5 * 1024 * 1024 * 1024).ends_with("GB"));
    }

    #[test]
    fn human_secs_scales() {
        assert!(human_secs(123.4).contains("123.4"));
        assert!(human_secs(0.5).contains("ms"));
        assert!(human_secs(2e-6).contains("µs"));
    }

    #[test]
    fn cores_positive() {
        assert!(available_cores() >= 1);
    }
}

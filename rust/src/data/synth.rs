//! Synthetic logistic-regression problem generator (paper component
//! `bin_opt_problem_generator`).
//!
//! The paper's datasets (LIBSVM W8A/A9A/PHISHING) are not redistributable
//! here, so the harness generates datasets with the *same shapes and
//! conditioning regime* and writes them in LIBSVM text format — the
//! loader then exercises the identical mmap→parse→densify→shuffle→split
//! pipeline (DESIGN.md §2 substitution table).
//!
//! Model: a ground-truth hyperplane w*, features ~ N(0, 1)·scale with a
//! sparsity mask (LIBSVM datasets are sparse), labels sampled from the
//! logistic model with temperature `noise` (so the problem is realizable
//! but not separable — keeping the Hessian well-conditioned like W8A's
//! λ(∇²f) ∈ [1e-3, 5.8e-3] regime under λ=1e-3 regularization).

use crate::rng::{Pcg64, Rng};

/// Specification for a synthetic dataset.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    /// Feature dimension excluding intercept (e.g. 300 for w8a-like).
    pub d_raw: usize,
    /// Number of samples.
    pub n_samples: usize,
    /// Fraction of non-zero features per sample (W8A ≈ 0.04).
    pub density: f64,
    /// Label noise temperature; 0 = deterministic labels.
    pub noise: f64,
    /// Constant shift added to every sample's logistic margin before
    /// the label is drawn — the label-skew knob for non-IID
    /// experiments. 0 keeps the classes roughly balanced; positive
    /// values tilt the dataset toward `+1` (e.g. +2 gives ≈ 80–90%
    /// positives under `noise = 1`), negative toward `−1`. Generating
    /// per-client datasets with different biases yields heterogeneous
    /// local objectives while staying on the same ground-truth
    /// hyperplane.
    pub label_bias: f64,
    /// PRG seed.
    pub seed: u64,
}

impl SynthSpec {
    /// Shape presets mirroring the paper's three benchmark datasets.
    pub fn preset(name: &str) -> Option<Self> {
        let (d_raw, n_samples, density) = match name {
            // W8A: d=301 incl. intercept, 49 749 samples, sparse binary
            "w8a" => (300, 49_749, 0.04),
            "a9a" => (123, 32_561, 0.11),
            "phishing" => (68, 11_055, 0.44),
            "quickstart" => (63, 8_192, 0.25),
            "tiny" => (15, 1_024, 0.5),
            _ => return None,
        };
        Some(Self {
            d_raw,
            n_samples,
            density,
            noise: 1.0,
            label_bias: 0.0,
            seed: 0x5EED,
        })
    }
}

/// A generated sample in sparse form (pre-densification).
pub struct SynthData {
    pub labels: Vec<f64>,
    /// Per-sample (idx0, value) lists, 0-based.
    pub rows: Vec<Vec<(u32, f64)>>,
    pub d_raw: usize,
}

/// Generate a synthetic dataset according to `spec`.
pub fn generate_synthetic(spec: &SynthSpec) -> SynthData {
    let mut rng = Pcg64::seed_from_u64(spec.seed);
    // Ground-truth weights (including an intercept term).
    let w_star: Vec<f64> =
        (0..spec.d_raw + 1).map(|_| rng.next_gaussian()).collect();
    let mut labels = Vec::with_capacity(spec.n_samples);
    let mut rows = Vec::with_capacity(spec.n_samples);
    for _ in 0..spec.n_samples {
        let mut feats: Vec<(u32, f64)> = Vec::new();
        let mut margin = w_star[spec.d_raw] + spec.label_bias; // icept
        for j in 0..spec.d_raw {
            if rng.bernoulli(spec.density) {
                let v = rng.next_gaussian();
                feats.push((j as u32, v));
                margin += w_star[j] * v;
            }
        }
        let label = if spec.noise > 0.0 {
            let p = 1.0 / (1.0 + (-margin / spec.noise).exp());
            if rng.bernoulli(p) {
                1.0
            } else {
                -1.0
            }
        } else if margin >= 0.0 {
            1.0
        } else {
            -1.0
        };
        labels.push(label);
        rows.push(feats);
    }
    SynthData { labels, rows, d_raw: spec.d_raw }
}

/// Serialize to LIBSVM text (1-based indices), as `bin_split`'s input.
pub fn write_libsvm(data: &SynthData) -> String {
    let mut out = String::with_capacity(data.rows.len() * 64);
    for (label, feats) in data.labels.iter().zip(&data.rows) {
        if *label > 0.0 {
            out.push_str("+1");
        } else {
            out.push_str("-1");
        }
        for (idx, val) in feats {
            out.push(' ');
            out.push_str(&(idx + 1).to_string());
            out.push(':');
            // Shortest round-trippable representation.
            out.push_str(&format!("{val}"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::libsvm::parse_libsvm_bytes;

    #[test]
    fn presets_exist() {
        for name in ["w8a", "a9a", "phishing", "quickstart", "tiny"] {
            assert!(SynthSpec::preset(name).is_some(), "{name}");
        }
        assert!(SynthSpec::preset("nope").is_none());
    }

    #[test]
    fn generator_is_deterministic() {
        let spec = SynthSpec {
            d_raw: 10,
            n_samples: 50,
            density: 0.3,
            noise: 1.0,
            label_bias: 0.0,
            seed: 1,
        };
        let a = generate_synthetic(&spec);
        let b = generate_synthetic(&spec);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.rows.len(), b.rows.len());
        assert_eq!(a.rows[7], b.rows[7]);
    }

    #[test]
    fn labels_are_pm_one_and_mixed() {
        let spec = SynthSpec {
            d_raw: 20,
            n_samples: 500,
            density: 0.5,
            noise: 1.0,
            label_bias: 0.0,
            seed: 2,
        };
        let d = generate_synthetic(&spec);
        assert!(d.labels.iter().all(|&l| l == 1.0 || l == -1.0));
        let pos = d.labels.iter().filter(|&&l| l == 1.0).count();
        assert!(pos > 50 && pos < 450, "degenerate label split: {pos}");
    }

    #[test]
    fn libsvm_roundtrip() {
        let spec = SynthSpec {
            d_raw: 8,
            n_samples: 40,
            density: 0.6,
            noise: 0.5,
            label_bias: 0.0,
            seed: 3,
        };
        let d = generate_synthetic(&spec);
        let text = write_libsvm(&d);
        let (samples, d_raw) = parse_libsvm_bytes(text.as_bytes()).unwrap();
        assert_eq!(samples.len(), 40);
        assert!(d_raw <= 8);
        for (s, (lab, row)) in
            samples.iter().zip(d.labels.iter().zip(&d.rows))
        {
            assert_eq!(s.label, *lab);
            assert_eq!(s.features.len(), row.len());
            for ((gi, gv), (ei, ev)) in s.features.iter().zip(row) {
                assert_eq!(gi, ei);
                assert!((gv - ev).abs() < 1e-12 * ev.abs().max(1.0));
            }
        }
    }

    #[test]
    fn density_respected() {
        let spec = SynthSpec {
            d_raw: 100,
            n_samples: 200,
            density: 0.1,
            noise: 1.0,
            label_bias: 0.0,
            seed: 4,
        };
        let d = generate_synthetic(&spec);
        let nnz: usize = d.rows.iter().map(|r| r.len()).sum();
        let rate = nnz as f64 / (200.0 * 100.0);
        assert!((rate - 0.1).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn label_bias_skews_the_class_balance() {
        let base = SynthSpec {
            d_raw: 20,
            n_samples: 600,
            density: 0.5,
            noise: 1.0,
            label_bias: 0.0,
            seed: 11,
        };
        let pos_frac = |bias: f64| {
            let d = generate_synthetic(&SynthSpec {
                label_bias: bias,
                ..base.clone()
            });
            d.labels.iter().filter(|&&l| l == 1.0).count() as f64
                / d.labels.len() as f64
        };
        let (lo, mid, hi) = (pos_frac(-2.0), pos_frac(0.0), pos_frac(2.0));
        assert!(lo < mid && mid < hi, "lo={lo} mid={mid} hi={hi}");
        assert!(hi > 0.7, "bias +2 should tilt positive: {hi}");
        assert!(lo < 0.3, "bias −2 should tilt negative: {lo}");
        // The bias shifts *labels only*: features are drawn from the
        // same PRG stream, so rows are identical across biases (a
        // seeded-determinism guarantee the per-client non-IID
        // generator relies on)...
        let a = generate_synthetic(&SynthSpec {
            label_bias: -2.0,
            ..base.clone()
        });
        let b = generate_synthetic(&SynthSpec {
            label_bias: 2.0,
            ..base.clone()
        });
        assert_eq!(a.rows.len(), b.rows.len());
        assert_eq!(a.rows[13], b.rows[13]);
        // ...and the same (spec, seed) reproduces labels bit-exactly.
        let c = generate_synthetic(&SynthSpec {
            label_bias: 2.0,
            ..base
        });
        assert_eq!(b.labels, c.labels);
    }
}

#!/usr/bin/env python3
"""CI bench-regression gate (stdlib only).

Compares a freshly emitted bench JSON against a committed baseline and
fails (exit 1) when any tracked metric regressed by more than the
threshold:

* ``BENCH_kernels.json``      — per-kernel ``simd_ns``   (key: name, n)
* ``BENCH_coordinator.json``  — per-pool   ``total_s`` **and**, where
  emitted (the ``event100k`` readiness-transport scaling row),
  ``idle_client_bytes`` (steady-state server-side bookkeeping per
  registered client; a memory regression fails CI exactly like a time
  regression) (key: pool, e.g. ``event100k`` /
  ``event100k/idle_client_bytes``)
* ``BENCH_shard.json``        — per-config ``total_s`` **and**
  ``payload_bytes`` (per-round shard→master payload; a payload
  regression fails CI exactly like a time regression) (key: key,
  e.g. ``S=2/seq`` / ``S=2/seq/payload_bytes``)
* ``BENCH_reduce.json``       — per-row    ``simd_ns``   (key: name, n;
  the reproducible-summation kernels)

The kernel and reduce tables additionally carry an ``avx512_ns``
column, gated as an *optional* metric (key suffix ``/avx512_ns``):
the AVX-512 tier is a host+toolchain capability, so a fresh run whose
column is ``null`` (runner without AVX-512, or the pinned pre-1.89
toolchain) downgrades the comparison to a note instead of failing the
gate. A present-and-slower ``avx512_ns`` fails like any other metric.

Usage:
    check_bench.py FRESH BASELINE          # gate (exit 1 on regression)
    check_bench.py --update FRESH BASELINE # refresh the baseline file
    check_bench.py --self-test             # verify the gate itself

The slowdown threshold is 0.25 (25 %) by default and can be overridden
with the ``BENCH_REGRESSION_THRESHOLD`` environment variable (e.g.
``BENCH_REGRESSION_THRESHOLD=0.5`` on noisy runners).

Baselines live in ``ci/baselines/`` and are refreshed by running the
benches on a representative runner and committing the result of
``--update`` (the first committed baselines are deliberately generous
upper bounds — see ci/README.md).
"""

import json
import os
import sys


def threshold():
    return float(os.environ.get("BENCH_REGRESSION_THRESHOLD", "0.25"))


# Key suffix of metrics that depend on a host/toolchain capability: a
# baseline entry missing from the fresh run is a note, not a failure.
OPTIONAL_SUFFIX = "/avx512_ns"


def extract(doc):
    """Return (mode, {key: metric_value}) for either bench schema."""
    if "kernels" in doc:
        rows = {}
        for k in doc["kernels"]:
            rows[f"{k['name']}[n={k['n']}]"] = float(k["simd_ns"])
            if k.get("avx512_ns") is not None:
                rows[f"{k['name']}[n={k['n']}]{OPTIONAL_SUFFIX}"] = float(
                    k["avx512_ns"]
                )
        return "kernels/simd_ns", rows
    if "pools" in doc:
        rows = {}
        for p in doc["pools"]:
            rows[p["pool"]] = float(p["total_s"])
            if p.get("idle_client_bytes") is not None:
                rows[f"{p['pool']}/idle_client_bytes"] = float(
                    p["idle_client_bytes"]
                )
        return "coordinator/total_s+idle", rows
    if "configs" in doc:
        rows = {}
        for c in doc["configs"]:
            rows[c["key"]] = float(c["total_s"])
            if "payload_bytes" in c:
                rows[f"{c['key']}/payload_bytes"] = float(
                    c["payload_bytes"]
                )
        return "shard/total_s+payload", rows
    if "reduce" in doc:
        rows = {}
        for k in doc["reduce"]:
            rows[f"{k['name']}[n={k['n']}]"] = float(k["simd_ns"])
            if k.get("avx512_ns") is not None:
                rows[f"{k['name']}[n={k['n']}]{OPTIONAL_SUFFIX}"] = float(
                    k["avx512_ns"]
                )
        return "reduce/simd_ns", rows
    raise SystemExit(
        "unrecognized bench JSON: no 'kernels', 'pools', 'configs' or "
        "'reduce' key"
    )


def compare(fresh, base, thresh):
    """Return (regressions, notes): regressions is a list of strings."""
    fresh_mode, fresh_rows = extract(fresh)
    base_mode, base_rows = extract(base)
    if fresh_mode != base_mode:
        raise SystemExit(
            f"schema mismatch: fresh is {fresh_mode}, baseline is {base_mode}"
        )
    regressions, notes = [], []
    for key, base_v in sorted(base_rows.items()):
        if key not in fresh_rows:
            if key.endswith(OPTIONAL_SUFFIX):
                # Capability-gated column: null on this runner (no
                # AVX-512, or the pinned pre-1.89 toolchain) is an
                # expected environment difference, not schema drift.
                notes.append(
                    f"  ~ {key}: tier unavailable on this runner (skipped)"
                )
                continue
            # A tracked metric vanishing must not silently shrink the
            # gate's coverage (renamed kernel, changed n, empty emit):
            # schema drift has to be acknowledged via --update.
            regressions.append(
                f"  ! {key}: missing from fresh run "
                f"(schema drift? refresh the baseline with --update)"
            )
            continue
        fresh_v = fresh_rows[key]
        if base_v <= 0:
            notes.append(f"  ~ {key}: non-positive baseline {base_v}")
            continue
        ratio = fresh_v / base_v
        line = f"{key}: {fresh_v:.1f} vs baseline {base_v:.1f} ({ratio:.2f}x)"
        if ratio > 1.0 + thresh:
            regressions.append(f"  ! {line}")
        else:
            notes.append(f"  . {line}")
    for key in sorted(set(fresh_rows) - set(base_rows)):
        notes.append(f"  + {key}: new metric (no baseline yet)")
    return regressions, notes


def self_test():
    """The gate must trip on a fabricated >threshold slowdown and stay
    quiet under one, for both schemas. Verifies the acceptance
    criterion 'ci.yml fails when a committed baseline kernel is
    artificially slowed >25%' without needing a Rust toolchain."""
    base = {
        "isa": "avx2",
        "kernels": [
            {"name": "dot", "n": 301, "simd_ns": 100.0},
            {"name": "axpy", "n": 4096, "simd_ns": 1000.0},
        ],
    }
    slowed = {
        "isa": "avx2",
        "kernels": [
            {"name": "dot", "n": 301, "simd_ns": 130.0},  # +30% -> trip
            {"name": "axpy", "n": 4096, "simd_ns": 1010.0},
        ],
    }
    ok = {
        "isa": "avx2",
        "kernels": [
            {"name": "dot", "n": 301, "simd_ns": 110.0},  # +10% -> pass
            {"name": "axpy", "n": 4096, "simd_ns": 900.0},
        ],
    }
    reg, _ = compare(slowed, base, 0.25)
    assert len(reg) == 1 and "dot[n=301]" in reg[0], reg
    reg, _ = compare(ok, base, 0.25)
    assert reg == [], reg
    # Threshold is honored.
    reg, _ = compare(slowed, base, 0.50)
    assert reg == [], reg

    cbase = {"pools": [{"pool": "seq", "total_s": 1.0},
                       {"pool": "threaded", "total_s": 0.5}]}
    cslow = {"pools": [{"pool": "seq", "total_s": 1.3},
                       {"pool": "threaded", "total_s": 0.5}]}
    reg, _ = compare(cslow, cbase, 0.25)
    assert len(reg) == 1 and reg[0].lstrip().startswith("! seq"), reg
    # The event-transport scaling row gates its idle-memory metric
    # exactly like a timing: a >threshold per-client growth trips.
    ibase = {"pools": [
        {"pool": "event100k", "total_s": 10.0, "idle_client_bytes": 100.0}]}
    igrow = {"pools": [
        {"pool": "event100k", "total_s": 10.0, "idle_client_bytes": 200.0}]}
    reg, _ = compare(igrow, ibase, 0.25)
    assert (
        len(reg) == 1 and "event100k/idle_client_bytes" in reg[0]
    ), reg
    reg, _ = compare(ibase, ibase, 0.25)
    assert reg == [], reg
    # A tracked metric disappearing (schema drift / empty emit) must
    # FAIL the gate, not silently shrink its coverage.
    reg, notes = compare({"pools": []}, cbase, 0.25)
    assert len(reg) == 2 and notes == [], (reg, notes)
    reg, _ = compare(
        {"kernels": [{"name": "dot", "n": 301, "simd_ns": 100.0}]},
        base,
        0.25,
    )
    assert len(reg) == 1 and "axpy[n=4096]" in reg[0], reg

    # avx512_ns is an optional, capability-gated column: a numeric
    # value is gated like any metric, a null (or absent) value in the
    # fresh run only downgrades the baseline entry to a note.
    abase = {"kernels": [
        {"name": "dot", "n": 301, "simd_ns": 100.0, "avx512_ns": 60.0}]}
    aslow = {"kernels": [
        {"name": "dot", "n": 301, "simd_ns": 100.0, "avx512_ns": 90.0}]}
    reg, _ = compare(aslow, abase, 0.25)
    assert len(reg) == 1 and "dot[n=301]/avx512_ns" in reg[0], reg
    anull = {"kernels": [
        {"name": "dot", "n": 301, "simd_ns": 100.0, "avx512_ns": None}]}
    reg, notes = compare(anull, abase, 0.25)
    assert reg == [], reg
    assert any("avx512_ns" in n and "unavailable" in n for n in notes), notes
    # A fresh run gaining the column over an old baseline: note only.
    reg, notes = compare(abase, anull, 0.25)
    assert reg == [], reg
    assert any("new metric" in n for n in notes), notes

    # Shard-tier schema: per-config total_s AND payload_bytes, keyed
    # by "S=N/pool" / "S=N/pool/payload_bytes".
    sbase = {"configs": [{"key": "S=1/seq", "shards": 1, "total_s": 1.0,
                          "payload_bytes": 50000},
                         {"key": "S=2/seq", "shards": 2, "total_s": 0.8,
                          "payload_bytes": 20000}]}
    sslow = {"configs": [{"key": "S=1/seq", "shards": 1, "total_s": 1.0,
                          "payload_bytes": 50000},
                         {"key": "S=2/seq", "shards": 2, "total_s": 1.1,
                          "payload_bytes": 20000}]}
    reg, _ = compare(sslow, sbase, 0.25)
    assert len(reg) == 1 and "S=2/seq" in reg[0], reg
    reg, _ = compare(sbase, sbase, 0.25)
    assert reg == [], reg
    # A payload regression fails the gate exactly like a time one.
    sfat = {"configs": [{"key": "S=1/seq", "shards": 1, "total_s": 1.0,
                         "payload_bytes": 50000},
                        {"key": "S=2/seq", "shards": 2, "total_s": 0.8,
                         "payload_bytes": 31000}]}
    reg, _ = compare(sfat, sbase, 0.25)
    assert len(reg) == 1 and "S=2/seq/payload_bytes" in reg[0], reg
    # A vanished config fails the gate (schema drift): both its time
    # and payload rows disappear.
    reg, _ = compare({"configs": []}, sbase, 0.25)
    assert len(reg) == 4, reg
    # A baseline predating the payload column only gains notes.
    old_base = {"configs": [{"key": "S=1/seq", "total_s": 1.0},
                            {"key": "S=2/seq", "total_s": 0.8}]}
    reg, notes = compare(sbase, old_base, 0.25)
    assert reg == [], reg
    assert any("payload_bytes" in n for n in notes), notes

    # Reduce schema: per-row simd_ns, keyed like the kernel table.
    rbase = {"reduce": [
        {"name": "binned_accumulate", "n": 4096, "naive_ns": 900.0,
         "scalar_ns": 4000.0, "simd_ns": 3000.0}]}
    rslow = {"reduce": [
        {"name": "binned_accumulate", "n": 4096, "naive_ns": 900.0,
         "scalar_ns": 4000.0, "simd_ns": 3900.0}]}
    reg, _ = compare(rslow, rbase, 0.25)
    assert len(reg) == 1 and "binned_accumulate[n=4096]" in reg[0], reg
    reg, _ = compare(rbase, rbase, 0.25)
    assert reg == [], reg
    print("check_bench.py self-test OK")


def main(argv):
    if "--self-test" in argv:
        self_test()
        return 0
    update = "--update" in argv
    paths = [a for a in argv if not a.startswith("--")]
    if len(paths) != 2:
        print(__doc__)
        return 2
    fresh_path, base_path = paths
    with open(fresh_path) as f:
        fresh = json.load(f)
    if update:
        os.makedirs(os.path.dirname(base_path) or ".", exist_ok=True)
        with open(base_path, "w") as f:
            json.dump(fresh, f, indent=2)
            f.write("\n")
        print(f"baseline {base_path} refreshed from {fresh_path}")
        return 0
    if not os.path.exists(base_path):
        print(f"no baseline at {base_path}; bootstrap with --update")
        return 1
    with open(base_path) as f:
        base = json.load(f)
    thresh = threshold()
    regressions, notes = compare(fresh, base, thresh)
    mode, _ = extract(base)
    print(f"bench gate [{mode}] threshold +{thresh:.0%} "
          f"({fresh_path} vs {base_path})")
    for n in notes:
        print(n)
    if regressions:
        print(f"PERF REGRESSION (> +{thresh:.0%}):")
        for r in regressions:
            print(r)
        return 1
    print("bench gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

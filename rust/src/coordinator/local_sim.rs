//! Single-node multi-core simulation (paper §5.12, v39):
//! a persistent worker pool sized to the available cores, clients
//! *statically dispatched* to workers (no work stealing → no
//! congestion), one message channel per direction.
//!
//! Round replies are **streamed**: each worker sends every client's
//! message to the master the moment it is computed, so the master's
//! incremental aggregation (buffer-and-commit, see the module docs of
//! [`crate::coordinator`]) overlaps with the remaining clients' compute.
//! A round may also target a participation subset (FedNL-PP): workers
//! skip non-selected clients and the master expects exactly one reply
//! per participant.
//!
//! Determinism: workers compute in parallel and replies arrive in
//! completion order, but every reduction commits in a fixed order —
//! round messages in round-subset order (driver side), and the
//! loss / gradient / warm-start / state reductions in ascending client
//! id order, replicating [`super::SeqPool`]'s flat sums bit-for-bit.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::{ClientFamily, ClientPool, PoolClient};
use crate::algorithms::ClientMsg;

enum Cmd {
    Round {
        x: Arc<Vec<f64>>,
        round: u64,
        need_loss: bool,
        /// Participating client ids; `None` = the full round.
        subset: Option<Arc<Vec<u32>>>,
    },
    EvalLoss { x: Arc<Vec<f64>> },
    LossGrad { x: Arc<Vec<f64>> },
    WarmStart { x: Arc<Vec<f64>> },
    InitState,
    /// Single-client (lᵢ, gᵢ) pull (FedNL-PP rejoin resync); only the
    /// worker owning the client replies.
    PullState(usize),
    SetAlpha(f64),
    Shutdown,
}

enum Reply {
    /// One client's round message, streamed as soon as it is computed.
    Msg(Box<ClientMsg>),
    /// (client id, local loss). Per-client so the master can reduce in
    /// client-id order regardless of arrival order.
    Loss(usize, f64),
    /// (client id, local loss, local gradient).
    LossGrad(usize, f64, Vec<f64>),
    /// (client id, packed Hᵢ⁰).
    Warm(usize, Vec<f64>),
    /// (client id, lᵢ, gᵢ) — FedNL-PP bootstrap.
    State(usize, f64, Vec<f64>),
    Ack,
}

struct Worker {
    cmd_tx: Sender<Cmd>,
    handle: Option<JoinHandle<()>>,
}

/// Thread-pool client simulator.
pub struct ThreadedPool {
    workers: Vec<Worker>,
    reply_rx: Receiver<Reply>,
    n_clients: usize,
    dim: usize,
    family: ClientFamily,
    default_alpha: f64,
    /// Replies still expected for the round in flight.
    outstanding: usize,
}

impl ThreadedPool {
    /// Distribute `clients` over `n_workers` threads (0 → #cores,
    /// clamped to the client count). Accepts either client family
    /// (FedNL [`crate::algorithms::ClientState`] or FedNL-PP
    /// [`crate::algorithms::PPClientState`]).
    pub fn new<C: PoolClient + 'static>(
        clients: Vec<C>,
        n_workers: usize,
    ) -> Self {
        let boxed = clients
            .into_iter()
            .map(|c| Box::new(c) as Box<dyn PoolClient>)
            .collect();
        Self::from_boxed(boxed, n_workers)
    }

    /// As [`ThreadedPool::new`], over pre-boxed clients.
    pub fn from_boxed(
        clients: Vec<Box<dyn PoolClient>>,
        n_workers: usize,
    ) -> Self {
        assert!(!clients.is_empty());
        let n_clients = clients.len();
        let dim = clients[0].dim();
        let family = clients[0].family();
        assert!(
            clients.iter().all(|c| c.family() == family),
            "pools are family-homogeneous: cannot mix FedNL and \
             FedNL-PP clients"
        );
        let default_alpha = clients[0].alpha();
        let n_workers = if n_workers == 0 {
            crate::utils::available_cores()
        } else {
            n_workers
        }
        .min(n_clients)
        .max(1);

        // Static round-robin dispatch (paper: "clients were statically
        // dispatched to this pool").
        let mut buckets: Vec<Vec<Box<dyn PoolClient>>> =
            (0..n_workers).map(|_| Vec::new()).collect();
        for (i, c) in clients.into_iter().enumerate() {
            buckets[i % n_workers].push(c);
        }

        let (reply_tx, reply_rx) = channel::<Reply>();
        let workers = buckets
            .into_iter()
            .map(|mut bucket| {
                let (cmd_tx, cmd_rx) = channel::<Cmd>();
                let tx = reply_tx.clone();
                let handle = std::thread::spawn(move || {
                    while let Ok(cmd) = cmd_rx.recv() {
                        match cmd {
                            Cmd::Round { x, round, need_loss, subset } => {
                                for c in bucket.iter_mut() {
                                    if let Some(s) = subset.as_deref() {
                                        if !s.contains(&(c.id() as u32)) {
                                            continue;
                                        }
                                    }
                                    let m = c.round(&x, round, need_loss);
                                    let _ =
                                        tx.send(Reply::Msg(Box::new(m)));
                                }
                            }
                            Cmd::EvalLoss { x } => {
                                for c in bucket.iter_mut() {
                                    let l = c.eval_loss(&x);
                                    let _ = tx.send(Reply::Loss(c.id(), l));
                                }
                            }
                            Cmd::LossGrad { x } => {
                                for c in bucket.iter_mut() {
                                    let (l, g) = c.eval_loss_grad(&x);
                                    let _ = tx
                                        .send(Reply::LossGrad(c.id(), l, g));
                                }
                            }
                            Cmd::WarmStart { x } => {
                                for c in bucket.iter_mut() {
                                    let p = c.warm_start(&x);
                                    let _ = tx.send(Reply::Warm(c.id(), p));
                                }
                            }
                            Cmd::InitState => {
                                for c in bucket.iter() {
                                    let (l, g) = c.state();
                                    let _ =
                                        tx.send(Reply::State(c.id(), l, g));
                                }
                            }
                            Cmd::PullState(id) => {
                                for c in bucket.iter() {
                                    if c.id() == id {
                                        let (l, g) = c.state();
                                        let _ = tx
                                            .send(Reply::State(id, l, g));
                                    }
                                }
                            }
                            Cmd::SetAlpha(a) => {
                                for c in bucket.iter_mut() {
                                    c.set_alpha(a);
                                }
                                let _ = tx.send(Reply::Ack);
                            }
                            Cmd::Shutdown => break,
                        }
                    }
                });
                Worker { cmd_tx, handle: Some(handle) }
            })
            .collect();

        Self {
            workers,
            reply_rx,
            n_clients,
            dim,
            family,
            default_alpha,
            outstanding: 0,
        }
    }

    fn broadcast(&self, make: impl Fn() -> Cmd) {
        for w in &self.workers {
            let _ = w.cmd_tx.send(make());
        }
    }
}

impl ClientPool for ThreadedPool {
    fn n_clients(&self) -> usize {
        self.n_clients
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn kind_name(&self) -> &'static str {
        "threaded"
    }

    fn family(&self) -> ClientFamily {
        self.family
    }

    fn default_alpha(&self) -> f64 {
        self.default_alpha
    }

    fn set_alpha(&mut self, alpha: f64) -> f64 {
        // Query form (non-finite): the workers' clients keep their
        // (identical, theoretical) α, cached at construction.
        if !(alpha.is_finite() && alpha > 0.0) {
            return self.default_alpha;
        }
        self.broadcast(|| Cmd::SetAlpha(alpha));
        for _ in 0..self.workers.len() {
            let _ = self.reply_rx.recv();
        }
        self.default_alpha = alpha;
        alpha
    }

    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    ) {
        assert_eq!(self.outstanding, 0, "previous round not fully drained");
        self.outstanding =
            subset.map(|s| s.len()).unwrap_or(self.n_clients);
        let x = Arc::new(x.to_vec());
        let subset = subset.map(|s| Arc::new(s.to_vec()));
        self.broadcast(|| Cmd::Round {
            x: Arc::clone(&x),
            round,
            need_loss,
            subset: subset.clone(),
        });
    }

    fn drain(&mut self) -> Vec<ClientMsg> {
        if self.outstanding == 0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        // Block for the first reply, then grab whatever else has
        // already arrived without blocking again.
        match self.reply_rx.recv() {
            Ok(Reply::Msg(m)) => {
                out.push(*m);
                self.outstanding -= 1;
            }
            Ok(_) => panic!("unexpected reply during round"),
            Err(_) => panic!("worker died"),
        }
        while self.outstanding > 0 {
            match self.reply_rx.try_recv() {
                Ok(Reply::Msg(m)) => {
                    out.push(*m);
                    self.outstanding -= 1;
                }
                Ok(_) => panic!("unexpected reply during round"),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => panic!("worker died"),
            }
        }
        out
    }

    fn eval_loss_each(&mut self, x: &[f64]) -> Vec<(u32, f64)> {
        let x = Arc::new(x.to_vec());
        self.broadcast(|| Cmd::EvalLoss { x: Arc::clone(&x) });
        // Collect in arrival order; the provided trait reduction sorts
        // by client id, so the f64 summation order matches SeqPool's
        // flat sum bit-for-bit.
        let mut parts: Vec<(u32, f64)> = Vec::with_capacity(self.n_clients);
        for _ in 0..self.n_clients {
            match self.reply_rx.recv() {
                Ok(Reply::Loss(id, l)) => parts.push((id as u32, l)),
                _ => panic!("worker died"),
            }
        }
        parts
    }

    fn loss_grad_each(&mut self, x: &[f64]) -> Vec<(u32, f64, Vec<f64>)> {
        let x = Arc::new(x.to_vec());
        self.broadcast(|| Cmd::LossGrad { x: Arc::clone(&x) });
        let mut parts: Vec<(u32, f64, Vec<f64>)> =
            Vec::with_capacity(self.n_clients);
        for _ in 0..self.n_clients {
            match self.reply_rx.recv() {
                Ok(Reply::LossGrad(id, l, g)) => {
                    parts.push((id as u32, l, g))
                }
                _ => panic!("worker died"),
            }
        }
        parts
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        let x = Arc::new(x.to_vec());
        self.broadcast(|| Cmd::WarmStart { x: Arc::clone(&x) });
        let mut all: Vec<(usize, Vec<f64>)> =
            Vec::with_capacity(self.n_clients);
        for _ in 0..self.n_clients {
            match self.reply_rx.recv() {
                Ok(Reply::Warm(id, p)) => all.push((id, p)),
                _ => panic!("worker died"),
            }
        }
        all.sort_by_key(|(id, _)| *id);
        all.into_iter().map(|(_, p)| p).collect()
    }

    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
        self.broadcast(|| Cmd::InitState);
        let mut all: Vec<(usize, f64, Vec<f64>)> =
            Vec::with_capacity(self.n_clients);
        for _ in 0..self.n_clients {
            match self.reply_rx.recv() {
                Ok(Reply::State(id, l, g)) => all.push((id, l, g)),
                _ => panic!("worker died"),
            }
        }
        all.sort_by_key(|&(id, _, _)| id);
        all.into_iter().map(|(_, l, g)| (l, g)).collect()
    }

    fn pull_state(&mut self, client: u32) -> Option<(f64, Vec<f64>)> {
        self.broadcast(|| Cmd::PullState(client as usize));
        // Exactly one worker owns the client and replies.
        match self.reply_rx.recv() {
            Ok(Reply::State(id, l, g)) => {
                assert_eq!(id, client as usize);
                Some((l, g))
            }
            _ => panic!("worker died"),
        }
    }
}

impl Drop for ThreadedPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd_tx.send(Cmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::ClientState;
    use crate::compressors::by_name;
    use crate::coordinator::SeqPool;
    use crate::data::{generate_synthetic, Dataset, SynthSpec};
    use crate::oracle::LogisticOracle;

    fn make_clients(n: usize, seed: u64) -> (Vec<ClientState>, usize) {
        let spec = SynthSpec {
            d_raw: 7,
            n_samples: n * 30,
            density: 0.6,
            noise: 1.0,
            label_bias: 0.0,
            seed,
        };
        let synth = generate_synthetic(&spec);
        let samples: Vec<crate::data::LibsvmSample> = synth
            .labels
            .iter()
            .zip(&synth.rows)
            .map(|(l, r)| crate::data::LibsvmSample {
                label: *l,
                features: r.clone(),
            })
            .collect();
        let ds = Dataset::from_libsvm(&samples, spec.d_raw);
        let d = ds.d;
        let cs = ds
            .split_even(n)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                ClientState::new(
                    i,
                    Box::new(LogisticOracle::new(sh, 1e-3)),
                    by_name("topk", d, 2, seed + i as u64).unwrap(),
                    None,
                )
            })
            .collect();
        (cs, d)
    }

    #[test]
    fn threaded_matches_sequential_bitwise() {
        let (cs1, d) = make_clients(6, 31);
        let (cs2, _) = make_clients(6, 31);
        let mut seq = SeqPool::new(cs1);
        let mut thr = ThreadedPool::new(cs2, 3);
        let x = vec![0.1; d];
        for round in 0..5 {
            let a = seq.round(&x, round, true);
            let b = thr.round(&x, round, true);
            assert_eq!(a.len(), b.len());
            for (ma, mb) in a.iter().zip(&b) {
                assert_eq!(ma.client_id, mb.client_id);
                assert_eq!(ma.grad, mb.grad);
                assert_eq!(ma.l_i, mb.l_i);
                assert_eq!(ma.update.values, mb.update.values);
                assert_eq!(ma.loss, mb.loss);
            }
        }
        let la = seq.eval_loss(&x);
        let lb = thr.eval_loss(&x);
        assert_eq!(la, lb, "client-id-ordered reductions must agree bitwise");
    }

    #[test]
    fn pool_sizes() {
        let (cs, _) = make_clients(4, 32);
        let thr = ThreadedPool::new(cs, 0); // auto
        assert_eq!(thr.n_clients(), 4);
        assert!(thr.workers.len() >= 1 && thr.workers.len() <= 4);
    }

    #[test]
    fn warm_start_order_preserved() {
        let (cs, d) = make_clients(5, 33);
        let mut thr = ThreadedPool::new(cs, 2);
        let packs = thr.warm_start(&vec![0.0; d]);
        assert_eq!(packs.len(), 5);
        let plen = d * (d + 1) / 2;
        for p in packs {
            assert_eq!(p.len(), plen);
        }
    }

    #[test]
    fn subset_round_streams_only_participants() {
        let (cs, d) = make_clients(5, 34);
        let mut thr = ThreadedPool::new(cs, 2);
        let x = vec![0.05; d];
        let subset = [3u32, 0, 4];
        thr.submit_round(&x, Some(&subset), 0, false);
        let mut got = Vec::new();
        loop {
            let batch = thr.drain();
            if batch.is_empty() {
                break;
            }
            got.extend(batch.into_iter().map(|m| m.client_id as u32));
        }
        got.sort_unstable();
        assert_eq!(got, vec![0, 3, 4]);
        // Pool is reusable afterwards.
        let msgs = thr.round(&x, 1, false);
        assert_eq!(msgs.len(), 5);
    }
}

//! Checkpoint/restore integration: a scripted `killmaster@R`
//! coordinator crash at EVERY round of a faulty run must heal
//! bit-identically, across the algorithm family and both in-process
//! transports.
//!
//! The engine writes a durable snapshot each round (the
//! `--checkpoint-every 1` cadence), the fault plan schedules the
//! coordinator's death entering round R, and the engine drops its
//! entire aggregate state (model, per-client Hᵢ mirrors, commit
//! watermarks, α, RNG stream positions, byte meters) and rebuilds it
//! from disk before continuing. The healed trace must match an
//! uninterrupted run of the same plan bit for bit — grad norms,
//! losses, committed/missing/flagged accounting — with Byzantine
//! corruption, a robust defense and drawn straggler delays composing
//! through the restore.

use fednl::algorithms::{
    run_engine_from, run_fednl_ls_pool, run_fednl_pool, run_fednl_pp_pool,
    ClientState, LineSearchParams, Options, PPClientState, StepPolicy,
};
use fednl::compressors::by_name;
use fednl::coordinator::{
    checkpoint, CheckpointCfg, ClientPool, CorruptMode, FaultPlan,
    FaultPool, SeqPool, ThreadedPool,
};
use fednl::data::{
    generate_synthetic, parse_libsvm_bytes, write_libsvm, Dataset, SynthSpec,
};
use fednl::metrics::Trace;
use fednl::oracle::LogisticOracle;
use fednl::robust::Defense;

const N_CLIENTS: usize = 4;
const N_I: usize = 30;
const ROUNDS: u64 = 8;

fn dataset(seed: u64) -> Dataset {
    let spec = SynthSpec {
        d_raw: 8,
        n_samples: N_CLIENTS * N_I,
        density: 0.5,
        noise: 1.0,
        label_bias: 0.0,
        seed,
    };
    // Text round-trip on every test: generator → LIBSVM → parser.
    let text = write_libsvm(&generate_synthetic(&spec));
    let (samples, got_d) = parse_libsvm_bytes(text.as_bytes()).unwrap();
    let mut ds = Dataset::from_libsvm(&samples, got_d.max(8));
    ds.reshuffle(seed ^ 0xABCD);
    ds
}

fn fednl_clients(ds: &Dataset) -> Vec<ClientState> {
    ds.split_even(N_CLIENTS)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            ClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("topk", ds.d, 4, 100 + id as u64).unwrap(),
                None,
            )
        })
        .collect()
}

fn pp_clients(ds: &Dataset, x0: &[f64]) -> Vec<PPClientState> {
    ds.split_even(N_CLIENTS)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            PPClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("topk", ds.d, 4, 100 + id as u64).unwrap(),
                None,
                x0,
            )
        })
        .collect()
}

/// The faults every leg runs under (killmaster events are layered on
/// top): two corruptions and a window of drawn lognormal delays
/// (median ≈ e^1 ≈ 3 ms — enough to prove the draws replay, cheap
/// enough to run 50+ legs).
fn base_plan() -> FaultPlan {
    FaultPlan::none()
        .with_corrupt(3, 1, CorruptMode::SignFlip)
        .with_corrupt(5, 0, CorruptMode::Scale(10.0))
        .with_delay_dist(2, 4, 1.0, 0.5)
}

/// One run of `algo` on a Seq or Threaded pool under `plan`, with or
/// without checkpointing. The Newton family additionally folds under
/// the median defense, so the snapshot's flagged accounting is
/// load-bearing; PP aggregates deltas and runs undefended.
fn run_leg(
    ds: &Dataset,
    algo: &str,
    threaded: bool,
    plan: &FaultPlan,
    ck: Option<CheckpointCfg>,
) -> Trace {
    let d = ds.d;
    let x0 = vec![0.0; d];
    let opts = Options {
        rounds: ROUNDS,
        track_loss: true,
        defense: if algo == "fednl-pp" {
            None
        } else {
            Some(Defense::Median)
        },
        checkpoint: ck,
        ..Default::default()
    };
    if algo == "fednl-pp" {
        let clients = pp_clients(ds, &x0);
        let run = |pool: &mut dyn ClientPool| {
            run_fednl_pp_pool(pool, &opts, 2, 7, x0.clone(), "ck/pp")
        };
        if threaded {
            let mut pool =
                FaultPool::new(ThreadedPool::new(clients, 2), plan.clone());
            run(&mut pool)
        } else {
            let mut pool =
                FaultPool::new(SeqPool::new(clients), plan.clone());
            run(&mut pool)
        }
    } else {
        let clients = fednl_clients(ds);
        let run = |pool: &mut dyn ClientPool| {
            if algo == "fednl" {
                run_fednl_pool(pool, &opts, x0.clone(), "ck/newton")
            } else {
                run_fednl_ls_pool(
                    pool,
                    &opts,
                    &LineSearchParams::default(),
                    x0.clone(),
                    "ck/ls",
                )
            }
        };
        if threaded {
            let mut pool =
                FaultPool::new(ThreadedPool::new(clients, 2), plan.clone());
            run(&mut pool)
        } else {
            let mut pool =
                FaultPool::new(SeqPool::new(clients), plan.clone());
            run(&mut pool)
        }
    }
}

/// Bitwise trace equality on everything the trajectory is a function
/// of (bytes and elapsed are metering, not trajectory).
fn assert_identical(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(
        a.records.len(),
        b.records.len(),
        "{what}: round counts differ"
    );
    for (x, y) in a.records.iter().zip(&b.records) {
        assert!(
            x.round == y.round
                && x.grad_norm.to_bits() == y.grad_norm.to_bits()
                && x.loss.to_bits() == y.loss.to_bits()
                && x.committed == y.committed
                && x.missing == y.missing
                && x.flagged == y.flagged,
            "{what}: diverged at round {}: grad {:.17e} vs {:.17e}, \
             committed {} vs {}, flagged {} vs {}",
            x.round,
            x.grad_norm,
            y.grad_norm,
            x.committed,
            y.committed,
            x.flagged,
            y.flagged
        );
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fednl-ck-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The property: for every algorithm, on both in-process pools, a
/// coordinator crash entering ANY round R heals into the
/// uninterrupted trajectory, bit for bit.
#[test]
fn killmaster_at_every_round_heals_bit_identically() {
    let ds = dataset(42);
    for algo in ["fednl", "fednl-ls", "fednl-pp"] {
        for threaded in [false, true] {
            let reference =
                run_leg(&ds, algo, threaded, &base_plan(), None);
            assert_eq!(reference.records.len() as u64, ROUNDS);
            for r in 0..ROUNDS {
                let dir =
                    tmp_dir(&format!("{algo}-{}-{r}", threaded as u8));
                let plan = base_plan().with_master_kill(r);
                let healed = run_leg(
                    &ds,
                    algo,
                    threaded,
                    &plan,
                    Some(CheckpointCfg::new(dir.to_str().unwrap(), 1)),
                );
                assert!(
                    std::fs::read_dir(&dir)
                        .map(|mut d| d.next().is_some())
                        .unwrap_or(false),
                    "{algo}: no snapshots written to {}",
                    dir.display()
                );
                assert_identical(
                    &reference,
                    &healed,
                    &format!(
                        "{algo}/{} killmaster@{r}",
                        if threaded { "threaded" } else { "seq" }
                    ),
                );
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }
}

/// A finished run's terminal snapshot restores to a finished run:
/// zero further rounds, the preloaded trace bit-identical — and the
/// `--checkpoint-every 2` cadence leaves the terminal round loadable.
#[test]
fn terminal_snapshot_restores_finished() {
    let ds = dataset(9);
    let dir = tmp_dir("terminal");
    let plan = FaultPlan::none();
    let first = run_leg(
        &ds,
        "fednl",
        false,
        &plan,
        Some(CheckpointCfg::new(dir.to_str().unwrap(), 2)),
    );
    let snap = checkpoint::load_latest(dir.to_str().unwrap())
        .unwrap()
        .expect("terminal snapshot missing");
    assert!(snap.finished);
    assert_eq!(snap.round_next, ROUNDS);
    let opts = Options {
        rounds: ROUNDS,
        track_loss: true,
        defense: Some(Defense::Median),
        ..Default::default()
    };
    let mut pool = SeqPool::new(fednl_clients(&ds));
    let resumed = run_engine_from(
        &mut pool,
        &opts,
        StepPolicy::Newton,
        vec![0.0; ds.d],
        "ck/resume",
        Some(snap),
    );
    assert_identical(&first, &resumed, "terminal restore");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Speculation overlaps server work with an unfinished round — a
/// snapshot cannot capture that in-flight state, so the combination
/// is rejected up front.
#[test]
#[should_panic(expected = "--speculate is incompatible with checkpointing")]
fn speculate_with_checkpointing_panics() {
    let ds = dataset(7);
    let dir = tmp_dir("speculate");
    let opts = Options {
        rounds: 2,
        speculate: true,
        checkpoint: Some(CheckpointCfg::new(dir.to_str().unwrap(), 1)),
        ..Default::default()
    };
    let mut pool = SeqPool::new(fednl_clients(&ds));
    let _ = run_fednl_pool(&mut pool, &opts, vec![0.0; ds.d], "ck/spec");
}

//! The TCP shard tier: relay aggregator processes between the master
//! and its clients (`coordinator::shard`'s real-network sibling).
//!
//! Topology (paper §9.3 star, one level deeper):
//!
//! ```text
//!   master ──(S relay channels)── relay s ──(n/S client channels)── clients
//! ```
//!
//! A relay ([`run_relay`]) is a [`RemotePool`] bound to its contiguous
//! global-id partition `[base, base+count)` on the *downward* side —
//! it speaks the ordinary client-facing wire protocol, so **clients
//! cannot tell a relay from the master** — and a command-driven
//! aggregator on the *upward* side, answering the `SHARD_*` frames
//! (tag table in `net::wire`). Each round it fans the ROUND out to its
//! partition, certifies its losses, and — in the default **sum mode**
//! (the `SHARD_ROUND` `sum` flag) — folds every reply into one exact
//! [`RoundSum`] superaccumulator and forwards a single compact
//! `SHARD_SUM` frame: master fan-in drops from `n` messages of O(d)
//! each (O(n·d) payload + fold work) to `S` frames of O(d) each
//! (O(S·d)), independent of `n`, while relay-side recv/decode/fold
//! work runs in parallel across relays. Atom mode (`SHARD_MSG`, the
//! FedNL-PP path and rounds with injected straggler delays) remains
//! available behind the same flag.
//!
//! [`RelayPool`] is the master-side face: a [`ClientPool`] over the
//! whole client set, so the round engine drives a relayed deployment
//! unchanged. Determinism is inherited from the reproducible
//! summation layer (`linalg::reduce`): the merged accumulators are
//! exact, so merging S partial sums is bit-identical to folding all n
//! atoms — trajectories match the unsharded run by construction, on
//! either reply format.
//!
//! [`RoundSum`]: crate::algorithms::RoundSum
//!
//! # Liveness through the tier
//!
//! * A relay certifies its lost clients upward (`SHARD_MSG` carries
//!   the partition's missing ids; `SHARD_PREPPED` its dead/rejoined
//!   sets from the retained downward listener).
//! * A lost **relay** (connection error, or a round reply missing the
//!   deadline-plus-slack budget) is retired and its whole partition is
//!   certified missing for the round in flight and reported dead
//!   thereafter — the engine's quorum/`on_missing` policy absorbs it
//!   like any other loss. Relay *re*-registration is not supported
//!   (ROADMAP known limit); client rejoin under a live relay works
//!   exactly as under a flat master.

use std::collections::VecDeque;
use std::time::Duration;

use anyhow::{Context, Result};

use super::client::connect_with_retry;
use super::framing::Channel;
use super::server::Bound;
use super::wire::{self, c2s, s2c};
use crate::algorithms::{ClientMsg, RoundSum};
use crate::coordinator::{ClientFamily, ClientPool, RoundMode};

/// Default extra patience the master grants a relay on top of the
/// per-client reply deadline: the relay must first wait out its own
/// stragglers before its SHARD_SUM / SHARD_MSG can exist. Configurable
/// per deployment via [`RelayPool::set_relay_slack`] (CLI
/// `master --relay-slack-ms`).
pub const DEFAULT_RELAY_SLACK: Duration = Duration::from_millis(2000);

/// Validate a CLI `--relay-slack-ms` value. Zero would treat every
/// relay as lost the moment a deadline is armed — "no custom slack"
/// is spelled by omitting the flag (mirroring `RoundPolicy::validate`'s
/// zero-deadline rule).
pub fn relay_slack_from_ms(ms: u64) -> Result<Duration> {
    anyhow::ensure!(
        ms > 0,
        "--relay-slack-ms 0 would certify every relay lost as soon as \
         a reply deadline is set; omit the flag for the default \
         {} ms",
        DEFAULT_RELAY_SLACK.as_millis()
    );
    Ok(Duration::from_millis(ms))
}

/// One relay process' configuration (CLI `fednl relay`).
#[derive(Debug, Clone)]
pub struct RelayCfg {
    /// This relay's shard id (0-based, unique per master).
    pub shard_id: u32,
    /// First global client id of the partition.
    pub base: u32,
    /// Clients in the partition.
    pub count: usize,
    /// Downward listen address for the partition's clients.
    pub listen: String,
    /// Upward master address.
    pub connect: String,
    /// Serve the downward partition through the readiness-based
    /// [`EventPool`] instead of the blocking [`RemotePool`] (CLI
    /// `relay --event`): one poll loop for the whole partition, and
    /// mux groups (`client --mux N`) can register under this relay.
    /// Unix-only; ignored (with an error at startup) elsewhere.
    ///
    /// [`EventPool`]: super::event::EventPool
    /// [`RemotePool`]: super::server::RemotePool
    pub event: bool,
}

/// The relay's downward face: any master-side transport that can also
/// politely release its clients at end of run. Object-safe so
/// [`run_relay_on`] can pick the blocking or readiness transport at
/// startup without duplicating the serve loop.
trait DownFace: ClientPool {
    fn shutdown(&mut self);
}

impl DownFace for super::server::RemotePool {
    fn shutdown(&mut self) {
        super::server::RemotePool::shutdown(self);
    }
}

#[cfg(unix)]
impl DownFace for super::event::EventPool {
    fn shutdown(&mut self) {
        super::event::EventPool::shutdown(self);
    }
}

/// Byte totals a finished relay reports (downward pool, upward link).
#[derive(Debug, Clone, Copy, Default)]
pub struct RelayReport {
    pub down_recv: u64,
    pub down_sent: u64,
    pub up_sent: u64,
    pub up_recv: u64,
}

/// Run one relay aggregator to completion (returns after the master's
/// SHUTDOWN, which is forwarded to the partition's clients).
pub fn run_relay(cfg: &RelayCfg) -> Result<RelayReport> {
    let bound = Bound::bind(&cfg.listen)?;
    run_relay_on(bound, cfg)
}

/// As [`run_relay`] over a pre-bound downward listener (lets harnesses
/// learn the ephemeral port before spawning the partition's clients).
pub fn run_relay_on(bound: Bound, cfg: &RelayCfg) -> Result<RelayReport> {
    // Downward first: the relay must know its partition's (d, family)
    // before it can register upward.
    let mut down: Box<dyn DownFace> = if cfg.event {
        #[cfg(unix)]
        {
            Box::new(super::event::EventPool::accept_base(
                bound, cfg.count, cfg.base,
            )?)
        }
        #[cfg(not(unix))]
        {
            anyhow::bail!("--event requires a unix host (epoll/poll)");
        }
    } else {
        Box::new(bound.accept_base(cfg.count, cfg.base)?)
    };
    let d = down.dim();
    let family = match down.family() {
        ClientFamily::FedNL => wire::FAMILY_FEDNL,
        ClientFamily::PP => wire::FAMILY_PP,
    };
    let stream = connect_with_retry(&cfg.connect, 50)?;
    let mut up = Channel::new(stream)?;
    up.send(
        c2s::SHARD_REGISTER,
        &wire::encode_shard_register(
            cfg.shard_id,
            cfg.base,
            cfg.count as u32,
            d as u32,
            family,
        ),
    )?;

    loop {
        // Master gone (EOF) = orderly end of the run from the relay's
        // point of view: release the clients and exit.
        let Ok((tag, payload)) = up.recv() else {
            down.shutdown();
            break;
        };
        match tag {
            s2c::SHARD_ROUND => {
                let (x, round, need_loss, sum, deadline_ms, subset) =
                    wire::decode_shard_round(&payload)?;
                let deadline = (deadline_ms > 0)
                    .then(|| Duration::from_millis(deadline_ms));
                down.set_reply_deadline(deadline);
                down.submit_round(&x, Some(&subset), round, need_loss);
                let mut msgs: Vec<ClientMsg> = Vec::new();
                loop {
                    let batch = down.drain();
                    if batch.is_empty() {
                        break;
                    }
                    msgs.extend(batch);
                }
                let mut missing = down.take_missing();
                if sum {
                    // Arithmetic pre-reduction: fold the partition's
                    // replies into one exact superaccumulator — the
                    // tier's O(S·d) fan-in. Fold order is irrelevant
                    // (the sum is exact), so no sorting is needed.
                    let mut merged = RoundSum::from_msgs(&msgs);
                    up.send(
                        c2s::SHARD_SUM,
                        &wire::encode_shard_sum(
                            cfg.shard_id,
                            &mut merged,
                            &missing,
                        ),
                    )?;
                } else {
                    // Atom mode: forward the per-client batch in
                    // round-subset order. (RemotePool already surfaces
                    // replies in that order; sorting keeps the
                    // contract explicit and transport-independent.)
                    let pos = |ci: u32| {
                        subset
                            .iter()
                            .position(|&c| c == ci)
                            .expect("reply outside the round subset")
                    };
                    msgs.sort_by_key(|m| pos(m.client_id as u32));
                    missing.sort_by_key(|&c| pos(c));
                    up.send(
                        c2s::SHARD_MSG,
                        &wire::encode_shard_msg(
                            cfg.shard_id,
                            &msgs,
                            &missing,
                        ),
                    )?;
                }
            }
            s2c::SHARD_PREP => {
                let r = {
                    let mut rd = crate::utils::ByteReader::new(&payload);
                    rd.get_u64()?
                };
                down.prepare_round(r);
                let rejoined = down.take_rejoined();
                let dead = down.dead_clients();
                up.send(
                    c2s::SHARD_PREPPED,
                    &wire::encode_shard_prepped(&rejoined, &dead),
                )?;
            }
            s2c::SHARD_PULL => {
                let client = {
                    let mut rd = crate::utils::ByteReader::new(&payload);
                    rd.get_u32()?
                };
                let state = down.pull_state(client);
                up.send(
                    c2s::SHARD_PULLED,
                    &wire::encode_shard_pulled(
                        state.as_ref().map(|(l, g)| (*l, g.as_slice())),
                    ),
                )?;
            }
            s2c::EVAL_LOSS => {
                let x = wire::decode_vec(&payload)?;
                let parts = down.eval_loss_each(&x);
                up.send(c2s::SHARD_LOSSES, &wire::encode_id_scalars(&parts))?;
            }
            s2c::LOSS_GRAD => {
                let x = wire::decode_vec(&payload)?;
                let parts = down.loss_grad_each(&x);
                up.send(
                    c2s::SHARD_GRADS,
                    &wire::encode_id_scalar_vecs(&parts),
                )?;
            }
            s2c::LOSS_GRAD_SUM => {
                // Pre-reduced probe: fold the partition's (fᵢ, ∇fᵢ)
                // next to the clients and ship one exact accumulator
                // pair — O(d) upward instead of n dense gradients.
                let x = wire::decode_vec(&payload)?;
                let (mut loss, mut grad, count) = down.loss_grad_sum(&x);
                up.send(
                    c2s::SHARD_GRAD_SUM,
                    &wire::encode_shard_grad_sum(
                        count, &mut loss, &mut grad,
                    ),
                )?;
            }
            s2c::WARM_START => {
                let x = wire::decode_vec(&payload)?;
                let packs = down.warm_start(&x);
                up.send(c2s::SHARD_WARM, &wire::encode_vec_batch(&packs))?;
            }
            s2c::STATE => {
                let states = down.init_state();
                let parts: Vec<(u32, f64, Vec<f64>)> = states
                    .into_iter()
                    .enumerate()
                    .map(|(slot, (l, g))| {
                        (cfg.base + slot as u32, l, g)
                    })
                    .collect();
                up.send(
                    c2s::SHARD_STATES,
                    &wire::encode_id_scalar_vecs(&parts),
                )?;
            }
            s2c::SET_ALPHA => {
                // Forward the negotiation (finite = install, NaN =
                // query) and echo the partition's effective α upward.
                let a = wire::decode_scalar(&payload)?;
                let effective = down.set_alpha(a);
                up.send(c2s::ACK, &wire::encode_scalar(effective))?;
            }
            s2c::SHUTDOWN => {
                down.shutdown();
                break;
            }
            other => anyhow::bail!("relay: unknown command tag {other}"),
        }
    }
    let (down_recv, down_sent) = down.transport_bytes().unwrap_or((0, 0));
    Ok(RelayReport {
        down_recv,
        down_sent,
        up_sent: up.bytes_sent,
        up_recv: up.bytes_received,
    })
}

/// Master-side handle to `S` relay aggregators, presented as one
/// [`ClientPool`] over the whole client set.
pub struct RelayPool {
    /// Upward channels indexed by shard id (`None` = lost relay).
    relays: Vec<Option<Channel>>,
    /// Global-id range `[lo, hi)` per shard (contiguous, ascending).
    ranges: Vec<(u32, u32)>,
    n_clients: usize,
    d: usize,
    family: ClientFamily,
    alpha: f64,
    /// Shards with an outstanding SHARD_MSG, ascending shard id.
    pending: VecDeque<u32>,
    /// Participants of the round in flight, per shard (cleared once
    /// the shard's batch arrives; a relay lost mid-round certifies the
    /// remainder).
    outstanding: Vec<Vec<u32>>,
    missing: Vec<u32>,
    rejoined: Vec<u32>,
    /// Dead clients per live shard, from the last SHARD_PREPPED poll.
    shard_dead: Vec<Vec<u32>>,
    deadline: Option<Duration>,
    /// Forwarding patience on top of `deadline` (see
    /// [`DEFAULT_RELAY_SLACK`]; CLI `master --relay-slack-ms`).
    slack: Duration,
    /// Reply format requested from the relays for subsequent rounds
    /// (encoded into each SHARD_ROUND frame at submit time).
    mode: RoundMode,
    retired_bytes: (u64, u64),
}

impl RelayPool {
    /// Listen on `addr` until exactly `n_shards` relays register; the
    /// partitions must tile `0..n` contiguously.
    pub fn listen(addr: &str, n_shards: usize) -> Result<Self> {
        Self::accept(Bound::bind(addr)?, n_shards)
    }

    /// Accept `n_shards` relay registrations on a pre-bound socket.
    pub fn accept(bound: Bound, n_shards: usize) -> Result<Self> {
        let listener = bound.into_listener();
        let mut relays: Vec<Option<Channel>> =
            (0..n_shards).map(|_| None).collect();
        let mut ranges: Vec<Option<(u32, u32)>> = vec![None; n_shards];
        let mut d = 0u32;
        let mut family = None;
        let mut registered = 0;
        while registered < n_shards {
            let (stream, _) = listener.accept()?;
            let mut ch = Channel::new(stream)?;
            let (tag, payload) = ch.recv()?;
            anyhow::ensure!(
                tag == c2s::SHARD_REGISTER,
                "expected SHARD_REGISTER"
            );
            let (sid, base, count, dim, fam) =
                wire::decode_shard_register(&payload)?;
            let sid = sid as usize;
            anyhow::ensure!(sid < n_shards, "shard id {sid} out of range");
            anyhow::ensure!(relays[sid].is_none(), "duplicate shard {sid}");
            if d == 0 {
                d = dim;
            } else {
                anyhow::ensure!(d == dim, "shard dimension mismatch");
            }
            let f = match fam {
                wire::FAMILY_FEDNL => ClientFamily::FedNL,
                _ => ClientFamily::PP,
            };
            match family {
                None => family = Some(f),
                Some(prev) => anyhow::ensure!(
                    prev == f,
                    "shard {sid} registered as {f:?} but earlier shards \
                     as {prev:?}: the tier is family-homogeneous"
                ),
            }
            relays[sid] = Some(ch);
            ranges[sid] = Some((base, base + count));
            registered += 1;
        }
        let ranges: Vec<(u32, u32)> =
            ranges.into_iter().map(|r| r.unwrap()).collect();
        let mut expect = 0u32;
        for (s, &(lo, hi)) in ranges.iter().enumerate() {
            anyhow::ensure!(
                lo == expect,
                "shard {s} partition starts at {lo}, expected {expect}: \
                 partitions must tile 0..n contiguously in shard order"
            );
            expect = hi;
        }
        let n_shards_len = relays.len();
        Ok(Self {
            relays,
            ranges,
            n_clients: expect as usize,
            d: d as usize,
            family: family.context("no shards registered")?,
            alpha: 0.0,
            pending: VecDeque::new(),
            outstanding: vec![Vec::new(); n_shards_len],
            missing: Vec::new(),
            rejoined: Vec::new(),
            shard_dead: vec![Vec::new(); n_shards_len],
            deadline: None,
            slack: DEFAULT_RELAY_SLACK,
            mode: RoundMode::Atoms,
            retired_bytes: (0, 0),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.relays.len()
    }

    /// Configure the relay forwarding slack (the extra patience on top
    /// of the per-client reply deadline before a silent relay is
    /// certified lost). CLI: `master --relay-slack-ms`.
    pub fn set_relay_slack(&mut self, slack: Duration) {
        self.slack = slack.max(Duration::from_millis(1));
    }

    /// Retire a relay: fold its byte meters, certify the round
    /// participants it still owed, and mark its whole partition dead.
    fn drop_relay(&mut self, s: usize) {
        if let Some(ch) = self.relays[s].take() {
            self.retired_bytes.0 += ch.bytes_received;
            self.retired_bytes.1 += ch.bytes_sent;
        }
        self.missing.append(&mut self.outstanding[s]);
        self.shard_dead[s].clear();
    }

    /// Send one command to every live relay; returns the shard ids
    /// actually sent (send failures drop the relay).
    fn ask_relays(&mut self, tag: u8, payload: &[u8]) -> Vec<usize> {
        let mut asked = Vec::with_capacity(self.relays.len());
        for s in 0..self.relays.len() {
            if let Some(ch) = self.relays[s].as_mut() {
                match ch.send(tag, payload) {
                    Ok(()) => asked.push(s),
                    Err(_) => self.drop_relay(s),
                }
            }
        }
        asked
    }

    /// Blocking receive of one probe reply from shard `s` (unbounded,
    /// like `RemotePool`'s probe receives — WARM_START legitimately
    /// exceeds round deadlines). Failures drop the relay and return
    /// `None` so the reduction proceeds over the surviving partitions.
    fn recv_expect(&mut self, s: usize, want: u8) -> Option<Vec<u8>> {
        self.recv_expect_within(s, want, None)
    }

    /// As [`RelayPool::recv_expect`] with an explicit receive budget —
    /// the per-round exchanges (SHARD_PREP) use `deadline + slack` so
    /// a hung-but-connected relay is certified lost instead of
    /// stalling the run the quorum policy is protecting.
    fn recv_expect_within(
        &mut self,
        s: usize,
        want: u8,
        timeout: Option<Duration>,
    ) -> Option<Vec<u8>> {
        let ch = self.relays[s].as_mut()?;
        let _ = ch.set_read_timeout(timeout);
        match ch.recv() {
            Ok((tag, payload)) if tag == want => Some(payload),
            _ => {
                self.drop_relay(s);
                None
            }
        }
    }

    /// Politely shut the tier down (relays forward to their clients).
    pub fn shutdown(&mut self) {
        for ch in self.relays.iter_mut().flatten() {
            let _ = ch.send(s2c::SHUTDOWN, &[]);
        }
    }
}

impl ClientPool for RelayPool {
    fn n_clients(&self) -> usize {
        self.n_clients
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn family(&self) -> ClientFamily {
        self.family
    }

    fn kind_name(&self) -> &'static str {
        "relay"
    }

    fn default_alpha(&self) -> f64 {
        // NaN = "ask the tier": the SET_ALPHA negotiation cascades
        // through the relays to the clients (see `RemotePool`).
        if self.alpha > 0.0 {
            self.alpha
        } else {
            f64::NAN
        }
    }

    fn set_alpha(&mut self, alpha: f64) -> f64 {
        let payload = wire::encode_scalar(alpha);
        let asked = self.ask_relays(s2c::SET_ALPHA, &payload);
        let mut echoes = Vec::with_capacity(asked.len());
        for s in asked {
            if let Some(p) = self.recv_expect(s, c2s::ACK) {
                if let Ok(a) = wire::decode_scalar(&p) {
                    echoes.push(a);
                }
            }
        }
        let (resolved, homogeneous) =
            wire::fold_alpha_echoes(alpha, echoes);
        // Mixed per-shard echoes: install the resolved α uniformly so
        // every partition trains with the α the master aggregates with
        // (mirrors RemotePool::set_alpha; no-op when homogeneous).
        if !homogeneous && resolved.is_finite() && resolved > 0.0 {
            let payload = wire::encode_scalar(resolved);
            let asked = self.ask_relays(s2c::SET_ALPHA, &payload);
            for s in asked {
                let _ = self.recv_expect(s, c2s::ACK);
            }
        }
        self.alpha = resolved;
        resolved
    }

    fn set_reply_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline.map(|d| d.max(Duration::from_millis(1)));
    }

    fn prepare_round(&mut self, round: u64) {
        // One liveness poll per relay per round: rejoins admitted by
        // the relays' retained listeners surface here, and the dead
        // sets feed the PP resampling policy.
        let payload = {
            let mut w = crate::utils::ByteWriter::with_capacity(8);
            w.put_u64(round);
            w.into_vec()
        };
        let asked = self.ask_relays(s2c::SHARD_PREP, &payload);
        // Bounded per-round exchange: with a reply deadline configured
        // a wedged relay must become a certified loss here, not a
        // master hang (the flat master's prepare_round is non-blocking
        // for the same reason).
        let budget = self.deadline.map(|d| d + self.slack);
        for s in asked {
            match self.recv_expect_within(s, c2s::SHARD_PREPPED, budget) {
                Some(p) => match wire::decode_shard_prepped(&p) {
                    Ok((rejoined, dead)) => {
                        self.rejoined.extend(rejoined);
                        self.shard_dead[s] = dead;
                    }
                    Err(_) => self.drop_relay(s),
                },
                None => {}
            }
        }
    }

    fn dead_clients(&self) -> Vec<u32> {
        let mut out = Vec::new();
        for s in 0..self.relays.len() {
            if self.relays[s].is_none() {
                // A lost relay's whole partition is unreachable.
                let (lo, hi) = self.ranges[s];
                out.extend(lo..hi);
            } else {
                out.extend(self.shard_dead[s].iter().copied());
            }
        }
        out.sort_unstable();
        out
    }

    fn take_missing(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.missing)
    }

    fn take_rejoined(&mut self) -> Vec<u32> {
        let mut out = std::mem::take(&mut self.rejoined);
        out.sort_unstable();
        out
    }

    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    ) {
        assert!(self.pending.is_empty(), "previous round not fully drained");
        let deadline_ms =
            self.deadline.map(|d| d.as_millis() as u64).unwrap_or(0);
        for s in 0..self.relays.len() {
            let (lo, hi) = self.ranges[s];
            let part: Vec<u32> = match subset {
                None => (lo..hi).collect(),
                Some(sub) => sub
                    .iter()
                    .copied()
                    .filter(|&c| c >= lo && c < hi)
                    .collect(),
            };
            if part.is_empty() {
                continue;
            }
            let Some(ch) = self.relays[s].as_mut() else {
                self.missing.extend(part);
                continue;
            };
            let payload = wire::encode_shard_round(
                x,
                round,
                need_loss,
                self.mode == RoundMode::Sums,
                deadline_ms,
                &part,
            );
            match ch.send(s2c::SHARD_ROUND, &payload) {
                Ok(()) => {
                    self.outstanding[s] = part;
                    self.pending.push_back(s as u32);
                }
                Err(_) => {
                    self.outstanding[s] = part;
                    self.drop_relay(s);
                }
            }
        }
    }

    fn set_round_mode(&mut self, mode: RoundMode) {
        self.mode = mode;
    }

    fn drain_sums(&mut self) -> Vec<RoundSum> {
        // Sum mode: one pre-reduced SHARD_SUM per relay per round,
        // ascending shard id — O(S·d) master fan-in. Validation is
        // count-based (committed + missing must tile the partition we
        // dispatched); a malformed or inconsistent frame retires the
        // relay and certifies its outstanding partition, never a
        // panic (network-facing input rule).
        debug_assert_eq!(self.mode, RoundMode::Sums);
        while let Some(s) = self.pending.pop_front() {
            let s = s as usize;
            let Some(ch) = self.relays[s].as_mut() else {
                self.missing.append(&mut self.outstanding[s]);
                continue;
            };
            let timeout = self.deadline.map(|d| d + self.slack);
            let _ = ch.set_read_timeout(timeout);
            match ch.recv() {
                Ok((tag, p)) if tag == c2s::SHARD_SUM => {
                    let Ok((sid, mut sum, missing)) =
                        wire::decode_shard_sum(&p, self.d)
                    else {
                        self.drop_relay(s);
                        continue;
                    };
                    let part = &self.outstanding[s];
                    let mut miss_sorted = missing.clone();
                    miss_sorted.sort_unstable();
                    let dups =
                        miss_sorted.windows(2).any(|w| w[0] == w[1]);
                    let valid = sid as usize == s
                        && !dups
                        && sum.committed as usize + missing.len()
                            == part.len()
                        && missing.iter().all(|c| part.contains(c));
                    if !valid {
                        self.drop_relay(s);
                        continue;
                    }
                    self.outstanding[s].clear();
                    self.missing.extend(missing);
                    if sum.committed == 0 {
                        continue; // whole partition certified
                    }
                    sum.wire_bytes = crate::net::FRAME_HEADER_BYTES
                        + p.len() as u64;
                    return vec![sum];
                }
                _ => self.drop_relay(s),
            }
        }
        Vec::new()
    }

    fn drain(&mut self) -> Vec<ClientMsg> {
        // One SHARD_MSG per call, ascending shard id: while the master
        // commits shard s's batch, the later relays' frames queue in
        // the OS socket buffers. A relay that cannot produce its frame
        // within deadline + slack (or whose connection dies) certifies
        // its whole outstanding partition.
        debug_assert_eq!(self.mode, RoundMode::Atoms);
        while let Some(s) = self.pending.pop_front() {
            let s = s as usize;
            let Some(ch) = self.relays[s].as_mut() else {
                self.missing.append(&mut self.outstanding[s]);
                continue;
            };
            let timeout = self.deadline.map(|d| d + self.slack);
            let _ = ch.set_read_timeout(timeout);
            match ch.recv() {
                Ok((tag, p)) if tag == c2s::SHARD_MSG => {
                    // Network-facing input: a malformed or inconsistent
                    // frame retires the relay (certifying its whole
                    // outstanding partition) — never a panic, exactly
                    // like `RemotePool::drain` treats a bad client.
                    let Ok((sid, msgs, mut missing)) =
                        wire::decode_shard_msg(&p)
                    else {
                        self.drop_relay(s);
                        continue;
                    };
                    // Every id the relay accounts for must be one of
                    // the participants we handed it, exactly once.
                    // (Cloned so the failure paths below can mutate
                    // the pool; partitions are O(n/S) ids.)
                    let part = self.outstanding[s].clone();
                    let mut accounted: Vec<u32> = msgs
                        .iter()
                        .map(|m| m.client_id as u32)
                        .chain(missing.iter().copied())
                        .collect();
                    accounted.sort_unstable();
                    let dups =
                        accounted.windows(2).any(|w| w[0] == w[1]);
                    let valid = sid as usize == s
                        && !dups
                        && accounted.iter().all(|c| part.contains(c));
                    if !valid {
                        self.drop_relay(s);
                        continue;
                    }
                    // A participant the relay left unaccounted (it
                    // must not: its downward pool certifies losses)
                    // would hang the round engine — certify it here.
                    for &c in &part {
                        if !accounted.contains(&c) {
                            missing.push(c);
                        }
                    }
                    self.outstanding[s].clear();
                    self.missing.extend(missing);
                    if msgs.is_empty() {
                        continue; // whole partition was certified
                    }
                    return msgs;
                }
                _ => self.drop_relay(s),
            }
        }
        Vec::new()
    }

    fn eval_loss_each(&mut self, x: &[f64]) -> Vec<(u32, f64)> {
        // Probe replies are network-facing input: a malformed batch
        // retires the relay and the reduction proceeds over the
        // surviving partitions (same rule as `drain`).
        let payload = wire::encode_vec(x);
        let asked = self.ask_relays(s2c::EVAL_LOSS, &payload);
        let mut parts = Vec::with_capacity(self.n_clients);
        for s in asked {
            if let Some(p) = self.recv_expect(s, c2s::SHARD_LOSSES) {
                match wire::decode_id_scalars(&p) {
                    Ok(batch) => parts.extend(batch),
                    Err(_) => self.drop_relay(s),
                }
            }
        }
        parts
    }

    fn loss_grad_each(&mut self, x: &[f64]) -> Vec<(u32, f64, Vec<f64>)> {
        let payload = wire::encode_vec(x);
        let asked = self.ask_relays(s2c::LOSS_GRAD, &payload);
        let mut parts = Vec::with_capacity(self.n_clients);
        for s in asked {
            if let Some(p) = self.recv_expect(s, c2s::SHARD_GRADS) {
                match wire::decode_id_scalar_vecs(&p) {
                    Ok(batch) => parts.extend(batch),
                    Err(_) => self.drop_relay(s),
                }
            }
        }
        parts
    }

    fn loss_grad_sum(
        &mut self,
        x: &[f64],
    ) -> (
        crate::linalg::reduce::RepAcc,
        crate::linalg::reduce::RepVec,
        u32,
    ) {
        // Pre-reduced probe over the tier: one SHARD_GRAD_SUM frame
        // per relay (O(S·d) fan-in) merged exactly — bit-identical to
        // the flat atom fold. A malformed reply retires the relay and
        // the reduction proceeds over the surviving partitions (same
        // rule as the other probes).
        let payload = wire::encode_vec(x);
        let asked = self.ask_relays(s2c::LOSS_GRAD_SUM, &payload);
        let mut loss = crate::linalg::reduce::RepAcc::new();
        let mut grad = crate::linalg::reduce::RepVec::new(self.d);
        let mut count = 0u32;
        for s in asked {
            if let Some(p) = self.recv_expect(s, c2s::SHARD_GRAD_SUM) {
                match wire::decode_shard_grad_sum(&p, self.d) {
                    // A short gradient accumulator is as malformed as
                    // an undecodable one (merge requires length d).
                    Ok((c, l, g)) if g.len() == self.d => {
                        loss.merge(l);
                        grad.merge(g);
                        count += c;
                    }
                    _ => self.drop_relay(s),
                }
            }
        }
        (loss, grad, count)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        let payload = wire::encode_vec(x);
        let asked = self.ask_relays(s2c::WARM_START, &payload);
        let mut packs = Vec::with_capacity(self.n_clients);
        for s in asked {
            if let Some(p) = self.recv_expect(s, c2s::SHARD_WARM) {
                match wire::decode_vec_batch(&p) {
                    Ok(batch) => packs.extend(batch),
                    Err(_) => self.drop_relay(s),
                }
            }
        }
        packs
    }

    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
        // The PP bootstrap needs every client's (lᵢ, gᵢ), indexed by
        // client id — require the full tier.
        assert!(
            self.relays.iter().all(|r| r.is_some()),
            "init_state requires every relay registered"
        );
        let asked = self.ask_relays(s2c::STATE, &[]);
        assert_eq!(asked.len(), self.n_shards(), "relay lost at bootstrap");
        let mut parts: Vec<(u32, f64, Vec<f64>)> =
            Vec::with_capacity(self.n_clients);
        for s in asked {
            let p = self
                .recv_expect(s, c2s::SHARD_STATES)
                .expect("relay lost at bootstrap");
            parts.extend(
                wire::decode_id_scalar_vecs(&p).expect("states decode"),
            );
        }
        parts.sort_by_key(|&(id, _, _)| id);
        assert!(
            parts.iter().enumerate().all(|(i, &(id, _, _))| id as usize == i),
            "init_state: incomplete client coverage"
        );
        parts.into_iter().map(|(_, l, g)| (l, g)).collect()
    }

    fn pull_state(&mut self, client: u32) -> Option<(f64, Vec<f64>)> {
        let s = self
            .ranges
            .iter()
            .position(|&(lo, hi)| client >= lo && client < hi)
            .unwrap_or_else(|| {
                panic!("client {client} outside every partition")
            });
        if self.relays[s].is_none() {
            return None;
        }
        let payload = {
            let mut w = crate::utils::ByteWriter::with_capacity(4);
            w.put_u32(client);
            w.into_vec()
        };
        {
            let ch = self.relays[s].as_mut()?;
            let timeout = self.deadline.or(Some(Duration::from_secs(5)));
            let _ = ch.set_read_timeout(timeout);
            if ch.send(s2c::SHARD_PULL, &payload).is_ok() {
                if let Ok((tag, p)) = ch.recv() {
                    if tag == c2s::SHARD_PULLED {
                        // Malformed payload falls through to the
                        // drop-relay path below (network input).
                        if let Ok(state) = wire::decode_shard_pulled(&p) {
                            return state;
                        }
                    }
                }
            }
        }
        self.drop_relay(s);
        None
    }

    fn transport_bytes(&self) -> Option<(u64, u64)> {
        let up = self.retired_bytes.0
            + self
                .relays
                .iter()
                .flatten()
                .map(|c| c.bytes_received)
                .sum::<u64>();
        let down = self.retired_bytes.1
            + self
                .relays
                .iter()
                .flatten()
                .map(|c| c.bytes_sent)
                .sum::<u64>();
        Some((up, down))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relay_slack_validation() {
        // Zero is rejected with a clear message (mirroring
        // RoundPolicy::validate's zero-deadline rule); positive values
        // parse to the exact duration.
        let err = relay_slack_from_ms(0).unwrap_err().to_string();
        assert!(err.contains("--relay-slack-ms"), "{err}");
        assert!(err.contains("2000"), "{err}");
        assert_eq!(
            relay_slack_from_ms(1).unwrap(),
            Duration::from_millis(1)
        );
        assert_eq!(
            relay_slack_from_ms(7500).unwrap(),
            Duration::from_millis(7500)
        );
        assert_eq!(DEFAULT_RELAY_SLACK, Duration::from_millis(2000));
    }
}

//! FedNL (paper Algorithm 1).
//!
//! One round:
//! 1. every client evaluates (∇fᵢ, ∇²fᵢ) at xᵏ, sends ∇fᵢ,
//!    Sᵢᵏ = Cᵢᵏ(∇²fᵢ − Hᵢᵏ) and lᵢᵏ, and updates Hᵢᵏ⁺¹ = Hᵢᵏ + αSᵢᵏ;
//! 2. the master folds each message into ∇f / lᵏ / Hᵏ **as it
//!    arrives** (buffer-and-commit, ascending client id) and takes the
//!    Newton-type step of line 11.
//!
//! The driver is a thin wrapper over the unified round engine
//! ([`crate::algorithms::engine`]) with the plain-Newton step policy,
//! so the sequential reference pool, the multi-threaded simulator and
//! the TCP master all execute the exact same algorithm.

use super::engine::{run_engine, StepPolicy};
use super::{ClientState, Options};
use crate::coordinator::{ClientPool, SlicePool};
use crate::metrics::Trace;

/// Run FedNL against any client transport.
pub fn run_fednl_pool(
    pool: &mut dyn ClientPool,
    opts: &Options,
    x0: Vec<f64>,
    label: &str,
) -> Trace {
    run_engine(pool, opts, StepPolicy::Newton, x0, label)
}

/// Convenience: run FedNL over in-process clients, sequentially.
pub fn run_fednl(
    clients: &mut [ClientState],
    opts: &Options,
    x0: Vec<f64>,
) -> Trace {
    assert!(!clients.is_empty());
    let label = format!("FedNL/{}", clients[0].compressor.name());
    run_fednl_pool(&mut SlicePool::new(clients), opts, x0, &label)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::UpdateRule;
    use crate::compressors::{by_name, Identity};
    use crate::data::{generate_synthetic, Dataset, SynthSpec};
    use crate::linalg::Mat;
    use crate::oracle::{LogisticOracle, QuadraticOracle};

    fn logistic_clients(
        n_clients: usize,
        compressor: &str,
        seed: u64,
    ) -> (Vec<ClientState>, usize) {
        let spec = SynthSpec {
            d_raw: 9,
            n_samples: n_clients * 40,
            density: 0.6,
            noise: 1.0,
            label_bias: 0.0,
            seed,
        };
        let synth = generate_synthetic(&spec);
        let samples: Vec<crate::data::LibsvmSample> = synth
            .labels
            .iter()
            .zip(&synth.rows)
            .map(|(l, r)| crate::data::LibsvmSample {
                label: *l,
                features: r.clone(),
            })
            .collect();
        let ds = Dataset::from_libsvm(&samples, spec.d_raw);
        let d = ds.d;
        let shards = ds.split_even(n_clients).unwrap();
        let clients = shards
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                let oracle = LogisticOracle::new(sh, 1e-3);
                let comp = by_name(compressor, d, 2, seed + i as u64).unwrap();
                ClientState::new(i, Box::new(oracle), comp, None)
            })
            .collect();
        (clients, d)
    }

    #[test]
    fn quadratic_identity_converges_superfast() {
        let q = Mat::from_rows(&[&[3.0, 1.0], &[1.0, 2.0]]);
        let mut clients = vec![ClientState::new(
            0,
            Box::new(QuadraticOracle::new(q, vec![1.0, 2.0])),
            Box::new(Identity),
            None,
        )];
        let opts = Options { rounds: 30, ..Default::default() };
        let trace = run_fednl(&mut clients, &opts, vec![0.0, 0.0]);
        assert!(
            trace.last_grad_norm() < 1e-10,
            "final ‖∇f‖ = {}",
            trace.last_grad_norm()
        );
    }

    #[test]
    fn logistic_all_compressors_converge() {
        for comp in crate::compressors::ALL_NAMES {
            let (mut clients, d) = logistic_clients(4, comp, 7);
            let opts =
                Options { rounds: 60, track_loss: true, ..Default::default() };
            let trace = run_fednl(&mut clients, &opts, vec![0.0; d]);
            assert!(
                trace.last_grad_norm() < 1e-8,
                "{comp}: ‖∇f‖ = {}",
                trace.last_grad_norm()
            );
            let first = trace.records.first().unwrap().loss;
            let last = trace.records.last().unwrap().loss;
            assert!(last < first, "{comp}: loss {first} → {last}");
        }
    }

    #[test]
    fn grad_norm_superlinear_drop() {
        let (mut clients, d) = logistic_clients(3, "topk", 3);
        let opts = Options { rounds: 80, ..Default::default() };
        let trace = run_fednl(&mut clients, &opts, vec![0.0; d]);
        let g0 = trace.records[0].grad_norm;
        assert!(trace.last_grad_norm() < g0 * 1e-6);
    }

    #[test]
    fn early_stop_on_tolerance() {
        let (mut clients, d) = logistic_clients(3, "identity", 4);
        let opts = Options {
            rounds: 500,
            tol_grad: Some(1e-6),
            ..Default::default()
        };
        let trace = run_fednl(&mut clients, &opts, vec![0.0; d]);
        assert!(trace.records.len() < 100, "{} rounds", trace.records.len());
        assert!(trace.last_grad_norm() <= 1e-6);
    }

    #[test]
    fn project_mu_rule_also_converges() {
        let (mut clients, d) = logistic_clients(3, "randk", 5);
        let opts = Options {
            rounds: 80,
            rule: UpdateRule::ProjectMu(1e-3),
            warm_start: true,
            ..Default::default()
        };
        let trace = run_fednl(&mut clients, &opts, vec![0.0; d]);
        assert!(
            trace.last_grad_norm() < 1e-6,
            "‖∇f‖ = {}",
            trace.last_grad_norm()
        );
    }

    #[test]
    fn bytes_accounting_monotone() {
        let (mut clients, d) = logistic_clients(2, "randseqk", 6);
        let opts = Options { rounds: 10, ..Default::default() };
        let trace = run_fednl(&mut clients, &opts, vec![0.0; d]);
        let mut prev = 0;
        for r in &trace.records {
            assert!(r.bytes_up > prev);
            prev = r.bytes_up;
        }
    }

    #[test]
    fn threaded_pool_trajectory_matches_sequential() {
        let (mut c1, d) = logistic_clients(6, "toplek", 8);
        let (c2, _) = logistic_clients(6, "toplek", 8);
        let opts = Options { rounds: 25, track_loss: true, ..Default::default() };
        let t_seq = run_fednl(&mut c1, &opts, vec![0.0; d]);
        let mut thr = crate::coordinator::ThreadedPool::new(c2, 3);
        let t_thr =
            run_fednl_pool(&mut thr, &opts, vec![0.0; d], "FedNL/threaded");
        assert_eq!(t_seq.records.len(), t_thr.records.len());
        for (a, b) in t_seq.records.iter().zip(&t_thr.records) {
            assert_eq!(a.grad_norm, b.grad_norm, "round {}", a.round);
            assert_eq!(a.loss, b.loss);
        }
    }
}

//! End-to-end benches mirroring the paper's tables at bench scale:
//! Table 1 (single-node per-compressor wall time), Table 3 (multi-node
//! TCP), and the §4 cost-model sanity row.
//!
//! Run: `cargo bench --bench paper_tables`
//! Full-scale regeneration lives in `fednl experiment table1 --full`.

use fednl::algorithms::{run_fednl_pool, Options};
use fednl::compressors::ALL_NAMES;
use fednl::harness::{
    prepare_problem, run_tcp_experiment, HarnessCfg, TcpAlgo, K_MULT, W8A,
};
use fednl::utils::{human_bytes, Stopwatch};

fn main() -> anyhow::Result<()> {
    let cfg = HarnessCfg {
        out_dir: std::env::temp_dir()
            .join("fednl_bench")
            .to_string_lossy()
            .into_owned(),
        ..Default::default()
    };
    cfg.ensure_out_dir()?;
    let problem = prepare_problem(&W8A, &cfg)?;

    println!(
        "== bench: Table 1 shape (d={}, n={}, n_i={}, r={}) ==",
        problem.d(),
        problem.n_clients,
        problem.n_i,
        problem.rounds
    );
    println!(
        "{:<24} {:>10} {:>14} {:>12} {:>10}",
        "compressor", "time (s)", "||grad||", "MB up", "s/round"
    );
    for comp in ALL_NAMES {
        let mut pool = problem.threaded_pool(comp, K_MULT, &cfg)?;
        let opts = Options { rounds: problem.rounds, ..Default::default() };
        let sw = Stopwatch::start();
        let tr = run_fednl_pool(
            &mut pool,
            &opts,
            vec![0.0; problem.d()],
            comp,
        );
        let secs = sw.elapsed_secs();
        println!(
            "{:<24} {:>10.3} {:>14.3e} {:>12} {:>10.4}",
            comp,
            secs,
            tr.last_grad_norm(),
            human_bytes(tr.total_bytes_up()),
            secs / tr.records.len() as f64
        );
    }

    println!("\n== bench: Table 3 shape (multi-node TCP loopback) ==");
    let mut p = prepare_problem(&W8A, &cfg)?;
    p.n_clients = 8;
    p.n_i = p.dataset.n_samples() / (p.n_clients + 1);
    println!(
        "{:<24} {:>10} {:>10} {:>12}",
        "run", "solve (s)", "rounds", "wire up"
    );
    for (name, comp, algo) in [
        ("FedNL/topk", "topk", TcpAlgo::FedNL),
        ("FedNL/randseqk", "randseqk", TcpAlgo::FedNL),
        ("FedNL-LS/toplek", "toplek", TcpAlgo::FedNLLS),
        ("GD/identity", "identity", TcpAlgo::Gd),
        ("LBFGS/identity", "identity", TcpAlgo::Lbfgs),
    ] {
        let (tr, solve, _init) =
            run_tcp_experiment(&p, comp, algo, 20_000, Some(1e-9), &cfg)?;
        println!(
            "{:<24} {:>10.3} {:>10} {:>12}",
            name,
            solve,
            tr.records.len(),
            human_bytes(tr.total_bytes_up())
        );
    }

    println!("\n== §4 cost model ==");
    println!("{}", fednl::harness::costmodel());
    Ok(())
}

//! Dataset handling (paper components `fs`, `bin_split`,
//! `bin_opt_problem_generator`): memory-mapped LIBSVM parsing, dataset
//! densification with intercept augmentation and label absorption, u.a.r.
//! re-shuffling, equal splitting across clients, and a synthetic
//! logistic-regression problem generator that writes LIBSVM text.

pub mod dataset;
pub mod libsvm;
pub mod synth;

pub use dataset::{power_law_sizes, ClientShard, Dataset, SplitSpec};
pub use libsvm::{parse_libsvm_bytes, parse_libsvm_file, LibsvmSample};
pub use synth::{generate_synthetic, write_libsvm, SynthSpec};

//! Multi-node TCP integration: real sockets on loopback, the full
//! unified wire protocol, all three algorithms through the single round
//! engine — and trajectory equivalence with the in-process reference
//! (the wire codec is bit-exact for f64).

use fednl::algorithms::{
    run_fednl, run_fednl_ls_pool, run_fednl_pool, run_fednl_pp,
    run_fednl_pp_pool, ClientState, LineSearchParams, OnMissing, Options,
    PPClientState, RoundPolicy,
};
use fednl::compressors::by_name;
use fednl::coordinator::{
    shard, ClientPool, CorruptMode, FaultPlan, FaultPool, SeqPool,
};
use fednl::data::{generate_synthetic, Dataset, LibsvmSample, SynthSpec};
use fednl::net::client::ClientMode;
use fednl::net::server::Bound;
use fednl::net::wire;
use fednl::net::{
    run_client, run_client_with, run_relay_on, Channel, ClientOpts,
    RelayCfg, RelayPool,
};
use fednl::oracle::LogisticOracle;

fn dataset(d_raw: usize, n: usize, seed: u64) -> Dataset {
    let spec = SynthSpec {
        d_raw,
        n_samples: n,
        density: 0.5,
        noise: 1.0,
        label_bias: 0.0,
        seed,
    };
    let synth = generate_synthetic(&spec);
    let samples: Vec<LibsvmSample> = synth
        .labels
        .iter()
        .zip(&synth.rows)
        .map(|(l, r)| LibsvmSample { label: *l, features: r.clone() })
        .collect();
    let mut ds = Dataset::from_libsvm(&samples, d_raw);
    ds.reshuffle(seed);
    ds
}

fn spawn_clients(
    ds: &Dataset,
    n: usize,
    comp: &str,
    addr: &str,
    pp: bool,
) -> Vec<std::thread::JoinHandle<anyhow::Result<(u64, u64)>>> {
    let d = ds.d;
    ds.split_even(n)
        .unwrap()
        .into_iter()
        .map(|shard| {
            let addr = addr.to_string();
            let comp = by_name(comp, d, 8, 100 + shard.client_id as u64).unwrap();
            std::thread::spawn(move || {
                let id = shard.client_id;
                let oracle = Box::new(LogisticOracle::new(shard, 1e-3));
                let mode = if pp {
                    ClientMode::PP(PPClientState::new(
                        id,
                        oracle,
                        comp,
                        None,
                        &vec![0.0; d],
                    ))
                } else {
                    ClientMode::FedNL(ClientState::new(id, oracle, comp, None))
                };
                run_client(&addr, id, mode)
            })
        })
        .collect()
}

#[test]
fn tcp_fednl_matches_in_process_reference() {
    let ds = dataset(9, 150, 7);
    let d = ds.d;
    const N: usize = 5;
    let opts = Options { rounds: 25, track_loss: true, ..Default::default() };

    // Reference: sequential in-process (identical seeds).
    let mut ref_clients: Vec<ClientState> = ds
        .split_even(N)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            ClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("randseqk", d, 8, 100 + id as u64).unwrap(),
                None,
            )
        })
        .collect();
    let t_ref = run_fednl(&mut ref_clients, &opts, vec![0.0; d]);

    // TCP run.
    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let handles = spawn_clients(&ds, N, "randseqk", &addr, false);
    let mut pool = bound.accept(N).unwrap();
    let t_tcp = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "tcp");
    pool.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }

    assert_eq!(t_ref.records.len(), t_tcp.records.len());
    for (a, b) in t_ref.records.iter().zip(&t_tcp.records) {
        // f64 wire encoding is bit-exact; trajectories must be identical.
        assert_eq!(a.grad_norm, b.grad_norm, "round {}", a.round);
        assert_eq!(a.loss, b.loss);
    }
    assert!(t_tcp.last_grad_norm() < 1e-8);
}

#[test]
fn tcp_fednl_ls_converges() {
    let ds = dataset(8, 120, 8);
    let d = ds.d;
    const N: usize = 4;
    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let handles = spawn_clients(&ds, N, "toplek", &addr, false);
    let mut pool = bound.accept(N).unwrap();
    let opts = Options { rounds: 40, ..Default::default() };
    let t = run_fednl_ls_pool(
        &mut pool,
        &opts,
        &LineSearchParams::default(),
        vec![0.0; d],
        "tcp-ls",
    );
    pool.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    assert!(t.last_grad_norm() < 1e-8, "{}", t.last_grad_norm());
}

#[test]
fn tcp_fednl_pp_matches_in_process() {
    let ds = dataset(7, 120, 9);
    let d = ds.d;
    const N: usize = 4;
    let opts = Options { rounds: 60, ..Default::default() };

    let mut ref_pps: Vec<PPClientState> = ds
        .split_even(N)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            PPClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("topk", d, 8, 100 + id as u64).unwrap(),
                None,
                &vec![0.0; d],
            )
        })
        .collect();
    let t_ref = run_fednl_pp(&mut ref_pps, &opts, 2, 77, vec![0.0; d]);

    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let handles = spawn_clients(&ds, N, "topk", &addr, true);
    let mut pool = bound.accept(N).unwrap();
    let t_tcp = run_fednl_pp_pool(
        &mut pool,
        &opts,
        2,
        77,
        vec![0.0; d],
        "tcp-pp",
    );
    pool.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    for (a, b) in t_ref.records.iter().zip(&t_tcp.records) {
        assert_eq!(a.grad_norm, b.grad_norm, "round {}", a.round);
    }
    assert!(t_tcp.last_grad_norm() < 1e-6);
}

#[test]
fn logical_byte_accounting_matches_transport_exactly() {
    // Satellite fix: `ClientMsg::wire_bytes()` and the drivers' frame
    // size helpers are exact framed sizes, so an in-process run's
    // logical byte counts must equal the TCP transport's metered
    // counts up to the connection handshake, which the round loop does
    // not model: one REGISTER frame per client (up) and the SET_ALPHA
    // command (down) / ACK echo (up) pair.
    let ds = dataset(8, 120, 12);
    let d = ds.d;
    const N: usize = 4;
    let opts = Options {
        rounds: 8,
        track_loss: true,
        warm_start: true,
        ..Default::default()
    };

    let mut ref_clients: Vec<ClientState> = ds
        .split_even(N)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            ClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("topk", d, 8, 100 + id as u64).unwrap(),
                None,
            )
        })
        .collect();
    let t_ref = run_fednl(&mut ref_clients, &opts, vec![0.0; d]);

    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let handles = spawn_clients(&ds, N, "topk", &addr, false);
    let mut pool = bound.accept(N).unwrap();
    let t_tcp = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "tcp-bytes");
    pool.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }

    // Per client: one REGISTER frame + one ACK echo up, one SET_ALPHA
    // command down.
    let handshake_up =
        (wire::register_frame_bytes() + wire::scalar_frame_bytes())
            * N as u64;
    let handshake_down = wire::scalar_frame_bytes() * N as u64;
    assert_eq!(t_ref.records.len(), t_tcp.records.len());
    for (a, b) in t_ref.records.iter().zip(&t_tcp.records) {
        assert_eq!(
            b.bytes_up,
            a.bytes_up + handshake_up,
            "round {}: logical up {} vs metered {}",
            a.round,
            a.bytes_up,
            b.bytes_up
        );
        assert_eq!(
            b.bytes_down,
            a.bytes_down + handshake_down,
            "round {}: logical down {} vs metered {}",
            a.round,
            a.bytes_down,
            b.bytes_down
        );
    }
}

#[test]
fn transport_bytes_metered() {
    let ds = dataset(6, 80, 10);
    let d = ds.d;
    const N: usize = 3;
    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let handles = spawn_clients(&ds, N, "randk", &addr, false);
    let mut pool = bound.accept(N).unwrap();
    let opts = Options { rounds: 5, ..Default::default() };
    let t = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "meter");
    let (up, down) = pool.transport_bytes().unwrap();
    pool.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    // Real socket-level byte counts: nonzero, and up-dominated (Hessian
    // updates + gradients vs broadcast x).
    assert!(up > 0 && down > 0);
    assert!(up > down, "up {up} ≤ down {down}");
    assert_eq!(t.records.len(), 5);
}

fn pp_clients_for(
    ds: &Dataset,
    n: usize,
    comp: &str,
    x0: &[f64],
) -> Vec<PPClientState> {
    ds.split_even(n)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            PPClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name(comp, ds.d, 8, 100 + id as u64).unwrap(),
                None,
                x0,
            )
        })
        .collect()
}

#[test]
fn tcp_fault_plan_matches_in_process_bitwise() {
    // The acceptance invariant: a FaultPlan with a mid-run kill+rejoin
    // and injected stragglers, under quorum < n, produces bit-identical
    // FedNL-PP trajectories on the in-process reference and the real
    // TCP transport (both wrapped in the same master-side FaultPool).
    let ds = dataset(7, 120, 31);
    let d = ds.d;
    const N: usize = 4;
    let x0 = vec![0.0; d];
    let plan =
        FaultPlan::parse("kill@4:1-11,delay@2:0:20,delay@6:3:20,drop@13:2")
            .unwrap();
    let opts = Options {
        rounds: 25,
        policy: RoundPolicy {
            quorum: Some(1),
            deadline_ms: Some(2000),
            on_missing: OnMissing::Drop,
        },
        ..Default::default()
    };
    let (tau, seed) = (3usize, 77u64);

    let mut seq = FaultPool::new(
        SeqPool::new(pp_clients_for(&ds, N, "topk", &x0)),
        plan.clone(),
    );
    let t_seq = run_fednl_pp_pool(
        &mut seq,
        &opts,
        tau,
        seed,
        x0.clone(),
        "fault-seq",
    );

    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let handles = spawn_clients(&ds, N, "topk", &addr, true);
    let mut tcp = FaultPool::new(bound.accept(N).unwrap(), plan);
    let t_tcp =
        run_fednl_pp_pool(&mut tcp, &opts, tau, seed, x0, "fault-tcp");
    tcp.into_inner().shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }

    assert_eq!(t_seq.records.len(), t_tcp.records.len());
    for (a, b) in t_seq.records.iter().zip(&t_tcp.records) {
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "round {}",
            a.round
        );
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        // PP traces report logical byte counters on every transport.
        assert_eq!(a.bytes_up, b.bytes_up);
        assert_eq!(a.bytes_down, b.bytes_down);
        assert_eq!((a.committed, a.missing), (b.committed, b.missing));
    }
    // The kill window engaged and healed after the rejoin.
    assert!(t_seq.records.iter().any(|r| r.missing > 0));
    // No scheduled faults after the drop at round 13.
    assert!(t_seq
        .records
        .iter()
        .filter(|r| r.round >= 14)
        .all(|r| r.missing == 0));
    let first = t_seq.records[0].grad_norm;
    assert!(
        t_seq.last_grad_norm() < first * 1e-2,
        "{} -> {}",
        first,
        t_seq.last_grad_norm()
    );
}

#[test]
fn tcp_corrupt_plan_and_defense_match_in_process_bitwise() {
    // Byzantine corruption is injected master-side in the FaultPool,
    // so the same `corrupt@` plan must reproduce the in-process FedNL
    // trajectory bit-for-bit over real sockets — both undefended (the
    // raw attack) and under `--defense median` (the robust fold sees
    // identical committed sets on every transport). Byte columns are
    // transport-metered for FedNL over TCP and deliberately not
    // compared; the defended run must also converge while the
    // undefended one must not.
    let ds = dataset(8, 180, 41);
    let d = ds.d;
    const N: usize = 6;
    let x0 = vec![0.0; d];
    let rounds = 18u64;
    let mut plan = FaultPlan::none();
    for r in 2..rounds {
        plan = plan
            .with_corrupt(r, 0, CorruptMode::Scale(100.0))
            .with_corrupt(r, 3, CorruptMode::Scale(100.0));
    }
    let fednl_clients = || -> Vec<ClientState> {
        ds.split_even(N)
            .unwrap()
            .into_iter()
            .map(|sh| {
                let id = sh.client_id;
                ClientState::new(
                    id,
                    Box::new(LogisticOracle::new(sh, 1e-3)),
                    by_name("topk", d, 8, 100 + id as u64).unwrap(),
                    None,
                )
            })
            .collect()
    };
    for defense in [None, Some(fednl::robust::Defense::Median)] {
        let opts = Options {
            rounds,
            warm_start: true,
            defense,
            ..Default::default()
        };
        let mut seq = FaultPool::new(
            SeqPool::new(fednl_clients()),
            plan.clone(),
        );
        let t_seq =
            run_fednl_pool(&mut seq, &opts, x0.clone(), "corrupt-seq");

        let bound = Bound::bind("127.0.0.1:0").unwrap();
        let addr = bound.local_addr().unwrap().to_string();
        let handles = spawn_clients(&ds, N, "topk", &addr, false);
        let mut tcp = FaultPool::new(bound.accept(N).unwrap(), plan.clone());
        let t_tcp =
            run_fednl_pool(&mut tcp, &opts, x0.clone(), "corrupt-tcp");
        tcp.into_inner().shutdown();
        for h in handles {
            h.join().unwrap().unwrap();
        }

        assert_eq!(t_seq.records.len(), t_tcp.records.len());
        for (a, b) in t_seq.records.iter().zip(&t_tcp.records) {
            assert_eq!(
                a.grad_norm.to_bits(),
                b.grad_norm.to_bits(),
                "defense={defense:?} round {}",
                a.round
            );
            assert_eq!((a.committed, a.missing), (b.committed, b.missing));
            assert_eq!(a.flagged, b.flagged, "round {}", a.round);
        }
        let first = t_seq.records[0].grad_norm;
        let last = t_seq.last_grad_norm();
        match defense {
            // Negated so a NaN/inf blow-up also counts as degraded.
            None => assert!(
                !(last < first * 1e-1),
                "attack ineffective: {first:.3e} -> {last:.3e}"
            ),
            Some(_) => {
                assert!(
                    last.is_finite() && last < first * 1e-2,
                    "defense failed: {first:.3e} -> {last:.3e}"
                );
                assert!(t_seq
                    .records
                    .iter()
                    .all(|r| r.flagged == (N as u32) - 1));
            }
        }
    }
}

/// Spawn a full relay tier on loopback: `n_shards` relay threads (one
/// ephemeral listener each) plus one client thread per dataset shard,
/// each connecting to the relay that owns its id. Returns the handles;
/// the caller accepts the relays on `master_bound`.
#[allow(clippy::type_complexity)]
fn spawn_relay_tier(
    ds: &Dataset,
    n: usize,
    n_shards: usize,
    comp: &str,
    master_addr: &str,
    pp: bool,
) -> (
    Vec<
        std::thread::JoinHandle<
            anyhow::Result<fednl::net::relay::RelayReport>,
        >,
    >,
    Vec<std::thread::JoinHandle<anyhow::Result<(u64, u64)>>>,
) {
    let d = ds.d;
    let ranges = shard::partition(n, n_shards);
    let mut shards_by_id: Vec<Option<fednl::data::ClientShard>> =
        ds.split_even(n).unwrap().into_iter().map(Some).collect();
    let mut relay_handles = Vec::new();
    let mut client_handles = Vec::new();
    for (s, &(lo, hi)) in ranges.iter().enumerate() {
        let relay_bound = Bound::bind("127.0.0.1:0").unwrap();
        let relay_addr = relay_bound.local_addr().unwrap().to_string();
        let rcfg = RelayCfg {
            shard_id: s as u32,
            base: lo,
            count: (hi - lo) as usize,
            listen: String::new(),
            connect: master_addr.to_string(),
            ..Default::default()
        };
        relay_handles.push(std::thread::spawn(move || {
            run_relay_on(relay_bound, &rcfg)
        }));
        for ci in lo..hi {
            let sh = shards_by_id[ci as usize].take().unwrap();
            let addr = relay_addr.clone();
            let comp = by_name(comp, d, 8, 100 + ci as u64).unwrap();
            client_handles.push(std::thread::spawn(move || {
                let id = sh.client_id;
                let oracle = Box::new(LogisticOracle::new(sh, 1e-3));
                let mode = if pp {
                    ClientMode::PP(PPClientState::new(
                        id,
                        oracle,
                        comp,
                        None,
                        &vec![0.0; d],
                    ))
                } else {
                    ClientMode::FedNL(ClientState::new(id, oracle, comp, None))
                };
                run_client(&addr, id, mode)
            }));
        }
    }
    (relay_handles, client_handles)
}

#[test]
fn tcp_relay_tier_matches_unsharded_bitwise() {
    // The sharded-master acceptance invariant over real sockets:
    // FedNL (with warm start — exercises the SHARD_WARM batch path)
    // through an S=2 relay tier is bit-identical to the flat
    // sequential reference, round for round.
    let ds = dataset(8, 120, 41);
    let d = ds.d;
    const N: usize = 5;
    let opts = Options {
        rounds: 15,
        track_loss: true,
        warm_start: true,
        ..Default::default()
    };

    let mut ref_clients: Vec<ClientState> = ds
        .split_even(N)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            ClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("randseqk", d, 8, 100 + id as u64).unwrap(),
                None,
            )
        })
        .collect();
    let t_ref = run_fednl(&mut ref_clients, &opts, vec![0.0; d]);

    let master = Bound::bind("127.0.0.1:0").unwrap();
    let addr = master.local_addr().unwrap().to_string();
    let (relays, clients) =
        spawn_relay_tier(&ds, N, 2, "randseqk", &addr, false);
    let mut pool = RelayPool::accept(master, 2).unwrap();
    assert_eq!(pool.n_clients(), N);
    assert_eq!(pool.n_shards(), 2);
    let t_tcp = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "relay");
    let (up, down) = pool.transport_bytes().unwrap();
    pool.shutdown();
    for h in relays {
        h.join().unwrap().unwrap();
    }
    for h in clients {
        h.join().unwrap().unwrap();
    }

    assert_eq!(t_ref.records.len(), t_tcp.records.len());
    for (a, b) in t_ref.records.iter().zip(&t_tcp.records) {
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "round {}",
            a.round
        );
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }
    assert!(t_tcp.last_grad_norm() < 1e-8);
    // The master↔relay channels metered real traffic in both
    // directions (the trace's byte columns report these for FedNL).
    assert!(up > 0 && down > 0);

    // FedNL-LS through an S=3 tier: the Armijo backtracking probes
    // ride EVAL_LOSS → SHARD_LOSSES per-client batches, whose
    // ascending-id reduction must match the flat pool bit for bit.
    let opts_ls = Options { rounds: 12, track_loss: true, ..Default::default() };
    let ref_ls: Vec<ClientState> = ds
        .split_even(N)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            ClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("toplek", d, 8, 100 + id as u64).unwrap(),
                None,
            )
        })
        .collect();
    let mut flat = SeqPool::new(ref_ls);
    let t_ref = run_fednl_ls_pool(
        &mut flat,
        &opts_ls,
        &LineSearchParams::default(),
        vec![0.0; d],
        "flat-ls",
    );
    let master = Bound::bind("127.0.0.1:0").unwrap();
    let addr = master.local_addr().unwrap().to_string();
    let (relays, clients) =
        spawn_relay_tier(&ds, N, 3, "toplek", &addr, false);
    let mut pool = RelayPool::accept(master, 3).unwrap();
    let t_tcp = run_fednl_ls_pool(
        &mut pool,
        &opts_ls,
        &LineSearchParams::default(),
        vec![0.0; d],
        "relay-ls",
    );
    pool.shutdown();
    for h in relays {
        h.join().unwrap().unwrap();
    }
    for h in clients {
        h.join().unwrap().unwrap();
    }
    assert_eq!(t_ref.records.len(), t_tcp.records.len());
    for (a, b) in t_ref.records.iter().zip(&t_tcp.records) {
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "ls round {}",
            a.round
        );
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }

    // FedNL-PP through the same tier: τ subsets cross shard
    // boundaries, the bootstrap uses the SHARD_STATES batch, and the
    // per-round ‖∇f‖ probe uses SHARD_GRADS. PP traces always report
    // logical byte counters, so those must agree bitwise too.
    let opts_pp = Options { rounds: 40, ..Default::default() };
    let mut ref_pps: Vec<PPClientState> = ds
        .split_even(N)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            PPClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("topk", d, 8, 100 + id as u64).unwrap(),
                None,
                &vec![0.0; d],
            )
        })
        .collect();
    let t_ref = run_fednl_pp(&mut ref_pps, &opts_pp, 3, 88, vec![0.0; d]);

    let master = Bound::bind("127.0.0.1:0").unwrap();
    let addr = master.local_addr().unwrap().to_string();
    let (relays, clients) = spawn_relay_tier(&ds, N, 2, "topk", &addr, true);
    let mut pool = RelayPool::accept(master, 2).unwrap();
    let t_tcp = run_fednl_pp_pool(
        &mut pool,
        &opts_pp,
        3,
        88,
        vec![0.0; d],
        "relay-pp",
    );
    pool.shutdown();
    for h in relays {
        h.join().unwrap().unwrap();
    }
    for h in clients {
        h.join().unwrap().unwrap();
    }
    assert_eq!(t_ref.records.len(), t_tcp.records.len());
    for (a, b) in t_ref.records.iter().zip(&t_tcp.records) {
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "pp round {}",
            a.round
        );
        assert_eq!(a.bytes_up, b.bytes_up);
        assert_eq!(a.bytes_down, b.bytes_down);
    }
}

#[test]
fn tcp_relay_tier_fault_plan_bit_identical() {
    // Faults compose through the tier over real sockets: the same
    // FaultPlan (kill+rejoin window crossing shard boundaries, a
    // one-round drop) under a quorum policy yields bit-identical
    // FedNL-PP trajectories on the flat in-process reference and on an
    // S=3 relay tier — including the rejoin-round STATE resync, which
    // rides the SHARD_PULL frame.
    let ds = dataset(7, 120, 42);
    let d = ds.d;
    const N: usize = 6;
    let x0 = vec![0.0; d];
    let plan = FaultPlan::parse("kill@3:1-10,drop@12:5").unwrap();
    let opts = Options {
        rounds: 25,
        policy: RoundPolicy {
            quorum: Some(1),
            deadline_ms: Some(2000),
            on_missing: OnMissing::Drop,
        },
        ..Default::default()
    };
    let (tau, seed) = (4usize, 67u64);

    let mut flat = FaultPool::new(
        SeqPool::new(pp_clients_for(&ds, N, "topk", &x0)),
        plan.clone(),
    );
    let t_flat = run_fednl_pp_pool(
        &mut flat,
        &opts,
        tau,
        seed,
        x0.clone(),
        "fault-flat",
    );
    assert!(t_flat.records.iter().any(|r| r.missing > 0));

    let master = Bound::bind("127.0.0.1:0").unwrap();
    let addr = master.local_addr().unwrap().to_string();
    let (relays, clients) = spawn_relay_tier(&ds, N, 3, "topk", &addr, true);
    let mut pool =
        FaultPool::new(RelayPool::accept(master, 3).unwrap(), plan);
    let t_tcp =
        run_fednl_pp_pool(&mut pool, &opts, tau, seed, x0, "fault-relay");
    pool.into_inner().shutdown();
    for h in relays {
        h.join().unwrap().unwrap();
    }
    for h in clients {
        h.join().unwrap().unwrap();
    }

    assert_eq!(t_flat.records.len(), t_tcp.records.len());
    for (a, b) in t_flat.records.iter().zip(&t_tcp.records) {
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "round {}",
            a.round
        );
        assert_eq!(a.bytes_up, b.bytes_up);
        assert_eq!(a.bytes_down, b.bytes_down);
        assert_eq!((a.committed, a.missing), (b.committed, b.missing));
    }
    let first = t_flat.records[0].grad_norm;
    assert!(
        t_flat.last_grad_norm() < first * 1e-2,
        "{} -> {}",
        first,
        t_flat.last_grad_norm()
    );
}

#[test]
fn tcp_graceful_leave_then_rejoin() {
    // Phase 1: client 2 serves two rounds, announces DEREGISTER and
    // exits; under a quorum policy the master keeps training on the
    // survivors. Phase 2: a replacement re-registers on the retained
    // listener and full rounds resume.
    let ds = dataset(6, 90, 32);
    let d = ds.d;
    const N: usize = 3;
    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let mut handles = Vec::new();
    for shard in ds.split_even(N).unwrap() {
        let addr = addr.clone();
        let comp = by_name("identity", d, 8, 100 + shard.client_id as u64)
            .unwrap();
        handles.push(std::thread::spawn(move || {
            let id = shard.client_id;
            let oracle = Box::new(LogisticOracle::new(shard, 1e-3));
            let opts = ClientOpts {
                leave_after_rounds: if id == 2 { Some(2) } else { None },
                ..Default::default()
            };
            run_client_with(
                &addr,
                id,
                ClientMode::FedNL(ClientState::new(id, oracle, comp, None)),
                opts,
            )
        }));
    }
    let mut pool = bound.accept(N).unwrap();
    let opts = Options {
        rounds: 5,
        policy: RoundPolicy {
            quorum: Some(1),
            deadline_ms: None,
            on_missing: OnMissing::Drop,
        },
        ..Default::default()
    };
    let t1 = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "leave");
    assert_eq!(t1.records[0].committed, 3);
    assert_eq!(t1.records[1].committed, 3);
    for r in &t1.records[2..] {
        assert_eq!(
            (r.committed, r.missing),
            (2, 1),
            "round {} after the leave",
            r.round
        );
    }
    assert_eq!(pool.dead_clients(), vec![2]);

    // Replacement client for id 2 (fresh state) re-registers.
    let sh = ds.split_even(N).unwrap().remove(2);
    let comp = by_name("identity", d, 8, 102).unwrap();
    let addr2 = addr.clone();
    handles.push(std::thread::spawn(move || {
        let oracle = Box::new(LogisticOracle::new(sh, 1e-3));
        run_client(
            &addr2,
            2,
            ClientMode::FedNL(ClientState::new(2, oracle, comp, None)),
        )
    }));
    // Wait until the retained listener admits it (polled per round).
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        pool.prepare_round(0);
        if pool.dead_clients().is_empty() {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "rejoin was never admitted"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(pool.take_rejoined(), vec![2]);

    // Phase 2: full rounds again (mechanics — every round commits 3).
    let t2 = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "rejoined");
    for r in &t2.records {
        assert_eq!((r.committed, r.missing), (3, 0), "round {}", r.round);
    }
    assert!(t2.last_grad_norm().is_finite());
    pool.shutdown();
    for h in handles {
        let _ = h.join();
    }
}

#[test]
fn tcp_reply_deadline_deregisters_straggler() {
    // A hand-rolled client that sleeps far beyond the reply deadline:
    // the master deregisters it on the first round and keeps training
    // on the survivors (quorum policy), never blocking on it again.
    use fednl::net::wire::{c2s, s2c};
    let ds = dataset(6, 90, 33);
    let d = ds.d;
    const N: usize = 3;
    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let mut handles = Vec::new();
    // Two well-behaved clients.
    for shard in ds.split_even(N).unwrap().into_iter().take(2) {
        let addr = addr.clone();
        let comp =
            by_name("identity", d, 8, 100 + shard.client_id as u64).unwrap();
        handles.push(std::thread::spawn(move || {
            let id = shard.client_id;
            let oracle = Box::new(LogisticOracle::new(shard, 1e-3));
            let _ = run_client(
                &addr,
                id,
                ClientMode::FedNL(ClientState::new(id, oracle, comp, None)),
            );
        }));
    }
    // The straggler: answers the handshake promptly, then sleeps 2 s
    // before every round reply.
    {
        let sh = ds.split_even(N).unwrap().remove(2);
        let comp = by_name("identity", d, 8, 102).unwrap();
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut state =
                ClientState::new(2, Box::new(LogisticOracle::new(sh, 1e-3)), comp, None);
            let stream = std::net::TcpStream::connect(&addr).unwrap();
            let mut ch = Channel::new(stream).unwrap();
            ch.send(
                c2s::REGISTER,
                &wire::encode_register(2, d as u32, wire::FAMILY_FEDNL, 0),
            )
            .unwrap();
            loop {
                let Ok((tag, p)) = ch.recv() else { break };
                match tag {
                    s2c::ROUND => {
                        let (x, round, need_loss) =
                            wire::decode_round(&p).unwrap();
                        std::thread::sleep(
                            std::time::Duration::from_millis(2000),
                        );
                        let m = state.round(&x, round, need_loss);
                        if ch
                            .send(c2s::MSG, &wire::encode_client_msg(&m))
                            .is_err()
                        {
                            break;
                        }
                    }
                    s2c::SET_ALPHA => {
                        let a = wire::decode_scalar(&p).unwrap();
                        if a.is_finite() && a > 0.0 {
                            state.alpha = a;
                        }
                        if ch
                            .send(c2s::ACK, &wire::encode_scalar(state.alpha))
                            .is_err()
                        {
                            break;
                        }
                    }
                    _ => break,
                }
            }
        }));
    }
    let mut pool = bound.accept(N).unwrap();
    let opts = Options {
        rounds: 4,
        policy: RoundPolicy {
            quorum: Some(2),
            deadline_ms: Some(400),
            on_missing: OnMissing::Drop,
        },
        ..Default::default()
    };
    let sw = std::time::Instant::now();
    let t = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "deadline");
    // Round 0 paid the deadline once; later rounds skip the dead
    // client at submit time (no per-round 400 ms stall).
    assert!(sw.elapsed() < std::time::Duration::from_secs(5));
    assert_eq!((t.records[0].committed, t.records[0].missing), (2, 1));
    for r in &t.records[1..] {
        assert_eq!((r.committed, r.missing), (2, 1), "round {}", r.round);
    }
    assert_eq!(pool.dead_clients(), vec![2]);
    pool.shutdown();
    for h in handles {
        let _ = h.join();
    }
}

#[test]
fn duplicate_client_id_rejected() {
    let ds = dataset(5, 40, 11);
    let d = ds.d;
    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    // Two clients both claiming id 0.
    let mk = |_i: usize| {
        let sh = ds.split_even(2).unwrap().remove(0);
        let addr = addr.clone();
        let comp = by_name("identity", d, 8, 0).unwrap();
        std::thread::spawn(move || {
            let oracle = Box::new(LogisticOracle::new(sh, 1e-3));
            run_client(
                &addr,
                0,
                ClientMode::FedNL(ClientState::new(0, oracle, comp, None)),
            )
        })
    };
    let h1 = mk(0);
    let h2 = mk(1);
    let res = bound.accept(2);
    assert!(res.is_err(), "duplicate registration must fail");
    // The client threads will error out when the master drops; ignore.
    let _ = h1.join();
    let _ = h2.join();
}

/// Spawn the failover depth-3 tree against `master_addr`: parent
/// relay P (`--parent 2`, master shard 0, ids 0..3) over child relays
/// A = [0,2) and B = [2,3), plus leaf relay C (master shard 1, ids
/// 3..6) — every client carrying `--fallback master_addr` so a severed
/// subtree rotates to the master and is adopted.
#[allow(clippy::type_complexity)]
fn spawn_relay_tree(
    ds: &Dataset,
    comp: &str,
    master_addr: &str,
) -> (
    Vec<
        std::thread::JoinHandle<
            anyhow::Result<fednl::net::relay::RelayReport>,
        >,
    >,
    Vec<std::thread::JoinHandle<anyhow::Result<(u64, u64)>>>,
) {
    let d = ds.d;
    let mut shards_by_id: Vec<Option<fednl::data::ClientShard>> =
        ds.split_even(6).unwrap().into_iter().map(Some).collect();
    let mut relays = Vec::new();
    let mut clients = Vec::new();

    let p_bound = Bound::bind("127.0.0.1:0").unwrap();
    let p_addr = p_bound.local_addr().unwrap().to_string();
    let pcfg = RelayCfg {
        shard_id: 0,
        base: 0,
        count: 3,
        listen: String::new(),
        connect: master_addr.to_string(),
        children: Some(2),
        ..Default::default()
    };
    relays.push(std::thread::spawn(move || run_relay_on(p_bound, &pcfg)));

    let mut leaves: Vec<(u32, u32, String)> = Vec::new();
    for (s, &(lo, hi)) in shard::partition(3, 2).iter().enumerate() {
        let leaf_bound = Bound::bind("127.0.0.1:0").unwrap();
        let leaf_addr = leaf_bound.local_addr().unwrap().to_string();
        let rcfg = RelayCfg {
            shard_id: s as u32,
            base: lo,
            count: (hi - lo) as usize,
            listen: String::new(),
            connect: p_addr.clone(),
            ..Default::default()
        };
        relays.push(std::thread::spawn(move || {
            run_relay_on(leaf_bound, &rcfg)
        }));
        leaves.push((lo, hi, leaf_addr));
    }
    let c_bound = Bound::bind("127.0.0.1:0").unwrap();
    let c_addr = c_bound.local_addr().unwrap().to_string();
    let ccfg = RelayCfg {
        shard_id: 1,
        base: 3,
        count: 3,
        listen: String::new(),
        connect: master_addr.to_string(),
        ..Default::default()
    };
    relays.push(std::thread::spawn(move || run_relay_on(c_bound, &ccfg)));
    leaves.push((3, 6, c_addr));

    for (lo, hi, leaf_addr) in leaves {
        for ci in lo..hi {
            let sh = shards_by_id[ci as usize].take().unwrap();
            let addr = leaf_addr.clone();
            let fallback = master_addr.to_string();
            let comp = by_name(comp, d, 8, 100 + ci as u64).unwrap();
            clients.push(std::thread::spawn(move || {
                let id = sh.client_id;
                let oracle = Box::new(LogisticOracle::new(sh, 1e-3));
                run_client_with(
                    &addr,
                    id,
                    ClientMode::FedNL(ClientState::new(
                        id, oracle, comp, None,
                    )),
                    ClientOpts {
                        fallback: vec![fallback],
                        ..Default::default()
                    },
                )
            }));
        }
    }
    (relays, clients)
}

#[test]
fn tcp_relay_tree_killrelay_heals_bit_identical() {
    // The failover tentpole over real sockets: `killrelay@4:0` severs
    // the inner node P of a depth-3 tree mid-run; its subtree (both
    // child relays and their 3 clients) dies by upward-EOF
    // propagation, the orphans rotate to `--fallback` and the master
    // adopts them at the next prepare_round. The healed trajectory
    // must be bit-identical to the same plan desugared on a flat
    // sequential pool, with losses confined to the kill round.
    let ds = dataset(8, 120, 51);
    let d = ds.d;
    const N: usize = 6;
    let x0 = vec![0.0; d];
    let plan = FaultPlan::parse("killrelay@4:0").unwrap();
    let opts = Options {
        rounds: 14,
        policy: RoundPolicy {
            quorum: Some(3),
            deadline_ms: Some(2000),
            on_missing: OnMissing::Drop,
        },
        ..Default::default()
    };

    let flat_clients: Vec<ClientState> = ds
        .split_even(N)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            ClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("topk", d, 8, 100 + id as u64).unwrap(),
                None,
            )
        })
        .collect();
    let mut flat = FaultPool::with_shard_layout(
        SeqPool::new(flat_clients),
        plan.clone(),
        2,
    );
    let t_flat = run_fednl_pool(&mut flat, &opts, x0.clone(), "tree-flat");

    let master = Bound::bind("127.0.0.1:0").unwrap();
    let addr = master.local_addr().unwrap().to_string();
    let (relays, clients) = spawn_relay_tree(&ds, "topk", &addr);
    let mut pool =
        FaultPool::new(RelayPool::accept(master, 2).unwrap(), plan);
    let t_tree = run_fednl_pool(&mut pool, &opts, x0, "tree-kill");
    pool.into_inner().shutdown();
    for h in relays {
        h.join().unwrap().unwrap();
    }
    for h in clients {
        h.join().unwrap().unwrap();
    }

    assert_eq!(t_flat.records.len(), t_tree.records.len());
    for (a, b) in t_flat.records.iter().zip(&t_tree.records) {
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "round {}",
            a.round
        );
        assert_eq!((a.committed, a.missing), (b.committed, b.missing));
    }
    // Exactly P's partition, exactly the kill round; healed after.
    for r in &t_flat.records {
        let expect = if r.round == 4 { (3, 3) } else { (6, 0) };
        assert_eq!((r.committed, r.missing), expect, "round {}", r.round);
    }
    let first = t_flat.records[0].grad_norm;
    assert!(
        t_flat.last_grad_norm() < first * 1e-2,
        "{} -> {}",
        first,
        t_flat.last_grad_norm()
    );
}

#[test]
fn tcp_relay_die_after_round_discards_staged_exactly_once() {
    // The commit-ack reply-lost window end to end: relay 0 fans round
    // 4 to its partition (every client computes and *stages* under
    // commit-ack), drains the replies, then dies without forwarding
    // upward. The master certifies the partition missing for round 4
    // in the same round (EOF sweep), adopts the orphans at round 5,
    // and the rejoin RESYNC carries watermark 3 — so the staged round
    // 4 is discarded, never double-applied. The run must be
    // bit-identical to `killrelay@4:0` desugared flat, where those
    // clients never computed round 4 at all: exactly-once either way.
    let ds = dataset(8, 120, 52);
    let d = ds.d;
    const N: usize = 6;
    let x0 = vec![0.0; d];
    let opts = Options {
        rounds: 12,
        policy: RoundPolicy {
            quorum: Some(3),
            deadline_ms: Some(2000),
            on_missing: OnMissing::Drop,
        },
        ..Default::default()
    };

    let plan = FaultPlan::parse("killrelay@4:0").unwrap();
    let flat_clients: Vec<ClientState> = ds
        .split_even(N)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            ClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("topk", d, 8, 100 + id as u64).unwrap(),
                None,
            )
        })
        .collect();
    let mut flat = FaultPool::with_shard_layout(
        SeqPool::new(flat_clients),
        plan,
        2,
    );
    let t_flat = run_fednl_pool(&mut flat, &opts, x0.clone(), "die-flat");

    // Flat S=2 relay tier; relay 0 scripted to die after round 4.
    let master = Bound::bind("127.0.0.1:0").unwrap();
    let addr = master.local_addr().unwrap().to_string();
    let mut shards_by_id: Vec<Option<fednl::data::ClientShard>> =
        ds.split_even(N).unwrap().into_iter().map(Some).collect();
    let mut relays = Vec::new();
    let mut clients = Vec::new();
    for (s, &(lo, hi)) in shard::partition(N, 2).iter().enumerate() {
        let relay_bound = Bound::bind("127.0.0.1:0").unwrap();
        let relay_addr = relay_bound.local_addr().unwrap().to_string();
        let rcfg = RelayCfg {
            shard_id: s as u32,
            base: lo,
            count: (hi - lo) as usize,
            listen: String::new(),
            connect: addr.clone(),
            die_after_round: if s == 0 { Some(4) } else { None },
            ..Default::default()
        };
        relays.push(std::thread::spawn(move || {
            run_relay_on(relay_bound, &rcfg)
        }));
        for ci in lo..hi {
            let sh = shards_by_id[ci as usize].take().unwrap();
            let caddr = relay_addr.clone();
            let fallback = addr.clone();
            let comp = by_name("topk", d, 8, 100 + ci as u64).unwrap();
            clients.push(std::thread::spawn(move || {
                let id = sh.client_id;
                let oracle = Box::new(LogisticOracle::new(sh, 1e-3));
                run_client_with(
                    &caddr,
                    id,
                    ClientMode::FedNL(ClientState::new(
                        id, oracle, comp, None,
                    )),
                    ClientOpts {
                        fallback: vec![fallback],
                        ..Default::default()
                    },
                )
            }));
        }
    }
    let mut pool = RelayPool::accept(master, 2).unwrap();
    let t_die = run_fednl_pool(&mut pool, &opts, x0, "die-relay");
    pool.shutdown();
    for h in relays {
        h.join().unwrap().unwrap();
    }
    for h in clients {
        h.join().unwrap().unwrap();
    }

    assert_eq!(t_flat.records.len(), t_die.records.len());
    for (a, b) in t_flat.records.iter().zip(&t_die.records) {
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "round {}",
            a.round
        );
        assert_eq!((a.committed, a.missing), (b.committed, b.missing));
    }
    for r in &t_die.records {
        let expect = if r.round == 4 { (3, 3) } else { (6, 0) };
        assert_eq!((r.committed, r.missing), expect, "round {}", r.round);
    }
    let first = t_die.records[0].grad_norm;
    assert!(
        t_die.last_grad_norm() < first * 1e-2,
        "{} -> {}",
        first,
        t_die.last_grad_norm()
    );
}

//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt` produced
//! by `python/compile/aot.py`) and run the Layer-2 JAX oracle from the
//! Rust hot path.
//!
//! Python never runs at request time: `make artifacts` lowers the jitted
//! oracle to HLO **text** once; here `HloModuleProto::from_text_file` →
//! `PjRtClient::compile` produces a native executable per dataset shape.
//! A client's design matrix is uploaded once as a device-resident buffer
//! and reused every round; only the d-vector x travels per call.
//!
//! The real implementation needs the `xla` crate and is gated behind the
//! off-by-default `xla` cargo feature so the crate builds with zero
//! native dependencies; without it a stub with the identical public API
//! returns a descriptive error from [`PjrtRuntime::load`] (callers
//! already treat a load failure as "artifacts unavailable").

#[cfg(feature = "xla")]
pub mod pjrt;

#[cfg(not(feature = "xla"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use pjrt::{PjrtOracle, PjrtRuntime, ShapeEntry};

//! Coordination layer: how the master reaches its clients.
//!
//! # The streaming pool API
//!
//! The FedNL drivers (`algorithms::engine`) talk to a [`ClientPool`],
//! whose round primitive is **non-blocking and subset-aware**:
//!
//! * [`ClientPool::submit_round`] dispatches one client round — to every
//!   client, or to a participation subset (FedNL-PP, Alg. 3) — and
//!   returns immediately;
//! * [`ClientPool::drain`] blocks until at least one outstanding reply
//!   is available and returns whatever has arrived (any order); an
//!   empty batch means the round is complete;
//! * [`ClientPool::round`] is the blocking shim built on the two
//!   (collect everything, sort by client id) for callers that do not
//!   stream.
//!
//! The master processes replies **as they arrive** (paper §7, §9.3):
//! the server-side aggregation of client i's sparse Hessian update and
//! gradient overlaps with client j's compute and in-flight network
//! transfer.
//!
//! # The buffer-and-commit determinism rule
//!
//! Streaming must not cost reproducibility. Replies may *arrive* in any
//! order, but state is *committed* in a fixed order: the driver buffers
//! early arrivals and applies messages in **round-subset order** (for a
//! full round that is ascending client id; for a FedNL-PP round it is
//! the seeded sampler's selection order, matching the sequential
//! reference). All f64 reductions — message aggregation, `eval_loss`,
//! `loss_grad`, `warm_start`, `init_state` — reduce in ascending client
//! id order on every transport, so the three pools produce
//! **bit-identical optimization trajectories** (asserted by the
//! integration tests).
//!
//! # Transports
//!
//! * [`SeqPool`] — in-process, sequential (reference semantics; owns its
//!   clients);
//! * [`SlicePool`] — the same over a borrowed `&mut [C]` client slice;
//! * [`local_sim::ThreadedPool`] — the paper's single-node multi-core
//!   simulator (§5.12): a worker pool sized to the physical cores,
//!   clients statically dispatched, every reply streamed to the master
//!   the moment it is computed;
//! * `net::server::RemotePool` — the multi-node TCP master (§7).
//!
//! All four drive either algorithm family: a pool is generic over a
//! [`PoolClient`] (plain FedNL / FedNL-LS clients *or* FedNL-PP
//! clients), and the wire protocol uses one unified ROUND/MSG exchange
//! for both (see `net::wire`).
//!
//! # Fault tolerance
//!
//! A round may lose participants. The pool-side contract (all default
//! to the no-fault behavior, so the in-process pools stay trivially
//! correct):
//!
//! * [`ClientPool::take_missing`] — participants of the round in
//!   flight whose reply will **never** arrive (fault injection, missed
//!   reply deadline, closed connection). `drain` must not return an
//!   empty batch while replies are outstanding *unless* the lost ones
//!   have been certified here — "empty batch" keeps meaning "the round
//!   is closed at the transport level".
//! * [`ClientPool::dead_clients`] / [`ClientPool::take_rejoined`] /
//!   [`ClientPool::prepare_round`] — liveness bookkeeping for the
//!   driver's participation sampling and rejoin resync.
//! * [`ClientPool::set_reply_deadline`] / [`ClientPool::pull_state`] —
//!   the reply deadline and the per-client STATE pull that the rejoin
//!   resync rides on.
//!
//! Deterministic fault *injection* lives in [`faults::FaultPool`], a
//! wrapper that imposes a seeded [`faults::FaultPlan`] on any inner
//! transport — because the injection is master-side and never decided
//! by wall clock, the same plan yields bit-identical trajectories on
//! every transport (the lossy-round extension of the buffer-and-commit
//! rule).

pub mod faults;
pub mod local_sim;

pub use faults::{FaultPlan, FaultPool};
pub use local_sim::ThreadedPool;

use std::time::Duration;

use crate::algorithms::{ClientMsg, ClientState, PPClientState};
use crate::linalg::vector;

/// Algorithm family of a client. The unified round exchange is
/// family-agnostic on the wire, so the **driver** checks that its pool
/// serves the family it expects (a FedNL server aggregating FedNL-PP
/// deltas as absolute quantities would be silently wrong math).
/// Mirrors `net::wire::{FAMILY_FEDNL, FAMILY_PP}` on the TCP transport.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientFamily {
    /// FedNL / FedNL-LS clients (Alg. 1–2): absolute ∇fᵢ, lᵢ.
    FedNL,
    /// FedNL-PP clients (Alg. 3): Δgᵢ, Δlᵢ deltas.
    PP,
}

/// One simulated client, driveable by any in-process pool.
///
/// Implemented by [`ClientState`] (FedNL / FedNL-LS, Alg. 1–2) and
/// [`PPClientState`] (FedNL-PP, Alg. 3). The message fields carry
/// absolute quantities for the former and deltas for the latter; the
/// pools do not care — the drivers check [`PoolClient::family`].
pub trait PoolClient: Send {
    fn id(&self) -> usize;
    fn dim(&self) -> usize;
    fn family(&self) -> ClientFamily;
    fn alpha(&self) -> f64;
    fn set_alpha(&mut self, alpha: f64);

    /// Execute one client round at iterate `x`.
    fn round(&mut self, x: &[f64], round: u64, need_loss: bool) -> ClientMsg;

    /// fᵢ(x) (line-search probes).
    fn eval_loss(&mut self, x: &[f64]) -> f64;

    /// (fᵢ(x), ∇fᵢ(x)) — the first-order reduction primitive.
    fn eval_loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>);

    /// Hᵢ⁰ = ∇²fᵢ(x⁰), returned packed (FedNL warm start).
    fn warm_start(&mut self, x: &[f64]) -> Vec<f64>;

    /// Current (lᵢ, gᵢ) pair (FedNL-PP bootstrap, Alg. 3 line 2).
    fn state(&self) -> (f64, Vec<f64>);
}

impl PoolClient for ClientState {
    fn id(&self) -> usize {
        self.id
    }

    fn dim(&self) -> usize {
        ClientState::dim(self)
    }

    fn family(&self) -> ClientFamily {
        ClientFamily::FedNL
    }

    fn alpha(&self) -> f64 {
        self.alpha
    }

    fn set_alpha(&mut self, alpha: f64) {
        self.alpha = alpha;
    }

    fn round(&mut self, x: &[f64], round: u64, need_loss: bool) -> ClientMsg {
        ClientState::round(self, x, round, need_loss)
    }

    fn eval_loss(&mut self, x: &[f64]) -> f64 {
        ClientState::eval_loss(self, x)
    }

    fn eval_loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        ClientState::eval_loss_grad(self, x)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<f64> {
        ClientState::warm_start(self, x)
    }

    fn state(&self) -> (f64, Vec<f64>) {
        panic!("STATE requested from a FedNL client (PP-only primitive)")
    }
}

impl PoolClient for PPClientState {
    fn id(&self) -> usize {
        self.id
    }

    fn dim(&self) -> usize {
        PPClientState::dim(self)
    }

    fn family(&self) -> ClientFamily {
        ClientFamily::PP
    }

    fn alpha(&self) -> f64 {
        self.alpha
    }

    fn set_alpha(&mut self, alpha: f64) {
        self.alpha = alpha;
    }

    fn round(&mut self, x: &[f64], round: u64, need_loss: bool) -> ClientMsg {
        self.participate(x, round, need_loss)
    }

    fn eval_loss(&mut self, x: &[f64]) -> f64 {
        self.oracle.loss(x)
    }

    fn eval_loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let mut g = vec![0.0; x.len()];
        let l = self.oracle.loss_grad(x, &mut g);
        (l, g)
    }

    fn warm_start(&mut self, _x: &[f64]) -> Vec<f64> {
        panic!("WARM_START requested from a FedNL-PP client (Alg. 3 initializes Hᵢ⁰ = 0)")
    }

    fn state(&self) -> (f64, Vec<f64>) {
        (self.l_i, self.g_i.clone())
    }
}

/// Master-side view of a set of FedNL clients.
pub trait ClientPool {
    fn n_clients(&self) -> usize;
    fn dim(&self) -> usize;

    /// Algorithm family every client of this pool serves (pools are
    /// family-homogeneous; enforced at construction). The round engine
    /// asserts this against the algorithm it is about to run.
    fn family(&self) -> ClientFamily;

    /// Short implementation name ("seq", "threaded", "remote") for
    /// logs and tests.
    fn kind_name(&self) -> &'static str {
        "pool"
    }

    /// Theoretical α of the clients' compressor class.
    fn default_alpha(&self) -> f64;

    /// Set the Hessian learning rate on every client.
    fn set_alpha(&mut self, alpha: f64);

    /// Dispatch one client round without waiting for replies. `subset`
    /// is the participating client ids (`None` = all clients). Exactly
    /// one reply per participant is later surfaced through [`drain`].
    ///
    /// [`drain`]: ClientPool::drain
    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    );

    /// Retrieve replies to the outstanding round: blocks until at least
    /// one is available, returns every reply that has arrived (in
    /// arrival order — **not** client order), and returns an empty
    /// batch once all participants have answered.
    fn drain(&mut self) -> Vec<ClientMsg>;

    /// Blocking shim: execute one round on every client and return the
    /// messages sorted by client id.
    fn round(
        &mut self,
        x: &[f64],
        round: u64,
        need_loss: bool,
    ) -> Vec<ClientMsg> {
        self.submit_round(x, None, round, need_loss);
        let mut msgs = Vec::with_capacity(self.n_clients());
        loop {
            let batch = self.drain();
            if batch.is_empty() {
                break;
            }
            msgs.extend(batch);
        }
        msgs.sort_by_key(|m| m.client_id);
        msgs
    }

    /// Average local loss at `x` (line-search probe). Reduced in
    /// ascending client id order on every transport.
    fn eval_loss(&mut self, x: &[f64]) -> f64;

    /// Average (f(x), ∇f(x)) reduction — the first-order baselines'
    /// round primitive (one d-vector per client per call). Reduced in
    /// ascending client id order on every transport.
    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>);

    /// Warm-start Hᵢ⁰ = ∇²fᵢ(x⁰); returns packed Hᵢ⁰ per client
    /// (client-id order).
    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>>;

    /// FedNL-PP bootstrap: every client's current (lᵢ, gᵢ) pair, in
    /// client-id order (Alg. 3 line 2).
    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)>;

    /// Cumulative transport-level bytes (up, down) if the transport
    /// meters them itself; in-process pools return `None` and the driver
    /// keeps the logical count.
    fn transport_bytes(&self) -> Option<(u64, u64)> {
        None
    }

    // --- fault tolerance / liveness (defaults = nothing ever fails) ---

    /// Called by the driver before it samples / submits round `round`:
    /// transports refresh liveness state here (poll re-registrations,
    /// advance a fault plan), so [`dead_clients`] and [`take_rejoined`]
    /// reflect this round.
    ///
    /// [`dead_clients`]: ClientPool::dead_clients
    /// [`take_rejoined`]: ClientPool::take_rejoined
    fn prepare_round(&mut self, _round: u64) {}

    /// Clients currently unable to participate (deregistered, or frozen
    /// by fault injection). Used by the FedNL-PP resampling policy.
    fn dead_clients(&self) -> Vec<u32> {
        Vec::new()
    }

    /// Participants of the round in flight whose reply is certified to
    /// never arrive. Drained by the round engine; returning an id here
    /// releases the engine from waiting on it.
    fn take_missing(&mut self) -> Vec<u32> {
        Vec::new()
    }

    /// Clients that came back since the last call (thawed by the fault
    /// plan, or re-registered over the wire). The FedNL-PP driver
    /// resyncs each via [`pull_state`].
    ///
    /// [`pull_state`]: ClientPool::pull_state
    fn take_rejoined(&mut self) -> Vec<u32> {
        Vec::new()
    }

    /// Per-client reply deadline for the round exchange. In-process
    /// transports ignore it; `RemotePool` deregisters clients whose
    /// reply misses it, and the fault injector uses it to convert
    /// injected delays beyond the deadline into deterministic drops.
    fn set_reply_deadline(&mut self, _deadline: Option<Duration>) {}

    /// Pull one client's current (lᵢ, gᵢ) (the FedNL-PP rejoin resync;
    /// same exchange as the STATE bootstrap, but for a single client).
    /// `None` means the client was lost again before answering — the
    /// driver skips the resync (the client is dead and unscheduled).
    fn pull_state(&mut self, _client: u32) -> Option<(f64, Vec<f64>)> {
        panic!("per-client state pull not supported by this transport")
    }
}

// --- shared sequential primitives (SeqPool / SlicePool) ---------------

fn submit_seq<C: PoolClient>(
    clients: &mut [C],
    queue: &mut Vec<ClientMsg>,
    x: &[f64],
    subset: Option<&[u32]>,
    round: u64,
    need_loss: bool,
) {
    assert!(queue.is_empty(), "previous round not fully drained");
    match subset {
        None => {
            for c in clients.iter_mut() {
                queue.push(c.round(x, round, need_loss));
            }
        }
        Some(s) => {
            for &ci in s {
                queue.push(clients[ci as usize].round(x, round, need_loss));
            }
        }
    }
}

fn eval_loss_seq<C: PoolClient>(clients: &mut [C], x: &[f64]) -> f64 {
    let n = clients.len() as f64;
    clients.iter_mut().map(|c| c.eval_loss(x)).sum::<f64>() / n
}

fn loss_grad_seq<C: PoolClient>(
    clients: &mut [C],
    x: &[f64],
) -> (f64, Vec<f64>) {
    let inv_n = 1.0 / clients.len() as f64;
    let mut g = vec![0.0; x.len()];
    let mut loss = 0.0;
    for c in clients.iter_mut() {
        let (l, gi) = c.eval_loss_grad(x);
        loss += l;
        vector::axpy(inv_n, &gi, &mut g);
    }
    (loss * inv_n, g)
}

/// Sequential in-process pool — the reference implementation. Generic
/// over the client family: `SeqPool<ClientState>` (the default) drives
/// FedNL / FedNL-LS, `SeqPool<PPClientState>` drives FedNL-PP.
pub struct SeqPool<C: PoolClient = ClientState> {
    pub clients: Vec<C>,
    queue: Vec<ClientMsg>,
}

impl<C: PoolClient> SeqPool<C> {
    pub fn new(clients: Vec<C>) -> Self {
        assert!(!clients.is_empty());
        Self { clients, queue: Vec::new() }
    }
}

impl<C: PoolClient> ClientPool for SeqPool<C> {
    fn n_clients(&self) -> usize {
        self.clients.len()
    }

    fn dim(&self) -> usize {
        self.clients[0].dim()
    }

    fn family(&self) -> ClientFamily {
        self.clients[0].family()
    }

    fn kind_name(&self) -> &'static str {
        "seq"
    }

    fn default_alpha(&self) -> f64 {
        self.clients[0].alpha()
    }

    fn set_alpha(&mut self, alpha: f64) {
        for c in &mut self.clients {
            c.set_alpha(alpha);
        }
    }

    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    ) {
        submit_seq(&mut self.clients, &mut self.queue, x, subset, round, need_loss);
    }

    fn drain(&mut self) -> Vec<ClientMsg> {
        std::mem::take(&mut self.queue)
    }

    fn eval_loss(&mut self, x: &[f64]) -> f64 {
        eval_loss_seq(&mut self.clients, x)
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        loss_grad_seq(&mut self.clients, x)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        self.clients.iter_mut().map(|c| c.warm_start(x)).collect()
    }

    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
        self.clients.iter().map(|c| c.state()).collect()
    }

    fn pull_state(&mut self, client: u32) -> Option<(f64, Vec<f64>)> {
        Some(self.clients[client as usize].state())
    }
}

/// Adapter: a mutable client slice as a sequential pool (borrowing
/// sibling of [`SeqPool`]; used by the `run_*` slice conveniences).
pub struct SlicePool<'a, C: PoolClient = ClientState> {
    clients: &'a mut [C],
    queue: Vec<ClientMsg>,
}

impl<'a, C: PoolClient> SlicePool<'a, C> {
    pub fn new(clients: &'a mut [C]) -> Self {
        assert!(!clients.is_empty());
        Self { clients, queue: Vec::new() }
    }
}

impl<C: PoolClient> ClientPool for SlicePool<'_, C> {
    fn n_clients(&self) -> usize {
        self.clients.len()
    }

    fn dim(&self) -> usize {
        self.clients[0].dim()
    }

    fn family(&self) -> ClientFamily {
        self.clients[0].family()
    }

    fn kind_name(&self) -> &'static str {
        "seq"
    }

    fn default_alpha(&self) -> f64 {
        self.clients[0].alpha()
    }

    fn set_alpha(&mut self, alpha: f64) {
        for c in self.clients.iter_mut() {
            c.set_alpha(alpha);
        }
    }

    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    ) {
        submit_seq(
            &mut *self.clients,
            &mut self.queue,
            x,
            subset,
            round,
            need_loss,
        );
    }

    fn drain(&mut self) -> Vec<ClientMsg> {
        std::mem::take(&mut self.queue)
    }

    fn eval_loss(&mut self, x: &[f64]) -> f64 {
        eval_loss_seq(&mut *self.clients, x)
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        loss_grad_seq(&mut *self.clients, x)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        self.clients.iter_mut().map(|c| c.warm_start(x)).collect()
    }

    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
        self.clients.iter().map(|c| c.state()).collect()
    }

    fn pull_state(&mut self, client: u32) -> Option<(f64, Vec<f64>)> {
        Some(self.clients[client as usize].state())
    }
}

//! Client/server state for the FedNL family.
//!
//! The client keeps its Hessian shift Hᵢᵏ in **packed upper-triangle
//! form** — compression, the shift update (line 6) and the Frobenius
//! distance lᵢᵏ (line 5) all live in packed coordinates, so nothing ever
//! materializes a second d×d matrix per client. The server keeps Hᵏ as a
//! dense symmetric matrix (the Newton solve wants it dense) and applies
//! the sparse compressed updates in O(k) (paper §5.6).

use crate::compressors::{Compressed, Compressor};
use crate::linalg::packed::PackedUpper;
use crate::linalg::{vector, Cholesky, Mat};
use crate::oracle::Oracle;

/// What a client sends the master each round — the **unified** message
/// of the whole algorithm family:
///
/// * FedNL / FedNL-LS (Alg. 1–2 line 5): `grad` = ∇fᵢ(xᵏ),
///   `l_i` = lᵢᵏ, `update` = Cᵢᵏ(∇²fᵢ(xᵏ) − Hᵢᵏ);
/// * FedNL-PP (Alg. 3 line 13): the same fields carry **deltas** of the
///   participant's server-tracked state — `grad` = Δgᵢ, `l_i` = Δlᵢ —
///   plus the compressed shift update.
///
/// One message type means one wire codec (`net::wire::encode_client_msg`)
/// and one streaming pool API for all three algorithms.
#[derive(Debug, Clone)]
pub struct ClientMsg {
    pub client_id: usize,
    /// ∇fᵢ(xᵏ) (FedNL) or Δgᵢ (FedNL-PP), dense d-vector.
    pub grad: Vec<f64>,
    /// Sᵢᵏ = Cᵢᵏ(∇²fᵢ(xᵏ) − Hᵢᵏ).
    pub update: Compressed,
    /// lᵢᵏ = ‖Hᵢᵏ − ∇²fᵢ(xᵏ)‖_F (FedNL) or Δlᵢ (FedNL-PP).
    pub l_i: f64,
    /// fᵢ(xᵏ) when the server tracks loss / runs line search.
    pub loss: Option<f64>,
}

impl ClientMsg {
    /// Exact framed size of this message on the TCP wire: frame header
    /// (payload length + tag) + client id + gradient (count + f64s) +
    /// lᵢ + loss flag (+ loss) + the compressed update. Kept
    /// byte-for-byte in sync with `net::wire::encode_client_msg` (a
    /// codec test asserts the agreement), so the in-process pools'
    /// logical byte accounting matches the TCP transport's metered
    /// counts.
    pub fn wire_bytes(&self) -> u64 {
        crate::net::FRAME_HEADER_BYTES
            + 4 // client id
            + 4 // gradient length
            + self.grad.len() as u64 * 8
            + 8 // lᵢ
            + 1 // loss presence flag
            + if self.loss.is_some() { 8 } else { 0 }
            + self.update.wire_bytes()
    }
}

/// Per-client FedNL state: local oracle + Hessian shift + compressor.
pub struct ClientState {
    pub id: usize,
    pub oracle: Box<dyn Oracle>,
    pub compressor: Box<dyn Compressor>,
    /// Hᵢᵏ in packed upper-triangle coordinates.
    pub h_shift: Vec<f64>,
    /// Hessian learning rate α (same value server-side).
    pub alpha: f64,
    pub pu: PackedUpper,
    // Reused round buffers (no allocation in the loop, §5.13):
    hess: Mat,
    hess_packed: Vec<f64>,
    diff: Vec<f64>,
    grad_buf: Vec<f64>,
}

impl ClientState {
    /// `alpha = None` → theoretical α from the compressor class.
    pub fn new(
        id: usize,
        oracle: Box<dyn Oracle>,
        compressor: Box<dyn Compressor>,
        alpha: Option<f64>,
    ) -> Self {
        let d = oracle.dim();
        let pu = PackedUpper::new(d);
        let n = pu.len();
        let alpha = alpha.unwrap_or_else(|| compressor.kind(n).alpha());
        Self {
            id,
            oracle,
            compressor,
            h_shift: vec![0.0; n],
            alpha,
            pu,
            hess: Mat::zeros(d, d),
            hess_packed: vec![0.0; n],
            diff: vec![0.0; n],
            grad_buf: vec![0.0; d],
        }
    }

    pub fn dim(&self) -> usize {
        self.grad_buf.len()
    }

    /// Initialize Hᵢ⁰ = ∇²fᵢ(x⁰) (the FedNL paper's warm start; the
    /// cold start Hᵢ⁰ = 0 also satisfies the theory but Option 1 then
    /// takes −(1/μ)∇f first steps). Returns the packed Hᵢ⁰ so the
    /// server can form H⁰ = (1/n)ΣHᵢ⁰.
    pub fn warm_start(&mut self, x0: &[f64]) -> Vec<f64> {
        self.oracle.hessian(x0, &mut self.hess);
        self.pu.pack(&self.hess, &mut self.hess_packed);
        self.h_shift.copy_from_slice(&self.hess_packed);
        self.hess_packed.clone()
    }

    /// One FedNL client round at iterate `x` (Alg. 1 lines 4–6).
    /// `need_loss` additionally returns fᵢ(xᵏ) (FedNL-LS line 5).
    pub fn round(&mut self, x: &[f64], round: u64, need_loss: bool) -> ClientMsg {
        let loss = self.oracle.loss_grad_hessian(
            x,
            &mut self.grad_buf,
            &mut self.hess,
        );
        self.pu.pack(&self.hess, &mut self.hess_packed);
        // diff = ∇²fᵢ(xᵏ) − Hᵢᵏ (packed).
        vector::sub(&self.hess_packed, &self.h_shift, &mut self.diff);
        // lᵢᵏ before the shift update (line 5).
        let l_i = self.pu.frobenius_sq_packed(&self.diff).sqrt();
        let update = self.compressor.compress(&self.pu, &self.diff, round);
        // Hᵢᵏ⁺¹ = Hᵢᵏ + α Sᵢᵏ, sparse in packed coords (line 6).
        let a = self.alpha * update.scale;
        for (v, idx) in update.values.iter().zip(update.indices()) {
            self.h_shift[idx as usize] += a * v;
        }
        ClientMsg {
            client_id: self.id,
            grad: self.grad_buf.clone(),
            update,
            l_i,
            loss: if need_loss { Some(loss) } else { None },
        }
    }

    /// Loss-only evaluation (line-search probes).
    pub fn eval_loss(&mut self, x: &[f64]) -> f64 {
        self.oracle.loss(x)
    }

    /// First-order evaluation (baseline solvers' round primitive).
    pub fn eval_loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let l = self.oracle.loss_grad(x, &mut self.grad_buf);
        (l, self.grad_buf.clone())
    }
}

/// Master state (Alg. 1 lines 8–11).
pub struct ServerState {
    pub d: usize,
    pub n_clients: usize,
    /// Hᵏ = (1/n) Σ Hᵢᵏ, dense symmetric.
    pub h: Mat,
    /// lᵏ = (1/n) Σ lᵢᵏ.
    pub l: f64,
    pub alpha: f64,
    pub pu: PackedUpper,
    /// Current iterate xᵏ.
    pub x: Vec<f64>,
    // Round scratch:
    grad_acc: Vec<f64>,
    sys: Mat,
    // Incremental-aggregation accumulators (begin_round/apply_msg/
    // finish_round):
    l_acc: f64,
    loss_acc: f64,
    have_loss: bool,
}

impl ServerState {
    pub fn new(d: usize, n_clients: usize, alpha: f64, x0: Vec<f64>) -> Self {
        assert_eq!(x0.len(), d);
        Self {
            d,
            n_clients,
            h: Mat::zeros(d, d),
            l: 0.0,
            alpha,
            pu: PackedUpper::new(d),
            x: x0,
            grad_acc: vec![0.0; d],
            sys: Mat::zeros(d, d),
            l_acc: 0.0,
            loss_acc: 0.0,
            have_loss: true,
        }
    }

    /// Install H⁰ = (1/n) Σ Hᵢ⁰ from warm-started clients.
    pub fn init_h_from_packed(&mut self, packed: &[Vec<f64>]) {
        let inv_n = 1.0 / packed.len() as f64;
        let mut acc = vec![0.0; self.pu.len()];
        for p in packed {
            vector::axpy(inv_n, p, &mut acc);
        }
        self.pu.unpack(&acc, &mut self.h);
    }

    /// Reset the round accumulators before streaming messages into
    /// [`ServerState::apply_msg`].
    pub fn begin_round(&mut self) {
        vector::fill_zero(&mut self.grad_acc);
        self.l_acc = 0.0;
        self.loss_acc = 0.0;
        self.have_loss = true;
    }

    /// Fold one client's message into the round state: gradient partial
    /// sum, lᵢ / loss accumulators, and the sparse Hessian update
    /// Hᵏ ← Hᵏ + (α/n)·Sᵢᵏ (paper §5.6), applied **as the message
    /// commits** so aggregation overlaps with the remaining clients'
    /// compute / network latency. The caller commits messages in a
    /// deterministic order (buffer-and-commit, ascending client id) so
    /// the f64 reduction is bit-identical to the blocking aggregation.
    pub fn apply_msg(&mut self, m: &ClientMsg) {
        let inv_n = 1.0 / self.n_clients as f64;
        vector::axpy(inv_n, &m.grad, &mut self.grad_acc);
        self.l_acc += m.l_i;
        match m.loss {
            Some(l) => self.loss_acc += l,
            None => self.have_loss = false,
        }
        self.pu.apply_sparse(
            &mut self.h,
            self.alpha * m.update.scale * inv_n,
            &m.update.indices(),
            &m.update.values,
        );
    }

    /// Close the round (Alg. 1 lines 9–10): install lᵏ and return
    /// (∇f(xᵏ), mean loss if every message carried one). `committed`
    /// is how many messages actually committed this round: under a
    /// quorum policy with missing clients the first-order reductions
    /// are rescaled to means over the survivors (∇f by n/committed on
    /// top of the per-message 1/n weights; lᵏ and the loss divided by
    /// the committed count). The full-round path (`committed == n`)
    /// keeps the exact pre-fault expressions so trajectories stay
    /// bitwise unchanged.
    pub fn finish_round(&mut self, committed: usize) -> (Vec<f64>, Option<f64>) {
        assert!(
            committed >= 1 && committed <= self.n_clients,
            "finish_round: committed {committed} out of 1..={}",
            self.n_clients
        );
        let inv_n = 1.0 / self.n_clients as f64;
        let mut grad = self.grad_acc.clone();
        let loss;
        if committed == self.n_clients {
            self.l = self.l_acc * inv_n;
            loss = if self.have_loss {
                Some(self.loss_acc * inv_n)
            } else {
                None
            };
        } else {
            let c = committed as f64;
            vector::scale(self.n_clients as f64 / c, &mut grad);
            self.l = self.l_acc / c;
            loss = if self.have_loss {
                Some(self.loss_acc / c)
            } else {
                None
            };
        }
        (grad, loss)
    }

    /// Newton direction −[system]⁻¹ g under the given rule
    /// (Alg. 1 line 11). Falls back to growing diagonal jitter if the
    /// factorization fails numerically.
    pub fn newton_direction(
        &mut self,
        g: &[f64],
        rule: super::UpdateRule,
    ) -> Vec<f64> {
        match rule {
            super::UpdateRule::LkShift => {
                self.sys.as_mut_slice().copy_from_slice(self.h.as_slice());
                let mut shift = self.l;
                for _ in 0..60 {
                    if let Some(ch) = Cholesky::factor(&self.sys, shift) {
                        let mut dir = ch.solve_vec(g);
                        vector::scale(-1.0, &mut dir);
                        return dir;
                    }
                    shift = (shift * 2.0).max(1e-12);
                }
                // Pathological: fall back to −g.
                let mut dir = g.to_vec();
                vector::scale(-1.0, &mut dir);
                dir
            }
            super::UpdateRule::ProjectMu(mu) => {
                let proj = crate::linalg::eigen::project_psd_mu(&self.h, mu);
                match Cholesky::factor(&proj, 0.0) {
                    Some(ch) => {
                        let mut dir = ch.solve_vec(g);
                        vector::scale(-1.0, &mut dir);
                        dir
                    }
                    None => {
                        let mut dir = g.to_vec();
                        vector::scale(-1.0, &mut dir);
                        dir
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::Identity;
    use crate::oracle::QuadraticOracle;

    fn quad_client(id: usize) -> ClientState {
        let q = Mat::from_rows(&[&[2.0, 0.5], &[0.5, 1.5]]);
        let oracle = QuadraticOracle::new(q, vec![1.0, -1.0]);
        ClientState::new(id, Box::new(oracle), Box::new(Identity), None)
    }

    #[test]
    fn identity_alpha_is_one() {
        let c = quad_client(0);
        assert_eq!(c.alpha, 1.0);
    }

    #[test]
    fn client_learns_exact_hessian_in_one_round_with_identity() {
        let mut c = quad_client(0);
        let msg = c.round(&[0.0, 0.0], 0, false);
        // l⁰ = ‖0 − Q‖_F > 0; after the update Hᵢ¹ = Q exactly.
        assert!(msg.l_i > 0.0);
        let msg2 = c.round(&[0.0, 0.0], 1, false);
        assert!(msg2.l_i < 1e-14, "l after identity update: {}", msg2.l_i);
    }

    #[test]
    fn server_aggregate_and_newton() {
        let mut s = ServerState::new(2, 2, 1.0, vec![0.0, 0.0]);
        let mut c0 = quad_client(0);
        let mut c1 = quad_client(1);
        let msgs =
            vec![c0.round(&s.x.clone(), 0, true), c1.round(&s.x.clone(), 0, true)];
        // The incremental commit path, exactly as the round engine
        // drives it.
        s.begin_round();
        for m in &msgs {
            s.apply_msg(m);
        }
        let (g, loss) = s.finish_round(2);
        assert!(loss.is_some());
        // Both clients identical → ∇f = ∇f₀ = Q·0 − b = −b = [−1, 1].
        assert!((g[0] + 1.0).abs() < 1e-14);
        assert!((g[1] - 1.0).abs() < 1e-14);
        // After identity aggregation H = Q; direction solves Newton.
        let dir = s.newton_direction(&g, super::super::UpdateRule::LkShift);
        assert_eq!(dir.len(), 2);
        // With l⁰ > 0 the step is damped but still a descent direction.
        assert!(vector::dot(&dir, &g) < 0.0);
    }

    #[test]
    fn finish_round_rescales_to_committed_count() {
        // 3 clients expected, only 2 commit: ∇f and lᵏ must become
        // means over the survivors, not thirds.
        let mut s = ServerState::new(2, 3, 1.0, vec![0.0, 0.0]);
        let mut c0 = quad_client(0);
        let mut c1 = quad_client(1);
        let m0 = c0.round(&[0.0, 0.0], 0, true);
        let m1 = c1.round(&[0.0, 0.0], 0, true);
        s.begin_round();
        s.apply_msg(&m0);
        s.apply_msg(&m1);
        let (g, loss) = s.finish_round(2);
        // Identical clients → the survivor mean equals one client's
        // values: ∇f = −b = [−1, 1].
        assert!((g[0] + 1.0).abs() < 1e-12, "g[0]={}", g[0]);
        assert!((g[1] - 1.0).abs() < 1e-12, "g[1]={}", g[1]);
        let expected_l = (m0.l_i + m1.l_i) / 2.0;
        assert!((s.l - expected_l).abs() < 1e-12);
        let expected_f = (m0.loss.unwrap() + m1.loss.unwrap()) / 2.0;
        assert!((loss.unwrap() - expected_f).abs() < 1e-12);
    }

    #[test]
    fn wire_bytes_positive() {
        let mut c = quad_client(0);
        let msg = c.round(&[0.1, 0.2], 0, false);
        assert!(msg.wire_bytes() > 16);
    }
}

//! Property tests for the reproducible summation layer
//! (`linalg::reduce`): the exactness/associativity contract the whole
//! coordination stack now rests on. Random shuffles and random binary
//! groupings of random f64 sets must produce **bit-identical** rounded
//! sums; exponent extremes, signed zeros and non-finite inputs must
//! resolve loudly and deterministically, never silently wrong.

use fednl::linalg::reduce::{RepAcc, RepVec, LIMBS};
use fednl::linalg::simd;
use fednl::rng::{Pcg64, Rng};

fn sum_seq(xs: &[f64]) -> u64 {
    let mut a = RepAcc::new();
    for &x in xs {
        a.accumulate(x);
    }
    a.round().to_bits()
}

/// Random f64 with a wide exponent spread (±2^-e .. ±2^e scaled
/// gaussians plus occasional subnormals and exact powers of two).
fn wild(rng: &mut Pcg64, span: i32) -> f64 {
    let e = (rng.next_u64() % (2 * span as u64 + 1)) as i32 - span;
    match rng.next_u64() % 8 {
        0 => 2.0f64.powi(e),                      // exact power of two
        1 => -(2.0f64.powi(e)),
        2 => f64::MIN_POSITIVE * (rng.next_f64() + 1e-3), // subnormal-ish
        _ => rng.next_gaussian() * 2.0f64.powi(e),
    }
}

/// Fold `xs` with a random binary grouping: split at a random point,
/// recurse on both halves, merge. Every grouping must agree with the
/// flat sequential fold, bit for bit.
fn sum_random_tree(rng: &mut Pcg64, xs: &[f64]) -> RepAcc {
    if xs.len() <= 1 {
        let mut a = RepAcc::new();
        if let Some(&x) = xs.first() {
            a.accumulate(x);
        }
        return a;
    }
    let cut = 1 + (rng.next_u64() % (xs.len() as u64 - 1)) as usize;
    let mut left = sum_random_tree(rng, &xs[..cut]);
    let right = sum_random_tree(rng, &xs[cut..]);
    left.merge(right);
    left
}

#[test]
fn prop_shuffles_and_groupings_are_bit_identical() {
    let mut rng = Pcg64::seed_from_u64(0xD3_CA_FE);
    for case in 0..120 {
        let span = 20 + (case % 5) * 60; // up to ±2^260 spreads
        let n = 1 + (rng.next_u64() % 40) as usize;
        let xs: Vec<f64> =
            (0..n).map(|_| wild(&mut rng, span as i32)).collect();
        let want = sum_seq(&xs);
        // Random shuffles.
        let mut perm = xs.clone();
        for _ in 0..4 {
            for i in (1..perm.len()).rev() {
                let j = (rng.next_u64() % (i as u64 + 1)) as usize;
                perm.swap(i, j);
            }
            assert_eq!(sum_seq(&perm), want, "case {case}: shuffle");
        }
        // Random binary merge trees (shard-shaped groupings).
        for _ in 0..4 {
            let mut tree = sum_random_tree(&mut rng, &perm);
            assert_eq!(
                tree.round().to_bits(),
                want,
                "case {case}: grouping"
            );
        }
        // The dispatched bulk kernel and its scalar fallback agree
        // with the one-at-a-time path exactly.
        let mut bulk = RepAcc::new();
        bulk.accumulate_slice(&xs);
        assert_eq!(bulk.round().to_bits(), want, "case {case}: simd");
        let mut bulk = RepAcc::new();
        bulk.accumulate_slice_scalar(&xs);
        assert_eq!(bulk.round().to_bits(), want, "case {case}: scalar");
        // Every available pinned tier scatters the exact same limbs
        // (not merely the same rounded sum) — the raw kernel contract
        // behind the dispatched path above.
        let mut want_limbs = None;
        for which in simd::Isa::ALL {
            if !simd::isa_available(which) {
                continue; // CI's forced-ISA legs cover absent tiers
            }
            let mut limbs = [0i64; LIMBS];
            let flags =
                simd::binned_accumulate_on(which, &mut limbs, &xs);
            match &want_limbs {
                None => want_limbs = Some((limbs, flags)),
                Some((wl, wf)) => {
                    assert_eq!(
                        &limbs,
                        wl,
                        "case {case}: {} limbs diverge",
                        which.name()
                    );
                    assert_eq!(
                        flags,
                        *wf,
                        "case {case}: {} flags diverge",
                        which.name()
                    );
                }
            }
        }
    }
}

#[test]
fn prop_overflow_underflow_extremes() {
    // Exact sums beyond the f64 range round to the correct infinity,
    // and cancelling back into range recovers the exact remainder —
    // the accumulator is never sticky-saturated.
    let mut rng = Pcg64::seed_from_u64(0xFFF);
    for _ in 0..50 {
        let k = 2 + (rng.next_u64() % 6) as usize;
        let xs: Vec<f64> = (0..k).map(|_| f64::MAX).collect();
        assert_eq!(sum_seq(&xs), f64::INFINITY.to_bits());
        let neg: Vec<f64> = xs.iter().map(|v| -v).collect();
        assert_eq!(sum_seq(&neg), f64::NEG_INFINITY.to_bits());
        // Cancel all but one copy, plus subnormal dust that must
        // survive exactly.
        let dust = 5e-324 * ((rng.next_u64() % 7) as f64);
        let mut both = Vec::new();
        both.extend_from_slice(&xs);
        both.push(dust);
        both.extend(neg.iter().take(k - 1));
        let want = (f64::MAX + 0.0).to_bits(); // MAX + dust rounds to MAX
        if dust == 0.0 {
            assert_eq!(sum_seq(&both), want);
        } else {
            assert_eq!(sum_seq(&both), want, "dust {dust:e}");
        }
        // Pure subnormal arithmetic stays exact.
        let tiny: Vec<f64> = (0..9).map(|_| 5e-324).collect();
        assert_eq!(sum_seq(&tiny), (5e-324 * 9.0).to_bits());
    }
}

#[test]
fn prop_signed_zeros_and_specials_fail_loudly_never_wrong() {
    // Signed zeros vanish (documented: the zero sum is +0.0).
    assert_eq!(sum_seq(&[-0.0, 0.0, -0.0]), 0.0f64.to_bits());
    // NaN poisons every grouping; mixed infinities are NaN; a
    // single-signed infinity wins over any finite mass — all
    // permutation-invariant by construction.
    let mut rng = Pcg64::seed_from_u64(0xBAD);
    let base: Vec<f64> = (0..10).map(|_| wild(&mut rng, 50)).collect();
    for special in [
        vec![f64::NAN],
        vec![f64::INFINITY, f64::NEG_INFINITY],
        vec![f64::NAN, f64::INFINITY],
    ] {
        let mut xs = base.clone();
        xs.extend(&special);
        for _ in 0..4 {
            for i in (1..xs.len()).rev() {
                let j = (rng.next_u64() % (i as u64 + 1)) as usize;
                xs.swap(i, j);
            }
            assert!(
                f64::from_bits(sum_seq(&xs)).is_nan(),
                "{special:?}"
            );
        }
    }
    let mut xs = base.clone();
    xs.push(f64::INFINITY);
    assert_eq!(sum_seq(&xs), f64::INFINITY.to_bits());
    let mut xs = base;
    xs.push(f64::NEG_INFINITY);
    assert_eq!(sum_seq(&xs), f64::NEG_INFINITY.to_bits());
}

#[test]
fn prop_matches_exact_integer_reference() {
    // Terms that are exact multiples of 2^-48 with bounded magnitude:
    // the true sum fits in i128 units, and Rust's i128→f64 cast is
    // round-to-nearest-even — an independent oracle for round().
    let mut rng = Pcg64::seed_from_u64(0x1234);
    for case in 0..300 {
        let n = 1 + (rng.next_u64() % 200) as usize;
        let mut exact: i128 = 0;
        let xs: Vec<f64> = (0..n)
            .map(|_| {
                let m = (rng.next_u64() % (1 << 52)) as i64
                    - (1i64 << 51);
                exact += m as i128;
                m as f64 / (1u64 << 48) as f64 // exact in f64
            })
            .collect();
        let want =
            (exact as f64 / (1u64 << 48) as f64).to_bits();
        assert_eq!(sum_seq(&xs), want, "case {case} n={n}");
    }
}

#[test]
fn prop_repvec_partition_invariance() {
    // The gradient-fold shape: p vectors split into arbitrary
    // contiguous shard partitions, each folded locally, partials
    // merged — always equal to the flat fold (what makes SHARD_SUM
    // safe for any S).
    let mut rng = Pcg64::seed_from_u64(0x9E_C7);
    for case in 0..40 {
        let d = 1 + (rng.next_u64() % 24) as usize;
        let p = 2 + (rng.next_u64() % 12) as usize;
        let rows: Vec<Vec<f64>> = (0..p)
            .map(|_| (0..d).map(|_| wild(&mut rng, 100)).collect())
            .collect();
        let mut flat = RepVec::new(d);
        for r in &rows {
            flat.accumulate(r);
        }
        let want: Vec<u64> =
            flat.round_vec().iter().map(|v| v.to_bits()).collect();
        for _ in 0..4 {
            // Random partition into up to 4 contiguous shards.
            let mut cuts = vec![0usize, p];
            for _ in 0..(rng.next_u64() % 3) {
                cuts.push((rng.next_u64() % (p as u64 + 1)) as usize);
            }
            cuts.sort_unstable();
            let mut merged = RepVec::new(0);
            for w in cuts.windows(2) {
                let mut part = RepVec::new(d);
                for r in &rows[w[0]..w[1]] {
                    part.accumulate(r);
                }
                merged.merge(part);
            }
            let got: Vec<u64> = merged
                .round_vec()
                .iter()
                .map(|v| v.to_bits())
                .collect();
            assert_eq!(got, want, "case {case}");
        }
    }
}

//! Explicit little-endian byte buffers (paper component `copylocal` +
//! the client→master "byte buffers" of §5.13/v36).
//!
//! The wire format (net::wire) and the compressed-update serialization
//! are built on these. Fixed-width 32-bit indices are used throughout —
//! the paper found fixed-width transfers beat varint encodings (§7).

/// Growable write buffer with explicit little-endian primitives.
#[derive(Debug, Default, Clone)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap) }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    pub fn clear(&mut self) {
        self.buf.clear();
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Bulk-write a f64 slice (hot path: gradient / Hessian payloads).
    pub fn put_f64_slice(&mut self, vs: &[f64]) {
        self.buf.reserve(vs.len() * 8);
        for &v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Bulk-write u32 indices (compressor index streams).
    pub fn put_u32_slice(&mut self, vs: &[u32]) {
        self.buf.reserve(vs.len() * 4);
        for &v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn put_bytes(&mut self, vs: &[u8]) {
        self.buf.extend_from_slice(vs);
    }
}

/// Zero-copy reader over a byte slice; all reads are checked.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        if self.remaining() < n {
            anyhow::bail!(
                "byte reader underrun: need {n}, have {}",
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f64_vec(&mut self, n: usize) -> anyhow::Result<Vec<f64>> {
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn get_u32_vec(&mut self, n: usize) -> anyhow::Result<Vec<u32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn get_bytes(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        self.take(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEADBEEF);
        w.put_u64(u64::MAX - 3);
        w.put_f64(-1.5e300);
        let mut r = ByteReader::new(w.as_slice());
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.get_f64().unwrap(), -1.5e300);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn roundtrip_slices() {
        let mut w = ByteWriter::new();
        let fs = [1.0, -2.0, f64::MIN_POSITIVE, 0.0];
        let us = [0u32, 42, u32::MAX];
        w.put_f64_slice(&fs);
        w.put_u32_slice(&us);
        let mut r = ByteReader::new(w.as_slice());
        assert_eq!(r.get_f64_vec(4).unwrap(), fs);
        assert_eq!(r.get_u32_vec(3).unwrap(), us);
    }

    #[test]
    fn underrun_is_error() {
        let w = ByteWriter::new();
        let mut r = ByteReader::new(w.as_slice());
        assert!(r.get_u32().is_err());
    }

    #[test]
    fn nan_roundtrip_bitexact() {
        let mut w = ByteWriter::new();
        let weird = f64::from_bits(0x7ff8_0000_dead_beef);
        w.put_f64(weird);
        let mut r = ByteReader::new(w.as_slice());
        assert_eq!(r.get_f64().unwrap().to_bits(), weird.to_bits());
    }
}

//! RandSeqK — the paper's NEW cache-aware RandK variant (Appendix C).
//!
//! Only the start index s ~ U[0, n) is random; the selected set is the
//! contiguous wrap-around window {s, s+1, …, s+k−1} (mod n). Each
//! coordinate is still covered by exactly k of the n possible windows,
//! so P[Z_ij = 1] = k/n — the marginal inclusion probability matches
//! RandK exactly, and by App. C.1's Observations 1-2 (the analysis never
//! uses joint independence) unbiasedness and the ω = n/k − 1 variance
//! bound carry over verbatim.
//!
//! Practical wins reproduced here (App. C.4):
//! * 1 PRG invocation instead of k;
//! * the window is two `memcpy`-able slices (kb/L + 2 cache-line
//!   transactions instead of k random ones);
//! * the wire carries a single u32 start index.

use super::{Compressed, Compressor, CompressorKind, IndexPayload};
use crate::linalg::packed::PackedUpper;
use crate::linalg::simd;
use crate::rng::{Pcg64, Rng};

/// Sequential-window random sparsifier.
#[derive(Debug, Clone)]
pub struct RandSeqK {
    k: usize,
    seed_base: u64,
}

impl RandSeqK {
    pub fn new(k: usize, seed_base: u64) -> Self {
        assert!(k > 0);
        Self { k, seed_base }
    }

    fn start_for_round(&self, n: usize, round: u64) -> u32 {
        let seed = crate::rng::pcg::splitmix64(
            self.seed_base ^ round.wrapping_mul(0xA24B_AED4),
        );
        let mut rng = Pcg64::seed_from_u64(seed);
        rng.next_below(n as u64) as u32 // the single PRG call
    }
}

impl Compressor for RandSeqK {
    fn name(&self) -> String {
        format!("RandSeqK[k={}]", self.k)
    }

    fn kind(&self, n: usize) -> CompressorKind {
        CompressorKind::Contractive { delta: self.k.min(n) as f64 / n as f64 }
    }

    fn compress(
        &mut self,
        _pu: &PackedUpper,
        src: &[f64],
        round: u64,
    ) -> Compressed {
        let n = src.len();
        let k = self.k.min(n);
        let start = self.start_for_round(n, round) as usize;
        // Contiguous gather through the kernel layer: at most two slice
        // copies (cache-aware, App. C.4).
        let mut values = Vec::with_capacity(k);
        simd::gather_window(src, start, k, &mut values);
        Compressed {
            payload: IndexPayload::SeqStart { start: start as u32, k: k as u32 },
            values,
            scale: 1.0,
            encoding: super::ValueEncoding::F64,
            n: n as u32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{distortion_sq, weighted_norm_sq};

    fn packed_src(d: usize, seed: u64) -> (PackedUpper, Vec<f64>) {
        let pu = PackedUpper::new(d);
        let mut rng = Pcg64::seed_from_u64(seed);
        let src = (0..pu.len()).map(|_| rng.next_gaussian()).collect();
        (pu, src)
    }

    #[test]
    fn window_wraps_correctly() {
        let (pu, src) = packed_src(4, 1); // n = 10
        let mut c = RandSeqK::new(7, 0);
        for round in 0..50 {
            let out = c.compress(&pu, &src, round);
            let idx = out.indices();
            assert_eq!(idx.len(), 7);
            // Consecutive mod n.
            for w in idx.windows(2) {
                assert_eq!((w[0] + 1) % out.n, w[1]);
            }
            for (v, i) in out.values.iter().zip(&idx) {
                assert_eq!(*v, src[*i as usize]);
            }
        }
    }

    #[test]
    fn marginal_inclusion_is_k_over_n() {
        let (pu, src) = packed_src(8, 2);
        let n = src.len(); // 36
        let k = 9;
        let mut counts = vec![0u32; n];
        let mut c = RandSeqK::new(k, 7);
        let trials = 6000;
        for r in 0..trials {
            for i in c.compress(&pu, &src, r).indices() {
                counts[i as usize] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64;
        for (i, &cnt) in counts.iter().enumerate() {
            assert!(
                (cnt as f64 - expect).abs() < expect * 0.2,
                "coord {i}: {cnt} vs {expect}"
            );
        }
    }

    #[test]
    fn same_variance_as_randk_in_expectation() {
        // E‖C(x) − x‖² = (1 − k/n)‖x‖² — identical to RandK (App. C).
        let (pu, src) = packed_src(7, 3);
        let n = src.len();
        let k = 7;
        let mut c = RandSeqK::new(k, 13);
        let trials = 6000;
        let mut acc = 0.0;
        for r in 0..trials {
            let out = c.compress(&pu, &src, r);
            acc += distortion_sq(&pu, &src, &out);
        }
        let mean = acc / trials as f64;
        let expect = (1.0 - k as f64 / n as f64) * weighted_norm_sq(&pu, &src);
        assert!((mean - expect).abs() < 0.06 * expect, "{mean} vs {expect}");
    }

    #[test]
    fn wire_carries_single_start_index() {
        let (pu, src) = packed_src(9, 4);
        let mut c = RandSeqK::new(10, 3);
        let out = c.compress(&pu, &src, 0);
        assert_eq!(
            out.wire_bytes(),
            10 * 8 + 8 + crate::compressors::CODEC_OVERHEAD_BYTES
        );
        assert!(matches!(out.payload, IndexPayload::SeqStart { .. }));
    }

    #[test]
    fn deterministic_per_round() {
        let (pu, src) = packed_src(6, 5);
        let mut c1 = RandSeqK::new(5, 99);
        let mut c2 = RandSeqK::new(5, 99);
        let a = c1.compress(&pu, &src, 3);
        let b = c2.compress(&pu, &src, 3);
        assert_eq!(a.indices(), b.indices());
        assert_eq!(a.values, b.values);
    }
}

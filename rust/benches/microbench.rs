//! Micro-benchmarks of the hot kernels (harness = false; self-contained
//! criterion-style statistics via `fednl::utils::TimerStats`).
//!
//! Run: `cargo bench --bench microbench [-- filter]`

use fednl::compressors::{by_name, ALL_NAMES};
use fednl::data::ClientShard;
use fednl::linalg::packed::PackedUpper;
use fednl::linalg::{cholesky, gauss, iterative, Mat};
use fednl::oracle::{LogisticOracle, Oracle};
use fednl::rng::{Pcg64, Rng};
use fednl::utils::TimerStats;

fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) {
    for _ in 0..warmup {
        f();
    }
    let mut st = TimerStats::new();
    for _ in 0..iters {
        st.time(&mut f);
    }
    println!(
        "{name:<46} min {:>10.3?}µs  median {:>10.3?}µs  mean {:>10.3?}µs ±{:>8.3?}",
        st.min() * 1e6,
        st.median() * 1e6,
        st.mean() * 1e6,
        st.stddev() * 1e6
    );
}

fn random_shard(d: usize, n: usize, seed: u64) -> ClientShard {
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut at = Mat::zeros(n, d);
    for r in 0..n {
        let lab = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        for c in 0..d - 1 {
            at.set(r, c, lab * rng.next_gaussian());
        }
        at.set(r, d - 1, lab);
    }
    ClientShard { client_id: 0, at }
}

fn random_spd(d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    let b = Mat::from_vec(d, d, (0..d * d).map(|_| rng.next_gaussian()).collect());
    let mut a = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            let mut s = 0.0;
            for k in 0..d {
                s += b.get(k, i) * b.get(k, j);
            }
            a.set(i, j, s / d as f64);
        }
    }
    a.add_diag(1.0);
    a
}

fn main() {
    // cargo bench appends `--bench`; ignore flag-like args.
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    let want = |n: &str| filter.is_empty() || n.contains(&filter);
    println!("== microbench (W8A client shape d=301, n_i=350) ==");

    let d = 301;
    let n_i = 350;
    let shard = random_shard(d, n_i, 1);

    if want("oracle") {
        let mut oracle = LogisticOracle::new(shard.clone(), 1e-3);
        let x = vec![0.05; d];
        let mut g = vec![0.0; d];
        let mut h = Mat::zeros(d, d);
        bench("oracle/fused loss+grad+hessian", 3, 20, || {
            let _ = oracle.loss_grad_hessian(&x, &mut g, &mut h);
        });
        bench("oracle/loss+grad only", 3, 50, || {
            let _ = oracle.loss_grad(&x, &mut g);
        });
        // §5.7 ablation-style: three separate evaluations recompute the
        // margins three times.
        bench("oracle/separate loss,grad,hess (3x margins)", 3, 20, || {
            let _ = oracle.loss(&x);
            oracle.grad(&x, &mut g);
            oracle.hessian(&x, &mut h);
        });
    }

    if want("solve") {
        let a = random_spd(d, 2);
        let mut rng = Pcg64::seed_from_u64(3);
        let b: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        bench("solve/cholesky (factor+subst)", 2, 20, || {
            let _ = cholesky::solve_spd(&a, 0.0, &b).unwrap();
        });
        bench("solve/gauss elimination", 2, 10, || {
            let _ = gauss::solve_gauss(&a, &b).unwrap();
        });
        bench("solve/conjugate gradient 1e-10", 2, 10, || {
            let _ = iterative::cg(&a, &b, 1e-10, 2000);
        });
    }

    if want("compress") {
        let pu = PackedUpper::new(d);
        let mut rng = Pcg64::seed_from_u64(4);
        let src: Vec<f64> =
            (0..pu.len()).map(|_| rng.next_gaussian()).collect();
        for name in ALL_NAMES {
            let mut c = by_name(name, d, 8, 5).unwrap();
            let mut round = 0u64;
            bench(&format!("compress/{name} (packed n={})", pu.len()), 3, 30, || {
                let out = c.compress(&pu, &src, round);
                round += 1;
                std::hint::black_box(out);
            });
        }
    }

    if want("matmul") {
        let a = random_spd(128, 6);
        let b = random_spd(128, 7);
        bench("matmul/naive 128", 2, 10, || {
            std::hint::black_box(a.matmul_naive(&b));
        });
        for tile in [8, 32, 64] {
            bench(&format!("matmul/tiled{tile} 128"), 2, 10, || {
                std::hint::black_box(a.matmul_tiled(&b, tile));
            });
        }
    }

    if want("pjrt") {
        match fednl::runtime::PjrtRuntime::load("artifacts") {
            Ok(rt) => {
                let sh = random_shard(301, 350, 8);
                let mut native = LogisticOracle::new(sh.clone(), 1e-3);
                match rt.oracle_for_shard(&sh, 1e-3) {
                    Ok(mut pj) => {
                        let x = vec![0.05; 301];
                        let mut g = vec![0.0; 301];
                        let mut h = Mat::zeros(301, 301);
                        bench("pjrt/oracle fused (AOT JAX+Pallas)", 2, 10, || {
                            let _ = pj.loss_grad_hessian(&x, &mut g, &mut h);
                        });
                        bench("pjrt/native oracle (same shape)", 2, 10, || {
                            let _ = native.loss_grad_hessian(&x, &mut g, &mut h);
                        });
                    }
                    Err(e) => println!("pjrt oracle unavailable: {e}"),
                }
            }
            Err(_) => println!("(artifacts not built; skipping pjrt bench)"),
        }
    }
}

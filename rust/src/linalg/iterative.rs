//! Iterative linear solvers (paper component `linalg_linsolvers`:
//! "Jacobi, Gauss-Seidel, Conjugate-Gradient").
//!
//! The paper notes (§5.9) it did *not* explore replacing the master's
//! direct solve with Krylov methods; we ship them anyway (as the paper's
//! library does) and expose the comparison in the ablation bench — a
//! "future work" item of the paper (Appendix N: "integrating iterative
//! inexact linear solvers").

use super::matrix::Mat;
use super::vector;

/// Result of an iterative solve.
#[derive(Debug, Clone)]
pub struct IterSolve {
    pub x: Vec<f64>,
    pub iters: usize,
    pub residual: f64,
    pub converged: bool,
}

/// Conjugate Gradient for SPD `A x = b`.
pub fn cg(a: &Mat, b: &[f64], tol: f64, max_iter: usize) -> IterSolve {
    let d = b.len();
    let mut x = vec![0.0; d];
    let mut r = b.to_vec(); // r = b - A·0
    let mut p = r.clone();
    let mut ap = vec![0.0; d];
    let mut rs = vector::norm2_sq(&r);
    let b_norm = vector::norm2(b).max(1e-300);

    for it in 0..max_iter {
        if rs.sqrt() / b_norm <= tol {
            return IterSolve { x, iters: it, residual: rs.sqrt(), converged: true };
        }
        a.matvec(&p, &mut ap);
        let denom = vector::dot(&p, &ap);
        if denom <= 0.0 || !denom.is_finite() {
            break; // not SPD / breakdown
        }
        let alpha = rs / denom;
        vector::axpy(alpha, &p, &mut x);
        vector::axpy(-alpha, &ap, &mut r);
        let rs_new = vector::norm2_sq(&r);
        let beta = rs_new / rs;
        for i in 0..d {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
    }
    let converged = rs.sqrt() / b_norm <= tol;
    IterSolve { x, iters: max_iter, residual: rs.sqrt(), converged }
}

/// Jacobi iteration (requires non-zero diagonal; converges for strictly
/// diagonally dominant / well-conditioned SPD systems).
pub fn jacobi(a: &Mat, b: &[f64], tol: f64, max_iter: usize) -> IterSolve {
    let d = b.len();
    let mut x = vec![0.0; d];
    let mut x_new = vec![0.0; d];
    let b_norm = vector::norm2(b).max(1e-300);
    let mut res = f64::INFINITY;
    for it in 0..max_iter {
        for i in 0..d {
            let row = a.row(i);
            let mut s = b[i];
            for j in 0..d {
                if j != i {
                    s -= row[j] * x[j];
                }
            }
            x_new[i] = s / row[i];
        }
        std::mem::swap(&mut x, &mut x_new);
        // residual ‖Ax − b‖
        let mut ax = vec![0.0; d];
        a.matvec(&x, &mut ax);
        vector::sub(&ax, b, &mut x_new); // reuse x_new as scratch
        res = vector::norm2(&x_new);
        if res / b_norm <= tol {
            return IterSolve { x, iters: it + 1, residual: res, converged: true };
        }
    }
    IterSolve { x, iters: max_iter, residual: res, converged: false }
}

/// Gauss–Seidel iteration (in-place sweep; typically ~2× Jacobi).
pub fn gauss_seidel(a: &Mat, b: &[f64], tol: f64, max_iter: usize) -> IterSolve {
    let d = b.len();
    let mut x = vec![0.0; d];
    let mut scratch = vec![0.0; d];
    let b_norm = vector::norm2(b).max(1e-300);
    let mut res = f64::INFINITY;
    for it in 0..max_iter {
        for i in 0..d {
            let row = a.row(i);
            let mut s = b[i];
            for j in 0..d {
                if j != i {
                    s -= row[j] * x[j];
                }
            }
            x[i] = s / row[i];
        }
        let mut ax = vec![0.0; d];
        a.matvec(&x, &mut ax);
        vector::sub(&ax, b, &mut scratch);
        res = vector::norm2(&scratch);
        if res / b_norm <= tol {
            return IterSolve { x, iters: it + 1, residual: res, converged: true };
        }
    }
    IterSolve { x, iters: max_iter, residual: res, converged: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn spd(d: usize, seed: u64, diag_boost: f64) -> (Mat, Vec<f64>) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let bmat = Mat::from_vec(
            d,
            d,
            (0..d * d).map(|_| rng.next_gaussian()).collect(),
        );
        let mut a = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += bmat.get(k, i) * bmat.get(k, j);
                }
                a.set(i, j, s / d as f64);
            }
        }
        a.add_diag(diag_boost);
        let b: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        (a, b)
    }

    fn residual(a: &Mat, x: &[f64], b: &[f64]) -> f64 {
        let mut ax = vec![0.0; b.len()];
        a.matvec(x, &mut ax);
        let mut r = vec![0.0; b.len()];
        vector::sub(&ax, b, &mut r);
        vector::norm2(&r)
    }

    #[test]
    fn cg_converges_on_spd() {
        let (a, b) = spd(30, 1, 1.0);
        let s = cg(&a, &b, 1e-12, 500);
        assert!(s.converged, "residual {}", s.residual);
        assert!(residual(&a, &s.x, &b) < 1e-9);
    }

    #[test]
    fn jacobi_converges_diag_dominant() {
        let (a, b) = spd(15, 2, 10.0); // strong diagonal
        let s = jacobi(&a, &b, 1e-10, 2000);
        assert!(s.converged);
        assert!(residual(&a, &s.x, &b) < 1e-8);
    }

    #[test]
    fn gauss_seidel_beats_jacobi() {
        let (a, b) = spd(15, 3, 10.0);
        let j = jacobi(&a, &b, 1e-10, 5000);
        let g = gauss_seidel(&a, &b, 1e-10, 5000);
        assert!(g.converged && j.converged);
        assert!(g.iters <= j.iters, "gs={} jacobi={}", g.iters, j.iters);
    }

    #[test]
    fn cg_exact_in_d_steps() {
        // CG terminates in ≤ d iterations in exact arithmetic.
        let (a, b) = spd(10, 4, 1.0);
        let s = cg(&a, &b, 1e-13, 11);
        assert!(s.converged, "iters={} res={}", s.iters, s.residual);
    }
}

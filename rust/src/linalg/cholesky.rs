//! Cholesky–Banachiewicz factorization + forward/backward substitution.
//!
//! The paper replaced Gaussian elimination with Cholesky for the master's
//! Newton solve (§5.9, v10, ×1.31): the system matrix `Hᵏ + lᵏI` is
//! symmetric positive definite by construction (Alg. 1 Option 2). The
//! row-oriented Banachiewicz order makes every inner loop a contiguous
//! dot over previously computed rows of L — the "cache-friendly
//! implementation which produces both L and Lᵀ factors" of v30 (we store
//! L row-major; forward substitution reads rows of L, backward
//! substitution walks the same storage as Lᵀ columns).

use super::matrix::Mat;
use super::vector;

/// Lower-triangular Cholesky factor (row-major dense storage).
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factor `a + shift·I = L Lᵀ`. Returns `None` if the shifted matrix
    /// is not numerically positive definite.
    pub fn factor(a: &Mat, shift: f64) -> Option<Self> {
        let d = a.rows();
        assert_eq!(a.cols(), d, "cholesky: square matrix required");
        let mut l = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..=i {
                // s = a_ij − Σ_{k<j} L_ik L_jk : contiguous row dots.
                let (li, lj) = (l.row(i), l.row(j));
                let acc = vector::dot(&li[..j], &lj[..j]);
                let mut s = a.get(i, j) - acc;
                if i == j {
                    s += shift;
                    if s <= 0.0 || !s.is_finite() {
                        return None;
                    }
                    l.set(i, j, s.sqrt());
                } else {
                    l.set(i, j, s / l.get(j, j));
                }
            }
        }
        Some(Self { l })
    }

    /// Dimension of the factored matrix.
    pub fn dim(&self) -> usize {
        self.l.rows()
    }

    /// Solve `L Lᵀ x = b` by forward then backward substitution,
    /// writing into `x` (in-place vector arithmetic, §5.9).
    pub fn solve(&self, b: &[f64], x: &mut [f64]) {
        let d = self.dim();
        debug_assert!(b.len() == d && x.len() == d);
        // Forward: L y = b. Row i's prefix is contiguous.
        for i in 0..d {
            let row = self.l.row(i);
            let s = vector::dot(&row[..i], &x[..i]);
            x[i] = (b[i] - s) / row[i];
        }
        // Backward: Lᵀ z = y. Walk columns of L (rows of Lᵀ) bottom-up;
        // eliminate x[i] from all earlier entries so the inner loop is a
        // contiguous AXPY over L's row i — cache-friendly (v30).
        for i in (0..d).rev() {
            let row = self.l.row(i);
            x[i] /= row[i];
            let xi = x[i];
            for k in 0..i {
                x[k] -= row[k] * xi;
            }
        }
    }

    /// Convenience allocating solve.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let mut x = vec![0.0; b.len()];
        self.solve(b, &mut x);
        x
    }

    /// Access the factor (tests/benches).
    pub fn factor_l(&self) -> &Mat {
        &self.l
    }
}

/// One-shot SPD solve of `(a + shift·I) x = b`.
pub fn solve_spd(a: &Mat, shift: f64, b: &[f64]) -> Option<Vec<f64>> {
    Cholesky::factor(a, shift).map(|ch| ch.solve_vec(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    /// Random SPD matrix A = BᵀB + εI.
    fn random_spd(d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let b = Mat::from_vec(
            d,
            d,
            (0..d * d).map(|_| rng.next_gaussian()).collect(),
        );
        let mut a = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += b.get(k, i) * b.get(k, j);
                }
                a.set(i, j, s);
            }
        }
        a.add_diag(0.1);
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(8, 1);
        let ch = Cholesky::factor(&a, 0.0).unwrap();
        let l = ch.factor_l();
        let mut rec = Mat::zeros(8, 8);
        for i in 0..8 {
            for j in 0..8 {
                let mut s = 0.0;
                for k in 0..8 {
                    s += l.get(i, k) * l.get(j, k);
                }
                rec.set(i, j, s);
            }
        }
        assert!(a.max_abs_diff(&rec) < 1e-10);
    }

    #[test]
    fn solve_matches_residual() {
        for d in [1, 2, 5, 17, 40] {
            let a = random_spd(d, d as u64);
            let mut rng = Pcg64::seed_from_u64(99 + d as u64);
            let b: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
            let x = solve_spd(&a, 0.0, &b).unwrap();
            let mut ax = vec![0.0; d];
            a.matvec(&x, &mut ax);
            for i in 0..d {
                assert!((ax[i] - b[i]).abs() < 1e-8, "d={d} i={i}");
            }
        }
    }

    #[test]
    fn shift_regularizes_indefinite() {
        // A = -I is not PD; A + 2I is.
        let a = Mat::identity_scaled(4, -1.0);
        assert!(Cholesky::factor(&a, 0.0).is_none());
        let ch = Cholesky::factor(&a, 2.0).unwrap();
        let x = ch.solve_vec(&[1.0, 2.0, 3.0, 4.0]);
        // (A + 2I) = I  ⇒  x = b.
        assert!((x[2] - 3.0).abs() < 1e-14);
    }

    #[test]
    fn rejects_nan() {
        let mut a = Mat::identity_scaled(3, 1.0);
        a.set(1, 1, f64::NAN);
        assert!(Cholesky::factor(&a, 0.0).is_none());
    }
}

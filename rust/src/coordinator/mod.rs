//! Coordination layer: how the master reaches its clients.
//!
//! # The streaming pool API
//!
//! The FedNL drivers (`algorithms::engine`) talk to a [`ClientPool`],
//! whose round primitive is **non-blocking and subset-aware**:
//!
//! * [`ClientPool::submit_round`] dispatches one client round — to every
//!   client, or to a participation subset (FedNL-PP, Alg. 3) — and
//!   returns immediately;
//! * [`ClientPool::drain`] blocks until at least one outstanding reply
//!   is available and returns whatever has arrived (any order); an
//!   empty batch means the round is complete;
//! * [`ClientPool::round`] is the blocking shim built on the two
//!   (collect everything, sort by client id) for callers that do not
//!   stream.
//!
//! The master processes replies **as they arrive** (paper §7, §9.3):
//! the server-side aggregation of client i's sparse Hessian update and
//! gradient overlaps with client j's compute and in-flight network
//! transfer.
//!
//! # Determinism by construction (reproducible summation)
//!
//! Streaming must not cost reproducibility. Since the reproducible
//! summation layer ([`crate::linalg::reduce`]) every cross-client f64
//! reduction — message aggregation ([`crate::algorithms::RoundSum`]),
//! `eval_loss`, `loss_grad`, `warm_start`, `init_state` — folds into
//! an **exact, associative, permutation-invariant** superaccumulator
//! and is rounded once at the end. Arrival order, commit order, thread
//! count, transport and shard grouping therefore cannot perturb a
//! single bit of the result: trajectories are bit-identical across
//! pools **by construction**, not by order discipline (asserted by the
//! integration tests, including deliberate stragglers and shuffled
//! arrivals). The engine still buffers-and-commits in round-subset
//! order on the atom path — the [`CommitBuffer`] guards duplicates,
//! holes and the Reuse replay slots — but the ordering is bookkeeping
//! now, not a numerical requirement.
//!
//! [`CommitBuffer`]: crate::algorithms::engine
//!
//! # Transports
//!
//! * [`SeqPool`] — in-process, sequential (reference semantics; owns its
//!   clients);
//! * [`SlicePool`] — the same over a borrowed `&mut [C]` client slice;
//! * [`local_sim::ThreadedPool`] — the paper's single-node multi-core
//!   simulator (§5.12): a worker pool sized to the physical cores,
//!   clients statically dispatched, every reply streamed to the master
//!   the moment it is computed;
//! * `net::server::RemotePool` — the multi-node TCP master (§7).
//!
//! All four drive either algorithm family: a pool is generic over a
//! [`PoolClient`] (plain FedNL / FedNL-LS clients *or* FedNL-PP
//! clients), and the wire protocol uses one unified ROUND/MSG exchange
//! for both (see `net::wire`).
//!
//! # Fault tolerance
//!
//! A round may lose participants. The pool-side contract (all default
//! to the no-fault behavior, so the in-process pools stay trivially
//! correct):
//!
//! * [`ClientPool::take_missing`] — participants of the round in
//!   flight whose reply will **never** arrive (fault injection, missed
//!   reply deadline, closed connection). `drain` must not return an
//!   empty batch while replies are outstanding *unless* the lost ones
//!   have been certified here — "empty batch" keeps meaning "the round
//!   is closed at the transport level".
//! * [`ClientPool::dead_clients`] / [`ClientPool::take_rejoined`] /
//!   [`ClientPool::prepare_round`] — liveness bookkeeping for the
//!   driver's participation sampling and rejoin resync.
//! * [`ClientPool::set_reply_deadline`] / [`ClientPool::pull_state`] —
//!   the reply deadline and the per-client STATE pull that the rejoin
//!   resync rides on.
//! * [`ClientPool::ack_round`] / [`ClientPool::resolve_staged`] /
//!   [`ClientPool::take_fresh_rejoined`] /
//!   [`ClientPool::pull_h_packed`] — the commit-ack protocol: clients
//!   that may fail over stage each round's apply until the master
//!   acknowledges the commit, and a rejoiner resolves (or exactly
//!   re-uploads) its state so "reply lost" and "ack lost" both land on
//!   exactly-once application.
//! * [`ClientPool::kill_shard`] / [`ClientPool::supports_shard_kill`] /
//!   [`ClientPool::shard_ranges`] — scripted relay-failure injection:
//!   native on the relay tier (the shard's channel is severed and the
//!   master adopts the orphaned partition), desugared to per-client
//!   kills elsewhere, bit-identical either way.
//!
//! Deterministic fault *injection* lives in [`faults::FaultPool`], a
//! wrapper that imposes a seeded [`faults::FaultPlan`] on any inner
//! transport — because the injection is master-side and never decided
//! by wall clock, the same plan yields bit-identical trajectories on
//! every transport (the lossy-round extension of the buffer-and-commit
//! rule).
//!
//! # Sharded aggregation (hierarchical masters)
//!
//! [`shard::ShardedPool`] fans the same pool API out to `S` shard
//! aggregators, each owning a contiguous client-id partition; its TCP
//! sibling is the relay tier in `net::relay`. Because the round
//! arithmetic is exactly associative, shards **pre-reduce**: each
//! forwards one merged [`RoundSum`] per round
//! ([`ClientPool::drain_sums`], wire frame `SHARD_SUM`), cutting the
//! master's fan-in payload and fold work from O(n·d) to O(S·d) while
//! trajectories stay **bit-identical between unsharded and sharded
//! runs for any S** — the merged sum equals the flat sum exactly, so
//! the invariant holds by construction. The first-order probe gets the
//! same treatment: [`ClientPool::loss_grad_sum`] surfaces the exact
//! (Σfᵢ, Σ∇fᵢ) accumulator pair, pre-reduced shard-side by the
//! aggregating tiers (`SHARD_GRAD_SUM` on the wire — one pair per
//! shard instead of n dense gradients), and the provided
//! [`ClientPool::loss_grad`] rounds-and-scales it once. The scalar
//! probe ([`ClientPool::eval_loss_each`] /
//! [`ClientPool::eval_loss`]) still surfaces atoms — its O(n) payload
//! is scalar-dominated. The FedNL-PP round path keeps
//! per-client atoms on the wire: its deltas feed the engine's
//! per-client (lᵢ, gᵢ) mirrors (rejoin resync) and its τ-subset
//! fan-in is already sublinear — the master-side folds still run
//! through [`RoundSum`], so PP trajectories share the
//! grouping-invariance guarantee.
//!
//! [`RoundSum`]: crate::algorithms::RoundSum

pub mod checkpoint;
pub mod faults;
pub mod local_sim;
pub mod shard;

pub use checkpoint::{AlgoSnap, CheckpointCfg, Snapshot};
pub use faults::{CorruptMode, FaultPlan, FaultPool};
pub use local_sim::ThreadedPool;
pub use shard::{ShardedPool, ShardStats};

use std::time::Duration;

use crate::algorithms::{ClientMsg, ClientState, PPClientState, RoundSum};
use crate::linalg::reduce::{RepAcc, RepVec};

/// How a pool surfaces the replies of the round in flight. Flat pools
/// serve either mode from the same atom stream; the shard tiers must
/// know **at submit time** (a relay's reply format is fixed when its
/// `SHARD_ROUND` frame is sent), which is why this is a sticky setting
/// rather than a `drain`-time choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundMode {
    /// Per-client [`ClientMsg`] atoms through [`ClientPool::drain`]
    /// (the FedNL-PP path, and the Reuse policy's replay cache).
    Atoms,
    /// Pre-reduced [`RoundSum`]s through [`ClientPool::drain_sums`]
    /// (the FedNL/LS path: shard tiers forward one merged accumulator
    /// per shard — O(S·d) master fan-in).
    Sums,
}

/// Algorithm family of a client. The unified round exchange is
/// family-agnostic on the wire, so the **driver** checks that its pool
/// serves the family it expects (a FedNL server aggregating FedNL-PP
/// deltas as absolute quantities would be silently wrong math).
/// Mirrors `net::wire::{FAMILY_FEDNL, FAMILY_PP}` on the TCP transport.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientFamily {
    /// FedNL / FedNL-LS clients (Alg. 1–2): absolute ∇fᵢ, lᵢ.
    FedNL,
    /// FedNL-PP clients (Alg. 3): Δgᵢ, Δlᵢ deltas.
    PP,
}

/// One simulated client, driveable by any in-process pool.
///
/// Implemented by [`ClientState`] (FedNL / FedNL-LS, Alg. 1–2) and
/// [`PPClientState`] (FedNL-PP, Alg. 3). The message fields carry
/// absolute quantities for the former and deltas for the latter; the
/// pools do not care — the drivers check [`PoolClient::family`].
pub trait PoolClient: Send {
    fn id(&self) -> usize;
    fn dim(&self) -> usize;
    fn family(&self) -> ClientFamily;
    fn alpha(&self) -> f64;
    fn set_alpha(&mut self, alpha: f64);

    /// Execute one client round at iterate `x`.
    fn round(&mut self, x: &[f64], round: u64, need_loss: bool) -> ClientMsg;

    /// fᵢ(x) (line-search probes).
    fn eval_loss(&mut self, x: &[f64]) -> f64;

    /// (fᵢ(x), ∇fᵢ(x)) — the first-order reduction primitive.
    fn eval_loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>);

    /// Hᵢ⁰ = ∇²fᵢ(x⁰), returned packed (FedNL warm start).
    fn warm_start(&mut self, x: &[f64]) -> Vec<f64>;

    /// Current (lᵢ, gᵢ) pair (FedNL-PP bootstrap, Alg. 3 line 2).
    fn state(&self) -> (f64, Vec<f64>);
}

impl PoolClient for ClientState {
    fn id(&self) -> usize {
        self.id
    }

    fn dim(&self) -> usize {
        ClientState::dim(self)
    }

    fn family(&self) -> ClientFamily {
        ClientFamily::FedNL
    }

    fn alpha(&self) -> f64 {
        self.alpha
    }

    fn set_alpha(&mut self, alpha: f64) {
        self.alpha = alpha;
    }

    fn round(&mut self, x: &[f64], round: u64, need_loss: bool) -> ClientMsg {
        ClientState::round(self, x, round, need_loss)
    }

    fn eval_loss(&mut self, x: &[f64]) -> f64 {
        ClientState::eval_loss(self, x)
    }

    fn eval_loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        ClientState::eval_loss_grad(self, x)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<f64> {
        ClientState::warm_start(self, x)
    }

    fn state(&self) -> (f64, Vec<f64>) {
        panic!("STATE requested from a FedNL client (PP-only primitive)")
    }
}

impl PoolClient for PPClientState {
    fn id(&self) -> usize {
        self.id
    }

    fn dim(&self) -> usize {
        PPClientState::dim(self)
    }

    fn family(&self) -> ClientFamily {
        ClientFamily::PP
    }

    fn alpha(&self) -> f64 {
        self.alpha
    }

    fn set_alpha(&mut self, alpha: f64) {
        self.alpha = alpha;
    }

    fn round(&mut self, x: &[f64], round: u64, need_loss: bool) -> ClientMsg {
        self.participate(x, round, need_loss)
    }

    fn eval_loss(&mut self, x: &[f64]) -> f64 {
        self.oracle.loss(x)
    }

    fn eval_loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let mut g = vec![0.0; x.len()];
        let l = self.oracle.loss_grad(x, &mut g);
        (l, g)
    }

    fn warm_start(&mut self, _x: &[f64]) -> Vec<f64> {
        panic!("WARM_START requested from a FedNL-PP client (Alg. 3 initializes Hᵢ⁰ = 0)")
    }

    fn state(&self) -> (f64, Vec<f64>) {
        (self.l_i, self.g_i.clone())
    }
}

/// Master-side view of a set of FedNL clients.
pub trait ClientPool {
    fn n_clients(&self) -> usize;
    fn dim(&self) -> usize;

    /// Algorithm family every client of this pool serves (pools are
    /// family-homogeneous; enforced at construction). The round engine
    /// asserts this against the algorithm it is about to run.
    fn family(&self) -> ClientFamily;

    /// Short implementation name ("seq", "threaded", "remote") for
    /// logs and tests.
    fn kind_name(&self) -> &'static str {
        "pool"
    }

    /// Theoretical α of the clients' compressor class. Transports that
    /// cannot know it without asking (the TCP master, the relay tier)
    /// return NaN — the "ask the clients" sentinel the `SET_ALPHA`
    /// negotiation resolves (see [`set_alpha`]).
    ///
    /// [`set_alpha`]: ClientPool::set_alpha
    fn default_alpha(&self) -> f64;

    /// Negotiate the Hessian learning rate and return the **effective**
    /// α the run must use. A finite positive `alpha` is installed on
    /// every client (and echoed back); a non-finite `alpha` is the
    /// query form — clients keep their own (theoretical) α and echo
    /// it, so the master learns the value without overriding it. The
    /// server must aggregate with the returned α, never the requested
    /// one: client/server α agreement is what keeps `Hᵏ` the true
    /// average of the `Hᵢᵏ`.
    fn set_alpha(&mut self, alpha: f64) -> f64;

    /// Dispatch one client round without waiting for replies. `subset`
    /// is the participating client ids (`None` = all clients). Exactly
    /// one reply per participant is later surfaced through [`drain`].
    ///
    /// [`drain`]: ClientPool::drain
    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    );

    /// Retrieve replies to the outstanding round: blocks until at least
    /// one is available, returns every reply that has arrived (in
    /// arrival order — **not** client order), and returns an empty
    /// batch once all participants have answered.
    fn drain(&mut self) -> Vec<ClientMsg>;

    /// Select the reply-aggregation mode for subsequent rounds (see
    /// [`RoundMode`]). Flat pools ignore it — their provided
    /// [`drain_sums`] folds the atom stream server-side either way;
    /// the shard tiers encode it into the round dispatch.
    ///
    /// [`drain_sums`]: ClientPool::drain_sums
    fn set_round_mode(&mut self, _mode: RoundMode) {}

    /// Sum-mode sibling of [`drain`]: blocks like `drain`, but surfaces
    /// pre-reduced [`RoundSum`]s (empty = round closed). Exactness
    /// makes the two paths interchangeable arithmetically — folding
    /// atoms here (the provided default) or merging shard-side partial
    /// sums yields bit-identical server state. Shard tiers override
    /// this to forward one merged accumulator per shard.
    ///
    /// [`drain`]: ClientPool::drain
    fn drain_sums(&mut self) -> Vec<RoundSum> {
        let batch = self.drain();
        if batch.is_empty() {
            return Vec::new();
        }
        vec![RoundSum::from_msgs(&batch)]
    }

    /// Blocking shim: execute one round on every client and return the
    /// messages sorted by client id.
    fn round(
        &mut self,
        x: &[f64],
        round: u64,
        need_loss: bool,
    ) -> Vec<ClientMsg> {
        self.submit_round(x, None, round, need_loss);
        let mut msgs = Vec::with_capacity(self.n_clients());
        loop {
            let batch = self.drain();
            if batch.is_empty() {
                break;
            }
            msgs.extend(batch);
        }
        msgs.sort_by_key(|m| m.client_id);
        msgs
    }

    /// Per-client losses at `x` — the probe primitive the reductions
    /// are built on. One `(client id, fᵢ(x))` entry per *live* client,
    /// in any order (the provided reductions sort). Shard tiers
    /// concatenate their partitions' entries here, which is what keeps
    /// the f64 reduction grouping identical on every topology.
    fn eval_loss_each(&mut self, x: &[f64]) -> Vec<(u32, f64)>;

    /// Per-client `(client id, fᵢ(x), ∇fᵢ(x))` entries, one per live
    /// client, any order. Sibling of [`eval_loss_each`].
    ///
    /// [`eval_loss_each`]: ClientPool::eval_loss_each
    fn loss_grad_each(&mut self, x: &[f64]) -> Vec<(u32, f64, Vec<f64>)>;

    /// Average local loss at `x` (line-search probe). A provided
    /// method folding the per-client atoms through the reproducible
    /// accumulator ([`crate::linalg::reduce`]), so every topology —
    /// flat pools, the sharded tier, the TCP relay tier — produces the
    /// bit-identical value regardless of the order (or grouping) the
    /// atoms arrive in. No sort needed: the sum is exact.
    fn eval_loss(&mut self, x: &[f64]) -> f64 {
        let parts = self.eval_loss_each(x);
        assert!(!parts.is_empty(), "eval_loss: no live clients");
        let vals: Vec<f64> = parts.iter().map(|&(_, l)| l).collect();
        let mut acc = RepAcc::new();
        acc.accumulate_slice(&vals);
        acc.round() / parts.len() as f64
    }

    /// Pre-reduced first-order probe: the exact (Σfᵢ, Σ∇fᵢ)
    /// superaccumulator pair over the live clients, plus their count —
    /// no rounding, no scaling. The provided method folds the
    /// per-client atoms of [`loss_grad_each`]; aggregating tiers
    /// override it to merge partial sums formed next to the clients
    /// (one accumulator pair per shard on the wire instead of n dense
    /// gradients — the `SHARD_SUM` payload cut applied to the probe
    /// path). Exactness of the accumulator makes every override
    /// bit-identical to this default, so [`loss_grad`] is
    /// grouping-invariant on every topology.
    ///
    /// [`loss_grad_each`]: ClientPool::loss_grad_each
    /// [`loss_grad`]: ClientPool::loss_grad
    fn loss_grad_sum(&mut self, x: &[f64]) -> (RepAcc, RepVec, u32) {
        let parts = self.loss_grad_each(x);
        let mut loss = RepAcc::new();
        let mut gsum = RepVec::new(x.len());
        for (_, l, gi) in &parts {
            loss.accumulate(*l);
            gsum.accumulate(gi);
        }
        (loss, gsum, parts.len() as u32)
    }

    /// Average (f(x), ∇f(x)) reduction — the first-order baselines'
    /// round primitive and the FedNL-PP convergence probe. Built on
    /// [`loss_grad_sum`]: exact Σ, one rounding, then the 1/n scaling
    /// — grouping- and order-invariant on every transport.
    ///
    /// [`loss_grad_sum`]: ClientPool::loss_grad_sum
    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let (mut loss, mut gsum, count) = self.loss_grad_sum(x);
        assert!(count > 0, "loss_grad: no live clients");
        let inv = 1.0 / count as f64;
        let mut g = gsum.round_vec();
        for gj in g.iter_mut() {
            *gj *= inv;
        }
        (loss.round() * inv, g)
    }

    /// Warm-start Hᵢ⁰ = ∇²fᵢ(x⁰); returns packed Hᵢ⁰ per client
    /// (client-id order).
    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>>;

    /// FedNL-PP bootstrap: every client's current (lᵢ, gᵢ) pair, in
    /// client-id order (Alg. 3 line 2).
    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)>;

    /// Cumulative transport-level bytes (up, down) if the transport
    /// meters them itself; in-process pools return `None` and the driver
    /// keeps the logical count.
    fn transport_bytes(&self) -> Option<(u64, u64)> {
        None
    }

    // --- fault tolerance / liveness (defaults = nothing ever fails) ---

    /// Called by the driver before it samples / submits round `round`:
    /// transports refresh liveness state here (poll re-registrations,
    /// advance a fault plan), so [`dead_clients`] and [`take_rejoined`]
    /// reflect this round.
    ///
    /// [`dead_clients`]: ClientPool::dead_clients
    /// [`take_rejoined`]: ClientPool::take_rejoined
    fn prepare_round(&mut self, _round: u64) {}

    /// Clients currently unable to participate (deregistered, or frozen
    /// by fault injection). Used by the FedNL-PP resampling policy.
    fn dead_clients(&self) -> Vec<u32> {
        Vec::new()
    }

    /// Participants of the round in flight whose reply is certified to
    /// never arrive. Drained by the round engine; returning an id here
    /// releases the engine from waiting on it.
    fn take_missing(&mut self) -> Vec<u32> {
        Vec::new()
    }

    /// Clients that came back since the last call (thawed by the fault
    /// plan, or re-registered over the wire). The FedNL-PP driver
    /// resyncs each via [`pull_state`].
    ///
    /// [`pull_state`]: ClientPool::pull_state
    fn take_rejoined(&mut self) -> Vec<u32> {
        Vec::new()
    }

    /// Per-client reply deadline for the round exchange. In-process
    /// transports ignore it; `RemotePool` deregisters clients whose
    /// reply misses it, and the fault injector uses it to convert
    /// injected delays beyond the deadline into deterministic drops.
    fn set_reply_deadline(&mut self, _deadline: Option<Duration>) {}

    /// Pull one client's current (lᵢ, gᵢ) (the FedNL-PP rejoin resync;
    /// same exchange as the STATE bootstrap, but for a single client).
    /// `None` means the client was lost again before answering — the
    /// driver skips the resync (the client is dead and unscheduled).
    fn pull_state(&mut self, _client: u32) -> Option<(f64, Vec<f64>)> {
        panic!("per-client state pull not supported by this transport")
    }

    // --- commit acks / shard failover (defaults = in-process: the
    // reply channel is the commit, nothing stages, relays never die) ---

    /// Announce that round `round` closed with `committed`'s replies
    /// counted. TCP transports forward a `ROUND_ACK` to each committed
    /// client that registered with `wants_ack` (the commit-ack
    /// protocol); everyone else ignores it. In-process pools no-op:
    /// their clients' applies are synchronous with the drain.
    fn ack_round(&mut self, _round: u64, _committed: &[u32]) {}

    /// Resolve a rejoiner's staged round application against the
    /// engine's commit watermark for that id (`RESYNC` on the wire:
    /// apply staged round ≤ `last_commit`, discard anything newer).
    /// Called by the driver for every id surfaced by
    /// [`take_rejoined`] before the client is scheduled again.
    ///
    /// [`take_rejoined`]: ClientPool::take_rejoined
    fn resolve_staged(&mut self, _client: u32, _last_commit: Option<u64>) {}

    /// Subset of the last [`take_rejoined`] batch that re-registered
    /// with the `fresh` flag (restarted process, empty in-memory
    /// state): these need the exact Hᵢ resync via [`pull_h_packed`].
    /// Must be drained after `take_rejoined` (it is a refinement of
    /// that batch, not an independent stream).
    ///
    /// [`take_rejoined`]: ClientPool::take_rejoined
    /// [`pull_h_packed`]: ClientPool::pull_h_packed
    fn take_fresh_rejoined(&mut self) -> Vec<u32> {
        Vec::new()
    }

    /// Exact H resync: every live FedNL client's packed Hᵢ, in
    /// client-id order (`PULL_H` broadcast on the wire). `None` means
    /// the transport cannot (or some client failed to answer) — the
    /// driver falls back to its approximate rejoin handling.
    fn pull_h_packed(&mut self) -> Option<Vec<Vec<f64>>> {
        None
    }

    /// True iff [`kill_shard`] is wired to a real failure path (the
    /// relay tier: severing the shard's channel exercises partition
    /// adoption end-to-end). The fault injector uses this to decide
    /// between a native `killrelay` and its per-client desugaring.
    ///
    /// [`kill_shard`]: ClientPool::kill_shard
    fn supports_shard_kill(&self) -> bool {
        false
    }

    /// Sever shard `shard`'s aggregator abruptly (scripted `killrelay`
    /// injection). Only meaningful when [`supports_shard_kill`]; the
    /// default panics so a misrouted injection fails loudly.
    ///
    /// [`supports_shard_kill`]: ClientPool::supports_shard_kill
    fn kill_shard(&mut self, _shard: u32) {
        panic!("shard kill not supported by this transport")
    }

    /// The contiguous global-id partition of each shard, ascending, if
    /// this pool aggregates through shards. The fault injector uses it
    /// to desugar `killrelay@R:S` into per-client kills on transports
    /// without a native kill path.
    fn shard_ranges(&self) -> Option<Vec<(u32, u32)>> {
        None
    }

    /// Scripted master-crash injection (`killmaster@R`): true iff the
    /// coordinator should die *now*, entering round `round`. The
    /// engine reacts by dropping its aggregate state and rebuilding it
    /// from the latest durable checkpoint — the in-process analogue of
    /// the `crashsmoke` supervisor SIGKILLing the real master process.
    /// Only the fault injector ever returns true.
    fn take_master_kill(&mut self, _round: u64) -> bool {
        false
    }
}

// --- shared sequential primitives (SeqPool / SlicePool) ---------------

/// Find the client with global id `ci`. Sequential pools select subset
/// members by *id*, not by position, so a pool may serve any contiguous
/// (or even sparse) global-id partition — the shard tier hands each
/// shard aggregator a slice of globally-numbered clients.
/// In-process α negotiation: a finite positive request is installed on
/// every client; the query form (non-finite) leaves the clients'
/// (identical, theoretical) α in place. Either way the effective value
/// is read back from the clients — the contract of
/// [`ClientPool::set_alpha`].
fn set_alpha_seq<C: PoolClient>(clients: &mut [C], alpha: f64) -> f64 {
    if alpha.is_finite() && alpha > 0.0 {
        for c in clients.iter_mut() {
            c.set_alpha(alpha);
        }
    }
    clients[0].alpha()
}

fn client_by_id<C: PoolClient>(clients: &mut [C], ci: u32) -> &mut C {
    // The common layouts (ids 0..n, or a contiguous ascending
    // partition base..base+m) resolve in O(1) via an offset probe, so
    // subset dispatch stays O(|subset|) on the hot path; anything else
    // falls back to a scan.
    let base = clients[0].id();
    let probe = (ci as usize).wrapping_sub(base);
    let idx = if probe < clients.len()
        && clients[probe].id() == ci as usize
    {
        probe
    } else {
        clients
            .iter()
            .position(|c| c.id() == ci as usize)
            .unwrap_or_else(|| {
                panic!("no client with id {ci} in this pool")
            })
    };
    &mut clients[idx]
}

fn submit_seq<C: PoolClient>(
    clients: &mut [C],
    queue: &mut Vec<ClientMsg>,
    x: &[f64],
    subset: Option<&[u32]>,
    round: u64,
    need_loss: bool,
) {
    assert!(queue.is_empty(), "previous round not fully drained");
    match subset {
        None => {
            for c in clients.iter_mut() {
                queue.push(c.round(x, round, need_loss));
            }
        }
        Some(s) => {
            for &ci in s {
                queue.push(client_by_id(clients, ci).round(
                    x,
                    round,
                    need_loss,
                ));
            }
        }
    }
}

fn eval_loss_each_seq<C: PoolClient>(
    clients: &mut [C],
    x: &[f64],
) -> Vec<(u32, f64)> {
    clients
        .iter_mut()
        .map(|c| (c.id() as u32, c.eval_loss(x)))
        .collect()
}

fn loss_grad_each_seq<C: PoolClient>(
    clients: &mut [C],
    x: &[f64],
) -> Vec<(u32, f64, Vec<f64>)> {
    clients
        .iter_mut()
        .map(|c| {
            let (l, g) = c.eval_loss_grad(x);
            (c.id() as u32, l, g)
        })
        .collect()
}

/// Sequential in-process pool — the reference implementation. Generic
/// over the client family: `SeqPool<ClientState>` (the default) drives
/// FedNL / FedNL-LS, `SeqPool<PPClientState>` drives FedNL-PP.
pub struct SeqPool<C: PoolClient = ClientState> {
    pub clients: Vec<C>,
    queue: Vec<ClientMsg>,
}

impl<C: PoolClient> SeqPool<C> {
    pub fn new(clients: Vec<C>) -> Self {
        assert!(!clients.is_empty());
        Self { clients, queue: Vec::new() }
    }
}

impl<C: PoolClient> ClientPool for SeqPool<C> {
    fn n_clients(&self) -> usize {
        self.clients.len()
    }

    fn dim(&self) -> usize {
        self.clients[0].dim()
    }

    fn family(&self) -> ClientFamily {
        self.clients[0].family()
    }

    fn kind_name(&self) -> &'static str {
        "seq"
    }

    fn default_alpha(&self) -> f64 {
        self.clients[0].alpha()
    }

    fn set_alpha(&mut self, alpha: f64) -> f64 {
        set_alpha_seq(&mut self.clients, alpha)
    }

    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    ) {
        submit_seq(&mut self.clients, &mut self.queue, x, subset, round, need_loss);
    }

    fn drain(&mut self) -> Vec<ClientMsg> {
        std::mem::take(&mut self.queue)
    }

    fn eval_loss_each(&mut self, x: &[f64]) -> Vec<(u32, f64)> {
        eval_loss_each_seq(&mut self.clients, x)
    }

    fn loss_grad_each(&mut self, x: &[f64]) -> Vec<(u32, f64, Vec<f64>)> {
        loss_grad_each_seq(&mut self.clients, x)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        self.clients.iter_mut().map(|c| c.warm_start(x)).collect()
    }

    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
        self.clients.iter().map(|c| c.state()).collect()
    }

    fn pull_state(&mut self, client: u32) -> Option<(f64, Vec<f64>)> {
        Some(client_by_id(&mut self.clients, client).state())
    }
}

/// Adapter: a mutable client slice as a sequential pool (borrowing
/// sibling of [`SeqPool`]; used by the `run_*` slice conveniences).
pub struct SlicePool<'a, C: PoolClient = ClientState> {
    clients: &'a mut [C],
    queue: Vec<ClientMsg>,
}

impl<'a, C: PoolClient> SlicePool<'a, C> {
    pub fn new(clients: &'a mut [C]) -> Self {
        assert!(!clients.is_empty());
        Self { clients, queue: Vec::new() }
    }
}

impl<C: PoolClient> ClientPool for SlicePool<'_, C> {
    fn n_clients(&self) -> usize {
        self.clients.len()
    }

    fn dim(&self) -> usize {
        self.clients[0].dim()
    }

    fn family(&self) -> ClientFamily {
        self.clients[0].family()
    }

    fn kind_name(&self) -> &'static str {
        "seq"
    }

    fn default_alpha(&self) -> f64 {
        self.clients[0].alpha()
    }

    fn set_alpha(&mut self, alpha: f64) -> f64 {
        set_alpha_seq(&mut *self.clients, alpha)
    }

    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    ) {
        submit_seq(
            &mut *self.clients,
            &mut self.queue,
            x,
            subset,
            round,
            need_loss,
        );
    }

    fn drain(&mut self) -> Vec<ClientMsg> {
        std::mem::take(&mut self.queue)
    }

    fn eval_loss_each(&mut self, x: &[f64]) -> Vec<(u32, f64)> {
        eval_loss_each_seq(&mut *self.clients, x)
    }

    fn loss_grad_each(&mut self, x: &[f64]) -> Vec<(u32, f64, Vec<f64>)> {
        loss_grad_each_seq(&mut *self.clients, x)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        self.clients.iter_mut().map(|c| c.warm_start(x)).collect()
    }

    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
        self.clients.iter().map(|c| c.state()).collect()
    }

    fn pull_state(&mut self, client: u32) -> Option<(f64, Vec<f64>)> {
        Some(client_by_id(&mut *self.clients, client).state())
    }
}

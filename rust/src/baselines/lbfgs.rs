//! L-BFGS (two-loop recursion, m-pair history, Armijo backtracking) —
//! the quasi-Newton comparator standing in for Ray/Scikit-Learn's
//! `lbfgs` solver and Spark MLlib's LogisticRegressionWithLBFGS
//! (DESIGN.md §2): same communication pattern as GD (one d-vector per
//! client per round) but curvature-aware.

use super::{armijo, BaselineOptions};
use crate::coordinator::ClientPool;
use crate::linalg::vector;
use crate::metrics::{RoundRecord, Trace};
use crate::net::wire;
use crate::utils::Stopwatch;
use std::collections::VecDeque;

/// Run L-BFGS with history size `m`.
pub fn run_lbfgs(
    pool: &mut dyn ClientPool,
    opts: &BaselineOptions,
    m: usize,
    x0: Vec<f64>,
) -> Trace {
    let d = x0.len();
    let n = pool.n_clients() as u64;
    let mut x = x0;
    let mut trace = Trace::new(format!("L-BFGS[m={m}]"));
    let sw = Stopwatch::start();
    let mut bytes_up = 0u64;
    let mut bytes_down = 0u64;

    // (s, y, ρ) pairs, newest at the back.
    let mut hist: VecDeque<(Vec<f64>, Vec<f64>, f64)> = VecDeque::new();
    let (mut f_x, mut grad) = pool.loss_grad(&x);
    // Exact framed sizes (LOSS_GRAD command down, GRAD reply up).
    bytes_down += wire::vec_frame_bytes(d) * n;
    bytes_up += wire::scalar_vec_frame_bytes(d) * n;

    for round in 0..opts.max_rounds {
        let gnorm = vector::norm2(&grad);
        trace.push(RoundRecord {
            round,
            grad_norm: gnorm,
            loss: f_x,
            bytes_up,
            bytes_down,
            elapsed: sw.elapsed_secs(),
            // Baseline reductions are all-or-nothing: full rounds only.
            committed: n as u32,
            missing: 0,
            flagged: 0,
        });
        if gnorm <= opts.tol_grad {
            break;
        }
        // Two-loop recursion for dir = −H·∇f.
        let mut q = grad.clone();
        let mut alphas = Vec::with_capacity(hist.len());
        for (s, yv, rho) in hist.iter().rev() {
            let a = rho * vector::dot(s, &q);
            vector::axpy(-a, yv, &mut q);
            alphas.push(a);
        }
        // Initial scaling γ = sᵀy / yᵀy of the newest pair.
        if let Some((s, yv, _)) = hist.back() {
            let gamma = vector::dot(s, yv) / vector::dot(yv, yv).max(1e-300);
            vector::scale(gamma.max(1e-12), &mut q);
        }
        for ((s, yv, rho), a) in hist.iter().zip(alphas.iter().rev()) {
            let b = rho * vector::dot(yv, &q);
            vector::axpy(a - b, s, &mut q);
        }
        let mut dir = q;
        vector::scale(-1.0, &mut dir);
        // Safeguard: fall back to steepest descent on a bad direction.
        if vector::dot(&dir, &grad) >= 0.0 {
            dir = grad.clone();
            vector::scale(-1.0, &mut dir);
            hist.clear();
        }
        let step = armijo(pool, &x, f_x, &grad, &dir, 1.0, 1e-4, 0.5, 60);
        bytes_down += wire::vec_frame_bytes(d) * n;
        bytes_up += wire::scalar_frame_bytes() * n;
        if step == 0.0 {
            break;
        }
        let mut x_new = vec![0.0; d];
        vector::add_scaled(&x, step, &dir, &mut x_new);
        let (f_new, g_new) = pool.loss_grad(&x_new);
        bytes_down += wire::vec_frame_bytes(d) * n;
        bytes_up += wire::scalar_vec_frame_bytes(d) * n;
        // Curvature pair.
        let mut s_vec = vec![0.0; d];
        vector::sub(&x_new, &x, &mut s_vec);
        let mut y_vec = vec![0.0; d];
        vector::sub(&g_new, &grad, &mut y_vec);
        let sy = vector::dot(&s_vec, &y_vec);
        if sy > 1e-12 * vector::norm2(&s_vec) * vector::norm2(&y_vec) {
            let rho = 1.0 / sy;
            hist.push_back((s_vec, y_vec, rho));
            if hist.len() > m {
                hist.pop_front();
            }
        }
        x = x_new;
        f_x = f_new;
        grad = g_new;
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::gd::tests::pool;
    use crate::baselines::run_gd;

    #[test]
    fn lbfgs_converges_tight() {
        let (mut p, d) = pool(3, 61);
        let opts = BaselineOptions { max_rounds: 500, tol_grad: 1e-9 };
        let tr = run_lbfgs(&mut p, &opts, 10, vec![0.0; d]);
        assert!(tr.last_grad_norm() <= 1e-9, "‖∇f‖={}", tr.last_grad_norm());
    }

    #[test]
    fn lbfgs_much_faster_than_gd() {
        let (mut p1, d) = pool(3, 62);
        let (mut p2, _) = pool(3, 62);
        let opts = BaselineOptions { max_rounds: 4000, tol_grad: 1e-8 };
        let tl = run_lbfgs(&mut p1, &opts, 10, vec![0.0; d]);
        let tg = run_gd(&mut p2, &opts, vec![0.0; d]);
        let rl = tl.rounds_to_tolerance(1e-8).unwrap();
        let rg = tg.rounds_to_tolerance(1e-8).unwrap_or(u64::MAX);
        assert!(rl * 2 < rg, "lbfgs {rl} vs gd {rg}");
    }
}

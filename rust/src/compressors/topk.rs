//! TopK: keep the k entries with the largest Frobenius-weighted energy.
//!
//! Selection uses a **4-ary min-heap of the k best seen so far** — the
//! paper benchmarked quicksort, merge sort, multi-way merge sort, CO
//! Funnelsort and radix sort and found the D-way heap fastest (§5.11,
//! v37). Selected indices are sorted ascending before transmission so
//! the master's sparse update walks memory monotonically (§5.11 v41,
//! ×1.0182).
//!
//! Contraction: picking the top-k energies e_i = w_i·v_i² guarantees
//! Σ_kept e ≥ (k/n)·Σ e, i.e. δ = k/n in the Frobenius norm — the
//! worst-case bound of App. D.2.
//!
//! The energy pass e_i = w_i·v_i² runs as a vectorized scan
//! ([`crate::linalg::simd::energy_scan`]) into a buffer reused across
//! rounds (§5.13), so the heap walks a dense array instead of
//! recomputing the (i, j) weight per element.

use super::{Compressed, Compressor, CompressorKind, IndexPayload};
use crate::linalg::packed::PackedUpper;
use crate::linalg::simd;

/// Deterministic TopK sparsifier.
#[derive(Debug, Clone)]
pub struct TopK {
    k: usize,
    /// Reused energy-scan buffer (zero allocation per round, §5.13).
    energy: Vec<f64>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "TopK requires k ≥ 1");
        Self { k, energy: Vec::new() }
    }

    pub fn k(&self) -> usize {
        self.k
    }
}

/// Min-heap over (energy, index) with arity 4: shallower than binary →
/// fewer cache-missing levels per sift (§5.11).
pub(crate) struct MinHeap4 {
    heap: Vec<(f64, u32)>,
}

impl MinHeap4 {
    pub fn with_capacity(k: usize) -> Self {
        Self { heap: Vec::with_capacity(k) }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn min(&self) -> f64 {
        self.heap[0].0
    }

    pub fn push(&mut self, e: f64, idx: u32) {
        self.heap.push((e, idx));
        let mut i = self.heap.len() - 1;
        while i > 0 {
            let parent = (i - 1) / 4;
            if self.heap[parent].0 > self.heap[i].0 {
                self.heap.swap(parent, i);
                i = parent;
            } else {
                break;
            }
        }
    }

    /// Replace the minimum with (e, idx) and sift down.
    pub fn replace_min(&mut self, e: f64, idx: u32) {
        self.heap[0] = (e, idx);
        let n = self.heap.len();
        let mut i = 0;
        loop {
            let first_child = i * 4 + 1;
            if first_child >= n {
                break;
            }
            let mut smallest = first_child;
            let last = (first_child + 4).min(n);
            for c in first_child + 1..last {
                if self.heap[c].0 < self.heap[smallest].0 {
                    smallest = c;
                }
            }
            if self.heap[smallest].0 < self.heap[i].0 {
                self.heap.swap(i, smallest);
                i = smallest;
            } else {
                break;
            }
        }
    }

    pub fn into_items(self) -> Vec<(f64, u32)> {
        self.heap
    }
}

/// Select the indices of the k largest energies (ties broken towards
/// lower index for determinism). Returns indices sorted ascending.
/// `scratch` holds the vectorized energy scan and is reused by stateful
/// callers to avoid per-round allocation.
pub(crate) fn select_topk_energy(
    pu: &PackedUpper,
    src: &[f64],
    k: usize,
    scratch: &mut Vec<f64>,
) -> Vec<u32> {
    let n = src.len();
    let k = k.min(n);
    scratch.resize(n, 0.0);
    simd::energy_scan(pu.weights(), src, scratch);
    let mut heap = MinHeap4::with_capacity(k);
    for (i, &e) in scratch.iter().enumerate() {
        if heap.len() < k {
            heap.push(e, i as u32);
        } else if e > heap.min() {
            heap.replace_min(e, i as u32);
        }
    }
    let mut idx: Vec<u32> =
        heap.into_items().into_iter().map(|(_, i)| i).collect();
    idx.sort_unstable(); // ascending: cache-friendly master update (v41)
    idx
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("TopK[k={}]", self.k)
    }

    fn kind(&self, n: usize) -> CompressorKind {
        CompressorKind::Contractive { delta: (self.k.min(n)) as f64 / n as f64 }
    }

    fn compress(
        &mut self,
        pu: &PackedUpper,
        src: &[f64],
        _round: u64,
    ) -> Compressed {
        let idx = select_topk_energy(pu, src, self.k, &mut self.energy);
        let values = idx.iter().map(|&i| src[i as usize]).collect();
        Compressed {
            payload: IndexPayload::Explicit(idx),
            values,
            scale: 1.0,
            encoding: super::ValueEncoding::F64,
            n: src.len() as u32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{distortion_sq, weighted_norm_sq};
    use crate::rng::{Pcg64, Rng};

    fn packed_src(d: usize, seed: u64) -> (PackedUpper, Vec<f64>) {
        let pu = PackedUpper::new(d);
        let mut rng = Pcg64::seed_from_u64(seed);
        let src = (0..pu.len()).map(|_| rng.next_gaussian()).collect();
        (pu, src)
    }

    #[test]
    fn selects_largest_magnitudes_on_diagonal_free_layout() {
        // d=1: single entry; d=2: entries (0,0),(0,1),(1,1).
        let pu = PackedUpper::new(2);
        let src = vec![3.0, -1.0, 0.5];
        let idx = select_topk_energy(&pu, &src, 1, &mut Vec::new());
        assert_eq!(idx, vec![0]); // 3² = 9 beats 2·1 and 0.25
    }

    #[test]
    fn off_diagonal_weighting_matters() {
        // (0,1) has weight 2: 2·2² = 8 > 2.5² = 6.25 of the diagonal.
        let pu = PackedUpper::new(2);
        let src = vec![2.5, 2.0, 0.0];
        let idx = select_topk_energy(&pu, &src, 1, &mut Vec::new());
        assert_eq!(idx, vec![1]);
    }

    #[test]
    fn contraction_bound_holds() {
        // ‖TopK(x) − x‖²_F ≤ (1 − k/n) ‖x‖²_F for many random inputs.
        for seed in 0..20 {
            let (pu, src) = packed_src(9, seed);
            let n = src.len();
            for k in [1, 4, n / 2, n] {
                let mut c = TopK::new(k);
                let out = c.compress(&pu, &src, 0);
                let dist = distortion_sq(&pu, &src, &out);
                let bound = (1.0 - k as f64 / n as f64)
                    * weighted_norm_sq(&pu, &src)
                    + 1e-12;
                assert!(dist <= bound, "seed={seed} k={k}: {dist} > {bound}");
            }
        }
    }

    #[test]
    fn k_equals_n_is_lossless() {
        let (pu, src) = packed_src(6, 3);
        let mut c = TopK::new(src.len());
        let out = c.compress(&pu, &src, 0);
        assert_eq!(out.to_dense(), src);
    }

    #[test]
    fn indices_sorted_and_unique() {
        let (pu, src) = packed_src(12, 4);
        let mut c = TopK::new(20);
        let out = c.compress(&pu, &src, 0);
        let idx = out.indices();
        assert_eq!(idx.len(), 20);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn heap_extracts_true_topk() {
        let (pu, src) = packed_src(15, 5);
        let k = 17;
        let got = select_topk_energy(&pu, &src, k, &mut Vec::new());
        // Brute-force expected set.
        let mut energies: Vec<(f64, u32)> = src
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                let (r, c) = pu.pair(i);
                let w = if r == c { 1.0 } else { 2.0 };
                (w * v * v, i as u32)
            })
            .collect();
        energies
            .sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        let mut expect: Vec<u32> =
            energies[..k].iter().map(|&(_, i)| i).collect();
        expect.sort_unstable();
        let mut got_sorted = got.clone();
        got_sorted.sort_unstable();
        // Energy multiset must match even if tie order differs.
        let sum_got: f64 = got
            .iter()
            .map(|&i| {
                let (r, c) = pu.pair(i as usize);
                let w = if r == c { 1.0 } else { 2.0 };
                w * src[i as usize] * src[i as usize]
            })
            .sum();
        let sum_expect: f64 = energies[..k].iter().map(|&(e, _)| e).sum();
        assert!((sum_got - sum_expect).abs() < 1e-12);
    }

    #[test]
    fn wire_bytes_accounts_values_and_indices() {
        let (pu, src) = packed_src(8, 6);
        let mut c = TopK::new(5);
        let out = c.compress(&pu, &src, 0);
        assert_eq!(
            out.wire_bytes(),
            5 * 8 + 5 * 4 + 4 + crate::compressors::CODEC_OVERHEAD_BYTES
        );
    }
}

//! Multi-node master: accepts n client connections and exposes them as a
//! [`ClientPool`], so the unified round engine drives real distributed
//! training unchanged (paper §9.3 setting: n clients + one master, star
//! topology, one TCP connection per client).
//!
//! The pool is **streaming**: `submit_round` pushes the ROUND frame to
//! every participant before any reply is read, and `drain` surfaces one
//! decoded reply at a time, so the driver's incremental aggregation of
//! client i overlaps with the *other* clients' compute and network
//! transfer (their frames accumulate in the OS socket buffers while the
//! master aggregates; recv + decode themselves run on the master thread,
//! between commits).
//!
//! # Liveness
//!
//! The pool survives client loss. A client is **deregistered** — its
//! channel retired, its id reported dead — when any of these fire:
//!
//! * its round reply misses the per-client deadline installed by
//!   [`ClientPool::set_reply_deadline`] (a `recv` timeout
//!   desynchronizes the frame stream, so the channel cannot be kept);
//! * its connection errors or closes (EOF — a crashed or departed
//!   client);
//! * it announces a graceful leave with the `DEREGISTER` frame.
//!
//! Deregistered participants of the round in flight surface through
//! [`ClientPool::take_missing`], which is what lets the round engine
//! close a quorum round instead of hanging. The listener stays open:
//! a dead client id may **rejoin** by reconnecting and re-sending
//! REGISTER (same id, dimension and family); rejoins are admitted in
//! [`ClientPool::prepare_round`] and reported through
//! [`ClientPool::take_rejoined`] so the FedNL-PP driver can resync the
//! client via the existing STATE pull.
//!
//! Clients that register with `REG_WANTS_ACK` run the commit-ack
//! protocol (`net::wire` § commit acks): the pool sends them a
//! ROUND_ACK after each committed round ([`ClientPool::ack_round`])
//! and a RESYNC watermark on rejoin ([`ClientPool::resolve_staged`]),
//! closing the "client computed but the reply was lost" hole
//! exactly-once. A rejoiner carrying `REG_FRESH` (blank Hᵢ) surfaces
//! through [`ClientPool::take_fresh_rejoined`]; the engine then pulls
//! every client's packed Hᵢ ([`ClientPool::pull_h_packed`]) to rebuild
//! the server Hessian exactly. Clients that registered without the
//! flag are never sent any of these frames, so existing deployments
//! meter byte-for-byte as before.

use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use anyhow::{Context, Result};

use super::framing::Channel;
use super::wire::{self, c2s, s2c};
use crate::algorithms::ClientMsg;
use crate::coordinator::{ClientFamily, ClientPool};

/// Master-side handle to n connected remote clients.
///
/// The pool may serve a **contiguous global-id partition** `[base,
/// base+n)` instead of `[0, n)`: the shard tier's relay aggregator
/// (`net::relay`) is exactly this pool bound to its partition, with
/// every public id (registration, subsets, replies, liveness reports)
/// staying global while channels are indexed by local slot.
pub struct RemotePool {
    /// Channels indexed by local slot = global id − `base`
    /// (`None` = deregistered).
    channels: Vec<Option<Channel>>,
    /// First global client id this pool serves.
    base: u32,
    /// Kept open after the initial accept so deregistered ids can
    /// rejoin; non-blocking (polled in `prepare_round`).
    listener: Option<TcpListener>,
    /// Algorithm family all clients declared at registration (pools
    /// are family-homogeneous; enforced during accept and rejoin).
    family: ClientFamily,
    d: usize,
    alpha: f64,
    /// Client ids of the round in flight, in subset order; replies are
    /// read (and surfaced to `drain`) in this order.
    pending: VecDeque<u32>,
    /// Participants of the round in flight certified lost.
    missing: Vec<u32>,
    /// Ids re-admitted by `prepare_round` since the last take.
    rejoined: Vec<u32>,
    /// Rejoiners that carried `REG_FRESH` since the last take.
    fresh: Vec<u32>,
    /// `REG_WANTS_ACK` per slot: commit acks and resync watermarks
    /// only flow to clients that asked (a client without the flag
    /// treats those tags as protocol violations).
    acks: Vec<bool>,
    /// Per-client reply deadline for the round exchange.
    deadline: Option<Duration>,
    /// Byte counters of retired channels, so `transport_bytes` stays
    /// cumulative across deregistrations. (received, sent).
    retired_bytes: (u64, u64),
}

/// A bound-but-not-yet-populated master socket; lets callers learn the
/// ephemeral port before spawning clients.
pub struct Bound {
    listener: TcpListener,
}

impl Bound {
    pub fn bind(addr: &str) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(Self { listener })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// [`Bound::bind`], retried while the killed previous owner's
    /// sockets drain out of TIME_WAIT — the restored-master relaunch
    /// (`master --restore`) must come back on the *same* address its
    /// clients hold in their `--fallback` rotation.
    pub fn bind_retry(addr: &str, attempts: u32) -> Result<Self> {
        assert!(attempts >= 1);
        for i in 0..attempts {
            match Self::bind(addr) {
                Ok(b) => return Ok(b),
                Err(e) if i + 1 == attempts => return Err(e),
                Err(_) => {
                    std::thread::sleep(Duration::from_millis(100))
                }
            }
        }
        unreachable!()
    }

    /// Accept until exactly `n_clients` clients register.
    pub fn accept(self, n_clients: usize) -> Result<RemotePool> {
        RemotePool::accept_on(self.listener, n_clients, 0)
    }

    /// As [`Bound::accept`], serving the global-id partition
    /// `[base, base+n_clients)` (the relay aggregator's downward face).
    pub fn accept_base(
        self,
        n_clients: usize,
        base: u32,
    ) -> Result<RemotePool> {
        RemotePool::accept_on(self.listener, n_clients, base)
    }

    /// Surrender the raw listener (shard-tier master bootstrap).
    pub fn into_listener(self) -> TcpListener {
        self.listener
    }
}

impl RemotePool {
    /// Listen on `addr` until exactly `n_clients` clients register.
    /// Clients may connect in any order; they self-identify with their
    /// id (dataset shard index).
    pub fn listen(addr: &str, n_clients: usize) -> Result<Self> {
        Bound::bind(addr)?.accept(n_clients)
    }

    fn accept_on(
        listener: TcpListener,
        n_clients: usize,
        base: u32,
    ) -> Result<Self> {
        let mut slots: Vec<Option<(Channel, u8, u8)>> =
            (0..n_clients).map(|_| None).collect();
        let mut d = 0usize;
        let mut registered = 0;
        while registered < n_clients {
            let (stream, _) = listener.accept()?;
            let mut ch = Channel::new(stream)?;
            let (tag, payload) = ch.recv()?;
            anyhow::ensure!(tag == c2s::REGISTER, "expected REGISTER");
            let (id, dim, family, flags) =
                wire::decode_register(&payload)?;
            anyhow::ensure!(
                id >= base && ((id - base) as usize) < n_clients,
                "client id {id} outside partition [{base}, {})",
                base as usize + n_clients
            );
            let id = (id - base) as usize;
            anyhow::ensure!(slots[id].is_none(), "duplicate client id {id}");
            if d == 0 {
                d = dim as usize;
            } else {
                anyhow::ensure!(d == dim as usize, "dimension mismatch");
            }
            // REG_FRESH is recorded even on the *initial* registration:
            // for a cold start it is vacuous (everyone starts fresh and
            // the engine's PULL_H rebuild is a no-op on zero state), but
            // a restored master's initial accept IS the reconnect of
            // clients that outlived the crash — a fresh registrant among
            // them must trigger the exact Hᵢ rebuild.
            slots[id] = Some((ch, family, flags));
            registered += 1;
        }
        let mut channels = Vec::with_capacity(n_clients);
        let mut acks = Vec::with_capacity(n_clients);
        let mut fresh = Vec::with_capacity(n_clients);
        let mut family = None;
        for (id, s) in slots.into_iter().enumerate() {
            let (ch, f, flags) = s.unwrap();
            let f = match f {
                wire::FAMILY_FEDNL => ClientFamily::FedNL,
                _ => ClientFamily::PP,
            };
            match family {
                None => family = Some(f),
                Some(prev) => anyhow::ensure!(
                    prev == f,
                    "client {id} registered as {f:?} but earlier clients \
                     registered as {prev:?}: pools are family-homogeneous"
                ),
            }
            channels.push(Some(ch));
            acks.push(flags & wire::REG_WANTS_ACK != 0);
            if flags & wire::REG_FRESH != 0 {
                fresh.push(base + id as u32);
            }
        }
        // Keep listening so deregistered ids can rejoin; polled
        // non-blocking between rounds.
        listener
            .set_nonblocking(true)
            .context("set_nonblocking on retained listener")?;
        Ok(Self {
            channels,
            base,
            listener: Some(listener),
            family: family.unwrap(),
            d,
            alpha: 0.0,
            pending: VecDeque::new(),
            missing: Vec::new(),
            rejoined: Vec::new(),
            fresh,
            acks,
            deadline: None,
            retired_bytes: (0, 0),
        })
    }

    /// Did any registrant ask for commit acks (`REG_WANTS_ACK`)? The
    /// relay tier ORs this into its own upward registration.
    pub fn wants_ack_any(&self) -> bool {
        self.acks.iter().any(|&a| a)
    }

    /// Treat every connected client as a rejoiner — the restored-master
    /// bootstrap (`master --restore`). The initial accept of a restored
    /// run IS the reconnect of clients that outlived the crash, so the
    /// engine's first `prepare_round` must resolve each client's staged
    /// ladder against the restored commit watermark (RESYNC) exactly as
    /// it would after an in-run failover. `REG_FRESH` registrants were
    /// already recorded during the accept.
    pub fn mark_all_rejoined(&mut self) {
        self.rejoined = (0..self.channels.len() as u32)
            .map(|slot| self.base + slot)
            .collect();
    }

    /// Retire a client's channel (folding its byte counters into the
    /// cumulative totals). The id may rejoin later.
    fn deregister(&mut self, ci: usize) {
        if let Some(ch) = self.channels[ci].take() {
            self.retired_bytes.0 += ch.bytes_received;
            self.retired_bytes.1 += ch.bytes_sent;
        }
    }

    /// Admit pending re-registrations of dead ids (non-blocking accept;
    /// each admission handshake is individually bounded). Capped at one
    /// accept per client slot per poll so a reconnect-looping peer
    /// cannot stall the training loop inside `prepare_round`.
    fn poll_rejoins(&mut self) {
        for _ in 0..self.channels.len() {
            // Borrow the listener only for the accept itself so the
            // admission below can take `&mut self`.
            let accepted = match &self.listener {
                Some(l) => l.accept(),
                None => return,
            };
            match accepted {
                Ok((stream, _)) => {
                    if let Some(id) = self.admit_rejoin(stream) {
                        self.rejoined.push(id as u32);
                    }
                }
                Err(_) => break, // WouldBlock (or transient error): done
            }
        }
    }

    /// Validate one reconnecting client; returns its global id if
    /// admitted. A malformed or conflicting registration drops the
    /// connection.
    fn admit_rejoin(&mut self, stream: TcpStream) -> Option<usize> {
        // The accepted socket may inherit the listener's non-blocking
        // mode on some platforms; the handshake below is blocking but
        // **bounded**: a stray connection that never completes REGISTER
        // (port scan, health check, crashed client) must not hang the
        // master inside `prepare_round`.
        stream.set_nonblocking(false).ok()?;
        let handshake = self.deadline.unwrap_or(Duration::from_secs(1));
        stream.set_read_timeout(Some(handshake)).ok()?;
        let mut ch = Channel::new(stream).ok()?;
        let (tag, payload) = ch.recv().ok()?;
        if tag != c2s::REGISTER {
            return None;
        }
        let (id, dim, family, flags) =
            wire::decode_register(&payload).ok()?;
        let slot = id.checked_sub(self.base)? as usize;
        let family = match family {
            wire::FAMILY_FEDNL => ClientFamily::FedNL,
            _ => ClientFamily::PP,
        };
        let admissible = slot < self.channels.len()
            && self.channels[slot].is_none()
            && dim as usize == self.d
            && family == self.family;
        if !admissible {
            return None;
        }
        // Resync the Hessian learning rate: a fresh-state rejoiner
        // would otherwise run with its own default α while the master
        // aggregates under the negotiated one. (Its Hᵢ is resynced by
        // the engine via `PULL_H` when the rejoiner sets `REG_FRESH`.)
        if self.alpha > 0.0 {
            let sent = ch
                .send(s2c::SET_ALPHA, &wire::encode_scalar(self.alpha))
                .is_ok();
            let acked =
                sent && matches!(ch.recv(), Ok((tag, _)) if tag == c2s::ACK);
            if !acked {
                return None;
            }
        }
        self.channels[slot] = Some(ch);
        self.acks[slot] = flags & wire::REG_WANTS_ACK != 0;
        if flags & wire::REG_FRESH != 0 {
            self.fresh.push(id);
        }
        Some(id as usize)
    }

    /// Send one command to every live client; returns the local slots
    /// actually sent (send failures deregister). The shared scaffolding
    /// of the probe reductions.
    fn ask_all(&mut self, tag: u8, payload: &[u8]) -> Vec<usize> {
        let n = self.channels.len();
        let mut asked = Vec::with_capacity(n);
        for ci in 0..n {
            if let Some(ch) = self.channels[ci].as_mut() {
                match ch.send(tag, payload) {
                    Ok(()) => asked.push(ci),
                    Err(_) => self.deregister(ci),
                }
            }
        }
        asked
    }

    /// Blocking receive on one channel expecting `want` (the reply tag
    /// of a reduction probe). On any failure — EOF, protocol
    /// violation, a DEREGISTER announcement — the client is
    /// deregistered and `None` returned so the reduction proceeds over
    /// the survivors. The round-reply deadline deliberately does NOT
    /// apply here: probes like WARM_START legitimately take longer
    /// than a round reply (the full d(d+1)/2 Hessian), and
    /// `RoundPolicy::deadline_ms` is scoped to the round exchange.
    fn recv_expect(&mut self, ci: usize, want: u8) -> Option<Vec<u8>> {
        let ch = self.channels[ci].as_mut()?;
        let _ = ch.set_read_timeout(None);
        match ch.recv() {
            Ok((tag, payload)) if tag == want => Some(payload),
            _ => {
                self.deregister(ci);
                None
            }
        }
    }

    /// Politely shut all (live) clients down.
    pub fn shutdown(&mut self) {
        for ch in self.channels.iter_mut().flatten() {
            let _ = ch.send(s2c::SHUTDOWN, &[]);
        }
    }
}

impl ClientPool for RemotePool {
    fn n_clients(&self) -> usize {
        self.channels.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn kind_name(&self) -> &'static str {
        "remote"
    }

    fn family(&self) -> ClientFamily {
        self.family
    }

    fn default_alpha(&self) -> f64 {
        // The master does not know the remote compressor class until
        // it asks: NaN is the query sentinel — `set_alpha(NaN)` leaves
        // the clients' theoretical α in place and resolves it from
        // their ACK echoes, so the TCP run trains with exactly the α
        // an in-process run of the same clients would use.
        if self.alpha > 0.0 {
            self.alpha
        } else {
            f64::NAN
        }
    }

    fn set_alpha(&mut self, alpha: f64) -> f64 {
        let payload = wire::encode_scalar(alpha);
        let asked = self.ask_all(s2c::SET_ALPHA, &payload);
        let mut echoes = Vec::with_capacity(asked.len());
        for ci in asked {
            if let Some(p) = self.recv_expect(ci, c2s::ACK) {
                if let Ok(a) = wire::decode_scalar(&p) {
                    echoes.push(a); // the α the client actually uses
                }
            }
        }
        let (resolved, homogeneous) =
            wire::fold_alpha_echoes(alpha, echoes);
        // Mixed echoes (clients registered with different compressor
        // classes): a NaN query would otherwise leave each client on
        // its own α while the server aggregates with one of them —
        // silently wrong math. Install the resolved α uniformly; the
        // second exchange happens only in the heterogeneous case, so
        // the usual handshake byte accounting is unchanged.
        if !homogeneous && resolved.is_finite() && resolved > 0.0 {
            let payload = wire::encode_scalar(resolved);
            let asked = self.ask_all(s2c::SET_ALPHA, &payload);
            for ci in asked {
                let _ = self.recv_expect(ci, c2s::ACK);
            }
        }
        self.alpha = resolved;
        resolved
    }

    fn prepare_round(&mut self, _round: u64) {
        self.poll_rejoins();
    }

    fn dead_clients(&self) -> Vec<u32> {
        self.channels
            .iter()
            .enumerate()
            .filter(|(_, ch)| ch.is_none())
            .map(|(slot, _)| self.base + slot as u32)
            .collect()
    }

    fn take_missing(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.missing)
    }

    fn take_rejoined(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.rejoined)
    }

    fn take_fresh_rejoined(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.fresh)
    }

    fn ack_round(&mut self, round: u64, committed: &[u32]) {
        // Commit acks go only to registrants that asked for them
        // (`REG_WANTS_ACK`); everyone else treats the tag as a
        // protocol violation, and the wire stays byte-identical to a
        // run without failover. TCP FIFO ordering guarantees each
        // client sees ROUND_ACK(k) before ROUND(k+1).
        let payload = wire::encode_round_ack(round);
        for &cid in committed {
            let Some(slot) = cid.checked_sub(self.base) else {
                continue;
            };
            let slot = slot as usize;
            if slot >= self.channels.len() || !self.acks[slot] {
                continue;
            }
            if let Some(ch) = self.channels[slot].as_mut() {
                if ch.send(s2c::ROUND_ACK, &payload).is_err() {
                    self.deregister(slot);
                }
            }
        }
    }

    fn resolve_staged(&mut self, client: u32, last_commit: Option<u64>) {
        let Some(slot) = client.checked_sub(self.base) else {
            return;
        };
        let slot = slot as usize;
        if slot >= self.channels.len() || !self.acks[slot] {
            return;
        }
        let payload = wire::encode_resync(last_commit);
        if let Some(ch) = self.channels[slot].as_mut() {
            if ch.send(s2c::RESYNC, &payload).is_err() {
                self.deregister(slot);
            }
        }
    }

    fn pull_h_packed(&mut self) -> Option<Vec<Vec<f64>>> {
        // Exact resync needs every peer's packed Hᵢ; with any slot
        // dead the caller falls back to the approximate warm path.
        if self.channels.iter().any(|c| c.is_none()) {
            return None;
        }
        let asked = self.ask_all(s2c::PULL_H, &[]);
        if asked.len() != self.channels.len() {
            return None;
        }
        let mut packs = Vec::with_capacity(asked.len());
        for ci in asked {
            let p = self.recv_expect(ci, c2s::WARM)?;
            packs.push(wire::decode_vec(&p).expect("pull_h decode"));
        }
        Some(packs)
    }

    fn set_reply_deadline(&mut self, deadline: Option<Duration>) {
        // TcpStream::set_read_timeout errors on a zero Duration (which
        // would silently *disable* the deadline at the `let _ =` call
        // sites); clamp to the strictest representable timeout instead.
        self.deadline = deadline.map(|d| d.max(Duration::from_millis(1)));
    }

    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    ) {
        assert!(self.pending.is_empty(), "previous round not fully drained");
        let payload = wire::encode_round(x, round, need_loss);
        // All sends complete before any receive: every participant
        // computes concurrently. (Family mismatches are caught by the
        // round engine against `self.family`, which the clients
        // declared at registration.) A dead participant — or one whose
        // send fails right here — is certified missing instead of sent.
        let all: Vec<u32>;
        let participants: &[u32] = match subset {
            Some(s) => s,
            None => {
                all = (0..self.channels.len() as u32)
                    .map(|slot| self.base + slot)
                    .collect();
                &all
            }
        };
        for &ci in participants {
            let slot = (ci - self.base) as usize;
            match self.channels[slot].as_mut() {
                Some(ch) => match ch.send(s2c::ROUND, &payload) {
                    Ok(()) => self.pending.push_back(ci),
                    Err(_) => {
                        self.deregister(slot);
                        self.missing.push(ci);
                    }
                },
                None => self.missing.push(ci),
            }
        }
    }

    fn drain(&mut self) -> Vec<ClientMsg> {
        // One decoded reply per call, in subset order: while the caller
        // aggregates this message, the remaining clients keep computing
        // and their frames accumulate in the kernel socket buffers, so
        // the next recv rarely blocks on a non-straggler. A reply that
        // misses the deadline, a closed connection or a DEREGISTER
        // announcement retires the client and certifies it missing;
        // the empty batch still means "round closed".
        while let Some(ci) = self.pending.pop_front() {
            let slot = (ci - self.base) as usize;
            let Some(ch) = self.channels[slot].as_mut() else {
                self.missing.push(ci);
                continue;
            };
            let _ = ch.set_read_timeout(self.deadline);
            match ch.recv() {
                Ok((tag, p)) if tag == c2s::MSG => {
                    let m = wire::decode_client_msg(&p)
                        .expect("decode client msg");
                    // A reply must identify as the client whose channel
                    // it came over — fail at the culprit, not later at
                    // the commit buffer under an innocent client's id.
                    assert_eq!(
                        m.client_id, ci as usize,
                        "client on channel {ci} replied with id {}",
                        m.client_id
                    );
                    return vec![m];
                }
                Ok(_) => {
                    // DEREGISTER (graceful leave) — or a protocol
                    // violation, which retires the channel the same way
                    // (never a panic: this is network-facing input).
                    self.deregister(slot);
                    self.missing.push(ci);
                }
                Err(_) => {
                    // Reply deadline missed, or the connection died.
                    self.deregister(slot);
                    self.missing.push(ci);
                }
            }
        }
        Vec::new()
    }

    fn eval_loss_each(&mut self, x: &[f64]) -> Vec<(u32, f64)> {
        let payload = wire::encode_vec(x);
        let asked = self.ask_all(s2c::EVAL_LOSS, &payload);
        let mut parts = Vec::with_capacity(asked.len());
        for slot in asked {
            if let Some(p) = self.recv_expect(slot, c2s::LOSS) {
                let l = wire::decode_scalar(&p).expect("loss");
                parts.push((self.base + slot as u32, l));
            }
        }
        parts
    }

    fn loss_grad_each(&mut self, x: &[f64]) -> Vec<(u32, f64, Vec<f64>)> {
        let payload = wire::encode_vec(x);
        let asked = self.ask_all(s2c::LOSS_GRAD, &payload);
        let mut parts = Vec::with_capacity(asked.len());
        for slot in asked {
            if let Some(p) = self.recv_expect(slot, c2s::GRAD) {
                let (l, g) =
                    wire::decode_loss_grad(&p).expect("grad decode");
                parts.push((self.base + slot as u32, l, g));
            }
        }
        parts
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        let payload = wire::encode_vec(x);
        let asked = self.ask_all(s2c::WARM_START, &payload);
        let mut packs = Vec::with_capacity(asked.len());
        for ci in asked {
            if let Some(p) = self.recv_expect(ci, c2s::WARM) {
                packs.push(wire::decode_vec(&p).expect("warm decode"));
            }
        }
        packs
    }

    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
        // The PP bootstrap needs every client's (lᵢ, gᵢ): the engine
        // indexes the result by client id.
        assert!(
            self.channels.iter().all(|c| c.is_some()),
            "init_state requires all clients registered"
        );
        for ch in self.channels.iter_mut().flatten() {
            ch.send(s2c::STATE, &[]).expect("state broadcast");
        }
        self.channels
            .iter_mut()
            .map(|ch| {
                let (tag, p) =
                    ch.as_mut().unwrap().recv().expect("state reply");
                assert_eq!(tag, c2s::STATE);
                wire::decode_loss_grad(&p).expect("state decode")
            })
            .collect()
    }

    fn pull_state(&mut self, client: u32) -> Option<(f64, Vec<f64>)> {
        // A rejoiner that dies (or stalls) again before answering the
        // pull is re-deregistered and skipped — the resync must not
        // take down the run the fault layer is protecting. The recv is
        // bounded even without a configured deadline.
        let ci = (client - self.base) as usize;
        {
            let ch = self.channels[ci].as_mut()?;
            let timeout = self.deadline.or(Some(Duration::from_secs(5)));
            let _ = ch.set_read_timeout(timeout);
            if ch.send(s2c::STATE, &[]).is_ok() {
                if let Ok((tag, p)) = ch.recv() {
                    if tag == c2s::STATE {
                        return Some(
                            wire::decode_loss_grad(&p)
                                .expect("state pull decode"),
                        );
                    }
                }
            }
        }
        self.deregister(ci);
        None
    }

    fn transport_bytes(&self) -> Option<(u64, u64)> {
        let up = self.retired_bytes.0
            + self
                .channels
                .iter()
                .flatten()
                .map(|c| c.bytes_received)
                .sum::<u64>();
        let down = self.retired_bytes.1
            + self
                .channels
                .iter()
                .flatten()
                .map(|c| c.bytes_sent)
                .sum::<u64>();
        Some((up, down))
    }
}

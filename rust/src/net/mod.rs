//! Multi-node networking over raw TCP (paper §7, App. L.1, J.2).
//!
//! Design decisions carried over from the paper:
//! * plain TCP/IP — no HTTP/gRPC layers ("any unnecessary abstractions
//!   ... take resources and are not free");
//! * **one** connection per client (the paper found a single channel
//!   beats per-stream connections);
//! * Nagle's algorithm disabled (`TCP_NODELAY`) because frames are
//!   explicitly sized and often small;
//! * fixed-width 32-bit indices on the wire (beat varints);
//! * RandK/RandSeqK transmit a PRG seed / start index, and the master
//!   reconstructs the coordinate set.
//!
//! Two master transports implement the same `ClientPool` contract:
//!
//! * [`server::RemotePool`] — one blocking socket per client, replies
//!   read in subset order. Simple, and fine up to a few hundred
//!   connections.
//! * [`event::EventPool`] — readiness-based: every socket is
//!   non-blocking and a single epoll loop ([`sys`]) drives per-
//!   connection read/write state machines (incremental
//!   `framing::FrameDecoder` in, `Arc`-shared pre-encoded frames
//!   out), inline on the master thread. Combined with the client-side
//!   multiplexer ([`mux`], CLI `client --mux N`) it holds 100k+
//!   registered clients behind a handful of sockets at a few bytes of
//!   idle bookkeeping per client. Trajectories are bit-identical to
//!   the blocking transports — arrival order changes, arithmetic does
//!   not (every reduction is an exact superaccumulator).
//!
//! The [`relay`] module adds the sharded aggregation tier on top:
//! relay aggregator processes that speak this client protocol downward
//! and the `SHARD_*` frames upward, so master fan-in scales as the
//! shard count instead of the client count (see `coordinator::shard`
//! for the determinism contract). A mux group reuses those `SHARD_*`
//! frames verbatim — to the master it is indistinguishable from a
//! relay fronting remote clients.

pub mod client;
#[cfg(unix)]
pub mod event;
pub mod framing;
pub mod mux;
pub mod relay;
pub mod server;
pub(crate) mod sys;
pub mod wire;

pub use client::{run_client, run_client_with, ClientOpts};
#[cfg(unix)]
pub use event::EventPool;
pub use framing::{Channel, FRAME_HEADER_BYTES};
pub use mux::{run_mux_clients, MuxReport};
pub use relay::{run_relay, run_relay_on, RelayCfg, RelayPool};
pub use server::RemotePool;

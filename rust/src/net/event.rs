//! Readiness-based master transport: one thread, 100k+ clients.
//!
//! [`EventPool`] replaces the blocking per-connection reads of
//! `net::server::RemotePool` with a single epoll-driven loop (see
//! `net::sys`) running **inline on the master thread** inside the
//! `ClientPool` calls — no event threads, no locks, no tokio. Every
//! socket is non-blocking; each connection owns a small read/write
//! state machine over the shared frame codec:
//!
//! * **read**: whatever the socket has is pulled into one scratch
//!   buffer shared by all connections and reassembled by the
//!   connection's [`FrameDecoder`] — partial-frame memory is allocated
//!   lazily per frame and released on completion, so an *idle*
//!   connection holds no payload buffers;
//! * **write**: outbound frames are pre-encoded once
//!   ([`encode_frame`]) and reference-counted — a round broadcast is
//!   one `Arc` queued to every participant, not one copy per client.
//!   A partial write parks the remainder as `(frame, offset)` and
//!   arms `EPOLLOUT`; the interest is dropped as soon as the queue
//!   drains.
//!
//! # Two connection kinds, one listener
//!
//! * **Plain** (`REGISTER`) — one remote client per socket, exactly
//!   the frames `RemotePool` speaks, so existing `fednl client`
//!   processes work unchanged.
//! * **Group** (`SHARD_REGISTER`) — a client-side multiplexer
//!   (`net::mux`, CLI `client --mux N`) hosting a contiguous
//!   partition of simulated clients behind one socket. The group
//!   speaks the `SHARD_*` batch frames — the same codecs the relay
//!   tier's upward face uses — so a round costs one command frame and
//!   one (pre-reduced or batched) reply per *group*, and per-idle-
//!   client server state shrinks to a few bytes of bookkeeping
//!   (`conn_of` slot + awaiting flag), metered honestly by
//!   [`EventPool::idle_bytes_per_client`].
//!
//! # Determinism
//!
//! The pool changes *when* replies arrive, never *what* is computed:
//! every cross-client reduction still folds through the exact
//! reproducible accumulators (`linalg::reduce`), and the engine's
//! buffer-and-commit layer already accepts arrival-order replies.
//! Trajectories are therefore bit-identical to `SeqPool` /
//! `ThreadedPool` / blocking `RemotePool` runs, with and without
//! faults and shards (asserted by `tests/integration_event.rs`).
//!
//! # Liveness
//!
//! The `RemotePool` contract carries over: a reply missing the
//! installed deadline, a dead connection, or a `DEREGISTER`
//! announcement retires the connection and certifies its round
//! participants missing ([`ClientPool::take_missing`]); the listener
//! stays open and re-registrations (plain ids *or* whole groups) are
//! admitted in [`ClientPool::prepare_round`]. Group replies get the
//! relay tier's extra forwarding slack on top of the deadline (the
//! group must first wait out its own members).

#![cfg(unix)]

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::framing::{encode_frame, Channel, FrameDecoder};
use super::relay::DEFAULT_RELAY_SLACK;
use super::server::Bound;
use super::sys::{Poller, Ready};
use super::wire::{self, c2s, s2c};
use crate::algorithms::{ClientMsg, RoundSum};
use crate::coordinator::{ClientFamily, ClientPool, RoundMode};

/// `conn_of` sentinel: client slot currently unregistered.
const NO_CONN: u32 = u32::MAX;

/// Read scratch shared by every connection (sized to a few frames of
/// typical round traffic; bigger frames just take several reads).
const SCRATCH_BYTES: usize = 64 << 10;

/// What a connection multiplexes.
#[derive(Clone, Copy)]
enum ConnKind {
    /// One remote client (global id).
    Plain { id: u32 },
    /// A mux group hosting the global-id partition `[lo, hi)`; `sid`
    /// is the group id it registered with (echoed in its batch frames).
    Group { sid: u32, lo: u32, hi: u32 },
}

/// Per-connection non-blocking state machine.
struct Conn {
    stream: TcpStream,
    kind: ConnKind,
    decoder: FrameDecoder,
    /// Outbound frames not yet fully written: (shared encoded frame,
    /// byte offset already written).
    outq: VecDeque<(Arc<Vec<u8>>, usize)>,
    /// Whether `EPOLLOUT` interest is currently armed.
    want_write: bool,
    bytes_sent: u64,
    bytes_received: u64,
}

impl Conn {
    /// Steady-state bookkeeping bytes this connection holds (the
    /// idle-memory meter; excludes the kernel's socket buffers).
    fn idle_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.decoder.buffered_bytes()
            + self
                .outq
                .iter()
                .map(|(f, _)| f.capacity())
                .sum::<usize>()
    }
}

/// What the pool currently expects from its connections (one logical
/// exchange is in flight at a time — the `ClientPool` call structure
/// guarantees it).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Expect {
    /// Between exchanges: only DEREGISTER is meaningful.
    Idle,
    /// A round is in flight: MSG (plain) / SHARD_MSG / SHARD_SUM.
    Round,
    /// A probe broadcast: one reply per connection, with the
    /// kind-specific tag.
    Probe { plain: u8, group: u8 },
}

/// Readiness-based master pool (see module docs).
pub struct EventPool {
    poller: Poller,
    /// Kept open (non-blocking) for rejoins, polled in
    /// `prepare_round` — never registered with the poller, so pending
    /// connections cannot wake the round loop.
    listener: TcpListener,
    /// Connections; the vector index is the poller token.
    conns: Vec<Option<Conn>>,
    /// Per client slot (global id − base): connection index, or
    /// [`NO_CONN`]. Four bytes per client — the dominant per-idle-
    /// client cost.
    conn_of: Vec<u32>,
    base: u32,
    d: usize,
    family: ClientFamily,
    alpha: f64,
    mode: RoundMode,
    deadline: Option<Duration>,
    /// Extra patience for group replies on top of `deadline` (the
    /// group waits out its own members first — relay-tier rule).
    slack: Duration,

    // --- round in flight ---
    /// Per client slot: reply still owed this round.
    awaiting: Vec<bool>,
    outstanding: usize,
    /// Per connection: participant ids handed to a *group* this round.
    group_await: Vec<Vec<u32>>,
    ready_msgs: Vec<ClientMsg>,
    ready_sums: Vec<RoundSum>,
    /// Armed at submit: plain replies due; groups get `+ slack`.
    due_plain: Option<Instant>,
    due_group: Option<Instant>,

    // --- probe in flight ---
    expect: Expect,
    /// Per connection: probe reply payload, once arrived, paired with
    /// the replier's kind *captured at arrival*. A peer may legally
    /// reply and disconnect inside one readable batch — the EOF
    /// retires the connection before the probe caller looks at the
    /// reply, so the reply must stay usable without touching `conns`.
    probe_replies: Vec<Option<(ConnKind, Vec<u8>)>>,

    missing: Vec<u32>,
    rejoined: Vec<u32>,
    /// Fresh-state rejoiners (`REG_FRESH`) since last taken by the
    /// engine's exact-resync path.
    fresh: Vec<u32>,
    /// Per client slot: the registrant asked for commit acks
    /// (`REG_WANTS_ACK`). Gates ROUND_ACK / RESYNC traffic.
    acks: Vec<bool>,
    retired_bytes: (u64, u64),
    scratch: Vec<u8>,
    events: Vec<Ready>,
}

impl EventPool {
    /// Accept registrations until the partition `[base, base+n)` is
    /// fully covered — by plain clients, mux groups, or any mix — then
    /// switch every socket to the non-blocking state machine.
    pub fn accept_base(
        bound: Bound,
        n_clients: usize,
        base: u32,
    ) -> Result<Self> {
        let listener = bound.into_listener();
        let mut conns: Vec<Option<Conn>> = Vec::new();
        let mut conn_of = vec![NO_CONN; n_clients];
        let mut acks = vec![false; n_clients];
        let mut covered = 0usize;
        let mut d = 0usize;
        let mut family: Option<ClientFamily> = None;
        let mut check_family =
            |family: &mut Option<ClientFamily>, f: u8| -> Result<()> {
                let f = match f {
                    wire::FAMILY_FEDNL => ClientFamily::FedNL,
                    _ => ClientFamily::PP,
                };
                match *family {
                    None => *family = Some(f),
                    Some(prev) => anyhow::ensure!(
                        prev == f,
                        "registration as {f:?} after earlier {prev:?}: \
                         pools are family-homogeneous"
                    ),
                }
                Ok(())
            };
        while covered < n_clients {
            let (stream, _) = listener.accept()?;
            let mut ch = Channel::new(stream)?;
            let (tag, payload) = ch.recv()?;
            let kind = match tag {
                c2s::REGISTER => {
                    // REG_FRESH on the *initial* registration is
                    // vacuous — there is no prior state to resync.
                    let (id, dim, fam, flags) =
                        wire::decode_register(&payload)?;
                    anyhow::ensure!(
                        id >= base && ((id - base) as usize) < n_clients,
                        "client id {id} outside partition [{base}, {})",
                        base as usize + n_clients
                    );
                    let slot = (id - base) as usize;
                    anyhow::ensure!(
                        conn_of[slot] == NO_CONN,
                        "duplicate client id {id}"
                    );
                    if d == 0 {
                        d = dim as usize;
                    } else {
                        anyhow::ensure!(
                            d == dim as usize,
                            "dimension mismatch"
                        );
                    }
                    check_family(&mut family, fam)?;
                    conn_of[slot] = conns.len() as u32;
                    acks[slot] = flags & wire::REG_WANTS_ACK != 0;
                    covered += 1;
                    ConnKind::Plain { id }
                }
                c2s::SHARD_REGISTER => {
                    // Mux-hosted clients never stage applies, so a
                    // group's flags stay unused here (the codec already
                    // rejects anything but REG_WANTS_ACK).
                    let (sid, lo, count, dim, fam, _flags) =
                        wire::decode_shard_register(&payload)?;
                    let hi = lo + count;
                    anyhow::ensure!(
                        lo >= base
                            && ((hi - base) as usize) <= n_clients,
                        "group [{lo}, {hi}) outside partition \
                         [{base}, {})",
                        base as usize + n_clients
                    );
                    if d == 0 {
                        d = dim as usize;
                    } else {
                        anyhow::ensure!(
                            d == dim as usize,
                            "dimension mismatch"
                        );
                    }
                    check_family(&mut family, fam)?;
                    for ci in lo..hi {
                        let slot = (ci - base) as usize;
                        anyhow::ensure!(
                            conn_of[slot] == NO_CONN,
                            "duplicate client id {ci} (group overlap)"
                        );
                        conn_of[slot] = conns.len() as u32;
                    }
                    covered += count as usize;
                    ConnKind::Group { sid, lo, hi }
                }
                other => anyhow::bail!(
                    "expected REGISTER or SHARD_REGISTER, got tag {other}"
                ),
            };
            let (stream, sent, received) = ch.into_parts();
            stream
                .set_nonblocking(true)
                .context("set_nonblocking on registered connection")?;
            conns.push(Some(Conn {
                stream,
                kind,
                decoder: FrameDecoder::new(),
                outq: VecDeque::new(),
                want_write: false,
                bytes_sent: sent,
                bytes_received: received,
            }));
        }
        listener
            .set_nonblocking(true)
            .context("set_nonblocking on retained listener")?;
        let mut poller = Poller::new().context("poller")?;
        for (idx, c) in conns.iter().enumerate() {
            let c = c.as_ref().unwrap();
            poller.register(
                c.stream.as_raw_fd(),
                idx as u64,
                true,
                false,
            )?;
        }
        let n_conns = conns.len();
        Ok(Self {
            poller,
            listener,
            conns,
            conn_of,
            base,
            d,
            family: family.context("no registrations")?,
            alpha: 0.0,
            mode: RoundMode::Atoms,
            deadline: None,
            slack: DEFAULT_RELAY_SLACK,
            awaiting: vec![false; n_clients],
            outstanding: 0,
            group_await: vec![Vec::new(); n_conns],
            ready_msgs: Vec::new(),
            ready_sums: Vec::new(),
            due_plain: None,
            due_group: None,
            expect: Expect::Idle,
            probe_replies: vec![None; n_conns],
            missing: Vec::new(),
            rejoined: Vec::new(),
            fresh: Vec::new(),
            acks,
            retired_bytes: (0, 0),
            scratch: vec![0u8; SCRATCH_BYTES],
            events: Vec::new(),
        })
    }

    /// As [`EventPool::accept_base`] for the canonical `[0, n)`
    /// partition.
    pub fn accept(bound: Bound, n_clients: usize) -> Result<Self> {
        Self::accept_base(bound, n_clients, 0)
    }

    /// Configure the group-reply slack (mirrors
    /// [`super::relay::RelayPool::set_relay_slack`]).
    pub fn set_group_slack(&mut self, slack: Duration) {
        self.slack = slack.max(Duration::from_millis(1));
    }

    /// Did any registrant ask for commit acks (`REG_WANTS_ACK`)? A
    /// relay serving this pool as its downward face ORs this into its
    /// own upward registration.
    pub fn wants_ack_any(&self) -> bool {
        self.acks.iter().any(|&a| a)
    }

    /// Estimated steady-state server-side bookkeeping bytes per
    /// registered client: the pool's per-client tables plus every
    /// connection's state machine, divided by the client count. This
    /// is the honest per-idle-client meter — process RSS would also
    /// charge whatever else lives in the process (e.g. the in-process
    /// mux threads of a loopback benchmark).
    pub fn idle_bytes_per_client(&self) -> f64 {
        let mut total = std::mem::size_of::<Self>()
            + self.conn_of.capacity() * std::mem::size_of::<u32>()
            + self.awaiting.capacity()
            + self.probe_replies.capacity()
                * std::mem::size_of::<Option<(ConnKind, Vec<u8>)>>()
            + self.scratch.capacity()
            + self.acks.capacity()
            + (self.missing.capacity()
                + self.rejoined.capacity()
                + self.fresh.capacity())
                * std::mem::size_of::<u32>();
        for c in self.conns.iter().flatten() {
            total += std::mem::size_of::<Option<Conn>>() + c.idle_bytes();
        }
        for g in &self.group_await {
            total += g.capacity() * std::mem::size_of::<u32>();
        }
        total as f64 / self.conn_of.len().max(1) as f64
    }

    /// Politely shut all live connections down (groups forward to
    /// their hosted clients).
    pub fn shutdown(&mut self) {
        let frame = Arc::new(encode_frame(s2c::SHUTDOWN, &[]));
        for idx in 0..self.conns.len() {
            if self.conns[idx].is_some() {
                let _ = self.queue_frame(idx, frame.clone());
            }
        }
        // Give queued bytes a brief chance to flush.
        let until = Instant::now() + Duration::from_millis(200);
        while self.conns.iter().flatten().any(|c| !c.outq.is_empty()) {
            let now = Instant::now();
            if now >= until {
                break;
            }
            let _ = self.pump(Some(until - now));
        }
    }

    // --- connection plumbing -----------------------------------------

    /// Retire connection `idx`: fold its byte meters, release its
    /// client slots, certify its in-flight participants missing.
    fn retire(&mut self, idx: usize) {
        let Some(conn) = self.conns[idx].take() else {
            return;
        };
        self.poller.deregister(conn.stream.as_raw_fd());
        self.retired_bytes.0 += conn.bytes_received;
        self.retired_bytes.1 += conn.bytes_sent;
        let (lo, hi) = match conn.kind {
            ConnKind::Plain { id } => (id, id + 1),
            ConnKind::Group { lo, hi, .. } => (lo, hi),
        };
        for ci in lo..hi {
            let slot = (ci - self.base) as usize;
            self.conn_of[slot] = NO_CONN;
            if self.awaiting[slot] {
                self.awaiting[slot] = false;
                self.outstanding -= 1;
                self.missing.push(ci);
            }
        }
        self.group_await[idx].clear();
    }

    /// Queue one pre-encoded frame to connection `idx`, writing as
    /// much as the socket takes right now. Returns `false` (and
    /// retires the connection) on a write error. Byte meters count
    /// bytes as the kernel accepts them — a frame parked in `outq`
    /// when the connection dies never inflates `transport_bytes`,
    /// matching the blocking transports' per-write accounting.
    fn queue_frame(&mut self, idx: usize, frame: Arc<Vec<u8>>) -> bool {
        let Some(conn) = self.conns[idx].as_mut() else {
            return false;
        };
        if !conn.outq.is_empty() {
            conn.outq.push_back((frame, 0));
            return true;
        }
        let mut off = 0usize;
        loop {
            match conn.stream.write(&frame[off..]) {
                Ok(0) => {
                    self.retire(idx);
                    return false;
                }
                Ok(n) => {
                    conn.bytes_sent += n as u64;
                    off += n;
                    if off == frame.len() {
                        return true;
                    }
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    conn.outq.push_back((frame, off));
                    self.set_write_interest(idx, true);
                    return true;
                }
                Err(_) => {
                    self.retire(idx);
                    return false;
                }
            }
        }
    }

    fn set_write_interest(&mut self, idx: usize, want: bool) {
        let Some(conn) = self.conns[idx].as_mut() else {
            return;
        };
        if conn.want_write == want {
            return;
        }
        conn.want_write = want;
        let _ = self.poller.reregister(
            conn.stream.as_raw_fd(),
            idx as u64,
            true,
            want,
        );
    }

    /// Resume the write queue after an `EPOLLOUT` wakeup.
    fn flush_writes(&mut self, idx: usize) {
        loop {
            let Some(conn) = self.conns[idx].as_mut() else {
                return;
            };
            let Some((frame, off)) = conn.outq.front_mut() else {
                self.set_write_interest(idx, false);
                return;
            };
            match conn.stream.write(&frame[*off..]) {
                Ok(0) => {
                    self.retire(idx);
                    return;
                }
                Ok(n) => {
                    *off += n;
                    let done = *off == frame.len();
                    conn.bytes_sent += n as u64;
                    if done {
                        conn.outq.pop_front();
                    }
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(_) => {
                    self.retire(idx);
                    return;
                }
            }
        }
    }

    /// Drain the socket's readable bytes into frames and dispatch
    /// them. Retires the connection on EOF, error, or any protocol
    /// violation.
    fn pump_reads(&mut self, idx: usize) {
        loop {
            if self.conns[idx].is_none() {
                return;
            }
            let frames = {
                let conn = self.conns[idx].as_mut().unwrap();
                match conn.stream.read(&mut self.scratch) {
                    Ok(0) => {
                        // EOF: clean close between frames, truncation
                        // mid-frame — retired either way.
                        self.retire(idx);
                        return;
                    }
                    Ok(n) => {
                        conn.bytes_received += n as u64;
                        match conn.decoder.push(&self.scratch[..n]) {
                            Ok(frames) => frames,
                            Err(_) => {
                                self.retire(idx);
                                return;
                            }
                        }
                    }
                    Err(e)
                        if e.kind() == ErrorKind::Interrupted =>
                    {
                        continue;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        return;
                    }
                    Err(_) => {
                        self.retire(idx);
                        return;
                    }
                }
            };
            for (tag, payload) in frames {
                if self.conns[idx].is_none() {
                    return;
                }
                self.handle_frame(idx, tag, payload);
            }
        }
    }

    /// Dispatch one decoded frame against the current expectation.
    fn handle_frame(&mut self, idx: usize, tag: u8, payload: Vec<u8>) {
        // A graceful leave is legal at any time.
        if tag == c2s::DEREGISTER {
            self.retire(idx);
            return;
        }
        match self.expect {
            Expect::Round => self.handle_round_frame(idx, tag, payload),
            Expect::Probe { plain, group } => {
                let kind = self.conns[idx].as_ref().unwrap().kind;
                let want = match kind {
                    ConnKind::Plain { .. } => plain,
                    ConnKind::Group { .. } => group,
                };
                if tag == want && self.probe_replies[idx].is_none() {
                    self.probe_replies[idx] = Some((kind, payload));
                } else {
                    // Wrong tag or duplicate reply: protocol
                    // violation, same rule as `recv_expect`.
                    self.retire(idx);
                }
            }
            Expect::Idle => {
                // Unsolicited traffic between exchanges: network-
                // facing input, retire rather than panic.
                self.retire(idx);
            }
        }
    }

    /// Round-reply state machine (per connection kind).
    fn handle_round_frame(
        &mut self,
        idx: usize,
        tag: u8,
        payload: Vec<u8>,
    ) {
        let kind_ok = match self.conns[idx].as_ref().unwrap().kind {
            ConnKind::Plain { id } => {
                if tag != c2s::MSG {
                    false
                } else {
                    match wire::decode_client_msg(&payload) {
                        Ok(m) if m.client_id == id as usize => {
                            let slot = (id - self.base) as usize;
                            if self.awaiting[slot] {
                                self.awaiting[slot] = false;
                                self.outstanding -= 1;
                                self.ready_msgs.push(m);
                                true
                            } else {
                                false // reply nobody asked for
                            }
                        }
                        _ => false, // undecodable or misidentified
                    }
                }
            }
            ConnKind::Group { sid, .. } => match tag {
                c2s::SHARD_MSG => {
                    self.absorb_group_msgs(idx, sid, &payload)
                }
                c2s::SHARD_SUM => {
                    self.absorb_group_sum(idx, sid, &payload)
                }
                _ => false,
            },
        };
        if !kind_ok {
            self.retire(idx);
        }
    }

    /// Validate and absorb a group's per-client atom batch (mirrors
    /// `RelayPool::drain`'s checks). Returns false on any violation.
    fn absorb_group_msgs(
        &mut self,
        idx: usize,
        sid: u32,
        payload: &[u8],
    ) -> bool {
        let Ok((got_sid, msgs, mut missing)) =
            wire::decode_shard_msg(payload)
        else {
            return false;
        };
        let part = std::mem::take(&mut self.group_await[idx]);
        let mut accounted: Vec<u32> = msgs
            .iter()
            .map(|m| m.client_id as u32)
            .chain(missing.iter().copied())
            .collect();
        accounted.sort_unstable();
        let dups = accounted.windows(2).any(|w| w[0] == w[1]);
        // Membership via binary search on sorted copies: atoms-mode
        // groups can span thousands of clients, and this runs on the
        // master's single event thread every round.
        let mut part_sorted = part.clone();
        part_sorted.sort_unstable();
        let valid = got_sid == sid
            && !part.is_empty()
            && !dups
            && accounted
                .iter()
                .all(|c| part_sorted.binary_search(c).is_ok());
        if !valid {
            self.group_await[idx] = part;
            return false;
        }
        // Anything the group left unaccounted is certified here so
        // the round can close (it must not happen: the group certifies
        // its own losses).
        for &c in &part {
            if accounted.binary_search(&c).is_err() {
                missing.push(c);
            }
        }
        for &c in &part {
            let slot = (c - self.base) as usize;
            debug_assert!(self.awaiting[slot]);
            self.awaiting[slot] = false;
            self.outstanding -= 1;
        }
        self.missing.extend(missing);
        self.ready_msgs.extend(msgs);
        true
    }

    /// Validate and absorb a group's pre-reduced round sum (mirrors
    /// `RelayPool::drain_sums`'s checks).
    fn absorb_group_sum(
        &mut self,
        idx: usize,
        sid: u32,
        payload: &[u8],
    ) -> bool {
        let Ok((got_sid, mut sum, missing)) =
            wire::decode_shard_sum(payload, self.d)
        else {
            return false;
        };
        let part = std::mem::take(&mut self.group_await[idx]);
        let mut miss_sorted = missing.clone();
        miss_sorted.sort_unstable();
        let dups = miss_sorted.windows(2).any(|w| w[0] == w[1]);
        let mut part_sorted = part.clone();
        part_sorted.sort_unstable();
        let valid = got_sid == sid
            && !part.is_empty()
            && !dups
            && sum.committed as usize + missing.len() == part.len()
            && miss_sorted
                .iter()
                .all(|c| part_sorted.binary_search(c).is_ok());
        if !valid {
            self.group_await[idx] = part;
            return false;
        }
        for &c in &part {
            let slot = (c - self.base) as usize;
            debug_assert!(self.awaiting[slot]);
            self.awaiting[slot] = false;
            self.outstanding -= 1;
        }
        self.missing.extend(missing);
        if sum.committed > 0 {
            sum.wire_bytes = crate::net::FRAME_HEADER_BYTES
                + payload.len() as u64;
            self.ready_sums.push(sum);
        }
        true
    }

    /// One readiness wait + event dispatch. Returns after the kernel
    /// reported (or the timeout expired).
    fn pump(&mut self, timeout: Option<Duration>) -> Result<()> {
        let mut events = std::mem::take(&mut self.events);
        events.clear();
        let res = self.poller.wait(&mut events, timeout);
        for ev in &events {
            let idx = ev.token as usize;
            if ev.writable {
                self.flush_writes(idx);
            }
            if ev.readable {
                self.pump_reads(idx);
            }
        }
        self.events = events;
        res.map(|_| ()).context("poller wait")
    }

    /// Expire overdue round participants: plain connections at the
    /// deadline, groups at deadline + slack (they wait out their own
    /// members first). Mirrors the blocking pools' per-reply timeouts.
    fn expire_overdue(&mut self, now: Instant) {
        let plain_over =
            self.due_plain.is_some_and(|t| now >= t);
        let group_over =
            self.due_group.is_some_and(|t| now >= t);
        if !plain_over && !group_over {
            return;
        }
        for idx in 0..self.conns.len() {
            let Some(conn) = self.conns[idx].as_ref() else {
                continue;
            };
            let overdue = match conn.kind {
                ConnKind::Plain { id } => {
                    plain_over
                        && self.awaiting[(id - self.base) as usize]
                }
                ConnKind::Group { .. } => {
                    group_over && !self.group_await[idx].is_empty()
                }
            };
            if overdue {
                self.retire(idx);
            }
        }
    }

    /// Next armed due-instant that is still relevant.
    fn next_due(&self) -> Option<Instant> {
        let plain_waiting = self.conns.iter().flatten().any(|c| {
            matches!(c.kind, ConnKind::Plain { id }
                if self.awaiting[(id - self.base) as usize])
        });
        let group_waiting = (0..self.conns.len())
            .any(|i| !self.group_await[i].is_empty());
        match (
            self.due_plain.filter(|_| plain_waiting),
            self.due_group.filter(|_| group_waiting),
        ) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    // --- broadcast + collect (probe scaffolding) ----------------------

    /// Queue one pre-encoded command to every live connection of
    /// either kind; returns the connection indices queued.
    fn ask_all(&mut self, tag: u8, payload: &[u8]) -> Vec<usize> {
        let frame = Arc::new(encode_frame(tag, payload));
        let mut asked = Vec::new();
        for idx in 0..self.conns.len() {
            if self.conns[idx].is_some()
                && self.queue_frame(idx, frame.clone())
            {
                asked.push(idx);
            }
        }
        asked
    }

    /// Pump until every asked connection has replied (or been
    /// retired). Unbounded like the blocking pools' probe receives —
    /// WARM_START legitimately exceeds round deadlines. Returns
    /// `(conn index, kind at reply time, payload)` in ascending
    /// connection order. The index may name a slot that retired
    /// *after* replying (reply + EOF in one readable batch) — callers
    /// must derive everything from the captured kind, never from
    /// `conns[idx]`.
    fn collect_probe(
        &mut self,
        asked: &[usize],
        plain: u8,
        group: u8,
    ) -> Vec<(usize, ConnKind, Vec<u8>)> {
        self.expect = Expect::Probe { plain, group };
        loop {
            let done = asked.iter().all(|&i| {
                self.conns[i].is_none()
                    || self.probe_replies[i].is_some()
            });
            if done {
                break;
            }
            if self.pump(None).is_err() {
                break;
            }
        }
        self.expect = Expect::Idle;
        let mut out = Vec::with_capacity(asked.len());
        for &i in asked {
            if let Some((kind, p)) = self.probe_replies[i].take() {
                out.push((i, kind, p));
            }
        }
        out
    }

    // --- rejoin admission --------------------------------------------

    /// Non-blocking accept loop (bounded per poll, like
    /// `RemotePool::poll_rejoins`): re-admit dead plain ids or whole
    /// dead groups.
    fn poll_rejoins(&mut self) {
        for _ in 0..self.conn_of.len().max(1) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if let Some((lo, hi)) = self.admit_rejoin(stream) {
                        self.rejoined.extend(lo..hi);
                    }
                }
                Err(_) => break, // WouldBlock or transient: done
            }
        }
    }

    /// Bounded blocking handshake for one reconnecting peer; returns
    /// the re-admitted global-id range. Malformed or conflicting
    /// registrations drop the connection (network-facing input).
    fn admit_rejoin(
        &mut self,
        stream: TcpStream,
    ) -> Option<(u32, u32)> {
        stream.set_nonblocking(false).ok()?;
        let handshake =
            self.deadline.unwrap_or(Duration::from_secs(1));
        stream.set_read_timeout(Some(handshake)).ok()?;
        let mut ch = Channel::new(stream).ok()?;
        let (tag, payload) = ch.recv().ok()?;
        let (kind, lo, hi, flags) = match tag {
            c2s::REGISTER => {
                let (id, dim, fam, flags) =
                    wire::decode_register(&payload).ok()?;
                let slot =
                    id.checked_sub(self.base)? as usize;
                let fam = match fam {
                    wire::FAMILY_FEDNL => ClientFamily::FedNL,
                    _ => ClientFamily::PP,
                };
                let ok = slot < self.conn_of.len()
                    && self.conn_of[slot] == NO_CONN
                    && dim as usize == self.d
                    && fam == self.family;
                if !ok {
                    return None;
                }
                (ConnKind::Plain { id }, id, id + 1, flags)
            }
            c2s::SHARD_REGISTER => {
                let (sid, lo, count, dim, fam, _flags) =
                    wire::decode_shard_register(&payload).ok()?;
                let hi = lo + count;
                let fam = match fam {
                    wire::FAMILY_FEDNL => ClientFamily::FedNL,
                    _ => ClientFamily::PP,
                };
                let lo_slot = lo.checked_sub(self.base)? as usize;
                let hi_slot = hi.checked_sub(self.base)? as usize;
                let ok = hi_slot <= self.conn_of.len()
                    && (lo_slot..hi_slot)
                        .all(|s| self.conn_of[s] == NO_CONN)
                    && dim as usize == self.d
                    && fam == self.family;
                if !ok {
                    return None;
                }
                // Hosted clients never stage; a rejoining group
                // carries no ack or fresh semantics of its own.
                (ConnKind::Group { sid, lo, hi }, lo, hi, 0u8)
            }
            _ => return None,
        };
        // α resync, as in `RemotePool::admit_rejoin`: a fresh-state
        // rejoiner must train with the negotiated α.
        if self.alpha > 0.0 {
            let sent = ch
                .send(s2c::SET_ALPHA, &wire::encode_scalar(self.alpha))
                .is_ok();
            let acked = sent
                && matches!(ch.recv(), Ok((t, _)) if t == c2s::ACK);
            if !acked {
                return None;
            }
        }
        let (stream, sent, received) = ch.into_parts();
        stream.set_read_timeout(None).ok()?;
        stream.set_nonblocking(true).ok()?;
        // Reuse a retired token slot when one exists.
        let idx = match self.conns.iter().position(|c| c.is_none()) {
            Some(i) => i,
            None => {
                self.conns.push(None);
                self.group_await.push(Vec::new());
                self.probe_replies.push(None);
                self.conns.len() - 1
            }
        };
        // A reply stashed by the slot's previous occupant must never
        // be attributed to (or block a reply from) the rejoiner.
        self.probe_replies[idx] = None;
        self.poller
            .register(stream.as_raw_fd(), idx as u64, true, false)
            .ok()?;
        self.conns[idx] = Some(Conn {
            stream,
            kind,
            decoder: FrameDecoder::new(),
            outq: VecDeque::new(),
            want_write: false,
            bytes_sent: sent,
            bytes_received: received,
        });
        for ci in lo..hi {
            self.conn_of[(ci - self.base) as usize] = idx as u32;
        }
        if let ConnKind::Plain { id } = kind {
            let slot = (id - self.base) as usize;
            self.acks[slot] = flags & wire::REG_WANTS_ACK != 0;
            if flags & wire::REG_FRESH != 0 {
                self.fresh.push(id);
            }
        }
        Some((lo, hi))
    }
}

impl ClientPool for EventPool {
    fn n_clients(&self) -> usize {
        self.conn_of.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn family(&self) -> ClientFamily {
        self.family
    }

    fn kind_name(&self) -> &'static str {
        "event"
    }

    fn default_alpha(&self) -> f64 {
        // NaN = "ask the clients" sentinel (see `RemotePool`).
        if self.alpha > 0.0 {
            self.alpha
        } else {
            f64::NAN
        }
    }

    fn set_alpha(&mut self, alpha: f64) -> f64 {
        let payload = wire::encode_scalar(alpha);
        let asked = self.ask_all(s2c::SET_ALPHA, &payload);
        let replies =
            self.collect_probe(&asked, c2s::ACK, c2s::ACK);
        let mut echoes = Vec::with_capacity(replies.len());
        for (_, _, p) in replies {
            if let Ok(a) = wire::decode_scalar(&p) {
                echoes.push(a);
            }
        }
        let (resolved, homogeneous) =
            wire::fold_alpha_echoes(alpha, echoes);
        // Heterogeneous echoes: install the resolved α uniformly
        // (second exchange only in that case — see `RemotePool`).
        if !homogeneous && resolved.is_finite() && resolved > 0.0 {
            let payload = wire::encode_scalar(resolved);
            let asked = self.ask_all(s2c::SET_ALPHA, &payload);
            let _ = self.collect_probe(&asked, c2s::ACK, c2s::ACK);
        }
        self.alpha = resolved;
        resolved
    }

    fn prepare_round(&mut self, _round: u64) {
        self.poll_rejoins();
    }

    fn dead_clients(&self) -> Vec<u32> {
        self.conn_of
            .iter()
            .enumerate()
            .filter(|(_, &c)| c == NO_CONN)
            .map(|(slot, _)| self.base + slot as u32)
            .collect()
    }

    fn take_missing(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.missing)
    }

    fn take_rejoined(&mut self) -> Vec<u32> {
        let mut out = std::mem::take(&mut self.rejoined);
        out.sort_unstable();
        out
    }

    fn take_fresh_rejoined(&mut self) -> Vec<u32> {
        let mut out = std::mem::take(&mut self.fresh);
        out.sort_unstable();
        out
    }

    fn ack_round(&mut self, round: u64, committed: &[u32]) {
        // One shared frame, queued only to registrants that asked
        // (`REG_WANTS_ACK`); mux-hosted group members never do. The
        // engine calls this between rounds (Expect::Idle), and
        // ROUND_ACK solicits no reply, so the state machine is
        // untouched. FIFO write queues order ROUND_ACK(k) before the
        // next round's command.
        let frame = Arc::new(encode_frame(
            s2c::ROUND_ACK,
            &wire::encode_round_ack(round),
        ));
        for &cid in committed {
            let Some(slot) = cid.checked_sub(self.base) else {
                continue;
            };
            let slot = slot as usize;
            if slot >= self.conn_of.len() || !self.acks[slot] {
                continue;
            }
            let c = self.conn_of[slot];
            if c == NO_CONN {
                continue;
            }
            let idx = c as usize;
            if matches!(
                self.conns[idx].as_ref().map(|c| c.kind),
                Some(ConnKind::Plain { .. })
            ) {
                let _ = self.queue_frame(idx, frame.clone());
            }
        }
    }

    fn resolve_staged(&mut self, client: u32, last_commit: Option<u64>) {
        let Some(slot) = client.checked_sub(self.base) else {
            return;
        };
        let slot = slot as usize;
        if slot >= self.conn_of.len() || !self.acks[slot] {
            return;
        }
        let c = self.conn_of[slot];
        if c == NO_CONN {
            return;
        }
        let idx = c as usize;
        if matches!(
            self.conns[idx].as_ref().map(|c| c.kind),
            Some(ConnKind::Plain { .. })
        ) {
            let frame = Arc::new(encode_frame(
                s2c::RESYNC,
                &wire::encode_resync(last_commit),
            ));
            let _ = self.queue_frame(idx, frame);
        }
    }

    fn pull_h_packed(&mut self) -> Option<Vec<Vec<f64>>> {
        // Exact resync needs every peer's stored Hᵢ. Mux groups host
        // simulated clients with no staging/fresh path, so a topology
        // containing one falls back to the approximate warm resync.
        if self.conn_of.iter().any(|&c| c == NO_CONN) {
            return None;
        }
        if self.conns.iter().flatten().any(|c| {
            matches!(c.kind, ConnKind::Group { .. })
        }) {
            return None;
        }
        let asked = self.ask_all(s2c::PULL_H, &[]);
        let replies =
            self.collect_probe(&asked, c2s::WARM, c2s::SHARD_WARM);
        let mut slots: Vec<Option<Vec<f64>>> =
            vec![None; self.conn_of.len()];
        for (_, kind, p) in replies {
            let ConnKind::Plain { id } = kind else {
                return None;
            };
            let pack = wire::decode_vec(&p).ok()?;
            slots[(id - self.base) as usize] = Some(pack);
        }
        slots.into_iter().collect()
    }

    fn set_reply_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline =
            deadline.map(|d| d.max(Duration::from_millis(1)));
    }

    fn set_round_mode(&mut self, mode: RoundMode) {
        self.mode = mode;
    }

    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    ) {
        assert!(
            self.outstanding == 0
                && self.ready_msgs.is_empty()
                && self.ready_sums.is_empty(),
            "previous round not fully drained"
        );
        self.expect = Expect::Round;
        // The plain-client broadcast is encoded **once** and shared by
        // every participant's write queue (built lazily: an all-group
        // topology never encodes it).
        let mut plain_frame: Option<Arc<Vec<u8>>> = None;
        // Per-group participant lists, collected first so each group
        // gets exactly one command frame.
        let mut group_parts: Vec<(usize, Vec<u32>)> = Vec::new();
        let all: Vec<u32>;
        let participants: &[u32] = match subset {
            Some(s) => s,
            None => {
                all = (0..self.conn_of.len() as u32)
                    .map(|slot| self.base + slot)
                    .collect();
                &all
            }
        };
        for &ci in participants {
            let slot = (ci - self.base) as usize;
            let c = self.conn_of[slot];
            if c == NO_CONN {
                self.missing.push(ci);
                continue;
            }
            let idx = c as usize;
            match self.conns[idx].as_ref().unwrap().kind {
                ConnKind::Plain { .. } => {
                    let frame = plain_frame
                        .get_or_insert_with(|| {
                            Arc::new(encode_frame(
                                s2c::ROUND,
                                &wire::encode_round(
                                    x, round, need_loss,
                                ),
                            ))
                        })
                        .clone();
                    self.awaiting[slot] = true;
                    self.outstanding += 1;
                    // A failed send retires the connection, which
                    // flips the awaiting flag into a missing cert.
                    let _ = self.queue_frame(idx, frame);
                }
                ConnKind::Group { .. } => {
                    match group_parts
                        .iter_mut()
                        .find(|(i, _)| *i == idx)
                    {
                        Some((_, part)) => part.push(ci),
                        None => group_parts.push((idx, vec![ci])),
                    }
                }
            }
        }
        let deadline_ms = self
            .deadline
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        for (idx, part) in group_parts {
            for &ci in &part {
                self.awaiting[(ci - self.base) as usize] = true;
            }
            self.outstanding += part.len();
            self.group_await[idx] = part;
            let payload = wire::encode_shard_round(
                x,
                round,
                need_loss,
                self.mode == RoundMode::Sums,
                deadline_ms,
                &self.group_await[idx],
            );
            let frame =
                Arc::new(encode_frame(s2c::SHARD_ROUND, &payload));
            let _ = self.queue_frame(idx, frame);
        }
        let now = Instant::now();
        self.due_plain = self.deadline.map(|d| now + d);
        self.due_group =
            self.deadline.map(|d| now + d + self.slack);
    }

    fn drain(&mut self) -> Vec<ClientMsg> {
        loop {
            if !self.ready_msgs.is_empty() {
                return std::mem::take(&mut self.ready_msgs);
            }
            if self.outstanding == 0 {
                self.expect = Expect::Idle;
                return Vec::new();
            }
            let now = Instant::now();
            self.expire_overdue(now);
            if self.outstanding == 0 {
                continue;
            }
            let timeout = self
                .next_due()
                .map(|t| t.saturating_duration_since(now));
            if self.pump(timeout).is_err() {
                // Poller failure: certify everything outstanding so
                // the engine can close the round.
                for idx in 0..self.conns.len() {
                    if self.conns[idx].is_some() {
                        self.retire(idx);
                    }
                }
            }
        }
    }

    fn drain_sums(&mut self) -> Vec<RoundSum> {
        loop {
            if !self.ready_sums.is_empty() {
                return std::mem::take(&mut self.ready_sums);
            }
            if !self.ready_msgs.is_empty() {
                // Plain participants reply with atoms even in sum
                // mode; fold them here (exact, so grouping-invariant).
                let batch = std::mem::take(&mut self.ready_msgs);
                return vec![RoundSum::from_msgs(&batch)];
            }
            if self.outstanding == 0 {
                self.expect = Expect::Idle;
                return Vec::new();
            }
            let now = Instant::now();
            self.expire_overdue(now);
            if self.outstanding == 0 {
                continue;
            }
            let timeout = self
                .next_due()
                .map(|t| t.saturating_duration_since(now));
            if self.pump(timeout).is_err() {
                for idx in 0..self.conns.len() {
                    if self.conns[idx].is_some() {
                        self.retire(idx);
                    }
                }
            }
        }
    }

    fn eval_loss_each(&mut self, x: &[f64]) -> Vec<(u32, f64)> {
        let payload = wire::encode_vec(x);
        let asked = self.ask_all(s2c::EVAL_LOSS, &payload);
        let replies = self.collect_probe(
            &asked,
            c2s::LOSS,
            c2s::SHARD_LOSSES,
        );
        let mut parts = Vec::new();
        for (idx, kind, p) in replies {
            match kind {
                ConnKind::Plain { id } => {
                    match wire::decode_scalar(&p) {
                        Ok(l) => parts.push((id, l)),
                        Err(_) => self.retire(idx),
                    }
                }
                ConnKind::Group { .. } => {
                    match wire::decode_id_scalars(&p) {
                        Ok(batch) => parts.extend(batch),
                        Err(_) => self.retire(idx),
                    }
                }
            }
        }
        parts
    }

    fn loss_grad_each(
        &mut self,
        x: &[f64],
    ) -> Vec<(u32, f64, Vec<f64>)> {
        let payload = wire::encode_vec(x);
        let asked = self.ask_all(s2c::LOSS_GRAD, &payload);
        let replies = self.collect_probe(
            &asked,
            c2s::GRAD,
            c2s::SHARD_GRADS,
        );
        let mut parts = Vec::new();
        for (idx, kind, p) in replies {
            match kind {
                ConnKind::Plain { id } => {
                    match wire::decode_loss_grad(&p) {
                        Ok((l, g)) => parts.push((id, l, g)),
                        Err(_) => self.retire(idx),
                    }
                }
                ConnKind::Group { .. } => {
                    match wire::decode_id_scalar_vecs(&p) {
                        Ok(batch) => parts.extend(batch),
                        Err(_) => self.retire(idx),
                    }
                }
            }
        }
        parts
    }

    fn loss_grad_sum(
        &mut self,
        x: &[f64],
    ) -> (
        crate::linalg::reduce::RepAcc,
        crate::linalg::reduce::RepVec,
        u32,
    ) {
        // Pre-reduced probe: groups fold next to their clients and
        // ship one exact accumulator pair (O(d) per group instead of
        // O(count·d)); plain clients upload dense gradients folded
        // here. Exactness keeps every mix bit-identical to the flat
        // fold.
        let payload = wire::encode_vec(x);
        let mut asked_plain = Vec::new();
        let mut asked_group = Vec::new();
        for idx in 0..self.conns.len() {
            let Some(conn) = self.conns[idx].as_ref() else {
                continue;
            };
            match conn.kind {
                ConnKind::Plain { .. } => asked_plain.push(idx),
                ConnKind::Group { .. } => asked_group.push(idx),
            }
        }
        let plain_frame =
            Arc::new(encode_frame(s2c::LOSS_GRAD, &payload));
        let group_frame =
            Arc::new(encode_frame(s2c::LOSS_GRAD_SUM, &payload));
        let mut asked = Vec::new();
        for &idx in &asked_plain {
            if self.queue_frame(idx, plain_frame.clone()) {
                asked.push(idx);
            }
        }
        for &idx in &asked_group {
            if self.queue_frame(idx, group_frame.clone()) {
                asked.push(idx);
            }
        }
        asked.sort_unstable();
        let replies = self.collect_probe(
            &asked,
            c2s::GRAD,
            c2s::SHARD_GRAD_SUM,
        );
        let mut loss = crate::linalg::reduce::RepAcc::new();
        let mut grad = crate::linalg::reduce::RepVec::new(self.d);
        let mut count = 0u32;
        for (idx, kind, p) in replies {
            match kind {
                ConnKind::Plain { .. } => {
                    match wire::decode_loss_grad(&p) {
                        Ok((l, g)) if g.len() == self.d => {
                            loss.accumulate(l);
                            grad.accumulate(&g);
                            count += 1;
                        }
                        _ => self.retire(idx),
                    }
                }
                ConnKind::Group { .. } => {
                    match wire::decode_shard_grad_sum(&p, self.d) {
                        Ok((c, l, g)) if g.len() == self.d => {
                            loss.merge(l);
                            grad.merge(g);
                            count += c;
                        }
                        _ => self.retire(idx),
                    }
                }
            }
        }
        (loss, grad, count)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        let payload = wire::encode_vec(x);
        let asked = self.ask_all(s2c::WARM_START, &payload);
        let replies = self.collect_probe(
            &asked,
            c2s::WARM,
            c2s::SHARD_WARM,
        );
        let mut packs = Vec::new();
        for (idx, kind, p) in replies {
            match kind {
                ConnKind::Plain { .. } => match wire::decode_vec(&p) {
                    Ok(v) => packs.push(v),
                    Err(_) => self.retire(idx),
                },
                ConnKind::Group { .. } => {
                    match wire::decode_vec_batch(&p) {
                        Ok(batch) => packs.extend(batch),
                        Err(_) => self.retire(idx),
                    }
                }
            }
        }
        packs
    }

    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
        assert!(
            self.conn_of.iter().all(|&c| c != NO_CONN),
            "init_state requires all clients registered"
        );
        let asked = self.ask_all(s2c::STATE, &[]);
        let replies = self.collect_probe(
            &asked,
            c2s::STATE,
            c2s::SHARD_STATES,
        );
        let mut parts: Vec<(u32, f64, Vec<f64>)> =
            Vec::with_capacity(self.conn_of.len());
        // Malformed state frames retire the sender like every other
        // probe decoder; the coverage assert below then reports the
        // bootstrap failure (mirrors `RelayPool::init_state`).
        for (idx, kind, p) in replies {
            match kind {
                ConnKind::Plain { id } => {
                    match wire::decode_loss_grad(&p) {
                        Ok((l, g)) => parts.push((id, l, g)),
                        Err(_) => self.retire(idx),
                    }
                }
                ConnKind::Group { .. } => {
                    match wire::decode_id_scalar_vecs(&p) {
                        Ok(batch) => parts.extend(batch),
                        Err(_) => self.retire(idx),
                    }
                }
            }
        }
        parts.sort_by_key(|&(id, _, _)| id);
        assert!(
            parts.iter().enumerate().all(|(i, &(id, _, _))| {
                id as usize == self.base as usize + i
            }),
            "init_state: incomplete client coverage"
        );
        parts.into_iter().map(|(_, l, g)| (l, g)).collect()
    }

    fn pull_state(&mut self, client: u32) -> Option<(f64, Vec<f64>)> {
        let slot = (client - self.base) as usize;
        let c = self.conn_of[slot];
        if c == NO_CONN {
            return None;
        }
        let idx = c as usize;
        let (cmd, payload, plain, group) =
            match self.conns[idx].as_ref().unwrap().kind {
                ConnKind::Plain { .. } => (
                    s2c::STATE,
                    Vec::new(),
                    c2s::STATE,
                    c2s::STATE,
                ),
                ConnKind::Group { .. } => {
                    let mut w =
                        crate::utils::ByteWriter::with_capacity(4);
                    w.put_u32(client);
                    (
                        s2c::SHARD_PULL,
                        w.into_vec(),
                        c2s::SHARD_PULLED,
                        c2s::SHARD_PULLED,
                    )
                }
            };
        let frame = Arc::new(encode_frame(cmd, &payload));
        if !self.queue_frame(idx, frame) {
            return None;
        }
        // Bounded wait (deadline or 5 s): a rejoiner that stalls again
        // must not take down the run the fault layer protects.
        let budget =
            self.deadline.unwrap_or(Duration::from_secs(5));
        let due = Instant::now() + budget;
        self.expect = Expect::Probe { plain, group };
        while self.conns[idx].is_some()
            && self.probe_replies[idx].is_none()
        {
            let now = Instant::now();
            if now >= due {
                break;
            }
            if self.pump(Some(due - now)).is_err() {
                break;
            }
        }
        self.expect = Expect::Idle;
        // A stray tag-matching frame from a *different* conn during
        // this one-target probe would be stashed and never taken —
        // wipe everything but our slot so it cannot masquerade as a
        // duplicate in a later exchange.
        for (i, r) in self.probe_replies.iter_mut().enumerate() {
            if i != idx {
                *r = None;
            }
        }
        let Some((kind, p)) = self.probe_replies[idx].take() else {
            self.retire(idx);
            return None;
        };
        let state = match kind {
            ConnKind::Plain { .. } => {
                wire::decode_loss_grad(&p).ok().map(Some)
            }
            ConnKind::Group { .. } => {
                wire::decode_shard_pulled(&p).ok()
            }
        };
        match state {
            Some(s) => s,
            None => {
                self.retire(idx);
                None
            }
        }
    }

    fn transport_bytes(&self) -> Option<(u64, u64)> {
        let up = self.retired_bytes.0
            + self
                .conns
                .iter()
                .flatten()
                .map(|c| c.bytes_received)
                .sum::<u64>();
        let down = self.retired_bytes.1
            + self
                .conns
                .iter()
                .flatten()
                .map(|c| c.bytes_sent)
                .sum::<u64>();
        Some((up, down))
    }
}

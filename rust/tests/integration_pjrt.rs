//! PJRT runtime integration: the AOT-compiled JAX/Pallas oracle must
//! agree with the native Rust oracle to near machine precision, and
//! FedNL must converge when driven by it.
//!
//! Requires `make artifacts`; tests are skipped (pass vacuously, with a
//! notice) when the artifact directory is missing so `cargo test` works
//! before the Python step.

use fednl::algorithms::{run_fednl, ClientState, Options};
use fednl::compressors::by_name;
use fednl::data::ClientShard;
use fednl::linalg::Mat;
use fednl::oracle::{LogisticOracle, Oracle};
use fednl::rng::{Pcg64, Rng};
use fednl::runtime::PjrtRuntime;

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts", "../../artifacts"] {
        if std::path::Path::new(&format!("{dir}/manifest.tsv")).exists() {
            return Some(dir.to_string());
        }
    }
    None
}

fn random_shard(d: usize, n: usize, seed: u64) -> ClientShard {
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut at = Mat::zeros(n, d);
    for r in 0..n {
        let lab = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        for c in 0..d - 1 {
            at.set(r, c, lab * rng.next_gaussian());
        }
        at.set(r, d - 1, lab);
    }
    ClientShard { client_id: 0, at }
}

#[test]
fn pjrt_oracle_matches_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let rt = PjrtRuntime::load(&dir).unwrap();
    // The 'tiny' artifact shape: d ≤ 16, n_i ≤ 128.
    let d = 16;
    let n_i = 100;
    let shard = random_shard(d, n_i, 42);
    let mut native = LogisticOracle::new(shard.clone(), 1e-3);
    let mut pjrt = rt.oracle_for_shard(&shard, 1e-3).unwrap();
    assert_eq!(pjrt.dim(), d);

    let mut rng = Pcg64::seed_from_u64(43);
    for trial in 0..5 {
        let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian() * 0.4).collect();
        let mut g1 = vec![0.0; d];
        let mut g2 = vec![0.0; d];
        let mut h1 = Mat::zeros(d, d);
        let mut h2 = Mat::zeros(d, d);
        let l1 = native.loss_grad_hessian(&x, &mut g1, &mut h1);
        let l2 = pjrt.loss_grad_hessian(&x, &mut g2, &mut h2);
        assert!(
            (l1 - l2).abs() < 1e-12 * l1.abs().max(1.0),
            "trial {trial}: loss {l1} vs {l2}"
        );
        for i in 0..d {
            assert!(
                (g1[i] - g2[i]).abs() < 1e-11,
                "trial {trial}: grad[{i}] {} vs {}",
                g1[i],
                g2[i]
            );
        }
        assert!(
            h1.max_abs_diff(&h2) < 1e-10,
            "trial {trial}: hessian diff {}",
            h1.max_abs_diff(&h2)
        );
    }
}

#[test]
fn fednl_converges_on_pjrt_oracle() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let rt = PjrtRuntime::load(&dir).unwrap();
    let d = 16;
    let n_clients = 3;
    let mut clients = Vec::new();
    for i in 0..n_clients {
        let shard = random_shard(d, 96, 50 + i as u64);
        let oracle = rt.oracle_for_shard(&shard, 1e-3).unwrap();
        clients.push(ClientState::new(
            i,
            Box::new(oracle),
            by_name("topk", d, 4, i as u64).unwrap(),
            None,
        ));
    }
    let opts = Options { rounds: 40, ..Default::default() };
    let trace = run_fednl(&mut clients, &opts, vec![0.0; d]);
    assert!(
        trace.last_grad_norm() < 1e-8,
        "PJRT-driven FedNL: {}",
        trace.last_grad_norm()
    );
}

#[test]
fn pjrt_and_native_produce_same_trajectory() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let rt = PjrtRuntime::load(&dir).unwrap();
    let d = 16;
    let shards: Vec<ClientShard> =
        (0..2).map(|i| random_shard(d, 80, 60 + i)).collect();
    let opts = Options { rounds: 15, track_loss: true, ..Default::default() };

    let mut native: Vec<ClientState> = shards
        .iter()
        .enumerate()
        .map(|(i, sh)| {
            ClientState::new(
                i,
                Box::new(LogisticOracle::new(sh.clone(), 1e-3)),
                by_name("randseqk", d, 4, i as u64).unwrap(),
                None,
            )
        })
        .collect();
    let t_native = run_fednl(&mut native, &opts, vec![0.0; d]);

    let mut pjrt: Vec<ClientState> = shards
        .iter()
        .enumerate()
        .map(|(i, sh)| {
            ClientState::new(
                i,
                Box::new(rt.oracle_for_shard(sh, 1e-3).unwrap()),
                by_name("randseqk", d, 4, i as u64).unwrap(),
                None,
            )
        })
        .collect();
    let t_pjrt = run_fednl(&mut pjrt, &opts, vec![0.0; d]);

    for (a, b) in t_native.records.iter().zip(&t_pjrt.records) {
        let rel = (a.grad_norm - b.grad_norm).abs() / (1.0 + a.grad_norm);
        assert!(rel < 1e-9, "round {}: {} vs {}", a.round, a.grad_norm, b.grad_norm);
    }
}

#[test]
fn manifest_shape_selection() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let rt = PjrtRuntime::load(&dir).unwrap();
    assert!(!rt.entries.is_empty());
    // Exact fit for the w8a shape.
    let e = rt.find_shape(301, 350).expect("w8a artifact");
    assert!(e.d_pad >= 301 && e.n_pad >= 350);
    // Impossible shape → None.
    assert!(rt.find_shape(100_000, 10).is_none());
}

//! Experiment harness: regenerates every table and figure of the paper
//! (DESIGN.md §4 experiment index).
//!
//! Each experiment has two scales:
//! * **ci** (default) — shrunk clients/rounds so the full suite runs in
//!   minutes on a laptop;
//! * **full** (`--full`) — the paper's parameters (n=142, r=1000,
//!   d=301 W8A shape; n=50 TCP clients for Table 3).
//!
//! Shapes, λ, x⁰=0, α=theoretical and the compressor set all follow the
//! paper; datasets are synthetic with matched shapes (DESIGN.md §2).

pub mod experiments;
pub mod setup;

pub use experiments::*;
pub use setup::*;

/// Experiment scale.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scale {
    Ci,
    Full,
}

/// Shared harness configuration.
#[derive(Debug, Clone)]
pub struct HarnessCfg {
    pub scale: Scale,
    /// Output directory for CSV traces and markdown tables.
    pub out_dir: String,
    /// Worker threads for the local simulator (0 = #cores).
    pub threads: usize,
    /// Force the sequential reference pool (`--seq`); by default
    /// experiments run on the multi-threaded simulator.
    pub seq: bool,
    /// Use the PJRT (AOT JAX/Pallas) oracle instead of the native one.
    pub pjrt: bool,
    /// Artifact dir for PJRT oracles.
    pub artifacts: String,
    pub seed: u64,
    /// Synthetic label-balance skew (`--label-bias B`; 0 = balanced,
    /// fed into [`crate::data::SynthSpec::label_bias`]).
    pub label_bias: f64,
    /// Client data partition (`--split power_law:G` /
    /// `--label-skew P`); the default is the paper's IID equal split.
    pub split: crate::data::SplitSpec,
}

impl Default for HarnessCfg {
    fn default() -> Self {
        Self {
            scale: Scale::Ci,
            out_dir: "results".into(),
            threads: 0,
            seq: false,
            pjrt: false,
            artifacts: "artifacts".into(),
            seed: 0x5EED,
            label_bias: 0.0,
            split: crate::data::SplitSpec::Even,
        }
    }
}

impl HarnessCfg {
    pub fn ensure_out_dir(&self) -> anyhow::Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        Ok(())
    }
}

//! Problem construction shared by experiments, examples and benches:
//! synthetic dataset → LIBSVM text → mmap parse → densify → shuffle →
//! split → client pools (the paper's full preparation pipeline §5,
//! steps (1)–(2) of its timing breakdown).

use anyhow::{Context, Result};

use super::{HarnessCfg, Scale};
use crate::algorithms::{ClientState, PPClientState};
use crate::compressors::by_name;
use crate::coordinator::{ClientPool, SeqPool, ThreadedPool};
use crate::data::{
    generate_synthetic, parse_libsvm_bytes, write_libsvm, Dataset, SynthSpec,
};
use crate::oracle::LogisticOracle;
use crate::runtime::PjrtRuntime;

/// Paper-matched problem shape.
#[derive(Debug, Clone)]
pub struct ProblemSpec {
    pub name: &'static str,
    /// d including intercept (W8A: 301).
    pub d: usize,
    /// Per-client samples at full scale.
    pub n_i_full: usize,
    /// Clients at full scale.
    pub n_clients_full: usize,
    pub lam: f64,
}

/// The paper's three benchmark datasets (Tables 1–3).
pub const W8A: ProblemSpec =
    ProblemSpec { name: "w8a", d: 301, n_i_full: 350, n_clients_full: 142, lam: 1e-3 };
pub const A9A: ProblemSpec =
    ProblemSpec { name: "a9a", d: 124, n_i_full: 229, n_clients_full: 142, lam: 1e-3 };
pub const PHISHING: ProblemSpec =
    ProblemSpec { name: "phishing", d: 69, n_i_full: 77, n_clients_full: 142, lam: 1e-3 };

impl ProblemSpec {
    /// (n_clients, n_i, rounds) at a given scale.
    pub fn dims(&self, scale: Scale) -> (usize, usize, u64) {
        match scale {
            Scale::Full => (self.n_clients_full, self.n_i_full, 1000),
            // CI scale: fewer clients/samples, but enough rounds for the
            // low-δ sparsifiers (δ = 8d / (d(d+1)/2) ≈ 16/d) to finish
            // their Hessian-learning phase at d ≈ 300.
            Scale::Ci => (16, self.n_i_full.min(128), 400),
        }
    }
}

/// A fully prepared problem: shards + initial point + metadata.
pub struct Problem {
    pub spec: ProblemSpec,
    pub dataset: Dataset,
    pub n_clients: usize,
    pub n_i: usize,
    pub rounds: u64,
    /// Seconds spent in data load+parse+split (paper's "initialization
    /// time", Tables 2–3).
    pub init_secs: f64,
}

/// Generate (through the real LIBSVM text round-trip) and split.
pub fn prepare_problem(
    spec: &ProblemSpec,
    cfg: &HarnessCfg,
) -> Result<Problem> {
    let sw = crate::utils::Stopwatch::start();
    let (n_clients, n_i, rounds) = spec.dims(cfg.scale);
    let total = n_clients * n_i + n_i; // headroom so leftovers exist
    let synth = generate_synthetic(&SynthSpec {
        d_raw: spec.d - 1,
        n_samples: total,
        density: 0.25,
        noise: 1.0,
        label_bias: cfg.label_bias,
        seed: cfg.seed,
    });
    // Real text round-trip: serializer → parser (exercises the paper's
    // §5.2 data path; at full scale this is tens of MB).
    let text = write_libsvm(&synth);
    let (samples, d_raw) =
        parse_libsvm_bytes(text.as_bytes()).context("parse synthetic")?;
    let mut ds = Dataset::from_libsvm(&samples, d_raw.max(spec.d - 1));
    ds.reshuffle(cfg.seed ^ 0xD5);
    let init_secs = sw.elapsed_secs();
    Ok(Problem {
        spec: spec.clone(),
        dataset: ds,
        n_clients,
        n_i,
        rounds,
        init_secs,
    })
}

impl Problem {
    pub fn d(&self) -> usize {
        self.dataset.d
    }

    /// Fresh FedNL clients with the given compressor ("topk", ...).
    pub fn clients(
        &self,
        compressor: &str,
        k_mult: usize,
        cfg: &HarnessCfg,
    ) -> Result<Vec<ClientState>> {
        let d = self.d();
        let shards = cfg.split.shards(
            &self.dataset,
            self.n_clients,
            self.n_i,
            cfg.seed,
        )?;
        let runtime = if cfg.pjrt {
            Some(PjrtRuntime::load(&cfg.artifacts)?)
        } else {
            None
        };
        shards
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                let comp = by_name(compressor, d, k_mult, cfg.seed + i as u64)?;
                let oracle: Box<dyn crate::oracle::Oracle> = match &runtime {
                    Some(rt) => {
                        Box::new(rt.oracle_for_shard(&sh, self.spec.lam)?)
                    }
                    None => Box::new(LogisticOracle::new(sh, self.spec.lam)),
                };
                Ok(ClientState::new(i, oracle, comp, None))
            })
            .collect()
    }

    /// FedNL-PP clients.
    pub fn pp_clients(
        &self,
        compressor: &str,
        k_mult: usize,
        cfg: &HarnessCfg,
        x0: &[f64],
    ) -> Result<Vec<PPClientState>> {
        let d = self.d();
        let shards = cfg.split.shards(
            &self.dataset,
            self.n_clients,
            self.n_i,
            cfg.seed,
        )?;
        shards
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                let comp = by_name(compressor, d, k_mult, cfg.seed + i as u64)?;
                Ok(PPClientState::new(
                    i,
                    Box::new(LogisticOracle::new(sh, self.spec.lam)),
                    comp,
                    None,
                    x0,
                ))
            })
            .collect()
    }

    /// Default pool: the multi-threaded simulator, so single-node runs
    /// use all cores out of the box. Falls back to the sequential
    /// reference pool when it cannot help (one client) or when the user
    /// forces it (`--seq` / `cfg.seq`). Trajectories are bit-identical
    /// across the two pools for the whole algorithm family: round
    /// replies commit in client-id order (buffer-and-commit) and the
    /// loss/gradient reductions also reduce in client-id order.
    pub fn pool(
        &self,
        compressor: &str,
        k_mult: usize,
        cfg: &HarnessCfg,
    ) -> Result<Box<dyn ClientPool>> {
        if cfg.seq || self.n_clients == 1 {
            Ok(Box::new(self.seq_pool(compressor, k_mult, cfg)?))
        } else {
            Ok(Box::new(self.threaded_pool(compressor, k_mult, cfg)?))
        }
    }

    /// Sequential pool.
    pub fn seq_pool(
        &self,
        compressor: &str,
        k_mult: usize,
        cfg: &HarnessCfg,
    ) -> Result<SeqPool> {
        Ok(SeqPool::new(self.clients(compressor, k_mult, cfg)?))
    }

    /// Threaded pool (the paper's single-node simulator).
    pub fn threaded_pool(
        &self,
        compressor: &str,
        k_mult: usize,
        cfg: &HarnessCfg,
    ) -> Result<ThreadedPool> {
        Ok(ThreadedPool::new(
            self.clients(compressor, k_mult, cfg)?,
            cfg.threads,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_ci_problem() {
        let cfg = HarnessCfg::default();
        let p = prepare_problem(&PHISHING, &cfg).unwrap();
        assert_eq!(p.d(), 69);
        assert_eq!(p.n_clients, 16);
        assert!(p.init_secs > 0.0);
        let pool = p.seq_pool("topk", 8, &cfg).unwrap();
        assert_eq!(pool.clients.len(), 16);
    }

    #[test]
    fn default_pool_is_threaded_unless_forced() {
        let cfg = HarnessCfg::default();
        let p = prepare_problem(&PHISHING, &cfg).unwrap();
        let pool = p.pool("topk", 2, &cfg).unwrap();
        assert_eq!(pool.kind_name(), "threaded");
        assert_eq!(pool.n_clients(), 16);
        let seq_cfg = HarnessCfg { seq: true, ..HarnessCfg::default() };
        let pool = p.pool("topk", 2, &seq_cfg).unwrap();
        assert_eq!(pool.kind_name(), "seq");
        assert_eq!(pool.n_clients(), 16);
    }

    #[test]
    fn spec_dims_scale() {
        assert_eq!(W8A.dims(Scale::Full), (142, 350, 1000));
        let (n, ni, r) = W8A.dims(Scale::Ci);
        assert!(n < 142 && ni <= 350 && r < 1000);
    }
}

"""AOT export: lower the Layer-2 oracle to HLO *text* artifacts.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Artifacts (one per padded dataset shape, plus the grad-only variant used
by line search / baselines):

    artifacts/logistic_oracle_d{D}_n{N}.hlo.txt
    artifacts/logistic_grad_d{D}_n{N}.hlo.txt
    artifacts/manifest.json      — shape registry consumed by rust runtime

Shapes cover the paper's three datasets (padded) plus the small shapes the
examples, integration tests and benches use.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402

# (name, raw d, raw n_i) — paper Table 2 dataset shapes + harness shapes.
SHAPES: list[tuple[str, int, int]] = [
    ("w8a", 301, 350),        # paper §5: d=301, n_i=350
    ("a9a", 124, 229),        # Table 2
    ("phishing", 69, 77),     # Table 2
    ("quickstart", 64, 128),  # examples/quickstart
    ("tiny", 16, 64),         # integration tests
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_shape(d_raw: int, n_raw: int) -> tuple[int, int, str, str]:
    d, n = model.pad_shapes(d_raw, n_raw)
    args = model.make_example_args(d, n)
    oracle_hlo = to_hlo_text(jax.jit(model.oracle).lower(*args))
    grad_hlo = to_hlo_text(jax.jit(model.grad_only).lower(*args))
    return d, n, oracle_hlo, grad_hlo


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact dir")
    ap.add_argument(
        "--shapes",
        default="",
        help="comma-separated name list to restrict (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = {s for s in args.shapes.split(",") if s}

    manifest = {"format": "hlo-text", "dtype": "f64", "entries": []}
    for name, d_raw, n_raw in SHAPES:
        if only and name not in only:
            continue
        d, n, oracle_hlo, grad_hlo = lower_shape(d_raw, n_raw)
        o_file = f"logistic_oracle_d{d}_n{n}.hlo.txt"
        g_file = f"logistic_grad_d{d}_n{n}.hlo.txt"
        with open(os.path.join(args.out, o_file), "w") as f:
            f.write(oracle_hlo)
        with open(os.path.join(args.out, g_file), "w") as f:
            f.write(grad_hlo)
        manifest["entries"].append(
            {
                "name": name,
                "d_raw": d_raw,
                "n_raw": n_raw,
                "d_pad": d,
                "n_pad": n,
                "oracle": o_file,
                "grad": g_file,
            }
        )
        print(f"[aot] {name}: ({d_raw},{n_raw}) -> padded ({d},{n})")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # TSV twin for the self-contained Rust loader (no JSON dependency).
    with open(os.path.join(args.out, "manifest.tsv"), "w") as f:
        for e in manifest["entries"]:
            f.write(
                f"{e['name']}\t{e['d_raw']}\t{e['n_raw']}\t{e['d_pad']}\t"
                f"{e['n_pad']}\t{e['oracle']}\t{e['grad']}\n"
            )
    print(f"[aot] wrote {len(manifest['entries'])} shapes to {args.out}")


if __name__ == "__main__":
    main()

//! End-to-end single-node integration: the full data pipeline feeding
//! every algorithm of the family, across both in-process transports.

use fednl::algorithms::{
    run_fednl, run_fednl_ls, run_fednl_ls_pool, run_fednl_pool,
    run_fednl_pp, run_fednl_pp_pool, ClientState, LineSearchParams,
    OnMissing, Options, PPClientState, RoundPolicy, UpdateRule,
};
use fednl::compressors::{by_name, ALL_NAMES};
use fednl::coordinator::{
    ClientPool, CorruptMode, FaultPlan, FaultPool, SeqPool, ShardedPool,
    ThreadedPool,
};
use fednl::data::{
    generate_synthetic, parse_libsvm_bytes, write_libsvm, Dataset, SynthSpec,
};
use fednl::linalg::Mat;
use fednl::oracle::{LogisticOracle, Oracle};

fn problem(
    d_raw: usize,
    n_clients: usize,
    n_i: usize,
    seed: u64,
) -> (Dataset, usize) {
    let spec = SynthSpec {
        d_raw,
        n_samples: n_clients * n_i,
        density: 0.4,
        noise: 1.0,
        label_bias: 0.0,
        seed,
    };
    // Text round-trip on every test: generator → LIBSVM → parser.
    let text = write_libsvm(&generate_synthetic(&spec));
    let (samples, got_d) = parse_libsvm_bytes(text.as_bytes()).unwrap();
    let mut ds = Dataset::from_libsvm(&samples, got_d.max(d_raw));
    ds.reshuffle(seed ^ 0xABCD);
    let d = ds.d;
    (ds, d)
}

fn clients_k(
    ds: &Dataset,
    n: usize,
    comp: &str,
    seed: u64,
    k_mult: usize,
) -> Vec<ClientState> {
    ds.split_even(n)
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, sh)| {
            ClientState::new(
                i,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name(comp, ds.d, k_mult, seed + i as u64).unwrap(),
                None,
            )
        })
        .collect()
}

fn clients(ds: &Dataset, n: usize, comp: &str, seed: u64) -> Vec<ClientState> {
    clients_k(ds, n, comp, seed, 8)
}

#[test]
fn full_pipeline_all_compressors_all_algorithms() {
    let (ds, d) = problem(12, 6, 60, 101);
    for comp in ALL_NAMES {
        // FedNL
        let mut cs = clients(&ds, 6, comp, 7);
        let opts = Options { rounds: 60, ..Default::default() };
        let t1 = run_fednl(&mut cs, &opts, vec![0.0; d]);
        assert!(t1.last_grad_norm() < 1e-8, "FedNL/{comp}: {}", t1.last_grad_norm());
        // FedNL-LS
        let mut cs = clients(&ds, 6, comp, 7);
        let t2 = run_fednl_ls(
            &mut cs,
            &opts,
            &LineSearchParams::default(),
            vec![0.0; d],
        );
        assert!(t2.last_grad_norm() < 1e-8, "LS/{comp}: {}", t2.last_grad_norm());
        // FedNL-PP (τ = half)
        let mut pps: Vec<PPClientState> = ds
            .split_even(6)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                PPClientState::new(
                    i,
                    Box::new(LogisticOracle::new(sh, 1e-3)),
                    by_name(comp, d, 8, 7 + i as u64).unwrap(),
                    None,
                    &vec![0.0; d],
                )
            })
            .collect();
        let opts_pp = Options { rounds: 150, ..Default::default() };
        let t3 = run_fednl_pp(&mut pps, &opts_pp, 3, 5, vec![0.0; d]);
        assert!(t3.last_grad_norm() < 1e-6, "PP/{comp}: {}", t3.last_grad_norm());
    }
}

#[test]
fn seq_and_threaded_transports_agree() {
    let (ds, d) = problem(10, 8, 40, 102);
    let opts = Options { rounds: 30, track_loss: true, ..Default::default() };
    let mut seq = SeqPool::new(clients(&ds, 8, "randk", 3));
    let t_seq = run_fednl_pool(&mut seq, &opts, vec![0.0; d], "seq");
    for workers in [1, 2, 5, 8] {
        let mut thr = ThreadedPool::new(clients(&ds, 8, "randk", 3), workers);
        let t_thr = run_fednl_pool(&mut thr, &opts, vec![0.0; d], "thr");
        for (a, b) in t_seq.records.iter().zip(&t_thr.records) {
            assert_eq!(a.grad_norm, b.grad_norm, "workers={workers}");
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.bytes_up, b.bytes_up);
        }
    }
}

#[test]
fn update_rules_reach_same_solution() {
    let (ds, d) = problem(9, 4, 50, 103);
    let opts_a = Options { rounds: 70, track_loss: true, ..Default::default() };
    let opts_b = Options {
        rounds: 70,
        rule: UpdateRule::ProjectMu(1e-3),
        warm_start: true,
        track_loss: true,
        ..Default::default()
    };
    let mut c1 = clients(&ds, 4, "topk", 11);
    let mut c2 = clients(&ds, 4, "topk", 11);
    let t1 = run_fednl(&mut c1, &opts_a, vec![0.0; d]);
    let t2 = run_fednl(&mut c2, &opts_b, vec![0.0; d]);
    assert!(t1.last_grad_norm() < 1e-8);
    assert!(t2.last_grad_norm() < 1e-8);
    let l1 = t1.records.last().unwrap().loss;
    let l2 = t2.records.last().unwrap().loss;
    assert!((l1 - l2).abs() < 1e-9, "f* mismatch: {l1} vs {l2}");
}

#[test]
fn compressed_runs_beat_identity_on_bytes() {
    // Paper Table 1's accounting: at a FIXED round budget all
    // compressors converge (superlinearly, to ≈0), but the sparsified
    // ones aggregate far less data at the master (49.5 GB for Ident vs
    // 4.2 GB TopK vs 0.36 GB TopLEK in the paper). Requires
    // k = 4d ≪ d(d+1)/2.
    let (ds, d) = problem(40, 4, 80, 104);
    let rounds = 250;
    let run = |comp: &str| {
        let mut cs = clients_k(&ds, 4, comp, 21, 4);
        let opts = Options { rounds, ..Default::default() };
        let t = run_fednl(&mut cs, &opts, vec![0.0; d]);
        assert!(
            t.last_grad_norm() <= 1e-8,
            "{comp} did not converge: {}",
            t.last_grad_norm()
        );
        t.total_bytes_up()
    };
    let ident = run("identity");
    for comp in ["topk", "randk", "randseqk", "toplek"] {
        let bytes = run(comp);
        assert!(
            bytes < ident / 2,
            "{comp} used {bytes} B ≥ half of identity's {ident} B"
        );
    }
}

#[test]
fn deterministic_across_runs() {
    let (ds, d) = problem(8, 3, 40, 105);
    let opts = Options { rounds: 25, ..Default::default() };
    let mut a = clients(&ds, 3, "toplek", 9);
    let mut b = clients(&ds, 3, "toplek", 9);
    let ta = run_fednl(&mut a, &opts, vec![0.0; d]);
    let tb = run_fednl(&mut b, &opts, vec![0.0; d]);
    for (ra, rb) in ta.records.iter().zip(&tb.records) {
        assert_eq!(ra.grad_norm, rb.grad_norm);
        assert_eq!(ra.bytes_up, rb.bytes_up);
    }
}

fn pp_clients(
    ds: &Dataset,
    n: usize,
    comp: &str,
    seed: u64,
    x0: &[f64],
) -> Vec<PPClientState> {
    ds.split_even(n)
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, sh)| {
            PPClientState::new(
                i,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name(comp, ds.d, 8, seed + i as u64).unwrap(),
                None,
                x0,
            )
        })
        .collect()
}

#[test]
fn fednl_pp_cross_transport_bit_identical() {
    // FedNL-PP through the unified round engine: the slice reference,
    // SeqPool and ThreadedPool (several worker counts) must produce
    // bit-identical trajectories — same seeded participation subsets,
    // same commit order (selection order), same out-of-band ‖∇f‖
    // reduction (ascending client id on every transport).
    let (ds, d) = problem(9, 6, 40, 107);
    let x0 = vec![0.0; d];
    let opts = Options { rounds: 40, ..Default::default() };
    let (tau, seed) = (2usize, 99u64);

    let mut ref_cs = pp_clients(&ds, 6, "topk", 5, &x0);
    let t_ref = run_fednl_pp(&mut ref_cs, &opts, tau, seed, x0.clone());
    let g0 = t_ref.records[0].grad_norm;
    assert!(
        t_ref.last_grad_norm() < g0 / 10.0,
        "no PP progress: {} → {}",
        g0,
        t_ref.last_grad_norm()
    );

    let mut seq = SeqPool::new(pp_clients(&ds, 6, "topk", 5, &x0));
    let t_seq =
        run_fednl_pp_pool(&mut seq, &opts, tau, seed, x0.clone(), "pp-seq");

    for (a, b) in t_ref.records.iter().zip(&t_seq.records) {
        assert_eq!(a.grad_norm, b.grad_norm, "seq round {}", a.round);
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.bytes_up, b.bytes_up);
    }

    for workers in [1usize, 2, 6] {
        let mut thr =
            ThreadedPool::new(pp_clients(&ds, 6, "topk", 5, &x0), workers);
        let t_thr = run_fednl_pp_pool(
            &mut thr,
            &opts,
            tau,
            seed,
            x0.clone(),
            "pp-thr",
        );
        for (a, b) in t_ref.records.iter().zip(&t_thr.records) {
            assert_eq!(
                a.grad_norm, b.grad_norm,
                "workers={workers} round {}",
                a.round
            );
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.bytes_up, b.bytes_up);
        }
    }
}

/// An oracle whose Hessian evaluation is artificially slow — a
/// simulated straggler client.
struct SlowOracle {
    inner: LogisticOracle,
    delay: std::time::Duration,
}

impl Oracle for SlowOracle {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn loss(&mut self, x: &[f64]) -> f64 {
        self.inner.loss(x)
    }

    fn loss_grad(&mut self, x: &[f64], g: &mut [f64]) -> f64 {
        self.inner.loss_grad(x, g)
    }

    fn loss_grad_hessian(
        &mut self,
        x: &[f64],
        g: &mut [f64],
        h: &mut Mat,
    ) -> f64 {
        std::thread::sleep(self.delay);
        self.inner.loss_grad_hessian(x, g, h)
    }
}

#[test]
fn straggler_reply_order_does_not_change_trajectory() {
    // Client 0 sleeps 20 ms per Hessian evaluation, so on a pool with
    // one worker per client its round reply arrives *last* while the
    // other replies wait in the commit buffer. Buffer-and-commit must
    // still aggregate in ascending client id order: the trajectory is
    // bit-identical to the no-straggler sequential reference.
    let (ds, d) = problem(8, 4, 40, 108);
    let make = |slow: bool| -> Vec<ClientState> {
        ds.split_even(4)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                let base = LogisticOracle::new(sh, 1e-3);
                let oracle: Box<dyn Oracle> = if slow && i == 0 {
                    Box::new(SlowOracle {
                        inner: base,
                        delay: std::time::Duration::from_millis(20),
                    })
                } else {
                    Box::new(base)
                };
                ClientState::new(
                    i,
                    oracle,
                    by_name("randseqk", d, 8, 60 + i as u64).unwrap(),
                    None,
                )
            })
            .collect()
    };
    let opts = Options { rounds: 6, track_loss: true, ..Default::default() };
    let mut seq = SeqPool::new(make(false));
    let t_seq = run_fednl_pool(&mut seq, &opts, vec![0.0; d], "seq");
    let mut thr = ThreadedPool::new(make(true), 4);
    let t_thr = run_fednl_pool(&mut thr, &opts, vec![0.0; d], "straggler");
    assert_eq!(t_seq.records.len(), t_thr.records.len());
    for (a, b) in t_seq.records.iter().zip(&t_thr.records) {
        assert_eq!(a.grad_norm, b.grad_norm, "round {}", a.round);
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.bytes_up, b.bytes_up);
    }
}

#[test]
fn fednl_quorum_drop_bit_identical_across_pools() {
    // One client killed for a window and one one-round drop: under the
    // Drop policy the engine rescales ∇f/lᵏ to the survivors. The same
    // plan must produce bit-identical trajectories (and identical
    // committed/missing accounting) on SeqPool and ThreadedPool.
    let (ds, d) = problem(9, 5, 40, 120);
    let plan = FaultPlan::parse("kill@3:1-9,drop@11:4").unwrap();
    let opts = Options {
        rounds: 40,
        track_loss: true,
        policy: RoundPolicy {
            quorum: Some(3),
            deadline_ms: None,
            on_missing: OnMissing::Drop,
        },
        ..Default::default()
    };
    let mut seq = FaultPool::new(
        SeqPool::new(clients(&ds, 5, "randseqk", 13)),
        plan.clone(),
    );
    let t_seq = run_fednl_pool(&mut seq, &opts, vec![0.0; d], "fault-seq");
    // The fault window actually engaged and healed.
    let r3 = &t_seq.records[3];
    assert_eq!((r3.committed, r3.missing), (4, 1), "kill window");
    let r11 = &t_seq.records[11];
    assert_eq!((r11.committed, r11.missing), (4, 1), "drop round");
    let r15 = &t_seq.records[15];
    assert_eq!((r15.committed, r15.missing), (5, 0), "post-rejoin");
    for workers in [1usize, 2, 5] {
        let mut thr = FaultPool::new(
            ThreadedPool::new(clients(&ds, 5, "randseqk", 13), workers),
            plan.clone(),
        );
        let t_thr =
            run_fednl_pool(&mut thr, &opts, vec![0.0; d], "fault-thr");
        assert_eq!(t_seq.records.len(), t_thr.records.len());
        for (a, b) in t_seq.records.iter().zip(&t_thr.records) {
            assert_eq!(
                a.grad_norm.to_bits(),
                b.grad_norm.to_bits(),
                "workers={workers} round {}",
                a.round
            );
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.bytes_up, b.bytes_up);
            assert_eq!((a.committed, a.missing), (b.committed, b.missing));
        }
    }
    // Despite the losses the run still converges after the rejoin.
    assert!(
        t_seq.last_grad_norm() < 1e-6,
        "no convergence under faults: {}",
        t_seq.last_grad_norm()
    );
}

#[test]
fn fednl_reuse_replays_stale_contribution() {
    // Under Reuse a frozen client's last committed message stands in:
    // every round still commits n messages (no holes), and after the
    // rejoin the run converges fully.
    let (ds, d) = problem(8, 4, 40, 121);
    let plan = FaultPlan::parse("kill@2:1-7").unwrap();
    let opts = Options {
        rounds: 50,
        policy: RoundPolicy {
            quorum: Some(2),
            deadline_ms: None,
            on_missing: OnMissing::Reuse,
        },
        ..Default::default()
    };
    let mut seq = FaultPool::new(
        SeqPool::new(clients(&ds, 4, "topk", 17)),
        plan.clone(),
    );
    let t_seq = run_fednl_pool(&mut seq, &opts, vec![0.0; d], "reuse-seq");
    for r in &t_seq.records {
        assert_eq!(r.committed, 4, "round {}: reuse must fill holes", r.round);
        assert_eq!(r.missing, 0, "round {}", r.round);
    }
    assert!(t_seq.last_grad_norm() < 1e-6, "{}", t_seq.last_grad_norm());
    // Bit-identical on the threaded pool.
    let mut thr = FaultPool::new(
        ThreadedPool::new(clients(&ds, 4, "topk", 17), 4),
        plan,
    );
    let t_thr = run_fednl_pool(&mut thr, &opts, vec![0.0; d], "reuse-thr");
    for (a, b) in t_seq.records.iter().zip(&t_thr.records) {
        assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits());
    }
}

#[test]
fn pp_resample_avoids_dead_and_stays_bit_identical() {
    // FedNL-PP with a client killed for a long window under Resample:
    // the sampler never hands the dead client a slot, so no round
    // loses a contribution, and the trajectories agree bitwise across
    // pools. After the rejoin the client is resynced and the run
    // converges fully.
    let (ds, d) = problem(9, 6, 40, 122);
    let x0 = vec![0.0; d];
    let plan = FaultPlan::parse("kill@3:2-20").unwrap();
    let opts = Options {
        rounds: 80,
        policy: RoundPolicy {
            quorum: Some(2),
            deadline_ms: None,
            on_missing: OnMissing::Resample,
        },
        ..Default::default()
    };
    let (tau, seed) = (3usize, 55u64);
    let mut seq = FaultPool::new(
        SeqPool::new(pp_clients(&ds, 6, "topk", 5, &x0)),
        plan.clone(),
    );
    let t_seq = run_fednl_pp_pool(
        &mut seq,
        &opts,
        tau,
        seed,
        x0.clone(),
        "pp-resample-seq",
    );
    for r in &t_seq.records {
        assert_eq!(r.missing, 0, "round {}: resample left a hole", r.round);
        assert_eq!(r.committed, tau as u32, "round {}", r.round);
    }
    assert!(t_seq.last_grad_norm() < 1e-5, "{}", t_seq.last_grad_norm());
    for workers in [1usize, 3, 6] {
        let mut thr = FaultPool::new(
            ThreadedPool::new(pp_clients(&ds, 6, "topk", 5, &x0), workers),
            plan.clone(),
        );
        let t_thr = run_fednl_pp_pool(
            &mut thr,
            &opts,
            tau,
            seed,
            x0.clone(),
            "pp-resample-thr",
        );
        for (a, b) in t_seq.records.iter().zip(&t_thr.records) {
            assert_eq!(
                a.grad_norm.to_bits(),
                b.grad_norm.to_bits(),
                "workers={workers} round {}",
                a.round
            );
            assert_eq!(a.bytes_up, b.bytes_up);
            assert_eq!((a.committed, a.missing), (b.committed, b.missing));
        }
    }
}

#[test]
fn pp_kill_rejoin_resyncs_exactly() {
    // A frozen-then-thawed PP client is resynced through the STATE
    // pull; because its state never moved, the resync is a no-op and
    // the post-rejoin run converges fully — bit-identically across
    // pools (including the rejoin-round STATE-pull byte accounting).
    let (ds, d) = problem(8, 5, 40, 123);
    let x0 = vec![0.0; d];
    let plan = FaultPlan::parse("kill@4:1-12").unwrap();
    let opts = Options {
        rounds: 80,
        policy: RoundPolicy {
            quorum: Some(1),
            deadline_ms: None,
            on_missing: OnMissing::Drop,
        },
        ..Default::default()
    };
    let (tau, seed) = (3usize, 77u64);
    let mut seq = FaultPool::new(
        SeqPool::new(pp_clients(&ds, 5, "randk", 9, &x0)),
        plan.clone(),
    );
    let t_seq = run_fednl_pp_pool(
        &mut seq,
        &opts,
        tau,
        seed,
        x0.clone(),
        "pp-rejoin-seq",
    );
    assert!(
        t_seq.records.iter().any(|r| r.missing > 0),
        "kill window never engaged"
    );
    assert!(
        t_seq
            .records
            .iter()
            .filter(|r| r.round >= 12)
            .all(|r| r.missing == 0),
        "losses after the rejoin"
    );
    assert!(t_seq.last_grad_norm() < 1e-5, "{}", t_seq.last_grad_norm());
    let mut thr = FaultPool::new(
        ThreadedPool::new(pp_clients(&ds, 5, "randk", 9, &x0), 5),
        plan,
    );
    let t_thr =
        run_fednl_pp_pool(&mut thr, &opts, tau, seed, x0, "pp-rejoin-thr");
    for (a, b) in t_seq.records.iter().zip(&t_thr.records) {
        assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits());
        assert_eq!(a.bytes_up, b.bytes_up);
        assert_eq!(a.bytes_down, b.bytes_down);
    }
}

#[test]
fn sharded_matches_unsharded_bitwise_all_algorithms() {
    // The shard tier's headline invariant, in-process: FedNL, FedNL-LS
    // and FedNL-PP trajectories are bit-identical between the flat
    // sequential reference (S=1) and the sharded tier at S ∈ {2, 3},
    // over both sequential and threaded shard aggregators. Shards
    // forward per-client atoms in commit order, so the master's f64
    // arithmetic never re-groups (see coordinator::shard).
    // (Since the reproducible-summation layer the FedNL/LS shard path
    // pre-reduces — SHARD_SUM frames replace per-client atoms — so the
    // byte columns are compared only for FedNL-PP, which stays on the
    // atom path; the payload cut is tracked by BENCH_shard.json.)
    let (ds, d) = problem(10, 6, 40, 130);
    let x0 = vec![0.0; d];
    let opts = Options { rounds: 25, track_loss: true, ..Default::default() };

    // FedNL + FedNL-LS references.
    let mut seq = SeqPool::new(clients(&ds, 6, "randseqk", 19));
    let t_fednl = run_fednl_pool(&mut seq, &opts, x0.clone(), "flat");
    let mut seq = SeqPool::new(clients(&ds, 6, "randseqk", 19));
    let t_ls = run_fednl_ls_pool(
        &mut seq,
        &opts,
        &LineSearchParams::default(),
        x0.clone(),
        "flat-ls",
    );
    // FedNL-PP reference (τ crossing shard boundaries).
    let (tau, seed) = (3usize, 91u64);
    let opts_pp = Options { rounds: 40, ..Default::default() };
    let mut seq = SeqPool::new(pp_clients(&ds, 6, "topk", 5, &x0));
    let t_pp = run_fednl_pp_pool(
        &mut seq,
        &opts_pp,
        tau,
        seed,
        x0.clone(),
        "flat-pp",
    );
    assert!(t_fednl.last_grad_norm() < 1e-8);

    let same = |a: &fednl::metrics::Trace, b: &fednl::metrics::Trace,
                tag: &str, check_bytes: bool| {
        assert_eq!(a.records.len(), b.records.len(), "{tag}");
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(
                ra.grad_norm.to_bits(),
                rb.grad_norm.to_bits(),
                "{tag} round {}",
                ra.round
            );
            assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "{tag}");
            if check_bytes {
                assert_eq!(ra.bytes_up, rb.bytes_up, "{tag}");
            }
            assert_eq!(ra.bytes_down, rb.bytes_down, "{tag}");
        }
    };

    for s in [2usize, 3] {
        // Sequential shard aggregators.
        let mut pool = ShardedPool::new_seq(clients(&ds, 6, "randseqk", 19), s);
        let t = run_fednl_pool(&mut pool, &opts, x0.clone(), "sh");
        same(&t_fednl, &t, &format!("fednl S={s} seq"), false);
        // The pre-reduction actually engaged: every shard forwarded
        // SHARD_SUM payload, O(d) per round per shard.
        let payload: u64 =
            pool.shard_stats().iter().map(|st| st.payload_bytes).sum();
        assert!(payload > 0, "S={s}: no pre-reduced payload recorded");
        // Threaded shard aggregators (replies stream out of order
        // within each shard; the exact sums make the order moot).
        let mut pool =
            ShardedPool::new_threaded(clients(&ds, 6, "randseqk", 19), s, 2);
        let t = run_fednl_pool(&mut pool, &opts, x0.clone(), "sh-thr");
        same(&t_fednl, &t, &format!("fednl S={s} threaded"), false);

        let mut pool = ShardedPool::new_seq(clients(&ds, 6, "randseqk", 19), s);
        let t = run_fednl_ls_pool(
            &mut pool,
            &opts,
            &LineSearchParams::default(),
            x0.clone(),
            "sh-ls",
        );
        same(&t_ls, &t, &format!("ls S={s}"), false);

        let mut pool =
            ShardedPool::new_seq(pp_clients(&ds, 6, "topk", 5, &x0), s);
        let t = run_fednl_pp_pool(
            &mut pool,
            &opts_pp,
            tau,
            seed,
            x0.clone(),
            "sh-pp",
        );
        same(&t_pp, &t, &format!("pp S={s} seq"), true);
        let mut pool = ShardedPool::new_threaded(
            pp_clients(&ds, 6, "topk", 5, &x0),
            s,
            2,
        );
        let t = run_fednl_pp_pool(
            &mut pool,
            &opts_pp,
            tau,
            seed,
            x0.clone(),
            "sh-pp-thr",
        );
        same(&t_pp, &t, &format!("pp S={s} threaded"), true);
    }
}

#[test]
fn sharded_under_fault_plan_bit_identical() {
    // PR 3's fault machinery composes through the tier: the same
    // FaultPlan (kill window + one-round drop, quorum rounds) yields
    // bit-identical trajectories on the flat pool and on the sharded
    // tier at S ∈ {2, 3} — including committed/missing accounting.
    let (ds, d) = problem(9, 6, 40, 131);
    let x0 = vec![0.0; d];
    let plan = FaultPlan::parse("kill@3:1-12,drop@14:5").unwrap();
    let opts = Options {
        rounds: 30,
        track_loss: true,
        policy: RoundPolicy {
            quorum: Some(3),
            deadline_ms: None,
            on_missing: OnMissing::Drop,
        },
        ..Default::default()
    };
    let mut flat = FaultPool::new(
        SeqPool::new(clients(&ds, 6, "topk", 23)),
        plan.clone(),
    );
    let t_flat = run_fednl_pool(&mut flat, &opts, x0.clone(), "flat");
    assert!(t_flat.records.iter().any(|r| r.missing > 0));
    for s in [2usize, 3] {
        let mut pool = FaultPool::new(
            ShardedPool::new_threaded(clients(&ds, 6, "topk", 23), s, 2),
            plan.clone(),
        );
        let t = run_fednl_pool(&mut pool, &opts, x0.clone(), "sh");
        assert_eq!(t_flat.records.len(), t.records.len());
        for (a, b) in t_flat.records.iter().zip(&t.records) {
            assert_eq!(
                a.grad_norm.to_bits(),
                b.grad_norm.to_bits(),
                "S={s} round {}",
                a.round
            );
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            // (bytes_up deliberately not compared: the sharded FedNL
            // path forwards pre-reduced SHARD_SUM payloads now.)
            assert_eq!((a.committed, a.missing), (b.committed, b.missing));
        }
    }

    // FedNL-PP under a kill window with Resample through the tier.
    let x0 = vec![0.0; d];
    let plan = FaultPlan::parse("kill@2:4-20").unwrap();
    let opts_pp = Options {
        rounds: 50,
        policy: RoundPolicy {
            quorum: Some(2),
            deadline_ms: None,
            on_missing: OnMissing::Resample,
        },
        ..Default::default()
    };
    let (tau, seed) = (3usize, 57u64);
    let mut flat = FaultPool::new(
        SeqPool::new(pp_clients(&ds, 6, "topk", 5, &x0)),
        plan.clone(),
    );
    let t_flat = run_fednl_pp_pool(
        &mut flat,
        &opts_pp,
        tau,
        seed,
        x0.clone(),
        "flat-pp",
    );
    for s in [2usize, 3] {
        let mut pool = FaultPool::new(
            ShardedPool::new_seq(pp_clients(&ds, 6, "topk", 5, &x0), s),
            plan.clone(),
        );
        let t = run_fednl_pp_pool(
            &mut pool,
            &opts_pp,
            tau,
            seed,
            x0.clone(),
            "sh-pp",
        );
        for (a, b) in t_flat.records.iter().zip(&t.records) {
            assert_eq!(
                a.grad_norm.to_bits(),
                b.grad_norm.to_bits(),
                "pp S={s} round {}",
                a.round
            );
            assert_eq!(a.bytes_up, b.bytes_up);
            assert_eq!((a.committed, a.missing), (b.committed, b.missing));
        }
    }
}

#[test]
fn corrupt_plan_bit_identical_across_pools() {
    // Deterministic corruption: the same `corrupt@` plan — one event
    // of every mode — must yield bit-identical (possibly diverging!)
    // trajectories on SeqPool, ThreadedPool at several worker counts,
    // and the sharded tier, because the injection is a pure function
    // of (plan, round, client), not of reply arrival order.
    let (ds, d) = problem(9, 5, 40, 150);
    let x0 = vec![0.0; d];
    let plan = FaultPlan::none()
        .with_corrupt(2, 1, CorruptMode::Scale(50.0))
        .with_corrupt(3, 0, CorruptMode::SignFlip)
        .with_corrupt(5, 4, CorruptMode::Garbage)
        .with_corrupt(7, 2, CorruptMode::Zero);
    // The parser round-trips the programmatic plan.
    assert_eq!(FaultPlan::parse(&plan.to_spec()).unwrap().to_spec(),
               plan.to_spec());
    let opts = Options { rounds: 12, track_loss: true, ..Default::default() };
    let mut seq = FaultPool::new(
        SeqPool::new(clients(&ds, 5, "topk", 33)),
        plan.clone(),
    );
    let t_ref = run_fednl_pool(&mut seq, &opts, x0.clone(), "corrupt-seq");
    // The attack engaged: the corrupted trajectory differs from clean.
    let mut clean = SeqPool::new(clients(&ds, 5, "topk", 33));
    let t_clean = run_fednl_pool(&mut clean, &opts, x0.clone(), "clean");
    assert!(
        t_ref
            .records
            .iter()
            .zip(&t_clean.records)
            .any(|(a, b)| a.grad_norm.to_bits() != b.grad_norm.to_bits()),
        "corrupt plan had no effect"
    );
    for workers in [1usize, 2, 5] {
        let mut thr = FaultPool::new(
            ThreadedPool::new(clients(&ds, 5, "topk", 33), workers),
            plan.clone(),
        );
        let t = run_fednl_pool(&mut thr, &opts, x0.clone(), "corrupt-thr");
        assert_eq!(t_ref.records.len(), t.records.len());
        for (a, b) in t_ref.records.iter().zip(&t.records) {
            assert_eq!(
                a.grad_norm.to_bits(),
                b.grad_norm.to_bits(),
                "workers={workers} round {}",
                a.round
            );
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.bytes_up, b.bytes_up);
        }
    }
    for s in [2usize, 3] {
        let mut sh = FaultPool::new(
            ShardedPool::new_threaded(clients(&ds, 5, "topk", 33), s, 2),
            plan.clone(),
        );
        let t = run_fednl_pool(&mut sh, &opts, x0.clone(), "corrupt-sh");
        for (a, b) in t_ref.records.iter().zip(&t.records) {
            assert_eq!(
                a.grad_norm.to_bits(),
                b.grad_norm.to_bits(),
                "S={s} round {}",
                a.round
            );
        }
    }
}

#[test]
fn defenses_bit_identical_across_pools_and_converge() {
    // The robust fold under a persistent scale attack: median and
    // trimmedmean:1 both neutralize two ×50 attackers out of six
    // clients (4 honest > 2f), converge, flag the documented count,
    // and stay bit-identical across SeqPool / ThreadedPool / the
    // sharded tier (the fold sorts with total_cmp over the committed
    // set, so arrival order and shard grouping are unobservable).
    let (ds, d) = problem(9, 6, 40, 151);
    let x0 = vec![0.0; d];
    let rounds = 25u64;
    let mut plan = FaultPlan::none();
    for r in 2..rounds {
        plan = plan
            .with_corrupt(r, 1, CorruptMode::Scale(50.0))
            .with_corrupt(r, 4, CorruptMode::Scale(50.0));
    }
    for (defense, want_flagged) in [
        (fednl::robust::Defense::Median, 5u32),
        (fednl::robust::Defense::TrimmedMean(1), 2u32),
    ] {
        let opts = Options {
            rounds,
            warm_start: true,
            defense: Some(defense),
            ..Default::default()
        };
        let mut seq = FaultPool::new(
            SeqPool::new(clients(&ds, 6, "topk", 37)),
            plan.clone(),
        );
        let t_ref = run_fednl_pool(&mut seq, &opts, x0.clone(), "def-seq");
        let g0 = t_ref.records[0].grad_norm;
        assert!(
            t_ref.last_grad_norm().is_finite()
                && t_ref.last_grad_norm() < g0 * 1e-2,
            "{defense:?} did not converge: {} → {}",
            g0,
            t_ref.last_grad_norm()
        );
        for r in t_ref.records.iter().filter(|r| r.round >= 2) {
            assert_eq!(
                r.flagged, want_flagged,
                "{defense:?} round {}",
                r.round
            );
        }
        let mut thr = FaultPool::new(
            ThreadedPool::new(clients(&ds, 6, "topk", 37), 3),
            plan.clone(),
        );
        let t_thr = run_fednl_pool(&mut thr, &opts, x0.clone(), "def-thr");
        let mut sh = FaultPool::new(
            ShardedPool::new_threaded(clients(&ds, 6, "topk", 37), 3, 2),
            plan.clone(),
        );
        let t_sh = run_fednl_pool(&mut sh, &opts, x0.clone(), "def-sh");
        for t in [&t_thr, &t_sh] {
            assert_eq!(t_ref.records.len(), t.records.len());
            for (a, b) in t_ref.records.iter().zip(&t.records) {
                assert_eq!(
                    a.grad_norm.to_bits(),
                    b.grad_norm.to_bits(),
                    "{defense:?} round {}",
                    a.round
                );
                assert_eq!(a.flagged, b.flagged);
                assert_eq!((a.committed, a.missing), (b.committed, b.missing));
            }
        }
    }
}

#[test]
fn pool_loss_grad_consistent_across_transports() {
    let (ds, d) = problem(7, 5, 30, 106);
    let mut seq = SeqPool::new(clients(&ds, 5, "identity", 1));
    let mut thr = ThreadedPool::new(clients(&ds, 5, "identity", 1), 2);
    let x = vec![0.1; d];
    let (l1, g1) = seq.loss_grad(&x);
    let (l2, g2) = thr.loss_grad(&x);
    assert!((l1 - l2).abs() < 1e-12);
    for (a, b) in g1.iter().zip(&g2) {
        assert!((a - b).abs() < 1e-12);
    }
}

#[test]
fn atom_and_sum_aggregation_paths_bit_identical() {
    // The reproducible-summation invariant, asserted end to end: with
    // no faults injected, a Reuse-policy run (which forces the atom
    // path — per-client messages through the CommitBuffer) and a
    // Drop-policy run (the pre-reduced sum path) must produce
    // bit-identical trajectories, flat AND sharded — the exact
    // accumulator makes the aggregation grouping unobservable.
    let (ds, d) = problem(9, 6, 40, 140);
    let x0 = vec![0.0; d];
    let mk_opts = |on_missing| Options {
        rounds: 20,
        track_loss: true,
        policy: RoundPolicy {
            quorum: None,
            deadline_ms: None,
            on_missing,
        },
        ..Default::default()
    };
    let mut seq = SeqPool::new(clients(&ds, 6, "topk", 31));
    let t_sums = run_fednl_pool(
        &mut seq,
        &mk_opts(OnMissing::Drop),
        x0.clone(),
        "sums",
    );
    let mut seq = SeqPool::new(clients(&ds, 6, "topk", 31));
    let t_atoms = run_fednl_pool(
        &mut seq,
        &mk_opts(OnMissing::Reuse),
        x0.clone(),
        "atoms",
    );
    let mut sh = ShardedPool::new_seq(clients(&ds, 6, "topk", 31), 3);
    let t_shard = run_fednl_pool(
        &mut sh,
        &mk_opts(OnMissing::Drop),
        x0.clone(),
        "shard-sums",
    );
    let mut sh = ShardedPool::new_seq(clients(&ds, 6, "topk", 31), 3);
    let t_shard_atoms = run_fednl_pool(
        &mut sh,
        &mk_opts(OnMissing::Reuse),
        x0,
        "shard-atoms",
    );
    for t in [&t_atoms, &t_shard, &t_shard_atoms] {
        assert_eq!(t.records.len(), t_sums.records.len());
        for (a, b) in t_sums.records.iter().zip(&t.records) {
            assert_eq!(
                a.grad_norm.to_bits(),
                b.grad_norm.to_bits(),
                "round {}: atom/sum paths diverged",
                a.round
            );
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        }
    }
}

#[test]
fn intra_thread_count_does_not_change_trajectory() {
    // `--intra-threads` (the row-partitioned §5.10 accumulate) and the
    // reproducible reductions together: the trajectory must be
    // invariant in the intra-client thread count, flat and sharded.
    // (The knob is a process-global; restore it before returning so
    // concurrently running tests see the default again.)
    // d_raw 40 → d ≥ 32, so the row-block threading actually engages.
    let (ds, d) = problem(40, 4, 60, 141);
    let x0 = vec![0.0; d];
    let opts = Options { rounds: 10, track_loss: true, ..Default::default() };
    let mut seq = SeqPool::new(clients(&ds, 4, "randk", 51));
    let t_ref = run_fednl_pool(&mut seq, &opts, x0.clone(), "intra1");
    for threads in [2usize, 3] {
        fednl::linalg::simd::set_intra_threads(threads);
        let mut seq = SeqPool::new(clients(&ds, 4, "randk", 51));
        let t = run_fednl_pool(&mut seq, &opts, x0.clone(), "intraN");
        let mut sh = ShardedPool::new_seq(clients(&ds, 4, "randk", 51), 2);
        let t_sh = run_fednl_pool(&mut sh, &opts, x0.clone(), "intraN-sh");
        fednl::linalg::simd::set_intra_threads(1);
        for (a, b) in t_ref.records.iter().zip(&t.records) {
            assert_eq!(
                a.grad_norm.to_bits(),
                b.grad_norm.to_bits(),
                "intra-threads={threads} round {}",
                a.round
            );
        }
        for (a, b) in t_ref.records.iter().zip(&t_sh.records) {
            assert_eq!(
                a.grad_norm.to_bits(),
                b.grad_norm.to_bits(),
                "sharded intra-threads={threads} round {}",
                a.round
            );
        }
    }
}

//! Dense row-major f64 matrix (paper component `linalg_matrices`).
//!
//! Design notes carried over from the paper:
//! * rows/cols are stored explicitly (v34: "store information about the
//!   number of columns ... explicitly");
//! * `sym_rank1_block_upper` accumulates the Hessian as a sum of
//!   symmetric rank-1 matrices over the *upper triangle only*, 4 samples
//!   per pass (§5.10 / v26+v52) — the single hottest kernel in FedNL,
//!   dispatched through [`super::simd`] (AVX2+FMA when available);
//! * `frobenius_sq_symmetric` exploits symmetry (v51);
//! * `add_diag` is the careful diagonal-update of §5.8 (v14);
//! * `matmul_tiled` is the cache-aware tiled multiply of §5.10, kept for
//!   benches/ablation (the Hessian path does not use a general matmul).

use super::vector;

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity scaled by `s`.
    pub fn identity_scaled(n: usize, s: f64) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, s);
        }
        m
    }

    /// Build from row slices.
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Build from a flat row-major vec.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] += v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Reset to zero without reallocating (buffer reuse, §5.13).
    pub fn fill_zero(&mut self) {
        vector::fill_zero(&mut self.data);
    }

    /// `self += alpha * other` (elementwise).
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        vector::axpy(alpha, &other.data, &mut self.data);
    }

    /// `self[i][i] += s` for all i (§5.8 custom diagonal update).
    pub fn add_diag(&mut self, s: f64) {
        let n = self.rows.min(self.cols);
        let stride = self.cols + 1;
        let mut idx = 0;
        for _ in 0..n {
            self.data[idx] += s;
            idx += stride;
        }
    }

    /// y = A x (row-major: each row dot x — contiguous access).
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] = vector::dot(self.row(i), x);
        }
    }

    /// y = Aᵀ x without materializing Aᵀ (paper v53: operate on the
    /// transposed argument instead of storing both A and Aᵀ).
    pub fn matvec_t(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        vector::fill_zero(y);
        for i in 0..self.rows {
            vector::axpy(x[i], self.row(i), y);
        }
    }

    /// Naive 3-loop matmul (the §5.10 baseline; kept for the ablation).
    pub fn matmul_naive(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows);
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..self.cols {
                    s += self.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    /// Cache-aware tiled matmul (§5.10): i-k-j loop order inside tiles so
    /// the innermost loop is a contiguous AXPY over C's row.
    pub fn matmul_tiled(&self, b: &Mat, tile: usize) -> Mat {
        assert_eq!(self.cols, b.rows);
        assert!(tile > 0);
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut c = Mat::zeros(m, n);
        for it in (0..m).step_by(tile) {
            let imax = (it + tile).min(m);
            for kt in (0..k).step_by(tile) {
                let kmax = (kt + tile).min(k);
                for jt in (0..n).step_by(tile) {
                    let jmax = (jt + tile).min(n);
                    for i in it..imax {
                        for kk in kt..kmax {
                            let aik = self.get(i, kk);
                            if aik == 0.0 {
                                continue;
                            }
                            let brow = &b.data[kk * n + jt..kk * n + jmax];
                            let crow = &mut c.data[i * n + jt..i * n + jmax];
                            vector::axpy(aik, brow, crow);
                        }
                    }
                }
            }
        }
        c
    }

    /// Accumulate `self += Σ_b h_b · a_b a_bᵀ` over the **upper triangle
    /// only**, processing up to 4 samples per sweep (§5.10 "better
    /// strategy": symmetric rank-1 sum, 4-sample ILP blocking).
    ///
    /// `samples` are row-slices of length d; `h` the per-sample weights.
    /// Call [`Mat::symmetrize_from_upper`] once after all batches.
    /// Dispatches to the AVX2+FMA kernel when available (4 FMAs per 4
    /// columns), with the 4-chain ILP scalar loop as fallback.
    pub fn sym_rank1_block_upper(&mut self, samples: &[&[f64]], h: &[f64]) {
        let d = self.rows;
        debug_assert_eq!(self.cols, d);
        debug_assert_eq!(samples.len(), h.len());
        super::simd::sym_rank1_upper(&mut self.data, d, samples, h);
    }

    /// Multi-threaded [`Mat::sym_rank1_block_upper`]: row-block
    /// partition of the upper triangle across `n_threads` scoped
    /// threads, bit-identical to the single-threaded accumulate for
    /// any thread count (each entry is written by exactly one thread
    /// in the same per-sample order). `n_threads = 1` is exactly the
    /// single-threaded kernel.
    pub fn sym_rank1_block_upper_mt(
        &mut self,
        samples: &[&[f64]],
        h: &[f64],
        n_threads: usize,
    ) {
        let d = self.rows;
        debug_assert_eq!(self.cols, d);
        debug_assert_eq!(samples.len(), h.len());
        super::simd::sym_rank1_upper_threaded(
            &mut self.data,
            d,
            samples,
            h,
            n_threads,
        );
    }

    /// Mirror the upper triangle into the lower one (one pass, §5.10).
    pub fn symmetrize_from_upper(&mut self) {
        let d = self.rows;
        debug_assert_eq!(self.cols, d);
        for i in 1..d {
            for j in 0..i {
                self.data[i * d + j] = self.data[j * d + i];
            }
        }
    }

    /// Squared Frobenius norm, generic.
    pub fn frobenius_sq(&self) -> f64 {
        vector::norm2_sq(&self.data)
    }

    /// Squared Frobenius norm for a symmetric matrix using only the
    /// upper triangle: ‖M‖²_F = Σ_i m_ii² + 2 Σ_{i<j} m_ij² (v51).
    pub fn frobenius_sq_symmetric(&self) -> f64 {
        let d = self.rows;
        debug_assert_eq!(self.cols, d);
        let mut diag = 0.0;
        let mut off = 0.0;
        for i in 0..d {
            let row = self.row(i);
            diag += row[i] * row[i];
            off += vector::norm2_sq(&row[i + 1..]);
        }
        diag + 2.0 * off
    }

    /// Max |a_ij - b_ij| (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Strict symmetry check within tolerance.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in i + 1..self.cols {
                if (self.get(i, j) - self.get(j, i)).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn random_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed_from_u64(seed);
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.next_gaussian()).collect())
    }

    #[test]
    fn get_set_row() {
        let mut m = Mat::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn add_diag_rect_safe() {
        let mut m = Mat::zeros(2, 3);
        m.add_diag(1.5);
        assert_eq!(m.get(0, 0), 1.5);
        assert_eq!(m.get(1, 1), 1.5);
        assert_eq!(m.get(1, 2), 0.0);
    }

    #[test]
    fn matvec_and_transpose() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let mut y = vec![0.0; 3];
        m.matvec(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 7.0, 11.0]);
        let mut z = vec![0.0; 2];
        m.matvec_t(&[1.0, 1.0, 1.0], &mut z);
        assert_eq!(z, vec![9.0, 12.0]);
    }

    #[test]
    fn tiled_matches_naive() {
        let a = random_mat(17, 13, 1);
        let b = random_mat(13, 19, 2);
        let c0 = a.matmul_naive(&b);
        for tile in [1, 4, 8, 32] {
            let c1 = a.matmul_tiled(&b, tile);
            assert!(c0.max_abs_diff(&c1) < 1e-12, "tile={tile}");
        }
    }

    #[test]
    fn sym_rank1_matches_dense() {
        // H = A diag(h) Aᵀ via rank-1 blocking vs explicit matmul.
        let d = 9;
        let n = 14; // not a multiple of 4 → exercises the tail loop
        let at = random_mat(n, d, 3);
        let mut rng = Pcg64::seed_from_u64(4);
        let h: Vec<f64> = (0..n).map(|_| rng.next_f64() + 0.1).collect();

        let mut hess = Mat::zeros(d, d);
        let rows: Vec<&[f64]> = (0..n).map(|i| at.row(i)).collect();
        hess.sym_rank1_block_upper(&rows, &h);
        hess.symmetrize_from_upper();

        let mut expect = Mat::zeros(d, d);
        for s in 0..n {
            for u in 0..d {
                for v in 0..d {
                    expect.add_at(u, v, h[s] * at.get(s, u) * at.get(s, v));
                }
            }
        }
        assert!(hess.max_abs_diff(&expect) < 1e-12);
        assert!(hess.is_symmetric(0.0));
    }

    #[test]
    fn frobenius_symmetric_matches_generic() {
        let d = 11;
        let a = random_mat(d, d, 5);
        let mut sym = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                sym.set(i, j, 0.5 * (a.get(i, j) + a.get(j, i)));
            }
        }
        let f1 = sym.frobenius_sq();
        let f2 = sym.frobenius_sq_symmetric();
        assert!((f1 - f2).abs() < 1e-10 * f1.max(1.0));
    }

    #[test]
    fn symmetrize_from_upper_works() {
        let mut m = Mat::zeros(3, 3);
        m.set(0, 1, 2.0);
        m.set(0, 2, 3.0);
        m.set(1, 2, 4.0);
        m.symmetrize_from_upper();
        assert!(m.is_symmetric(0.0));
        assert_eq!(m.get(2, 0), 3.0);
    }

    #[test]
    fn axpy_matrix() {
        let mut a = Mat::identity_scaled(2, 1.0);
        let b = Mat::identity_scaled(2, 2.0);
        a.axpy(0.5, &b);
        assert_eq!(a.get(0, 0), 2.0);
        assert_eq!(a.get(0, 1), 0.0);
    }
}

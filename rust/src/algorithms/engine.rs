//! The unified round engine: one driver loop for the whole FedNL
//! family (Alg. 1–3), over any [`ClientPool`] transport.
//!
//! The engine owns everything the three per-algorithm drivers used to
//! triplicate — α resolution, warm start, the streaming
//! submit/drain/commit loop, byte accounting, trace recording and the
//! tolerance check — and delegates what actually differs to a
//! [`StepPolicy`]:
//!
//! * [`StepPolicy::Newton`] — FedNL (Alg. 1): aggregate, then
//!   xᵏ⁺¹ = xᵏ − [system]⁻¹ ∇f(xᵏ);
//! * [`StepPolicy::LineSearch`] — FedNL-LS (Alg. 2): the same
//!   aggregation, then Armijo backtracking with `eval_loss` probes;
//! * [`StepPolicy::PartialParticipation`] — FedNL-PP (Alg. 3): solve
//!   xᵏ⁺¹ from the persistent (Hᵏ, lᵏ, gᵏ) *before* sampling, then
//!   stream the τ participants' deltas into the persistent state.
//!
//! # Reproducible aggregation: the sum path and the atom path
//!
//! Every round reduction folds into the exact superaccumulator state
//! of [`RoundSum`] (`linalg::reduce`), which is associative and
//! permutation-invariant — aggregation order, transport, thread count
//! and shard grouping cannot perturb a bit of the result. The engine
//! therefore has two interchangeable drain paths:
//!
//! * **sum path** (the FedNL/LS default): [`ClientPool::drain_sums`]
//!   surfaces pre-reduced partial sums — one merged accumulator per
//!   shard on the shard tiers (O(S·d) master fan-in), a folded batch
//!   on flat pools — and the engine merges them in any order;
//! * **atom path** (FedNL-PP, and [`OnMissing::Reuse`], which replays
//!   cached per-client messages): replies stream out of
//!   [`ClientPool::drain`] in arrival order and a [`CommitBuffer`]
//!   books them in round-subset order — pure accounting now (duplicate
//!   and hole detection, replay slots); the arithmetic no longer
//!   depends on it.
//!
//! Exactness makes the two paths produce bit-identical trajectories
//! (asserted by the integration tests), so the choice is purely about
//! payload and per-client visibility.
//!
//! # Fault-tolerant quorum rounds
//!
//! A round no longer requires every participant to answer. The pools
//! certify participants that will *never* reply (fault injection, a
//! missed reply deadline, a closed connection) through
//! [`ClientPool::take_missing`]; the engine then applies the run's
//! [`RoundPolicy`]:
//!
//! * [`OnMissing::Drop`] — the missing contribution is skipped: the
//!   commit ladder skips the hole and the first-order reductions are
//!   rescaled to the committed count (∇f and lᵏ become means over the
//!   survivors; the Hessian state stays exact because a client that
//!   never computed the round also never moved its local Hᵢᵏ);
//! * [`OnMissing::Resample`] — FedNL-PP only: participation picks that
//!   land on clients already known dead are replaced by fresh draws
//!   from the same seeded sampler over the live remainder (see
//!   [`select_pp_subset`]); failures detected mid-round still drop;
//! * [`OnMissing::Reuse`] — the client's last committed message is
//!   replayed in its slot with the Hessian update blanked (stale ∇fᵢ
//!   and lᵢ, no double-applied Sᵢ). For FedNL-PP the deltas of a
//!   missing participant are zero by definition, so Reuse degrades to
//!   Drop there.
//!
//! The round *closes* only when every participant is accounted for
//! (replied or certified missing) — the engine never closes on a
//! wall-clock race, so given the same missing sets the trajectories
//! are **bit-identical across SeqPool / ThreadedPool / RemotePool**,
//! extending the buffer-and-commit determinism rule to lossy rounds
//! (asserted by the fault-injection integration tests). If fewer than
//! [`RoundPolicy::quorum`] messages commit, the engine aborts loudly.
//!
//! # Speculative aggregation past quorum
//!
//! With [`Options::speculate`] (`--speculate`), the sum path overlaps
//! the server-side round finish with straggler draining: the moment
//! the quorum's commits have been absorbed while some participants are
//! still outstanding, a snapshot of the server state runs
//! `finish_round` + `newton_direction` on a helper thread. The result
//! is adopted **iff** the round finally closes on exactly the
//! snapshot's commit count (every outstanding participant was
//! certified missing) — then the snapshot equals the final state and
//! the precomputed step is, by construction, bit for bit the step the
//! inline path would have produced. If any straggler's sum lands after
//! the launch, the speculation is joined and discarded and the round
//! finishes inline. Either way the trajectory is identical to the
//! non-speculative run; the won overlap is reported as
//! [`Trace::overlap_secs`] (`overlap_s` in `BENCH_coordinator.json`).

use std::time::Duration;

use super::fednl_ls::LineSearchParams;
use super::{ClientMsg, Options, RoundSum, ServerState, UpdateRule};
use crate::compressors::{Compressed, IndexPayload, ValueEncoding};
use crate::coordinator::checkpoint::{
    self, AlgoSnap, CheckpointCfg, Snapshot,
};
use crate::coordinator::{ClientFamily, ClientPool, RoundMode};
use crate::linalg::packed::PackedUpper;
use crate::linalg::{vector, Cholesky, Mat};
use crate::metrics::{RoundRecord, Trace};
use crate::net::wire;
use crate::rng::{sample_distinct, Pcg64, Rng};
use crate::robust::Defense;
use crate::utils::Stopwatch;

/// What the master does with an aggregated round (the only part of the
/// driver loop that differs between Alg. 1, 2 and 3).
#[derive(Clone, Copy)]
pub enum StepPolicy<'a> {
    /// FedNL (Alg. 1): plain Newton-type step under `Options::rule`.
    Newton,
    /// FedNL-LS (Alg. 2): Armijo backtracking line search.
    LineSearch(&'a LineSearchParams),
    /// FedNL-PP (Alg. 3): τ-subset participation with a seeded sampler
    /// (the sampler lives here, in the driver — transports only see the
    /// subset).
    PartialParticipation { tau: usize, seed: u64 },
}

/// What the engine does with a participant whose reply will never
/// arrive (see the module docs for the exact semantics per algorithm).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OnMissing {
    /// Skip the contribution; rescale first-order reductions to the
    /// committed count.
    Drop,
    /// FedNL-PP: replace known-dead participation picks with fresh
    /// seeded draws over the live clients (elsewhere acts like Drop).
    Resample,
    /// Replay the client's last committed message with the Hessian
    /// update blanked (FedNL/LS only; degrades to Drop for PP deltas).
    Reuse,
}

impl OnMissing {
    /// Parse a CLI spelling (`drop` | `resample` | `reuse`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "drop" => Ok(OnMissing::Drop),
            "resample" => Ok(OnMissing::Resample),
            "reuse" => Ok(OnMissing::Reuse),
            other => anyhow::bail!("unknown on-missing policy '{other}'"),
        }
    }
}

/// Fault-tolerance contract of one training run. The default policy
/// (`quorum: None`, no deadline, [`OnMissing::Drop`]) reproduces the
/// strict pre-fault behavior: with no faults injected nothing is ever
/// missing, and a missing reply without quorum slack aborts the run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundPolicy {
    /// Minimum committed replies (arrived + reused) for a round to be
    /// accepted; `None` = every participant. Clamped to the round's
    /// participant count. A round that closes below quorum panics.
    pub quorum: Option<usize>,
    /// Per-client reply deadline, forwarded to the transport
    /// ([`ClientPool::set_reply_deadline`]): `RemotePool` deregisters a
    /// client whose reply misses it, and the deterministic fault
    /// injector converts injected delays longer than this into drops.
    pub deadline_ms: Option<u64>,
    /// What to do with participants that never reply.
    pub on_missing: OnMissing,
}

impl Default for RoundPolicy {
    fn default() -> Self {
        Self { quorum: None, deadline_ms: None, on_missing: OnMissing::Drop }
    }
}

impl RoundPolicy {
    /// CLI-parse-time sanity checks, so an unsatisfiable policy fails
    /// with a clear message *before* data loading and registration
    /// instead of aborting (or hanging) mid-run:
    ///
    /// * `quorum` of 0, or larger than the client count, can never be
    ///   met (the engine clamps per-round, but a CLI value above `n`
    ///   is always a typo);
    /// * a zero `deadline_ms` would make every reply late — "no
    ///   deadline" is spelled by omitting the flag;
    /// * an explicit `on_missing` policy on a remote (TCP) master
    ///   without a reply deadline is inert against stragglers: a hung
    ///   client that never closes its socket blocks the round forever
    ///   before the policy can engage. (In-process pools and the fault
    ///   injector certify losses without a clock, so `remote = false`
    ///   skips this check.)
    pub fn validate(
        &self,
        n_clients: usize,
        remote: bool,
        explicit_on_missing: bool,
    ) -> anyhow::Result<()> {
        if let Some(q) = self.quorum {
            anyhow::ensure!(q >= 1, "--quorum must be at least 1");
            anyhow::ensure!(
                q <= n_clients,
                "--quorum {q} exceeds the client count {n_clients}: the \
                 quorum can never be met"
            );
        }
        if let Some(ms) = self.deadline_ms {
            anyhow::ensure!(
                ms > 0,
                "--deadline-ms 0 would declare every reply late; omit \
                 the flag for 'no deadline'"
            );
        }
        if remote && explicit_on_missing && self.deadline_ms.is_none() {
            anyhow::bail!(
                "--on-missing on a TCP master requires --deadline-ms: \
                 without a reply deadline a hung client blocks the round \
                 before the missing-policy can engage"
            );
        }
        Ok(())
    }
}

/// Buffer-and-commit: replies may arrive in any order, but `commit`
/// sees them in the round's subset order (ascending client id for a
/// full round). Early arrivals wait in `pending`; participants
/// certified missing become *holes* the commit ladder steps over, so
/// the committed prefix order is invariant no matter when a loss is
/// detected.
pub(crate) struct CommitBuffer {
    /// client id → slot in the subset (usize::MAX = not participating).
    slot_of: Vec<usize>,
    pending: Vec<Option<ClientMsg>>,
    /// Slots whose participant was certified missing.
    hole: Vec<bool>,
    next: usize,
    /// Messages committed so far (holes excluded).
    committed: usize,
}

impl CommitBuffer {
    pub fn new(n_clients: usize, subset: Option<&[u32]>) -> Self {
        let mut slot_of = vec![usize::MAX; n_clients];
        let m = match subset {
            None => {
                for (i, s) in slot_of.iter_mut().enumerate() {
                    *s = i;
                }
                n_clients
            }
            Some(s) => {
                for (pos, &ci) in s.iter().enumerate() {
                    slot_of[ci as usize] = pos;
                }
                s.len()
            }
        };
        Self {
            slot_of,
            pending: (0..m).map(|_| None).collect(),
            hole: vec![false; m],
            next: 0,
            committed: 0,
        }
    }

    fn slot(&self, client_id: usize) -> usize {
        let slot = *self
            .slot_of
            .get(client_id)
            .expect("client id out of range");
        assert!(
            slot != usize::MAX,
            "reply from non-participating client {client_id}"
        );
        slot
    }

    /// Accept one arrived message; fire `commit` for it and for any
    /// buffered successors whose turn it unblocks.
    pub fn offer(
        &mut self,
        m: ClientMsg,
        commit: impl FnMut(&ClientMsg),
    ) {
        let slot = self.slot(m.client_id);
        assert!(
            !self.hole[slot],
            "reply from client {} already certified missing",
            m.client_id
        );
        // A slot below `next` was already committed (and taken back to
        // None), so `is_none()` alone would silently swallow a late
        // duplicate — check both sides of the commit ladder.
        assert!(
            slot >= self.next && self.pending[slot].is_none(),
            "duplicate reply from client {}",
            m.client_id
        );
        self.pending[slot] = Some(m);
        self.advance(commit);
    }

    /// Certify that a participant's reply will never arrive; its slot
    /// becomes a hole the ladder steps over (unblocking any buffered
    /// successors).
    pub fn mark_missing(
        &mut self,
        client_id: u32,
        commit: impl FnMut(&ClientMsg),
    ) {
        let slot = self.slot(client_id as usize);
        assert!(
            slot >= self.next && self.pending[slot].is_none(),
            "client {client_id} reported missing after its reply committed"
        );
        assert!(!self.hole[slot], "client {client_id} reported missing twice");
        self.hole[slot] = true;
        self.advance(commit);
    }

    fn advance(&mut self, mut commit: impl FnMut(&ClientMsg)) {
        while self.next < self.pending.len() {
            if self.hole[self.next] {
                self.next += 1;
                continue;
            }
            match self.pending[self.next].take() {
                Some(msg) => {
                    commit(&msg);
                    self.committed += 1;
                    self.next += 1;
                }
                None => break,
            }
        }
    }

    pub fn is_complete(&self) -> bool {
        self.next == self.pending.len()
    }

    /// Committed (non-hole) messages so far.
    pub fn committed(&self) -> usize {
        self.committed
    }

    /// Participants of the round (committed + holes + still pending).
    pub fn len(&self) -> usize {
        self.pending.len()
    }
}

/// Draw the FedNL-PP participation subset for one round. The base
/// τ-sample is always drawn first, so the no-fault RNG stream (and
/// therefore every pre-fault trajectory) is unchanged; under
/// [`OnMissing::Resample`] picks that land on clients in `dead` are
/// then replaced by fresh draws over the live, not-yet-selected
/// remainder. A dead client is never drawn twice in one round, the
/// result never contains a dead client, and the replacement draws
/// consume the same seeded stream on every transport. If fewer live
/// candidates exist than dead picks, the unreplaceable picks are
/// removed (the effective subset shrinks).
pub fn select_pp_subset(
    rng: &mut Pcg64,
    n: usize,
    tau: usize,
    dead: &[u32],
    on_missing: OnMissing,
) -> Vec<u32> {
    let mut selected = sample_distinct(rng, n, tau);
    if on_missing != OnMissing::Resample || dead.is_empty() {
        return selected;
    }
    let mut is_dead = vec![false; n];
    for &c in dead {
        if (c as usize) < n {
            is_dead[c as usize] = true;
        }
    }
    let mut in_subset = vec![false; n];
    for &c in &selected {
        in_subset[c as usize] = true;
    }
    // Live candidates not already selected, ascending id; a partial
    // Fisher–Yates over them replaces each dead pick in place (the
    // replacement inherits the dead pick's selection-order slot).
    let mut candidates: Vec<u32> = (0..n as u32)
        .filter(|&c| !is_dead[c as usize] && !in_subset[c as usize])
        .collect();
    let mut next = 0usize;
    for slot in 0..selected.len() {
        if !is_dead[selected[slot] as usize] {
            continue;
        }
        if next >= candidates.len() {
            break; // not enough live clients; leftover dead picks drop below
        }
        let j =
            next + rng.next_below((candidates.len() - next) as u64) as usize;
        candidates.swap(next, j);
        selected[slot] = candidates[next];
        next += 1;
    }
    selected.retain(|&c| !is_dead[c as usize]);
    selected
}

/// Run one member of the FedNL family against any client transport.
pub fn run_engine(
    pool: &mut dyn ClientPool,
    opts: &Options,
    policy: StepPolicy<'_>,
    x0: Vec<f64>,
    label: &str,
) -> Trace {
    run_engine_from(pool, opts, policy, x0, label, None)
}

/// [`run_engine`] resuming from a durable coordinator [`Snapshot`]
/// (`master --restore`): the engine reinstalls the snapshot state
/// verbatim — aggregate, watermarks, byte meters, trace prefix, RNG
/// position — and continues at `snap.round_next`, producing a
/// trajectory bit-identical to the uninterrupted run.
pub fn run_engine_from(
    pool: &mut dyn ClientPool,
    opts: &Options,
    policy: StepPolicy<'_>,
    x0: Vec<f64>,
    label: &str,
    resume: Option<Snapshot>,
) -> Trace {
    match policy {
        StepPolicy::PartialParticipation { tau, seed } => {
            run_pp(pool, opts, tau, seed, x0, label, resume)
        }
        _ => run_newton_family(pool, opts, policy, x0, label, resume),
    }
}

/// FedNL / FedNL-LS: full-participation rounds over a [`ServerState`]
/// (under faults: full-*intent* rounds — every client is asked, the
/// quorum policy absorbs the ones that cannot answer).
fn run_newton_family(
    pool: &mut dyn ClientPool,
    opts: &Options,
    policy: StepPolicy<'_>,
    x0: Vec<f64>,
    label: &str,
    resume: Option<Snapshot>,
) -> Trace {
    let ls: Option<&LineSearchParams> = match policy {
        StepPolicy::LineSearch(p) => Some(p),
        _ => None,
    };
    // The unified ROUND/MSG exchange is family-agnostic, so guard here:
    // aggregating a PP client's deltas as absolute gradients would be
    // silently wrong math on any transport.
    assert_eq!(
        pool.family(),
        ClientFamily::FedNL,
        "FedNL/FedNL-LS requires FedNL-family clients, but this pool \
         serves FedNL-PP clients"
    );
    let d = pool.dim();
    let n = pool.n_clients();
    let rp = opts.policy;
    pool.set_reply_deadline(rp.deadline_ms.map(Duration::from_millis));
    // α negotiation: an explicit opts.alpha is installed everywhere; a
    // transport that cannot know the theoretical α (TCP, relays) hands
    // back NaN from default_alpha and set_alpha resolves the clients'
    // own value — the server must aggregate with the α the clients
    // actually use, on every topology (bit-identity across transports
    // depends on it).
    // On resume the snapshot's α is re-installed verbatim — the
    // trajectory is a function of its exact bits, so a renegotiation
    // that settled elsewhere would silently fork the run.
    let requested = match &resume {
        Some(snap) => snap.alpha,
        None => opts.alpha.unwrap_or_else(|| pool.default_alpha()),
    };
    let alpha = pool.set_alpha(requested);
    assert!(
        alpha.is_finite() && alpha > 0.0,
        "α negotiation failed: no client reported a usable α"
    );
    let ck: Option<&CheckpointCfg> = opts.checkpoint.as_ref();
    assert!(
        ck.is_none() || !opts.speculate,
        "--speculate is incompatible with checkpointing: a snapshot \
         cannot capture an in-flight speculation"
    );
    let mut server = ServerState::new(d, n, alpha, x0);
    let mut trace = Trace::new(label.to_string());
    let sw = Stopwatch::start();
    let mut bytes_up = 0u64;
    let mut bytes_down = 0u64;
    // Reply-aggregation mode: the reproducible summation layer makes
    // the round sum grouping-invariant, so the default is pre-reduced
    // sums — shard tiers then forward one merged accumulator per shard
    // (O(S·d) fan-in). Two features still need atom visibility: Reuse
    // (it replays cached per-client messages) and `--defense` (robust
    // folds are per-client or non-associative; see `crate::robust`).
    // Exactness guarantees both paths produce bit-identical
    // trajectories.
    let sum_mode =
        rp.on_missing != OnMissing::Reuse && opts.defense.is_none();
    pool.set_round_mode(if sum_mode {
        RoundMode::Sums
    } else {
        RoundMode::Atoms
    });
    // Last committed message per client, kept only under Reuse.
    let mut reuse_cache: Vec<Option<ClientMsg>> =
        (0..n).map(|_| None).collect();
    // (seconds blocked waiting for replies, seconds committing them) —
    // the wait/aggregate wall-clock split reported by the coordinator
    // bench.
    let mut timing = (0.0f64, 0.0f64);
    // The quorum threshold `check_quorum` will enforce, hoisted so the
    // speculative path can recognize "quorum is in" mid-drain.
    let need = rp.quorum.unwrap_or(n).min(n).max(1);
    // Commit watermark per client: the last round whose commit counted
    // this client's own reply. Drives the rejoin RESYNC resolution of
    // the commit-ack protocol — a rejoiner's staged shift is applied
    // iff its round is at or below this watermark.
    let mut last_commit: Vec<Option<u64>> = vec![None; n];

    if resume.is_none() && opts.warm_start {
        let x = server.x.clone();
        bytes_down += wire::vec_frame_bytes(d) * n as u64;
        let packed = pool.warm_start(&x);
        bytes_up += packed
            .iter()
            .map(|p| wire::vec_frame_bytes(p.len()))
            .sum::<u64>();
        server.init_h_from_packed(&packed);
    }

    // ROUND_ACK gating under checkpointing: acks buffer here and are
    // released only once a snapshot covering their round is durable, so
    // no client ever permanently commits a round a restored master
    // could re-run (the crash-safety half of exactly-once). The staged
    // ladder on failover clients grows to the checkpoint cadence in the
    // meantime.
    let mut pending_acks: Vec<(u64, Vec<u32>)> = Vec::new();
    let mut start_round = 0u64;
    if let Some(snap) = &resume {
        // `master --restore`: reinstall the durable coordinator state
        // and continue at the recorded round. The reconnecting clients
        // resolve their staged ladders against the restored watermark
        // through the ordinary rejoin path below.
        let (s, lc, rc) = install_newton_snapshot(snap, d, n, alpha);
        server = s;
        last_commit = lc;
        reuse_cache = rc;
        bytes_up = snap.bytes_up;
        bytes_down = snap.bytes_down;
        trace.records = snap.records.clone();
        start_round =
            if snap.finished { opts.rounds } else { snap.round_next };
    } else if let Some(cfg) = ck {
        // Round-0 baseline: even a crash before the first cadence
        // boundary (killmaster@0 included) has a restore point.
        let snap = newton_snap(
            &server,
            &last_commit,
            &reuse_cache,
            &trace,
            (bytes_up, bytes_down),
            0,
            false,
            &rp,
            label,
            &cfg.plan_spec,
        );
        checkpoint::write_snapshot(&cfg.dir, &snap)
            .expect("checkpoint write failed");
    }

    for round in start_round..opts.rounds {
        // Scripted coordinator crash (`killmaster@R`), in-process
        // flavor: entering round R, drop every piece of master state
        // and rebuild it from the latest durable snapshot — the same
        // restore path `master --restore` runs after a real SIGKILL.
        // The in-process clients survive, exactly like TCP clients
        // outliving the killed master process.
        if pool.take_master_kill(round) {
            let cfg = ck.expect(
                "killmaster@R requires checkpointing (--checkpoint-dir)",
            );
            let snap = checkpoint::load_latest(&cfg.dir)
                .expect("checkpoint load failed")
                .expect("killmaster@R fired with no snapshot on disk");
            assert_eq!(
                snap.round_next, round,
                "killmaster@{round}: the latest snapshot resumes at a \
                 different round; align --checkpoint-every with the \
                 kill round"
            );
            let (s, lc, rc) = install_newton_snapshot(&snap, d, n, alpha);
            server = s;
            last_commit = lc;
            reuse_cache = rc;
            bytes_up = snap.bytes_up;
            bytes_down = snap.bytes_down;
            trace.records = snap.records.clone();
            pending_acks.clear();
        }
        pool.prepare_round(round);
        // Rejoin resolution (commit-ack protocol): each rejoiner's
        // staged-but-unacked shift resolves against this engine's
        // commit watermark — applied iff its round committed here
        // (the reply was delivered but the ack was lost), discarded
        // otherwise. Exactly-once either way. A *frozen* in-process
        // rejoiner stages nothing, so resolution is a no-op, exactly
        // like the pre-failover behavior.
        let rejoined = pool.take_rejoined();
        if !rejoined.is_empty() {
            // Under checkpointing, the RESYNC watermark must never run
            // ahead of the durable state: the rejoiner permanently
            // commits staged rounds at or below the watermark, and a
            // later master crash must not re-run them. Force a covering
            // snapshot before resolving (also makes a subsequent
            // PULL_H exact — no pending staged shifts remain).
            if let Some(cfg) = ck {
                if !pending_acks.is_empty() {
                    let snap = newton_snap(
                        &server,
                        &last_commit,
                        &reuse_cache,
                        &trace,
                        (bytes_up, bytes_down),
                        round,
                        false,
                        &rp,
                        label,
                        &cfg.plan_spec,
                    );
                    write_and_flush_acks(
                        cfg,
                        &snap,
                        pool,
                        &mut pending_acks,
                    );
                }
            }
            for ci in rejoined {
                pool.resolve_staged(ci, last_commit[ci as usize]);
            }
        }
        // Fresh-state rejoiners (`REG_FRESH`): rebuild the exact
        // server-side H = (1/n)ΣHᵢ from a full packed-Hᵢ pull, so a
        // process that restarted with reset state resyncs bitwise.
        // When the pull cannot be exact (some peer is dead or cannot
        // serve it), fall back to the old approximate behavior: the
        // shifts re-learn ∇²fᵢ over the following rounds.
        if !pool.take_fresh_rejoined().is_empty() {
            if let Some(packed) = pool.pull_h_packed() {
                bytes_down += wire::empty_frame_bytes() * n as u64;
                bytes_up += packed
                    .iter()
                    .map(|p| wire::vec_frame_bytes(p.len()))
                    .sum::<u64>();
                server.init_h_from_packed(&packed);
            }
        }
        let x = server.x.clone();
        bytes_down += wire::round_frame_bytes(d) * n as u64;
        // LS always needs fᵢ(xᵏ) (Alg. 2 line 5).
        let need_loss = opts.track_loss || ls.is_some();
        pool.submit_round(&x, None, round, need_loss);
        server.begin_round();
        // Speculative aggregation past quorum (`--speculate`, sum path
        // only): the moment the quorum's replies have committed while
        // stragglers are still outstanding, snapshot the server and
        // finish the round on a helper thread. See [`Speculation`] for
        // the adoption rule that keeps this bit-identical.
        let mut spec: Option<Speculation> = None;
        // `acked`: clients whose own reply was absorbed this round —
        // the commit-ack recipients. A Reuse replay is *committed*
        // (trace accounting) but never acked: the client did not
        // deliver the round, so its watermark must not advance.
        //
        // `flagged`: contributions the defense altered or excluded
        // this round (NormClip: clipped messages; trimmed mean: 2F;
        // median: m−1). Always 0 when undefended.
        let mut flagged = 0u32;
        let (committed, missing, acked) = if sum_mode {
            let mut committed_live = 0usize;
            let (c, mut missing_ids) =
                drain_and_sum(pool, n, &mut bytes_up, &mut timing, |s| {
                    committed_live += s.committed as usize;
                    server.apply_sum(s);
                    if opts.speculate
                        && spec.is_none()
                        && committed_live >= need
                        && committed_live < n
                    {
                        spec = Some(Speculation::launch(
                            &server,
                            committed_live,
                            opts.rule,
                        ));
                    }
                });
            // Sums carry counts, not ids: the absorbed set is the
            // complement of the certified-missing set.
            missing_ids.sort_unstable();
            let acked: Vec<u32> = (0..n as u32)
                .filter(|ci| missing_ids.binary_search(ci).is_err())
                .collect();
            (c, missing_ids.len(), acked)
        } else {
            let mut buf = CommitBuffer::new(n, None);
            // Round buffer for the non-associative defenses: the
            // committed messages are folded into one synthetic
            // sum-equivalent message after the round closes (see
            // `crate::robust`). NormClip stays streaming — each
            // commit is clipped (or passed through untouched) and
            // absorbed immediately.
            let mut robust_buf: Vec<ClientMsg> = Vec::new();
            let res = drain_and_commit(
                pool,
                &mut buf,
                &rp,
                Some(&mut reuse_cache),
                &mut bytes_up,
                &mut timing,
                |m| match opts.defense {
                    Some(Defense::NormClip(tau)) => {
                        match crate::robust::clip(m, tau) {
                            Some(clipped) => {
                                flagged += 1;
                                server.apply_msg(&clipped);
                            }
                            None => server.apply_msg(m),
                        }
                    }
                    Some(_) => robust_buf.push(m.clone()),
                    None => server.apply_msg(m),
                },
            );
            if let Some(def) = opts.defense {
                if !def.is_per_client() && !robust_buf.is_empty() {
                    let (synth, fl) = def
                        .aggregate(&robust_buf)
                        .expect("defense fold failed");
                    flagged = fl;
                    server.apply_msg(&synth);
                }
            }
            res
        };
        check_quorum(&rp, committed, n, round, label);
        // Announce the round's commit to the repliers it counted and
        // advance their watermarks. The pools forward ROUND_ACK only
        // to registrants that asked (`REG_WANTS_ACK`); their FIFO
        // channels order it before the next round's command. Under
        // checkpointing the ack is deferred until the covering
        // snapshot is durable (see `pending_acks` above).
        if ck.is_some() {
            pending_acks.push((round, acked.clone()));
        } else {
            pool.ack_round(round, &acked);
        }
        for &ci in &acked {
            last_commit[ci as usize] = Some(round);
        }
        // Resolve the speculation: adoptable iff the round closed on
        // exactly the snapshot's commit count — then nothing was
        // absorbed after launch, the helper's finish IS the inline
        // finish bit for bit, and its runtime is overlap we saved. A
        // late straggler makes the snapshot stale: join, discard, and
        // finish inline exactly as the non-speculative engine would.
        let mut spec_dir: Option<Vec<f64>> = None;
        let (grad, loss) = match spec.take() {
            Some(sp) if sp.committed == committed => {
                let res =
                    sp.handle.join().expect("speculation thread panicked");
                trace.overlap_secs += res.busy_secs;
                server = res.server;
                spec_dir = Some(res.dir);
                (res.grad, res.loss)
            }
            other => {
                if let Some(sp) = other {
                    drop(sp.handle.join());
                }
                server.finish_round(committed)
            }
        };
        let gnorm = vector::norm2(&grad);
        let (up, down) =
            pool.transport_bytes().unwrap_or((bytes_up, bytes_down));
        trace.push(RoundRecord {
            round,
            grad_norm: gnorm,
            loss: loss.unwrap_or(f64::NAN),
            bytes_up: up,
            bytes_down: down,
            elapsed: sw.elapsed_secs(),
            committed: committed as u32,
            missing: missing as u32,
            flagged,
        });
        if let Some(tol) = opts.tol_grad {
            if gnorm <= tol {
                break;
            }
        }
        let dir = match spec_dir {
            Some(dir) => dir,
            None => server.newton_direction(&grad, opts.rule),
        };
        match ls {
            None => {
                // Alg. 1 line 11.
                vector::axpy(1.0, &dir, &mut server.x);
            }
            Some(ls) => {
                // Alg. 2 line 12: backtracking; each probe is one
                // f-reduction over the clients.
                let f_x = loss.expect("LS requires client losses");
                let slope = vector::dot(&grad, &dir); // < 0 for descent
                let mut step = 1.0;
                let mut trial = vec![0.0; d];
                for _bt in 0..=ls.max_backtracks {
                    vector::add_scaled(&server.x, step, &dir, &mut trial);
                    let f_trial = pool.eval_loss(&trial);
                    bytes_down += wire::vec_frame_bytes(d) * n as u64;
                    bytes_up += wire::scalar_frame_bytes() * n as u64;
                    if f_trial <= f_x + ls.c * step * slope {
                        break;
                    }
                    step *= ls.gamma;
                }
                vector::add_scaled(
                    &server.x.clone(),
                    step,
                    &dir,
                    &mut server.x,
                );
            }
        }
        // Durable checkpoint every `every` rounds, written *after* the
        // x-update so the snapshot is exactly the state the next round
        // reads; the deferred ROUND_ACKs it covers flush right after.
        if let Some(cfg) = ck {
            if (round + 1) % cfg.every == 0 {
                let snap = newton_snap(
                    &server,
                    &last_commit,
                    &reuse_cache,
                    &trace,
                    (bytes_up, bytes_down),
                    round + 1,
                    false,
                    &rp,
                    label,
                    &cfg.plan_spec,
                );
                write_and_flush_acks(cfg, &snap, pool, &mut pending_acks);
            }
        }
    }
    if let Some(cfg) = ck {
        // Terminal snapshot, marked finished so restoring a completed
        // run executes zero further rounds. Also flushes the acks a
        // tolerance break left pending.
        let round_next = trace.records.last().map_or(0, |r| r.round + 1);
        let snap = newton_snap(
            &server,
            &last_commit,
            &reuse_cache,
            &trace,
            (bytes_up, bytes_down),
            round_next,
            true,
            &rp,
            label,
            &cfg.plan_spec,
        );
        write_and_flush_acks(cfg, &snap, pool, &mut pending_acks);
    }
    trace.wait_secs = timing.0;
    trace.aggregate_secs = timing.1;
    trace
}

/// FedNL-PP (Alg. 3): the model update happens *before* sampling; the
/// server state (Hᵏ, lᵏ, gᵏ) is persistent and updated incrementally
/// from the participants' deltas.
fn run_pp(
    pool: &mut dyn ClientPool,
    opts: &Options,
    tau: usize,
    seed: u64,
    x0: Vec<f64>,
    label: &str,
    resume: Option<Snapshot>,
) -> Trace {
    let n = pool.n_clients();
    assert!(tau >= 1 && tau <= n, "tau must be in [1, n]");
    // PP aggregates *deltas* into persistent state; a robust fold of
    // deltas does not defend the accumulated (Hᵏ, lᵏ, gᵏ), so the
    // combination is rejected rather than silently half-applied. The
    // CLI surfaces the same error before data loading.
    assert!(
        opts.defense.is_none(),
        "--defense supports the Newton family (fednl, fednl-ls) only, \
         not FedNL-PP"
    );
    assert_eq!(
        pool.family(),
        ClientFamily::PP,
        "FedNL-PP requires FedNL-PP-family clients, but this pool \
         serves FedNL clients"
    );
    let d = pool.dim();
    let inv_n = 1.0 / n as f64;
    let rp = opts.policy;
    pool.set_reply_deadline(rp.deadline_ms.map(Duration::from_millis));
    // PP rounds stay on the atom path: the per-client deltas feed the
    // engine's (lᵢ, gᵢ) mirrors (rejoin resync), and a τ-subset round
    // is already sublinear fan-in. The cross-client folds below still
    // run through the reproducible accumulator, so PP trajectories are
    // grouping-invariant like the Newton family's.
    pool.set_round_mode(RoundMode::Atoms);
    // Same α negotiation as the Newton family (see run_newton_family).
    let requested = opts.alpha.unwrap_or_else(|| pool.default_alpha());
    let alpha = pool.set_alpha(requested);
    assert!(
        alpha.is_finite() && alpha > 0.0,
        "α negotiation failed: no client reported a usable α"
    );
    // Server init from client initials (line 2), H⁰ = 0. Reproducible
    // sums: exact Σ, one rounding, then the 1/n scaling.
    let mut h = Mat::zeros(d, d);
    let pu = PackedUpper::new(d);
    let init = pool.init_state();
    let mut l = {
        let mut acc = crate::linalg::reduce::RepAcc::new();
        for (li, _) in &init {
            acc.accumulate(*li);
        }
        acc.round() * inv_n
    };
    let mut g = {
        let mut acc = crate::linalg::reduce::RepVec::new(d);
        for (_, gi) in &init {
            acc.accumulate(gi);
        }
        let mut g = acc.round_vec();
        vector::scale(inv_n, &mut g);
        g
    };
    // Per-client mirrors of the server-tracked (lᵢ, gᵢ): the running
    // sums above cannot absorb a rejoining client's STATE pull on their
    // own, so the engine keeps the per-client decomposition the deltas
    // imply (O(n·d) memory) and resyncs rejoiners exactly.
    let mut l_of: Vec<f64> = init.iter().map(|(li, _)| *li).collect();
    let mut g_of: Vec<Vec<f64>> =
        init.iter().map(|(_, gi)| gi.clone()).collect();
    let mut x = x0;
    let mut trace = Trace::new(label.to_string());
    let sw = Stopwatch::start();
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut bytes_up =
        wire::scalar_vec_frame_bytes(d) * init.len() as u64;
    let mut bytes_down = wire::empty_frame_bytes() * init.len() as u64;
    let mut timing = (0.0f64, 0.0f64);
    // Per-round exact delta sums (reused allocation).
    let mut rsum = RoundSum::new();
    let ck: Option<&CheckpointCfg> = opts.checkpoint.as_ref();
    let mut start_round = 0u64;
    if let Some(snap) = &resume {
        // `--restore`: the persistent (Hᵏ, lᵏ, gᵏ), the per-client
        // mirrors, and the subset sampler resume mid-stream from the
        // snapshot; the init_state pull above is discarded (its byte
        // charges are overwritten by the snapshot's meters).
        install_pp_snapshot(
            snap, d, n, &mut h, &mut l, &mut g, &mut l_of, &mut g_of,
            &mut rng, &mut x,
        );
        assert_eq!(
            alpha.to_bits(),
            snap.alpha.to_bits(),
            "restored α differs from the snapshot's"
        );
        bytes_up = snap.bytes_up;
        bytes_down = snap.bytes_down;
        trace.records = snap.records.clone();
        start_round =
            if snap.finished { opts.rounds } else { snap.round_next };
    } else if let Some(cfg) = ck {
        // Round-0 baseline (see run_newton_family).
        let snap = pp_snap(
            d,
            n,
            alpha,
            &h,
            l,
            &g,
            &l_of,
            &g_of,
            &rng,
            &x,
            &trace,
            (bytes_up, bytes_down),
            0,
            false,
            &rp,
            label,
            &cfg.plan_spec,
        );
        checkpoint::write_snapshot(&cfg.dir, &snap)
            .expect("checkpoint write failed");
    }

    for round in start_round..opts.rounds {
        // Scripted coordinator crash (`killmaster@R`), in-process
        // flavor — see run_newton_family. PP has no ack protocol to
        // flush: the mirrors, sampler position, and aggregates all
        // live in the snapshot.
        if pool.take_master_kill(round) {
            let cfg = ck.expect(
                "killmaster@R requires checkpointing (--checkpoint-dir)",
            );
            let snap = checkpoint::load_latest(&cfg.dir)
                .expect("checkpoint load failed")
                .expect("killmaster@R fired with no snapshot on disk");
            assert_eq!(
                snap.round_next, round,
                "killmaster@{round}: the latest snapshot resumes at a \
                 different round; align --checkpoint-every with the \
                 kill round"
            );
            install_pp_snapshot(
                &snap, d, n, &mut h, &mut l, &mut g, &mut l_of,
                &mut g_of, &mut rng, &mut x,
            );
            bytes_up = snap.bytes_up;
            bytes_down = snap.bytes_down;
            trace.records = snap.records.clone();
        }
        pool.prepare_round(round);
        // Rejoin resync (STATE pull): fold the difference between the
        // client's actual (lᵢ, gᵢ) and the engine's mirror into the
        // running sums. For a frozen-then-thawed client the difference
        // is exactly zero.
        for ci in pool.take_rejoined() {
            let i = ci as usize;
            // A rejoiner lost again before answering the pull is
            // skipped: it is deregistered and will not be scheduled.
            let Some((l_new, g_new)) = pool.pull_state(ci) else {
                continue;
            };
            bytes_down += wire::empty_frame_bytes();
            bytes_up += wire::scalar_vec_frame_bytes(d);
            l += (l_new - l_of[i]) * inv_n;
            for j in 0..d {
                g[j] += (g_new[j] - g_of[i][j]) * inv_n;
            }
            l_of[i] = l_new;
            g_of[i] = g_new;
        }
        // Line 4: xᵏ⁺¹ = (Hᵏ + lᵏI)⁻¹ gᵏ.
        let mut shift = l.max(0.0);
        for _ in 0..60 {
            if let Some(ch) = Cholesky::factor(&h, shift) {
                x = ch.solve_vec(&g);
                break;
            }
            shift = (shift * 2.0).max(1e-12);
        }
        // Lines 5-6: sample Sᵏ, send xᵏ⁺¹ to the τ participants. The
        // seeded sampler lives here in the driver; every transport
        // receives the same subset in the same order. Under the
        // Resample policy, picks landing on known-dead clients are
        // replaced by fresh seeded draws over the live remainder.
        let dead = pool.dead_clients();
        let selected =
            select_pp_subset(&mut rng, n, tau, &dead, rp.on_missing);
        bytes_down += wire::round_frame_bytes(d) * selected.len() as u64;
        pool.submit_round(&x, Some(&selected), round, false);
        let mut buf = CommitBuffer::new(n, Some(&selected));
        rsum.reset();
        let (committed, missing, _arrived) = drain_and_commit(
            pool,
            &mut buf,
            &rp,
            // PP deltas must not be replayed (a missing participant's
            // delta is zero by definition): Reuse degrades to Drop.
            None,
            &mut bytes_up,
            &mut timing,
            |m| {
                // Lines 18-20: the round's delta sums fold into the
                // exact accumulator (commit order irrelevant); the
                // per-client mirrors track each participant's
                // cumulative (lᵢ, gᵢ) for the rejoin resync.
                rsum.absorb(m);
                let i = m.client_id;
                l_of[i] += m.l_i;
                vector::axpy(1.0, &m.grad, &mut g_of[i]);
            },
        );
        check_quorum(&rp, committed, selected.len(), round, label);
        // Fold the exact round deltas into the persistent state (one
        // rounding per quantity, grouping-invariant).
        l += inv_n * rsum.l.round();
        if !rsum.grad.is_empty() {
            let gd = rsum.grad.round_vec();
            vector::axpy(inv_n, &gd, &mut g);
        }
        rsum.apply_hessian(&pu, &mut h, alpha * inv_n);
        // Out-of-band convergence measurement at xᵏ⁺¹ (the paper makes
        // the same caveat: ∇f(xᵏ) is not part of PP training). Because
        // this probe is measurement-only, it does NOT count toward the
        // communicated-bytes totals (paper App. E.1 accounting) — and
        // for the same reason the PP trace always reports the logical
        // counters, since a transport's metered totals would include
        // the probe's LOSS_GRAD/GRAD frames.
        let (loss, grad) = pool.loss_grad(&x);
        let gnorm = vector::norm2(&grad);
        let (up, down) = (bytes_up, bytes_down);
        trace.push(RoundRecord {
            round,
            grad_norm: gnorm,
            loss,
            bytes_up: up,
            bytes_down: down,
            elapsed: sw.elapsed_secs(),
            committed: committed as u32,
            missing: missing as u32,
            flagged: 0,
        });
        if let Some(tol) = opts.tol_grad {
            if gnorm <= tol {
                break;
            }
        }
        // Durable checkpoint at the cadence boundary: state after the
        // round's folds, sampler past the round's draws — exactly what
        // round + 1 reads.
        if let Some(cfg) = ck {
            if (round + 1) % cfg.every == 0 {
                let snap = pp_snap(
                    d,
                    n,
                    alpha,
                    &h,
                    l,
                    &g,
                    &l_of,
                    &g_of,
                    &rng,
                    &x,
                    &trace,
                    (bytes_up, bytes_down),
                    round + 1,
                    false,
                    &rp,
                    label,
                    &cfg.plan_spec,
                );
                checkpoint::write_snapshot(&cfg.dir, &snap)
                    .expect("checkpoint write failed");
                let _ = checkpoint::prune(
                    &cfg.dir,
                    checkpoint::KEEP_SNAPSHOTS,
                );
            }
        }
    }
    if let Some(cfg) = ck {
        // Terminal snapshot (see run_newton_family).
        let round_next = trace.records.last().map_or(0, |r| r.round + 1);
        let snap = pp_snap(
            d,
            n,
            alpha,
            &h,
            l,
            &g,
            &l_of,
            &g_of,
            &rng,
            &x,
            &trace,
            (bytes_up, bytes_down),
            round_next,
            true,
            &rp,
            label,
            &cfg.plan_spec,
        );
        checkpoint::write_snapshot(&cfg.dir, &snap)
            .expect("checkpoint write failed");
        let _ = checkpoint::prune(&cfg.dir, checkpoint::KEEP_SNAPSHOTS);
    }
    trace.wait_secs = timing.0;
    trace.aggregate_secs = timing.1;
    trace
}

/// Rebuild the Newton-family coordinator state from a durable
/// [`Snapshot`] — shared by `--restore` and the in-process
/// `killmaster@R` rebuild. The aggregate H and shift l land in a fresh
/// [`ServerState`] at the snapshot's iterate; the per-round scratch
/// (`sys`, `sum`) is rebuilt by the next round's `begin_round` /
/// `newton_direction` exactly as in an uninterrupted run.
fn install_newton_snapshot(
    snap: &Snapshot,
    d: usize,
    n: usize,
    alpha: f64,
) -> (ServerState, Vec<Option<u64>>, Vec<Option<ClientMsg>>) {
    assert_eq!(
        (snap.d, snap.n),
        (d, n),
        "snapshot shape (d={}, n={}) does not match the run",
        snap.d,
        snap.n
    );
    assert_eq!(
        alpha.to_bits(),
        snap.alpha.to_bits(),
        "restored α differs from the snapshot's"
    );
    let AlgoSnap::Newton { h, l, last_commit, reuse_cache } = &snap.algo
    else {
        panic!(
            "snapshot holds FedNL-PP state but the run is Newton-family"
        );
    };
    let mut server = ServerState::new(d, n, alpha, snap.x.clone());
    server.h.as_mut_slice().copy_from_slice(h);
    server.l = *l;
    (server, last_commit.clone(), reuse_cache.clone())
}

/// Reinstall the FedNL-PP driver state from a durable [`Snapshot`]:
/// persistent aggregates, per-client mirrors, iterate, and the subset
/// sampler mid-stream (bit-exact continuation of the draw sequence).
#[allow(clippy::too_many_arguments)]
fn install_pp_snapshot(
    snap: &Snapshot,
    d: usize,
    n: usize,
    h: &mut Mat,
    l: &mut f64,
    g: &mut Vec<f64>,
    l_of: &mut Vec<f64>,
    g_of: &mut Vec<Vec<f64>>,
    rng: &mut Pcg64,
    x: &mut Vec<f64>,
) {
    assert_eq!(
        (snap.d, snap.n),
        (d, n),
        "snapshot shape (d={}, n={}) does not match the run",
        snap.d,
        snap.n
    );
    let AlgoSnap::Pp {
        h: sh,
        l: sl,
        g: sg,
        l_of: slo,
        g_of: sgo,
        rng_state,
        rng_inc,
    } = &snap.algo
    else {
        panic!(
            "snapshot holds Newton-family state but the run is FedNL-PP"
        );
    };
    h.as_mut_slice().copy_from_slice(sh);
    *l = *sl;
    *g = sg.clone();
    *l_of = slo.clone();
    *g_of = sgo.clone();
    *rng = Pcg64::from_parts(*rng_state, *rng_inc);
    *x = snap.x.clone();
}

/// Assemble a Newton-family [`Snapshot`] of the coordinator state as it
/// stands entering `round_next`.
#[allow(clippy::too_many_arguments)]
fn newton_snap(
    server: &ServerState,
    last_commit: &[Option<u64>],
    reuse_cache: &[Option<ClientMsg>],
    trace: &Trace,
    bytes: (u64, u64),
    round_next: u64,
    finished: bool,
    rp: &RoundPolicy,
    label: &str,
    plan_spec: &str,
) -> Snapshot {
    Snapshot {
        finished,
        round_next,
        d: server.d,
        n: server.n_clients,
        alpha: server.alpha,
        bytes_up: bytes.0,
        bytes_down: bytes.1,
        x: server.x.clone(),
        label: label.to_string(),
        plan_spec: plan_spec.to_string(),
        policy: *rp,
        algo: AlgoSnap::Newton {
            h: server.h.as_slice().to_vec(),
            l: server.l,
            last_commit: last_commit.to_vec(),
            reuse_cache: reuse_cache.to_vec(),
        },
        records: trace.records.clone(),
    }
}

/// Assemble a FedNL-PP [`Snapshot`] entering `round_next`.
#[allow(clippy::too_many_arguments)]
fn pp_snap(
    d: usize,
    n: usize,
    alpha: f64,
    h: &Mat,
    l: f64,
    g: &[f64],
    l_of: &[f64],
    g_of: &[Vec<f64>],
    rng: &Pcg64,
    x: &[f64],
    trace: &Trace,
    bytes: (u64, u64),
    round_next: u64,
    finished: bool,
    rp: &RoundPolicy,
    label: &str,
    plan_spec: &str,
) -> Snapshot {
    let (rng_state, rng_inc) = rng.state_parts();
    Snapshot {
        finished,
        round_next,
        d,
        n,
        alpha,
        bytes_up: bytes.0,
        bytes_down: bytes.1,
        x: x.to_vec(),
        label: label.to_string(),
        plan_spec: plan_spec.to_string(),
        policy: *rp,
        algo: AlgoSnap::Pp {
            h: h.as_slice().to_vec(),
            l,
            g: g.to_vec(),
            l_of: l_of.to_vec(),
            g_of: g_of.to_vec(),
            rng_state,
            rng_inc,
        },
        records: trace.records.clone(),
    }
}

/// Write a snapshot durably, prune superseded ones, and only then
/// release the deferred `ROUND_ACK`s it covers — the ordering IS the
/// crash-safety invariant: a client learns its round committed only
/// after the commit is on disk.
fn write_and_flush_acks(
    cfg: &CheckpointCfg,
    snap: &Snapshot,
    pool: &mut dyn ClientPool,
    pending: &mut Vec<(u64, Vec<u32>)>,
) {
    checkpoint::write_snapshot(&cfg.dir, snap)
        .expect("checkpoint write failed");
    let _ = checkpoint::prune(&cfg.dir, checkpoint::KEEP_SNAPSHOTS);
    for (r, acked) in pending.drain(..) {
        pool.ack_round(r, &acked);
    }
}

/// Abort loudly when a round closed below quorum (`None` = all
/// participants, clamped to the round's participant count).
fn check_quorum(
    rp: &RoundPolicy,
    committed: usize,
    participants: usize,
    round: u64,
    label: &str,
) {
    let need = rp
        .quorum
        .unwrap_or(participants)
        .min(participants)
        .max(1);
    assert!(
        committed >= need,
        "{label}: round {round} closed with {committed}/{participants} \
         commits, below quorum {need}"
    );
}

/// The stale replay a [`OnMissing::Reuse`] commit injects: the cached
/// message with the Hessian update blanked, so Sᵢ is never applied
/// twice while the stale ∇fᵢ / lᵢ / fᵢ still stand in for the missing
/// client in the first-order reductions.
fn stale_replay(cached: &ClientMsg) -> ClientMsg {
    ClientMsg {
        client_id: cached.client_id,
        grad: cached.grad.clone(),
        update: Compressed {
            payload: IndexPayload::Explicit(Vec::new()),
            values: Vec::new(),
            scale: 1.0,
            encoding: ValueEncoding::F64,
            n: cached.update.n,
        },
        l_i: cached.l_i,
        loss: cached.loss,
    }
}

/// What a speculative round finish hands back: the post-finish server
/// state, the round's reductions, the Newton direction, and how long
/// the (overlapped) work took.
struct SpecResult {
    server: ServerState,
    grad: Vec<f64>,
    loss: Option<f64>,
    dir: Vec<f64>,
    busy_secs: f64,
}

/// One in-flight speculative round finish (`--speculate`).
///
/// At launch the engine has absorbed exactly `committed` client
/// commits — the quorum — and is still draining stragglers. A clone of
/// the server state runs `finish_round(committed)` plus the Newton
/// direction on a helper thread, overlapping the server-side work of
/// the round with the wait. The adoption rule keeps the trajectory
/// bit-identical by construction: the result is adopted **iff** the
/// round finally closes on exactly `committed` commits, i.e. no
/// further sum was absorbed after the snapshot — then the snapshot
/// equals the final server state and the helper performed the exact
/// computation the inline path would have. Any straggler that lands
/// after launch bumps the final count, the stale speculation is joined
/// and discarded, and the round finishes inline as if speculation were
/// off.
struct Speculation {
    /// Commit count baked into the snapshot.
    committed: usize,
    handle: std::thread::JoinHandle<SpecResult>,
}

impl Speculation {
    fn launch(
        server: &ServerState,
        committed: usize,
        rule: UpdateRule,
    ) -> Self {
        let mut snap = server.clone();
        let handle = std::thread::spawn(move || {
            let sw = Stopwatch::start();
            let (grad, loss) = snap.finish_round(committed);
            let dir = snap.newton_direction(&grad, rule);
            SpecResult {
                server: snap,
                grad,
                loss,
                dir,
                busy_secs: sw.elapsed_secs(),
            }
        });
        Speculation { committed, handle }
    }
}

/// Sum-mode round pump: pull pre-reduced [`RoundSum`]s until every
/// participant is accounted for (absorbed into a sum, or certified
/// missing). Because the sums are exact, no ordering or per-client
/// buffering is needed — a shard tier hands the engine S merged
/// accumulators instead of n atoms, and the absorbed state is
/// bit-identical either way. Returns (committed, missing ids).
fn drain_and_sum(
    pool: &mut dyn ClientPool,
    participants: usize,
    bytes_up: &mut u64,
    timing: &mut (f64, f64),
    mut absorb: impl FnMut(RoundSum),
) -> (usize, Vec<u32>) {
    let mut accounted = 0usize;
    let mut missing: Vec<u32> = Vec::new();
    let mut pool_closed = false;
    loop {
        for ci in pool.take_missing() {
            missing.push(ci);
            accounted += 1;
        }
        if accounted >= participants || pool_closed {
            break;
        }
        let sw = Stopwatch::start();
        let batch = pool.drain_sums();
        timing.0 += sw.elapsed_secs();
        if batch.is_empty() {
            pool_closed = true;
            continue;
        }
        let sw = Stopwatch::start();
        for s in batch {
            *bytes_up += s.wire_bytes;
            accounted += s.committed as usize;
            absorb(s);
        }
        timing.1 += sw.elapsed_secs();
    }
    // Losses certified together with the close are not stranded.
    if accounted < participants {
        for ci in pool.take_missing() {
            missing.push(ci);
            accounted += 1;
        }
    }
    assert!(
        accounted == participants,
        "round closed with {accounted}/{participants} participants \
         accounted for"
    );
    (participants - missing.len(), missing)
}

/// Pump the pool until every participant of the round is accounted for
/// — replied, or certified missing and resolved per the round policy.
/// Returns (committed, missing, arrived ids): `arrived` lists the
/// participants whose *own* reply was offered (Reuse replays are
/// committed but not arrived — the commit-ack watermark must not
/// advance on a replay). `timing` accumulates (wait, aggregate)
/// seconds; `cache` (Reuse only) holds each client's last committed
/// message and is refreshed from this round's commits.
fn drain_and_commit(
    pool: &mut dyn ClientPool,
    buf: &mut CommitBuffer,
    policy: &RoundPolicy,
    mut cache: Option<&mut Vec<Option<ClientMsg>>>,
    bytes_up: &mut u64,
    timing: &mut (f64, f64),
    mut commit: impl FnMut(&ClientMsg),
) -> (usize, usize, Vec<u32>) {
    let caching = cache.is_some();
    // Fresh commits to fold back into the cache after the round (kept
    // outside the commit closure so the cache stays readable for
    // replay lookups mid-round). Reuse therefore costs one clone per
    // committed message even on fault-free rounds — the policy is
    // opt-in, and the copy is O(d + k) per client.
    let mut fresh: Vec<ClientMsg> = Vec::new();
    let mut arrived: Vec<u32> = Vec::new();
    // Set once the pool reports the round closed (empty drain): one
    // final `take_missing` pass then runs before the completeness
    // assert, so losses certified together with the close are not
    // stranded.
    let mut pool_closed = false;
    loop {
        // Resolve participants the pool certified as lost: Reuse
        // replays the cached last commit in the lost client's slot,
        // everything else leaves a hole the ladder skips.
        for ci in pool.take_missing() {
            let replay = match (&policy.on_missing, &cache) {
                (OnMissing::Reuse, Some(c)) => {
                    c[ci as usize].as_ref().map(stale_replay)
                }
                _ => None,
            };
            match replay {
                // Replays travel no bytes — nothing was received.
                Some(m) => buf.offer(m, &mut commit),
                None => buf.mark_missing(ci, &mut commit),
            }
        }
        if buf.is_complete() || pool_closed {
            break;
        }
        let sw = Stopwatch::start();
        let batch = pool.drain();
        timing.0 += sw.elapsed_secs();
        if batch.is_empty() {
            pool_closed = true;
            continue;
        }
        let sw = Stopwatch::start();
        for m in batch {
            *bytes_up += m.wire_bytes();
            if caching {
                fresh.push(m.clone());
            }
            arrived.push(m.client_id as u32);
            buf.offer(m, &mut commit);
        }
        timing.1 += sw.elapsed_secs();
    }
    assert!(
        buf.is_complete(),
        "round ended with unaccounted client replies"
    );
    if let Some(c) = cache.as_deref_mut() {
        for m in fresh {
            c[m.client_id] = Some(m);
        }
    }
    arrived.sort_unstable();
    (buf.committed(), buf.len() - buf.committed(), arrived)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{Compressed, IndexPayload, ValueEncoding};

    fn msg(id: usize) -> ClientMsg {
        ClientMsg {
            client_id: id,
            grad: vec![id as f64],
            update: Compressed {
                payload: IndexPayload::Explicit(Vec::new()),
                values: Vec::new(),
                scale: 1.0,
                encoding: ValueEncoding::F64,
                n: 4,
            },
            l_i: 0.0,
            loss: None,
        }
    }

    #[test]
    fn commit_buffer_full_round_commits_in_client_order() {
        let mut buf = CommitBuffer::new(4, None);
        let mut order = Vec::new();
        // Arrival order 2, 0, 3, 1 → commit order 0, 1, 2, 3.
        for id in [2usize, 0, 3, 1] {
            buf.offer(msg(id), |m| order.push(m.client_id));
        }
        assert!(buf.is_complete());
        assert_eq!(buf.committed(), 4);
        assert_eq!(order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn commit_buffer_subset_commits_in_selection_order() {
        // Subset [3, 1, 2]: commit order must follow the sampler, not
        // ascending ids (matches the sequential PP reference).
        let subset = [3u32, 1, 2];
        let mut buf = CommitBuffer::new(5, Some(&subset));
        let mut order = Vec::new();
        for id in [2usize, 3, 1] {
            buf.offer(msg(id), |m| order.push(m.client_id));
        }
        assert!(buf.is_complete());
        assert_eq!(order, vec![3, 1, 2]);
    }

    #[test]
    fn commit_buffer_hole_unblocks_successors() {
        // Client 0 is certified missing while 1..3 already arrived:
        // marking the hole must flush the buffered successors in
        // order, and the committed count excludes the hole.
        let mut buf = CommitBuffer::new(4, None);
        let mut order = Vec::new();
        for id in [2usize, 1, 3] {
            buf.offer(msg(id), |m| order.push(m.client_id));
        }
        assert!(order.is_empty());
        buf.mark_missing(0, |m| order.push(m.client_id));
        assert!(buf.is_complete());
        assert_eq!(order, vec![1, 2, 3]);
        assert_eq!(buf.committed(), 3);
        assert_eq!(buf.len(), 4);
    }

    #[test]
    fn commit_buffer_hole_in_subset_order() {
        let subset = [4u32, 0, 2];
        let mut buf = CommitBuffer::new(5, Some(&subset));
        let mut order = Vec::new();
        buf.offer(msg(2), |m| order.push(m.client_id));
        buf.mark_missing(0, |m| order.push(m.client_id));
        buf.offer(msg(4), |m| order.push(m.client_id));
        assert!(buf.is_complete());
        assert_eq!(order, vec![4, 2]);
        assert_eq!(buf.committed(), 2);
    }

    #[test]
    #[should_panic(expected = "certified missing")]
    fn commit_buffer_rejects_reply_after_missing() {
        let mut buf = CommitBuffer::new(2, None);
        buf.mark_missing(1, |_| {});
        buf.offer(msg(1), |_| {});
    }

    #[test]
    #[should_panic(expected = "missing after its reply committed")]
    fn commit_buffer_rejects_missing_after_commit() {
        let mut buf = CommitBuffer::new(2, None);
        buf.offer(msg(0), |_| {});
        buf.mark_missing(0, |_| {});
    }

    #[test]
    #[should_panic(expected = "non-participating")]
    fn commit_buffer_rejects_foreign_client() {
        let subset = [1u32];
        let mut buf = CommitBuffer::new(3, Some(&subset));
        buf.offer(msg(2), |_| {});
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn commit_buffer_rejects_duplicates() {
        let mut buf = CommitBuffer::new(2, None);
        buf.offer(msg(1), |_| {});
        buf.offer(msg(1), |_| {});
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn commit_buffer_rejects_duplicates_after_commit() {
        // The slot was committed (taken back to None) — the guard must
        // still fire rather than silently re-buffering the message.
        let mut buf = CommitBuffer::new(2, None);
        buf.offer(msg(0), |_| {});
        buf.offer(msg(0), |_| {});
    }

    #[test]
    fn round_policy_validation() {
        let ok = RoundPolicy {
            quorum: Some(3),
            deadline_ms: Some(500),
            on_missing: OnMissing::Drop,
        };
        assert!(ok.validate(5, true, true).is_ok());
        assert!(ok.validate(3, false, false).is_ok());
        // Quorum above the client count, or zero, can never be met.
        let q9 = RoundPolicy { quorum: Some(9), ..ok };
        assert!(q9.validate(5, false, false).is_err());
        let q0 = RoundPolicy { quorum: Some(0), ..ok };
        assert!(q0.validate(5, false, false).is_err());
        // A zero deadline declares every reply late.
        let dl0 = RoundPolicy { deadline_ms: Some(0), ..ok };
        assert!(dl0.validate(5, false, false).is_err());
        // Explicit on-missing without a deadline: fatal only on the
        // remote transport, where losses need a clock to be certified.
        let no_dl = RoundPolicy { deadline_ms: None, ..ok };
        assert!(no_dl.validate(5, true, true).is_err());
        assert!(no_dl.validate(5, false, true).is_ok());
        assert!(no_dl.validate(5, true, false).is_ok());
        // The default policy is always valid.
        assert!(RoundPolicy::default().validate(1, true, false).is_ok());
    }

    #[test]
    fn select_pp_subset_matches_sampler_when_no_faults() {
        // The base draw must consume the RNG exactly like the plain
        // sampler so pre-fault trajectories are unchanged.
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        let plain = sample_distinct(&mut a, 10, 4);
        let sel = select_pp_subset(&mut b, 10, 4, &[], OnMissing::Resample);
        assert_eq!(plain, sel);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn select_pp_subset_resample_avoids_dead() {
        let dead = [0u32, 3, 7];
        for seed in 0..200u64 {
            let mut rng = Pcg64::seed_from_u64(seed);
            let sel =
                select_pp_subset(&mut rng, 10, 5, &dead, OnMissing::Resample);
            assert_eq!(sel.len(), 5, "seed {seed}");
            let mut sorted = sel.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 5, "seed {seed}: duplicates in {sel:?}");
            for c in &sel {
                assert!(!dead.contains(c), "seed {seed}: dead {c} selected");
            }
        }
    }

    #[test]
    fn select_pp_subset_shrinks_when_live_exhausted() {
        // 4 clients, 3 dead, τ=3: at most the single live client can
        // participate.
        let dead = [0u32, 1, 2];
        let mut rng = Pcg64::seed_from_u64(7);
        let sel = select_pp_subset(&mut rng, 4, 3, &dead, OnMissing::Resample);
        assert!(sel.len() <= 1);
        for c in &sel {
            assert_eq!(*c, 3);
        }
    }

    #[test]
    fn select_pp_subset_drop_keeps_dead_picks() {
        // Under Drop the base sample is returned untouched (dead picks
        // become runtime holes instead).
        let mut a = Pcg64::seed_from_u64(9);
        let mut b = Pcg64::seed_from_u64(9);
        let plain = sample_distinct(&mut a, 8, 4);
        let sel = select_pp_subset(&mut b, 8, 4, &[1, 2], OnMissing::Drop);
        assert_eq!(plain, sel);
    }

    use crate::algorithms::ClientState;
    use crate::compressors::by_name;
    use crate::coordinator::SeqPool;
    use crate::data::{generate_synthetic, Dataset, SynthSpec};
    use crate::oracle::LogisticOracle;

    fn make_clients(n: usize, seed: u64) -> (Vec<ClientState>, usize) {
        let spec = SynthSpec {
            d_raw: 7,
            n_samples: n * 24,
            density: 0.6,
            noise: 1.0,
            label_bias: 0.0,
            seed,
        };
        let synth = generate_synthetic(&spec);
        let samples: Vec<crate::data::LibsvmSample> = synth
            .labels
            .iter()
            .zip(&synth.rows)
            .map(|(l, r)| crate::data::LibsvmSample {
                label: *l,
                features: r.clone(),
            })
            .collect();
        let ds = Dataset::from_libsvm(&samples, spec.d_raw);
        let d = ds.d;
        let cs = ds
            .split_even(n)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                ClientState::new(
                    i,
                    Box::new(LogisticOracle::new(sh, 1e-3)),
                    by_name("topk", d, 2, seed + i as u64).unwrap(),
                    None,
                )
            })
            .collect();
        (cs, d)
    }

    /// [`SeqPool`] wrapper recording the engine's commit-ack calls and
    /// scripting one fresh rejoiner, so the ack/resolve sequencing can
    /// be asserted without a transport.
    struct RecordingPool {
        inner: SeqPool<ClientState>,
        rejoiner: u32,
        rejoin_at: u64,
        round: u64,
        acks: Vec<(u64, Vec<u32>)>,
        resolves: Vec<(u32, Option<u64>)>,
        pulls: usize,
    }

    impl ClientPool for RecordingPool {
        fn n_clients(&self) -> usize {
            self.inner.n_clients()
        }

        fn dim(&self) -> usize {
            self.inner.dim()
        }

        fn family(&self) -> ClientFamily {
            self.inner.family()
        }

        fn default_alpha(&self) -> f64 {
            self.inner.default_alpha()
        }

        fn set_alpha(&mut self, alpha: f64) -> f64 {
            self.inner.set_alpha(alpha)
        }

        fn submit_round(
            &mut self,
            x: &[f64],
            subset: Option<&[u32]>,
            round: u64,
            need_loss: bool,
        ) {
            self.inner.submit_round(x, subset, round, need_loss);
        }

        fn drain(&mut self) -> Vec<ClientMsg> {
            self.inner.drain()
        }

        fn eval_loss_each(&mut self, x: &[f64]) -> Vec<(u32, f64)> {
            self.inner.eval_loss_each(x)
        }

        fn loss_grad_each(
            &mut self,
            x: &[f64],
        ) -> Vec<(u32, f64, Vec<f64>)> {
            self.inner.loss_grad_each(x)
        }

        fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
            self.inner.warm_start(x)
        }

        fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
            self.inner.init_state()
        }

        fn prepare_round(&mut self, round: u64) {
            self.round = round;
        }

        fn take_rejoined(&mut self) -> Vec<u32> {
            if self.round == self.rejoin_at {
                vec![self.rejoiner]
            } else {
                Vec::new()
            }
        }

        fn take_fresh_rejoined(&mut self) -> Vec<u32> {
            if self.round == self.rejoin_at {
                vec![self.rejoiner]
            } else {
                Vec::new()
            }
        }

        fn ack_round(&mut self, round: u64, committed: &[u32]) {
            self.acks.push((round, committed.to_vec()));
        }

        fn resolve_staged(
            &mut self,
            client: u32,
            last_commit: Option<u64>,
        ) {
            self.resolves.push((client, last_commit));
        }

        fn pull_h_packed(&mut self) -> Option<Vec<Vec<f64>>> {
            self.pulls += 1;
            Some(self.inner.clients.iter().map(|c| c.packed_h()).collect())
        }
    }

    #[test]
    fn engine_acks_every_round_and_resolves_rejoiners() {
        let rounds = 4u64;
        let opts = Options { rounds, ..Default::default() };
        // Reference: a plain SeqPool with no rejoin scripted.
        let (cs, d) = make_clients(3, 77);
        let mut reference = SeqPool::new(cs);
        let reference = run_engine(
            &mut reference,
            &opts,
            StepPolicy::Newton,
            vec![0.0; d],
            "ref",
        );
        // Recorded run: client 1 surfaces as a *fresh* rejoiner at
        // round 2's prepare.
        let (cs, d2) = make_clients(3, 77);
        assert_eq!(d, d2);
        let mut pool = RecordingPool {
            inner: SeqPool::new(cs),
            rejoiner: 1,
            rejoin_at: 2,
            round: 0,
            acks: Vec::new(),
            resolves: Vec::new(),
            pulls: 0,
        };
        let trace = run_engine(
            &mut pool,
            &opts,
            StepPolicy::Newton,
            vec![0.0; d],
            "recorded",
        );
        // Every round acks its full committed set, in order.
        assert_eq!(pool.acks.len(), rounds as usize);
        for (r, (round, ids)) in pool.acks.iter().enumerate() {
            assert_eq!(*round, r as u64);
            assert_eq!(ids, &[0, 1, 2]);
        }
        // The rejoiner resolves against the watermark of the last
        // round that counted its reply — round 1, the one before the
        // rejoin surfaced.
        assert_eq!(pool.resolves, vec![(1, Some(1))]);
        // One exact H pull for the fresh rejoiner.
        assert_eq!(pool.pulls, 1);
        // The pull lands at round 2's *prepare*, after x² was already
        // fixed: rounds 0..=2 stay bitwise on the reference. The
        // rebuilt H — clients' α·Sᵢᵏ shifts summed exactly, one /n —
        // equals the server's per-round (α/n)-accumulated H only up to
        // last-bit roundings, so round 3 may drift by ulps.
        assert_eq!(reference.records.len(), trace.records.len());
        for (a, b) in reference.records.iter().zip(&trace.records) {
            assert_eq!(a.committed, b.committed);
            assert_eq!(a.missing, b.missing);
            if a.round <= 2 {
                assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits());
            } else {
                let rel = (a.grad_norm - b.grad_norm).abs()
                    / a.grad_norm.max(f64::MIN_POSITIVE);
                assert!(rel < 1e-9, "round {}: rel drift {rel}", a.round);
            }
        }
    }
}

"""Layer-2 JAX model: the fused FedNL local oracle.

One jitted function computes (f_i, ∇f_i, ∇²f_i) for L2-regularized
logistic regression (Eq. 2-5), calling the Layer-1 Pallas kernels for the
three compute stages. Margins and sigmoid values are computed **once** and
reused across all three outputs — the paper's §5.7 "reuse computation from
oracles" optimization becomes operator fusion here.

Signature (all f64):
    oracle(A: (d, n), x: (d,), w: (n,), lam: scalar) -> (loss, grad, hess)

* A carries labels absorbed into its columns (column_j = b_j · a_j, §5.13).
* w is a per-sample weight: 1/n_real for real samples, 0.0 for padding
  columns. This lets one AOT artifact (compiled for padded d×n) serve any
  client whose local shard fits, with exact numerics — padding columns
  contribute 0 to loss/grad/Hessian, padding rows of x are zero.
* lam is a runtime input, so one artifact serves any regularizer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import logistic as k


def pad_shapes(d: int, n: int, bd: int = 16, bn: int = 128) -> tuple[int, int]:
    """Round (d, n) up to tile multiples used by the AOT artifacts."""
    pd = ((d + bd - 1) // bd) * bd
    pn = ((n + bn - 1) // bn) * bn
    return pd, pn


def oracle(
    a: jax.Array, x: jax.Array, w: jax.Array, lam: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(f, ∇f, ∇²f) with margin/sigmoid reuse, Pallas-backed hot loops."""
    # Stage 1 (Pallas): classification margins z = Aᵀx — computed ONCE.
    z = k.margins(a, x)
    # Cheap O(n) elementwise reuse (fused by XLA into one pass):
    sig_neg = jax.nn.sigmoid(-z)          # 1/(1+e^z)
    loss = jnp.sum(w * jnp.logaddexp(0.0, -z)) + 0.5 * lam * jnp.dot(x, x)
    c = -w * sig_neg                       # gradient coefficients
    h = w * sig_neg * (1.0 - sig_neg)      # Hessian weights σ(z)σ(-z)
    # Stage 2 (Pallas): gradient mat-vec.
    grad = k.matvec(a, c) + lam * x
    # Stage 3 (Pallas): weighted Gram — the Eq. 4 hot-spot.
    d = a.shape[0]
    hess = k.weighted_gram(a, h) + lam * jnp.eye(d, dtype=a.dtype)
    return loss, grad, hess


def grad_only(
    a: jax.Array, x: jax.Array, w: jax.Array, lam: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """(f, ∇f) without the Hessian — used by line-search probes (FedNL-LS
    evaluates f at trial points; Alg. 2 line 12) and first-order baselines."""
    z = k.margins(a, x)
    sig_neg = jax.nn.sigmoid(-z)
    loss = jnp.sum(w * jnp.logaddexp(0.0, -z)) + 0.5 * lam * jnp.dot(x, x)
    grad = k.matvec(a, -w * sig_neg) + lam * x
    return loss, grad


def make_example_args(d: int, n: int):
    """ShapeDtypeStructs for AOT lowering at a padded (d, n)."""
    f64 = jnp.float64
    return (
        jax.ShapeDtypeStruct((d, n), f64),
        jax.ShapeDtypeStruct((d,), f64),
        jax.ShapeDtypeStruct((n,), f64),
        jax.ShapeDtypeStruct((), f64),
    )


__all__ = ["oracle", "grad_only", "pad_shapes", "make_example_args"]

//! Property-based suites (self-contained mini-framework: seeded random
//! generation, many cases per property, failing seed reported in the
//! assert message — the role proptest would play).

use fednl::compressors::{
    by_name, distortion_sq, weighted_norm_sq, ALL_NAMES,
};
use fednl::data::parse_libsvm_bytes;
use fednl::linalg::packed::PackedUpper;
use fednl::linalg::{cholesky, gauss, iterative, Mat};
use fednl::oracle::{numerics, LogisticOracle};
use fednl::rng::{Pcg64, Rng};

fn random_packed(d: usize, rng: &mut Pcg64) -> (PackedUpper, Vec<f64>) {
    let pu = PackedUpper::new(d);
    let src = (0..pu.len()).map(|_| rng.next_gaussian()).collect();
    (pu, src)
}

/// Every compressor's *scaled contractive form* must satisfy
/// E‖C(x)−x‖² ≤ (1−δ)‖x‖² on arbitrary inputs (averaged over rounds for
/// the randomized ones).
#[test]
fn prop_contraction_bound_all_compressors() {
    let mut rng = Pcg64::seed_from_u64(1);
    for case in 0..30 {
        let d = 2 + (rng.next_below(10) as usize);
        let (pu, src) = random_packed(d, &mut rng);
        let total = weighted_norm_sq(&pu, &src);
        if total < 1e-12 {
            continue;
        }
        for name in ALL_NAMES {
            let mut c = by_name(name, d, 2, case).unwrap();
            let delta = c.kind(pu.len()).delta();
            let trials = 400;
            let mut acc = 0.0;
            for r in 0..trials {
                let out = c.compress(&pu, &src, r);
                acc += distortion_sq(&pu, &src, &out);
            }
            let mean = acc / trials as f64;
            let bound = (1.0 - delta) * total;
            assert!(
                mean <= bound * 1.12 + 1e-12,
                "case {case} {name} d={d}: E dist {mean} > (1-δ)‖x‖² {bound}"
            );
        }
    }
}

/// Decompressed values must always equal the source at their indices
/// (no compressor corrupts data — only selects/quantizes).
#[test]
fn prop_selected_values_faithful() {
    let mut rng = Pcg64::seed_from_u64(2);
    for case in 0..50 {
        let d = 2 + (rng.next_below(12) as usize);
        let (pu, src) = random_packed(d, &mut rng);
        for name in ["topk", "randk", "randseqk", "toplek", "identity"] {
            let mut c = by_name(name, d, 2, case).unwrap();
            let out = c.compress(&pu, &src, case);
            for (v, i) in out.values.iter().zip(out.indices()) {
                assert_eq!(
                    *v, src[i as usize],
                    "case {case} {name}: value mismatch at {i}"
                );
            }
        }
    }
}

/// Linear-solver agreement: Cholesky, Gaussian elimination and CG agree
/// on random SPD systems.
#[test]
fn prop_solver_agreement() {
    let mut rng = Pcg64::seed_from_u64(3);
    for case in 0..25 {
        let d = 2 + (rng.next_below(20) as usize);
        let b_mat = Mat::from_vec(
            d,
            d,
            (0..d * d).map(|_| rng.next_gaussian()).collect(),
        );
        let mut a = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += b_mat.get(k, i) * b_mat.get(k, j);
                }
                a.set(i, j, s / d as f64);
            }
        }
        a.add_diag(0.5);
        let rhs: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let x1 = cholesky::solve_spd(&a, 0.0, &rhs).unwrap();
        let x2 = gauss::solve_gauss(&a, &rhs).unwrap();
        let x3 = iterative::cg(&a, &rhs, 1e-13, 10 * d).x;
        for i in 0..d {
            assert!((x1[i] - x2[i]).abs() < 1e-7, "case {case} chol vs gauss");
            assert!((x1[i] - x3[i]).abs() < 1e-6, "case {case} chol vs cg");
        }
    }
}

/// The logistic oracle's analytic derivatives match finite differences
/// at random points of random problems.
#[test]
fn prop_oracle_derivatives() {
    let mut rng = Pcg64::seed_from_u64(4);
    for case in 0..10 {
        let d = 3 + (rng.next_below(6) as usize);
        let n = 10 + (rng.next_below(30) as usize);
        let mut at = Mat::zeros(n, d);
        for r in 0..n {
            let lab = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            for c in 0..d - 1 {
                at.set(r, c, lab * rng.next_gaussian());
            }
            at.set(r, d - 1, lab);
        }
        let mut o = LogisticOracle::from_matrix(at, 1e-3);
        let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian() * 0.3).collect();
        let ge = numerics::check_grad(&mut o, &x);
        let he = numerics::check_hessian(&mut o, &x);
        assert!(ge < 1e-6, "case {case}: grad FD err {ge}");
        assert!(he < 1e-4, "case {case}: hess FD err {he}");
    }
}

/// LIBSVM writer→parser round-trip for random datasets (fuzz-lite).
#[test]
fn prop_libsvm_roundtrip_fuzz() {
    let mut rng = Pcg64::seed_from_u64(5);
    for case in 0..40 {
        let n = 1 + rng.next_below(30) as usize;
        let d = 1 + rng.next_below(20) as usize;
        let mut text = String::new();
        let mut expect = Vec::new();
        for _ in 0..n {
            let label = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            text.push_str(if label > 0.0 { "+1" } else { "-1" });
            let mut feats = Vec::new();
            for j in 0..d {
                if rng.bernoulli(0.4) {
                    // Mixed formats: plain, exponent, high precision.
                    let v = match rng.next_below(3) {
                        0 => rng.next_gaussian(),
                        1 => rng.next_gaussian() * 1e-7,
                        _ => (rng.next_below(1000) as f64) / 8.0,
                    };
                    text.push_str(&format!(" {}:{}", j + 1, v));
                    feats.push((j as u32, v));
                }
            }
            text.push('\n');
            expect.push((label, feats));
        }
        let (samples, _) = parse_libsvm_bytes(text.as_bytes()).unwrap();
        assert_eq!(samples.len(), n, "case {case}");
        for (s, (lab, feats)) in samples.iter().zip(&expect) {
            assert_eq!(s.label, *lab, "case {case}");
            assert_eq!(s.features.len(), feats.len(), "case {case}");
            for ((gi, gv), (ei, ev)) in s.features.iter().zip(feats) {
                assert_eq!(gi, ei);
                assert!(
                    (gv - ev).abs() <= 1e-13 * ev.abs().max(1e-3),
                    "case {case}: {gv} vs {ev}"
                );
            }
        }
    }
}

/// Wire codec fuzz: random ClientMsgs survive encode→decode bit-exactly.
#[test]
fn prop_wire_roundtrip_fuzz() {
    use fednl::algorithms::ClientMsg;
    use fednl::compressors::{Compressed, IndexPayload};
    use fednl::net::wire;
    let mut rng = Pcg64::seed_from_u64(6);
    for case in 0..100 {
        let d = 1 + rng.next_below(40) as usize;
        let n = 1 + rng.next_below(200) as u32;
        let k = 1 + rng.next_below(n as u64 % 50 + 1) as u32;
        let payload = match rng.next_below(4) {
            0 => IndexPayload::Explicit(
                (0..k).map(|_| rng.next_below(n as u64) as u32).collect(),
            ),
            1 => IndexPayload::Seed { seed: rng.next_u64(), k },
            2 => IndexPayload::SeqStart {
                start: rng.next_below(n as u64) as u32,
                k,
            },
            _ => IndexPayload::Dense,
        };
        let nvals = match &payload {
            IndexPayload::Dense => n as usize,
            IndexPayload::Explicit(ix) => ix.len(),
            IndexPayload::Seed { k, .. } | IndexPayload::SeqStart { k, .. } => {
                *k as usize
            }
        };
        let msg = ClientMsg {
            client_id: rng.next_below(1000) as usize,
            grad: (0..d).map(|_| rng.next_gaussian()).collect(),
            update: Compressed {
                payload,
                values: (0..nvals).map(|_| rng.next_gaussian()).collect(),
                scale: if rng.bernoulli(0.3) { 8.0 / 9.0 } else { 1.0 },
                encoding: fednl::compressors::ValueEncoding::F64,
                n,
            },
            l_i: rng.next_f64(),
            loss: if rng.bernoulli(0.5) {
                Some(rng.next_gaussian())
            } else {
                None
            },
        };
        // Logical wire accounting is exact for every payload shape.
        assert_eq!(
            msg.wire_bytes(),
            wire::encode_client_msg(&msg).len() as u64
                + wire::FRAME_HEADER_BYTES,
            "case {case}"
        );
        let dec = wire::decode_client_msg(&wire::encode_client_msg(&msg))
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(dec.client_id, msg.client_id);
        assert_eq!(dec.grad, msg.grad);
        assert_eq!(dec.l_i, msg.l_i);
        assert_eq!(dec.loss, msg.loss);
        assert_eq!(dec.update.values, msg.update.values);
        assert_eq!(dec.update.scale, msg.update.scale);
        assert_eq!(dec.update.payload, msg.update.payload);
    }
}

/// TopLEK never sends more than TopK would, over many random inputs.
#[test]
fn prop_toplek_never_exceeds_k() {
    let mut rng = Pcg64::seed_from_u64(7);
    for case in 0..60 {
        let d = 2 + rng.next_below(12) as usize;
        let (pu, src) = random_packed(d, &mut rng);
        let k = 1 + rng.next_below(pu.len() as u64) as usize;
        let mut lek = fednl::compressors::TopLEK::new(k, case);
        use fednl::compressors::Compressor;
        let out = lek.compress(&pu, &src, case);
        assert!(
            out.values.len() <= k,
            "case {case}: sent {} > k={k}",
            out.values.len()
        );
    }
}

//! FedNL-PP (paper Algorithm 3): partial participation — only a
//! τ-subset Sᵏ of clients, chosen uniformly at random, works each round.
//!
//! The server maintains gᵏ = (1/n)Σ gᵢᵏ, lᵏ = (1/n)Σ lᵢᵏ and
//! Hᵏ = (1/n)Σ Hᵢᵏ incrementally from participant deltas; the model
//! update xᵏ⁺¹ = (Hᵏ + lᵏI)⁻¹ gᵏ happens *before* sampling (line 4).
//! Non-participants change nothing. gᵢ is the "Hessian-corrected local
//! gradient" (Hᵢ + lᵢI)wᵢ − ∇fᵢ(wᵢ), evaluated on the packed Hᵢ without
//! densifying.
//!
//! Since the streaming-coordination refactor PP is an ordinary client
//! of the unified round engine: a [`PPClientState`] implements
//! [`crate::coordinator::PoolClient`] (its round = Alg. 3's
//! `participate`, its message fields carry the deltas), so FedNL-PP
//! runs over **every** [`crate::coordinator::ClientPool`] transport —
//! `SeqPool`, `ThreadedPool` and the TCP `RemotePool` — with the seeded
//! participation sampler living in the driver.
//!
//! The trace's ‖∇f(xᵏ)‖ is computed out-of-band over all clients — the
//! paper makes the same caveat ("FedNL-PP lacks explicit support for the
//! computation of ∇f(xᵏ) as part of the training process").

use super::engine::{run_engine, StepPolicy};
use super::{ClientMsg, Options};
use crate::compressors::Compressor;
use crate::coordinator::{ClientPool, SlicePool};
use crate::linalg::packed::PackedUpper;
use crate::linalg::{vector, Mat};
use crate::metrics::Trace;
use crate::oracle::Oracle;

/// Per-client FedNL-PP state (Alg. 3 initialization, line 2).
pub struct PPClientState {
    pub id: usize,
    pub oracle: Box<dyn Oracle>,
    pub compressor: Box<dyn Compressor>,
    pub alpha: f64,
    /// Local model copy wᵢ.
    pub w: Vec<f64>,
    /// Hᵢ packed.
    pub h_shift: Vec<f64>,
    pub l_i: f64,
    pub g_i: Vec<f64>,
    pu: PackedUpper,
    hess: Mat,
    hess_packed: Vec<f64>,
    diff: Vec<f64>,
    grad_buf: Vec<f64>,
}

impl PPClientState {
    pub fn new(
        id: usize,
        mut oracle: Box<dyn Oracle>,
        compressor: Box<dyn Compressor>,
        alpha: Option<f64>,
        x0: &[f64],
    ) -> Self {
        let d = oracle.dim();
        let pu = PackedUpper::new(d);
        let n = pu.len();
        let alpha = alpha.unwrap_or_else(|| compressor.kind(n).alpha());
        // Initialization with Hᵢ⁰ = 0:
        //   lᵢ⁰ = ‖0 − ∇²fᵢ(x⁰)‖_F, gᵢ⁰ = lᵢ⁰·x⁰ − ∇fᵢ(x⁰).
        let mut hess = Mat::zeros(d, d);
        let mut grad = vec![0.0; d];
        let _ = oracle.loss_grad_hessian(x0, &mut grad, &mut hess);
        let mut hess_packed = vec![0.0; n];
        pu.pack(&hess, &mut hess_packed);
        let l0 = pu.frobenius_sq_packed(&hess_packed).sqrt();
        let mut g0 = vec![0.0; d];
        for i in 0..d {
            g0[i] = l0 * x0[i] - grad[i];
        }
        Self {
            id,
            oracle,
            compressor,
            alpha,
            w: x0.to_vec(),
            h_shift: vec![0.0; n],
            l_i: l0,
            g_i: g0,
            pu,
            hess,
            hess_packed,
            diff: vec![0.0; n],
            grad_buf: vec![0.0; d],
        }
    }

    pub fn dim(&self) -> usize {
        self.grad_buf.len()
    }

    /// Participate in round `round` with new model `x` (lines 9–13).
    /// Returns the unified [`ClientMsg`]: `grad` carries Δgᵢ and `l_i`
    /// carries Δlᵢ (the server adds them to its running sums).
    pub fn participate(
        &mut self,
        x: &[f64],
        round: u64,
        need_loss: bool,
    ) -> ClientMsg {
        let d = self.dim();
        self.w.copy_from_slice(x);
        let loss = self.oracle.loss_grad_hessian(
            x,
            &mut self.grad_buf,
            &mut self.hess,
        );
        self.pu.pack(&self.hess, &mut self.hess_packed);
        vector::sub(&self.hess_packed, &self.h_shift, &mut self.diff);
        let update = self.compressor.compress(&self.pu, &self.diff, round);
        // Hᵢ ← Hᵢ + α·C(∇²fᵢ − Hᵢ) (line 10).
        let a = self.alpha * update.scale;
        for (v, idx) in update.values.iter().zip(update.indices()) {
            self.h_shift[idx as usize] += a * v;
        }
        // lᵢ ← ‖Hᵢ − ∇²fᵢ‖_F (line 11) — recompute on the updated shift.
        vector::sub(&self.h_shift, &self.hess_packed, &mut self.diff);
        let l_new = self.pu.frobenius_sq_packed(&self.diff).sqrt();
        // gᵢ ← (Hᵢ + lᵢI)wᵢ − ∇fᵢ(wᵢ) (line 12), packed matvec.
        let mut g_new = vec![0.0; d];
        self.pu.matvec_packed(&self.h_shift, &self.w, &mut g_new);
        for i in 0..d {
            g_new[i] += l_new * self.w[i] - self.grad_buf[i];
        }
        let dl = l_new - self.l_i;
        let mut dg = vec![0.0; d];
        vector::sub(&g_new, &self.g_i, &mut dg);
        self.l_i = l_new;
        self.g_i = g_new;
        ClientMsg {
            client_id: self.id,
            grad: dg,
            update,
            l_i: dl,
            loss: if need_loss { Some(loss) } else { None },
        }
    }
}

/// Run FedNL-PP with `tau` participating clients per round, over any
/// client transport (the pool's clients must be [`PPClientState`]s —
/// in-process — or TCP clients running in PP mode).
pub fn run_fednl_pp_pool(
    pool: &mut dyn ClientPool,
    opts: &Options,
    tau: usize,
    seed: u64,
    x0: Vec<f64>,
    label: &str,
) -> Trace {
    run_engine(
        pool,
        opts,
        StepPolicy::PartialParticipation { tau, seed },
        x0,
        label,
    )
}

/// Convenience: FedNL-PP over in-process clients.
pub fn run_fednl_pp(
    clients: &mut [PPClientState],
    opts: &Options,
    tau: usize,
    seed: u64,
    x0: Vec<f64>,
) -> Trace {
    assert!(!clients.is_empty());
    let label = format!("FedNL-PP/{}", clients[0].compressor.name());
    run_fednl_pp_pool(&mut SlicePool::new(clients), opts, tau, seed, x0, &label)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::by_name;
    use crate::data::{generate_synthetic, Dataset, SynthSpec};
    use crate::oracle::LogisticOracle;

    fn pp_clients(
        n: usize,
        comp: &str,
        seed: u64,
        x0: &[f64],
        d_raw: usize,
    ) -> Vec<PPClientState> {
        let spec = SynthSpec {
            d_raw,
            n_samples: n * 40,
            density: 0.6,
            noise: 1.0,
            label_bias: 0.0,
            seed,
        };
        let synth = generate_synthetic(&spec);
        let samples: Vec<crate::data::LibsvmSample> = synth
            .labels
            .iter()
            .zip(&synth.rows)
            .map(|(l, r)| crate::data::LibsvmSample {
                label: *l,
                features: r.clone(),
            })
            .collect();
        let ds = Dataset::from_libsvm(&samples, spec.d_raw);
        let d = ds.d;
        ds.split_even(n)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                PPClientState::new(
                    i,
                    Box::new(LogisticOracle::new(sh, 1e-3)),
                    by_name(comp, d, 2, seed + i as u64).unwrap(),
                    None,
                    x0,
                )
            })
            .collect()
    }

    #[test]
    fn full_participation_converges() {
        let d = 9;
        let x0 = vec![0.0; d];
        let mut cs = pp_clients(4, "topk", 21, &x0, d - 1);
        let opts = Options { rounds: 120, ..Default::default() };
        let tr = run_fednl_pp(&mut cs, &opts, 4, 1, x0);
        assert!(tr.last_grad_norm() < 1e-8, "‖∇f‖={}", tr.last_grad_norm());
    }

    #[test]
    fn partial_participation_converges_slower_but_converges() {
        let d = 9;
        let x0 = vec![0.0; d];
        let mut full = pp_clients(6, "randk", 22, &x0, d - 1);
        let mut part = pp_clients(6, "randk", 22, &x0, d - 1);
        let opts = Options { rounds: 200, ..Default::default() };
        let tr_full = run_fednl_pp(&mut full, &opts, 6, 2, x0.clone());
        let tr_part = run_fednl_pp(&mut part, &opts, 2, 2, x0);
        assert!(tr_full.last_grad_norm() < 1e-8);
        assert!(tr_part.last_grad_norm() < 1e-5, "partial: {}", tr_part.last_grad_norm());
        // Partial needs more rounds to a fixed tolerance.
        let rf = tr_full.rounds_to_tolerance(1e-6).unwrap();
        let rp = tr_part.rounds_to_tolerance(1e-6).unwrap_or(u64::MAX);
        assert!(rp >= rf, "partial {rp} < full {rf}");
    }

    #[test]
    fn selection_is_seeded_deterministic() {
        let d = 7;
        let x0 = vec![0.0; d];
        let mut a = pp_clients(5, "randseqk", 23, &x0, d - 1);
        let mut b = pp_clients(5, "randseqk", 23, &x0, d - 1);
        let opts = Options { rounds: 30, ..Default::default() };
        let ta = run_fednl_pp(&mut a, &opts, 2, 9, x0.clone());
        let tb = run_fednl_pp(&mut b, &opts, 2, 9, x0);
        for (ra, rb) in ta.records.iter().zip(&tb.records) {
            assert_eq!(ra.grad_norm, rb.grad_norm);
        }
    }
}

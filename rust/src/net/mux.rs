//! Client-side multiplexer: thousands of simulated clients, one socket.
//!
//! [`run_mux_clients`] hosts a contiguous partition of in-process
//! clients behind a single TCP connection to an [`EventPool`] master
//! (or, transitively, to a relay's downward `EventPool` face). It is
//! the `SlicePool` idea extended over TCP: the hosted clients live in
//! one process — sharing the loaded dataset, the allocator, and one
//! frame codec — while the wire carries one *batched* exchange per
//! group instead of one connection per client.
//!
//! The protocol is deliberately **not new**: a mux group registers
//! with `SHARD_REGISTER` and then speaks exactly the relay tier's
//! upward frames (`SHARD_ROUND` → `SHARD_MSG`/`SHARD_SUM`, probe
//! batches, `SHARD_PREP`, …), so the master cannot distinguish a mux
//! group from a relay fronting remote clients — one validation path,
//! one codec, bit-identical arithmetic. The serve loop below mirrors
//! `run_relay_on` with the downward `RemotePool` replaced by an
//! in-process [`SlicePool`]; the only semantic difference is liveness:
//! hosted clients cannot individually die or rejoin, so `SHARD_PREP`
//! always reports empty rejoin/dead sets and a lost group is the unit
//! of failure (the master certifies the whole partition missing).
//!
//! [`EventPool`]: super::event::EventPool

use anyhow::{Context, Result};

use super::client::connect_with_retry;
use super::framing::Channel;
use super::wire::{self, c2s, s2c};
use crate::algorithms::{ClientMsg, RoundSum};
use crate::coordinator::{ClientFamily, ClientPool, PoolClient, SlicePool};

/// Byte totals a finished mux group reports (upward link only — there
/// is no downward transport).
#[derive(Debug, Clone, Copy, Default)]
pub struct MuxReport {
    pub up_sent: u64,
    pub up_recv: u64,
}

/// Host `clients` (contiguous ascending ids) behind one connection to
/// `connect`, serving rounds and probes until the master's SHUTDOWN
/// (or EOF). `group_id` is echoed in every batch frame so the master
/// can validate provenance; it only needs to be stable per connection,
/// not globally unique.
pub fn run_mux_clients<C: PoolClient>(
    clients: &mut [C],
    group_id: u32,
    connect: &str,
) -> Result<MuxReport> {
    anyhow::ensure!(!clients.is_empty(), "mux group hosts no clients");
    let base = clients[0].id() as u32;
    anyhow::ensure!(
        clients
            .iter()
            .enumerate()
            .all(|(i, c)| c.id() == base as usize + i),
        "mux group ids must be contiguous ascending"
    );
    let mut pool = SlicePool::new(clients);
    let d = pool.dim();
    let family = match pool.family() {
        ClientFamily::FedNL => wire::FAMILY_FEDNL,
        ClientFamily::PP => wire::FAMILY_PP,
    };
    let stream = connect_with_retry(connect, 50)?;
    let mut up = Channel::new(stream)?;
    up.send(
        c2s::SHARD_REGISTER,
        &wire::encode_shard_register(
            group_id,
            base,
            pool.n_clients() as u32,
            d as u32,
            family,
            0, // hosted clients never stage — no ack traffic wanted
        ),
    )
    .context("mux registration")?;

    loop {
        // Master gone (EOF) = orderly end of the run.
        let Ok((tag, payload)) = up.recv() else { break };
        match tag {
            s2c::SHARD_ROUND => {
                let (x, round, need_loss, sum, deadline_ms, subset) =
                    wire::decode_shard_round(&payload)?;
                // The deadline is advisory here: in-process clients
                // compute synchronously, so the group either replies
                // in full or (if wedged) blows the master's
                // group-slack budget and is retired whole.
                let _ = deadline_ms;
                pool.submit_round(&x, Some(&subset), round, need_loss);
                let mut msgs: Vec<ClientMsg> = Vec::new();
                loop {
                    let batch = pool.drain();
                    if batch.is_empty() {
                        break;
                    }
                    msgs.extend(batch);
                }
                if sum {
                    // Pre-reduce next to the clients: one exact
                    // superaccumulator upward, O(d) regardless of the
                    // hosted count.
                    let mut merged = RoundSum::from_msgs(&msgs);
                    up.send(
                        c2s::SHARD_SUM,
                        &wire::encode_shard_sum(
                            group_id,
                            &mut merged,
                            &[],
                        ),
                    )?;
                } else {
                    // Atom mode, round-subset order (the relay-tier
                    // contract; SlicePool already surfaces replies in
                    // that order, the sort keeps it explicit).
                    let pos = |ci: u32| {
                        subset
                            .iter()
                            .position(|&c| c == ci)
                            .expect("reply outside the round subset")
                    };
                    msgs.sort_by_key(|m| pos(m.client_id as u32));
                    up.send(
                        c2s::SHARD_MSG,
                        &wire::encode_shard_msg(group_id, &msgs, &[]),
                    )?;
                }
            }
            s2c::SHARD_PREP => {
                // Hosted clients have no independent liveness:
                // nothing rejoins, nothing dies, reply empty.
                up.send(
                    c2s::SHARD_PREPPED,
                    &wire::encode_shard_prepped(&[], &[], &[]),
                )?;
            }
            s2c::SHARD_PULL => {
                let client = {
                    let mut rd = crate::utils::ByteReader::new(&payload);
                    rd.get_u32()?
                };
                let state = pool.pull_state(client);
                up.send(
                    c2s::SHARD_PULLED,
                    &wire::encode_shard_pulled(
                        state.as_ref().map(|(l, g)| (*l, g.as_slice())),
                    ),
                )?;
            }
            s2c::EVAL_LOSS => {
                let x = wire::decode_vec(&payload)?;
                let parts = pool.eval_loss_each(&x);
                up.send(
                    c2s::SHARD_LOSSES,
                    &wire::encode_id_scalars(&parts),
                )?;
            }
            s2c::LOSS_GRAD => {
                let x = wire::decode_vec(&payload)?;
                let parts = pool.loss_grad_each(&x);
                up.send(
                    c2s::SHARD_GRADS,
                    &wire::encode_id_scalar_vecs(&parts),
                )?;
            }
            s2c::LOSS_GRAD_SUM => {
                let x = wire::decode_vec(&payload)?;
                let (mut loss, mut grad, count) = pool.loss_grad_sum(&x);
                up.send(
                    c2s::SHARD_GRAD_SUM,
                    &wire::encode_shard_grad_sum(
                        count, &mut loss, &mut grad,
                    ),
                )?;
            }
            s2c::WARM_START => {
                let x = wire::decode_vec(&payload)?;
                let packs = pool.warm_start(&x);
                up.send(
                    c2s::SHARD_WARM,
                    &wire::encode_vec_batch(&packs),
                )?;
            }
            s2c::STATE => {
                let states = pool.init_state();
                let parts: Vec<(u32, f64, Vec<f64>)> = states
                    .into_iter()
                    .enumerate()
                    .map(|(slot, (l, g))| (base + slot as u32, l, g))
                    .collect();
                up.send(
                    c2s::SHARD_STATES,
                    &wire::encode_id_scalar_vecs(&parts),
                )?;
            }
            s2c::SET_ALPHA => {
                let a = wire::decode_scalar(&payload)?;
                let effective = pool.set_alpha(a);
                up.send(c2s::ACK, &wire::encode_scalar(effective))?;
            }
            s2c::SHUTDOWN => break,
            other => anyhow::bail!("mux: unknown command tag {other}"),
        }
    }
    Ok(MuxReport { up_sent: up.bytes_sent, up_recv: up.bytes_received })
}

//! Ablation bench — re-measures the paper's §5 optimization ladder on
//! this implementation (Table 4 / App. B analogue). Each row toggles
//! one design decision and reports the slowdown of the *unoptimized*
//! variant, mirroring the paper's per-step relative speedups.
//!
//! Run: `cargo bench --bench ablation`

use fednl::data::ClientShard;
use fednl::linalg::packed::PackedUpper;
use fednl::linalg::{cholesky, gauss, Mat};
use fednl::oracle::{LogisticOracle, Oracle};
use fednl::rng::{Pcg64, Rng};
use fednl::utils::TimerStats;

fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut st = TimerStats::new();
    for _ in 0..iters {
        st.time(&mut f);
    }
    st.min()
}

fn row(name: &str, paper: &str, base: f64, opt: f64) {
    println!(
        "{name:<52} {:>9.3}ms vs {:>9.3}ms  → ×{:<6.3} (paper: {paper})",
        base * 1e3,
        opt * 1e3,
        base / opt
    );
}

fn random_shard(d: usize, n: usize, seed: u64) -> ClientShard {
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut at = Mat::zeros(n, d);
    for r in 0..n {
        let lab = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        for c in 0..d - 1 {
            at.set(r, c, lab * rng.next_gaussian());
        }
        at.set(r, d - 1, lab);
    }
    ClientShard { client_id: 0, at }
}

fn main() {
    let d = 301;
    let n_i = 350;
    println!("== ablation ladder (W8A client shape d={d}, n_i={n_i}) ==\n");

    // ---- §5.7 margin/sigmoid reuse (paper ×1.50) ---------------------
    {
        let mut oracle = LogisticOracle::new(random_shard(d, n_i, 1), 1e-3);
        let x = vec![0.05; d];
        let mut g = vec![0.0; d];
        let mut h = Mat::zeros(d, d);
        let fused =
            time(2, 15, || { let _ = oracle.loss_grad_hessian(&x, &mut g, &mut h); });
        let separate = time(2, 15, || {
            let _ = oracle.loss(&x);
            oracle.grad(&x, &mut g);
            oracle.hessian(&x, &mut h);
        });
        row("§5.7 margin reuse: separate oracles vs fused", "×1.50", separate, fused);
    }

    // ---- §5.10 Hessian strategy (paper ×3.07 cumulative) -------------
    {
        let shard = random_shard(d, n_i, 2);
        let mut rng = Pcg64::seed_from_u64(3);
        let h_w: Vec<f64> = (0..n_i).map(|_| rng.next_f64() * 0.25).collect();
        // Optimized: symmetric rank-1 blocks on the upper triangle.
        let opt = time(2, 15, || {
            let mut hess = Mat::zeros(d, d);
            let rows: Vec<&[f64]> = (0..n_i).map(|r| shard.at.row(r)).collect();
            hess.sym_rank1_block_upper(&rows, &h_w);
            hess.symmetrize_from_upper();
            std::hint::black_box(hess);
        });
        // Baseline: materialize scaled A then full tiled matmul AᵀΛA.
        let base = time(2, 8, || {
            let mut scaled = shard.at.clone(); // (n × d)
            for r in 0..n_i {
                let w = h_w[r];
                for v in scaled.row_mut(r) {
                    *v *= w;
                }
            }
            // (d × n) · (n × d) via transpose-free tiled matmul of
            // atᵀ·scaled — emulate with naive 3-loop over at.
            let mut hess = Mat::zeros(d, d);
            for r in 0..n_i {
                let a_row = shard.at.row(r);
                let s_row = scaled.row(r);
                for i in 0..d {
                    let ai = a_row[i];
                    if ai == 0.0 {
                        continue;
                    }
                    let dst = hess.row_mut(i);
                    for j in 0..d {
                        dst[j] += ai * s_row[j];
                    }
                }
            }
            std::hint::black_box(hess);
        });
        row("§5.10 hessian: dense full-matrix accum vs sym-rank1", "×1.85", base, opt);
    }

    // ---- §5.9 linear solve (paper ×1.31) ------------------------------
    {
        let shard = random_shard(d, n_i, 4);
        let mut oracle = LogisticOracle::new(shard, 1e-3);
        let mut g = vec![0.0; d];
        let mut h = Mat::zeros(d, d);
        let _ = oracle.loss_grad_hessian(&vec![0.0; d], &mut g, &mut h);
        let chol = time(2, 15, || {
            std::hint::black_box(cholesky::solve_spd(&h, 1e-3, &g).unwrap());
        });
        let ge = time(2, 15, || {
            let mut hs = h.clone();
            hs.add_diag(1e-3);
            std::hint::black_box(gauss::solve_gauss(&hs, &g).unwrap());
        });
        row("§5.9 solve: gaussian elimination vs cholesky", "×1.31", ge, chol);
    }

    // ---- §5.6 sparse server update (paper ×1.44) ----------------------
    {
        let pu = PackedUpper::new(d);
        let mut rng = Pcg64::seed_from_u64(5);
        let k = 8 * d;
        let idx: Vec<u32> =
            fednl::rng::sample_distinct(&mut rng, pu.len(), k);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        let vals: Vec<f64> = (0..k).map(|_| rng.next_gaussian()).collect();
        let mut hmat = Mat::zeros(d, d);
        let sparse = time(3, 50, || {
            pu.apply_sparse(&mut hmat, 0.5, &sorted, &vals);
        });
        // Dense alternative: materialize the full packed buffer & add.
        let mut dense_buf = vec![0.0; pu.len()];
        let dense = time(3, 50, || {
            for b in dense_buf.iter_mut() {
                *b = 0.0;
            }
            for (i, &ix) in sorted.iter().enumerate() {
                dense_buf[ix as usize] = vals[i];
            }
            let mut full = Mat::zeros(d, d);
            pu.unpack(&dense_buf, &mut full);
            hmat.axpy(0.5, &full);
        });
        row("§5.6 server update: densify+add vs sparse apply", "×1.44", dense, sparse);

        // §5.11 sorted vs unsorted index application (paper ×1.0182).
        let unsorted = time(3, 50, || {
            pu.apply_sparse(&mut hmat, 0.5, &idx, &vals);
        });
        row("§5.11 master update: unsorted vs sorted indices", "×1.018", unsorted, sparse);
    }

    // ---- v51 Frobenius symmetry (paper ×1.0075) -----------------------
    {
        let m = {
            let mut rng = Pcg64::seed_from_u64(6);
            let mut m = Mat::zeros(d, d);
            for i in 0..d {
                for j in i..d {
                    let v = rng.next_gaussian();
                    m.set(i, j, v);
                    m.set(j, i, v);
                }
            }
            m
        };
        let sym = time(3, 200, || {
            std::hint::black_box(m.frobenius_sq_symmetric());
        });
        let gen = time(3, 200, || {
            std::hint::black_box(m.frobenius_sq());
        });
        row("v51 frobenius: full scan vs upper-triangle", "×1.0075", gen, sym);
    }

    // ---- §5.12 threading (paper ×1.40) --------------------------------
    {
        use fednl::algorithms::{run_fednl_pool, ClientState, Options};
        use fednl::compressors::by_name;
        use fednl::coordinator::{SeqPool, ThreadedPool};
        let make_clients = || -> Vec<ClientState> {
            (0..8)
                .map(|i| {
                    ClientState::new(
                        i,
                        Box::new(LogisticOracle::new(
                            random_shard(128, 128, 10 + i as u64),
                            1e-3,
                        )),
                        by_name("topk", 128, 8, i as u64).unwrap(),
                        None,
                    )
                })
                .collect()
        };
        let opts = Options { rounds: 15, ..Default::default() };
        let seq = time(1, 5, || {
            let mut pool = SeqPool::new(make_clients());
            std::hint::black_box(run_fednl_pool(
                &mut pool,
                &opts,
                vec![0.0; 128],
                "seq",
            ));
        });
        let thr = time(1, 5, || {
            let mut pool = ThreadedPool::new(make_clients(), 0);
            std::hint::black_box(run_fednl_pool(
                &mut pool,
                &opts,
                vec![0.0; 128],
                "thr",
            ));
        });
        row("§5.12 clients: sequential vs worker pool (8 clients)", "×1.40", seq, thr);
    }

    println!("\n(×>1 in the last column = the optimized variant wins; the paper's factors are from the Xeon 6246 testbed)");
}

//! # Unlocking FedNL — self-contained compute-optimized implementation
//!
//! Reproduction of Burlachenko & Richtárik, *"Unlocking FedNL: Self-Contained
//! Compute-Optimized Implementation"* (2024), as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: the FedNL /
//!   FedNL-LS / FedNL-PP algorithm family, communication compressors
//!   (TopK, RandK, RandSeqK, TopLEK, Natural, Identity), a single-node
//!   multi-threaded simulator, and a multi-node TCP master/client runtime.
//!   Its dense hot path (dot/AXPY, the §5.10 rank-1 Hessian accumulate,
//!   the §5.7 fused sigmoid pass, the §5.11 compressor energy scans) runs
//!   on [`linalg::simd`], a runtime-dispatched kernel layer: AVX2+FMA
//!   intrinsics when the host CPU supports them, portable 4-way-unrolled
//!   scalar fallbacks otherwise — no compile-time feature flags, fixed
//!   reduction orders, bit-reproducible trajectories per machine.
//! * **Layer 2 (python/compile/model.py)** — the logistic-regression oracle
//!   (loss, gradient, Hessian) expressed in JAX, AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — the oracle hot-spot as a Pallas
//!   kernel, validated against a pure-jnp reference.
//!
//! The crate is deliberately *self-contained*: every substrate the paper's
//! C++ implementation built in-house (dense linear algebra, direct and
//! iterative linear solvers, LIBSVM parsing, PRNGs, thread pools, TCP
//! framing, CLI parsing, benchmarking) is implemented here from scratch on
//! top of `std` only, mirroring the paper's "relies only on OS interfaces"
//! design philosophy. The only required external dependency is `anyhow`
//! (error handling); the `xla` crate (PJRT bridge to the AOT artifacts) is
//! optional behind the `xla` cargo feature, with a stub runtime otherwise.

pub mod algorithms;
pub mod baselines;
pub mod cli;
pub mod compressors;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod oracle;
pub mod rng;
pub mod robust;
pub mod runtime;
pub mod utils;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

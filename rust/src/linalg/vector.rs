//! Dense-vector kernels over `&[f64]`.
//!
//! Hot operations (dot, AXPY, norms, fused add-scaled) delegate to the
//! runtime-dispatched kernel layer in [`super::simd`]: AVX2+FMA
//! intrinsics when the host CPU has them, portable 4-way manually
//! unrolled loops otherwise (paper v32 "manually unroll loops for vector
//! and vector-scalar operations" / §5.4 AVX intrinsics). The remaining
//! operations are bandwidth-bound copies the autovectorizer already
//! handles.

use super::simd;

/// Dot product (runtime-dispatched SIMD).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    simd::dot(a, b)
}

/// `y += alpha * x` (AXPY, runtime-dispatched SIMD).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    simd::axpy(alpha, x, y)
}

/// `y = x` fast copy.
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// `x *= alpha`.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// `out = a - b`.
#[inline]
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert!(a.len() == b.len() && b.len() == out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// `out = a + b`.
#[inline]
pub fn add(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert!(a.len() == b.len() && b.len() == out.len());
    for i in 0..a.len() {
        out[i] = a[i] + b[i];
    }
}

/// Euclidean norm ‖x‖₂.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    simd::norm2_sq(x).sqrt()
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(x: &[f64]) -> f64 {
    simd::norm2_sq(x)
}

/// ℓ∞ norm (runtime-dispatched abs-max scan).
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    simd::abs_max(x)
}

/// Set all entries to zero (allocation-free reset of reused buffers).
#[inline]
pub fn fill_zero(x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi = 0.0;
    }
}

/// Fused `out = a + alpha * b` (paper v42 "fused operation for
/// matrix-vector operation and add multiple of vector").
#[inline]
pub fn add_scaled(a: &[f64], alpha: f64, b: &[f64], out: &mut [f64]) {
    debug_assert!(a.len() == b.len() && b.len() == out.len());
    simd::add_scaled(a, alpha, b, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5 - 3.0).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12 * naive.abs().max(1.0));
    }

    #[test]
    fn dot_handles_short_vectors() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[1.0, 1.0, 1.0]), 6.0);
    }

    #[test]
    fn axpy_works() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpy_long_matches_scalar() {
        let x: Vec<f64> = (0..131).map(|i| (i as f64).cos()).collect();
        let mut y1: Vec<f64> = (0..131).map(|i| i as f64 * 0.1).collect();
        let mut y2 = y1.clone();
        axpy(-1.7, &x, &mut y1);
        crate::linalg::simd::scalar::axpy(-1.7, &x, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() <= 4.0 * f64::EPSILON * a.abs().max(1.0));
        }
    }

    #[test]
    fn norms() {
        let x = [3.0, 4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-15);
        assert_eq!(norm2_sq(&x), 25.0);
        assert_eq!(norm_inf(&[-7.0, 2.0]), 7.0);
    }

    #[test]
    fn add_scaled_fused() {
        let a = [1.0, 1.0];
        let b = [2.0, 4.0];
        let mut out = [0.0; 2];
        add_scaled(&a, 0.5, &b, &mut out);
        assert_eq!(out, [2.0, 3.0]);
    }

    #[test]
    fn sub_add_roundtrip() {
        let a = [5.0, 7.0, -1.0];
        let b = [1.0, 2.0, 3.0];
        let mut d = [0.0; 3];
        let mut s = [0.0; 3];
        sub(&a, &b, &mut d);
        add(&d, &b, &mut s);
        assert_eq!(s, a);
    }
}

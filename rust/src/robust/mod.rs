//! Byzantine-robust server-side aggregation (`--defense`).
//!
//! The attack half of the robustness subsystem lives in
//! `coordinator::faults` (`corrupt@R:C:MODE` events, injected
//! deterministically by `FaultPool` before commit). This module is the
//! defense half: pluggable robust folds applied at the master's
//! [`ServerState`](crate::algorithms::ServerState) aggregation point
//! in `algorithms::engine`, selected with `--defense`:
//!
//! * `normclip:TAU` — per-client L2 clipping: each committed message's
//!   joint contribution vector (the gradient concatenated with the
//!   effective Hessian-update entries `scale·vⱼ`) is rescaled by
//!   γ = min(1, τ/‖·‖₂) before it is absorbed. A message at or below
//!   the threshold is passed through **untouched** (the comparison is
//!   `‖·‖² ≤ τ²`; no value is rewritten), so a clip threshold no
//!   honest client reaches leaves the trajectory bit-identical to the
//!   undefended run.
//! * `median` — coordinate-wise median across the round's committed
//!   messages, over gradient coordinates, `lᵢ`, losses, and every
//!   packed Hessian-update coordinate.
//! * `trimmedmean:F` — coordinate-wise trimmed mean: per coordinate,
//!   the F smallest and F largest contributions are discarded and the
//!   survivors averaged. `F = 0` discards nothing and reproduces the
//!   undefended mean bit for bit (see below). A round whose committed
//!   count m does not satisfy 2F < m aborts loudly.
//!
//! # The sum-equivalent fold
//!
//! The engine's round bookkeeping — `finish_round(committed)` with its
//! single rounding per quantity, the 1/committed first-order scaling
//! and the α/n Hessian weight — is left byte-for-byte untouched.
//! Instead of teaching [`ServerState`](crate::algorithms::ServerState)
//! about robust statistics, [`Defense::aggregate`] compresses the
//! round's m committed messages into **one synthetic message** whose
//! entries are *sum-equivalents*: per coordinate, the robust statistic
//! multiplied back up to sum scale (median·m; trimmed-mean
//! Σkept·(m/(m−2F))), so the engine's mean-of-committed division
//! recovers exactly the robust statistic. Absorbing a single message
//! into the exact superaccumulators is lossless, which is what makes
//! the `trimmedmean:0` ≡ undefended property *bitwise*: the kept-value
//! sum is formed in the same exact accumulator the undefended path
//! uses, the scale factor m/(m−0) is exactly 1.0, and one absorbed
//! f64 re-rounds to itself.
//!
//! Missing compressed coordinates are treated as explicit zeros: a
//! TopK client that did not select packed index j contributed 0 to j
//! in the undefended sum, so the robust order statistics at j see a
//! multiset padded with zeros up to m. (Coordinate-wise median
//! therefore suppresses coordinates fewer than half the clients
//! selected — the correct robust reading of a sparse round.)
//!
//! # Ordering and transports
//!
//! Median and trimmed mean are **not associative**, so the engine
//! forces the atom `RoundMode` while a defense is enabled — shard
//! tiers and mux groups forward per-client atoms exactly as FedNL-PP
//! rounds already do, with no new wire tags. The folds themselves sort
//! by `f64::total_cmp`, and the per-coordinate inputs are fixed sets,
//! so the synthetic message — and hence the trajectory — is
//! bit-identical across SeqPool / ThreadedPool / RemotePool /
//! EventPool under any arrival order. NormClip is per-client and
//! commutes with pre-reduction, so a future relay-side hook could
//! clip *before* `SHARD_SUM` folding and restore O(S) fan-in under
//! it; the present implementation applies every defense at the
//! master's atom fold for uniformity.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::algorithms::ClientMsg;
use crate::compressors::{Compressed, IndexPayload, ValueEncoding};
use crate::linalg::reduce::RepAcc;

/// A server-side robust aggregation rule (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Defense {
    /// Per-client joint L2 clip to the given threshold τ.
    NormClip(f64),
    /// Coordinate-wise median across the round's committed messages.
    Median,
    /// Coordinate-wise trimmed mean discarding the F smallest and F
    /// largest contributions per coordinate.
    TrimmedMean(usize),
}

impl Defense {
    /// Parse a CLI spelling: `normclip:TAU` | `median` |
    /// `trimmedmean:F`.
    pub fn parse(s: &str) -> Result<Self> {
        if s == "median" {
            return Ok(Defense::Median);
        }
        if let Some(t) = s.strip_prefix("normclip:") {
            let tau: f64 = t
                .parse()
                .map_err(|_| anyhow::anyhow!("bad normclip threshold '{t}'"))?;
            ensure!(
                tau.is_finite() && tau > 0.0,
                "normclip threshold must be finite and positive, got '{t}'"
            );
            return Ok(Defense::NormClip(tau));
        }
        if let Some(f) = s.strip_prefix("trimmedmean:") {
            let f: usize = f.parse().map_err(|_| {
                anyhow::anyhow!("bad trimmedmean trim count '{f}'")
            })?;
            return Ok(Defense::TrimmedMean(f));
        }
        bail!(
            "unknown defense '{s}' (expected normclip:TAU | median | \
             trimmedmean:F)"
        )
    }

    /// The canonical CLI spelling (inverse of [`Defense::parse`]).
    pub fn to_spec(self) -> String {
        match self {
            Defense::NormClip(t) => format!("normclip:{t}"),
            Defense::Median => "median".to_string(),
            Defense::TrimmedMean(f) => format!("trimmedmean:{f}"),
        }
    }

    /// Whether the defense transforms messages one at a time (NormClip)
    /// rather than folding the whole round (median / trimmed mean).
    pub fn is_per_client(self) -> bool {
        matches!(self, Defense::NormClip(_))
    }

    /// Robust sum-equivalent of one coordinate's m contributions
    /// (module docs): sorts, applies the order statistic, scales back
    /// to sum scale so the engine's 1/committed division recovers the
    /// statistic. Median/TrimmedMean only.
    fn fold(self, vals: &mut [f64]) -> f64 {
        let m = vals.len();
        vals.sort_unstable_by(|a, b| a.total_cmp(b));
        match self {
            Defense::Median => {
                let med = if m % 2 == 1 {
                    vals[m / 2]
                } else {
                    0.5 * (vals[m / 2 - 1] + vals[m / 2])
                };
                med * m as f64
            }
            Defense::TrimmedMean(f) => {
                // Exact sum of the kept slice; the scale factor is
                // exactly 1.0 when f = 0, so round(Σ)·1.0 is the
                // undefended sum bit for bit.
                let mut acc = RepAcc::new();
                for &v in &vals[f..m - f] {
                    acc.accumulate(v);
                }
                acc.round() * (m as f64 / (m - 2 * f) as f64)
            }
            Defense::NormClip(_) => {
                unreachable!("NormClip is per-client, not a round fold")
            }
        }
    }

    /// How many contributions the defense altered or excluded this
    /// round — the trace's `flagged` column. Median passes only the
    /// middle order statistic(s) through, so it reports m−1;
    /// TrimmedMean discards F from each end (2F); NormClip reports
    /// the clipped-message count from the engine instead.
    fn flagged(self, m: usize) -> u32 {
        match self {
            Defense::Median => (m - 1) as u32,
            Defense::TrimmedMean(f) => (2 * f) as u32,
            Defense::NormClip(_) => 0,
        }
    }

    /// Fold a round's committed messages into one synthetic
    /// sum-equivalent message (module docs) plus the `flagged` count.
    /// Median/TrimmedMean only; the engine applies NormClip per
    /// message via [`clip`].
    ///
    /// The synthetic message carries `client_id = 0` (it is absorbed,
    /// never booked), an `Explicit`/`F64` update with `scale = 1.0`,
    /// and a loss only when every input carried one (mirroring the
    /// undefended `have_loss` rule).
    pub fn aggregate(self, msgs: &[ClientMsg]) -> Result<(ClientMsg, u32)> {
        ensure!(!msgs.is_empty(), "defense fold over an empty round");
        let m = msgs.len();
        if let Defense::TrimmedMean(f) = self {
            ensure!(
                2 * f < m,
                "trimmedmean:{f} needs more than 2·{f} committed \
                 messages, got {m}"
            );
        }
        let d = msgs[0].grad.len();
        let n = msgs[0].update.n;
        for msg in msgs {
            ensure!(
                msg.grad.len() == d && msg.update.n == n,
                "inconsistent message shapes in one round"
            );
        }
        // Gradient coordinates: every message carries all d.
        let mut vals = Vec::with_capacity(m);
        let mut grad = Vec::with_capacity(d);
        for j in 0..d {
            vals.clear();
            vals.extend(msgs.iter().map(|msg| msg.grad[j]));
            grad.push(self.fold(&mut vals));
        }
        // lᵢ, and the loss when every input carried one.
        vals.clear();
        vals.extend(msgs.iter().map(|msg| msg.l_i));
        let l_i = self.fold(&mut vals);
        let loss = if msgs.iter().all(|msg| msg.loss.is_some()) {
            vals.clear();
            vals.extend(msgs.iter().map(|msg| msg.loss.unwrap()));
            Some(self.fold(&mut vals))
        } else {
            None
        };
        // Hessian update: union of the packed indices any message
        // selected, each coordinate's multiset padded with zeros to m
        // (a client that did not select index j contributed 0 there).
        // BTreeMap keeps the synthetic payload in ascending-index
        // order deterministically. Each column remembers the last
        // message that touched it so a duplicated index *within* one
        // message is rejected outright — inferring duplicates from the
        // aggregate column length would miss a double-count whenever
        // some other message skipped that index.
        let mut per_idx: BTreeMap<u32, (usize, Vec<f64>)> = BTreeMap::new();
        for (mi, msg) in msgs.iter().enumerate() {
            for (v, idx) in
                msg.update.values.iter().zip(msg.update.indices())
            {
                let col = per_idx
                    .entry(idx)
                    .or_insert_with(|| (usize::MAX, Vec::new()));
                ensure!(
                    col.0 != mi,
                    "duplicate packed index {idx} within one message"
                );
                col.0 = mi;
                col.1.push(msg.update.scale * v);
            }
        }
        let mut indices = Vec::with_capacity(per_idx.len());
        let mut values = Vec::with_capacity(per_idx.len());
        for (idx, (_, mut col)) in per_idx {
            debug_assert!(col.len() <= m, "column {idx} overfull");
            col.resize(m, 0.0);
            indices.push(idx);
            values.push(self.fold(&mut col));
        }
        let synth = ClientMsg {
            client_id: 0,
            grad,
            update: Compressed {
                payload: IndexPayload::Explicit(indices),
                values,
                scale: 1.0,
                encoding: ValueEncoding::F64,
                n,
            },
            l_i,
            loss,
        };
        Ok((synth, self.flagged(m)))
    }
}

/// NormClip one committed message: γ = min(1, τ/ν) with
/// ν² = ‖grad‖² + Σⱼ(scale·vⱼ)² — the joint L2 norm of everything the
/// message folds into the server state (lᵢ and the loss are scalars
/// the attack model leaves honest; they pass through). Returns `None`
/// when ν ≤ τ — a true no-op, no value is rewritten — otherwise the
/// clipped copy (gradient scaled, `update.scale` scaled; the encoded
/// values stay untouched so wire accounting is unchanged). A
/// non-finite norm (a NaN or ±∞ smuggled into the payload) clips to
/// zero outright — grad, `update.scale`, *and* the encoded values are
/// overwritten with 0.0, because scaling by γ = 0 would leave the
/// poisoned entries in place (NaN·0 = NaN, and the engine absorbs
/// `scale·vⱼ` per packed value).
pub fn clip(msg: &ClientMsg, tau: f64) -> Option<ClientMsg> {
    let mut ss = 0.0f64;
    for g in &msg.grad {
        ss += g * g;
    }
    for v in &msg.update.values {
        let w = msg.update.scale * v;
        ss += w * w;
    }
    if ss <= tau * tau {
        return None;
    }
    let mut out = msg.clone();
    if ss.is_finite() {
        let gamma = tau / ss.sqrt();
        for g in &mut out.grad {
            *g *= gamma;
        }
        out.update.scale *= gamma;
    } else {
        out.grad.fill(0.0);
        out.update.values.fill(0.0);
        out.update.scale = 0.0;
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run_engine, Options, StepPolicy};
    use crate::compressors::by_name;
    use crate::coordinator::SeqPool;
    use crate::data::{generate_synthetic, Dataset, SynthSpec};
    use crate::oracle::LogisticOracle;
    use crate::rng::{shuffle, Pcg64};

    fn msg(
        id: usize,
        grad: Vec<f64>,
        idx: Vec<u32>,
        vals: Vec<f64>,
        scale: f64,
        l_i: f64,
    ) -> ClientMsg {
        ClientMsg {
            client_id: id,
            grad,
            update: Compressed {
                payload: IndexPayload::Explicit(idx),
                values: vals,
                scale,
                encoding: ValueEncoding::F64,
                n: 6,
            },
            l_i,
            loss: None,
        }
    }

    #[test]
    fn parse_round_trips_and_rejects() {
        for spec in ["normclip:2.5", "median", "trimmedmean:1"] {
            let d = Defense::parse(spec).unwrap();
            assert_eq!(d.to_spec(), spec);
            assert_eq!(Defense::parse(&d.to_spec()).unwrap(), d);
        }
        assert_eq!(
            Defense::parse("normclip:10").unwrap(),
            Defense::NormClip(10.0)
        );
        for bad in [
            "", "mean", "medianx", "median:3", "normclip", "normclip:",
            "normclip:abc", "normclip:0", "normclip:-1", "normclip:inf",
            "normclip:NaN", "trimmedmean", "trimmedmean:",
            "trimmedmean:-1", "trimmedmean:1.5", "trimmedmean:abc",
        ] {
            assert!(Defense::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn median_fold_is_permutation_invariant() {
        // Three clients with disjoint sparse updates; shuffling the
        // commit order must not move a bit of the synthetic message.
        let msgs = vec![
            msg(0, vec![1.0, -2.0], vec![0, 3], vec![0.5, 0.25], 2.0, 0.1),
            msg(1, vec![-0.5, 4.0], vec![3, 5], vec![1.5, -0.75], 1.0, 0.3),
            msg(2, vec![100.0, 0.0], vec![0, 5], vec![-9.0, 8.0], 1.0, 0.2),
        ];
        let (base, flagged) = Defense::Median.aggregate(&msgs).unwrap();
        assert_eq!(flagged, 2);
        let mut rng = Pcg64::seed_from_u64(42);
        for _ in 0..8 {
            let mut perm = msgs.clone();
            shuffle(&mut rng, &mut perm);
            let (got, _) = Defense::Median.aggregate(&perm).unwrap();
            assert_eq!(got.grad.len(), base.grad.len());
            for (a, b) in got.grad.iter().zip(&base.grad) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(got.l_i.to_bits(), base.l_i.to_bits());
            assert_eq!(got.update.indices(), base.update.indices());
            for (a, b) in got.update.values.iter().zip(&base.update.values)
            {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // Median sum-equivalents: grad j=0 → median(1,-0.5,100)·3;
        // packed idx 0 is {1.0, -9.0, 0} → median 0·3 = 0.
        assert_eq!(base.grad[0].to_bits(), (1.0f64 * 3.0).to_bits());
        assert_eq!(base.update.indices(), vec![0, 3, 5]);
        assert_eq!(base.update.values[0].to_bits(), 0.0f64.to_bits());
    }

    #[test]
    fn trimmed_mean_discards_extremes() {
        // Five contributions at grad[0]: one huge outlier each side;
        // f=1 keeps {-1, 0, 2} → sum-equivalent 1·(5/3).
        let msgs: Vec<ClientMsg> = [(-1e9, 0), (2.0, 1), (0.0, 2),
            (-1.0, 3), (1e9, 4)]
            .iter()
            .map(|&(g, id)| {
                msg(id, vec![g], vec![0], vec![g], 1.0, 0.0)
            })
            .collect();
        let (synth, flagged) =
            Defense::TrimmedMean(1).aggregate(&msgs).unwrap();
        assert_eq!(flagged, 2);
        let want = 1.0 * (5.0 / 3.0);
        assert_eq!(synth.grad[0].to_bits(), want.to_bits());
        assert_eq!(synth.update.values[0].to_bits(), want.to_bits());
        // f too large for the committed count aborts loudly.
        assert!(Defense::TrimmedMean(2).aggregate(&msgs).is_err());
        assert!(Defense::TrimmedMean(3).aggregate(&msgs).is_err());
    }

    #[test]
    fn trimmed_mean_zero_is_the_exact_sum() {
        // f=0 sum-equivalents must equal the exact RepAcc sum bit for
        // bit — the undefended absorb of the same values.
        let msgs = vec![
            msg(0, vec![0.1, 1e17], vec![1], vec![0.25], 2.0, 0.7),
            msg(1, vec![0.2, 1.0], vec![1, 4], vec![-0.5, 3.0], 1.0, -0.7),
            msg(2, vec![0.3, -1e17], vec![4], vec![1e-3], 4.0, 0.1),
        ];
        let (synth, flagged) =
            Defense::TrimmedMean(0).aggregate(&msgs).unwrap();
        assert_eq!(flagged, 0);
        for j in 0..2 {
            let mut acc = RepAcc::new();
            for m in &msgs {
                acc.accumulate(m.grad[j]);
            }
            assert_eq!(synth.grad[j].to_bits(), acc.round().to_bits());
        }
        // Packed index 1: 2.0·0.25 + 1.0·(−0.5) = 0.
        let mut acc = RepAcc::new();
        acc.accumulate(2.0 * 0.25);
        acc.accumulate(-0.5);
        assert_eq!(synth.update.values[0].to_bits(), acc.round().to_bits());
    }

    #[test]
    fn clip_is_identity_below_threshold() {
        let m = msg(0, vec![3.0, 4.0], vec![2], vec![1.0], 0.5, 1.0);
        // ν² = 9 + 16 + 0.25 = 25.25.
        assert!(clip(&m, 5.025).is_none(), "ν ≈ 5.02 ≤ τ must pass");
        let clipped = clip(&m, 0.5).expect("ν > τ must clip");
        let gamma = 0.5 / 25.25f64.sqrt();
        assert_eq!(clipped.grad[0].to_bits(), (3.0 * gamma).to_bits());
        assert_eq!(clipped.grad[1].to_bits(), (4.0 * gamma).to_bits());
        assert_eq!(
            clipped.update.scale.to_bits(),
            (0.5 * gamma).to_bits()
        );
        // Encoded values and l_i pass through untouched.
        assert_eq!(clipped.update.values, m.update.values);
        assert_eq!(clipped.l_i.to_bits(), m.l_i.to_bits());
        // A NaN payload clips to zero, never propagates — grad,
        // scale, AND encoded values (the engine absorbs scale·vⱼ, and
        // 0·NaN is still NaN, so γ-scaling alone would not disarm it).
        let bad = msg(
            1,
            vec![f64::NAN, 1.0],
            vec![2, 4],
            vec![f64::NAN, f64::INFINITY],
            2.0,
            0.0,
        );
        let z = clip(&bad, 1.0).expect("non-finite ν must clip");
        for g in &z.grad {
            assert_eq!(g.to_bits(), 0.0f64.to_bits());
        }
        assert_eq!(z.update.scale.to_bits(), 0.0f64.to_bits());
        for v in &z.update.values {
            assert_eq!(v.to_bits(), 0.0f64.to_bits());
        }
    }

    #[test]
    fn duplicate_packed_index_within_one_message_rejected() {
        // The duplicate lives at an index no other message selected,
        // so the column never exceeds m entries — only per-message
        // tracking can catch the double count.
        let good = msg(0, vec![1.0], vec![0, 1], vec![1.0, 2.0], 1.0, 0.0);
        let dup = msg(1, vec![1.0], vec![3, 3], vec![1.0, 2.0], 1.0, 0.0);
        for defense in [Defense::Median, Defense::TrimmedMean(0)] {
            let err = defense
                .aggregate(&[good.clone(), dup.clone()])
                .unwrap_err();
            assert!(
                err.to_string().contains("duplicate packed index 3"),
                "unexpected error: {err}"
            );
        }
    }

    fn make_clients(
        n: usize,
        seed: u64,
    ) -> (Vec<crate::algorithms::ClientState>, usize) {
        let spec = SynthSpec {
            d_raw: 7,
            n_samples: n * 24,
            density: 0.6,
            noise: 1.0,
            label_bias: 0.0,
            seed,
        };
        let synth = generate_synthetic(&spec);
        let samples: Vec<crate::data::LibsvmSample> = synth
            .labels
            .iter()
            .zip(&synth.rows)
            .map(|(l, r)| crate::data::LibsvmSample {
                label: *l,
                features: r.clone(),
            })
            .collect();
        let ds = Dataset::from_libsvm(&samples, spec.d_raw);
        let d = ds.d;
        let cs = ds
            .split_even(n)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                crate::algorithms::ClientState::new(
                    i,
                    Box::new(LogisticOracle::new(sh, 1e-3)),
                    by_name("topk", d, 2, seed + i as u64).unwrap(),
                    None,
                )
            })
            .collect();
        (cs, d)
    }

    fn run_with(defense: Option<Defense>) -> Vec<u64> {
        let (cs, d) = make_clients(5, 1234);
        let mut pool = SeqPool::new(cs);
        let opts = Options {
            rounds: 8,
            warm_start: true,
            defense,
            ..Default::default()
        };
        let trace = run_engine(
            &mut pool,
            &opts,
            StepPolicy::Newton,
            vec![0.0; d],
            "robust-prop",
        );
        trace.records.iter().map(|r| r.grad_norm.to_bits()).collect()
    }

    #[test]
    fn huge_normclip_is_bitwise_undefended() {
        // A threshold no honest client reaches: the clip never fires,
        // the atom path equals the sum path by exactness, so the
        // trajectory is the undefended one bit for bit.
        assert_eq!(run_with(None), run_with(Some(Defense::NormClip(1e300))));
    }

    #[test]
    fn trimmed_mean_zero_is_bitwise_undefended() {
        assert_eq!(
            run_with(None),
            run_with(Some(Defense::TrimmedMean(0)))
        );
    }
}
